//! Bench: regenerate Fig. 4 — adapted STREAM on the softcore vs the
//! PicoRV32 baseline model, plus the §4.1/§4.2 38×/144× ratios.
//! `cargo bench --bench fig4_stream [-- --full]`
use simdsoftcore::coordinator::{experiments, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = std::time::Instant::now();
    print!("{}", experiments::fig4(Scale { full, ..Default::default() }).render());
    print!("{}", experiments::fig4_ratios(Scale { full, ..Default::default() }).render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
