//! Bench: regenerate Fig. 6 — the cycle-annotated pipeline diagram of
//! the sorting-in-chunks loop, plus Fig. 5's merge semantics.
//! `cargo bench --bench fig6_pipeline_trace`
use simdsoftcore::coordinator::experiments;

fn main() {
    print!("{}", experiments::fig5().render());
    print!("{}", experiments::fig6());
    print!("{}", experiments::discussion().render());
}
