//! Bench: reference-ISS vs timed-core instruction throughput (host
//! side). The acceptance bar for the differential subsystem is that the
//! architectural-only ISS executes the full workload registry at >= 10x
//! the simulated-instructions-per-host-second of the timed core in
//! `--release` — that margin is what makes lockstep fuzzing and the
//! ISS functional backend cheap enough to run everywhere.
//!
//! `cargo bench --bench iss_throughput`

use simdsoftcore::machine::{Backend, Machine};
use simdsoftcore::util::stats::fmt_count;
use simdsoftcore::workloads::{registry, Scenario};
use std::time::Instant;

struct Row {
    name: String,
    variant: &'static str,
    instrs: u64,
    timed_secs: f64,
    iss_secs: f64,
}

/// Best-of-3 per backend (min is the least-biased estimator on a noisy
/// shared host).
fn measure(machine: &Machine, name: &'static str, sc: &Scenario) -> (u64, f64) {
    let mut best = f64::INFINITY;
    let mut instrs = 0;
    for _ in 0..3 {
        let mut w = simdsoftcore::workloads::lookup(name).expect("registered");
        let t0 = Instant::now();
        let r = machine.run(&mut *w, sc).expect("workload runs");
        best = best.min(t0.elapsed().as_secs_f64());
        assert_eq!(r.verified, Some(true), "{name} must verify on every backend");
        instrs = r.throughput.instret;
    }
    (instrs, best)
}

fn main() {
    let timed = Machine::paper_default();
    let iss = Machine::paper_default().backend(Backend::RefIss);

    let mut rows = Vec::new();
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            // Default sizes are seconds-scale on the timed core; run
            // the registry at a quarter of that (still far beyond cache
            // capacities) so the full matrix stays benchable.
            let size = (probe.default_size() / 4).max(probe.smoke_size());
            let sc = Scenario::new(variant, size);
            let (instrs, timed_secs) = measure(&timed, entry.name, &sc);
            let (iss_instrs, iss_secs) = measure(&iss, entry.name, &sc);
            assert_eq!(instrs, iss_instrs, "{}: backends disagree on instret", entry.name);
            rows.push(Row {
                name: entry.name.to_string(),
                variant: variant.name(),
                instrs,
                timed_secs,
                iss_secs,
            });
        }
    }

    println!("== reference ISS vs timed core throughput (full registry) ==");
    println!(
        "{:<24} {:>8} {:>14} {:>12} {:>12} {:>8}",
        "workload", "variant", "sim instrs", "core Mi/s", "iss Mi/s", "ratio"
    );
    let (mut total_i, mut total_timed, mut total_iss) = (0u64, 0f64, 0f64);
    for r in &rows {
        total_i += r.instrs;
        total_timed += r.timed_secs;
        total_iss += r.iss_secs;
        let core_rate = r.instrs as f64 / r.timed_secs / 1e6;
        let iss_rate = r.instrs as f64 / r.iss_secs / 1e6;
        println!(
            "{:<24} {:>8} {:>14} {:>12.1} {:>12.1} {:>7.1}x",
            r.name,
            r.variant,
            fmt_count(r.instrs),
            core_rate,
            iss_rate,
            iss_rate / core_rate
        );
    }
    let core_rate = total_i as f64 / total_timed / 1e6;
    let iss_rate = total_i as f64 / total_iss / 1e6;
    let ratio = iss_rate / core_rate;
    println!(
        "{:<24} {:>8} {:>14} {:>12.1} {:>12.1} {:>7.1}x",
        "TOTAL",
        "-",
        fmt_count(total_i),
        core_rate,
        iss_rate,
        ratio
    );
    println!();
    if ratio >= 10.0 {
        println!("PASS: ISS runs the registry {ratio:.1}x faster than the timed core (bar: 10x)");
    } else {
        println!("FAIL: ISS/core throughput ratio {ratio:.1}x is below the 10x acceptance bar");
        std::process::exit(1);
    }
}
