//! Bench: reference-ISS vs timed-core instruction throughput, plus the
//! ISS's block engine vs per-instruction dispatch (host side).
//!
//! Two acceptance bars:
//!
//! - the architectural-only ISS executes the full workload registry at
//!   >= 10x the simulated-instructions-per-host-second of the timed
//!   core in `--release` — that margin is what makes lockstep fuzzing
//!   and the ISS functional backend cheap enough to run everywhere;
//! - the cached basic-block engine (DESIGN.md §11) runs dhrystone and
//!   coremark >= 3x faster than per-instruction dispatch on the same
//!   ISS — the engine has to pay for its extra machinery.
//!
//! `cargo bench --bench iss_throughput [-- [--quick] [--json PATH]]`
//!
//! `--quick` skips the (slow) timed-core comparison and shrinks sizes
//! for CI; `--json PATH` writes the engine-comparison table as a JSON
//! document (the `BENCH_exec.json` CI artifact).

use simdsoftcore::machine::{Backend, Machine};
use simdsoftcore::ref_iss::{ExecEngine, RefIss};
use simdsoftcore::service::json::ObjWriter;
use simdsoftcore::util::stats::fmt_count;
use simdsoftcore::workloads::{common, lookup, registry, Scenario};
use std::time::Instant;

const DRAM: usize = 64 * 1024 * 1024;

/// Workloads the block-engine bar is enforced on (the ISS hot paths the
/// cosim and fuzz drivers live in).
const BAR_WORKLOADS: [&str; 2] = ["dhrystone", "coremark"];
const BAR_RATIO: f64 = 3.0;

struct Row {
    name: String,
    variant: &'static str,
    instrs: u64,
    timed_secs: f64,
    iss_secs: f64,
}

struct EngineRow {
    name: String,
    variant: &'static str,
    instrs: u64,
    per_instr_secs: f64,
    blocks_secs: f64,
}

impl EngineRow {
    fn ratio(&self) -> f64 {
        self.per_instr_secs / self.blocks_secs
    }
}

/// Best-of-N per backend (min is the least-biased estimator on a noisy
/// shared host).
fn measure(machine: &Machine, name: &str, sc: &Scenario, reps: usize) -> (u64, f64) {
    let mut best = f64::INFINITY;
    let mut instrs = 0;
    for _ in 0..reps {
        let mut w = lookup(name).expect("registered");
        let t0 = Instant::now();
        let r = machine.run(&mut *w, sc).expect("workload runs");
        best = best.min(t0.elapsed().as_secs_f64());
        assert_eq!(r.verified, Some(true), "{name} must verify on every backend");
        instrs = r.throughput.instret;
    }
    (instrs, best)
}

/// Time only the execute phase of one ISS engine (build/load/predecode
/// excluded — the bar is about dispatch throughput, and the program
/// build cost is identical for both engines anyway).
fn measure_engine(name: &str, sc: &Scenario, engine: ExecEngine, reps: usize) -> (u64, f64) {
    let mut best = f64::INFINITY;
    let mut instrs = 0;
    for _ in 0..reps {
        let mut w = lookup(name).expect("registered");
        let prog = w.build(sc);
        let mut iss = RefIss::new(sc.vlen_bits, DRAM);
        iss.load(&prog).expect("workload image fits bench DRAM");
        for (addr, bytes) in w.init_image() {
            iss.host_write(*addr, bytes).expect("init image fits bench DRAM");
        }
        let t0 = Instant::now();
        let r = iss.run_with(common::MAX_INSTRS, engine).expect("workload runs");
        best = best.min(t0.elapsed().as_secs_f64());
        assert!(w.verify(&iss).is_ok(), "{name} must verify on {engine:?}");
        instrs = r.instret;
    }
    (instrs, best)
}

fn engine_json(rows: &[EngineRow], pass: bool) -> String {
    let mut items = Vec::new();
    for r in rows {
        let mut o = ObjWriter::new();
        o.field_str("workload", &r.name)
            .field_str("variant", r.variant)
            .field_u64("instrs", r.instrs)
            .field_f64("per_instr_secs", r.per_instr_secs)
            .field_f64("blocks_secs", r.blocks_secs)
            .field_f64("per_instr_mips", r.instrs as f64 / r.per_instr_secs / 1e6)
            .field_f64("blocks_mips", r.instrs as f64 / r.blocks_secs / 1e6)
            .field_f64("ratio", r.ratio());
        items.push(o.finish());
    }
    let bar: Vec<String> = BAR_WORKLOADS.iter().map(|w| format!("\"{w}\"")).collect();
    let mut doc = ObjWriter::new();
    doc.field_str("bench", "iss_exec_engines")
        .field_raw("bar_workloads", &format!("[{}]", bar.join(",")))
        .field_f64("bar_ratio", BAR_RATIO)
        .field_raw("rows", &format!("[{}]", items.join(",")))
        .field_bool("pass", pass);
    doc.finish()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| args.get(i + 1).expect("--json requires a path").clone());
    let reps = if quick { 2 } else { 3 };

    // ---- part 1: ISS (block engine) vs timed core, full registry ----
    if !quick {
        let timed = Machine::paper_default();
        let iss = Machine::paper_default().backend(Backend::RefIss);
        let mut rows = Vec::new();
        for entry in registry() {
            let probe = entry.make();
            for &variant in probe.variants() {
                // Default sizes are seconds-scale on the timed core; run
                // the registry at a quarter of that (still far beyond
                // cache capacities) so the full matrix stays benchable.
                let size = (probe.default_size() / 4).max(probe.smoke_size());
                let sc = Scenario::new(variant, size);
                let (instrs, timed_secs) = measure(&timed, entry.name, &sc, reps);
                let (iss_instrs, iss_secs) = measure(&iss, entry.name, &sc, reps);
                assert_eq!(instrs, iss_instrs, "{}: backends disagree on instret", entry.name);
                rows.push(Row {
                    name: entry.name.to_string(),
                    variant: variant.name(),
                    instrs,
                    timed_secs,
                    iss_secs,
                });
            }
        }

        println!("== reference ISS vs timed core throughput (full registry) ==");
        println!(
            "{:<24} {:>8} {:>14} {:>12} {:>12} {:>8}",
            "workload", "variant", "sim instrs", "core Mi/s", "iss Mi/s", "ratio"
        );
        let (mut total_i, mut total_timed, mut total_iss) = (0u64, 0f64, 0f64);
        for r in &rows {
            total_i += r.instrs;
            total_timed += r.timed_secs;
            total_iss += r.iss_secs;
            let core_rate = r.instrs as f64 / r.timed_secs / 1e6;
            let iss_rate = r.instrs as f64 / r.iss_secs / 1e6;
            println!(
                "{:<24} {:>8} {:>14} {:>12.1} {:>12.1} {:>7.1}x",
                r.name,
                r.variant,
                fmt_count(r.instrs),
                core_rate,
                iss_rate,
                iss_rate / core_rate
            );
        }
        let core_rate = total_i as f64 / total_timed / 1e6;
        let iss_rate = total_i as f64 / total_iss / 1e6;
        let ratio = iss_rate / core_rate;
        println!(
            "{:<24} {:>8} {:>14} {:>12.1} {:>12.1} {:>7.1}x",
            "TOTAL",
            "-",
            fmt_count(total_i),
            core_rate,
            iss_rate,
            ratio
        );
        println!();
        if ratio >= 10.0 {
            println!(
                "PASS: ISS runs the registry {ratio:.1}x faster than the timed core (bar: 10x)"
            );
        } else {
            println!("FAIL: ISS/core throughput ratio {ratio:.1}x is below the 10x acceptance bar");
            std::process::exit(1);
        }
        println!();
    }

    // ---- part 2: block engine vs per-instruction dispatch -----------
    let mut erows = Vec::new();
    for name in ["dhrystone", "coremark", "stream-copy", "memcpy", "sort"] {
        let probe = lookup(name).expect("registered");
        for &variant in probe.variants() {
            let divisor = if quick { 16 } else { 4 };
            let size = (probe.default_size() / divisor).max(probe.smoke_size());
            let sc = Scenario::new(variant, size);
            let (instrs, per_instr_secs) =
                measure_engine(name, &sc, ExecEngine::PerInstr, reps);
            let (b_instrs, blocks_secs) = measure_engine(name, &sc, ExecEngine::Blocks, reps);
            assert_eq!(instrs, b_instrs, "{name}: engines disagree on instret");
            erows.push(EngineRow {
                name: name.to_string(),
                variant: variant.name(),
                instrs,
                per_instr_secs,
                blocks_secs,
            });
        }
    }

    println!("== ISS block engine vs per-instruction dispatch ==");
    println!(
        "{:<24} {:>8} {:>14} {:>14} {:>12} {:>8}",
        "workload", "variant", "sim instrs", "per-instr Mi/s", "blocks Mi/s", "speedup"
    );
    for r in &erows {
        println!(
            "{:<24} {:>8} {:>14} {:>14.1} {:>12.1} {:>7.1}x",
            r.name,
            r.variant,
            fmt_count(r.instrs),
            r.instrs as f64 / r.per_instr_secs / 1e6,
            r.instrs as f64 / r.blocks_secs / 1e6,
            r.ratio()
        );
    }
    println!();

    let mut pass = true;
    for bar in BAR_WORKLOADS {
        for r in erows.iter().filter(|r| r.name == bar) {
            if r.ratio() >= BAR_RATIO {
                println!(
                    "PASS: {} ({}) block engine is {:.1}x per-instruction dispatch (bar: {BAR_RATIO}x)",
                    r.name,
                    r.variant,
                    r.ratio()
                );
            } else {
                println!(
                    "FAIL: {} ({}) block-engine speedup {:.1}x is below the {BAR_RATIO}x bar",
                    r.name,
                    r.variant,
                    r.ratio()
                );
                pass = false;
            }
        }
    }

    if let Some(path) = json_path {
        let doc = engine_json(&erows, pass);
        std::fs::write(&path, format!("{doc}\n")).expect("write --json output");
        println!("wrote {path}");
    }
    if !pass {
        std::process::exit(1);
    }
}
