//! Bench: simulator hot-path throughput (host-side performance, §Perf in
//! EXPERIMENTS.md). Measures simulated instructions per host second on
//! the workloads that dominate experiment wall time.
//!
//! `cargo bench --bench sim_hotpath`

use simdsoftcore::core::Core;
use simdsoftcore::util::stats::fmt_count;
use simdsoftcore::workloads::{memcpy, sort, stream};
use std::time::Instant;

struct Row {
    name: &'static str,
    sim_instrs: u64,
    sim_cycles: u64,
    host_secs: f64,
}

/// Best-of-3 (the shared host is noisy; min is the least-biased
/// estimator of the true cost).
fn measure(name: &'static str, f: impl Fn() -> (u64, u64)) -> Row {
    let mut best = f64::INFINITY;
    let mut out = (0, 0);
    for _ in 0..3 {
        let t0 = Instant::now();
        out = f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Row { name, sim_instrs: out.0, sim_cycles: out.1, host_secs: best }
}

fn main() {
    let rows = vec![
        measure("alu loop (dhrystone-like x2000)", || {
            let mut core = Core::paper_default();
            let r =
                simdsoftcore::workloads::cpubench::run_dhrystone_like(&mut core, 2000).unwrap();
            (r.instret, r.cycles)
        }),
        measure("vector memcpy 16 MiB", || {
            let mut core = Core::paper_default();
            let r = memcpy::run(&mut core, 16 * 1024 * 1024, true).unwrap();
            (r.throughput.instret, r.throughput.cycles)
        }),
        measure("scalar memcpy 4 MiB", || {
            let mut core = Core::paper_default();
            let r = memcpy::run(&mut core, 4 * 1024 * 1024, false).unwrap();
            (r.throughput.instret, r.throughput.cycles)
        }),
        measure("STREAM Triad 1M elems", || {
            let mut core = Core::paper_default();
            let r = stream::run(&mut core, stream::Kernel::Triad, 1024 * 1024, false).unwrap();
            (r.throughput.instret, r.throughput.cycles)
        }),
        measure("qsort 64K elems", || {
            let mut core = Core::paper_default();
            let r = sort::run_qsort(&mut core, 64 * 1024).unwrap();
            (r.throughput.instret, r.throughput.cycles)
        }),
        measure("vector mergesort 256K elems", || {
            let mut core = Core::paper_default();
            let r = sort::run_vector_mergesort(&mut core, 256 * 1024).unwrap();
            (r.throughput.instret, r.throughput.cycles)
        }),
    ];

    println!("== simulator hot-path throughput ==");
    println!(
        "{:<34} {:>16} {:>16} {:>10} {:>12} {:>12}",
        "workload", "sim instrs", "sim cycles", "host s", "Minstr/s", "Mcycle/s"
    );
    let mut total_i = 0u64;
    let mut total_t = 0f64;
    for r in &rows {
        total_i += r.sim_instrs;
        total_t += r.host_secs;
        println!(
            "{:<34} {:>16} {:>16} {:>10.3} {:>12.1} {:>12.1}",
            r.name,
            fmt_count(r.sim_instrs),
            fmt_count(r.sim_cycles),
            r.host_secs,
            r.sim_instrs as f64 / r.host_secs / 1e6,
            r.sim_cycles as f64 / r.host_secs / 1e6,
        );
    }
    println!(
        "aggregate: {:.1} M simulated instructions / host second",
        total_i as f64 / total_t / 1e6
    );
}
