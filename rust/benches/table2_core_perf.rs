//! Bench: regenerate Table 2 — DMIPS/MHz and CoreMark/MHz (derived from
//! measured IPC; see workloads::cpubench for the derivation constants).
//! `cargo bench --bench table2_core_perf`
use simdsoftcore::coordinator::experiments;

fn main() {
    let t0 = std::time::Instant::now();
    print!("{}", experiments::table2().render());
    print!("{}", experiments::table1().render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
