//! Bench: regenerate Fig. 3 (left) — memcpy throughput vs LLC block size.
//! `cargo bench --bench fig3_llc_block_sweep [-- --full]`
use simdsoftcore::coordinator::{experiments, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = std::time::Instant::now();
    let table = experiments::fig3_left(Scale { full, ..Default::default() });
    print!("{}", table.render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
