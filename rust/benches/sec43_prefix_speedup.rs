//! Bench: regenerate §4.3.2 — c3_prefix vs the serial loop and vs the
//! calibrated ARM A53 model.
//! `cargo bench --bench sec43_prefix_speedup [-- --full]`
use simdsoftcore::coordinator::{experiments, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = std::time::Instant::now();
    print!("{}", experiments::sec43_prefix(Scale { full, ..Default::default() }).render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
