//! Bench: ablations of the §3.1 design choices DESIGN.md calls out.
//!
//! 1. **Replacement policy** (§3.1): the paper asserts "a random policy
//!    would stagnate the bandwidth for memory copying, when the source
//!    and destination are aligned" — NRU vs Random on aligned memcpy.
//! 2. **Double-rate interconnect** (§3.1.4).
//! 3. **No-fetch-on-full-block-write** (§3.1.1) — approximated by
//!    comparing vector memcpy (full-block stores) against a scalar-store
//!    copy of the same volume, which must fetch destination blocks.
//! 4. **Sub-blocked LLC / critical-sub-block-first** (§3.1.3) —
//!    burst-setup sensitivity as a proxy for serving L1 early.
//!
//! `cargo bench --bench ablations`

use simdsoftcore::core::{Core, CoreConfig};
use simdsoftcore::mem::{MemConfig, Replacement};
use simdsoftcore::workloads::memcpy;

fn rate(mut mem: MemConfig, bytes: usize) -> f64 {
    mem.dram.size_bytes = 192 * 1024 * 1024;
    let mut core = Core::new(CoreConfig::paper_default(), mem);
    let r = memcpy::run(&mut core, bytes, true).expect("memcpy runs");
    assert!(r.verified);
    r.throughput.bytes_per_second() / 1e9
}

fn main() {
    let bytes = if std::env::args().any(|a| a == "--full") {
        64 * 1024 * 1024
    } else {
        8 * 1024 * 1024
    };
    println!("== ablations: §3.1 design choices (memcpy {} MiB, VLEN=256) ==", bytes >> 20);

    // (1) replacement policy, aligned src/dst (the paper's claim).
    let nru = rate(MemConfig::paper_default(), bytes);
    let mut random = MemConfig::paper_default();
    random.replacement = Replacement::Random;
    let rnd = rate(random, bytes);
    println!("replacement   : NRU {nru:.2} GB/s vs Random {rnd:.2} GB/s  (NRU/Random = {:.2}×)", nru / rnd);

    // (2) interconnect rate.
    let mut single = MemConfig::paper_default();
    single.dram.double_rate = false;
    let sr = rate(single, bytes);
    println!("interconnect  : double-rate {nru:.2} GB/s vs single-rate {sr:.2} GB/s  ({:.2}×)", nru / sr);

    // (3) §3.1.1 no-fetch: vector (full-block stores) vs scalar copy.
    let small = bytes.min(4 * 1024 * 1024);
    let mut vcore = Core::paper_default();
    memcpy::run(&mut vcore, small, true).expect("vector");
    let anf = vcore.mem.stats().dl1.alloc_no_fetch;
    let mut score = Core::paper_default();
    let scalar = memcpy::run(&mut score, small, false).expect("scalar");
    println!(
        "full-block st : vector path allocated {anf} blocks without fetch (= every store); \
         scalar copy (partial-block stores, must fetch) {:.2} GB/s",
        scalar.throughput.bytes_per_second() / 1e9
    );

    // (4) burst setup sensitivity (proxy for §3.1.3's early service).
    for setup in [5u64, 20, 60] {
        let mut m = MemConfig::paper_default();
        m.dram.burst_setup_cycles = setup;
        println!("burst setup {setup:>3}: {:.2} GB/s", rate(m, bytes));
    }
    println!("\npaper claims: NRU chosen over random for streaming (§3.1); double");
    println!("rate 'saturates the bandwidth more easily' (§3.1.4); full-block");
    println!("writes avoid the fetch (§3.1.1); longer bursts amortise setup (§3.1.2).");
}
