//! Bench: regenerate §4.3.1 — vector mergesort vs qsort() on the
//! softcore and vs the calibrated ARM A53 model.
//! `cargo bench --bench sec43_sort_speedup [-- --full]`
//! (--full sorts the paper's 16M elements; takes minutes of host time.)
use simdsoftcore::coordinator::{experiments, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = std::time::Instant::now();
    print!("{}", experiments::sec43_sort(Scale { full, ..Default::default() }).render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
