//! Bench: simulated cycle counts vs issue width (the `pipe-sweep`
//! curve, bench-shaped). Width 1 is the paper's single-issue pipeline;
//! the acceptance bar is a >= 15% cycle reduction at width 2 on the
//! dhrystone-like cpubench kernel and scalar STREAM copy, with
//! architectural results (instret, verify) identical at every width.
//!
//! `cargo bench --bench pipeline_width`

use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::{lookup, Scenario, Variant};

fn main() {
    let rows: [(&str, Variant, usize); 5] = [
        ("dhrystone", Variant::Scalar, 300),
        ("coremark", Variant::Scalar, 100),
        ("stream-copy", Variant::Scalar, 256 * 1024),
        ("memcpy", Variant::Vector, 4 * 1024 * 1024),
        ("prefix", Variant::Vector, 256 * 1024),
    ];

    println!("== cycles vs issue width ==");
    println!(
        "{:<14} {:>8} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "workload", "variant", "w1 cycles", "w2 cycles", "w4 cycles", "w2 gain", "w4 gain"
    );
    let mut ok = true;
    for (name, variant, size) in rows {
        let sc = Scenario::new(variant, size);
        let run = |width: usize| {
            let mut w = lookup(name).expect("registered workload");
            let r = Machine::paper_default()
                .issue_width(width)
                .run(&mut *w, &sc)
                .expect("workload runs");
            assert_eq!(r.verified, Some(true), "{name} width {width}");
            r.throughput
        };
        let (w1, w2, w4) = (run(1), run(2), run(4));
        assert_eq!(w1.instret, w2.instret, "{name}: instret must not depend on width");
        assert_eq!(w1.instret, w4.instret, "{name}: instret must not depend on width");
        let gain2 = 1.0 - w2.cycles as f64 / w1.cycles as f64;
        let gain4 = 1.0 - w4.cycles as f64 / w1.cycles as f64;
        println!(
            "{:<14} {:>8} {:>12} {:>12} {:>12} {:>7.1}% {:>7.1}%",
            name,
            variant.name(),
            w1.cycles,
            w2.cycles,
            w4.cycles,
            gain2 * 100.0,
            gain4 * 100.0
        );
        if matches!(name, "dhrystone" | "stream-copy") && gain2 < 0.15 {
            ok = false;
        }
    }
    println!();
    if ok {
        println!("PASS: dual issue saves >= 15% on dhrystone and stream-copy (bar: 15%)");
    } else {
        println!("FAIL: dual issue saved < 15% on dhrystone or stream-copy");
        std::process::exit(1);
    }
}
