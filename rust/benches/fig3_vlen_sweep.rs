//! Bench: regenerate Fig. 3 (right) — memcpy throughput vs vector width.
//! `cargo bench --bench fig3_vlen_sweep [-- --full]`
use simdsoftcore::coordinator::{experiments, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = std::time::Instant::now();
    let table = experiments::fig3_right(Scale { full, ..Default::default() });
    print!("{}", table.render());
    print!("{}", experiments::memcpy_headline(Scale { full, ..Default::default() }).render());
    println!("(host wall time: {:.2?})", t0.elapsed());
}
