//! Control-flow graph recovery over a predecoded text segment.
//!
//! The CFG is built from the same [`DecodeCache`] view both execution
//! backends fetch from (DESIGN.md §12): leaders are the entry pc, every
//! direct branch/jal target, every statically resolved jalr target, the
//! word after every block terminator, and every undecodable word. A
//! basic block runs from a leader to the next terminator or leader.
//!
//! Indirect jumps (`jalr`) get an edge only when constant propagation
//! pins their target (see [`crate::analysis::dataflow`]); an unresolved
//! `jalr` is a CFG sink, which is the analyzer's main documented source
//! of unsoundness (unreachable-block findings downstream of it are
//! conservative, never the absence of an error finding on a path the
//! CFG does know about).

use std::collections::HashMap;

use crate::isa::{DecodeCache, Instr};

/// Why a basic block ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminator {
    /// Conditional branch; falls through to the next word when not taken.
    Branch { target: u32 },
    /// Unconditional `jal`.
    Jump { target: u32 },
    /// `jalr`. `resolved` is the post-mask (`& !1`) target when constant
    /// propagation pinned the base register, else `None`.
    Indirect { resolved: Option<u32> },
    /// `ecall` — clean halt.
    Halt,
    /// `ebreak` — raises a Break fault.
    Break,
    /// The block is a single undecodable word; fetching it faults.
    Illegal,
    /// The next word is a leader of another block.
    FallThrough,
    /// The last text word is not a terminator: execution runs off the
    /// end of the text segment.
    FallOff,
}

/// A basic block of `ninstr` decoded instructions starting at word
/// index `start`. An [`Terminator::Illegal`] block has `ninstr == 0`
/// and spans exactly one (undecodable) word.
#[derive(Debug, Clone)]
pub struct BasicBlock {
    pub start: usize,
    pub ninstr: usize,
    pub term: Terminator,
    pub succs: Vec<usize>,
    pub reachable: bool,
}

impl BasicBlock {
    /// Words consumed by the block.
    pub fn span(&self) -> usize {
        self.ninstr.max(1)
    }

    /// pc of the first word.
    pub fn pc(&self, base: u32) -> u32 {
        base.wrapping_add((self.start as u32) * 4)
    }

    /// pc of the terminator instruction (or of the undecodable word for
    /// an [`Terminator::Illegal`] block).
    pub fn term_pc(&self, base: u32) -> u32 {
        let last = self.start + self.ninstr.saturating_sub(1);
        base.wrapping_add((last as u32) * 4)
    }
}

/// Recovered control-flow graph.
#[derive(Debug)]
pub struct Cfg {
    pub blocks: Vec<BasicBlock>,
    /// Owning block id for every text word.
    pub block_at: Vec<usize>,
    /// Block containing the entry pc, if the entry is a valid text pc.
    pub entry_block: Option<usize>,
    pub base: u32,
    pub nwords: usize,
}

/// Direct control-transfer target of `i` at `pc`, if it is a branch or
/// jal (jalr is indirect and returns `None`).
pub fn direct_target(i: &Instr, pc: u32) -> Option<u32> {
    use Instr::*;
    match *i {
        Jal { offset, .. }
        | Beq { offset, .. }
        | Bne { offset, .. }
        | Blt { offset, .. }
        | Bge { offset, .. }
        | Bltu { offset, .. }
        | Bgeu { offset, .. } => Some(pc.wrapping_add(offset as u32)),
        _ => None,
    }
}

fn classify(
    i: &Instr,
    pc: u32,
    jalr_targets: &HashMap<usize, u32>,
    idx: usize,
) -> Option<Terminator> {
    use Instr::*;
    match *i {
        Jal { offset, .. } => Some(Terminator::Jump { target: pc.wrapping_add(offset as u32) }),
        Jalr { .. } => Some(Terminator::Indirect { resolved: jalr_targets.get(&idx).copied() }),
        Beq { offset, .. }
        | Bne { offset, .. }
        | Blt { offset, .. }
        | Bge { offset, .. }
        | Bltu { offset, .. }
        | Bgeu { offset, .. } => {
            Some(Terminator::Branch { target: pc.wrapping_add(offset as u32) })
        }
        Ecall => Some(Terminator::Halt),
        Ebreak => Some(Terminator::Break),
        _ => None,
    }
}

impl Cfg {
    /// Recover the CFG from `cache`, entering at `entry`.
    /// `extra_leaders` are resolved jalr targets from a previous
    /// constant-propagation round; `jalr_targets` maps the word index of
    /// a `jalr` to its resolved (masked) target.
    pub fn build(
        cache: &DecodeCache,
        entry: u32,
        extra_leaders: &[u32],
        jalr_targets: &HashMap<usize, u32>,
    ) -> Cfg {
        let n = cache.len();
        let base = cache.base();
        let mut leader = vec![false; n];
        let mark = |leader: &mut Vec<bool>, pc: u32| {
            if let Some(idx) = cache.word_index(pc) {
                leader[idx] = true;
            }
        };
        mark(&mut leader, entry);
        for &pc in extra_leaders {
            mark(&mut leader, pc);
        }
        for idx in 0..n {
            let pc = base.wrapping_add((idx as u32) * 4);
            match cache.get(idx) {
                None => {
                    // Undecodable words form their own single-word blocks.
                    leader[idx] = true;
                    if idx + 1 < n {
                        leader[idx + 1] = true;
                    }
                }
                Some(i) => {
                    if classify(&i, pc, jalr_targets, idx).is_some() {
                        if idx + 1 < n {
                            leader[idx + 1] = true;
                        }
                        if let Some(t) = direct_target(&i, pc) {
                            mark(&mut leader, t);
                        }
                    }
                }
            }
        }

        // Form blocks by linear sweep.
        let mut blocks: Vec<BasicBlock> = Vec::new();
        let mut block_at = vec![0usize; n];
        let mut idx = 0;
        while idx < n {
            let start = idx;
            let id = blocks.len();
            let term;
            let mut ninstr = 0;
            if cache.get(idx).is_none() {
                term = Terminator::Illegal;
                idx += 1;
            } else {
                loop {
                    let pc = base.wrapping_add((idx as u32) * 4);
                    // A decoded run never crosses a leader, so `get` is Some.
                    let i = cache.get(idx).expect("leader marking keeps runs decodable");
                    ninstr += 1;
                    idx += 1;
                    if let Some(t) = classify(&i, pc, jalr_targets, idx - 1) {
                        term = t;
                        break;
                    }
                    if idx == n {
                        term = Terminator::FallOff;
                        break;
                    }
                    if leader[idx] {
                        term = Terminator::FallThrough;
                        break;
                    }
                }
            }
            for w in start..idx {
                block_at[w] = id;
            }
            blocks.push(BasicBlock { start, ninstr, term, succs: Vec::new(), reachable: false });
        }

        // Successor edges. Every valid in-text target is a leader by
        // construction, so its word index is a block start.
        let text_block = |pc: u32| -> Option<usize> { cache.word_index(pc).map(|w| block_at[w]) };
        for b in blocks.iter_mut() {
            let end = b.start + b.span();
            let mut succs = Vec::new();
            match b.term {
                Terminator::Branch { target } => {
                    if let Some(t) = text_block(target) {
                        succs.push(t);
                    }
                    if end < n {
                        succs.push(block_at[end]);
                    }
                }
                Terminator::Jump { target } => {
                    if let Some(t) = text_block(target) {
                        succs.push(t);
                    }
                }
                Terminator::Indirect { resolved: Some(t) } => {
                    if let Some(t) = text_block(t) {
                        succs.push(t);
                    }
                }
                Terminator::FallThrough => {
                    succs.push(block_at[end]);
                }
                Terminator::Indirect { resolved: None }
                | Terminator::Halt
                | Terminator::Break
                | Terminator::Illegal
                | Terminator::FallOff => {}
            }
            succs.dedup();
            b.succs = succs;
        }

        let entry_block = cache.word_index(entry).map(|w| block_at[w]);
        let mut cfg = Cfg { blocks, block_at, entry_block, base, nwords: n };
        cfg.mark_reachable();
        cfg
    }

    fn mark_reachable(&mut self) {
        let Some(e) = self.entry_block else { return };
        let mut stack = vec![e];
        while let Some(b) = stack.pop() {
            if self.blocks[b].reachable {
                continue;
            }
            self.blocks[b].reachable = true;
            stack.extend(self.blocks[b].succs.iter().copied());
        }
    }

    /// Decoded instructions of `b` with their pcs.
    pub fn instrs<'a>(
        &'a self,
        cache: &'a DecodeCache,
        b: &'a BasicBlock,
    ) -> impl Iterator<Item = (u32, Instr)> + 'a {
        (b.start..b.start + b.ninstr).map(move |w| {
            let pc = self.base.wrapping_add((w as u32) * 4);
            (pc, cache.get(w).expect("block instr decoded"))
        })
    }

    /// pc one past the last text word.
    pub fn text_end(&self) -> u32 {
        self.base.wrapping_add((self.nwords as u32) * 4)
    }

    /// True when the exit state of `b` cannot be summarized by its CFG
    /// successors (halt, fault, unresolved indirect, or a possible
    /// transfer outside the text segment). Liveness treats every
    /// register as live across such exits.
    pub fn exit_unknown(&self, b: &BasicBlock) -> bool {
        let in_text = |pc: u32| -> bool { pc % 4 == 0 && self.in_text(pc) };
        match b.term {
            Terminator::Halt | Terminator::Break | Terminator::Illegal | Terminator::FallOff => {
                true
            }
            Terminator::Indirect { resolved } => !resolved.is_some_and(in_text),
            Terminator::Branch { target } => !in_text(target) || b.start + b.span() >= self.nwords,
            Terminator::Jump { target } => !in_text(target),
            Terminator::FallThrough => false,
        }
    }

    fn in_text(&self, pc: u32) -> bool {
        let off = pc.wrapping_sub(self.base);
        off % 4 == 0 && (off / 4) < self.nwords as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    fn cfg_of(text: &[u32], base: u32) -> Cfg {
        let mut cache = DecodeCache::empty();
        cache.predecode(base, text);
        Cfg::build(&cache, base, &[], &HashMap::new())
    }

    fn assemble(f: impl FnOnce(&mut Asm)) -> (DecodeCache, Cfg) {
        let mut a = Asm::new();
        f(&mut a);
        let prog = a.assemble().expect("fixture assembles");
        let mut cache = DecodeCache::empty();
        cache.predecode(prog.text_base, &prog.text);
        let cfg = Cfg::build(&cache, prog.entry, &[], &HashMap::new());
        (cache, cfg)
    }

    #[test]
    fn straight_line_is_one_block() {
        let (_, cfg) = assemble(|a| {
            a.li(A0, 7);
            a.li(A1, 9);
            a.halt();
        });
        assert_eq!(cfg.blocks.len(), 1);
        let b = &cfg.blocks[0];
        assert!(b.reachable);
        assert_eq!(b.term, Terminator::Halt);
    }

    #[test]
    fn branch_splits_blocks_and_links_edges() {
        let (_, cfg) = assemble(|a| {
            let skip = a.new_label("skip");
            a.li(A0, 1);
            a.bnez(A0, skip);
            a.li(A1, 2);
            a.bind(skip);
            a.halt();
        });
        // li-block+bnez | li a1 | halt
        assert_eq!(cfg.blocks.len(), 3);
        let head = &cfg.blocks[0];
        assert!(matches!(head.term, Terminator::Branch { .. }));
        assert_eq!(head.succs.len(), 2);
        assert!(cfg.blocks.iter().all(|b| b.reachable));
    }

    #[test]
    fn jal_skipped_code_is_unreachable() {
        let (_, cfg) = assemble(|a| {
            let end = a.new_label("end");
            a.j(end);
            a.li(A0, 1); // skipped
            a.bind(end);
            a.halt();
        });
        let unreachable: Vec<_> = cfg.blocks.iter().filter(|b| !b.reachable).collect();
        assert_eq!(unreachable.len(), 1);
        assert!(matches!(unreachable[0].term, Terminator::FallThrough));
    }

    #[test]
    fn undecodable_word_forms_illegal_block() {
        // addi a0,zero,1 ; <garbage> ; ecall
        let text = [0x0010_0513, 0xffff_ffff, 0x0000_0073];
        let cfg = cfg_of(&text, 0x1000);
        assert_eq!(cfg.blocks.len(), 3);
        assert_eq!(cfg.blocks[0].term, Terminator::FallThrough);
        assert_eq!(cfg.blocks[1].term, Terminator::Illegal);
        assert_eq!(cfg.blocks[1].ninstr, 0);
        assert!(cfg.blocks[1].reachable, "fallthrough reaches the illegal word");
    }

    #[test]
    fn last_word_without_terminator_falls_off() {
        // addi a0,zero,1 (no halt)
        let cfg = cfg_of(&[0x0010_0513], 0x1000);
        assert_eq!(cfg.blocks.len(), 1);
        assert_eq!(cfg.blocks[0].term, Terminator::FallOff);
    }

    #[test]
    fn resolved_jalr_gets_edge() {
        let (_cache, mut cfg_unresolved) = assemble(|a| {
            a.li(T6, 0x1000);
            a.emit(crate::isa::Instr::Jalr { rd: ZERO, rs1: T6, offset: 8 });
            a.halt();
        });
        // Without resolution the jalr is a sink.
        let jalr_block = cfg_unresolved
            .blocks
            .iter_mut()
            .find(|b| matches!(b.term, Terminator::Indirect { .. }))
            .unwrap();
        assert!(jalr_block.succs.is_empty());
    }
}
