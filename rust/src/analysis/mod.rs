//! Static guest-program analyzer (DESIGN.md §12): CFG recovery over the
//! predecoded text segment plus dataflow lints for RV32IM and the
//! paper's I′/S′ SIMD instruction types.
//!
//! The analyzer answers "is this program structurally broken?" before a
//! single instruction executes: uninitialized scalar/vector/carry reads,
//! dead writes, constant-folded out-of-DRAM or misaligned accesses,
//! stores that overlap the text segment (static SMC), branches out of
//! text, wild/misaligned indirect jumps, unreachable blocks, and
//! fall-off-the-end-of-text paths. Error-severity findings are tied to
//! the lint-oracle property checked in `tests/analysis_oracle.rs`: a
//! program the analyzer passes with **zero errors** runs to a clean
//! exit on [`crate::ref_iss::RefIss`] for every fuzzer preset.
//!
//! Known-unsound corners (documented, by design): an unresolved `jalr`
//! is a CFG sink; resolved indirect targets are best-effort constants;
//! self-modifying stores are reported but their *patched* program is
//! not analyzed; and a pc inside DRAM but outside the text segment is
//! flagged as an error even though the architecture will happily fetch
//! raw bytes there (the gap is zero-filled, so it faults in practice).

pub mod cfg;
pub mod dataflow;
pub mod perf;
pub mod sched;

use std::collections::HashMap;
use std::fmt;

use crate::asm::Program;
use crate::isa::DecodeCache;
use crate::mem::config::MemConfig;

pub use cfg::{BasicBlock, Cfg, Terminator};
pub use dataflow::{effects, ConstState, Effects, InitState, Interval, LiveState, MemRef};
pub use perf::{
    analyze_perf, BlockCost, CostSim, MemTiming, PerfModel, PerfReport, StallEvent, StallKind,
};
pub use sched::{schedule_program, verify_schedule, ScheduleOutcome};

/// How many instructions of disassembly context a finding carries.
const CONTEXT_WINDOW: usize = 4;
/// Cap on jalr-resolution/CFG-rebuild rounds (each round resolves at
/// least one new indirect target or stops).
const MAX_RESOLVE_ROUNDS: usize = 64;

/// Severity of a finding. Errors are the machine-checked tier: the
/// lint oracle asserts that zero-error programs run clean on the ISS.
/// `Perf` findings (the stall-attribution lints from [`perf`]) never
/// affect correctness — they explain cycles, not faults — and are only
/// produced by the dedicated perf entry points, never by
/// [`analyze_program`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Error,
    Warning,
    Perf,
}

/// Kind of a finding. The severity split is part of the analyzer's
/// contract (see [`Severity`]): everything the architecture *faults on*
/// (or that prevents loading) is an error; everything it tolerates but
/// almost certainly indicates a broken program is a warning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FindingKind {
    /// Text or data segment does not fit in DRAM; loading faults.
    ImageOverflow,
    /// Entry pc is not a word-aligned text address.
    EntryOutOfText,
    /// A reachable word does not decode; fetching it faults.
    IllegalWord,
    /// A reachable `ebreak` raises a Break fault.
    UnexpectedBreak,
    /// Execution can run past the last text word.
    FallOffEnd,
    /// Direct branch/jal target outside the text segment.
    BranchOutOfText,
    /// Branch/jump target is not word-aligned; fetching it faults.
    MisalignedTarget,
    /// Resolved indirect jump leaves DRAM entirely.
    WildJump,
    /// Constant-folded access past the end of DRAM.
    OutOfDramAccess,
    /// Custom slot/funct3 pair the standard unit pool rejects.
    UnknownCustomOp,
    /// Store whose byte range overlaps the text segment (static SMC).
    StoreToText,
    /// Constant-folded access not naturally aligned (tolerated by the
    /// memory system, but usually a bug in address arithmetic).
    MisalignedAccess,
    /// Read of a scalar register never written on some path from entry.
    UninitScalarRead,
    /// Read of a vector register never written on some path from entry.
    UninitVectorRead,
    /// `c3` prefix/carry read before any `c3` op defined the carry.
    UninitCarryRead,
    /// Scalar register written but never read afterwards.
    DeadWrite,
    /// Vector register written but never read afterwards.
    DeadVectorWrite,
    /// Block not reachable from the entry pc.
    UnreachableBlock,
    /// A dependent instruction waits on a load's result inside the
    /// load-use window (perf: the bubble a scheduler can often hide).
    LoadUseBubble,
    /// An instruction waits for an earlier in-flight write to the same
    /// destination register to retire (WAW ordering).
    WawWait,
    /// An issue group closed early (stall, or a serialising div/mul)
    /// and dual-issue slots went unused.
    WastedIssueSlot,
    /// Two ops contended for a SIMD unit's one-issue-per-cycle slot.
    UnitConflict,
}

impl FindingKind {
    pub fn severity(self) -> Severity {
        use FindingKind::*;
        match self {
            ImageOverflow | EntryOutOfText | IllegalWord | UnexpectedBreak | FallOffEnd
            | BranchOutOfText | MisalignedTarget | WildJump | OutOfDramAccess
            | UnknownCustomOp => Severity::Error,
            StoreToText | MisalignedAccess | UninitScalarRead | UninitVectorRead
            | UninitCarryRead | DeadWrite | DeadVectorWrite | UnreachableBlock => {
                Severity::Warning
            }
            LoadUseBubble | WawWait | WastedIssueSlot | UnitConflict => Severity::Perf,
        }
    }

    pub fn name(self) -> &'static str {
        use FindingKind::*;
        match self {
            ImageOverflow => "image-overflow",
            EntryOutOfText => "entry-out-of-text",
            IllegalWord => "illegal-word",
            UnexpectedBreak => "unexpected-break",
            FallOffEnd => "fall-off-end",
            BranchOutOfText => "branch-out-of-text",
            MisalignedTarget => "misaligned-target",
            WildJump => "wild-jump",
            OutOfDramAccess => "out-of-dram-access",
            UnknownCustomOp => "unknown-custom-op",
            StoreToText => "store-to-text",
            MisalignedAccess => "misaligned-access",
            UninitScalarRead => "uninit-scalar-read",
            UninitVectorRead => "uninit-vector-read",
            UninitCarryRead => "uninit-carry-read",
            DeadWrite => "dead-write",
            DeadVectorWrite => "dead-vector-write",
            UnreachableBlock => "unreachable-block",
            LoadUseBubble => "load-use-bubble",
            WawWait => "waw-wait",
            WastedIssueSlot => "wasted-issue-slot",
            UnitConflict => "unit-conflict",
        }
    }
}

/// One pc-anchored finding with a disassembly context window (same
/// rendering as the cosim divergence report).
#[derive(Debug, Clone)]
pub struct Finding {
    pub kind: FindingKind,
    pub pc: u32,
    pub message: String,
    pub context: Vec<String>,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} [{}] {:#010x}: {}",
            match self.kind.severity() {
                Severity::Error => "error  ",
                Severity::Warning => "warning",
                Severity::Perf => "perf   ",
            },
            self.kind.name(),
            self.pc,
            self.message
        )
    }
}

/// One data-memory reference seen during the constant-propagation
/// sweep. `addr` is the folded absolute address when every operand was
/// a known constant.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    pub pc: u32,
    pub addr: Option<u32>,
    pub len: usize,
    pub store: bool,
}

/// Analyzer output: findings plus CFG statistics and the memory-access
/// evidence the fuzzer invariant tests assert over.
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub blocks: usize,
    pub reachable_blocks: usize,
    pub instrs: usize,
    pub accesses: Vec<Access>,
}

impl Report {
    pub fn errors(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.kind.severity() == Severity::Error)
    }

    pub fn warnings(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.kind.severity() == Severity::Warning)
    }

    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    pub fn warning_count(&self) -> usize {
        self.warnings().count()
    }

    pub fn perf_findings(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.kind.severity() == Severity::Perf)
    }

    pub fn perf_count(&self) -> usize {
        self.perf_findings().count()
    }

    pub fn is_clean(&self) -> bool {
        self.error_count() == 0
    }

    pub fn has_kind(&self, kind: FindingKind) -> bool {
        self.findings.iter().any(|f| f.kind == kind)
    }

    /// Human-readable rendering; warnings (and perf findings) beyond
    /// `max_warnings` each are summarized with a count.
    pub fn render(&self, max_warnings: usize) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{} blocks ({} reachable), {} instrs, {} errors, {} warnings{}",
            self.blocks,
            self.reachable_blocks,
            self.instrs,
            self.error_count(),
            self.warning_count(),
            match self.perf_count() {
                0 => String::new(),
                n => format!(", {n} perf"),
            }
        );
        let mut emitted_warnings = 0usize;
        let mut emitted_perf = 0usize;
        for f in &self.findings {
            match f.kind.severity() {
                Severity::Warning => {
                    emitted_warnings += 1;
                    if emitted_warnings > max_warnings {
                        continue;
                    }
                }
                Severity::Perf => {
                    emitted_perf += 1;
                    if emitted_perf > max_warnings {
                        continue;
                    }
                }
                Severity::Error => {}
            }
            let _ = writeln!(out, "{f}");
            for line in &f.context {
                let _ = writeln!(out, "    {line}");
            }
        }
        if emitted_warnings > max_warnings {
            let _ = writeln!(out, "... {} more warnings", emitted_warnings - max_warnings);
        }
        if emitted_perf > max_warnings {
            let _ = writeln!(out, "... {} more perf findings", emitted_perf - max_warnings);
        }
        out
    }
}

/// Analyzer parameters: the machine shape the program is judged
/// against.
#[derive(Debug, Clone, Copy)]
pub struct AnalysisConfig {
    pub vlen_bits: usize,
    pub dram_bytes: usize,
}

impl Default for AnalysisConfig {
    fn default() -> Self {
        AnalysisConfig {
            vlen_bits: 256,
            dram_bytes: MemConfig::paper_default().dram.size_bytes,
        }
    }
}

/// Recover the final CFG of `prog`: leaders, blocks, edges, and
/// constant-propagation-resolved `jalr` targets (iterated until no new
/// indirect target resolves). Exposed for the fuzzer's structural
/// invariant tests.
pub fn recover_cfg(prog: &Program, cfg: &AnalysisConfig) -> (DecodeCache, Cfg) {
    let vlen_bytes = cfg.vlen_bits / 8;
    let mut cache = DecodeCache::empty();
    cache.predecode(prog.text_base, &prog.text);
    let mut jalr_map: HashMap<usize, u32> = HashMap::new();
    let mut graph = Cfg::build(&cache, prog.entry, &[], &jalr_map);
    for _ in 0..MAX_RESOLVE_ROUNDS {
        let consts = dataflow::const_states(&graph, &cache, cfg.dram_bytes, vlen_bytes);
        let new = dataflow::resolve_jalrs(&graph, &cache, &consts, vlen_bytes);
        let mut changed = false;
        for (w, t) in new {
            changed |= jalr_map.insert(w, t).is_none();
        }
        if !changed {
            break;
        }
        let extra: Vec<u32> = jalr_map.values().copied().collect();
        graph = Cfg::build(&cache, prog.entry, &extra, &jalr_map);
    }
    (cache, graph)
}

/// Run the full analysis pipeline over `prog`.
pub fn analyze_program(prog: &Program, config: &AnalysisConfig) -> Report {
    let vlen_bytes = config.vlen_bits / 8;
    let dram = config.dram_bytes as u64;
    let (cache, graph) = recover_cfg(prog, config);
    let mut findings: Vec<Finding> = Vec::new();
    let mut accesses: Vec<Access> = Vec::new();
    let ctx = |pc: u32| context_window(&cache, &prog.text, pc);
    let text_base = prog.text_base;
    let text_end = graph.text_end();

    // Image fit: a program that does not fit DRAM never starts.
    let image_end = (prog.data_base as u64 + prog.data.len() as u64).max(text_end as u64);
    if image_end > dram {
        findings.push(Finding {
            kind: FindingKind::ImageOverflow,
            pc: prog.entry,
            message: format!(
                "image ends at {image_end:#x} but DRAM is {dram:#x} bytes; loading faults"
            ),
            context: Vec::new(),
        });
    }
    if graph.entry_block.is_none() {
        findings.push(Finding {
            kind: FindingKind::EntryOutOfText,
            pc: prog.entry,
            message: format!(
                "entry pc {:#010x} is not a word-aligned text address in [{:#010x}, {:#010x})",
                prog.entry, text_base, text_end
            ),
            context: Vec::new(),
        });
    }

    let in_text = |pc: u32| (text_base..text_end).contains(&pc);

    // ---- structural findings per block ----------------------------------
    for b in &graph.blocks {
        let pc = b.pc(graph.base);
        let tpc = b.term_pc(graph.base);
        if !b.reachable {
            if b.ninstr > 0 {
                findings.push(Finding {
                    kind: FindingKind::UnreachableBlock,
                    pc,
                    message: format!(
                        "block of {} instruction{} is unreachable from the entry pc",
                        b.ninstr,
                        if b.ninstr == 1 { "" } else { "s" }
                    ),
                    context: ctx(pc),
                });
            }
            continue;
        }
        let mut bad_target = |target: u32, what: &str| {
            if target % 4 != 0 {
                findings.push(Finding {
                    kind: FindingKind::MisalignedTarget,
                    pc: tpc,
                    message: format!(
                        "{what} target {target:#010x} is not word-aligned; fetch faults"
                    ),
                    context: ctx(tpc),
                });
            } else if !in_text(target) {
                findings.push(Finding {
                    kind: FindingKind::BranchOutOfText,
                    pc: tpc,
                    message: format!(
                        "{what} target {target:#010x} is outside the text segment [{text_base:#010x}, {text_end:#010x})"
                    ),
                    context: ctx(tpc),
                });
            }
        };
        match b.term {
            Terminator::Branch { target } => {
                bad_target(target, "taken-branch");
                if b.start + b.span() >= graph.nwords {
                    findings.push(Finding {
                        kind: FindingKind::FallOffEnd,
                        pc: tpc,
                        message: "not-taken path falls off the end of the text segment".into(),
                        context: ctx(tpc),
                    });
                }
            }
            Terminator::Jump { target } => bad_target(target, "jal"),
            Terminator::Indirect { resolved: Some(target) } => {
                if target % 4 != 0 {
                    bad_target(target, "resolved jalr");
                } else if target as u64 + 4 > dram {
                    findings.push(Finding {
                        kind: FindingKind::WildJump,
                        pc: tpc,
                        message: format!(
                            "resolved jalr target {target:#010x} is outside DRAM ({dram:#x} bytes)"
                        ),
                        context: ctx(tpc),
                    });
                } else {
                    bad_target(target, "resolved jalr");
                }
            }
            Terminator::Indirect { resolved: None } => {}
            Terminator::Break => {
                findings.push(Finding {
                    kind: FindingKind::UnexpectedBreak,
                    pc: tpc,
                    message: "reachable ebreak raises a Break fault".into(),
                    context: ctx(tpc),
                });
            }
            Terminator::Illegal => {
                let w = prog.text.get(b.start).copied().unwrap_or(0);
                findings.push(Finding {
                    kind: FindingKind::IllegalWord,
                    pc,
                    message: format!("word {w:#010x} does not decode; fetching it faults"),
                    context: ctx(pc),
                });
            }
            Terminator::FallOff => {
                findings.push(Finding {
                    kind: FindingKind::FallOffEnd,
                    pc: tpc,
                    message: "execution falls off the end of the text segment".into(),
                    context: ctx(tpc),
                });
            }
            Terminator::Halt | Terminator::FallThrough => {}
        }
    }

    // ---- constant-propagation sweep: addresses & unknown custom ops ------
    let consts = dataflow::const_states(&graph, &cache, config.dram_bytes, vlen_bytes);
    for (id, b) in graph.blocks.iter().enumerate() {
        if !b.reachable {
            continue;
        }
        let Some(st0) = &consts[id] else { continue };
        let mut st = st0.clone();
        for (pc, i) in graph.instrs(&cache, b) {
            let e = effects(&i, vlen_bytes);
            if !e.valid_custom {
                findings.push(Finding {
                    kind: FindingKind::UnknownCustomOp,
                    pc,
                    message: format!(
                        "`{i}` names a slot/funct3 pair the standard unit pool rejects"
                    ),
                    context: ctx(pc),
                });
            }
            if let Some(m) = e.mem {
                let range = dataflow::mem_addr_range(&m, &st);
                let addr = range.singleton();
                accesses.push(Access { pc, addr, len: m.len, store: m.store });
                if addr.is_none() && !range.is_top() {
                    // Range-only knowledge still decides out-of-DRAM when
                    // the *entire* interval faults (the range is sound, so
                    // every concrete execution faults) — keeps the
                    // "errors = what the architecture faults on" contract.
                    if range.lo as u64 + m.len as u64 > dram {
                        findings.push(Finding {
                            kind: FindingKind::OutOfDramAccess,
                            pc,
                            message: format!(
                                "{} of {} bytes at an address in {range} runs past the end of DRAM ({dram:#x} bytes) for every possible value",
                                if m.store { "store" } else { "load" },
                                m.len
                            ),
                            context: ctx(pc),
                        });
                    }
                }
                if let Some(a) = addr {
                    let end = a as u64 + m.len as u64;
                    let align: u32 = if m.index.is_some() { 4 } else { m.len as u32 };
                    if end > dram {
                        findings.push(Finding {
                            kind: FindingKind::OutOfDramAccess,
                            pc,
                            message: format!(
                                "{} of {} bytes at {a:#010x} runs past the end of DRAM ({dram:#x} bytes)",
                                if m.store { "store" } else { "load" },
                                m.len
                            ),
                            context: ctx(pc),
                        });
                    } else {
                        if align > 1 && a % align != 0 {
                            findings.push(Finding {
                                kind: FindingKind::MisalignedAccess,
                                pc,
                                message: format!(
                                    "{} address {a:#010x} is not {align}-byte aligned",
                                    if m.store { "store" } else { "load" }
                                ),
                                context: ctx(pc),
                            });
                        }
                        if m.store && a < text_end && end > text_base as u64 {
                            findings.push(Finding {
                                kind: FindingKind::StoreToText,
                                pc,
                                message: format!(
                                    "store at {a:#010x} overlaps the text segment [{text_base:#010x}, {text_end:#010x}) — self-modifying code is invisible to static analysis"
                                ),
                                context: ctx(pc),
                            });
                        }
                    }
                }
            }
            st.transfer(&i, pc, vlen_bytes);
        }
    }

    // ---- must-init sweep: uninitialized reads ----------------------------
    let inits = dataflow::init_states(&graph, &cache, vlen_bytes);
    for (id, b) in graph.blocks.iter().enumerate() {
        if !b.reachable {
            continue;
        }
        let Some(st0) = &inits[id] else { continue };
        let mut st = *st0;
        for (pc, i) in graph.instrs(&cache, b) {
            let e = effects(&i, vlen_bytes);
            for &r in &e.uses {
                if !st.scalar(r) {
                    findings.push(Finding {
                        kind: FindingKind::UninitScalarRead,
                        pc,
                        message: format!(
                            "`{i}` reads {} before any write reaches this point",
                            r.abi_name()
                        ),
                        context: ctx(pc),
                    });
                }
            }
            for &v in &e.vuses {
                if !st.vec(v) {
                    findings.push(Finding {
                        kind: FindingKind::UninitVectorRead,
                        pc,
                        message: format!("`{i}` reads {v} before any write reaches this point"),
                        context: ctx(pc),
                    });
                }
            }
            if e.uses_carry && !st.carry {
                findings.push(Finding {
                    kind: FindingKind::UninitCarryRead,
                    pc,
                    message: format!(
                        "`{i}` reads the c3 carry before any prefix/reset defined it"
                    ),
                    context: ctx(pc),
                });
            }
            st.transfer(&i, vlen_bytes);
        }
    }

    // ---- liveness sweep: dead writes -------------------------------------
    let live_out = dataflow::live_out_states(&graph, &cache, vlen_bytes);
    for (id, b) in graph.blocks.iter().enumerate() {
        if !b.reachable {
            continue;
        }
        let mut st = live_out[id];
        let instrs: Vec<_> = graph.instrs(&cache, b).collect();
        for (pc, i) in instrs.iter().rev() {
            let e = effects(i, vlen_bytes);
            for &r in &e.defs {
                if r.num() != 0 && !st.scalar(r) {
                    findings.push(Finding {
                        kind: FindingKind::DeadWrite,
                        pc: *pc,
                        message: format!("`{i}` writes {} but nothing reads it", r.abi_name()),
                        context: ctx(*pc),
                    });
                }
            }
            for &v in &e.vdefs {
                if v.num() != 0 && !st.vec(v) {
                    findings.push(Finding {
                        kind: FindingKind::DeadVectorWrite,
                        pc: *pc,
                        message: format!("`{i}` writes {v} but nothing reads it"),
                        context: ctx(*pc),
                    });
                }
            }
            st.transfer(i, vlen_bytes);
        }
    }

    findings.sort_by_key(|f| (f.kind.severity(), f.pc));
    let reachable_blocks = graph.blocks.iter().filter(|b| b.reachable).count();
    let instrs = graph.blocks.iter().map(|b| b.ninstr).sum();
    Report {
        findings,
        blocks: graph.blocks.len(),
        reachable_blocks,
        instrs,
        accesses,
    }
}

/// Disassembly window of up to [`CONTEXT_WINDOW`] instructions ending
/// at `pc` (most recent last), matching the cosim divergence report.
fn context_window(cache: &DecodeCache, text: &[u32], pc: u32) -> Vec<String> {
    let Some(idx) = cache.word_index(pc) else { return Vec::new() };
    let lo = idx.saturating_sub(CONTEXT_WINDOW - 1);
    (lo..=idx)
        .map(|k| {
            let kpc = cache.base().wrapping_add((k as u32) * 4);
            match cache.get(k) {
                Some(i) => crate::cosim::context_line(kpc, &i),
                None => format!("{kpc:#010x}: .word {:#010x}", text[k]),
            }
        })
        .collect()
}

/// Static-vs-dynamic consistency: every recovered CFG block must agree
/// with the boundaries [`crate::ref_iss::block::BlockCache`] lowering
/// would produce from the same start word. A CFG block may be *shorter*
/// only because a jump target (leader) splits it, and *longer* only
/// past the ISS's `MAX_BLOCK_UOPS` cap; any other disagreement means
/// the two definitions of "basic block" have drifted.
pub fn check_block_consistency(prog: &Program, graph: &Cfg) -> Result<(), String> {
    use crate::ref_iss::block::{ends_block, MAX_BLOCK_UOPS};
    let mut cache = DecodeCache::empty();
    cache.predecode(prog.text_base, &prog.text);
    for b in &graph.blocks {
        if b.ninstr == 0 {
            continue;
        }
        // Replicate the ISS scan from this block's start word.
        let mut k = b.start;
        let mut count = 0usize;
        while k < cache.len() && count < MAX_BLOCK_UOPS {
            let Some(i) = cache.get(k) else { break };
            count += 1;
            if ends_block(&i) {
                break;
            }
            k += 1;
        }
        let pc = b.pc(graph.base);
        if b.ninstr > count && count < MAX_BLOCK_UOPS {
            return Err(format!(
                "cfg block at {pc:#010x} has {} instrs but ISS lowering stops after {count}",
                b.ninstr
            ));
        }
        if b.ninstr < count && !matches!(b.term, Terminator::FallThrough) {
            return Err(format!(
                "cfg block at {pc:#010x} ends after {} instrs ({:?}) but ISS lowering continues to {count}",
                b.ninstr, b.term
            ));
        }
        // Terminator classification must agree with ends_block per instr.
        for (n, (ipc, i)) in graph.instrs(&cache, b).enumerate() {
            let is_last = n + 1 == b.ninstr;
            let cfg_terminates = is_last
                && matches!(
                    b.term,
                    Terminator::Branch { .. }
                        | Terminator::Jump { .. }
                        | Terminator::Indirect { .. }
                        | Terminator::Halt
                        | Terminator::Break
                );
            if cfg_terminates != ends_block(&i) {
                return Err(format!(
                    "terminator disagreement at {ipc:#010x}: cfg={cfg_terminates} iss={}",
                    ends_block(&i)
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;
    use crate::isa::Instr;

    fn analyze(f: impl FnOnce(&mut Asm)) -> Report {
        let mut a = Asm::new();
        f(&mut a);
        let prog = a.assemble().expect("fixture assembles");
        analyze_program(&prog, &AnalysisConfig::default())
    }

    #[test]
    fn clean_program_is_clean() {
        let r = analyze(|a| {
            a.li(A0, 1);
            a.li(A1, 2);
            a.emit(Instr::Add { rd: A2, rs1: A0, rs2: A1 });
            a.emit(Instr::Sw { rs1: SP, rs2: A2, offset: -4 });
            a.halt();
        });
        assert!(r.is_clean(), "unexpected errors: {}", r.render(50));
        assert_eq!(r.blocks, 1);
    }

    #[test]
    fn uninit_scalar_read_flagged() {
        let r = analyze(|a| {
            a.emit(Instr::Add { rd: A0, rs1: A1, rs2: A2 });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::UninitScalarRead));
        assert!(r.is_clean(), "uninit reads are warnings");
    }

    #[test]
    fn dead_write_flagged() {
        let r = analyze(|a| {
            a.li(A0, 1);
            a.li(A0, 2); // first li is dead
            a.emit(Instr::Sw { rs1: SP, rs2: A0, offset: -4 });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::DeadWrite));
    }

    #[test]
    fn out_of_dram_access_is_error() {
        let r = analyze(|a| {
            a.li(A0, 0x7000_0000);
            a.emit(Instr::Lw { rd: A1, rs1: A0, offset: 0 });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::OutOfDramAccess));
        assert_eq!(r.error_count(), 1);
    }

    #[test]
    fn access_wrapping_the_address_space_is_out_of_dram() {
        // End-of-range rule at the 4 GiB boundary: a 4-byte load at
        // 0xFFFF_FFFE must fold to end = 0x1_0000_0002 (u64, no wrap to
        // a small in-DRAM address) and be flagged like the backends'
        // MemWrap fault.
        let r = analyze(|a| {
            a.li(A0, 0xFFFF_FFFEu32 as i32 as i64);
            a.emit(Instr::Lw { rd: A1, rs1: A0, offset: 0 });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::OutOfDramAccess), "{}", r.render(50));
        assert_eq!(r.error_count(), 1);
    }

    #[test]
    fn sp_relative_store_at_top_of_dram_is_clean() {
        let r = analyze(|a| {
            a.li(A0, 7);
            a.emit(Instr::Sw { rs1: SP, rs2: A0, offset: -4 });
            a.halt();
        });
        assert!(r.is_clean(), "{}", r.render(50));
        // But storing *at* sp (== DRAM top) is out of bounds.
        let r = analyze(|a| {
            a.li(A0, 7);
            a.emit(Instr::Sw { rs1: SP, rs2: A0, offset: 0 });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::OutOfDramAccess));
    }

    #[test]
    fn unknown_custom_op_is_error() {
        use crate::isa::instr::IPrime;
        use crate::isa::CustomSlot;
        let r = analyze(|a| {
            a.emit(Instr::CustomI {
                slot: CustomSlot::C2,
                funct3: 3,
                ops: IPrime { vrs1: V0, vrd1: V1, vrs2: V0, vrd2: V0, rs1: ZERO, rd: ZERO },
            });
            a.halt();
        });
        assert!(r.has_kind(FindingKind::UnknownCustomOp));
        assert!(!r.is_clean());
    }

    #[test]
    fn uninit_carry_flagged_until_reset() {
        let r = analyze(|a| {
            a.prefix(V2, V1); // carry read before reset; v1 uninit too
            a.halt();
        });
        assert!(r.has_kind(FindingKind::UninitCarryRead));
        assert!(r.has_kind(FindingKind::UninitVectorRead));
        let r = analyze(|a| {
            a.prefix_reset();
            a.prefix(V2, V1);
            a.halt();
        });
        assert!(!r.has_kind(FindingKind::UninitCarryRead));
    }

    #[test]
    fn jalr_chain_resolves_and_keeps_code_reachable() {
        let r = analyze(|a| {
            // auipc+jalr to the next instruction, twice in sequence —
            // the second pair is only reachable through the first, so
            // resolution must iterate.
            for _ in 0..2 {
                a.emit(Instr::Auipc { rd: T6, imm: 0 });
                a.emit(Instr::Jalr { rd: ZERO, rs1: T6, offset: 8 });
            }
            a.li(A0, 1);
            a.emit(Instr::Sw { rs1: SP, rs2: A0, offset: -4 });
            a.halt();
        });
        assert!(r.is_clean(), "{}", r.render(50));
        assert!(!r.has_kind(FindingKind::UnreachableBlock));
        assert_eq!(r.reachable_blocks, r.blocks);
    }

    #[test]
    fn fall_off_end_is_error() {
        let r = analyze(|a| {
            a.li(A0, 1);
        });
        assert!(r.has_kind(FindingKind::FallOffEnd));
    }

    #[test]
    fn consistency_holds_on_fixture() {
        let mut a = Asm::new();
        let skip = a.new_label("skip");
        a.li(A0, 3);
        a.bnez(A0, skip);
        a.li(A1, 1);
        a.bind(skip);
        a.sort8(V1, V1);
        a.halt();
        let prog = a.assemble().unwrap();
        let (_, graph) = recover_cfg(&prog, &AnalysisConfig::default());
        check_block_consistency(&prog, &graph).expect("boundaries agree");
    }
}
