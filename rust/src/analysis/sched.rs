//! Semantics-preserving intra-block instruction scheduler.
//!
//! [`schedule_program`] reorders instructions *within* each basic block
//! to shrink the static cycle cost from [`super::perf::PerfModel`] —
//! hoisting loads out of their use window, pairing independent ops into
//! dual-issue groups, and spreading SIMD-unit issues apart. The block
//! structure, every control transfer, and all architectural semantics
//! are preserved by construction:
//!
//! * **Pinned instructions never move.** PC-relative producers
//!   (`auipc`/`jal`/`jalr`), `csrrs` (counter reads are
//!   position-sensitive under lockstep), `fence`, `ecall`, `ebreak`,
//!   undecodable custom ops (they fault at their own pc), and the
//!   block's terminator act as full barriers: everything before stays
//!   before, everything after stays after, so their absolute word
//!   position is unchanged and CFG leaders/targets cannot shift.
//! * **Dependences are edges.** RAW/WAR/WAW over scalar registers
//!   (`x0` ignored) and vector registers (`v0` ignored), the `c3`
//!   prefix-unit carry state (carry-touching ops stay in program
//!   order), and memory: no memory operation crosses a store (loads
//!   may reorder with loads only).
//!
//! The original order is always a topological order of this DAG, and a
//! block is only rewritten when the replayed cost of the new order is
//! strictly lower — scheduling can never pessimize the model's
//! estimate. Equivalence of the rewritten program is not argued, it is
//! *checked*: [`verify_schedule`] runs original and scheduled programs
//! to completion on the reference ISS and demands identical final
//! architectural state, then cosimulates the scheduled program against
//! the ISS in lockstep on the timed core.

use std::cmp::Reverse;

use super::cfg::Terminator;
use super::dataflow::effects;
use super::perf::PerfModel;
use super::{recover_cfg, AnalysisConfig};
use crate::arch::ArchState;
use crate::asm::Program;
use crate::core::CoreConfig;
use crate::cosim::{run_lockstep, LockstepOutcome};
use crate::isa::Instr;
use crate::machine::Machine;
use crate::ref_iss::RefIss;
use crate::simd::units::{static_op, StaticMemKind};

/// Result of scheduling a program.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    /// The rewritten program (identical to the input when nothing
    /// improved).
    pub program: Program,
    /// Blocks whose instruction order changed.
    pub blocks_changed: usize,
    /// Instructions that ended up at a different word index.
    pub instrs_moved: usize,
}

impl ScheduleOutcome {
    pub fn changed(&self) -> bool {
        self.blocks_changed > 0
    }
}

/// Reorder instructions within each reachable basic block of `prog` to
/// minimize the flat-memory cost model for `core`. Only blocks where
/// the model predicts a strictly lower cycle count are rewritten.
pub fn schedule_program(
    prog: &Program,
    acfg: &AnalysisConfig,
    core: &CoreConfig,
) -> ScheduleOutcome {
    let (cache, graph) = recover_cfg(prog, acfg);
    let model = PerfModel::flat(*core);
    let vlen_bytes = core.vlen_bytes();
    let mut text = prog.text.clone();
    let mut blocks_changed = 0;
    let mut instrs_moved = 0;
    for b in graph.blocks.iter().filter(|b| b.reachable) {
        // A FallOff block runs off the end of the text segment and
        // faults; moving anything would move the fault point.
        if b.ninstr < 3 || matches!(b.term, Terminator::FallOff) {
            continue;
        }
        let seq: Vec<(u32, Instr)> = graph.instrs(&cache, b).collect();
        // `instrs` yields the terminator instruction for blocks ended by
        // an explicit control transfer / halt; it must stay last.
        let term_pinned = !matches!(b.term, Terminator::FallThrough);
        if let Some(order) = schedule_block(&seq, term_pinned, vlen_bytes, &model) {
            blocks_changed += 1;
            for (k, &src) in order.iter().enumerate() {
                if src != k {
                    instrs_moved += 1;
                }
                text[b.start + k] = prog.text[b.start + src];
            }
        }
    }
    let mut program = prog.clone();
    program.text = text;
    ScheduleOutcome { program, blocks_changed, instrs_moved }
}

/// Critical-path weight of an instruction: its result latency under the
/// flat model, used as the list-scheduling priority contribution.
fn latency_weight(i: &Instr, cfg: &CoreConfig) -> u64 {
    use Instr::*;
    match *i {
        _ if i.is_load() => cfg.load_use_cycles.max(2),
        Mul { .. } | Mulh { .. } | Mulhsu { .. } | Mulhu { .. } => cfg.mul_cycles,
        Div { .. } | Divu { .. } | Rem { .. } | Remu { .. } => cfg.div_cycles,
        CustomI { slot, funct3, .. } | CustomS { slot, funct3, .. } => {
            match static_op(slot.index(), funct3, cfg.lanes()) {
                Some(op) => match op.mem {
                    Some(StaticMemKind::Load) => op.latency.max(2),
                    _ => op.latency.max(1),
                },
                None => 1,
            }
        }
        _ => 1,
    }
}

/// Schedule one straight-line sequence. Returns the new order as
/// `order[new_index] = old_index`, or `None` when the model does not
/// predict a strict improvement.
fn schedule_block(
    seq: &[(u32, Instr)],
    term_pinned: bool,
    vlen_bytes: usize,
    model: &PerfModel,
) -> Option<Vec<usize>> {
    use Instr::*;
    let n = seq.len();
    let effs: Vec<_> = seq.iter().map(|(_, i)| effects(i, vlen_bytes)).collect();
    let mut pinned: Vec<bool> = seq
        .iter()
        .zip(&effs)
        .map(|(&(_, i), e)| {
            i.is_pc_relative()
                || matches!(i, Csrrs { .. } | Fence | Ecall | Ebreak)
                || !e.valid_custom
        })
        .collect();
    if term_pinned {
        pinned[n - 1] = true;
    }

    // Dependence DAG, edges j -> i for j < i. The original order is a
    // topological order by construction.
    let dep = |j: usize, i: usize| -> bool {
        if pinned[i] || pinned[j] {
            return true;
        }
        let (a, b) = (&effs[j], &effs[i]);
        let raw = a.defs.iter().any(|d| d.num() != 0 && b.uses.contains(d));
        let war = b.defs.iter().any(|d| d.num() != 0 && a.uses.contains(d));
        let waw = a.defs.iter().any(|d| d.num() != 0 && b.defs.contains(d));
        if raw || war || waw {
            return true;
        }
        let vraw = a.vdefs.iter().any(|d| d.num() != 0 && b.vuses.contains(d));
        let vwar = b.vdefs.iter().any(|d| d.num() != 0 && a.vuses.contains(d));
        let vwaw = a.vdefs.iter().any(|d| d.num() != 0 && b.vdefs.contains(d));
        if vraw || vwar || vwaw {
            return true;
        }
        // The c3 carry is a single piece of hidden state: keep every
        // carry-touching op in program order.
        if (a.uses_carry || a.defs_carry) && (b.uses_carry || b.defs_carry) {
            return true;
        }
        // Memory: nothing crosses a store (no alias analysis); loads
        // reorder freely with loads.
        match (&a.mem, &b.mem) {
            (Some(ma), Some(mb)) => ma.store || mb.store,
            _ => false,
        }
    };
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 1..n {
        for j in 0..i {
            if dep(j, i) {
                preds[i].push(j);
                succs[j].push(i);
            }
        }
    }

    // Priority: longest latency-weighted path to the end of the block.
    let mut prio = vec![0u64; n];
    for i in (0..n).rev() {
        let tail = succs[i].iter().map(|&s| prio[s]).max().unwrap_or(0);
        prio[i] = latency_weight(&seq[i].1, &model.cfg) + tail;
    }

    // Greedy list scheduling: among ready instructions pick the one the
    // cost model would issue earliest, breaking ties by critical path,
    // then original order (so the schedule is deterministic and reduces
    // to the identity when nothing can improve).
    let mut indeg: Vec<usize> = preds.iter().map(Vec::len).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut sim = model.sim();
    let mut order = Vec::with_capacity(n);
    loop {
        let pick = ready
            .iter()
            .copied()
            .min_by_key(|&i| (sim.peek_issue(seq[i].0, &seq[i].1), Reverse(prio[i]), i));
        let Some(pick) = pick else { break };
        ready.retain(|&i| i != pick);
        sim.step(seq[pick].0, &seq[pick].1);
        order.push(pick);
        for &s in &succs[pick] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(s);
            }
        }
    }
    debug_assert_eq!(order.len(), n);
    if order.iter().enumerate().all(|(k, &src)| k == src) {
        return None;
    }
    // Accept only strict improvement under the model; ties keep the
    // original order (no churn for zero gain).
    let orig = model.sequence_cost(seq).min_cycles;
    let scheduled: Vec<(u32, Instr)> =
        order.iter().enumerate().map(|(k, &src)| (seq[k].0, seq[src].1)).collect();
    if model.sequence_cost(&scheduled).min_cycles >= orig {
        return None;
    }
    Some(order)
}

fn run_to_halt(
    prog: &Program,
    init: &[(u32, Vec<u8>)],
    vlen_bits: usize,
    dram_bytes: usize,
    max_instrs: u64,
    label: &str,
) -> Result<RefIss, String> {
    let mut iss = RefIss::new(vlen_bits, dram_bytes);
    iss.load(prog).map_err(|e| format!("{label}: load failed: {e}"))?;
    for (addr, bytes) in init {
        iss.host_write(*addr, bytes)
            .map_err(|e| format!("{label}: init write at {addr:#010x} failed: {e}"))?;
    }
    iss.run(max_instrs).map_err(|e| format!("{label}: faulted: {e}"))?;
    if !ArchState::halted(&iss) {
        return Err(format!("{label}: did not halt within {max_instrs} instructions"));
    }
    Ok(iss)
}

/// Prove `sched` architecturally equivalent to `orig` for one input
/// image: run both to a clean halt on the reference ISS and require an
/// identical final state (retired instruction count, every scalar and
/// vector register, the full memory image), then run the scheduled
/// program on the timed core in lockstep against the ISS — the
/// per-instruction cosim catches any divergence the end-state compare
/// could mask.
pub fn verify_schedule(
    orig: &Program,
    sched: &Program,
    init: &[(u32, Vec<u8>)],
    vlen_bits: usize,
    dram_bytes: usize,
    issue_width: usize,
    max_instrs: u64,
) -> Result<(), String> {
    let a = run_to_halt(orig, init, vlen_bits, dram_bytes, max_instrs, "original")?;
    let b = run_to_halt(sched, init, vlen_bits, dram_bytes, max_instrs, "scheduled")?;
    if a.instret() != b.instret() {
        return Err(format!(
            "instret mismatch: original {} vs scheduled {}",
            a.instret(),
            b.instret()
        ));
    }
    for n in 1..32u8 {
        let r = crate::isa::Reg::new(n);
        if a.reg(r) != b.reg(r) {
            return Err(format!(
                "x{n} mismatch: original {:#010x} vs scheduled {:#010x}",
                a.reg(r),
                b.reg(r)
            ));
        }
    }
    for n in 1..8u8 {
        let v = crate::isa::VReg::new(n);
        if a.vreg(v) != b.vreg(v) {
            return Err(format!("v{n} mismatch after halt"));
        }
    }
    let len = a.mem_size();
    if len != b.mem_size() || a.mem_slice(0, len) != b.mem_slice(0, len) {
        return Err("final memory images differ".to_string());
    }

    // Lockstep: scheduled program, timed core (flat memory) vs ISS.
    let m = Machine::for_vlen(vlen_bits)
        .magic_memory(true)
        .dram_bytes(dram_bytes)
        .issue_width(issue_width);
    let mut core = m.build();
    core.load(sched).map_err(|e| format!("core load failed: {e}"))?;
    let mut iss = RefIss::new(vlen_bits, dram_bytes);
    iss.load(sched).map_err(|e| format!("iss load failed: {e}"))?;
    for (addr, bytes) in init {
        core.mem.host_write(*addr, bytes);
        iss.host_write(*addr, bytes)
            .map_err(|e| format!("iss init write at {addr:#010x} failed: {e}"))?;
    }
    match run_lockstep(&mut core, &mut iss, max_instrs) {
        Ok(rep) => match rep.outcome {
            LockstepOutcome::Halted => Ok(()),
            LockstepOutcome::Faulted(e) => Err(format!("scheduled lockstep faulted: {e}")),
            LockstepOutcome::Watchdog(n) => {
                Err(format!("scheduled lockstep hit the {n}-instruction watchdog"))
            }
        },
        Err(d) => Err(format!("scheduled program diverged on the timed core:\n{d}")),
    }
}
