//! Static per-basic-block cycle cost model (DESIGN.md §12).
//!
//! [`PerfModel`] replays the timed core's issue rules — operand RAW
//! stalls against per-register ready times, the `load_use_cycles` load
//! pipe, the iterative divider issuing alone, vector-destination WAW
//! ordering, each SIMD unit's one-issue-per-cycle slot, taken
//! branches/jumps closing their issue group, and the `issue_width`
//! 1/2/4 group accounting — over a straight-line instruction sequence
//! *without executing it*. The replay is a transcription of
//! `Core::step` + `Core::exec_custom` with the architectural work
//! removed; every timing parameter is read from [`CoreConfig`] (which
//! also owns the shared `serial_issue` predicate), and custom-op
//! latencies come from `simd::units::static_op`, pinned against the
//! executing units by a unit test.
//!
//! ## Exactness contract
//!
//! Under [`MemTiming::Flat`] (magic memory: every access issues and
//! completes in the same cycle, instruction fetch never stalls) the
//! estimate for a straight-line sequence entered with all registers
//! ready is **cycle-exact** against `Core` at every issue width — a
//! property test drives this over the fuzz generator and every registry
//! workload's basic blocks. Under [`MemTiming::Bounded`] each data
//! access may additionally cost up to `worst_access_cycles`, so costs
//! widen to a `[min, max]` interval: `min` is the flat/all-hit replay,
//! `max` a conservative estimate, not a proven bound (it ignores fetch
//! stalls and cross-block cache state).
//!
//! Per-block costs assume a clean entry state (no in-flight writes from
//! a predecessor block) and model the terminator in its taken form;
//! both assumptions are part of why whole-program numbers from block
//! costs are estimates even under flat memory.

use crate::asm::Program;
use crate::core::CoreConfig;
use crate::isa::{reg::V0, Instr, Reg, VReg};
use crate::mem::config::MemConfig;
use crate::simd::units::{static_op, StaticMemKind};

use super::{recover_cfg, AnalysisConfig, Finding, FindingKind};

/// What the model assumes about the memory hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemTiming {
    /// Magic/flat memory: every access ready the cycle it issues. This
    /// is the regime the cycle-exactness guarantee covers.
    Flat,
    /// A cached hierarchy: each data access may cost up to
    /// `worst_access_cycles` extra cycles, widening costs to intervals.
    Bounded { worst_access_cycles: u64 },
}

impl MemTiming {
    /// A conservative per-access bound derived from a memory
    /// configuration: DRAM burst setup plus the LLC-block transfer time
    /// plus the LLC hit latency — the cost of a full miss that has to
    /// stream one LLC block from one DRAM channel.
    pub fn bounded_by(mem: &MemConfig) -> MemTiming {
        let block = mem.llc.block_bytes() as u64;
        let per_cycle = mem.dram.bytes_per_cycle().max(1) as u64;
        MemTiming::Bounded {
            worst_access_cycles: mem.dram.burst_setup_cycles
                + block.div_ceil(per_cycle)
                + mem.llc_hit_cycles,
        }
    }

    fn worst(self) -> u64 {
        match self {
            MemTiming::Flat => 0,
            MemTiming::Bounded { worst_access_cycles } => worst_access_cycles,
        }
    }
}

/// Why an instruction's issue slipped past the cycle its group opened.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StallKind {
    /// Waited on a load result inside the load-use window.
    LoadUse,
    /// Waited for an earlier in-flight write to the same vector
    /// destination to retire (write-ordering).
    Waw,
    /// An issue group closed with unused dual-issue slots (operand
    /// stall past the group, or a serialising div/mul issuing alone).
    WastedSlots,
    /// Contended for a SIMD unit's one-issue-per-cycle slot.
    UnitConflict,
}

/// One pc-anchored stall the replay attributed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallEvent {
    /// The stalled (or group-closing) instruction.
    pub pc: u32,
    pub kind: StallKind,
    /// Bubble length in cycles for stalls; unused slots for
    /// [`StallKind::WastedSlots`].
    pub cycles: u64,
    /// The producing instruction for load-use / WAW waits.
    pub producer: Option<u32>,
    /// The contended SIMD slot for [`StallKind::UnitConflict`].
    pub unit: Option<usize>,
}

/// Cost of one basic block (or straight-line sequence).
#[derive(Debug, Clone)]
pub struct BlockCost {
    /// pc of the first instruction.
    pub pc: u32,
    /// Instructions the replay covered.
    pub instrs: usize,
    /// Cycles under flat/all-hit memory.
    pub min_cycles: u64,
    /// Cycles with every access at the worst-case bound (equals
    /// `min_cycles` under [`MemTiming::Flat`]).
    pub max_cycles: u64,
    /// Whether `min_cycles` carries the cycle-exactness guarantee:
    /// flat memory and the whole sequence modeled (no fault stop).
    pub exact: bool,
    /// False when the replay stopped early at an instruction the core
    /// would fault on (unknown custom op, `ebreak`).
    pub complete: bool,
    /// Stall attributions from the flat replay, in program order.
    pub events: Vec<StallEvent>,
}

/// The static cost model: a [`CoreConfig`] (timing parameters + issue
/// rules) plus a memory assumption.
#[derive(Debug, Clone, Copy)]
pub struct PerfModel {
    pub cfg: CoreConfig,
    pub mem: MemTiming,
}

/// Replay state: the timing-relevant slice of `Core`, nothing else.
#[derive(Clone)]
struct Replay {
    cfg: CoreConfig,
    /// Extra cycles charged to every data access (0 = flat).
    extra: u64,
    record: bool,
    cycle: u64,
    issue_used: u64,
    reg_ready: [u64; 32],
    vreg_ready: [u64; 8],
    /// Last issue cycle per SIMD slot (u64::MAX = never, as in `Core`).
    unit_issue_cycle: [u64; 4],
    /// Last writer of each scalar register: (pc, was-a-load).
    reg_writer: [Option<(u32, bool)>; 32],
    vreg_writer: [Option<(u32, bool)>; 8],
    halted: bool,
    events: Vec<StallEvent>,
}

enum StepExit {
    Continue,
    Halt,
    /// The core would fault here (unknown custom op, `ebreak`): the
    /// replay stops with the cycle count accumulated so far.
    Fault,
}

impl Replay {
    fn new(cfg: CoreConfig, extra: u64, record: bool) -> Self {
        Replay {
            cfg,
            extra,
            record,
            cycle: 0,
            issue_used: 0,
            reg_ready: [0; 32],
            vreg_ready: [0; 8],
            unit_issue_cycle: [u64::MAX; 4],
            reg_writer: [None; 32],
            vreg_writer: [None; 8],
            halted: false,
            events: Vec::new(),
        }
    }

    fn read_reg(&mut self, r: Reg, t: &mut u64, pc: u32) {
        let n = r.num() as usize;
        if self.reg_ready[n] > *t {
            let wait = self.reg_ready[n] - *t;
            if self.record {
                if let Some((src, true)) = self.reg_writer[n] {
                    self.events.push(StallEvent {
                        pc,
                        kind: StallKind::LoadUse,
                        cycles: wait,
                        producer: Some(src),
                        unit: None,
                    });
                }
            }
            *t = self.reg_ready[n];
        }
    }

    fn read_vreg(&mut self, v: VReg, t: &mut u64, pc: u32) {
        let n = v.num() as usize;
        if self.vreg_ready[n] > *t {
            let wait = self.vreg_ready[n] - *t;
            if self.record {
                if let Some((src, true)) = self.vreg_writer[n] {
                    self.events.push(StallEvent {
                        pc,
                        kind: StallKind::LoadUse,
                        cycles: wait,
                        producer: Some(src),
                        unit: None,
                    });
                }
            }
            *t = self.vreg_ready[n];
        }
    }

    fn write_reg(&mut self, r: Reg, ready: u64, pc: u32, load: bool) {
        let n = r.num() as usize;
        if n == 0 {
            return;
        }
        self.reg_ready[n] = ready;
        self.reg_writer[n] = Some((pc, load));
    }

    fn write_vreg(&mut self, v: VReg, ready: u64, pc: u32, load: bool) {
        let n = v.num() as usize;
        if n == 0 {
            return;
        }
        self.vreg_ready[n] = ready;
        self.vreg_writer[n] = Some((pc, load));
    }

    fn wasted(&mut self, pc: u32, slots: u64) {
        if self.record && slots > 0 {
            self.events.push(StallEvent {
                pc,
                kind: StallKind::WastedSlots,
                cycles: slots,
                producer: None,
                unit: None,
            });
        }
    }

    /// One instruction through the issue rules — structured exactly as
    /// `Core::step` (group-full close, serial-issue close, per-class
    /// operand stalls and latencies, post-issue group accounting).
    /// Returns the exit state and the instruction's issue time (the
    /// scheduler's selection metric).
    fn step(&mut self, pc: u32, instr: &Instr, taken: bool) -> (StepExit, u64) {
        use Instr::*;
        let width = self.cfg.issue_width as u64;
        if width > 1 && self.issue_used >= width {
            self.cycle += self.cfg.base_cpi;
            self.issue_used = 0;
        }
        // Fetch is modeled as always ready: true under flat memory
        // (magic fetch), an approximation otherwise.
        let serial = width > 1 && self.cfg.serial_issue(instr);
        if serial && self.issue_used > 0 {
            self.wasted(pc, width - self.issue_used);
            self.cycle += self.cfg.base_cpi;
            self.issue_used = 0;
        }

        let group_cycle = self.cycle;
        let mut t = self.cycle;
        let mut redirect = false;
        match *instr {
            Lui { rd, .. } => self.write_reg(rd, t + 1, pc, false),
            Auipc { rd, .. } => self.write_reg(rd, t + 1, pc, false),
            Jal { rd, .. } => {
                self.write_reg(rd, t + 1, pc, false);
                redirect = true;
                t += self.cfg.branch_taken_penalty;
            }
            Jalr { rd, rs1, .. } => {
                self.read_reg(rs1, &mut t, pc);
                self.write_reg(rd, t + 1, pc, false);
                redirect = true;
                t += self.cfg.branch_taken_penalty;
            }
            Beq { rs1, rs2, .. }
            | Bne { rs1, rs2, .. }
            | Blt { rs1, rs2, .. }
            | Bge { rs1, rs2, .. }
            | Bltu { rs1, rs2, .. }
            | Bgeu { rs1, rs2, .. } => {
                self.read_reg(rs1, &mut t, pc);
                self.read_reg(rs2, &mut t, pc);
                if taken {
                    redirect = true;
                    t += self.cfg.branch_taken_penalty;
                }
            }
            Lb { rd, rs1, .. }
            | Lh { rd, rs1, .. }
            | Lw { rd, rs1, .. }
            | Lbu { rd, rs1, .. }
            | Lhu { rd, rs1, .. } => {
                self.read_reg(rs1, &mut t, pc);
                t += self.extra;
                let ready = self.cfg.flat_load_ready(t);
                self.write_reg(rd, ready, pc, true);
            }
            Sb { rs1, rs2, .. } | Sh { rs1, rs2, .. } | Sw { rs1, rs2, .. } => {
                self.read_reg(rs1, &mut t, pc);
                // Widths > 1 model a store buffer: the data operand is
                // consumed at commit and never stalls issue.
                if width <= 1 {
                    self.read_reg(rs2, &mut t, pc);
                }
                t += self.extra;
            }
            Addi { rd, rs1, .. }
            | Slti { rd, rs1, .. }
            | Sltiu { rd, rs1, .. }
            | Xori { rd, rs1, .. }
            | Ori { rd, rs1, .. }
            | Andi { rd, rs1, .. }
            | Slli { rd, rs1, .. }
            | Srli { rd, rs1, .. }
            | Srai { rd, rs1, .. } => {
                self.read_reg(rs1, &mut t, pc);
                self.write_reg(rd, t + 1, pc, false);
            }
            Add { rd, rs1, rs2 }
            | Sub { rd, rs1, rs2 }
            | Sll { rd, rs1, rs2 }
            | Slt { rd, rs1, rs2 }
            | Sltu { rd, rs1, rs2 }
            | Xor { rd, rs1, rs2 }
            | Srl { rd, rs1, rs2 }
            | Sra { rd, rs1, rs2 }
            | Or { rd, rs1, rs2 }
            | And { rd, rs1, rs2 } => {
                self.read_reg(rs1, &mut t, pc);
                self.read_reg(rs2, &mut t, pc);
                self.write_reg(rd, t + 1, pc, false);
            }
            Mul { rd, rs1, rs2 }
            | Mulh { rd, rs1, rs2 }
            | Mulhsu { rd, rs1, rs2 }
            | Mulhu { rd, rs1, rs2 } => {
                self.read_reg(rs1, &mut t, pc);
                self.read_reg(rs2, &mut t, pc);
                t += self.cfg.mul_cycles - 1;
                self.write_reg(rd, t + 1, pc, false);
            }
            Div { rd, rs1, rs2 }
            | Divu { rd, rs1, rs2 }
            | Rem { rd, rs1, rs2 }
            | Remu { rd, rs1, rs2 } => {
                self.read_reg(rs1, &mut t, pc);
                self.read_reg(rs2, &mut t, pc);
                t += self.cfg.div_cycles - 1;
                self.write_reg(rd, t + 1, pc, false);
            }
            Fence => {}
            Ecall => self.halted = true,
            Ebreak => return (StepExit::Fault, t),
            // csrrs reads no base register in the timed core (the
            // counter CSRs have no register operand path).
            Csrrs { rd, .. } => self.write_reg(rd, t + 1, pc, false),
            CustomI { slot, funct3, ops } => {
                match self.custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    None,
                    ops.vrs1,
                    ops.vrs2,
                    ops.rd,
                    ops.vrd1,
                    ops.vrd2,
                    &mut t,
                ) {
                    Some(()) => {}
                    None => return (StepExit::Fault, t),
                }
            }
            CustomS { slot, funct3, ops } => {
                match self.custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    Some(ops.rs2),
                    ops.vrs1,
                    V0,
                    ops.rd,
                    ops.vrd1,
                    V0,
                    &mut t,
                ) {
                    Some(()) => {}
                    None => return (StepExit::Fault, t),
                }
            }
        }

        if width <= 1 {
            self.cycle = t + self.cfg.base_cpi;
        } else if serial {
            self.cycle = t + self.cfg.base_cpi;
            self.issue_used = 0;
        } else {
            if t == group_cycle {
                self.issue_used += 1;
            } else {
                if self.issue_used > 0 {
                    self.wasted(pc, width - self.issue_used);
                }
                self.cycle = t;
                self.issue_used = 1;
            }
            if redirect || self.halted {
                self.cycle = t + self.cfg.base_cpi;
                self.issue_used = 0;
            }
        }
        if self.halted {
            (StepExit::Halt, t)
        } else {
            (StepExit::Continue, t)
        }
    }

    /// The custom-op issue path, mirroring `Core::exec_custom`: both
    /// vector sources are read (stalling) regardless of semantic use,
    /// destinations wait for in-flight writes (WAW), and at width > 1
    /// each slot accepts one issue per cycle.
    #[allow(clippy::too_many_arguments)]
    fn custom(
        &mut self,
        pc: u32,
        slot: usize,
        funct3: u8,
        rs1: Reg,
        rs2: Option<Reg>,
        vrs1: VReg,
        vrs2: VReg,
        rd: Reg,
        vrd1: VReg,
        vrd2: VReg,
        t: &mut u64,
    ) -> Option<()> {
        let op = static_op(slot, funct3, self.cfg.lanes())?;
        self.read_reg(rs1, t, pc);
        if let Some(r) = rs2 {
            self.read_reg(r, t, pc);
        }
        self.read_vreg(vrs1, t, pc);
        self.read_vreg(vrs2, t, pc);
        for v in [vrd1, vrd2] {
            let n = v.num() as usize;
            if n != 0 && self.vreg_ready[n] > *t {
                let wait = self.vreg_ready[n] - *t;
                if self.record {
                    let producer = self.vreg_writer[n].map(|(src, _)| src);
                    self.events.push(StallEvent {
                        pc,
                        kind: StallKind::Waw,
                        cycles: wait,
                        producer,
                        unit: None,
                    });
                }
                *t = self.vreg_ready[n];
            }
        }
        if self.cfg.issue_width > 1 {
            if self.unit_issue_cycle[slot] == *t {
                *t += 1;
                if self.record {
                    self.events.push(StallEvent {
                        pc,
                        kind: StallKind::UnitConflict,
                        cycles: 1,
                        producer: None,
                        unit: Some(slot),
                    });
                }
            }
            self.unit_issue_cycle[slot] = *t;
        }
        match op.mem {
            Some(StaticMemKind::Load) => {
                *t += self.extra;
                let ready = (*t + op.latency).max(*t + 2);
                self.write_vreg(vrd1, ready, pc, true);
            }
            Some(StaticMemKind::Store) => {
                *t += self.extra;
            }
            None => {
                let ready = *t + op.latency;
                if op.writes_vrd1 {
                    self.write_vreg(vrd1, ready, pc, false);
                }
                if op.writes_vrd2 {
                    self.write_vreg(vrd2, ready, pc, false);
                }
                if op.writes_rd {
                    self.write_reg(rd, ready, pc, false);
                }
            }
        }
        Some(())
    }
}

impl PerfModel {
    pub fn new(cfg: CoreConfig, mem: MemTiming) -> Self {
        PerfModel { cfg, mem }
    }

    /// A flat-memory model (the cycle-exact regime).
    pub fn flat(cfg: CoreConfig) -> Self {
        PerfModel { cfg, mem: MemTiming::Flat }
    }

    /// Cost of a straight-line sequence entered with a clean state (all
    /// registers ready, no open issue group). Branches are modeled in
    /// their taken form; the replay stops (with `complete = false`)
    /// at an instruction the core would fault on.
    pub fn sequence_cost(&self, seq: &[(u32, Instr)]) -> BlockCost {
        let (min_cycles, events, covered, complete) = self.replay(seq, 0, true);
        let worst = self.mem.worst();
        let max_cycles = if worst == 0 {
            min_cycles
        } else {
            self.replay(seq, worst, false).0
        };
        BlockCost {
            pc: seq.first().map(|&(pc, _)| pc).unwrap_or(0),
            instrs: covered,
            min_cycles,
            max_cycles,
            exact: self.mem == MemTiming::Flat && complete,
            complete,
            events,
        }
    }

    fn replay(
        &self,
        seq: &[(u32, Instr)],
        extra: u64,
        record: bool,
    ) -> (u64, Vec<StallEvent>, usize, bool) {
        let mut r = Replay::new(self.cfg, extra, record);
        let mut covered = 0usize;
        let mut complete = true;
        for &(pc, ref instr) in seq {
            match r.step(pc, instr, true).0 {
                StepExit::Continue => covered += 1,
                StepExit::Halt => {
                    covered += 1;
                    break;
                }
                StepExit::Fault => {
                    complete = false;
                    break;
                }
            }
        }
        (r.cycle, r.events, covered, complete)
    }

    /// An incremental flat-memory simulator over the same replay: the
    /// list scheduler's lookahead (peek a candidate's issue time, then
    /// commit the chosen one).
    pub fn sim(&self) -> CostSim {
        CostSim { r: Replay::new(self.cfg, self.mem.worst(), false) }
    }

    /// Per-block costs for every reachable block of `prog`, in block
    /// order.
    pub fn block_costs(&self, prog: &Program, acfg: &AnalysisConfig) -> Vec<BlockCost> {
        let (cache, graph) = recover_cfg(prog, acfg);
        let mut out = Vec::new();
        for b in graph.blocks.iter().filter(|b| b.reachable && b.ninstr > 0) {
            let seq: Vec<(u32, Instr)> = graph.instrs(&cache, b).collect();
            out.push(self.sequence_cost(&seq));
        }
        out
    }
}

/// Incremental cost simulator (see [`PerfModel::sim`]).
#[derive(Clone)]
pub struct CostSim {
    r: Replay,
}

impl CostSim {
    /// The issue time `instr` would get if stepped now, without
    /// mutating the simulator.
    pub fn peek_issue(&self, pc: u32, instr: &Instr) -> u64 {
        let mut probe = self.r.clone();
        probe.step(pc, instr, true).1
    }

    /// Commit `instr`.
    pub fn step(&mut self, pc: u32, instr: &Instr) {
        self.r.step(pc, instr, true);
    }

    /// Cycles consumed so far.
    pub fn cycle(&self) -> u64 {
        self.r.cycle
    }
}

/// Per-block costs plus the stall findings, for the `analyze --perf`
/// surface.
#[derive(Debug)]
pub struct PerfReport {
    pub costs: Vec<BlockCost>,
    pub findings: Vec<Finding>,
}

impl PerfReport {
    /// Flat-memory whole-program lower bound: the sum of block minima
    /// (each block entered once, clean state, taken terminators).
    pub fn total_min_cycles(&self) -> u64 {
        self.costs.iter().map(|c| c.min_cycles).sum()
    }
}

/// Run the cost model over every reachable block and turn the stall
/// events into pc-anchored `perf`-severity findings. Deliberately a
/// separate entry point from `analyze_program`: perf findings never
/// affect `Report::is_clean()` or the lint oracle.
pub fn analyze_perf(
    prog: &Program,
    acfg: &AnalysisConfig,
    model: &PerfModel,
) -> PerfReport {
    let (cache, graph) = recover_cfg(prog, acfg);
    let costs = model.block_costs(prog, acfg);
    // Constant-propagated address ranges: attached to data-port
    // (c0 slot) conflict findings so the report says *which* accesses
    // contend, not just that two did.
    let vlen_bytes = acfg.vlen_bits / 8;
    let consts = super::dataflow::const_states(&graph, &cache, acfg.dram_bytes, vlen_bytes);
    let mut addr_ranges: std::collections::HashMap<u32, super::Interval> =
        std::collections::HashMap::new();
    for (id, b) in graph.blocks.iter().enumerate() {
        let Some(st0) = &consts[id] else { continue };
        let mut st = st0.clone();
        for (pc, i) in graph.instrs(&cache, b) {
            let e = super::dataflow::effects(&i, vlen_bytes);
            if let Some(m) = e.mem {
                let r = super::dataflow::mem_addr_range(&m, &st);
                if !r.is_top() {
                    addr_ranges.insert(pc, r);
                }
            }
            st.transfer(&i, pc, vlen_bytes);
        }
    }
    let mut findings = Vec::new();
    for cost in &costs {
        for ev in &cost.events {
            let (kind, message) = match ev.kind {
                StallKind::LoadUse => (
                    FindingKind::LoadUseBubble,
                    format!(
                        "stalls {} cycle(s) on the load issued at {:#010x} (load-use window)",
                        ev.cycles,
                        ev.producer.unwrap_or(0)
                    ),
                ),
                StallKind::Waw => (
                    FindingKind::WawWait,
                    match ev.producer {
                        Some(src) => format!(
                            "waits {} cycle(s) for the in-flight vector write from {src:#010x} \
                             to retire (WAW ordering)",
                            ev.cycles
                        ),
                        None => format!(
                            "waits {} cycle(s) for an in-flight vector write to retire \
                             (WAW ordering)",
                            ev.cycles
                        ),
                    },
                ),
                StallKind::WastedSlots => (
                    FindingKind::WastedIssueSlot,
                    format!("closes its issue group early; {} issue slot(s) wasted", ev.cycles),
                ),
                StallKind::UnitConflict => {
                    let slot = ev.unit.unwrap_or(0);
                    let mut msg = format!(
                        "waits 1 cycle for SIMD unit slot c{slot} (one issue per cycle{})",
                        if slot == 0 { ", one data-port access" } else { "" }
                    );
                    if slot == 0 {
                        if let Some(r) = addr_ranges.get(&ev.pc) {
                            msg.push_str(&format!("; this access targets {r}"));
                        }
                    }
                    (FindingKind::UnitConflict, msg)
                }
            };
            findings.push(Finding {
                kind,
                pc: ev.pc,
                message,
                context: super::context_window(&cache, &prog.text, ev.pc),
            });
        }
    }
    findings.sort_by_key(|f| (f.kind.severity(), f.pc));
    PerfReport { costs, findings }
}
