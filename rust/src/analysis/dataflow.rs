//! Dataflow passes over the recovered CFG: per-instruction def/use
//! effects (including the I′/S′ operand slots and the `c3` prefix-unit
//! carry state), constant propagation for address lints and jalr
//! resolution, must-initialized tracking, and backward liveness for
//! dead-write detection (DESIGN.md §12).

use std::collections::VecDeque;

use super::cfg::{BasicBlock, Cfg};
use crate::arch::sp_init;
use crate::isa::reg::{self, Reg, VReg};
use crate::isa::{DecodeCache, Instr};

// ---------------------------------------------------------------------------
// Def/use effects
// ---------------------------------------------------------------------------

/// One (possibly indexed) data-memory reference.
#[derive(Debug, Clone, Copy)]
pub struct MemRef {
    pub base: Reg,
    /// Second base register for `lv`/`sv` (address is `base + index`).
    pub index: Option<Reg>,
    pub offset: i32,
    pub len: usize,
    pub store: bool,
}

/// Architectural def/use summary of one instruction. For custom
/// instructions this encodes the standard unit pool's slot bindings
/// (c0 mem, c1 merge, c2 sort, c3 prefix); a slot/funct3 pair outside
/// that table sets `valid_custom = false` (it faults at execute).
#[derive(Debug, Clone, Default)]
pub struct Effects {
    pub uses: Vec<Reg>,
    pub defs: Vec<Reg>,
    pub vuses: Vec<VReg>,
    pub vdefs: Vec<VReg>,
    pub uses_carry: bool,
    pub defs_carry: bool,
    pub mem: Option<MemRef>,
    pub valid_custom: bool,
}

/// Def/use sets of `i` under the standard unit pool. `vlen_bytes` sizes
/// vector memory references.
pub fn effects(i: &Instr, vlen_bytes: usize) -> Effects {
    use Instr::*;
    let mut e = Effects { valid_custom: true, ..Effects::default() };
    match *i {
        Lui { rd, .. } | Auipc { rd, .. } | Jal { rd, .. } => e.defs.push(rd),
        Jalr { rd, rs1, .. } => {
            e.uses.push(rs1);
            e.defs.push(rd);
        }
        Beq { rs1, rs2, .. }
        | Bne { rs1, rs2, .. }
        | Blt { rs1, rs2, .. }
        | Bge { rs1, rs2, .. }
        | Bltu { rs1, rs2, .. }
        | Bgeu { rs1, rs2, .. } => {
            e.uses.push(rs1);
            e.uses.push(rs2);
        }
        Lb { rd, rs1, offset } | Lbu { rd, rs1, offset } => {
            e.uses.push(rs1);
            e.defs.push(rd);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 1, store: false });
        }
        Lh { rd, rs1, offset } | Lhu { rd, rs1, offset } => {
            e.uses.push(rs1);
            e.defs.push(rd);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 2, store: false });
        }
        Lw { rd, rs1, offset } => {
            e.uses.push(rs1);
            e.defs.push(rd);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 4, store: false });
        }
        Sb { rs1, rs2, offset } => {
            e.uses.push(rs1);
            e.uses.push(rs2);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 1, store: true });
        }
        Sh { rs1, rs2, offset } => {
            e.uses.push(rs1);
            e.uses.push(rs2);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 2, store: true });
        }
        Sw { rs1, rs2, offset } => {
            e.uses.push(rs1);
            e.uses.push(rs2);
            e.mem = Some(MemRef { base: rs1, index: None, offset, len: 4, store: true });
        }
        Addi { rd, rs1, .. }
        | Slti { rd, rs1, .. }
        | Sltiu { rd, rs1, .. }
        | Xori { rd, rs1, .. }
        | Ori { rd, rs1, .. }
        | Andi { rd, rs1, .. }
        | Slli { rd, rs1, .. }
        | Srli { rd, rs1, .. }
        | Srai { rd, rs1, .. }
        | Csrrs { rd, rs1, .. } => {
            e.uses.push(rs1);
            e.defs.push(rd);
        }
        Add { rd, rs1, rs2 }
        | Sub { rd, rs1, rs2 }
        | Sll { rd, rs1, rs2 }
        | Slt { rd, rs1, rs2 }
        | Sltu { rd, rs1, rs2 }
        | Xor { rd, rs1, rs2 }
        | Srl { rd, rs1, rs2 }
        | Sra { rd, rs1, rs2 }
        | Or { rd, rs1, rs2 }
        | And { rd, rs1, rs2 }
        | Mul { rd, rs1, rs2 }
        | Mulh { rd, rs1, rs2 }
        | Mulhsu { rd, rs1, rs2 }
        | Mulhu { rd, rs1, rs2 }
        | Div { rd, rs1, rs2 }
        | Divu { rd, rs1, rs2 }
        | Rem { rd, rs1, rs2 }
        | Remu { rd, rs1, rs2 } => {
            e.uses.push(rs1);
            e.uses.push(rs2);
            e.defs.push(rd);
        }
        Fence | Ecall | Ebreak => {}
        CustomI { slot, funct3, ops } => match (slot.index(), funct3) {
            // c1_merge: (vrd1, vrd2) = merge(vrs1, vrs2)
            (1, 0) => {
                e.vuses.extend([ops.vrs1, ops.vrs2]);
                e.vdefs.extend([ops.vrd1, ops.vrd2]);
            }
            // c1_vadd: vrd1 = vrs1 + vrs2
            (1, 1) => {
                e.vuses.extend([ops.vrs1, ops.vrs2]);
                e.vdefs.push(ops.vrd1);
            }
            // c1_vscale: vrd1 = vrs1 * rs1
            (1, 2) => {
                e.vuses.push(ops.vrs1);
                e.uses.push(ops.rs1);
                e.vdefs.push(ops.vrd1);
            }
            // c1_vfilt: (vrd1, rd) = filter(vrs1, rs1)
            (1, 3) => {
                e.vuses.push(ops.vrs1);
                e.uses.push(ops.rs1);
                e.vdefs.push(ops.vrd1);
                e.defs.push(ops.rd);
            }
            // c2_sort: vrd1 = sort(vrs1)
            (2, 0) => {
                e.vuses.push(ops.vrs1);
                e.vdefs.push(ops.vrd1);
            }
            // c3_prefix: vrd1 = prefix(vrs1) + carry; carry updated
            (3, 0) => {
                e.vuses.push(ops.vrs1);
                e.vdefs.push(ops.vrd1);
                e.uses_carry = true;
                e.defs_carry = true;
            }
            // c3_reset
            (3, 1) => e.defs_carry = true,
            // c3_carry: rd = carry
            (3, 2) => {
                e.uses_carry = true;
                e.defs.push(ops.rd);
            }
            _ => e.valid_custom = false,
        },
        CustomS { slot, funct3, ops } => match (slot.index(), funct3) {
            // c0_lv: vrd1 = mem[rs1 + rs2]
            (0, 4) => {
                e.uses.extend([ops.rs1, ops.rs2]);
                e.vdefs.push(ops.vrd1);
                e.mem = Some(MemRef {
                    base: ops.rs1,
                    index: Some(ops.rs2),
                    offset: 0,
                    len: vlen_bytes,
                    store: false,
                });
            }
            // c0_sv: mem[rs1 + rs2] = vrs1
            (0, 5) => {
                e.uses.extend([ops.rs1, ops.rs2]);
                e.vuses.push(ops.vrs1);
                e.mem = Some(MemRef {
                    base: ops.rs1,
                    index: Some(ops.rs2),
                    offset: 0,
                    len: vlen_bytes,
                    store: true,
                });
            }
            _ => e.valid_custom = false,
        },
    }
    e
}

// ---------------------------------------------------------------------------
// Constant propagation (interval domain)
// ---------------------------------------------------------------------------

/// Unsigned value-range abstraction of one register: every value `v`
/// with `lo <= v <= hi`. `[0, u32::MAX]` is ⊤. The domain is *sound by
/// construction*: every transfer over-approximates the architecture, so
/// a property the whole interval satisfies (e.g. "this access runs past
/// DRAM") is a property of every concrete execution — which is what
/// lets range-derived findings keep the "errors = exactly what the
/// architecture faults on" contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub lo: u32,
    pub hi: u32,
}

impl Interval {
    pub const TOP: Interval = Interval { lo: 0, hi: u32::MAX };

    #[inline]
    pub fn exact(v: u32) -> Self {
        Interval { lo: v, hi: v }
    }

    /// `[lo, hi]`, or ⊤ when the bounds are inverted (an empty range
    /// has no meaning here; callers only construct non-empty ones).
    #[inline]
    pub fn new(lo: u32, hi: u32) -> Self {
        if lo <= hi {
            Interval { lo, hi }
        } else {
            Interval::TOP
        }
    }

    #[inline]
    pub fn singleton(self) -> Option<u32> {
        (self.lo == self.hi).then_some(self.lo)
    }

    #[inline]
    pub fn is_top(self) -> bool {
        self == Interval::TOP
    }

    /// Least upper bound (interval hull).
    #[inline]
    pub fn join(self, o: Interval) -> Interval {
        Interval { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }

    /// Widening: any bound still moving after the join jumps straight
    /// to its extreme, bounding the fixpoint chain length.
    #[inline]
    fn widen(self, next: Interval) -> Interval {
        Interval {
            lo: if next.lo < self.lo { 0 } else { self.lo },
            hi: if next.hi > self.hi { u32::MAX } else { self.hi },
        }
    }

    /// Wrapping add: precise when neither or both ends wrap, ⊤ when the
    /// sum straddles the 2^32 boundary.
    fn add(self, o: Interval) -> Interval {
        let lo = self.lo as u64 + o.lo as u64;
        let hi = self.hi as u64 + o.hi as u64;
        const M: u64 = u32::MAX as u64;
        if hi <= M {
            Interval { lo: lo as u32, hi: hi as u32 }
        } else if lo > M {
            Interval { lo: (lo - M - 1) as u32, hi: (hi - M - 1) as u32 }
        } else {
            Interval::TOP
        }
    }

    /// Wrapping subtract, same wrap discipline as [`Interval::add`].
    fn sub(self, o: Interval) -> Interval {
        let lo = self.lo as i64 - o.hi as i64;
        let hi = self.hi as i64 - o.lo as i64;
        if lo >= 0 {
            Interval { lo: lo as u32, hi: hi as u32 }
        } else if hi < 0 {
            Interval { lo: (lo + (1 << 32)) as u32, hi: (hi + (1 << 32)) as u32 }
        } else {
            Interval::TOP
        }
    }

    fn mul(self, o: Interval) -> Interval {
        let hi = self.hi as u64 * o.hi as u64;
        if hi <= u32::MAX as u64 {
            Interval { lo: self.lo * o.lo, hi: hi as u32 }
        } else {
            Interval::TOP
        }
    }

    /// Smallest all-ones mask covering `x` (`0b0010_1000 -> 0b0011_1111`).
    #[inline]
    fn smear(x: u32) -> u32 {
        if x == 0 {
            0
        } else {
            u32::MAX >> x.leading_zeros()
        }
    }

    fn and(self, o: Interval) -> Interval {
        match (self.singleton(), o.singleton()) {
            (Some(a), Some(b)) => Interval::exact(a & b),
            // a & b can clear bits but never set them past either bound.
            _ => Interval { lo: 0, hi: self.hi.min(o.hi) },
        }
    }

    fn or(self, o: Interval) -> Interval {
        match (self.singleton(), o.singleton()) {
            (Some(a), Some(b)) => Interval::exact(a | b),
            // a | b >= max(a, b); its top set bit is bounded by the top
            // set bit of hi1 | hi2.
            _ => Interval { lo: self.lo.max(o.lo), hi: Self::smear(self.hi | o.hi) },
        }
    }

    fn xor(self, o: Interval) -> Interval {
        match (self.singleton(), o.singleton()) {
            (Some(a), Some(b)) => Interval::exact(a ^ b),
            _ => Interval { lo: 0, hi: Self::smear(self.hi | o.hi) },
        }
    }

    fn shl_imm(self, s: u32) -> Interval {
        let s = s & 31;
        if (self.hi as u64) << s <= u32::MAX as u64 {
            Interval { lo: self.lo << s, hi: self.hi << s }
        } else {
            Interval::TOP
        }
    }

    fn shr_imm(self, s: u32) -> Interval {
        let s = s & 31;
        Interval { lo: self.lo >> s, hi: self.hi >> s }
    }

    fn sar_imm(self, s: u32) -> Interval {
        let s = s & 31;
        if self.hi <= i32::MAX as u32 {
            // All non-negative: arithmetic == logical shift.
            self.shr_imm(s)
        } else if self.lo > i32::MAX as u32 {
            // All negative: `>>` on i32 is monotone and stays negative,
            // and the negative range is order-preserved as u32.
            Interval {
                lo: ((self.lo as i32) >> s) as u32,
                hi: ((self.hi as i32) >> s) as u32,
            }
        } else {
            Interval::TOP
        }
    }

    fn shl(self, o: Interval) -> Interval {
        match o.singleton() {
            Some(s) => self.shl_imm(s),
            None => Interval::TOP,
        }
    }

    fn shr(self, o: Interval) -> Interval {
        match o.singleton() {
            Some(s) => self.shr_imm(s),
            // Unknown amount: the result can only shrink.
            None => Interval { lo: 0, hi: self.hi },
        }
    }

    fn sar(self, o: Interval) -> Interval {
        match o.singleton() {
            Some(s) => self.sar_imm(s),
            None if self.hi <= i32::MAX as u32 => Interval { lo: 0, hi: self.hi },
            None => Interval::TOP,
        }
    }

    /// `a < b` unsigned: decided when the ranges are disjoint.
    fn ltu(self, o: Interval) -> Interval {
        if self.hi < o.lo {
            Interval::exact(1)
        } else if self.lo >= o.hi {
            Interval::exact(0)
        } else {
            Interval { lo: 0, hi: 1 }
        }
    }

    /// `a < b` signed: decided for singletons, else `[0, 1]`.
    fn lts(self, o: Interval) -> Interval {
        match (self.singleton(), o.singleton()) {
            (Some(a), Some(b)) => Interval::exact(((a as i32) < (b as i32)) as u32),
            _ => Interval { lo: 0, hi: 1 },
        }
    }

    /// True when every value is non-negative as i32.
    #[inline]
    fn all_signed_nonneg(self) -> bool {
        self.hi <= i32::MAX as u32
    }

    fn mulhu(self, o: Interval) -> Interval {
        // mulhu is monotone in both unsigned arguments.
        Interval {
            lo: ((self.lo as u64 * o.lo as u64) >> 32) as u32,
            hi: ((self.hi as u64 * o.hi as u64) >> 32) as u32,
        }
    }

    fn mulh_signed(self, o: Interval) -> Interval {
        if self.all_signed_nonneg() && o.all_signed_nonneg() {
            self.mulhu(o)
        } else {
            Interval::TOP
        }
    }

    fn divu(self, o: Interval) -> Interval {
        if o.lo >= 1 {
            Interval { lo: self.lo / o.hi, hi: self.hi / o.lo }
        } else {
            // Division by zero yields u32::MAX (RISC-V), so a divisor
            // range containing 0 gives up.
            Interval::TOP
        }
    }

    fn remu(self, o: Interval) -> Interval {
        // remu(a, b) <= a always (remu(a, 0) == a per the spec), and
        // < b when b != 0.
        if o.lo >= 1 {
            Interval { lo: 0, hi: self.hi.min(o.hi - 1) }
        } else {
            Interval { lo: 0, hi: self.hi }
        }
    }

    fn div_signed(self, o: Interval) -> Interval {
        if self.all_signed_nonneg() && o.all_signed_nonneg() && o.lo >= 1 {
            self.divu(o)
        } else {
            Interval::TOP
        }
    }

    fn rem_signed(self, o: Interval) -> Interval {
        if self.all_signed_nonneg() && o.all_signed_nonneg() && o.lo >= 1 {
            Interval { lo: 0, hi: self.hi.min(o.hi - 1) }
        } else {
            Interval::TOP
        }
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_top() {
            write!(f, "⊤")
        } else if let Some(v) = self.singleton() {
            write!(f, "{v:#x}")
        } else {
            write!(f, "[{:#x}, {:#x}]", self.lo, self.hi)
        }
    }
}

/// Value-range state per scalar register. `x0` is pinned to `0`.
///
/// `get` keeps the historical flat-lattice contract (`Some` only for a
/// single known constant) so the jalr resolver and the singleton
/// address lints are byte-identical to the old domain; `range` exposes
/// the interval for the range-based lints and the cost model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConstState {
    regs: [Interval; 32],
}

impl ConstState {
    /// Architectural state after [`crate::ref_iss::RefIss::load`]: every
    /// register is zeroed, then `sp` is set to the top of DRAM.
    pub fn entry(dram_bytes: usize) -> Self {
        let mut regs = [Interval::exact(0); 32];
        regs[reg::SP.num() as usize] = Interval::exact(sp_init(dram_bytes));
        ConstState { regs }
    }

    /// Known constant value of `r`, if its range is a singleton.
    #[inline]
    pub fn get(&self, r: Reg) -> Option<u32> {
        self.range(r).singleton()
    }

    /// Value range of `r`.
    #[inline]
    pub fn range(&self, r: Reg) -> Interval {
        if r.num() == 0 {
            Interval::exact(0)
        } else {
            self.regs[r.num() as usize]
        }
    }

    #[inline]
    fn set(&mut self, r: Reg, v: Interval) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    fn join(&self, other: &ConstState) -> ConstState {
        let mut out = self.clone();
        for k in 0..32 {
            out.regs[k] = out.regs[k].join(other.regs[k]);
        }
        out
    }

    fn widen(&self, next: &ConstState) -> ConstState {
        let mut out = self.clone();
        for k in 0..32 {
            out.regs[k] = out.regs[k].widen(next.regs[k]);
        }
        out
    }

    /// Apply `i` at `pc`.
    pub fn transfer(&mut self, i: &Instr, pc: u32, vlen_bytes: usize) {
        if let Some((rd, v)) = eval_scalar_def(i, pc, self) {
            self.set(rd, v);
        } else {
            // Remaining scalar defs (CSR reads, custom rd writers)
            // produce unknown values.
            for rd in effects(i, vlen_bytes).defs {
                self.set(rd, Interval::TOP);
            }
        }
    }
}

/// Range of a scalar-producing instruction, or `None` if the
/// instruction's defs must be set to ⊤ from its [`effects`]. Unlike the
/// old flat-constant domain, `mulh*`/`div*`/`rem*` and sub-word loads
/// keep (sound) partial information instead of dropping to ⊤.
fn eval_scalar_def(i: &Instr, pc: u32, st: &ConstState) -> Option<(Reg, Interval)> {
    use Instr::*;
    let e = |v: u32| Interval::exact(v);
    let r = match *i {
        Lui { rd, imm } => (rd, e(imm as u32)),
        Auipc { rd, imm } => (rd, e(pc.wrapping_add(imm as u32))),
        Jal { rd, .. } | Jalr { rd, .. } => (rd, e(pc.wrapping_add(4))),
        Addi { rd, rs1, imm } => (rd, st.range(rs1).add(e(imm as u32))),
        Slti { rd, rs1, imm } => (rd, st.range(rs1).lts(e(imm as u32))),
        Sltiu { rd, rs1, imm } => (rd, st.range(rs1).ltu(e(imm as u32))),
        Xori { rd, rs1, imm } => (rd, st.range(rs1).xor(e(imm as u32))),
        Ori { rd, rs1, imm } => (rd, st.range(rs1).or(e(imm as u32))),
        Andi { rd, rs1, imm } => (rd, st.range(rs1).and(e(imm as u32))),
        Slli { rd, rs1, shamt } => (rd, st.range(rs1).shl_imm(u32::from(shamt))),
        Srli { rd, rs1, shamt } => (rd, st.range(rs1).shr_imm(u32::from(shamt))),
        Srai { rd, rs1, shamt } => (rd, st.range(rs1).sar_imm(u32::from(shamt))),
        Add { rd, rs1, rs2 } => (rd, st.range(rs1).add(st.range(rs2))),
        Sub { rd, rs1, rs2 } => (rd, st.range(rs1).sub(st.range(rs2))),
        Sll { rd, rs1, rs2 } => (rd, st.range(rs1).shl(st.range(rs2))),
        Slt { rd, rs1, rs2 } => (rd, st.range(rs1).lts(st.range(rs2))),
        Sltu { rd, rs1, rs2 } => (rd, st.range(rs1).ltu(st.range(rs2))),
        Xor { rd, rs1, rs2 } => (rd, st.range(rs1).xor(st.range(rs2))),
        Srl { rd, rs1, rs2 } => (rd, st.range(rs1).shr(st.range(rs2))),
        Sra { rd, rs1, rs2 } => (rd, st.range(rs1).sar(st.range(rs2))),
        Or { rd, rs1, rs2 } => (rd, st.range(rs1).or(st.range(rs2))),
        And { rd, rs1, rs2 } => (rd, st.range(rs1).and(st.range(rs2))),
        Mul { rd, rs1, rs2 } => {
            let (a, b) = (st.range(rs1), st.range(rs2));
            match (a.singleton(), b.singleton()) {
                (Some(x), Some(y)) => (rd, e(x.wrapping_mul(y))),
                _ => (rd, a.mul(b)),
            }
        }
        Mulh { rd, rs1, rs2 } | Mulhsu { rd, rs1, rs2 } => {
            (rd, st.range(rs1).mulh_signed(st.range(rs2)))
        }
        Mulhu { rd, rs1, rs2 } => (rd, st.range(rs1).mulhu(st.range(rs2))),
        Div { rd, rs1, rs2 } => (rd, st.range(rs1).div_signed(st.range(rs2))),
        Divu { rd, rs1, rs2 } => (rd, st.range(rs1).divu(st.range(rs2))),
        Rem { rd, rs1, rs2 } => (rd, st.range(rs1).rem_signed(st.range(rs2))),
        Remu { rd, rs1, rs2 } => (rd, st.range(rs1).remu(st.range(rs2))),
        // Sub-word unsigned loads have architectural range bounds even
        // though their values are unknown.
        Lbu { rd, .. } => (rd, Interval::new(0, 0xff)),
        Lhu { rd, .. } => (rd, Interval::new(0, 0xffff)),
        Lb { rd, .. } | Lh { rd, .. } | Lw { rd, .. } => (rd, Interval::TOP),
        _ => return None,
    };
    Some(r)
}

/// Sound address range of a memory reference under `st`:
/// `base + index + offset` in interval arithmetic (⊤ when a wrap
/// straddles the address space).
pub fn mem_addr_range(m: &MemRef, st: &ConstState) -> Interval {
    let mut r = st.range(m.base);
    if let Some(idx) = m.index {
        r = r.add(st.range(idx));
    }
    r.add(Interval::exact(m.offset as u32))
}

// ---------------------------------------------------------------------------
// Must-initialized tracking
// ---------------------------------------------------------------------------

/// Registers guaranteed written on every path from entry. Meet is
/// intersection; a read outside the set is an uninitialized-read finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InitState {
    pub scalars: u32,
    pub vecs: u8,
    pub carry: bool,
}

impl InitState {
    /// Post-load architectural state: the loader zeroes everything, but
    /// only `x0`/`sp` (and the hardwired `v0`) carry *meaningful* values;
    /// reading any other register before writing it is flagged.
    pub fn entry() -> Self {
        InitState {
            scalars: 1 | (1 << reg::SP.num()),
            vecs: 1, // v0
            carry: false,
        }
    }

    #[inline]
    pub fn scalar(&self, r: Reg) -> bool {
        self.scalars & (1 << r.num()) != 0
    }

    #[inline]
    pub fn vec(&self, v: VReg) -> bool {
        self.vecs & (1 << v.num()) != 0
    }

    fn meet(&self, other: &InitState) -> InitState {
        InitState {
            scalars: self.scalars & other.scalars,
            vecs: self.vecs & other.vecs,
            carry: self.carry && other.carry,
        }
    }

    pub fn transfer(&mut self, i: &Instr, vlen_bytes: usize) {
        let e = effects(i, vlen_bytes);
        for r in e.defs {
            self.scalars |= 1 << r.num();
        }
        for v in e.vdefs {
            self.vecs |= 1 << v.num();
        }
        if e.defs_carry {
            self.carry = true;
        }
    }
}

// ---------------------------------------------------------------------------
// Liveness (backward)
// ---------------------------------------------------------------------------

/// Live register sets (union meet, backward direction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveState {
    pub scalars: u32,
    pub vecs: u8,
    pub carry: bool,
}

impl LiveState {
    pub fn none() -> Self {
        LiveState { scalars: 0, vecs: 0, carry: false }
    }

    /// Conservative exit state: everything observable.
    pub fn all() -> Self {
        LiveState { scalars: u32::MAX, vecs: u8::MAX, carry: true }
    }

    fn union(&self, other: &LiveState) -> LiveState {
        LiveState {
            scalars: self.scalars | other.scalars,
            vecs: self.vecs | other.vecs,
            carry: self.carry || other.carry,
        }
    }

    #[inline]
    pub fn scalar(&self, r: Reg) -> bool {
        self.scalars & (1 << r.num()) != 0
    }

    #[inline]
    pub fn vec(&self, v: VReg) -> bool {
        self.vecs & (1 << v.num()) != 0
    }

    /// Backward transfer: kill defs, then gen uses.
    pub fn transfer(&mut self, i: &Instr, vlen_bytes: usize) {
        let e = effects(i, vlen_bytes);
        for r in &e.defs {
            self.scalars &= !(1 << r.num());
        }
        for v in &e.vdefs {
            self.vecs &= !(1 << v.num());
        }
        if e.defs_carry {
            self.carry = false;
        }
        for r in &e.uses {
            self.scalars |= 1 << r.num();
        }
        for v in &e.vuses {
            self.vecs |= 1 << v.num();
        }
        if e.uses_carry {
            self.carry = true;
        }
        // x0/v0 are hardwired; they are never "live" in a meaningful sense
        // but keeping their bits set is harmless (dead-write reporting
        // skips them explicitly).
    }
}

// ---------------------------------------------------------------------------
// Fixpoint drivers
// ---------------------------------------------------------------------------

/// Generic forward worklist fixpoint. Returns the in-state of every
/// block (`None` for blocks unreachable from the entry).
pub fn forward_fixpoint<S: Clone + PartialEq>(
    cfg: &Cfg,
    entry: S,
    transfer: impl Fn(&BasicBlock, &S) -> S,
    meet: impl Fn(&S, &S) -> S,
) -> Vec<Option<S>> {
    let n = cfg.blocks.len();
    let mut ins: Vec<Option<S>> = vec![None; n];
    let Some(e) = cfg.entry_block else { return ins };
    ins[e] = Some(entry);
    let mut inq = vec![false; n];
    let mut work = VecDeque::from([e]);
    inq[e] = true;
    while let Some(b) = work.pop_front() {
        inq[b] = false;
        let st = ins[b].clone().expect("queued block has a state");
        let out = transfer(&cfg.blocks[b], &st);
        for &s in &cfg.blocks[b].succs {
            let merged = match &ins[s] {
                None => out.clone(),
                Some(cur) => meet(cur, &out),
            };
            if ins[s].as_ref() != Some(&merged) {
                ins[s] = Some(merged);
                if !inq[s] {
                    inq[s] = true;
                    work.push_back(s);
                }
            }
        }
    }
    ins
}

/// Join updates per block before widening kicks in. Small enough to
/// terminate fast, large enough that short counted loops (the usual
/// induction: pointer += stride a few times) converge to their exact
/// hull first.
const WIDEN_AFTER: u32 = 8;

/// Constant-propagation (value-range) in-states for every reachable
/// block. Unlike the generic [`forward_fixpoint`], this driver widens:
/// the interval domain has chains as long as the value space, so after
/// [`WIDEN_AFTER`] joins a still-moving bound jumps to its extreme,
/// bounding iteration without giving up soundness.
pub fn const_states(
    cfg: &Cfg,
    cache: &DecodeCache,
    dram_bytes: usize,
    vlen_bytes: usize,
) -> Vec<Option<ConstState>> {
    let n = cfg.blocks.len();
    let mut ins: Vec<Option<ConstState>> = vec![None; n];
    let Some(e) = cfg.entry_block else { return ins };
    ins[e] = Some(ConstState::entry(dram_bytes));
    let mut updates = vec![0u32; n];
    let mut inq = vec![false; n];
    let mut work = VecDeque::from([e]);
    inq[e] = true;
    while let Some(b) = work.pop_front() {
        inq[b] = false;
        let mut out = ins[b].clone().expect("queued block has a state");
        for (pc, i) in cfg.instrs(cache, &cfg.blocks[b]) {
            out.transfer(&i, pc, vlen_bytes);
        }
        for &s in &cfg.blocks[b].succs {
            let joined = match &ins[s] {
                None => out.clone(),
                Some(cur) => cur.join(&out),
            };
            let next = match &ins[s] {
                Some(cur) if updates[s] >= WIDEN_AFTER => cur.widen(&joined),
                _ => joined,
            };
            if ins[s].as_ref() != Some(&next) {
                updates[s] += 1;
                ins[s] = Some(next);
                if !inq[s] {
                    inq[s] = true;
                    work.push_back(s);
                }
            }
        }
    }
    ins
}

/// Must-initialized in-states for every reachable block.
pub fn init_states(cfg: &Cfg, cache: &DecodeCache, vlen_bytes: usize) -> Vec<Option<InitState>> {
    forward_fixpoint(
        cfg,
        InitState::entry(),
        |b, st| {
            let mut out = *st;
            for (_, i) in cfg.instrs(cache, b) {
                out.transfer(&i, vlen_bytes);
            }
            out
        },
        |a, b| a.meet(b),
    )
}

/// Backward liveness: live-out set of every block. Blocks whose exit is
/// not summarized by CFG successors (see [`Cfg::exit_unknown`]) treat
/// every register as live.
pub fn live_out_states(cfg: &Cfg, cache: &DecodeCache, vlen_bytes: usize) -> Vec<LiveState> {
    let n = cfg.blocks.len();
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, b) in cfg.blocks.iter().enumerate() {
        for &s in &b.succs {
            preds[s].push(id);
        }
    }
    let mut live_in = vec![LiveState::none(); n];
    let mut live_out = vec![LiveState::none(); n];
    let mut inq = vec![true; n];
    let mut work: VecDeque<usize> = (0..n).collect();
    while let Some(b) = work.pop_front() {
        inq[b] = false;
        let blk = &cfg.blocks[b];
        let mut out = if cfg.exit_unknown(blk) { LiveState::all() } else { LiveState::none() };
        for &s in &blk.succs {
            out = out.union(&live_in[s]);
        }
        live_out[b] = out;
        let mut st = out;
        let instrs: Vec<_> = cfg.instrs(cache, blk).collect();
        for (_, i) in instrs.iter().rev() {
            st.transfer(i, vlen_bytes);
        }
        if st != live_in[b] {
            live_in[b] = st;
            for &p in &preds[b] {
                if !inq[p] {
                    inq[p] = true;
                    work.push_back(p);
                }
            }
        }
    }
    live_out
}

/// Resolve `jalr` targets: for each reachable block ending in an
/// unresolved indirect jump, fold the block body from its const
/// in-state and compute `(base + offset) & !1`. Returns
/// `(word_index_of_jalr, masked_target)` pairs.
pub fn resolve_jalrs(
    cfg: &Cfg,
    cache: &DecodeCache,
    states: &[Option<ConstState>],
    vlen_bytes: usize,
) -> Vec<(usize, u32)> {
    let mut out = Vec::new();
    for (id, b) in cfg.blocks.iter().enumerate() {
        if !matches!(b.term, super::cfg::Terminator::Indirect { resolved: None }) {
            continue;
        }
        let Some(st0) = &states[id] else { continue };
        let mut st = st0.clone();
        let mut resolved = None;
        for (pc, i) in cfg.instrs(cache, b) {
            if let Instr::Jalr { rs1, offset, .. } = i {
                resolved = st.get(rs1).map(|c| c.wrapping_add(offset as u32) & !1);
            }
            st.transfer(&i, pc, vlen_bytes);
        }
        if let Some(t) = resolved {
            out.push((b.start + b.ninstr - 1, t));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::{IPrime, SPrime};
    use crate::isa::reg::*;
    use crate::isa::CustomSlot;

    #[test]
    fn effects_cover_custom_slots() {
        let ip = IPrime { vrs1: V1, vrd1: V2, vrs2: V3, vrd2: V4, rs1: A0, rd: A1 };
        let merge = Instr::CustomI { slot: CustomSlot::C1, funct3: 0, ops: ip };
        let e = effects(&merge, 32);
        assert_eq!(e.vuses, vec![V1, V3]);
        assert_eq!(e.vdefs, vec![V2, V4]);
        assert!(e.valid_custom && e.defs.is_empty());

        let vfilt = Instr::CustomI { slot: CustomSlot::C1, funct3: 3, ops: ip };
        let e = effects(&vfilt, 32);
        assert_eq!(e.defs, vec![A1]);
        assert_eq!(e.uses, vec![A0]);

        let prefix = Instr::CustomI { slot: CustomSlot::C3, funct3: 0, ops: ip };
        let e = effects(&prefix, 32);
        assert!(e.uses_carry && e.defs_carry);

        let bad = Instr::CustomI { slot: CustomSlot::C2, funct3: 1, ops: ip };
        assert!(!effects(&bad, 32).valid_custom);

        let sp = SPrime { vrs1: V1, vrd1: V2, imm: 0, rs2: A2, rs1: A0, rd: ZERO };
        let lv = Instr::CustomS { slot: CustomSlot::C0, funct3: 4, ops: sp };
        let e = effects(&lv, 64);
        let m = e.mem.expect("lv touches memory");
        assert!(!m.store && m.len == 64 && m.index == Some(A2));

        let bad_s = Instr::CustomS { slot: CustomSlot::C1, funct3: 4, ops: sp };
        assert!(!effects(&bad_s, 64).valid_custom);
    }

    #[test]
    fn const_entry_matches_loader() {
        let st = ConstState::entry(64 * 1024 * 1024);
        assert_eq!(st.get(ZERO), Some(0));
        assert_eq!(st.get(SP), Some(64 * 1024 * 1024));
        assert_eq!(st.get(A0), Some(0));
    }

    #[test]
    fn const_transfer_folds_li_and_auipc_chains() {
        let mut st = ConstState::entry(1 << 20);
        // lui a0, 0x100 ; addi a0, a0, 0x42
        st.transfer(&Instr::Lui { rd: A0, imm: 0x100 << 12 }, 0x1000, 32);
        st.transfer(&Instr::Addi { rd: A0, rs1: A0, imm: 0x42 }, 0x1004, 32);
        assert_eq!(st.get(A0), Some(0x0010_0042));
        // auipc t0, 0 at 0x2000
        st.transfer(&Instr::Auipc { rd: T0, imm: 0 }, 0x2000, 32);
        assert_eq!(st.get(T0), Some(0x2000));
        // a load makes its destination unknown
        st.transfer(&Instr::Lw { rd: A0, rs1: SP, offset: -4 }, 0x2004, 32);
        assert_eq!(st.get(A0), None);
        // x0 stays pinned even if "written"
        st.transfer(&Instr::Addi { rd: ZERO, rs1: A0, imm: 1 }, 0x2008, 32);
        assert_eq!(st.get(ZERO), Some(0));
    }

    #[test]
    fn init_meet_is_intersection_and_carry_tracked() {
        let mut a = InitState::entry();
        a.transfer(&Instr::Addi { rd: A0, rs1: ZERO, imm: 1 }, 32);
        let b = InitState::entry();
        let m = a.meet(&b);
        assert!(!m.scalar(A0) && m.scalar(SP));

        let ip = IPrime { vrs1: V1, vrd1: V2, vrs2: V0, vrd2: V0, rs1: ZERO, rd: ZERO };
        let mut c = InitState::entry();
        assert!(!c.carry);
        c.transfer(&Instr::CustomI { slot: CustomSlot::C3, funct3: 1, ops: ip }, 32);
        assert!(c.carry, "c3_reset defines the carry");
    }

    #[test]
    fn liveness_kill_then_gen() {
        let mut st = LiveState::none();
        st.scalars = 1 << A0.num();
        // a0 = a1 + a2 : a0 dies, a1/a2 born
        st.transfer(&Instr::Add { rd: A0, rs1: A1, rs2: A2 }, 32);
        assert!(!st.scalar(A0) && st.scalar(A1) && st.scalar(A2));
    }

    #[test]
    fn interval_add_sub_track_wraparound() {
        let a = Interval::new(10, 20);
        let b = Interval::new(1, 2);
        assert_eq!(a.add(b), Interval::new(11, 22));
        assert_eq!(a.sub(b), Interval::new(8, 19));
        // Both ends wrap: still precise.
        let top_end = Interval::new(u32::MAX - 1, u32::MAX);
        assert_eq!(top_end.add(Interval::exact(2)), Interval::new(0, 1));
        assert_eq!(
            Interval::new(0, 1).sub(Interval::exact(2)),
            Interval::new(u32::MAX - 1, u32::MAX)
        );
        // Straddling the 2^32 boundary loses everything.
        assert!(Interval::new(u32::MAX - 1, u32::MAX).add(Interval::new(0, 2)).is_top());
        assert!(Interval::new(0, 4).sub(Interval::exact(2)).is_top());
    }

    #[test]
    fn interval_bitops_and_shifts_stay_sound() {
        let a = Interval::new(0x10, 0x1f);
        assert_eq!(a.shl_imm(4), Interval::new(0x100, 0x1f0));
        assert_eq!(a.shr_imm(4), Interval::exact(1));
        assert!(Interval::new(0, u32::MAX).shl_imm(1).is_top());
        // Bit ops on non-singletons fall back to bit-smeared bounds.
        let b = Interval::new(8, 11);
        assert_eq!(a.and(b), Interval::new(0, 11));
        assert_eq!(a.or(b), Interval::new(0x10, 0x1f));
        assert_eq!(a.xor(b), Interval::new(0, 0x1f));
        // All-negative ranges shift arithmetically without losing sign.
        let neg = Interval::new(-64i32 as u32, -16i32 as u32);
        assert_eq!(neg.sar_imm(2), Interval::new(-16i32 as u32, -4i32 as u32));
        assert!(Interval::new(0, u32::MAX).sar_imm(2).is_top());
    }

    #[test]
    fn interval_compare_divide_and_remainder() {
        let small = Interval::new(0, 9);
        let big = Interval::new(10, 20);
        assert_eq!(small.ltu(big), Interval::exact(1));
        assert_eq!(big.ltu(small), Interval::exact(0));
        assert_eq!(small.ltu(Interval::new(5, 20)), Interval::new(0, 1));
        assert_eq!(big.divu(Interval::new(2, 5)), Interval::new(2, 10));
        assert!(big.divu(Interval::new(0, 5)).is_top(), "divisor range with 0 must give up");
        assert_eq!(big.remu(Interval::exact(8)), Interval::new(0, 7));
        assert_eq!(big.remu(Interval::new(0, 8)), Interval::new(0, 20));
    }

    #[test]
    fn const_range_feeds_address_intervals() {
        let mut st = ConstState::entry(1 << 20);
        // lbu bounds its destination to a byte even though the loaded
        // value itself is unknown.
        st.transfer(&Instr::Lbu { rd: A0, rs1: SP, offset: -1 }, 0x1000, 32);
        assert_eq!(st.range(A0), Interval::new(0, 255));
        st.transfer(&Instr::Slli { rd: A0, rs1: A0, shamt: 2 }, 0x1004, 32);
        assert_eq!(st.range(A0), Interval::new(0, 1020));
        st.transfer(&Instr::Addi { rd: A1, rs1: ZERO, imm: 0x800 }, 0x1008, 32);
        let m = MemRef { base: A1, index: Some(A0), offset: 4, len: 4, store: false };
        assert_eq!(mem_addr_range(&m, &st), Interval::new(0x804, 0x804 + 1020));
    }
}
