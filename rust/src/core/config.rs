//! Core timing configuration (§3.2 of the paper).

use crate::isa::Instr;

/// Timing parameters of the single-pipeline-stage softcore.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreConfig {
    /// Vector register width in bits (Fig. 3 right explores 128–1024;
    /// Table 1 selects 256).
    pub vlen_bits: usize,
    /// Clock the design closed timing at, used to convert cycles to
    /// seconds (150 MHz in Table 1; 125 MHz for the 1024-bit variant).
    pub fmax_mhz: f64,
    /// Extra load-use latency on a DL1 hit: the paper's 3-cycle load pipe
    /// means a dependent instruction executes 3 cycles after the load
    /// issues ("effectively ... 2 cycles for cache hits", §3.2).
    pub load_use_cycles: u64,
    /// Iterative divider latency (div/rem block the pipeline).
    pub div_cycles: u64,
    /// Single-cycle DSP multiplier (§3.2 "almost all instructions consume
    /// 1 cycle").
    pub mul_cycles: u64,
    /// Extra cycles after a taken branch/jump (0: the single-stage core
    /// fetches the target next cycle on an IL1 hit).
    pub branch_taken_penalty: u64,
    /// CPI multiplier for *every* instruction — 1 for this work. The
    /// PicoRV32 baseline model reuses the core with ~4 (its documented
    /// CPI ballpark) and no caches.
    pub base_cpi: u64,
    /// In-order issue width: how many instructions may enter the
    /// pipeline per cycle. `1` (the default, also how `0` behaves) is
    /// the paper's single-issue model, reproduced cycle for cycle.
    /// `2`/`4` enable the superscalar issue-group model (DESIGN.md §5):
    /// same-cycle instructions must be independent (scoreboard), share
    /// the single data port and each SIMD unit's one-issue-per-cycle
    /// slot, `div`/`rem` issue alone, and a taken branch or jump ends
    /// its issue group.
    pub issue_width: usize,
}

impl CoreConfig {
    /// The paper's selected configuration (Table 1).
    pub fn paper_default() -> Self {
        Self::for_vlen(256)
    }

    /// Table-1 timing at a given VLEN. Following §4.1, every width closed
    /// timing at 150 MHz except 1024-bit which ran at 125 MHz.
    pub fn for_vlen(vlen_bits: usize) -> Self {
        CoreConfig {
            vlen_bits,
            fmax_mhz: if vlen_bits >= 1024 { 125.0 } else { 150.0 },
            load_use_cycles: 3,
            div_cycles: 32,
            mul_cycles: 1,
            branch_taken_penalty: 0,
            base_cpi: 1,
            issue_width: 1,
        }
    }

    pub fn lanes(&self) -> usize {
        self.vlen_bits / 32
    }

    pub fn vlen_bytes(&self) -> usize {
        self.vlen_bits / 8
    }

    /// Convert a cycle count to seconds at this core's clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.fmax_mhz * 1e6)
    }

    /// Whether `i` must issue alone when `issue_width > 1`: the iterative
    /// divider always blocks the group; the multiplier only does when it
    /// is configured multi-cycle (`mul_cycles > 1`). This predicate is
    /// the single source of truth shared by the timed core's issue logic
    /// and the static cost model (`analysis::perf`).
    pub fn serial_issue(&self, i: &Instr) -> bool {
        match i {
            Instr::Div { .. } | Instr::Divu { .. } | Instr::Rem { .. } | Instr::Remu { .. } => true,
            Instr::Mul { .. } | Instr::Mulh { .. } | Instr::Mulhsu { .. } | Instr::Mulhu { .. } => {
                self.mul_cycles > 1
            }
            _ => false,
        }
    }

    /// The completion cycle of a load issued at `issue` under flat/magic
    /// memory (access ready the same cycle): the load-use pipe and the
    /// 2-cycle data-return floor, whichever is later. Shared by the core
    /// (which applies the same formula to the real access's ready time)
    /// and the static cost model's exact flat-memory path.
    pub fn flat_load_ready(&self, issue: u64) -> u64 {
        (issue + self.load_use_cycles).max(issue + 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = CoreConfig::paper_default();
        assert_eq!(c.vlen_bits, 256);
        assert_eq!(c.fmax_mhz, 150.0);
        assert_eq!(c.lanes(), 8);
        assert_eq!(c.vlen_bytes(), 32);
        assert_eq!(c.load_use_cycles, 3);
        assert_eq!(c.issue_width, 1, "the paper machine is single-issue");
    }

    #[test]
    fn wide_vlen_clocks_slower() {
        assert_eq!(CoreConfig::for_vlen(1024).fmax_mhz, 125.0);
        assert_eq!(CoreConfig::for_vlen(512).fmax_mhz, 150.0);
    }

    #[test]
    fn cycle_time_conversion() {
        let c = CoreConfig::paper_default();
        assert!((c.cycles_to_seconds(150_000_000) - 1.0).abs() < 1e-12);
    }
}
