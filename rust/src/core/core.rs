//! The single-pipeline-stage softcore (§3.2).
//!
//! Timing model (the "contract" in DESIGN.md §5):
//! - every instruction issues in order, one per cycle (plus stalls);
//! - ALU/branch results are visible to the next instruction (no forwarding
//!   stalls by construction — consecutive dependent instructions execute
//!   back-to-back, §3.2);
//! - loads have a 3-cycle pipe: a dependent instruction executes 3 cycles
//!   after the load issues (2 effective stall cycles, §3.2); misses add
//!   the memory system's latency;
//! - div/rem block for `div_cycles`;
//! - custom SIMD instructions occupy their unit's pipeline for
//!   `cN_cycles` (the unit's structural latency) but are fully pipelined
//!   (initiation interval 1): back-to-back calls overlap, which is the
//!   effect Fig. 6 visualises;
//! - dependency tracking is by per-register ready times (scoreboard), the
//!   simulator equivalent of the template's delayed destination-name
//!   shift register;
//! - `issue_width > 1` (DESIGN.md §5 "Pipeline model") opens an in-order
//!   superscalar issue group per cycle: up to `issue_width` independent
//!   instructions issue together, subject to the scoreboard, one
//!   data-port access per cycle, one issue per SIMD unit per cycle,
//!   div/rem issuing alone and a taken branch/jump ending its group.
//!   Scalar stores consume their data operand at commit (store-buffer
//!   model), not at issue. `issue_width = 1` bypasses all of this and
//!   reproduces the original timestamp model cycle for cycle.

use super::config::CoreConfig;
use super::trace::{Trace, TraceEvent};
use crate::asm::Program;
use crate::isa::instr::csr;
use crate::isa::{decode, DecodeCache, DecodeError, Instr};
use crate::mem::{MemConfig, MemConfigError, MemSys};
use crate::simd::{standard_pool, UnitError, UnitInputs, UnitPool, VecMemOp, VecVal};

#[derive(Debug)]
pub enum SimError {
    Illegal { pc: u32, source: DecodeError },
    MemFault { pc: u32, addr: u32, len: usize, size: usize },
    /// A multi-byte access whose end address (`addr + len`) overflows
    /// the 32-bit address space (e.g. a 4-byte load at 0xFFFF_FFFE).
    /// Architecturally distinct from [`SimError::MemFault`]: no DRAM
    /// size can ever make such an access legal, and the address
    /// computation must not wrap back over low memory. All three
    /// backends (Core, RefIss, PicoCore) raise it identically.
    MemWrap { pc: u32, addr: u32, len: usize },
    /// Instruction fetch outside DRAM (a wild `jalr`/branch target).
    FetchFault { pc: u32, size: usize },
    /// Instruction fetch from a non-word-aligned pc (reachable through
    /// `jalr`, which clears only bit 0, and through branch offsets that
    /// are multiples of 2 but not 4).
    FetchMisaligned { pc: u32 },
    Unit { pc: u32, source: UnitError },
    Watchdog(u64),
    Break(u32),
    /// A host-side image write (`RefIss::load` / `RefIss::host_write`)
    /// outside simulated DRAM — the image is rejected instead of
    /// panicking on the slice copy.
    ImageFault { addr: u32, len: usize, size: usize },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Illegal { pc, source } => {
                write!(f, "illegal instruction at pc {pc:#010x}: {source}")
            }
            SimError::MemFault { pc, addr, len, size } => write!(
                f,
                "memory fault at pc {pc:#010x}: access {addr:#010x}+{len} outside DRAM ({size:#x} bytes)"
            ),
            SimError::MemWrap { pc, addr, len } => write!(
                f,
                "memory fault at pc {pc:#010x}: access {addr:#010x}+{len} wraps the 32-bit address space"
            ),
            SimError::FetchFault { pc, size } => {
                write!(f, "fetch fault: pc {pc:#010x} outside DRAM ({size:#x} bytes)")
            }
            SimError::FetchMisaligned { pc } => {
                write!(f, "misaligned fetch: pc {pc:#010x} is not word-aligned")
            }
            SimError::Unit { pc, source } => {
                write!(f, "custom instruction fault at pc {pc:#010x}: {source}")
            }
            SimError::Watchdog(max) => {
                write!(f, "watchdog: exceeded {max} instructions without halting")
            }
            SimError::Break(pc) => write!(f, "ebreak at pc {pc:#010x}"),
            SimError::ImageFault { addr, len, size } => write!(
                f,
                "image fault: host write {addr:#010x}+{len} outside DRAM ({size:#x} bytes)"
            ),
        }
    }
}

impl std::error::Error for SimError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SimError::Illegal { source, .. } => Some(source),
            SimError::Unit { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Retired-instruction class counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CoreCounters {
    pub alu: u64,
    pub loads: u64,
    pub stores: u64,
    pub branches: u64,
    pub taken_branches: u64,
    pub jumps: u64,
    pub mul: u64,
    pub div: u64,
    pub custom: [u64; 4],
    /// Cycles lost waiting on source operands (RAW hazards).
    /// Write-ordering waits are NOT booked here — they are
    /// `waw_stall_cycles` (the seed model lumped both together,
    /// inflating the RAW-hazard count on vector code).
    pub raw_stall_cycles: u64,
    /// Cycles a custom instruction waited for a prior writer of its
    /// destination vreg (in-order writeback, WAW hazard).
    pub waw_stall_cycles: u64,
    /// Cycles lost waiting on instruction fetch (IL1 misses).
    pub fetch_stall_cycles: u64,
    /// Cycles lost on the data port's structural hazard (an operation
    /// issued the previous cycle). MSHR-full waits are NOT booked here:
    /// they delay an access's completion and are counted per cache
    /// level in `CacheStats::mshr_wait_cycles`.
    pub mem_struct_stall_cycles: u64,
    /// Cycles lost waiting for in-flight data on the blocking port
    /// (bandwidth/latency exposure; zero once the port is non-blocking,
    /// where the wait shows up as MSHR/queue statistics and RAW stalls
    /// instead).
    pub mem_bw_stall_cycles: u64,
    /// Instructions that issued in the same cycle as at least one
    /// earlier instruction (always 0 at `issue_width = 1`). At width 2
    /// this equals the number of dual-issued cycles.
    pub dual_issue_pairs: u64,
    /// Unused issue slots in cycles where at least one instruction
    /// issued (always 0 at `issue_width = 1`; cycles where *nothing*
    /// issued are covered by the stall counters instead).
    pub issue_slots_wasted: u64,
}

impl CoreCounters {
    pub fn custom_total(&self) -> u64 {
        self.custom.iter().sum()
    }

    /// Total data-port stall (the former `mem_port_stall_cycles`).
    pub fn mem_stall_cycles(&self) -> u64 {
        self.mem_struct_stall_cycles + self.mem_bw_stall_cycles
    }
}

/// Result of a completed run.
#[derive(Debug, Clone, Copy)]
pub struct RunResult {
    pub cycles: u64,
    pub instret: u64,
    pub counters: CoreCounters,
}

impl RunResult {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instret as f64 / self.cycles as f64
        }
    }
}

pub struct Core {
    pub cfg: CoreConfig,
    pub mem: MemSys,
    pub pool: UnitPool,
    pub trace: Trace,

    regs: [u32; 32],
    vregs: [VecVal; 8],
    pc: u32,
    cycle: u64,
    instret: u64,
    reg_ready: [u64; 32],
    vreg_ready: [u64; 8],
    halted: bool,

    /// Predecoded text segment (shared contract with the reference ISS:
    /// decode once per word, invalidate on stores overlapping the text
    /// range — see `crate::isa::predecode`).
    text: DecodeCache,
    /// Fetch line buffer: base address of the IL1 block the last fetch
    /// came from. Fetches within the same block with an already-decoded
    /// instruction skip the IL1 model entirely (a hit is timing-neutral:
    /// ready == now) — the dominant fast path. Invalidated on load()
    /// and on any store into the text range.
    fetch_block_base: u32,
    fetch_block_mask: u32,
    /// IL1 hits skipped via the line buffer (credited to IL1 stats at
    /// the end of run()).
    fast_fetches: u64,
    /// Superscalar issue-group bookkeeping (`issue_width > 1` only):
    /// instructions already issued at cycle `self.cycle`.
    issue_used: u64,
    /// Last cycle each SIMD unit slot accepted an instruction — each
    /// unit is fully pipelined but single-issue (initiation interval 1),
    /// so two custom instructions on one slot cannot share a cycle.
    unit_issue_cycle: [u64; 4],

    counters: CoreCounters,
}

impl Core {
    /// Core with the standard unit pool for its VLEN; panics on an
    /// invalid memory configuration (use [`Core::try_new`] to handle
    /// rejected configs gracefully).
    pub fn new(cfg: CoreConfig, mem_cfg: MemConfig) -> Self {
        Self::try_new(cfg, mem_cfg).expect("invalid memory configuration")
    }

    /// Fallible constructor: rejects invalid memory configurations
    /// (zero ways/MSHRs/channels, L1 block larger than the LLC block, a
    /// DL1 block that does not match the vector width, …) instead of
    /// panicking mid-build.
    pub fn try_new(cfg: CoreConfig, mem_cfg: MemConfig) -> Result<Self, MemConfigError> {
        if mem_cfg.dl1.block_bits != cfg.vlen_bits {
            // §3.1.1: the DL1 block size must equal the vector register
            // width — the no-fetch-on-full-write path depends on it.
            return Err(MemConfigError::BlockVlenMismatch {
                block_bits: mem_cfg.dl1.block_bits,
                vlen_bits: cfg.vlen_bits,
            });
        }
        let lanes = cfg.lanes();
        let mem_block_bytes = mem_cfg.il1.block_bytes();
        Ok(Self {
            cfg,
            mem: MemSys::new(mem_cfg)?,
            pool: standard_pool(cfg.vlen_bits),
            trace: Trace::disabled(),
            regs: [0; 32],
            vregs: [VecVal::zero(lanes); 8],
            pc: 0,
            cycle: 0,
            instret: 0,
            reg_ready: [0; 32],
            vreg_ready: [0; 8],
            halted: false,
            text: DecodeCache::empty(),
            fetch_block_base: u32::MAX,
            fetch_block_mask: !(mem_block_bytes as u32 - 1),
            fast_fetches: 0,
            issue_used: 0,
            unit_issue_cycle: [u64::MAX; 4],
            counters: CoreCounters::default(),
        })
    }

    /// Paper-default core (Table 1).
    pub fn paper_default() -> Self {
        Self::new(CoreConfig::paper_default(), MemConfig::paper_default())
    }

    /// Paper-shaped core at a given VLEN (used by the Fig. 3 sweeps).
    pub fn for_vlen(vlen_bits: usize) -> Self {
        Self::new(CoreConfig::for_vlen(vlen_bits), MemConfig::for_vlen(vlen_bits))
    }

    /// Load a program and reset architectural state. The stack pointer is
    /// initialised to the top of DRAM (16-byte aligned, capped at the
    /// 32-bit address-space limit — see [`crate::arch::sp_init`]).
    ///
    /// An image that does not fit the configured DRAM is rejected as
    /// [`SimError::ImageFault`] (the same contract as [`crate::ref_iss::RefIss::load`])
    /// instead of panicking on the host-side copy — ELF segments place
    /// arbitrary user-controlled addresses on this path.
    pub fn load(&mut self, prog: &Program) -> Result<(), SimError> {
        let size = self.mem.dram_size();
        for (base, len) in [(prog.text_base, prog.text.len() * 4), (prog.data_base, prog.data.len())]
        {
            if base as u64 + len as u64 > size as u64 {
                return Err(SimError::ImageFault { addr: base, len, size });
            }
        }
        self.mem.load_program(prog);
        self.regs = [0; 32];
        self.vregs = [VecVal::zero(self.cfg.lanes()); 8];
        self.regs[2] = crate::arch::sp_init(self.mem.dram_size());
        self.pc = prog.entry;
        self.cycle = 0;
        self.instret = 0;
        self.reg_ready = [0; 32];
        self.vreg_ready = [0; 8];
        self.halted = false;
        self.counters = CoreCounters::default();
        self.text.predecode(prog.text_base, &prog.text);
        self.fetch_block_base = u32::MAX;
        self.fast_fetches = 0;
        self.issue_used = 0;
        self.unit_issue_cycle = [u64::MAX; 4];
        self.pool.reset_all();
        Ok(())
    }

    // ---- host accessors ---------------------------------------------------

    pub fn reg(&self, r: crate::isa::Reg) -> u32 {
        self.regs[r.num() as usize]
    }

    pub fn set_reg(&mut self, r: crate::isa::Reg, v: u32) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    pub fn vreg(&self, v: crate::isa::VReg) -> VecVal {
        self.vregs[v.num() as usize]
    }

    pub fn set_vreg(&mut self, v: crate::isa::VReg, val: VecVal) {
        if v.num() != 0 {
            self.vregs[v.num() as usize] = val;
        }
    }

    pub fn pc(&self) -> u32 {
        self.pc
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    pub fn instret(&self) -> u64 {
        self.instret
    }

    pub fn halted(&self) -> bool {
        self.halted
    }

    pub fn counters(&self) -> CoreCounters {
        self.counters
    }

    /// Run until `ecall` or the instruction budget is exhausted.
    pub fn run(&mut self, max_instrs: u64) -> Result<RunResult, SimError> {
        let start_instret = self.instret;
        while !self.halted {
            if self.instret - start_instret >= max_instrs {
                self.flush_fetch_credits();
                return Err(SimError::Watchdog(max_instrs));
            }
            self.step()?;
        }
        self.flush_fetch_credits();
        Ok(RunResult { cycles: self.cycle, instret: self.instret, counters: self.counters })
    }

    /// Credit line-buffer fetches to the IL1 hit counters (they are
    /// architecturally IL1 hits; the line buffer is a simulator
    /// optimisation, not a microarchitectural feature).
    pub fn flush_fetch_credits(&mut self) {
        if self.fast_fetches > 0 {
            self.mem.credit_il1_hits(self.fast_fetches);
            self.fast_fetches = 0;
        }
    }

    #[inline]
    fn check_mem(&self, addr: u32, len: usize) -> Result<(), SimError> {
        // End-of-range rule in u64 (not usize, whose width is
        // host-dependent): first classify accesses whose end address
        // overflows the 32-bit space, then plain out-of-DRAM ones.
        let end = addr as u64 + len as u64;
        if end > 1 << 32 {
            return Err(SimError::MemWrap { pc: self.pc, addr, len });
        }
        if end > self.mem.dram_size() as u64 {
            return Err(SimError::MemFault {
                pc: self.pc,
                addr,
                len,
                size: self.mem.dram_size(),
            });
        }
        Ok(())
    }

    #[inline]
    fn read_reg_stalling(&mut self, r: crate::isa::Reg, t: &mut u64) -> u32 {
        let n = r.num() as usize;
        if self.reg_ready[n] > *t {
            self.counters.raw_stall_cycles += self.reg_ready[n] - *t;
            *t = self.reg_ready[n];
        }
        self.regs[n]
    }

    #[inline]
    fn read_vreg_stalling(&mut self, v: crate::isa::VReg, t: &mut u64) -> VecVal {
        let n = v.num() as usize;
        if self.vreg_ready[n] > *t {
            self.counters.raw_stall_cycles += self.vreg_ready[n] - *t;
            *t = self.vreg_ready[n];
        }
        self.vregs[n]
    }

    #[inline]
    fn write_reg(&mut self, r: crate::isa::Reg, v: u32, ready: u64) {
        let n = r.num() as usize;
        if n != 0 {
            self.regs[n] = v;
            self.reg_ready[n] = ready;
        }
    }

    #[inline]
    fn write_vreg(&mut self, v: crate::isa::VReg, val: VecVal, ready: u64) {
        let n = v.num() as usize;
        if n != 0 {
            self.vregs[n] = val;
            self.vreg_ready[n] = ready;
        }
    }

    /// Decode the instruction at `pc` whose fetched word is `word`,
    /// through the predecoded text cache. Text words are predecoded at
    /// `load()`; this path only decodes words that were undecodable at
    /// load time or have been invalidated by a store into the text
    /// range, plus any fetch from outside the text segment.
    fn decode_at(&mut self, pc: u32, word: u32) -> Result<Instr, SimError> {
        if let Some(idx) = self.text.word_index(pc) {
            if let Some(i) = self.text.get(idx) {
                return Ok(i);
            }
            let i = decode(word).map_err(|source| SimError::Illegal { pc, source })?;
            self.text.put(idx, i);
            return Ok(i);
        }
        decode(word).map_err(|source| SimError::Illegal { pc, source })
    }

    /// A store (scalar or vector) wrote into `[addr, addr+len)`, which
    /// overlaps the text segment: drop the stale decodes, clear the
    /// fetch line buffer (the buffered IL1 block may hold the old
    /// bytes), and make the memory hierarchy coherent for instruction
    /// fetch. The hierarchy sync is host-side (no cycles booked): after
    /// self-modifying code the refetch is modeled as cold, which is the
    /// conservative choice and changes nothing for programs that never
    /// store to text (the golden traces pin this).
    fn invalidate_text(&mut self, addr: u32, len: usize) {
        self.text.invalidate(addr, len);
        self.fetch_block_base = u32::MAX;
        self.mem.sync_fetch();
    }

    /// Execute one instruction.
    pub fn step(&mut self) -> Result<(), SimError> {
        debug_assert!(!self.halted, "step() after halt");
        let pc = self.pc;
        // Misaligned fetch faults before any array/cache indexing: a
        // wild `jalr` (bit 0 cleared, bit 1 live) or a branch offset of
        // 4k+2 must report, not truncate into the decode cache or read
        // across an IL1 block boundary.
        if pc % 4 != 0 {
            return Err(SimError::FetchMisaligned { pc });
        }
        let width = self.cfg.issue_width as u64;
        if width > 1 && self.issue_used >= width {
            // The open issue group is full: start the next cycle.
            self.cycle += self.cfg.base_cpi;
            self.issue_used = 0;
        }
        // Fast path: same IL1 block as the previous fetch and already
        // decoded — an IL1 hit is timing-neutral, so skip the model.
        let cached = if (pc & self.fetch_block_mask) == self.fetch_block_base {
            self.text.word_index(pc).and_then(|idx| self.text.get(idx))
        } else {
            None
        };
        let instr = match cached {
            Some(i) => {
                self.fast_fetches += 1;
                i
            }
            None => {
                if (pc as usize).checked_add(4).is_none_or(|end| end > self.mem.dram_size()) {
                    return Err(SimError::FetchFault { pc, size: self.mem.dram_size() });
                }
                let (word, fetch_ready) = self.mem.fetch(pc, self.cycle);
                if fetch_ready > self.cycle {
                    self.counters.fetch_stall_cycles += fetch_ready - self.cycle;
                    if width > 1 && self.issue_used > 0 {
                        // The IL1 miss closes the open issue group.
                        self.counters.issue_slots_wasted += width - self.issue_used;
                        self.issue_used = 0;
                    }
                    self.cycle = fetch_ready;
                }
                self.fetch_block_base = pc & self.fetch_block_mask;
                self.decode_at(pc, word)?
            }
        };

        // Serialising classes issue alone: the iterative divider (and a
        // multi-cycle multiplier, if configured) blocks the pipeline. The
        // predicate lives on CoreConfig so the static cost model
        // (analysis::perf) reads the same rule instead of duplicating it.
        use Instr::*;
        let serial = width > 1 && self.cfg.serial_issue(&instr);
        if serial && self.issue_used > 0 {
            self.counters.issue_slots_wasted += width - self.issue_used;
            self.cycle += self.cfg.base_cpi;
            self.issue_used = 0;
        }

        let group_cycle = self.cycle; // the issue group this instruction tries to join
        let mut t = self.cycle; // issue time after operand stalls
        let mut next_pc = pc.wrapping_add(4);
        // Control-flow redirect (taken branch or jump). Tracked
        // explicitly rather than by comparing next_pc to pc + 4: a jump
        // *targeting* pc + 4 still redirects fetch and must end its
        // issue group at width > 1.
        let mut redirect = false;
        let mut end = t + 1; // completion time for the trace
        match instr {
            Lui { rd, imm } => {
                self.counters.alu += 1;
                self.write_reg(rd, imm as u32, t + 1);
            }
            Auipc { rd, imm } => {
                self.counters.alu += 1;
                self.write_reg(rd, pc.wrapping_add(imm as u32), t + 1);
            }
            Jal { rd, offset } => {
                self.counters.jumps += 1;
                self.write_reg(rd, pc.wrapping_add(4), t + 1);
                next_pc = pc.wrapping_add(offset as u32);
                redirect = true;
                t += self.cfg.branch_taken_penalty;
            }
            Jalr { rd, rs1, offset } => {
                self.counters.jumps += 1;
                let base = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, pc.wrapping_add(4), t + 1);
                next_pc = base.wrapping_add(offset as u32) & !1;
                redirect = true;
                t += self.cfg.branch_taken_penalty;
            }
            Beq { rs1, rs2, offset }
            | Bne { rs1, rs2, offset }
            | Blt { rs1, rs2, offset }
            | Bge { rs1, rs2, offset }
            | Bltu { rs1, rs2, offset }
            | Bgeu { rs1, rs2, offset } => {
                self.counters.branches += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                let b = self.read_reg_stalling(rs2, &mut t);
                let take = match instr {
                    Beq { .. } => a == b,
                    Bne { .. } => a != b,
                    Blt { .. } => (a as i32) < (b as i32),
                    Bge { .. } => (a as i32) >= (b as i32),
                    Bltu { .. } => a < b,
                    Bgeu { .. } => a >= b,
                    _ => unreachable!(),
                };
                if take {
                    self.counters.taken_branches += 1;
                    next_pc = pc.wrapping_add(offset as u32);
                    redirect = true;
                    t += self.cfg.branch_taken_penalty;
                }
            }
            Lb { rd, rs1, offset }
            | Lh { rd, rs1, offset }
            | Lw { rd, rs1, offset }
            | Lbu { rd, rs1, offset }
            | Lhu { rd, rs1, offset } => {
                self.counters.loads += 1;
                let base = self.read_reg_stalling(rs1, &mut t);
                let addr = base.wrapping_add(offset as u32);
                let len = match instr {
                    Lb { .. } | Lbu { .. } => 1,
                    Lh { .. } | Lhu { .. } => 2,
                    _ => 4,
                };
                self.check_mem(addr, len)?;
                let mut buf = [0u8; 4];
                let access = self.mem.read(addr, &mut buf[..len], t);
                self.counters.mem_struct_stall_cycles += access.struct_stall;
                self.counters.mem_bw_stall_cycles += access.bw_stall;
                t = access.issue;
                let value = match instr {
                    Lb { .. } => buf[0] as i8 as i32 as u32,
                    Lbu { .. } => buf[0] as u32,
                    Lh { .. } => i16::from_le_bytes([buf[0], buf[1]]) as i32 as u32,
                    Lhu { .. } => u16::from_le_bytes([buf[0], buf[1]]) as u32,
                    _ => u32::from_le_bytes(buf),
                };
                let ready = (t + self.cfg.load_use_cycles).max(access.ready + 2);
                self.write_reg(rd, value, ready);
                end = ready;
            }
            Sb { rs1, rs2, offset } | Sh { rs1, rs2, offset } | Sw { rs1, rs2, offset } => {
                self.counters.stores += 1;
                let base = self.read_reg_stalling(rs1, &mut t);
                // Superscalar widths model a store buffer: the data
                // operand is consumed at commit, not at issue, so the
                // store does not stall on a still-in-flight value. The
                // width-1 model reads it at issue, as the seed did.
                let val = if width > 1 {
                    self.regs[rs2.num() as usize]
                } else {
                    self.read_reg_stalling(rs2, &mut t)
                };
                let addr = base.wrapping_add(offset as u32);
                let len = match instr {
                    Sb { .. } => 1,
                    Sh { .. } => 2,
                    _ => 4,
                };
                self.check_mem(addr, len)?;
                let bytes = val.to_le_bytes();
                let access = self.mem.write(addr, &bytes[..len], t);
                self.counters.mem_struct_stall_cycles += access.struct_stall;
                self.counters.mem_bw_stall_cycles += access.bw_stall;
                t = access.issue;
                end = access.ready;
                if self.text.overlaps(addr, len) {
                    self.invalidate_text(addr, len);
                }
            }
            Addi { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a.wrapping_add(imm as u32), t + 1);
            }
            Slti { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, ((a as i32) < imm) as u32, t + 1);
            }
            Sltiu { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, (a < imm as u32) as u32, t + 1);
            }
            Xori { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a ^ imm as u32, t + 1);
            }
            Ori { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a | imm as u32, t + 1);
            }
            Andi { rd, rs1, imm } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a & imm as u32, t + 1);
            }
            Slli { rd, rs1, shamt } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a << shamt, t + 1);
            }
            Srli { rd, rs1, shamt } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, a >> shamt, t + 1);
            }
            Srai { rd, rs1, shamt } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                self.write_reg(rd, ((a as i32) >> shamt) as u32, t + 1);
            }
            Add { rd, rs1, rs2 }
            | Sub { rd, rs1, rs2 }
            | Sll { rd, rs1, rs2 }
            | Slt { rd, rs1, rs2 }
            | Sltu { rd, rs1, rs2 }
            | Xor { rd, rs1, rs2 }
            | Srl { rd, rs1, rs2 }
            | Sra { rd, rs1, rs2 }
            | Or { rd, rs1, rs2 }
            | And { rd, rs1, rs2 } => {
                self.counters.alu += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                let b = self.read_reg_stalling(rs2, &mut t);
                let v = match instr {
                    Add { .. } => a.wrapping_add(b),
                    Sub { .. } => a.wrapping_sub(b),
                    Sll { .. } => a << (b & 31),
                    Slt { .. } => ((a as i32) < (b as i32)) as u32,
                    Sltu { .. } => (a < b) as u32,
                    Xor { .. } => a ^ b,
                    Srl { .. } => a >> (b & 31),
                    Sra { .. } => ((a as i32) >> (b & 31)) as u32,
                    Or { .. } => a | b,
                    And { .. } => a & b,
                    _ => unreachable!(),
                };
                self.write_reg(rd, v, t + 1);
            }
            Mul { rd, rs1, rs2 }
            | Mulh { rd, rs1, rs2 }
            | Mulhsu { rd, rs1, rs2 }
            | Mulhu { rd, rs1, rs2 } => {
                self.counters.mul += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                let b = self.read_reg_stalling(rs2, &mut t);
                let v = match instr {
                    Mul { .. } => a.wrapping_mul(b),
                    Mulh { .. } => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    Mulhsu { .. } => (((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32,
                    Mulhu { .. } => (((a as u64) * (b as u64)) >> 32) as u32,
                    _ => unreachable!(),
                };
                t += self.cfg.mul_cycles - 1;
                self.write_reg(rd, v, t + 1);
                end = t + 1;
            }
            Div { rd, rs1, rs2 }
            | Divu { rd, rs1, rs2 }
            | Rem { rd, rs1, rs2 }
            | Remu { rd, rs1, rs2 } => {
                self.counters.div += 1;
                let a = self.read_reg_stalling(rs1, &mut t);
                let b = self.read_reg_stalling(rs2, &mut t);
                let v = match instr {
                    Div { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            a
                        } else {
                            ((a as i32).wrapping_div(b as i32)) as u32
                        }
                    }
                    Divu { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else {
                            a / b
                        }
                    }
                    Rem { .. } => {
                        if b == 0 {
                            a
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            0
                        } else {
                            ((a as i32).wrapping_rem(b as i32)) as u32
                        }
                    }
                    Remu { .. } => {
                        if b == 0 {
                            a
                        } else {
                            a % b
                        }
                    }
                    _ => unreachable!(),
                };
                // Iterative divider blocks the (single-stage) pipeline.
                t += self.cfg.div_cycles - 1;
                self.write_reg(rd, v, t + 1);
                end = t + 1;
            }
            Fence => {
                self.counters.alu += 1;
                // Single in-order core: fence is a timing no-op.
            }
            Ecall => {
                self.halted = true;
            }
            Ebreak => {
                return Err(SimError::Break(pc));
            }
            Csrrs { rd, csr: c, rs1: _ } => {
                self.counters.alu += 1;
                let v = match c {
                    csr::CYCLE | csr::TIME => self.cycle as u32,
                    csr::CYCLEH | csr::TIMEH => (self.cycle >> 32) as u32,
                    csr::INSTRET => self.instret as u32,
                    csr::INSTRETH => (self.instret >> 32) as u32,
                    _ => 0,
                };
                self.write_reg(rd, v, t + 1);
            }
            CustomI { slot, funct3, ops } => {
                end = self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    None,
                    0,
                    ops.vrs1,
                    ops.vrs2,
                    ops.rd,
                    ops.vrd1,
                    ops.vrd2,
                    &mut t,
                )?;
            }
            CustomS { slot, funct3, ops } => {
                end = self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    Some(ops.rs2),
                    ops.imm,
                    ops.vrs1,
                    crate::isa::reg::V0,
                    ops.rd,
                    ops.vrd1,
                    crate::isa::reg::V0,
                    &mut t,
                )?;
            }
        }

        if self.trace.enabled {
            self.trace.record(self.instret, TraceEvent { start: t, end: end.max(t + 1), pc, instr });
        }

        self.pc = next_pc;
        self.instret += 1;
        if width <= 1 {
            // The original single-issue timestamp model, untouched.
            self.cycle = t + self.cfg.base_cpi;
        } else if serial {
            // Issued alone; the divider occupied the pipeline through
            // `t`, and nothing shares its issue cycle.
            self.counters.issue_slots_wasted += width - 1;
            self.cycle = t + self.cfg.base_cpi;
            self.issue_used = 0;
        } else {
            if t == group_cycle {
                // No stall: the instruction joined the open group.
                self.issue_used += 1;
                if self.issue_used > 1 {
                    self.counters.dual_issue_pairs += 1;
                }
            } else {
                // Stalled past the open group (scoreboard, data port or
                // unit slot): close it and open a new group at the
                // actual issue cycle.
                if self.issue_used > 0 {
                    self.counters.issue_slots_wasted += width - self.issue_used;
                }
                self.cycle = t;
                self.issue_used = 1;
            }
            if redirect || self.halted {
                // A taken branch/jump ends its issue group (the
                // redirected fetch arrives next cycle); the halting
                // ecall closes and charges the final group so run()
                // reports consumed cycles in the width-1 convention.
                self.counters.issue_slots_wasted += width - self.issue_used;
                self.cycle = t + self.cfg.base_cpi;
                self.issue_used = 0;
            }
        }
        Ok(())
    }

    /// Issue a custom instruction: read operands (stalling), run the unit,
    /// route any memory request through DL1, and schedule writebacks.
    /// Returns the completion cycle (for the trace).
    #[allow(clippy::too_many_arguments)]
    fn exec_custom(
        &mut self,
        pc: u32,
        slot: usize,
        funct3: u8,
        rs1: crate::isa::Reg,
        rs2: Option<crate::isa::Reg>,
        imm: u8,
        vrs1: crate::isa::VReg,
        vrs2: crate::isa::VReg,
        rd: crate::isa::Reg,
        vrd1: crate::isa::VReg,
        vrd2: crate::isa::VReg,
        t: &mut u64,
    ) -> Result<u64, SimError> {
        self.counters.custom[slot] += 1;
        let rs1_v = self.read_reg_stalling(rs1, t);
        let rs2_v = rs2.map(|r| self.read_reg_stalling(r, t)).unwrap_or(0);
        let vrs1_v = self.read_vreg_stalling(vrs1, t);
        let vrs2_v = self.read_vreg_stalling(vrs2, t);
        // WAW: results write in order; wait until prior writers are
        // done. Booked as waw_stall_cycles — the seed misbooked these
        // waits as RAW-hazard stalls.
        for reg in [vrd1, vrd2] {
            let n = reg.num() as usize;
            if n != 0 && self.vreg_ready[n] > *t {
                self.counters.waw_stall_cycles += self.vreg_ready[n] - *t;
                *t = self.vreg_ready[n];
            }
        }
        // Structural rule at issue_width > 1: a unit is fully pipelined
        // but accepts one instruction per cycle, so a second custom op
        // on the same slot waits a cycle. (At width 1 consecutive issue
        // times are strictly increasing, so this never fires.)
        if self.cfg.issue_width > 1 {
            if self.unit_issue_cycle[slot] == *t {
                *t += 1;
            }
            self.unit_issue_cycle[slot] = *t;
        }

        let inputs = UnitInputs { funct3, rs1: rs1_v, rs2: rs2_v, imm, vrs1: vrs1_v, vrs2: vrs2_v };
        let out = self
            .pool
            .get_mut(slot)
            .and_then(|u| u.execute(&inputs))
            .map_err(|source| SimError::Unit { pc, source })?;

        let mut end = *t + out.latency;
        match out.mem {
            Some(VecMemOp::Load { addr }) => {
                let len = self.cfg.vlen_bytes();
                self.check_mem(addr, len)?;
                // Stack buffer: the hot vector path must not allocate.
                let mut buf = [0u8; crate::simd::MAX_VLEN_BITS / 8];
                let access = self.mem.read(addr, &mut buf[..len], *t);
                self.counters.mem_struct_stall_cycles += access.struct_stall;
                self.counters.mem_bw_stall_cycles += access.bw_stall;
                *t = access.issue;
                let ready = (*t + out.latency).max(access.ready + 2);
                self.write_vreg(vrd1, VecVal::from_bytes(&buf[..len]), ready);
                end = ready;
            }
            Some(VecMemOp::Store { addr, data }) => {
                let len = self.cfg.vlen_bytes();
                self.check_mem(addr, len)?;
                let mut buf = [0u8; crate::simd::MAX_VLEN_BITS / 8];
                data.write_bytes(&mut buf[..len]);
                let access = self.mem.write(addr, &buf[..len], *t);
                self.counters.mem_struct_stall_cycles += access.struct_stall;
                self.counters.mem_bw_stall_cycles += access.bw_stall;
                *t = access.issue;
                end = access.ready;
                if self.text.overlaps(addr, len) {
                    self.invalidate_text(addr, len);
                }
            }
            None => {
                let ready = *t + out.latency;
                if let Some(v) = out.vrd1 {
                    self.write_vreg(vrd1, v, ready);
                }
                if let Some(v) = out.vrd2 {
                    self.write_vreg(vrd2, v, ready);
                }
                if let Some(v) = out.rd {
                    self.write_reg(rd, v, ready);
                }
            }
        }
        Ok(end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    fn run_asm(build: impl FnOnce(&mut Asm)) -> Core {
        let mut a = Asm::new();
        build(&mut a);
        let p = a.assemble().unwrap();
        let mut core = Core::paper_default();
        core.load(&p).unwrap();
        core.run(1_000_000).unwrap();
        core
    }

    #[test]
    fn arithmetic_and_halt() {
        let c = run_asm(|a| {
            a.li(A0, 20);
            a.li(A1, 22);
            a.add(A2, A0, A1);
            a.halt();
        });
        assert_eq!(c.reg(A2), 42);
        assert!(c.halted());
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let c = run_asm(|a| {
            a.li(ZERO, 99);
            a.addi(ZERO, ZERO, 5);
            a.mv(A0, ZERO);
            a.halt();
        });
        assert_eq!(c.reg(A0), 0);
    }

    #[test]
    fn back_to_back_dependent_alu_has_no_stall() {
        // 100 dependent addis: 1 cycle each (§3.2).
        let c = run_asm(|a| {
            for _ in 0..100 {
                a.addi(A0, A0, 1);
            }
            a.halt();
        });
        assert_eq!(c.reg(A0), 100);
        assert_eq!(c.counters().raw_stall_cycles, 0);
    }

    #[test]
    fn load_use_stall_is_two_cycles() {
        // lw then immediately use: dependent instruction executes 3 cycles
        // after the load (2 stall cycles).
        let mut a = Asm::new();
        let buf = a.words("buf", &[7]);
        a.la(A1, buf);
        a.lw(A0, 0, A1);
        a.addi(A0, A0, 1); // dependent
        a.halt();
        let p = a.assemble().unwrap();
        let mut warm = Core::paper_default();
        warm.load(&p).unwrap();
        warm.run(100).unwrap();
        assert_eq!(warm.reg(A0), 8);
        // Warm run to measure the hit-latency path: run again after caches
        // are warm.
        let cold_stalls = warm.counters().raw_stall_cycles;
        assert!(cold_stalls >= 2, "load-use stall expected, got {cold_stalls}");
    }

    #[test]
    fn loop_and_branch() {
        let c = run_asm(|a| {
            let l = a.new_label("loop");
            a.li(A0, 10);
            a.li(A1, 0);
            a.bind(l);
            a.add(A1, A1, A0);
            a.addi(A0, A0, -1);
            a.bnez(A0, l);
            a.halt();
        });
        assert_eq!(c.reg(A1), 55);
    }

    #[test]
    fn memory_roundtrip_and_sign_extension() {
        let mut a = Asm::new();
        let buf = a.buffer("buf", 64, 8);
        a.la(A1, buf);
        a.li(A0, -2);
        a.sb(A0, 0, A1);
        a.lb(A2, 0, A1);
        a.lbu(A3, 0, A1);
        a.li(A0, -3);
        a.sh(A0, 8, A1);
        a.lh(A4, 8, A1);
        a.lhu(A5, 8, A1);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        assert_eq!(c.reg(A2) as i32, -2);
        assert_eq!(c.reg(A3), 0xFE);
        assert_eq!(c.reg(A4) as i32, -3);
        assert_eq!(c.reg(A5), 0xFFFD);
    }

    #[test]
    fn mul_div_semantics() {
        let c = run_asm(|a| {
            a.li(A0, -6);
            a.li(A1, 4);
            a.mul(A2, A0, A1); // -24
            a.div(A3, A0, A1); // -1 (trunc)
            a.rem(A4, A0, A1); // -2
            a.li(T0, 0);
            a.div(A5, A0, T0); // div by zero => -1
            a.remu(A6, A0, T0); // rem by zero => a
            a.halt();
        });
        assert_eq!(c.reg(A2) as i32, -24);
        assert_eq!(c.reg(A3) as i32, -1);
        assert_eq!(c.reg(A4) as i32, -2);
        assert_eq!(c.reg(A5), u32::MAX);
        assert_eq!(c.reg(A6) as i32, -6);
    }

    #[test]
    fn div_blocks_pipeline() {
        let base = run_asm(|a| {
            a.li(A0, 100);
            a.li(A1, 7);
            a.halt();
        })
        .cycle();
        let with_div = run_asm(|a| {
            a.li(A0, 100);
            a.li(A1, 7);
            a.divu(A2, A0, A1);
            a.halt();
        })
        .cycle();
        assert!(
            with_div >= base + 32,
            "divider must block ~32 cycles (got {} vs {})",
            with_div,
            base
        );
    }

    #[test]
    fn function_call_and_return() {
        let c = run_asm(|a| {
            let f = a.new_label("double");
            a.li(A0, 21);
            a.call(f);
            a.halt();
            a.bind(f);
            a.add(A0, A0, A0);
            a.ret();
        });
        assert_eq!(c.reg(A0), 42);
    }

    #[test]
    fn rdcycle_and_rdinstret_increase() {
        let c = run_asm(|a| {
            a.rdcycle(S0);
            for _ in 0..10 {
                a.nop();
            }
            a.rdcycle(S1);
            a.rdinstret(S2);
            a.halt();
        });
        let d = c.reg(S1).wrapping_sub(c.reg(S0));
        assert!((10..=20).contains(&d), "10 nops ≈ 10-20 cycles, got {d}");
        assert!(c.reg(S2) >= 12);
    }

    #[test]
    fn vector_load_sort_store() {
        let mut a = Asm::new();
        let data = a.words("data", &[5, 3, 8, 1, 9, 2, 7, 4].map(|x: i32| x as u32));
        a.dalign(32);
        let out = a.buffer("out", 32, 32);
        a.la(A0, data);
        a.la(A1, out);
        a.lv(V1, A0, ZERO);
        a.sort8(V2, V1);
        a.sv(V2, A1, ZERO);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        c.mem.flush_all();
        let bytes = c.mem.dram_slice(p.sym("out"), 32);
        let got: Vec<i32> = bytes
            .chunks(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5, 7, 8, 9]);
    }

    #[test]
    fn v0_is_hardwired_zero() {
        let mut a = Asm::new();
        let data = a.words("data", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.dalign(32);
        let out = a.buffer("out", 32, 32);
        a.la(A0, data);
        a.la(A1, out);
        a.lv(V0, A0, ZERO); // write to v0 discarded
        a.sv(V0, A1, ZERO); // stores zeros
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        c.mem.flush_all();
        assert_eq!(c.mem.dram_slice(p.sym("out"), 32), &[0u8; 32]);
    }

    #[test]
    fn waw_waits_are_not_booked_as_raw_stalls() {
        // Two sorts writing the same destination vreg: the second waits
        // for the first's writeback (WAW), which must land in
        // waw_stall_cycles, not inflate the RAW-hazard counter (the
        // seed lumped them together).
        let mut a = Asm::new();
        let d = a.words("d", &[8, 7, 6, 5, 4, 3, 2, 1]);
        a.la(A0, d);
        a.lv(V1, A0, ZERO);
        a.sort8(V2, V1);
        a.sort8(V2, V1);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        assert_eq!(c.vreg(V2).to_i32s(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(
            c.counters().waw_stall_cycles > 0,
            "second sort must wait on the first's V2 writeback: {:?}",
            c.counters()
        );
    }

    #[test]
    fn custom_sort_is_pipelined() {
        // Two independent sorts issue back-to-back; their latencies
        // overlap (Fig. 6's pipelining effect). Total runtime must be well
        // under 2 × 6 cycles of serial sort latency.
        let mut a = Asm::new();
        let d1 = a.words("d1", &[8, 7, 6, 5, 4, 3, 2, 1]);
        let d2 = a.words("d2", &[16, 15, 14, 13, 12, 11, 10, 9]);
        a.la(A0, d1);
        a.la(A1, d2);
        a.lv(V1, A0, ZERO);
        a.lv(V2, A1, ZERO);
        a.rdcycle(S0);
        a.sort8(V3, V1);
        a.sort8(V4, V2);
        a.rdcycle(S1);
        a.sv(V3, A0, ZERO);
        a.sv(V4, A1, ZERO);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        // The two sorts overlap (Fig. 6: the second sort issues ~2 cycles
        // after the first, waiting on its own load — far less than the
        // 6-cycle sort latency, so the pipelines overlap).
        let issue_span = c.reg(S1).wrapping_sub(c.reg(S0));
        assert!(
            issue_span < 6,
            "sorts must overlap (span {issue_span} < sort latency 6); serial would be ≥ 12"
        );
        // But consuming v4 (the sv) waits for the sort latency.
        c.mem.flush_all();
        let b = c.mem.dram_slice(p.sym("d2"), 32);
        let got: Vec<i32> =
            b.chunks(4).map(|x| i32::from_le_bytes(x.try_into().unwrap())).collect();
        assert_eq!(got, vec![9, 10, 11, 12, 13, 14, 15, 16]);
    }

    #[test]
    fn try_new_rejects_mismatched_configs_without_panicking() {
        // DL1 block (256) != vlen (512): an Err, not a panic.
        let err = Core::try_new(CoreConfig::for_vlen(512), MemConfig::paper_default()).unwrap_err();
        assert!(matches!(
            err,
            MemConfigError::BlockVlenMismatch { block_bits: 256, vlen_bits: 512 }
        ));
        // Invalid memory internals propagate too.
        let mut mem = MemConfig::paper_default();
        mem.llc_mshrs = 0;
        let err = Core::try_new(CoreConfig::paper_default(), mem).unwrap_err();
        assert!(matches!(err, MemConfigError::ZeroMshrs { .. }));
    }

    #[test]
    fn blocking_port_stall_is_bandwidth_classified() {
        // Two back-to-back loads from different LLC blocks on the
        // default (blocking) machine: the second waits on the port until
        // the first miss's data returned — bandwidth exposure, not a
        // structural hazard.
        let mut a = Asm::new();
        a.li(A1, 0x20000);
        a.li(A2, 0x40000);
        a.lw(A0, 0, A1);
        a.lw(A3, 0, A2);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        let ctr = c.counters();
        assert!(ctr.mem_bw_stall_cycles > 0, "second load waited on the blocking port");
        assert_eq!(
            ctr.mem_stall_cycles(),
            ctr.mem_struct_stall_cycles + ctr.mem_bw_stall_cycles
        );
    }

    #[test]
    fn nonblocking_core_overlaps_independent_misses() {
        // The same two-load program on a blocking vs a non-blocking
        // (4 MSHRs, 2 channels) machine: overlapping the misses must
        // save cycles end to end.
        let mut a = Asm::new();
        a.li(A1, 0x20000);
        a.li(A2, 0x40000);
        a.lw(A0, 0, A1);
        a.lw(A3, 0, A2);
        a.lw(A4, 4, A1);
        a.lw(A5, 4, A2);
        a.halt();
        let p = a.assemble().unwrap();

        let mut blocking = Core::paper_default();
        blocking.load(&p).unwrap();
        let slow = blocking.run(100).unwrap().cycles;

        let mut mem = MemConfig::paper_default();
        mem.dl1_mshrs = 4;
        mem.llc_mshrs = 4;
        mem.dram.channels = 2;
        let mut nb = Core::new(CoreConfig::paper_default(), mem);
        nb.load(&p).unwrap();
        let fast = nb.run(100).unwrap().cycles;
        assert!(fast < slow, "overlapped misses must be faster ({fast} vs {slow})");
        assert_eq!(nb.counters().mem_bw_stall_cycles, 0, "non-blocking port never holds data");
    }

    #[test]
    fn watchdog_fires_on_infinite_loop() {
        let mut a = Asm::new();
        let l = a.here("forever");
        a.j(l);
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        assert!(matches!(c.run(1000), Err(SimError::Watchdog(1000))));
    }

    #[test]
    fn ebreak_reports() {
        let mut a = Asm::new();
        a.ebreak();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        assert!(matches!(c.run(10), Err(SimError::Break(_))));
    }

    #[test]
    fn mem_fault_detected() {
        let mut a = Asm::new();
        a.li(A0, 0x7fff_f000u32 as i64);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        assert!(matches!(c.run(10), Err(SimError::MemFault { .. })));
    }

    #[test]
    fn wild_jalr_outside_dram_is_a_fetch_fault() {
        // Used to index past the decode cache / read DRAM-relative; a
        // wild jump must be a reported fault, not a panic.
        let mut a = Asm::new();
        a.li(A0, 0xF000_0000u32 as i64);
        a.jalr(RA, A0, 0);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        match c.run(10) {
            Err(SimError::FetchFault { pc, .. }) => assert_eq!(pc, 0xF000_0000),
            other => panic!("expected FetchFault, got {other:?}"),
        }
    }

    #[test]
    fn misaligned_jalr_target_is_a_misaligned_fetch_fault() {
        // pc + 2 crosses into the middle of an instruction; the seed
        // model truncated the decode-cache index (or tripped the L1's
        // block-crossing assertion at a block edge) instead of
        // faulting.
        let mut a = Asm::new();
        a.auipc(A0, 0);
        a.jalr(RA, A0, 6); // target = auipc pc + 6 -> pc % 4 == 2
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        match c.run(10) {
            Err(SimError::FetchMisaligned { pc }) => assert_eq!(pc % 4, 2),
            other => panic!("expected FetchMisaligned, got {other:?}"),
        }
    }

    #[test]
    fn misaligned_branch_target_is_a_misaligned_fetch_fault() {
        // A branch offset of 4k+2 encodes fine (offsets are multiples
        // of 2) but lands between instructions; taking it must fault.
        use crate::isa::{encode, Instr};
        let mut a = Asm::new();
        a.nop();
        a.halt();
        let mut p = a.assemble().unwrap();
        // Overwrite the nop with `beq zero, zero, +6` (raw encoding; the
        // assembler's label API only produces aligned targets).
        p.text[0] = encode(&Instr::Beq { rs1: ZERO, rs2: ZERO, offset: 6 }).unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        assert!(matches!(c.run(10), Err(SimError::FetchMisaligned { .. })));
    }

    #[test]
    fn dual_issue_pairs_independent_alu_ops() {
        // 400 pairs of independent addis: width 2 retires two per
        // cycle on the hit path (cold-fill and IL1-boundary stalls are
        // identical for both widths, so the bound is kept loose).
        let mut a = Asm::new();
        for _ in 0..400 {
            a.addi(A0, A0, 1);
            a.addi(A1, A1, 1);
        }
        a.halt();
        let p = a.assemble().unwrap();
        let run_width = |width: usize| {
            let mut cfg = CoreConfig::paper_default();
            cfg.issue_width = width;
            let mut c = Core::new(cfg, MemConfig::paper_default());
            c.load(&p).unwrap();
            c.run(10_000).unwrap();
            c
        };
        let single = run_width(1);
        let dual = run_width(2);
        assert_eq!(single.reg(A0), 400);
        assert_eq!(dual.reg(A0), 400);
        assert_eq!(dual.reg(A1), single.reg(A1), "architectural state is width-independent");
        assert!(
            dual.cycle() * 4 < single.cycle() * 3,
            "independent ALU pairs must dual-issue ({} vs {})",
            dual.cycle(),
            single.cycle()
        );
        assert!(dual.counters().dual_issue_pairs >= 350, "{:?}", dual.counters());
        assert_eq!(single.counters().dual_issue_pairs, 0);
        assert_eq!(single.counters().issue_slots_wasted, 0);
    }

    #[test]
    fn dual_issue_serialises_dependent_chains() {
        // 100 dependent addis cannot pair: width 2 keeps CPI >= 1 on
        // the chain and wastes a slot per single-instruction group.
        let mut a = Asm::new();
        for _ in 0..100 {
            a.addi(A0, A0, 1);
        }
        a.halt();
        let p = a.assemble().unwrap();
        let mut cfg = CoreConfig::paper_default();
        cfg.issue_width = 2;
        let mut dual = Core::new(cfg, MemConfig::paper_default());
        dual.load(&p).unwrap();
        dual.run(10_000).unwrap();
        let mut single = Core::paper_default();
        single.load(&p).unwrap();
        single.run(10_000).unwrap();
        assert_eq!(dual.reg(A0), 100);
        assert_eq!(dual.counters().dual_issue_pairs, 0, "a RAW chain never pairs");
        assert!(dual.cycle() >= 100, "the chain keeps CPI >= 1 at any width");
        assert!(dual.cycle() <= single.cycle(), "width 2 must not be slower");
        assert!(dual.counters().issue_slots_wasted >= 100);
    }

    #[test]
    fn dual_issue_div_issues_alone_and_taken_branch_ends_group() {
        let mut a = Asm::new();
        a.li(A0, 100);
        a.li(A1, 7);
        a.divu(A2, A0, A1);
        a.halt();
        let p = a.assemble().unwrap();
        let mut cfg = CoreConfig::paper_default();
        cfg.issue_width = 4;
        let mut c = Core::new(cfg, MemConfig::paper_default());
        c.load(&p).unwrap();
        c.run(100).unwrap();
        assert_eq!(c.reg(A2), 14);
        // The div issued alone: its cycle wasted width-1 = 3 slots.
        assert!(c.counters().issue_slots_wasted >= 3, "{:?}", c.counters());

        // A taken-branch loop at width 2 still makes forward progress
        // and matches the architectural result of width 1.
        let mut a = Asm::new();
        let l = a.new_label("loop");
        a.li(A0, 10);
        a.li(A1, 0);
        a.bind(l);
        a.add(A1, A1, A0);
        a.addi(A0, A0, -1);
        a.bnez(A0, l);
        a.halt();
        let p = a.assemble().unwrap();
        let mut cfg = CoreConfig::paper_default();
        cfg.issue_width = 2;
        let mut c = Core::new(cfg, MemConfig::paper_default());
        c.load(&p).unwrap();
        c.run(1000).unwrap();
        assert_eq!(c.reg(A1), 55);
    }

    #[test]
    fn prefix_instruction_state_carries() {
        let mut a = Asm::new();
        let d = a.words("d", &[1u32; 8]);
        a.la(A0, d);
        a.lv(V1, A0, ZERO);
        a.prefix_reset();
        a.prefix(V2, V1);
        a.prefix(V3, V1);
        a.prefix_carry(A5);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        c.run(100).unwrap();
        assert_eq!(c.vreg(V2).to_i32s(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(c.vreg(V3).to_i32s(), vec![9, 10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(c.reg(A5), 16);
    }

    #[test]
    fn run_result_reports_ipc() {
        let mut a = Asm::new();
        for _ in 0..50 {
            a.nop();
        }
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = Core::paper_default();
        c.load(&p).unwrap();
        let r = c.run(100).unwrap();
        assert_eq!(r.instret, 51);
        assert!(r.ipc() > 0.5, "mostly 1 IPC, got {}", r.ipc());
    }
}
