//! The softcore microarchitecture (§3 of the paper): a single-pipeline-
//! stage RV32IM core with 8 VLEN-bit vector registers, per-register
//! scoreboarding for the load pipe and the pipelined custom SIMD units,
//! and the §3.1 cache hierarchy behind it.

pub mod config;
#[allow(clippy::module_inception)]
pub mod core;
pub mod trace;

pub use config::CoreConfig;
pub use core::{Core, CoreCounters, RunResult, SimError};
pub use trace::{Trace, TraceEvent};
