//! Instruction-level trace events — the data behind Fig. 6 of the paper
//! (instruction start/end times in the sorting-in-chunks loop).

use crate::isa::Instr;
use std::fmt::Write as _;

/// One retired instruction with its issue/complete cycle times.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Cycle at which the instruction issued (after all stalls).
    pub start: u64,
    /// Cycle at which its results became architecturally visible (for
    /// pipelined custom instructions this is start + cN_cycles; for plain
    /// ALU ops start + 1).
    pub end: u64,
    pub pc: u32,
    pub instr: Instr,
}

/// Trace collector with an instruction-index window so long runs can
/// capture just the loop of interest (as the paper's Fig. 6 does).
#[derive(Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Record only instructions with retire index in `[from, to)`.
    pub window: Option<(u64, u64)>,
    pub enabled: bool,
}

impl Trace {
    pub fn disabled() -> Self {
        Self::default()
    }

    pub fn windowed(from: u64, to: u64) -> Self {
        Self { events: Vec::new(), window: Some((from, to)), enabled: true }
    }

    pub fn full() -> Self {
        Self { events: Vec::new(), window: None, enabled: true }
    }

    #[inline]
    pub fn record(&mut self, instr_index: u64, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if let Some((from, to)) = self.window {
            if instr_index < from || instr_index >= to {
                return;
            }
        }
        self.events.push(ev);
    }

    /// Stable text serialisation for golden-trace regression tests: one
    /// line per retired instruction, `pc: disassembly`. Deliberately
    /// **architectural only** — no cycle numbers — so golden files pin
    /// down instruction flow (what executed, in which order) while
    /// timing-model refactors (MSHRs, prefetching, channel counts) stay
    /// free to move cycles around.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            let _ = writeln!(out, "{:#010x}: {}", e.pc, e.instr);
        }
        out
    }

    /// Render an ASCII pipeline diagram in the style of Fig. 6: one row
    /// per instruction, `#` spans from issue to completion.
    pub fn render_pipeline(&self) -> String {
        if self.events.is_empty() {
            return "(empty trace)\n".to_string();
        }
        let t0 = self.events.iter().map(|e| e.start).min().unwrap();
        let t1 = self.events.iter().map(|e| e.end).max().unwrap();
        let span = ((t1 - t0) as usize).min(200);
        let mut out = String::new();
        let _ = writeln!(out, "{:<38} {:>6}  cycles {}..{}", "instruction", "issue", t0, t1);
        for e in &self.events {
            let s = (e.start - t0) as usize;
            let w = ((e.end - e.start) as usize).max(1);
            let mut bar = String::new();
            bar.push_str(&" ".repeat(s.min(span)));
            bar.push_str(&"#".repeat(w.min(span + 1 - s.min(span))));
            let _ = writeln!(out, "{:<38} {:>6}  |{bar}", e.instr.to_string(), e.start);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;

    fn ev(start: u64, end: u64) -> TraceEvent {
        TraceEvent { start, end, pc: 0, instr: Instr::Addi { rd: A0, rs1: A0, imm: 1 } }
    }

    #[test]
    fn window_filters_by_instruction_index() {
        let mut t = Trace::windowed(10, 12);
        t.record(9, ev(0, 1));
        t.record(10, ev(1, 2));
        t.record(11, ev(2, 3));
        t.record(12, ev(3, 4));
        assert_eq!(t.events.len(), 2);
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(0, ev(0, 1));
        assert!(t.events.is_empty());
    }

    #[test]
    fn render_shows_overlap() {
        let mut t = Trace::full();
        t.record(0, ev(0, 6));
        t.record(1, ev(2, 8));
        let s = t.render_pipeline();
        assert!(s.contains("######"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rows");
    }

    #[test]
    fn empty_render() {
        assert!(Trace::full().render_pipeline().contains("empty"));
    }

    #[test]
    fn render_text_is_architectural_only() {
        let mut t = Trace::full();
        t.record(0, TraceEvent { start: 7, end: 13, pc: 0x40, instr: ev(0, 1).instr });
        let s = t.render_text();
        assert_eq!(s, "0x00000040: addi a0, a0, 1\n");
        // Different timing, identical serialisation.
        let mut t2 = Trace::full();
        t2.record(0, TraceEvent { start: 99, end: 250, pc: 0x40, instr: ev(0, 1).instr });
        assert_eq!(t2.render_text(), s);
    }
}
