//! Text assembler: a `.s`-like surface syntax over [`super::Asm`].
//!
//! Supports the RV32IM mnemonics, the pseudo-instructions GNU `as`
//! accepts for them, the paper's custom-SIMD mnemonics (both the named
//! forms like `c2.sort` and the generic `cN.iK`/`cN.sK` forms), labels,
//! and a directive subset: `.text .data .word .half .byte .space .align
//! .equ .global .entry`.
//!
//! ```
//! use simdsoftcore::asm::assemble_text;
//! let prog = assemble_text(r#"
//!     .text
//!     main:
//!         li   a0, 5
//!     loop:
//!         addi a0, a0, -1
//!         bnez a0, loop
//!         ecall
//! "#).unwrap();
//! assert_eq!(prog.entry, prog.sym("main"));
//! ```

use super::builder::{Asm, AsmError, Label};
use crate::isa::instr::{CustomSlot, IPrime, Instr, SPrime};
use crate::isa::reg::{Reg, VReg, ZERO};
use std::collections::HashMap;

#[derive(Debug)]
pub enum ParseError {
    Syntax { line: usize, msg: String },
    Asm(AsmError),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Syntax { line, msg } => write!(f, "line {line}: {msg}"),
            ParseError::Asm(e) => std::fmt::Display::fmt(e, f),
        }
    }
}

impl std::error::Error for ParseError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ParseError::Asm(e) => Some(e),
            ParseError::Syntax { .. } => None,
        }
    }
}

impl From<AsmError> for ParseError {
    fn from(e: AsmError) -> Self {
        ParseError::Asm(e)
    }
}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError::Syntax { line, msg: msg.into() }
}

/// Assemble a source string with default segment bases.
pub fn assemble_text(src: &str) -> Result<crate::asm::Program, ParseError> {
    assemble_text_with(src, Asm::new())
}

/// Assemble a source string into a caller-configured builder (custom
/// segment bases etc.).
pub fn assemble_text_with(src: &str, mut a: Asm) -> Result<crate::asm::Program, ParseError> {
    let mut parser = Parser { equs: HashMap::new(), in_data: false, entry_name: None };

    for (lineno, raw) in src.lines().enumerate() {
        let line = lineno + 1;
        let code = strip_comment(raw).trim();
        if code.is_empty() {
            continue;
        }
        // A line may carry `label:` prefixes before a statement.
        let mut rest = code;
        while let Some(colon) = find_label_colon(rest) {
            let (name, tail) = rest.split_at(colon);
            let name = name.trim();
            if !is_ident(name) {
                return Err(err(line, format!("bad label name '{name}'")));
            }
            let l = a.named_label(name);
            if parser.in_data {
                a.bind_data(l);
            } else {
                a.bind(l);
            }
            rest = tail[1..].trim();
        }
        if rest.is_empty() {
            continue;
        }
        parser.statement(&mut a, line, rest)?;
    }

    if let Some(name) = parser.entry_name {
        let l = a.named_label(&name);
        a.entry(l);
    }
    Ok(a.assemble()?)
}

struct Parser {
    equs: HashMap<String, i64>,
    in_data: bool,
    entry_name: Option<String>,
}

impl Parser {
    fn statement(&mut self, a: &mut Asm, line: usize, stmt: &str) -> Result<(), ParseError> {
        let (mnemonic, rest) = match stmt.find(char::is_whitespace) {
            Some(i) => (&stmt[..i], stmt[i..].trim()),
            None => (stmt, ""),
        };
        let ops: Vec<&str> = if rest.is_empty() {
            vec![]
        } else {
            rest.split(',').map(str::trim).collect()
        };

        if let Some(directive) = mnemonic.strip_prefix('.') {
            return self.directive(a, line, directive, &ops);
        }
        if self.in_data {
            return Err(err(line, format!("instruction '{mnemonic}' in .data section")));
        }
        self.instruction(a, line, mnemonic, &ops)
    }

    fn directive(
        &mut self,
        a: &mut Asm,
        line: usize,
        d: &str,
        ops: &[&str],
    ) -> Result<(), ParseError> {
        match d {
            "text" => self.in_data = false,
            "data" => self.in_data = true,
            "global" | "globl" | "section" | "p2align" => {} // accepted, ignored
            "entry" => {
                let name = ops.first().ok_or_else(|| err(line, ".entry needs a symbol"))?;
                self.entry_name = Some(name.to_string());
            }
            "equ" | "set" => {
                if ops.len() != 2 {
                    return Err(err(line, ".equ needs 'name, value'"));
                }
                let v = self.imm(line, ops[1])?;
                self.equs.insert(ops[0].to_string(), v);
            }
            "word" => {
                for op in ops {
                    if let Ok(v) = self.imm(line, op) {
                        if self.in_data {
                            a.dw(&[v as u32]);
                        } else {
                            a.word(v as u32);
                        }
                    } else if is_ident(op) {
                        let l = a.named_label(op);
                        if self.in_data {
                            // Data-side label words are not supported (they
                            // would need data fixups); text-side are.
                            return Err(err(line, ".word <label> only allowed in .text"));
                        }
                        a.word_label(l);
                    } else {
                        return Err(err(line, format!("bad .word operand '{op}'")));
                    }
                }
            }
            "half" => {
                for op in ops {
                    let v = self.imm(line, op)?;
                    if self.in_data {
                        a.db(&(v as u16).to_le_bytes());
                    } else {
                        return Err(err(line, ".half only allowed in .data"));
                    }
                }
            }
            "byte" => {
                for op in ops {
                    let v = self.imm(line, op)?;
                    if self.in_data {
                        a.db(&[(v as u8)]);
                    } else {
                        return Err(err(line, ".byte only allowed in .data"));
                    }
                }
            }
            "space" | "zero" => {
                let n = self.imm(line, ops.first().ok_or_else(|| err(line, ".space needs size"))?)?;
                if self.in_data {
                    a.dspace(n as usize);
                } else {
                    return Err(err(line, ".space only allowed in .data"));
                }
            }
            "align" => {
                let n = self.imm(line, ops.first().ok_or_else(|| err(line, ".align needs n"))?)?;
                if self.in_data {
                    a.dalign(1usize << n);
                } // .text is always word-aligned; ignore
            }
            other => return Err(err(line, format!("unknown directive .{other}"))),
        }
        Ok(())
    }

    fn reg(&self, line: usize, s: &str) -> Result<Reg, ParseError> {
        Reg::parse(s).ok_or_else(|| err(line, format!("bad register '{s}'")))
    }

    fn vreg(&self, line: usize, s: &str) -> Result<VReg, ParseError> {
        VReg::parse(s).ok_or_else(|| err(line, format!("bad vector register '{s}'")))
    }

    fn imm(&self, line: usize, s: &str) -> Result<i64, ParseError> {
        parse_int(s)
            .or_else(|| self.equs.get(s).copied())
            .ok_or_else(|| err(line, format!("bad immediate '{s}'")))
    }

    /// `offset(base)` memory operand.
    fn mem(&self, line: usize, s: &str) -> Result<(i32, Reg), ParseError> {
        let open = s.find('(').ok_or_else(|| err(line, format!("bad memory operand '{s}'")))?;
        if !s.ends_with(')') {
            return Err(err(line, format!("bad memory operand '{s}'")));
        }
        let off_str = s[..open].trim();
        let off = if off_str.is_empty() { 0 } else { self.imm(line, off_str)? };
        let base = self.reg(line, s[open + 1..s.len() - 1].trim())?;
        Ok((off as i32, base))
    }

    fn label(&self, a: &mut Asm, s: &str, line: usize) -> Result<Label, ParseError> {
        if !is_ident(s) {
            return Err(err(line, format!("bad label operand '{s}'")));
        }
        Ok(a.named_label(s))
    }

    #[allow(clippy::too_many_lines)]
    fn instruction(
        &mut self,
        a: &mut Asm,
        line: usize,
        m: &str,
        ops: &[&str],
    ) -> Result<(), ParseError> {
        macro_rules! need {
            ($n:expr) => {
                if ops.len() != $n {
                    return Err(err(line, format!("'{m}' expects {} operands, got {}", $n, ops.len())));
                }
            };
        }
        macro_rules! r3 {
            ($f:ident) => {{
                need!(3);
                let (rd, rs1, rs2) =
                    (self.reg(line, ops[0])?, self.reg(line, ops[1])?, self.reg(line, ops[2])?);
                a.$f(rd, rs1, rs2);
            }};
        }
        macro_rules! i3 {
            ($f:ident) => {{
                need!(3);
                let (rd, rs1) = (self.reg(line, ops[0])?, self.reg(line, ops[1])?);
                let imm = self.imm(line, ops[2])? as i32;
                a.$f(rd, rs1, imm);
            }};
        }
        macro_rules! sh3 {
            ($f:ident) => {{
                need!(3);
                let (rd, rs1) = (self.reg(line, ops[0])?, self.reg(line, ops[1])?);
                let sh = self.imm(line, ops[2])? as u8;
                a.$f(rd, rs1, sh);
            }};
        }
        macro_rules! ld {
            ($f:ident) => {{
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let (off, base) = self.mem(line, ops[1])?;
                a.$f(rd, off, base);
            }};
        }
        macro_rules! st {
            ($f:ident) => {{
                need!(2);
                let rs2 = self.reg(line, ops[0])?;
                let (off, base) = self.mem(line, ops[1])?;
                a.$f(rs2, off, base);
            }};
        }
        macro_rules! br2 {
            ($f:ident) => {{
                need!(3);
                let (rs1, rs2) = (self.reg(line, ops[0])?, self.reg(line, ops[1])?);
                let t = self.label(a, ops[2], line)?;
                a.$f(rs1, rs2, t);
            }};
        }
        macro_rules! br1 {
            ($f:ident) => {{
                need!(2);
                let rs = self.reg(line, ops[0])?;
                let t = self.label(a, ops[1], line)?;
                a.$f(rs, t);
            }};
        }

        // Custom-SIMD mnemonics (named binding + generic forms).
        if let Some(rest) = m.strip_prefix('c') {
            if let Some((slot_s, op_s)) = rest.split_once('.') {
                if let Ok(slot_i) = slot_s.parse::<usize>() {
                    if let Some(slot) = CustomSlot::from_index(slot_i) {
                        return self.custom(a, line, slot, op_s, ops);
                    }
                }
            }
        }

        match m {
            "lui" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let hi = self.imm(line, ops[1])? as i32;
                a.lui(rd, hi << 12);
            }
            "auipc" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let hi = self.imm(line, ops[1])? as i32;
                a.auipc(rd, hi << 12);
            }
            "jal" => match ops.len() {
                1 => {
                    let t = self.label(a, ops[0], line)?;
                    a.call(t);
                }
                2 => {
                    let rd = self.reg(line, ops[0])?;
                    let t = self.label(a, ops[1], line)?;
                    a.jal(rd, t);
                }
                n => return Err(err(line, format!("jal expects 1-2 operands, got {n}"))),
            },
            "jalr" => match ops.len() {
                1 => {
                    let rs = self.reg(line, ops[0])?;
                    a.jalr(crate::isa::reg::RA, rs, 0);
                }
                2 => {
                    let rd = self.reg(line, ops[0])?;
                    let (off, base) = self.mem(line, ops[1])?;
                    a.jalr(rd, base, off);
                }
                n => return Err(err(line, format!("jalr expects 1-2 operands, got {n}"))),
            },
            "beq" => br2!(beq),
            "bne" => br2!(bne),
            "blt" => br2!(blt),
            "bge" => br2!(bge),
            "bltu" => br2!(bltu),
            "bgeu" => br2!(bgeu),
            "bgt" => br2!(bgt),
            "ble" => br2!(ble),
            "bgtu" => br2!(bgtu),
            "bleu" => br2!(bleu),
            "beqz" => br1!(beqz),
            "bnez" => br1!(bnez),
            "blez" => br1!(blez),
            "bgez" => br1!(bgez),
            "bltz" => br1!(bltz),
            "bgtz" => br1!(bgtz),
            "lb" => ld!(lb),
            "lh" => ld!(lh),
            "lw" => ld!(lw),
            "lbu" => ld!(lbu),
            "lhu" => ld!(lhu),
            "sb" => st!(sb),
            "sh" => st!(sh),
            "sw" => st!(sw),
            "addi" => i3!(addi),
            "slti" => i3!(slti),
            "sltiu" => i3!(sltiu),
            "xori" => i3!(xori),
            "ori" => i3!(ori),
            "andi" => i3!(andi),
            "slli" => sh3!(slli),
            "srli" => sh3!(srli),
            "srai" => sh3!(srai),
            "add" => r3!(add),
            "sub" => r3!(sub),
            "sll" => r3!(sll),
            "slt" => r3!(slt),
            "sltu" => r3!(sltu),
            "xor" => r3!(xor),
            "srl" => r3!(srl),
            "sra" => r3!(sra),
            "or" => r3!(or),
            "and" => r3!(and),
            "mul" => r3!(mul),
            "mulh" => r3!(mulh),
            "mulhsu" => r3!(mulhsu),
            "mulhu" => r3!(mulhu),
            "div" => r3!(div),
            "divu" => r3!(divu),
            "rem" => r3!(rem),
            "remu" => r3!(remu),
            "fence" => a.fence(),
            "ecall" | "halt" => a.ecall(),
            "ebreak" => a.ebreak(),
            "nop" => a.nop(),
            "li" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let v = self.imm(line, ops[1])?;
                a.li(rd, v);
            }
            "la" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let t = self.label(a, ops[1], line)?;
                a.la(rd, t);
            }
            "mv" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let rs = self.reg(line, ops[1])?;
                a.mv(rd, rs);
            }
            "not" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let rs = self.reg(line, ops[1])?;
                a.not(rd, rs);
            }
            "neg" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let rs = self.reg(line, ops[1])?;
                a.neg(rd, rs);
            }
            "seqz" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let rs = self.reg(line, ops[1])?;
                a.seqz(rd, rs);
            }
            "snez" => {
                need!(2);
                let rd = self.reg(line, ops[0])?;
                let rs = self.reg(line, ops[1])?;
                a.snez(rd, rs);
            }
            "j" => {
                need!(1);
                let t = self.label(a, ops[0], line)?;
                a.j(t);
            }
            "call" => {
                need!(1);
                let t = self.label(a, ops[0], line)?;
                a.call(t);
            }
            "jr" => {
                need!(1);
                let rs = self.reg(line, ops[0])?;
                a.jr(rs);
            }
            "ret" => a.ret(),
            "rdcycle" => {
                need!(1);
                let rd = self.reg(line, ops[0])?;
                a.rdcycle(rd);
            }
            "rdcycleh" => {
                need!(1);
                let rd = self.reg(line, ops[0])?;
                a.rdcycleh(rd);
            }
            "rdinstret" => {
                need!(1);
                let rd = self.reg(line, ops[0])?;
                a.rdinstret(rd);
            }
            other => return Err(err(line, format!("unknown mnemonic '{other}'"))),
        }
        Ok(())
    }

    /// Custom instruction forms:
    /// named: `c0.lv vd, rs1, rs2` / `c0.sv vs, rs1, rs2` / `c2.sort vd, vs`
    /// / `c1.merge vd1, vd2, vs1, vs2` / `c1.vadd vd, vs1, vs2` /
    /// `c1.vscale vd, vs, rs` / `c3.prefix vd, vs` / `c3.reset` /
    /// `c3.carry rd`;
    /// generic: `cN.iK rd, vrd1, vrd2, rs1, vrs1, vrs2` and
    /// `cN.sK rd, vrd1, rs1, rs2, vrs1, imm`.
    fn custom(
        &mut self,
        a: &mut Asm,
        line: usize,
        slot: CustomSlot,
        op: &str,
        ops: &[&str],
    ) -> Result<(), ParseError> {
        match (slot, op) {
            (CustomSlot::C0, "lv") => {
                if ops.len() != 3 {
                    return Err(err(line, "c0.lv expects 'vd, rs1, rs2'"));
                }
                let vd = self.vreg(line, ops[0])?;
                let rs1 = self.reg(line, ops[1])?;
                let rs2 = self.reg(line, ops[2])?;
                a.lv(vd, rs1, rs2);
            }
            (CustomSlot::C0, "sv") => {
                if ops.len() != 3 {
                    return Err(err(line, "c0.sv expects 'vs, rs1, rs2'"));
                }
                let vs = self.vreg(line, ops[0])?;
                let rs1 = self.reg(line, ops[1])?;
                let rs2 = self.reg(line, ops[2])?;
                a.sv(vs, rs1, rs2);
            }
            (CustomSlot::C2, "sort") => {
                if ops.len() != 2 {
                    return Err(err(line, "c2.sort expects 'vd, vs'"));
                }
                let vd = self.vreg(line, ops[0])?;
                let vs = self.vreg(line, ops[1])?;
                a.sort8(vd, vs);
            }
            (CustomSlot::C1, "merge") => {
                if ops.len() != 4 {
                    return Err(err(line, "c1.merge expects 'vd1, vd2, vs1, vs2'"));
                }
                let vd1 = self.vreg(line, ops[0])?;
                let vd2 = self.vreg(line, ops[1])?;
                let vs1 = self.vreg(line, ops[2])?;
                let vs2 = self.vreg(line, ops[3])?;
                a.merge(vd1, vd2, vs1, vs2);
            }
            (CustomSlot::C1, "vadd") => {
                if ops.len() != 3 {
                    return Err(err(line, "c1.vadd expects 'vd, vs1, vs2'"));
                }
                let vd = self.vreg(line, ops[0])?;
                let vs1 = self.vreg(line, ops[1])?;
                let vs2 = self.vreg(line, ops[2])?;
                a.vadd(vd, vs1, vs2);
            }
            (CustomSlot::C1, "vscale") => {
                if ops.len() != 3 {
                    return Err(err(line, "c1.vscale expects 'vd, vs, rs'"));
                }
                let vd = self.vreg(line, ops[0])?;
                let vs = self.vreg(line, ops[1])?;
                let rs = self.reg(line, ops[2])?;
                a.vscale(vd, vs, rs);
            }
            (CustomSlot::C3, "prefix") => {
                if ops.len() != 2 {
                    return Err(err(line, "c3.prefix expects 'vd, vs'"));
                }
                let vd = self.vreg(line, ops[0])?;
                let vs = self.vreg(line, ops[1])?;
                a.prefix(vd, vs);
            }
            (CustomSlot::C3, "reset") => a.prefix_reset(),
            (CustomSlot::C3, "carry") => {
                if ops.len() != 1 {
                    return Err(err(line, "c3.carry expects 'rd'"));
                }
                let rd = self.reg(line, ops[0])?;
                a.prefix_carry(rd);
            }
            _ => {
                // Generic forms: iK / sK.
                if let Some(k) = op.strip_prefix('i').and_then(|k| k.parse::<u8>().ok()) {
                    if ops.len() != 6 {
                        return Err(err(line, "cN.iK expects 'rd, vrd1, vrd2, rs1, vrs1, vrs2'"));
                    }
                    let instr = Instr::CustomI {
                        slot,
                        funct3: k,
                        ops: IPrime {
                            rd: self.reg(line, ops[0])?,
                            vrd1: self.vreg(line, ops[1])?,
                            vrd2: self.vreg(line, ops[2])?,
                            rs1: self.reg(line, ops[3])?,
                            vrs1: self.vreg(line, ops[4])?,
                            vrs2: self.vreg(line, ops[5])?,
                        },
                    };
                    a.emit(instr);
                } else if let Some(k) = op.strip_prefix('s').and_then(|k| k.parse::<u8>().ok()) {
                    if ops.len() != 6 {
                        return Err(err(line, "cN.sK expects 'rd, vrd1, rs1, rs2, vrs1, imm'"));
                    }
                    let instr = Instr::CustomS {
                        slot,
                        funct3: k,
                        ops: SPrime {
                            rd: self.reg(line, ops[0])?,
                            vrd1: self.vreg(line, ops[1])?,
                            rs1: self.reg(line, ops[2])?,
                            rs2: self.reg(line, ops[3])?,
                            vrs1: self.vreg(line, ops[4])?,
                            imm: self.imm(line, ops[5])? as u8,
                        },
                    };
                    a.emit(instr);
                } else {
                    return Err(err(line, format!("unknown custom mnemonic '{slot}.{op}'")));
                }
            }
        }
        let _ = ZERO; // silence unused import on some cfgs
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    let mut end = line.len();
    for pat in ["#", "//", ";"] {
        if let Some(i) = line.find(pat) {
            end = end.min(i);
        }
    }
    &line[..end]
}

/// Find the colon ending a leading `label:` prefix (not inside operands).
fn find_label_colon(s: &str) -> Option<usize> {
    let colon = s.find(':')?;
    // Only treat as a label if everything before the colon is an identifier.
    is_ident(s[..colon].trim()).then_some(colon)
}

fn is_ident(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
}

fn parse_int(s: &str) -> Option<i64> {
    let (neg, body) = match s.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, s),
    };
    let v = if let Some(hex) = body.strip_prefix("0x").or_else(|| body.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16).ok().or_else(|| u64::from_str_radix(hex, 16).ok().map(|u| u as i64))?
    } else if let Some(bin) = body.strip_prefix("0b") {
        i64::from_str_radix(bin, 2).ok()?
    } else {
        body.parse::<i64>().ok()?
    };
    Some(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode;
    use crate::isa::reg::*;

    #[test]
    fn countdown_loop_assembles() {
        let p = assemble_text(
            r#"
            # simple countdown
            .entry main
            main:
                li a0, 3
            loop:
                addi a0, a0, -1   // decrement
                bnez a0, loop
                ecall
        "#,
        )
        .unwrap();
        assert_eq!(p.entry, p.sym("main"));
        assert_eq!(p.text.len(), 4);
        assert_eq!(
            decode(p.text[2]).unwrap(),
            Instr::Bne { rs1: A0, rs2: ZERO, offset: -4 }
        );
    }

    #[test]
    fn data_and_la() {
        let p = assemble_text(
            r#"
            .data
            table: .word 10, 20, 30
            buf:   .space 64
            .text
            main:
                la a0, table
                lw a1, 4(a0)
                ecall
        "#,
        )
        .unwrap();
        assert_eq!(&p.data[4..8], &20u32.to_le_bytes());
        assert_eq!(p.sym("buf"), p.sym("table") + 12);
    }

    #[test]
    fn custom_mnemonics() {
        let p = assemble_text(
            r#"
            main:
                c0.lv v1, a0, a1
                c2.sort v1, v1
                c1.merge v1, v2, v1, v2
                c3.prefix v3, v1
                c3.reset
                c3.carry a5
                c0.sv v1, a2, a3
                c1.i3 a0, v1, v2, a1, v3, v4
                c0.s6 a0, v1, a1, a2, v2, 1
                ecall
        "#,
        )
        .unwrap();
        for w in &p.text[..9] {
            assert!(matches!(
                decode(*w).unwrap(),
                Instr::CustomI { .. } | Instr::CustomS { .. }
            ));
        }
        match decode(p.text[7]).unwrap() {
            Instr::CustomI { slot: CustomSlot::C1, funct3: 3, ops } => {
                assert_eq!(ops.rd, A0);
                assert_eq!(ops.vrs2, V4);
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn equ_constants() {
        let p = assemble_text(
            r#"
            .equ N, 64
            main:
                li a0, N
                addi a0, a0, N
                ecall
        "#,
        )
        .unwrap();
        assert_eq!(decode(p.text[0]).unwrap(), Instr::Addi { rd: A0, rs1: ZERO, imm: 64 });
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = assemble_text("main:\n  bogus a0, a1\n").unwrap_err();
        match e {
            ParseError::Syntax { line, msg } => {
                assert_eq!(line, 2);
                assert!(msg.contains("bogus"));
            }
            other => panic!("{other}"),
        }
        let e = assemble_text("  lw a0, 4[sp]\n").unwrap_err();
        assert!(matches!(e, ParseError::Syntax { line: 1, .. }));
    }

    #[test]
    fn hex_and_binary_immediates() {
        let p = assemble_text("li a0, 0x10\nli a1, 0b101\nli a2, -0x8\necall\n").unwrap();
        assert_eq!(decode(p.text[0]).unwrap(), Instr::Addi { rd: A0, rs1: ZERO, imm: 16 });
        assert_eq!(decode(p.text[1]).unwrap(), Instr::Addi { rd: A1, rs1: ZERO, imm: 5 });
        assert_eq!(decode(p.text[2]).unwrap(), Instr::Addi { rd: A2, rs1: ZERO, imm: -8 });
    }

    #[test]
    fn comments_all_styles() {
        let p = assemble_text("nop # a\nnop // b\nnop ; c\necall\n").unwrap();
        assert_eq!(p.text.len(), 4);
    }
}
