//! Typed program builder — the in-Rust equivalent of the paper's inline
//! assembly with the patched binutils (§2.1): workloads are authored as
//! Rust functions that emit RV32IM + custom-SIMD instructions with label
//! support, then assembled to a flat [`Program`] image.
//!
//! ```
//! use simdsoftcore::asm::Asm;
//! use simdsoftcore::isa::reg::*;
//!
//! let mut a = Asm::new();
//! let loop_ = a.new_label("loop");
//! a.li(A0, 10);
//! a.bind(loop_);
//! a.addi(A0, A0, -1);
//! a.bnez(A0, loop_);
//! a.halt();
//! let prog = a.assemble().unwrap();
//! assert!(prog.text.len() >= 4);
//! ```

use super::program::{Program, DEFAULT_DATA_BASE, DEFAULT_TEXT_BASE};
use crate::isa::encode::{encode, EncodeError};
use crate::isa::instr::{csr, CustomSlot, IPrime, Instr, SPrime};
use crate::isa::reg::{Reg, VReg, RA, ZERO};
use std::collections::HashMap;

#[derive(Debug)]
pub enum AsmError {
    UnboundLabel(String),
    DoubleBound(String),
    BranchOutOfRange { label: String, offset: i64 },
    JumpOutOfRange { label: String, offset: i64 },
    Encode { index: usize, source: EncodeError },
    SegmentOverlap { text_end: u32, data_base: u32 },
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsmError::UnboundLabel(name) => write!(f, "label '{name}' used but never bound"),
            AsmError::DoubleBound(name) => write!(f, "label '{name}' bound twice"),
            AsmError::BranchOutOfRange { label, offset } => {
                write!(f, "branch to '{label}' out of range (offset {offset})")
            }
            AsmError::JumpOutOfRange { label, offset } => {
                write!(f, "jump to '{label}' out of range (offset {offset})")
            }
            AsmError::Encode { index, source } => {
                write!(f, "encode error at instruction {index}: {source}")
            }
            AsmError::SegmentOverlap { text_end, data_base } => write!(
                f,
                "text segment (ends {text_end:#x}) overlaps data segment (base {data_base:#x})"
            ),
        }
    }
}

impl std::error::Error for AsmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AsmError::Encode { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// A (possibly not-yet-bound) position in the program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Label(usize);

#[derive(Debug, Clone, Copy)]
enum LabelPos {
    /// Index into the text item list.
    Text(usize),
    /// Byte offset into the data segment.
    Data(usize),
}

#[derive(Debug, Clone)]
enum Item {
    /// Fully-resolved instruction.
    Fixed(Instr),
    /// Branch with label-relative offset to patch.
    Branch(Instr, Label),
    /// `jal rd, label`.
    Jal(Reg, Label),
    /// `lui rd, %hi(label)`.
    Hi20(Reg, Label),
    /// Instruction whose 12-bit immediate is `%lo(label)` (addi/lw/sw...).
    Lo12(Instr, Label),
    /// Literal word (e.g. `.word label` jump tables).
    WordLabel(Label),
    /// Raw literal word in the text stream (`.word 0x...`).
    WordLiteral(u32),
}

/// The program builder. See module docs for an example.
pub struct Asm {
    text_base: u32,
    data_base: u32,
    items: Vec<Item>,
    data: Vec<u8>,
    labels: Vec<(String, Option<LabelPos>)>,
    named: HashMap<String, Label>,
    entry: Option<Label>,
}

impl Default for Asm {
    fn default() -> Self {
        Self::new()
    }
}

impl Asm {
    pub fn new() -> Self {
        Self::with_bases(DEFAULT_TEXT_BASE, DEFAULT_DATA_BASE)
    }

    pub fn with_bases(text_base: u32, data_base: u32) -> Self {
        assert_eq!(text_base % 4, 0, "text base must be word-aligned");
        Self {
            text_base,
            data_base,
            items: Vec::new(),
            data: Vec::new(),
            labels: Vec::new(),
            named: HashMap::new(),
            entry: None,
        }
    }

    // ---- labels ---------------------------------------------------------

    /// Create a fresh label with a diagnostic name (names need not be
    /// unique; `named_label` gives uniqueness by name).
    pub fn new_label(&mut self, name: &str) -> Label {
        let id = Label(self.labels.len());
        self.labels.push((name.to_string(), None));
        id
    }

    /// Get or create the unique label with this exact name (used by the
    /// text assembler and for cross-referencing data symbols).
    pub fn named_label(&mut self, name: &str) -> Label {
        if let Some(&l) = self.named.get(name) {
            return l;
        }
        let l = self.new_label(name);
        self.named.insert(name.to_string(), l);
        l
    }

    /// Bind `label` to the current text position.
    pub fn bind(&mut self, label: Label) {
        assert!(
            self.labels[label.0].1.is_none(),
            "label '{}' bound twice",
            self.labels[label.0].0
        );
        self.labels[label.0].1 = Some(LabelPos::Text(self.items.len()));
    }

    /// Create and bind a label at the current text position.
    pub fn here(&mut self, name: &str) -> Label {
        let l = self.new_label(name);
        self.bind(l);
        l
    }

    /// Bind `label` to the current data position.
    pub fn bind_data(&mut self, label: Label) {
        assert!(
            self.labels[label.0].1.is_none(),
            "label '{}' bound twice",
            self.labels[label.0].0
        );
        self.labels[label.0].1 = Some(LabelPos::Data(self.data.len()));
    }

    /// Mark the entry point (defaults to the first text instruction).
    pub fn entry(&mut self, label: Label) {
        self.entry = Some(label);
    }

    /// Number of instruction slots emitted so far (li/la may expand to 2).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.len() == 0
    }

    // ---- data segment ---------------------------------------------------

    /// Append raw bytes to the data segment.
    pub fn db(&mut self, bytes: &[u8]) {
        self.data.extend_from_slice(bytes);
    }

    /// Append 32-bit words (little-endian) to the data segment.
    pub fn dw(&mut self, words: &[u32]) {
        for w in words {
            self.data.extend_from_slice(&w.to_le_bytes());
        }
    }

    /// Reserve `n` zero bytes in the data segment.
    pub fn dspace(&mut self, n: usize) {
        self.data.resize(self.data.len() + n, 0);
    }

    /// Align the data cursor to a multiple of `align` bytes.
    pub fn dalign(&mut self, align: usize) {
        assert!(align.is_power_of_two());
        while self.data.len() % align != 0 {
            self.data.push(0);
        }
    }

    /// Convenience: bind a fresh data label, aligned, with reserved space.
    pub fn buffer(&mut self, name: &str, bytes: usize, align: usize) -> Label {
        self.dalign(align);
        let l = self.named_label(name);
        self.bind_data(l);
        self.dspace(bytes);
        l
    }

    /// Convenience: bind a fresh data label over initialised words.
    pub fn words(&mut self, name: &str, ws: &[u32]) -> Label {
        self.dalign(4);
        let l = self.named_label(name);
        self.bind_data(l);
        self.dw(ws);
        l
    }

    // ---- raw emit -------------------------------------------------------

    pub fn emit(&mut self, instr: Instr) {
        self.items.push(Item::Fixed(instr));
    }

    /// Emit a literal `.word` in the text stream.
    pub fn word(&mut self, w: u32) {
        // Represent as a Fixed item via a decode round-trip when possible;
        // otherwise store as a word-label-free literal. We use a dedicated
        // data-in-text escape: a raw word item.
        self.items.push(Item::WordLiteral(w));
    }

    /// Emit `.word label` (absolute address of `label`).
    pub fn word_label(&mut self, label: Label) {
        self.items.push(Item::WordLabel(label));
    }

    // ---- RV32I ----------------------------------------------------------

    pub fn lui(&mut self, rd: Reg, imm_hi: i32) {
        self.emit(Instr::Lui { rd, imm: imm_hi });
    }
    pub fn auipc(&mut self, rd: Reg, imm_hi: i32) {
        self.emit(Instr::Auipc { rd, imm: imm_hi });
    }
    pub fn jal(&mut self, rd: Reg, target: Label) {
        self.items.push(Item::Jal(rd, target));
    }
    pub fn jalr(&mut self, rd: Reg, rs1: Reg, offset: i32) {
        self.emit(Instr::Jalr { rd, rs1, offset });
    }

    pub fn beq(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Beq { rs1, rs2, offset: 0 }, t));
    }
    pub fn bne(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Bne { rs1, rs2, offset: 0 }, t));
    }
    pub fn blt(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Blt { rs1, rs2, offset: 0 }, t));
    }
    pub fn bge(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Bge { rs1, rs2, offset: 0 }, t));
    }
    pub fn bltu(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Bltu { rs1, rs2, offset: 0 }, t));
    }
    pub fn bgeu(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.items.push(Item::Branch(Instr::Bgeu { rs1, rs2, offset: 0 }, t));
    }

    pub fn lb(&mut self, rd: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Lb { rd, rs1, offset });
    }
    pub fn lh(&mut self, rd: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Lh { rd, rs1, offset });
    }
    pub fn lw(&mut self, rd: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Lw { rd, rs1, offset });
    }
    pub fn lbu(&mut self, rd: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Lbu { rd, rs1, offset });
    }
    pub fn lhu(&mut self, rd: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Lhu { rd, rs1, offset });
    }
    pub fn sb(&mut self, rs2: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Sb { rs1, rs2, offset });
    }
    pub fn sh(&mut self, rs2: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Sh { rs1, rs2, offset });
    }
    pub fn sw(&mut self, rs2: Reg, offset: i32, rs1: Reg) {
        self.emit(Instr::Sw { rs1, rs2, offset });
    }

    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Addi { rd, rs1, imm });
    }
    pub fn slti(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Slti { rd, rs1, imm });
    }
    pub fn sltiu(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Sltiu { rd, rs1, imm });
    }
    pub fn xori(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Xori { rd, rs1, imm });
    }
    pub fn ori(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Ori { rd, rs1, imm });
    }
    pub fn andi(&mut self, rd: Reg, rs1: Reg, imm: i32) {
        self.emit(Instr::Andi { rd, rs1, imm });
    }
    pub fn slli(&mut self, rd: Reg, rs1: Reg, shamt: u8) {
        self.emit(Instr::Slli { rd, rs1, shamt });
    }
    pub fn srli(&mut self, rd: Reg, rs1: Reg, shamt: u8) {
        self.emit(Instr::Srli { rd, rs1, shamt });
    }
    pub fn srai(&mut self, rd: Reg, rs1: Reg, shamt: u8) {
        self.emit(Instr::Srai { rd, rs1, shamt });
    }

    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Add { rd, rs1, rs2 });
    }
    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Sub { rd, rs1, rs2 });
    }
    pub fn sll(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Sll { rd, rs1, rs2 });
    }
    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Slt { rd, rs1, rs2 });
    }
    pub fn sltu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Sltu { rd, rs1, rs2 });
    }
    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Xor { rd, rs1, rs2 });
    }
    pub fn srl(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Srl { rd, rs1, rs2 });
    }
    pub fn sra(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Sra { rd, rs1, rs2 });
    }
    pub fn or(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Or { rd, rs1, rs2 });
    }
    pub fn and(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::And { rd, rs1, rs2 });
    }

    pub fn fence(&mut self) {
        self.emit(Instr::Fence);
    }
    pub fn ecall(&mut self) {
        self.emit(Instr::Ecall);
    }
    pub fn ebreak(&mut self) {
        self.emit(Instr::Ebreak);
    }

    // ---- M extension ----------------------------------------------------

    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Mul { rd, rs1, rs2 });
    }
    pub fn mulh(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Mulh { rd, rs1, rs2 });
    }
    pub fn mulhsu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Mulhsu { rd, rs1, rs2 });
    }
    pub fn mulhu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Mulhu { rd, rs1, rs2 });
    }
    pub fn div(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Div { rd, rs1, rs2 });
    }
    pub fn divu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Divu { rd, rs1, rs2 });
    }
    pub fn rem(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Rem { rd, rs1, rs2 });
    }
    pub fn remu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::Remu { rd, rs1, rs2 });
    }

    // ---- pseudo-instructions ---------------------------------------------

    pub fn nop(&mut self) {
        self.addi(ZERO, ZERO, 0);
    }

    pub fn mv(&mut self, rd: Reg, rs: Reg) {
        self.addi(rd, rs, 0);
    }

    pub fn not(&mut self, rd: Reg, rs: Reg) {
        self.xori(rd, rs, -1);
    }

    pub fn neg(&mut self, rd: Reg, rs: Reg) {
        self.sub(rd, ZERO, rs);
    }

    pub fn seqz(&mut self, rd: Reg, rs: Reg) {
        self.sltiu(rd, rs, 1);
    }

    pub fn snez(&mut self, rd: Reg, rs: Reg) {
        self.sltu(rd, ZERO, rs);
    }

    /// Load a 32-bit immediate (1 or 2 instructions).
    pub fn li(&mut self, rd: Reg, imm: i64) {
        let imm = imm as i32; // callers may pass u32 via `as i64`
        if (-2048..=2047).contains(&imm) {
            self.addi(rd, ZERO, imm);
            return;
        }
        // lui + addi with carry correction: hi = (imm + 0x800) >> 12.
        let hi = (imm as u32).wrapping_add(0x800) & 0xffff_f000;
        let lo = imm.wrapping_sub(hi as i32);
        debug_assert!((-2048..=2047).contains(&lo));
        self.lui(rd, hi as i32);
        if lo != 0 {
            self.addi(rd, rd, lo);
        }
    }

    /// Load the absolute address of `label` (lui+addi; always 2 slots for
    /// deterministic code size).
    pub fn la(&mut self, rd: Reg, label: Label) {
        self.items.push(Item::Hi20(rd, label));
        self.items.push(Item::Lo12(Instr::Addi { rd, rs1: rd, imm: 0 }, label));
    }

    pub fn j(&mut self, target: Label) {
        self.jal(ZERO, target);
    }

    pub fn call(&mut self, target: Label) {
        self.jal(RA, target);
    }

    pub fn ret(&mut self) {
        self.jalr(ZERO, RA, 0);
    }

    pub fn jr(&mut self, rs: Reg) {
        self.jalr(ZERO, rs, 0);
    }

    pub fn beqz(&mut self, rs: Reg, t: Label) {
        self.beq(rs, ZERO, t);
    }
    pub fn bnez(&mut self, rs: Reg, t: Label) {
        self.bne(rs, ZERO, t);
    }
    pub fn blez(&mut self, rs: Reg, t: Label) {
        self.bge(ZERO, rs, t);
    }
    pub fn bgez(&mut self, rs: Reg, t: Label) {
        self.bge(rs, ZERO, t);
    }
    pub fn bltz(&mut self, rs: Reg, t: Label) {
        self.blt(rs, ZERO, t);
    }
    pub fn bgtz(&mut self, rs: Reg, t: Label) {
        self.blt(ZERO, rs, t);
    }
    pub fn bgt(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.blt(rs2, rs1, t);
    }
    pub fn ble(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.bge(rs2, rs1, t);
    }
    pub fn bgtu(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.bltu(rs2, rs1, t);
    }
    pub fn bleu(&mut self, rs1: Reg, rs2: Reg, t: Label) {
        self.bgeu(rs2, rs1, t);
    }

    /// Read the 64-bit cycle counter low word.
    pub fn rdcycle(&mut self, rd: Reg) {
        self.emit(Instr::Csrrs { rd, csr: csr::CYCLE, rs1: ZERO });
    }
    pub fn rdcycleh(&mut self, rd: Reg) {
        self.emit(Instr::Csrrs { rd, csr: csr::CYCLEH, rs1: ZERO });
    }
    pub fn rdinstret(&mut self, rd: Reg) {
        self.emit(Instr::Csrrs { rd, csr: csr::INSTRET, rs1: ZERO });
    }

    /// Halt convention: `ecall` returns control to the host/coordinator.
    pub fn halt(&mut self) {
        self.ecall();
    }

    // ---- custom SIMD instructions (§2, default fabric binding) -----------
    //
    // These wrappers encode the standard unit set this repo loads into the
    // four reconfigurable slots (see `simd::units`): c0 = load/store
    // vector (S′), c1 = merge + elementwise ops (I′), c2 = sorting
    // network (I′), c3 = prefix sum (I′, stateful).

    /// `c0.lv vrd1, (rs1+rs2)` — load a VLEN vector from `rs1 + rs2`.
    pub fn lv(&mut self, vrd: VReg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::CustomS {
            slot: CustomSlot::C0,
            funct3: 4,
            ops: SPrime { vrs1: VReg::ZERO, vrd1: vrd, imm: 0, rs2, rs1, rd: ZERO },
        });
    }

    /// `c0.sv vrs1, (rs1+rs2)` — store a VLEN vector to `rs1 + rs2`.
    pub fn sv(&mut self, vrs: VReg, rs1: Reg, rs2: Reg) {
        self.emit(Instr::CustomS {
            slot: CustomSlot::C0,
            funct3: 5,
            ops: SPrime { vrs1: vrs, vrd1: VReg::ZERO, imm: 0, rs2, rs1, rd: ZERO },
        });
    }

    /// `c2.sort vrd1, vrs1` — bitonic-sort the VLEN/32 elements of `vrs1`.
    pub fn sort8(&mut self, vrd: VReg, vrs: VReg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C2,
            funct3: 0,
            ops: IPrime {
                vrs1: vrs,
                vrd1: vrd,
                vrs2: VReg::ZERO,
                vrd2: VReg::ZERO,
                rs1: ZERO,
                rd: ZERO,
            },
        });
    }

    /// `c1.merge vrd1, vrd2, vrs1, vrs2` — odd-even merge of two sorted
    /// vectors; low half → vrd1, high half → vrd2 (Fig. 5).
    pub fn merge(&mut self, vrd1: VReg, vrd2: VReg, vrs1: VReg, vrs2: VReg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C1,
            funct3: 0,
            ops: IPrime { vrs1, vrd1, vrs2, vrd2, rs1: ZERO, rd: ZERO },
        });
    }

    /// `c1.vadd vrd1, vrs1, vrs2` — elementwise 32-bit add.
    pub fn vadd(&mut self, vrd: VReg, vrs1: VReg, vrs2: VReg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C1,
            funct3: 1,
            ops: IPrime { vrs1, vrd1: vrd, vrs2, vrd2: VReg::ZERO, rs1: ZERO, rd: ZERO },
        });
    }

    /// `c1.vscale vrd1, vrs1, rs1` — elementwise multiply by scalar `rs1`.
    pub fn vscale(&mut self, vrd: VReg, vrs: VReg, rs1: Reg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C1,
            funct3: 2,
            ops: IPrime { vrs1: vrs, vrd1: vrd, vrs2: VReg::ZERO, vrd2: VReg::ZERO, rs1, rd: ZERO },
        });
    }

    /// `c1.vfilt rd, vrd1, vrs1, rs1` — compact lanes of `vrs1` strictly
    /// below the scalar threshold `rs1` into `vrd1` (order-preserving);
    /// the selected count lands in `rd`. The §4.3.2-motivated database
    /// selection instruction (an exploration beyond the paper's set,
    /// using the I′ type's 6-operand capacity).
    pub fn vfilt(&mut self, rd: Reg, vrd: VReg, vrs: VReg, rs1: Reg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C1,
            funct3: 3,
            ops: IPrime { vrs1: vrs, vrd1: vrd, vrs2: VReg::ZERO, vrd2: VReg::ZERO, rs1, rd },
        });
    }

    /// `c3.prefix vrd1, vrs1` — Hillis-Steele prefix sum over the vector
    /// plus the unit's carry accumulator; the accumulator is updated with
    /// the total (Fig. 7).
    pub fn prefix(&mut self, vrd: VReg, vrs: VReg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C3,
            funct3: 0,
            ops: IPrime {
                vrs1: vrs,
                vrd1: vrd,
                vrs2: VReg::ZERO,
                vrd2: VReg::ZERO,
                rs1: ZERO,
                rd: ZERO,
            },
        });
    }

    /// `c3.reset` — clear the prefix-sum carry accumulator.
    pub fn prefix_reset(&mut self) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C3,
            funct3: 1,
            ops: IPrime {
                vrs1: VReg::ZERO,
                vrd1: VReg::ZERO,
                vrs2: VReg::ZERO,
                vrd2: VReg::ZERO,
                rs1: ZERO,
                rd: ZERO,
            },
        });
    }

    /// `c3.carry rd` — read the carry accumulator into a base register.
    pub fn prefix_carry(&mut self, rd: Reg) {
        self.emit(Instr::CustomI {
            slot: CustomSlot::C3,
            funct3: 2,
            ops: IPrime {
                vrs1: VReg::ZERO,
                vrd1: VReg::ZERO,
                vrs2: VReg::ZERO,
                vrd2: VReg::ZERO,
                rs1: ZERO,
                rd,
            },
        });
    }

    // ---- assembly --------------------------------------------------------

    fn label_addr(&self, label: Label) -> Option<u32> {
        match self.labels[label.0].1? {
            LabelPos::Text(i) => Some(self.text_base + (i as u32) * 4),
            LabelPos::Data(off) => Some(self.data_base + off as u32),
        }
    }

    fn label_name(&self, label: Label) -> &str {
        &self.labels[label.0].0
    }

    /// Resolve all fixups and produce the program image.
    pub fn assemble(self) -> Result<Program, AsmError> {
        let text_end = self.text_base + (self.items.len() as u32) * 4;
        if !self.data.is_empty() && text_end > self.data_base {
            return Err(AsmError::SegmentOverlap { text_end, data_base: self.data_base });
        }

        let mut text = Vec::with_capacity(self.items.len());
        for (i, item) in self.items.iter().enumerate() {
            let pc = self.text_base + (i as u32) * 4;
            let word = match item {
                Item::Fixed(instr) => {
                    encode(instr).map_err(|source| AsmError::Encode { index: i, source })?
                }
                Item::WordLiteral(w) => *w,
                Item::Branch(instr, target) => {
                    let addr = self
                        .label_addr(*target)
                        .ok_or_else(|| AsmError::UnboundLabel(self.label_name(*target).into()))?;
                    let offset = addr as i64 - pc as i64;
                    if !(-4096..=4094).contains(&offset) {
                        return Err(AsmError::BranchOutOfRange {
                            label: self.label_name(*target).into(),
                            offset,
                        });
                    }
                    let patched = patch_branch(instr, offset as i32);
                    encode(&patched).map_err(|source| AsmError::Encode { index: i, source })?
                }
                Item::Jal(rd, target) => {
                    let addr = self
                        .label_addr(*target)
                        .ok_or_else(|| AsmError::UnboundLabel(self.label_name(*target).into()))?;
                    let offset = addr as i64 - pc as i64;
                    if !(-(1 << 20)..=(1 << 20) - 2).contains(&offset) {
                        return Err(AsmError::JumpOutOfRange {
                            label: self.label_name(*target).into(),
                            offset,
                        });
                    }
                    encode(&Instr::Jal { rd: *rd, offset: offset as i32 })
                        .map_err(|source| AsmError::Encode { index: i, source })?
                }
                Item::Hi20(rd, target) => {
                    let addr = self
                        .label_addr(*target)
                        .ok_or_else(|| AsmError::UnboundLabel(self.label_name(*target).into()))?;
                    let hi = addr.wrapping_add(0x800) & 0xffff_f000;
                    encode(&Instr::Lui { rd: *rd, imm: hi as i32 })
                        .map_err(|source| AsmError::Encode { index: i, source })?
                }
                Item::Lo12(instr, target) => {
                    let addr = self
                        .label_addr(*target)
                        .ok_or_else(|| AsmError::UnboundLabel(self.label_name(*target).into()))?;
                    let hi = addr.wrapping_add(0x800) & 0xffff_f000;
                    let lo = addr.wrapping_sub(hi) as i32;
                    let patched = patch_lo12(instr, lo);
                    encode(&patched).map_err(|source| AsmError::Encode { index: i, source })?
                }
                Item::WordLabel(target) => self
                    .label_addr(*target)
                    .ok_or_else(|| AsmError::UnboundLabel(self.label_name(*target).into()))?,
            };
            text.push(word);
        }

        let mut symbols = HashMap::new();
        for (name, pos) in &self.labels {
            if let Some(pos) = pos {
                let addr = match pos {
                    LabelPos::Text(idx) => self.text_base + (*idx as u32) * 4,
                    LabelPos::Data(off) => self.data_base + *off as u32,
                };
                symbols.insert(name.clone(), addr);
            }
        }

        let entry = match self.entry {
            Some(l) => self
                .label_addr(l)
                .ok_or_else(|| AsmError::UnboundLabel(self.label_name(l).into()))?,
            None => self.text_base,
        };

        Ok(Program {
            text_base: self.text_base,
            text,
            data_base: self.data_base,
            data: self.data,
            symbols,
            entry,
        })
    }
}

fn patch_branch(instr: &Instr, offset: i32) -> Instr {
    use Instr::*;
    match *instr {
        Beq { rs1, rs2, .. } => Beq { rs1, rs2, offset },
        Bne { rs1, rs2, .. } => Bne { rs1, rs2, offset },
        Blt { rs1, rs2, .. } => Blt { rs1, rs2, offset },
        Bge { rs1, rs2, .. } => Bge { rs1, rs2, offset },
        Bltu { rs1, rs2, .. } => Bltu { rs1, rs2, offset },
        Bgeu { rs1, rs2, .. } => Bgeu { rs1, rs2, offset },
        other => panic!("patch_branch on non-branch {other:?}"),
    }
}

fn patch_lo12(instr: &Instr, lo: i32) -> Instr {
    use Instr::*;
    match *instr {
        Addi { rd, rs1, .. } => Addi { rd, rs1, imm: lo },
        Lw { rd, rs1, .. } => Lw { rd, rs1, offset: lo },
        Lb { rd, rs1, .. } => Lb { rd, rs1, offset: lo },
        Lh { rd, rs1, .. } => Lh { rd, rs1, offset: lo },
        Lbu { rd, rs1, .. } => Lbu { rd, rs1, offset: lo },
        Lhu { rd, rs1, .. } => Lhu { rd, rs1, offset: lo },
        Sw { rs1, rs2, .. } => Sw { rs1, rs2, offset: lo },
        Sb { rs1, rs2, .. } => Sb { rs1, rs2, offset: lo },
        Sh { rs1, rs2, .. } => Sh { rs1, rs2, offset: lo },
        other => panic!("patch_lo12 on unsupported instruction {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode;
    use crate::isa::reg::*;

    #[test]
    fn forward_and_backward_branches_resolve() {
        let mut a = Asm::new();
        let start = a.here("start");
        let end = a.new_label("end");
        a.beq(A0, A1, end); // forward
        a.addi(A0, A0, 1);
        a.j(start); // backward
        a.bind(end);
        a.halt();
        let p = a.assemble().unwrap();
        assert_eq!(p.text.len(), 4);
        // beq forward by 12 bytes
        assert_eq!(
            decode(p.text[0]).unwrap(),
            Instr::Beq { rs1: A0, rs2: A1, offset: 12 }
        );
        // jal backward by -8
        assert_eq!(decode(p.text[2]).unwrap(), Instr::Jal { rd: ZERO, offset: -8 });
    }

    #[test]
    fn li_small_and_large() {
        let mut a = Asm::new();
        a.li(A0, 42);
        a.li(A1, 0x12345);
        a.li(A2, -1);
        a.li(A3, 0x0000_0800); // needs lui because 0x800 > 2047
        let p = a.assemble().unwrap();
        // 1 + 2 + 1 + 2 instructions
        assert_eq!(p.text.len(), 6);
        assert_eq!(decode(p.text[0]).unwrap(), Instr::Addi { rd: A0, rs1: ZERO, imm: 42 });
        // Verify 0x12345 materialisation semantics by symbolic execution.
        let check = |hi_word: u32, lo_word: u32, expect: u32| {
            let hi = match decode(hi_word).unwrap() {
                Instr::Lui { imm, .. } => imm as u32,
                other => panic!("expected lui, got {other}"),
            };
            let lo = match decode(lo_word).unwrap() {
                Instr::Addi { imm, .. } => imm,
                other => panic!("expected addi, got {other}"),
            };
            assert_eq!(hi.wrapping_add(lo as u32), expect);
        };
        check(p.text[1], p.text[2], 0x12345);
        check(p.text[4], p.text[5], 0x800);
    }

    #[test]
    fn la_points_at_data() {
        let mut a = Asm::new();
        let buf = a.buffer("buf", 64, 16);
        a.la(A0, buf);
        a.halt();
        let p = a.assemble().unwrap();
        let addr = p.sym("buf");
        let hi = match decode(p.text[0]).unwrap() {
            Instr::Lui { imm, .. } => imm as u32,
            other => panic!("{other}"),
        };
        let lo = match decode(p.text[1]).unwrap() {
            Instr::Addi { imm, .. } => imm,
            other => panic!("{other}"),
        };
        assert_eq!(hi.wrapping_add(lo as u32), addr);
    }

    #[test]
    fn unbound_label_is_an_error() {
        let mut a = Asm::new();
        let nowhere = a.new_label("nowhere");
        a.j(nowhere);
        assert!(matches!(a.assemble(), Err(AsmError::UnboundLabel(n)) if n == "nowhere"));
    }

    #[test]
    fn branch_out_of_range_is_reported() {
        let mut a = Asm::new();
        let far = a.new_label("far");
        a.beq(A0, A1, far);
        for _ in 0..2000 {
            a.nop();
        }
        a.bind(far);
        a.halt();
        assert!(matches!(a.assemble(), Err(AsmError::BranchOutOfRange { .. })));
    }

    #[test]
    fn data_segment_and_symbols() {
        let mut a = Asm::new();
        let tbl = a.words("table", &[1, 2, 3, 4]);
        a.dalign(64);
        let buf = a.buffer("buf", 32, 32);
        a.la(A0, tbl);
        a.la(A1, buf);
        a.halt();
        let p = a.assemble().unwrap();
        assert_eq!(p.sym("table") % 4, 0);
        assert_eq!(p.sym("buf") % 32, 0);
        assert_eq!(&p.data[0..4], &1u32.to_le_bytes());
        assert!(p.sym("buf") >= p.sym("table") + 16);
    }

    #[test]
    fn custom_wrappers_encode_and_decode() {
        let mut a = Asm::new();
        a.lv(V1, A0, A1);
        a.sv(V1, A2, A3);
        a.sort8(V2, V1);
        a.merge(V1, V2, V1, V2);
        a.vadd(V3, V1, V2);
        a.vscale(V4, V3, T0);
        a.prefix(V5, V4);
        a.prefix_reset();
        a.prefix_carry(A5);
        a.halt();
        let p = a.assemble().unwrap();
        // Every emitted word must decode back to a custom instruction.
        for (i, w) in p.text[..9].iter().enumerate() {
            let instr = decode(*w).unwrap_or_else(|e| panic!("word {i}: {e}"));
            assert!(
                matches!(instr, Instr::CustomI { .. } | Instr::CustomS { .. }),
                "word {i} decoded to {instr}"
            );
        }
        // Spot-check lv operand wiring.
        match decode(p.text[0]).unwrap() {
            Instr::CustomS { slot: CustomSlot::C0, funct3: 4, ops } => {
                assert_eq!(ops.vrd1, V1);
                assert_eq!(ops.rs1, A0);
                assert_eq!(ops.rs2, A1);
            }
            other => panic!("lv decoded to {other}"),
        }
    }

    #[test]
    fn segment_overlap_rejected() {
        let mut a = Asm::with_bases(0x1000, 0x1010);
        a.words("d", &[1]);
        for _ in 0..8 {
            a.nop();
        }
        assert!(matches!(a.assemble(), Err(AsmError::SegmentOverlap { .. })));
    }

    #[test]
    fn entry_defaults_and_overrides() {
        let mut a = Asm::new();
        a.nop();
        let main = a.here("main");
        a.halt();
        a.entry(main);
        let p = a.assemble().unwrap();
        assert_eq!(p.entry, p.sym("main"));
    }
}
