//! Assembler layer — the software-tooling substrate of the paper
//! (its binutils/GCC patch for I′/S′ inline assembly, §2.1).
//!
//! Two front ends share one back end:
//! - [`Asm`] — a typed builder API; all in-repo workloads are authored
//!   through it (the analogue of the paper's inline asm in C).
//! - [`assemble_text`] — a `.s`-style text assembler with the custom
//!   SIMD mnemonics (`c0.lv`, `c2.sort`, …), used by examples and tests.

pub mod builder;
pub mod program;
pub mod text;

pub use builder::{Asm, AsmError, Label};
pub use program::{Program, DEFAULT_DATA_BASE, DEFAULT_TEXT_BASE};
pub use text::{assemble_text, assemble_text_with, ParseError};
