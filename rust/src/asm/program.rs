//! Assembled program image: text + data segments, symbols, entry point.
//!
//! The softcore has a single flat address space shared between data and
//! instructions (modified Harvard — §3 of the paper: IL1 and DL1 both sit
//! in front of the unified LLC), so a `Program` is just two byte ranges
//! plus metadata. The simulator copies both into simulated DRAM.

use std::collections::HashMap;

/// Default load address of the text segment.
pub const DEFAULT_TEXT_BASE: u32 = 0x0000_1000;

/// Default load address of the data segment (1 MiB up, leaving room for
/// large unrolled loops).
pub const DEFAULT_DATA_BASE: u32 = 0x0010_0000;

/// Address the core jumps to on `ecall`-halt convention; execution stops
/// when the core executes `ecall` (the softcore framework's "return to
/// host" — in hardware this raised an interrupt to the ARM host).
#[derive(Debug, Clone)]
pub struct Program {
    /// Load address of the text segment (instruction words).
    pub text_base: u32,
    /// Machine words of the text segment.
    pub text: Vec<u32>,
    /// Load address of the initialised data segment.
    pub data_base: u32,
    /// Initialised data bytes.
    pub data: Vec<u8>,
    /// Symbol table (labels → absolute addresses).
    pub symbols: HashMap<String, u32>,
    /// Entry point (defaults to `text_base`).
    pub entry: u32,
}

impl Program {
    /// Size of the text segment in bytes.
    pub fn text_size(&self) -> usize {
        self.text.len() * 4
    }

    /// Address one past the end of the text segment.
    pub fn text_end(&self) -> u32 {
        self.text_base + self.text_size() as u32
    }

    /// Address one past the end of the data segment.
    pub fn data_end(&self) -> u32 {
        self.data_base + self.data.len() as u32
    }

    /// Look up a symbol, panicking with a useful message if absent
    /// (programs are authored in-repo; a missing symbol is a bug).
    pub fn sym(&self, name: &str) -> u32 {
        *self
            .symbols
            .get(name)
            .unwrap_or_else(|| panic!("program has no symbol '{name}'"))
    }

    /// Disassemble the text segment (for traces and debugging).
    pub fn disassemble(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        // Invert the symbol table for labelling.
        let mut by_addr: HashMap<u32, Vec<&str>> = HashMap::new();
        for (name, &addr) in &self.symbols {
            by_addr.entry(addr).or_default().push(name);
        }
        for (i, &word) in self.text.iter().enumerate() {
            let addr = self.text_base + (i as u32) * 4;
            if let Some(names) = by_addr.get(&addr) {
                for n in names {
                    let _ = writeln!(out, "{n}:");
                }
            }
            match crate::isa::decode(word) {
                Ok(instr) => {
                    let _ = writeln!(out, "  {addr:#010x}: {word:08x}  {instr}");
                }
                Err(_) => {
                    let _ = writeln!(out, "  {addr:#010x}: {word:08x}  .word {word:#010x}");
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Program {
        Program {
            text_base: 0x1000,
            text: vec![0x0015_0513, 0x0000_0073], // addi a0,a0,1; ecall
            data_base: 0x2000,
            data: vec![1, 2, 3],
            symbols: HashMap::from([("start".to_string(), 0x1000u32)]),
            entry: 0x1000,
        }
    }

    #[test]
    fn segment_geometry() {
        let p = tiny();
        assert_eq!(p.text_size(), 8);
        assert_eq!(p.text_end(), 0x1008);
        assert_eq!(p.data_end(), 0x2003);
    }

    #[test]
    fn symbol_lookup() {
        assert_eq!(tiny().sym("start"), 0x1000);
    }

    #[test]
    #[should_panic(expected = "no symbol")]
    fn missing_symbol_panics() {
        tiny().sym("nope");
    }

    #[test]
    fn disassembly_includes_labels_and_mnemonics() {
        let d = tiny().disassemble();
        assert!(d.contains("start:"));
        assert!(d.contains("addi a0, a0, 1"));
        assert!(d.contains("ecall"));
    }
}
