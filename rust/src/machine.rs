//! `Machine`: a fluent builder over the simulator configuration and the
//! single entry point for running [`Workload`]s.
//!
//! Before this module, every driver hand-wired
//! `Core::new(CoreConfig::for_vlen(v), mem_cfg)` + LLC-geometry math +
//! `UnitPool::load` + buffer layout. A machine collapses that into:
//!
//! ```no_run
//! use simdsoftcore::machine::Machine;
//! use simdsoftcore::workloads::{Scenario, Variant};
//!
//! let machine = Machine::paper_default().vlen(512).llc_block(2048);
//! let mut w = simdsoftcore::workloads::lookup("memcpy").unwrap();
//! let report = machine
//!     .run(&mut *w, &Scenario::new(Variant::Vector, 1024 * 1024))
//!     .unwrap();
//! println!("{:.2} GB/s", report.throughput.bytes_per_second() / 1e9);
//! ```
//!
//! [`Machine::run`] performs build → load → init → run → verify →
//! throughput accounting in one call and returns a uniform
//! [`WorkloadReport`]. Simulated DRAM is auto-sized to the workload's
//! buffer footprint (DRAM capacity never affects timing, only bounds
//! checking). Custom units are installed through *factories* so one
//! machine can be reused across the points of a sweep.

use crate::baseline::{PicoConfig, PicoCore};
use crate::core::{Core, CoreConfig, CoreCounters, SimError};
use crate::mem::{CacheGeometry, MemConfig, MemConfigError, MemModel, MemStats, Replacement};
use crate::ref_iss::RefIss;
use crate::simd::CustomUnit;
use crate::workloads::common::{self, Throughput};
use crate::workloads::workload::{
    run_on_budget, run_on_iss, Scenario, Variant, Workload, WorkloadReport,
};

/// Errors from [`Machine::run`] and [`run_on_pico`].
#[derive(Debug)]
pub enum MachineError {
    /// The simulated core faulted or hit its watchdog.
    Sim(SimError),
    /// The scenario asked for a variant the workload does not implement.
    UnsupportedVariant { workload: String, variant: Variant },
    /// A required custom-unit slot is empty on this machine.
    MissingUnit { workload: String, slot: usize },
    /// The configured memory system is invalid (zero ways/MSHRs, L1
    /// block larger than the LLC block, …) — reported instead of
    /// panicking mid-build.
    Config(MemConfigError),
}

impl std::fmt::Display for MachineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MachineError::Sim(e) => write!(f, "simulation failed: {e}"),
            MachineError::UnsupportedVariant { workload, variant } => {
                write!(f, "workload '{workload}' has no {variant} variant")
            }
            MachineError::MissingUnit { workload, slot } => {
                write!(f, "workload '{workload}' needs a unit in slot c{slot}, which is empty")
            }
            MachineError::Config(e) => write!(f, "invalid machine configuration: {e}"),
        }
    }
}

impl std::error::Error for MachineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MachineError::Sim(e) => Some(e),
            MachineError::Config(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SimError> for MachineError {
    fn from(e: SimError) -> Self {
        MachineError::Sim(e)
    }
}

impl From<MemConfigError> for MachineError {
    fn from(e: MemConfigError) -> Self {
        MachineError::Config(e)
    }
}

/// Builds a custom unit for a machine; receives the lane count so one
/// factory serves every vector width in a sweep.
pub type UnitFactory = Box<dyn Fn(usize) -> Box<dyn CustomUnit>>;

/// Which execution backend [`Machine::run`] drives.
///
/// `Timed` is the cycle-level [`Core`] (the default — every performance
/// number comes from it). `RefIss` is the architectural-only reference
/// ISS ([`crate::ref_iss::RefIss`]): same registers/memory/instret,
/// no timing state at all, an order of magnitude faster — the
/// functional backend the differential suites compare the core against
/// (DESIGN.md §9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    #[default]
    Timed,
    RefIss,
}

/// A reusable simulator configuration: core timing + memory geometry +
/// custom-unit loadout. `build()` materialises a fresh [`Core`];
/// `run()` executes a workload scenario end to end.
pub struct Machine {
    core: CoreConfig,
    mem: MemConfig,
    backend: Backend,
    /// Set by an explicit `fmax_mhz()` call; survives later `vlen()`
    /// changes (which would otherwise reset the clock to the
    /// width-dependent default).
    fmax_override: Option<f64>,
    units: Vec<(usize, UnitFactory)>,
    cleared: Vec<usize>,
}

impl Machine {
    /// The paper's Table-1 configuration (VLEN = 256, 150 MHz,
    /// 16384-bit LLC blocks, standard unit pool).
    pub fn paper_default() -> Self {
        Self::for_vlen(256)
    }

    /// Table-1-shaped machine at a given vector width.
    pub fn for_vlen(vlen_bits: usize) -> Self {
        Self {
            core: CoreConfig::for_vlen(vlen_bits),
            mem: MemConfig::for_vlen(vlen_bits),
            backend: Backend::default(),
            fmax_override: None,
            units: Vec::new(),
            cleared: Vec::new(),
        }
    }

    /// Change the vector width, preserving every override already
    /// applied in the chain: LLC block/ways (and thus capacity), DRAM
    /// settings, replacement policy, and an explicit `fmax_mhz`. Only
    /// the width-derived parts (L1 geometry, default clock) re-derive.
    pub fn vlen(mut self, vlen_bits: usize) -> Self {
        let llc = self.mem.llc;
        let capacity = llc.capacity_bytes();
        let dram = self.mem.dram;
        let replacement = self.mem.replacement;
        let (dl1_mshrs, llc_mshrs) = (self.mem.dl1_mshrs, self.mem.llc_mshrs);
        let prefetch_depth = self.mem.prefetch_depth;
        let model = self.mem.model;
        let issue_width = self.core.issue_width;
        self.core = CoreConfig::for_vlen(vlen_bits);
        self.core.issue_width = issue_width;
        if let Some(f) = self.fmax_override {
            self.core.fmax_mhz = f;
        }
        self.mem = MemConfig::for_vlen(vlen_bits);
        self.mem.dram = dram;
        self.mem.replacement = replacement;
        self.mem.dl1_mshrs = dl1_mshrs;
        self.mem.llc_mshrs = llc_mshrs;
        self.mem.prefetch_depth = prefetch_depth;
        self.mem.model = model;
        self.mem.llc = CacheGeometry {
            sets: capacity / (llc.block_bits / 8) / llc.ways,
            ways: llc.ways,
            block_bits: llc.block_bits,
        };
        self
    }

    /// LLC block size in bits, keeping the LLC capacity constant (the
    /// Fig. 3 left sweep: set count scales inversely with block size).
    pub fn llc_block(mut self, block_bits: usize) -> Self {
        let capacity = self.mem.llc.capacity_bytes();
        self.mem.llc.block_bits = block_bits;
        self.mem.llc.sets = capacity / (block_bits / 8) / self.mem.llc.ways;
        self
    }

    /// LLC associativity, keeping the LLC capacity constant. A zero way
    /// count is carried through so `validate()`/`run()` report it as a
    /// configuration error rather than dividing by zero here.
    pub fn llc_ways(mut self, ways: usize) -> Self {
        let capacity = self.mem.llc.capacity_bytes();
        self.mem.llc.ways = ways;
        if ways > 0 {
            self.mem.llc.sets = capacity / self.mem.llc.block_bytes() / ways;
        }
        self
    }

    /// Simulated DRAM capacity in bytes ([`Machine::run`] grows this
    /// automatically to fit a workload's buffers).
    pub fn dram_bytes(mut self, bytes: usize) -> Self {
        self.mem.dram.size_bytes = bytes;
        self
    }

    /// Clock used for cycles → seconds conversion (overrides the
    /// width-dependent default, also across later `vlen()` calls).
    pub fn fmax_mhz(mut self, mhz: f64) -> Self {
        self.core.fmax_mhz = mhz;
        self.fmax_override = Some(mhz);
        self
    }

    /// In-order issue width of the core pipeline (survives later
    /// `vlen()` calls). `1` (the default) is the paper's single-issue
    /// model, cycle-for-cycle identical to the seed; `2`/`4` enable the
    /// superscalar issue-group model — a timing-only change, the
    /// architectural results are identical at every width (DESIGN.md
    /// §5). The library accepts any width (`0` behaves as `1`, other
    /// values model an N-wide group); the sweep surface
    /// (`MachinePoint::validate`) restricts the design space to
    /// {1, 2, 4}.
    pub fn issue_width(mut self, n: usize) -> Self {
        self.core.issue_width = n;
        self
    }

    /// Cache replacement policy at DL1 and the LLC.
    pub fn replacement(mut self, r: Replacement) -> Self {
        self.mem.replacement = r;
        self
    }

    /// §3.1.4 double-rate interconnect on/off.
    pub fn double_rate(mut self, on: bool) -> Self {
        self.mem.dram.double_rate = on;
        self
    }

    /// Cycles to open a DRAM burst.
    pub fn burst_setup(mut self, cycles: u64) -> Self {
        self.mem.dram.burst_setup_cycles = cycles;
        self
    }

    /// MSHR count at DL1 *and* the LLC. `1` (the default) is the paper's
    /// fully-blocking port; `>= 2` makes the hierarchy non-blocking —
    /// hits proceed under misses and up to `n` misses overlap.
    pub fn mshrs(mut self, n: usize) -> Self {
        self.mem.dl1_mshrs = n;
        self.mem.llc_mshrs = n;
        self
    }

    /// Next-N-line stream prefetch depth on the LLC fill path (0 = off;
    /// needs `mshrs >= 2` to have a free fill MSHR to ride on).
    pub fn prefetch_depth(mut self, n: usize) -> Self {
        self.mem.prefetch_depth = n;
        self
    }

    /// Independent DRAM channels (1 = the paper's single AXI port).
    pub fn dram_channels(mut self, n: usize) -> Self {
        self.mem.dram.channels = n;
        self
    }

    /// Swap the cache hierarchy for the flat single-cycle magic-memory
    /// oracle (differential testing; identical architectural results).
    pub fn magic_memory(mut self, on: bool) -> Self {
        self.mem.model = if on { MemModel::Flat } else { MemModel::Cached };
        self
    }

    /// Select the execution backend `run()` drives (default:
    /// [`Backend::Timed`]). `Backend::RefIss` runs workloads on the
    /// reference ISS — same architectural results, no cycle accounting
    /// (the report's `cycles` equals `instret`).
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// Validate the configured memory system without building a core.
    pub fn validate(&self) -> Result<(), MemConfigError> {
        self.mem.validate()
    }

    /// Load a custom unit into slot `c0..c3` (replacing the standard
    /// unit there). The factory receives the machine's lane count.
    pub fn with_unit(
        mut self,
        slot: usize,
        make: impl Fn(usize) -> Box<dyn CustomUnit> + 'static,
    ) -> Self {
        assert!(slot < 4, "custom slots are c0..c3");
        self.units.push((slot, Box::new(make)));
        self
    }

    /// Leave slot `c0..c3` empty (model a fabric with the unit not
    /// loaded; running a workload that requires it then errors).
    pub fn without_unit(mut self, slot: usize) -> Self {
        assert!(slot < 4, "custom slots are c0..c3");
        self.cleared.push(slot);
        self
    }

    pub fn core_config(&self) -> &CoreConfig {
        &self.core
    }

    pub fn mem_config(&self) -> &MemConfig {
        &self.mem
    }

    /// Materialise a ready core: standard unit pool for the configured
    /// width, minus `without_unit` slots, plus `with_unit` overrides.
    pub fn build(&self) -> Core {
        self.build_with_mem(self.mem)
    }

    fn build_with_mem(&self, mem: MemConfig) -> Core {
        let mut core = Core::new(self.core, mem);
        for &slot in &self.cleared {
            core.pool.unload(slot);
        }
        for (slot, make) in &self.units {
            core.pool.load(*slot, make(self.core.lanes()));
        }
        core
    }

    /// Materialise the reference ISS with this machine's vector width,
    /// clock (for report accounting only), unit loadout and memory
    /// capacity. The cache geometry is irrelevant to the ISS — memory
    /// is a flat image of the DRAM size.
    pub fn build_iss(&self) -> RefIss {
        self.build_iss_with_bytes(self.mem.dram.size_bytes)
    }

    fn build_iss_with_bytes(&self, mem_bytes: usize) -> RefIss {
        let mut iss = RefIss::new(self.core.vlen_bits, mem_bytes);
        iss.fmax_mhz = self.core.fmax_mhz;
        for &slot in &self.cleared {
            iss.pool.unload(slot);
        }
        for (slot, make) in &self.units {
            iss.pool.load(*slot, make(self.core.lanes()));
        }
        iss
    }

    /// Run one workload scenario end to end on a fresh core and report
    /// uniform throughput/verification results. The scenario's
    /// `vlen_bits` is taken from this machine's configuration.
    pub fn run(&self, w: &mut dyn Workload, sc: &Scenario) -> Result<WorkloadReport, MachineError> {
        self.run_budget(w, sc, crate::workloads::common::MAX_INSTRS)
    }

    /// [`Machine::run`] with an explicit retired-instruction budget
    /// (the sweep service's per-point watchdog; see
    /// [`crate::workloads::workload::run_on_budget`]).
    pub fn run_budget(
        &self,
        w: &mut dyn Workload,
        sc: &Scenario,
        max_instrs: u64,
    ) -> Result<WorkloadReport, MachineError> {
        if !w.variants().contains(&sc.variant) {
            return Err(MachineError::UnsupportedVariant {
                workload: w.name().to_string(),
                variant: sc.variant,
            });
        }
        let sc = Scenario { vlen_bits: self.core.vlen_bits, ..*sc };
        let (buffers, bytes_each) = w.buffers(&sc);
        let mut mem = self.mem;
        mem.dram.size_bytes = mem.dram.size_bytes.max(dram_needed(buffers, bytes_each));
        // Reject invalid configurations up front (a sweep point like
        // `--llc-ways 0` becomes an error row, not a thread panic).
        mem.validate()?;
        match self.backend {
            Backend::Timed => {
                let mut core = self.build_with_mem(mem);
                for &slot in w.required_units(sc.variant) {
                    if core.pool.get(slot).is_none() {
                        return Err(MachineError::MissingUnit {
                            workload: w.name().to_string(),
                            slot,
                        });
                    }
                }
                Ok(run_on_budget(w, &mut core, &sc, max_instrs)?)
            }
            Backend::RefIss => {
                let mut iss = self.build_iss_with_bytes(mem.dram.size_bytes);
                for &slot in w.required_units(sc.variant) {
                    if iss.pool.get(slot).is_none() {
                        return Err(MachineError::MissingUnit {
                            workload: w.name().to_string(),
                            slot,
                        });
                    }
                }
                Ok(run_on_iss(w, &mut iss, &sc)?)
            }
        }
    }
}

/// DRAM capacity covering `buffers` × `bytes_each` under the workload
/// buffer layout, rounded to a 2 MiB multiple (covers every LLC block
/// size).
pub fn dram_needed(buffers: usize, bytes_each: usize) -> usize {
    let need = common::BUF_BASE as usize + buffers * (bytes_each + 128 * 1024);
    need.div_ceil(2 * 1024 * 1024) * 2 * 1024 * 1024
}

/// Run a scalar workload scenario on the PicoRV32 baseline model,
/// reusing the workload's program and input image. The Pico model does
/// not implement [`crate::arch::ArchState`] (it has no vector state and
/// keeps its memory private), so `Workload::verify` cannot run against
/// it and `verified` is `None`.
pub fn run_on_pico(
    w: &mut dyn Workload,
    cfg: PicoConfig,
    sc: &Scenario,
) -> Result<WorkloadReport, MachineError> {
    if sc.variant != Variant::Scalar {
        return Err(MachineError::UnsupportedVariant {
            workload: w.name().to_string(),
            variant: sc.variant,
        });
    }
    let sc = Scenario { vlen_bits: 256, ..*sc };
    let (buffers, bytes_each) = w.buffers(&sc);
    let cfg =
        PicoConfig { dram_size: cfg.dram_size.max(dram_needed(buffers, bytes_each)), ..cfg };
    let prog = w.build(&sc);
    let mut pico = PicoCore::new(cfg);
    pico.load(&prog)?;
    for (addr, bytes) in w.init_image() {
        pico.host_write(*addr, bytes);
    }
    pico.run(common::MAX_INSTRS)?;
    let throughput = Throughput {
        cycles: pico.cycle(),
        instret: pico.instret(),
        bytes: w.bytes_moved(&sc),
        fmax_mhz: cfg.fmax_mhz,
    };
    Ok(WorkloadReport {
        workload: w.name().to_string(),
        variant: sc.variant,
        size: sc.size,
        elems: w.elems(&sc),
        throughput,
        verified: None,
        verify_error: None,
        mem: MemStats::default(),
        counters: CoreCounters::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::memcpy::Memcpy;
    use crate::workloads::prefix::Prefix;

    #[test]
    fn builder_reproduces_paper_config() {
        let m = Machine::paper_default();
        assert_eq!(m.core_config().vlen_bits, 256);
        assert_eq!(m.mem_config().llc.block_bits, 16384);
        let core = m.build();
        assert_eq!(core.cfg.vlen_bits, 256);
        assert!(core.pool.get(0).is_some() && core.pool.get(3).is_some());
    }

    #[test]
    fn llc_block_keeps_capacity() {
        let m = Machine::paper_default().llc_block(2048);
        let llc = m.mem_config().llc;
        assert_eq!(llc.block_bits, 2048);
        assert_eq!(llc.capacity_bytes(), 256 * 1024);
    }

    #[test]
    fn vlen_preserves_overrides() {
        let m = Machine::paper_default().llc_block(4096).dram_bytes(128 * 1024 * 1024).vlen(512);
        assert_eq!(m.core_config().vlen_bits, 512);
        assert_eq!(m.mem_config().llc.block_bits, 4096);
        assert_eq!(m.mem_config().llc.capacity_bytes(), 256 * 1024);
        assert_eq!(m.mem_config().dram.size_bytes, 128 * 1024 * 1024);
        assert_eq!(m.mem_config().dl1.block_bits, 512, "L1 blocks track VLEN");
    }

    #[test]
    fn issue_width_survives_vlen_and_defaults_to_one() {
        let m = Machine::paper_default();
        assert_eq!(m.core_config().issue_width, 1);
        let m = Machine::paper_default().issue_width(2).vlen(512);
        assert_eq!(m.core_config().issue_width, 2);
        assert_eq!(m.build().cfg.issue_width, 2);
    }

    #[test]
    fn vlen_is_order_independent_for_ways_and_fmax() {
        // Regression: vlen() used to silently reset llc_ways and an
        // explicit fmax override to the width defaults.
        let m = Machine::paper_default().llc_ways(1).fmax_mhz(100.0).vlen(512);
        assert_eq!(m.mem_config().llc.ways, 1);
        assert_eq!(m.mem_config().llc.capacity_bytes(), 256 * 1024);
        assert_eq!(m.core_config().fmax_mhz, 100.0);
        // Without an explicit override the clock re-derives from width.
        let m = Machine::paper_default().vlen(1024);
        assert_eq!(m.core_config().fmax_mhz, 125.0);
    }

    #[test]
    fn run_executes_and_verifies_a_workload() {
        let m = Machine::paper_default();
        let mut w = Memcpy::new();
        let r = m.run(&mut w, &Scenario::new(Variant::Vector, 64 * 1024)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.elems, 16 * 1024);
        assert!(r.throughput.bytes_per_cycle() > 2.5);
    }

    #[test]
    fn run_rejects_missing_units() {
        let m = Machine::paper_default().without_unit(3);
        let mut w = Prefix::new();
        let err = m.run(&mut w, &Scenario::new(Variant::Vector, 1024)).unwrap_err();
        assert!(matches!(err, MachineError::MissingUnit { slot: 3, .. }), "{err}");
        // The scalar variant does not need c3 and still runs.
        let r = m.run(&mut w, &Scenario::new(Variant::Scalar, 1024)).unwrap();
        assert_eq!(r.verified, Some(true));
    }

    #[test]
    fn run_rejects_unknown_variant() {
        let m = Machine::paper_default();
        let mut w = crate::workloads::cpubench::CpuBench::dhrystone();
        let err = m.run(&mut w, &Scenario::new(Variant::Vector, 10)).unwrap_err();
        assert!(matches!(err, MachineError::UnsupportedVariant { .. }), "{err}");
    }

    #[test]
    fn invalid_configs_error_instead_of_panicking() {
        let mut w = Memcpy::new();
        let sc = Scenario::new(Variant::Vector, 16 * 1024);

        let err = Machine::paper_default().llc_ways(0).run(&mut w, &sc).unwrap_err();
        assert!(matches!(err, MachineError::Config(MemConfigError::ZeroWays { .. })), "{err}");

        let err = Machine::paper_default().mshrs(0).run(&mut w, &sc).unwrap_err();
        assert!(matches!(err, MachineError::Config(MemConfigError::ZeroMshrs { .. })), "{err}");

        // L1 block (VLEN) larger than the LLC block.
        let err = Machine::for_vlen(512).llc_block(256).run(&mut w, &sc).unwrap_err();
        assert!(
            matches!(err, MachineError::Config(MemConfigError::LlcBlockTooSmall { .. })),
            "{err}"
        );
        assert!(Machine::paper_default().validate().is_ok());
    }

    #[test]
    fn ref_iss_backend_verifies_workloads_and_matches_instret() {
        let sc = Scenario::new(Variant::Vector, 64 * 1024);
        let timed = Machine::paper_default().run(&mut Memcpy::new(), &sc).unwrap();
        let iss = Machine::paper_default()
            .backend(Backend::RefIss)
            .run(&mut Memcpy::new(), &sc)
            .unwrap();
        assert_eq!(iss.verified, Some(true));
        assert_eq!(
            iss.throughput.instret, timed.throughput.instret,
            "instruction count must not depend on the backend"
        );
        assert_eq!(iss.throughput.cycles, iss.throughput.instret, "ISS reports nominal CPI 1");
        assert_eq!(iss.mem.dram.bursts(), 0, "the ISS has no memory hierarchy");
    }

    #[test]
    fn ref_iss_backend_rejects_missing_units() {
        let m = Machine::paper_default().backend(Backend::RefIss).without_unit(3);
        let err = m.run(&mut Prefix::new(), &Scenario::new(Variant::Vector, 1024)).unwrap_err();
        assert!(matches!(err, MachineError::MissingUnit { slot: 3, .. }), "{err}");
    }

    #[test]
    fn magic_memory_machine_verifies_workloads() {
        let m = Machine::paper_default().magic_memory(true);
        let mut w = Memcpy::new();
        let r = m.run(&mut w, &Scenario::new(Variant::Vector, 64 * 1024)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.mem.dram.bursts(), 0, "flat model never bursts");
    }

    #[test]
    fn nonblocking_axes_survive_vlen_and_speed_up_memcpy() {
        let m = Machine::paper_default().mshrs(4).prefetch_depth(4).dram_channels(2).vlen(512);
        assert_eq!(m.mem_config().dl1_mshrs, 4);
        assert_eq!(m.mem_config().llc_mshrs, 4);
        assert_eq!(m.mem_config().prefetch_depth, 4);
        assert_eq!(m.mem_config().dram.channels, 2);

        let sc = Scenario::new(Variant::Vector, 256 * 1024);
        let blocking = Machine::paper_default().run(&mut Memcpy::new(), &sc).unwrap();
        let nb = Machine::paper_default()
            .mshrs(4)
            .prefetch_depth(4)
            .run(&mut Memcpy::new(), &sc)
            .unwrap();
        assert_eq!(nb.verified, Some(true));
        assert!(
            nb.throughput.cycles < blocking.throughput.cycles,
            "prefetch + MSHRs must speed up streaming memcpy ({} vs {})",
            nb.throughput.cycles,
            blocking.throughput.cycles
        );
        assert!(nb.mem.llc.prefetches > 0, "prefetcher actually ran");
    }

    #[test]
    fn dram_auto_sizes_to_workload() {
        // 64 MiB of default DRAM cannot hold a 32 MiB copy (two buffers
        // above BUF_BASE); run() must grow it rather than fault.
        let m = Machine::paper_default();
        let mut w = Memcpy::new();
        let r = m.run(&mut w, &Scenario::new(Variant::Vector, 32 * 1024 * 1024)).unwrap();
        assert_eq!(r.verified, Some(true));
    }

    #[test]
    fn with_unit_overrides_a_slot() {
        use crate::simd::{UnitError, UnitInputs, UnitOutput};
        struct Nop;
        impl CustomUnit for Nop {
            fn name(&self) -> &'static str {
                "nop"
            }
            fn describe(&self, _f3: u8) -> Option<&'static str> {
                Some("no-op")
            }
            fn execute(&mut self, _inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
                Ok(UnitOutput::nothing(1))
            }
        }
        let m = Machine::paper_default().with_unit(2, |_lanes| Box::new(Nop));
        let core = m.build();
        assert_eq!(core.pool.get(2).unwrap().name(), "nop");
    }

    #[test]
    fn pico_harness_runs_scalar_workloads() {
        let mut w = crate::workloads::stream::Stream::new(crate::workloads::stream::Kernel::Copy);
        let r = run_on_pico(&mut w, PicoConfig::default(), &Scenario::new(Variant::Scalar, 1024))
            .unwrap();
        assert_eq!(r.verified, None);
        assert!(r.throughput.cycles > 0);
        // Pico is flat and slow: well under 1 B/cycle.
        assert!(r.throughput.bytes_per_cycle() < 1.0);
        let err =
            run_on_pico(&mut w, PicoConfig::default(), &Scenario::new(Variant::Vector, 1024))
                .unwrap_err();
        assert!(matches!(err, MachineError::UnsupportedVariant { .. }));
    }
}
