//! riscv-dv-style deterministic random program generation + the
//! differential fuzz campaign driver.
//!
//! [`generate`] builds a random — but **reproducible** (seeded
//! [`Xoshiro256`]) and **guaranteed-terminating** — program over
//! [`crate::asm::Asm`], mixing the op classes of the ISA under
//! configurable [`OpWeights`]: scalar ALU, control flow, mul/div,
//! loads/stores, and the paper's I′/S′ custom SIMD instructions
//! (including the stateful `c3.prefix`). Termination is structural, not
//! statistical:
//!
//! - conditional branches and `jal` only ever target *forward* labels a
//!   few ops ahead;
//! - `jalr` appears as an `auipc`+`jalr` pair whose target is the next
//!   instruction (exact forward target, exercising the indirect-jump
//!   datapath);
//! - backward branches exist only inside a self-contained counted-loop
//!   construct with a dedicated counter register that nothing else
//!   writes, and forward-branch targets can never land inside it;
//! - every program ends in the halting `ecall`.
//!
//! Memory traffic stays inside a 4 KiB random-initialised data window
//! whose base lives in a reserved register, so no generated program can
//! fault — any fault, watchdog or architectural divergence observed by
//! [`run_case`] is therefore a real bug (in the timed core, the ISS, or
//! this generator) and is reported as a [`FuzzFailure`] carrying the
//! full assembly listing and the lockstep divergence report.
//!
//! The one sanctioned exception is the opt-in **wild-jump** op class
//! (`OpWeights::wildjump`, 0 in every preset): it emits `jalr`s to
//! out-of-DRAM or non-word-aligned targets, which must end the program
//! in a fetch fault reported *identically* by both backends (the
//! simulator used to panic instead). With the class enabled,
//! [`run_case`] accepts an identical fetch-fault outcome as agreement;
//! data faults and watchdogs stay failures.
//!
//! A second opt-in class, **smc** (`OpWeights::smc`, 0 in every
//! preset), emits self-modifying stores: an encoded ALU instruction is
//! written over the program's own text — both over a word execution has
//! not yet reached and over one it has already executed — and the
//! patched slot is then executed. Both backends predecode text at load,
//! so the class exercises their decode/block-cache invalidation paths;
//! a stale cache diverges in lockstep. SMC programs still terminate
//! normally, so `run_case` needs no special handling for the class.
//!
//! [`run_campaign`] crosses seeds with machine-configuration points
//! ([`MachinePoint`] — the same axis registry every sweep surface uses,
//! so the `fuzz` CLI can sweep VLEN/MSHRs/prefetch/channels) and runs
//! the cases on a bounded worker pool.

use crate::asm::{Asm, Label, Program};
use crate::coordinator::sweep::{self, MachinePoint, Parallelism};
use crate::cosim::{run_lockstep, LockstepOutcome};
use crate::isa::reg::*;
use crate::isa::VReg;
use crate::ref_iss::RefIss;
use crate::util::Xoshiro256;

/// Bytes of the random-initialised data window all loads/stores hit.
pub const DATA_BYTES: usize = 4096;

/// Simulated DRAM per fuzz case (text + data + untouched stack top).
pub const FUZZ_DRAM_BYTES: usize = 2 * 1024 * 1024;

/// Registers the generator reserves (never in the operand pools):
/// `s11` = data-window base, `s10` = loop counter, `t6` = scratch for
/// vector-memory offsets and the `auipc`+`jalr` pair; `sp`/`gp`/`tp`/
/// `ra` stay untouched entirely.
const DEST_POOL: [crate::isa::Reg; 24] = [
    T0, T1, T2, S0, S1, A0, A1, A2, A3, A4, A5, A6, A7, S2, S3, S4, S5, S6, S7, S8, S9, T3, T4,
    T5,
];

/// Relative frequencies of the generator's op classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpWeights {
    pub alu: u32,
    pub branch: u32,
    pub muldiv: u32,
    pub mem: u32,
    pub vec: u32,
    pub vecmem: u32,
    /// Wild jumps (`jalr` to out-of-DRAM or misaligned targets). 0 in
    /// every preset: a wild jump deterministically ends the program in
    /// a fetch fault, so the class is opt-in (`--weights wildjump=N`)
    /// and [`run_case`] then accepts identical fetch faults.
    pub wildjump: u32,
    /// Self-modifying stores (opt-in, `--weights smc=N`): patch an
    /// encoded instruction over the program's own text — both a word
    /// the program has *not yet* reached and one it has *already*
    /// executed (and therefore predecoded) — then execute the patched
    /// word. Any stale decode or block cache in either backend shows up
    /// as an architectural lockstep divergence. 0 in every preset
    /// because SMC deliberately defeats the decode caches the normal
    /// campaign assumes are transparent.
    pub smc: u32,
}

impl OpWeights {
    /// Everything in proportion (the default preset).
    pub fn balanced() -> Self {
        Self { alu: 6, branch: 2, muldiv: 1, mem: 3, vec: 2, vecmem: 2, wildjump: 0, smc: 0 }
    }

    /// RV32IM only — no custom SIMD instructions at all.
    pub fn scalar() -> Self {
        Self { vec: 0, vecmem: 0, muldiv: 2, mem: 4, ..Self::balanced() }
    }

    /// Custom-unit heavy (I′/S′ mixes dominate).
    pub fn vector() -> Self {
        Self { alu: 3, branch: 1, muldiv: 1, mem: 1, vec: 5, vecmem: 4, wildjump: 0, smc: 0 }
    }

    /// The balanced mix plus wild jumps — every case ends in either the
    /// halting `ecall` or a fetch fault both backends must report
    /// identically.
    pub fn wild() -> Self {
        Self { wildjump: 2, ..Self::balanced() }
    }

    /// The balanced mix plus self-modifying stores — every decode /
    /// block cache in both backends must invalidate on stores over
    /// text, or lockstep diverges.
    pub fn smc() -> Self {
        Self { smc: 2, ..Self::balanced() }
    }

    pub fn total(&self) -> u32 {
        self.alu
            + self.branch
            + self.muldiv
            + self.mem
            + self.vec
            + self.vecmem
            + self.wildjump
            + self.smc
    }

    /// Parse the CLI spelling
    /// `alu=4,branch=1,muldiv=1,mem=2,vec=2,vecmem=2,wildjump=0`
    /// (unnamed classes keep the balanced default's value).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut w = Self::balanced();
        for part in spec.split(',') {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("--weights expects class=N pairs, got '{part}'"))?;
            let val: u32 = val
                .trim()
                .parse()
                .map_err(|_| format!("bad weight value '{val}' for class '{key}'"))?;
            match key.trim() {
                "alu" => w.alu = val,
                "branch" => w.branch = val,
                "muldiv" => w.muldiv = val,
                "mem" => w.mem = val,
                "vec" => w.vec = val,
                "vecmem" => w.vecmem = val,
                "wildjump" => w.wildjump = val,
                "smc" => w.smc = val,
                other => {
                    return Err(format!(
                        "unknown op class '{other}' (classes: alu, branch, muldiv, mem, vec, \
                         vecmem, wildjump, smc)"
                    ))
                }
            }
        }
        if w.total() == 0 {
            return Err("at least one op-class weight must be positive".into());
        }
        Ok(w)
    }

    /// The preset rotation used when no explicit `--weights` is given:
    /// seeds cycle through balanced / scalar-only / vector-heavy mixes
    /// so one campaign covers all three.
    pub fn preset_for_seed(seed: u64) -> (&'static str, Self) {
        match seed % 3 {
            0 => ("balanced", Self::balanced()),
            1 => ("scalar", Self::scalar()),
            _ => ("vector", Self::vector()),
        }
    }
}

#[derive(Clone, Copy)]
enum OpClass {
    Alu,
    Branch,
    MulDiv,
    Mem,
    Vec,
    VecMem,
    WildJump,
    Smc,
}

fn pick_class(rng: &mut Xoshiro256, w: &OpWeights) -> OpClass {
    let mut x = rng.below(w.total());
    for (class, wt) in [
        (OpClass::Alu, w.alu),
        (OpClass::Branch, w.branch),
        (OpClass::MulDiv, w.muldiv),
        (OpClass::Mem, w.mem),
        (OpClass::Vec, w.vec),
        (OpClass::VecMem, w.vecmem),
        (OpClass::WildJump, w.wildjump),
        (OpClass::Smc, w.smc),
    ] {
        if x < wt {
            return class;
        }
        x -= wt;
    }
    unreachable!("weights sum to total")
}

fn dest(rng: &mut Xoshiro256) -> crate::isa::Reg {
    DEST_POOL[rng.below(DEST_POOL.len() as u32) as usize]
}

/// Source pool = dest pool + `zero` + the data base (read-only).
fn src(rng: &mut Xoshiro256) -> crate::isa::Reg {
    match rng.below(DEST_POOL.len() as u32 + 2) {
        n if (n as usize) < DEST_POOL.len() => DEST_POOL[n as usize],
        n if n as usize == DEST_POOL.len() => ZERO,
        _ => S11,
    }
}

fn vdest(rng: &mut Xoshiro256) -> VReg {
    VReg(1 + rng.below(7) as u8)
}

fn vsrc(rng: &mut Xoshiro256) -> VReg {
    VReg(rng.below(8) as u8)
}

fn imm12(rng: &mut Xoshiro256) -> i32 {
    rng.below(4096) as i32 - 2048
}

fn emit_alu(a: &mut Asm, rng: &mut Xoshiro256) {
    let (rd, r1, r2) = (dest(rng), src(rng), src(rng));
    match rng.below(22) {
        0 => a.addi(rd, r1, imm12(rng)),
        1 => a.slti(rd, r1, imm12(rng)),
        2 => a.sltiu(rd, r1, imm12(rng)),
        3 => a.xori(rd, r1, imm12(rng)),
        4 => a.ori(rd, r1, imm12(rng)),
        5 => a.andi(rd, r1, imm12(rng)),
        6 => a.slli(rd, r1, rng.below(32) as u8),
        7 => a.srli(rd, r1, rng.below(32) as u8),
        8 => a.srai(rd, r1, rng.below(32) as u8),
        9 => a.lui(rd, (rng.next_u32() & 0xffff_f000) as i32),
        10 => a.auipc(rd, (rng.next_u32() & 0xffff_f000) as i32),
        11 => a.add(rd, r1, r2),
        12 => a.sub(rd, r1, r2),
        13 => a.sll(rd, r1, r2),
        14 => a.slt(rd, r1, r2),
        15 => a.sltu(rd, r1, r2),
        16 => a.xor(rd, r1, r2),
        17 => a.srl(rd, r1, r2),
        18 => a.sra(rd, r1, r2),
        19 => a.or(rd, r1, r2),
        20 => a.and(rd, r1, r2),
        _ => {
            // Counter CSR reads; cycle/time values are timing-dependent
            // and get synced by the lockstep driver.
            if rng.below(4) == 0 {
                a.rdcycle(rd);
            } else {
                a.rdinstret(rd);
            }
        }
    }
}

fn emit_muldiv(a: &mut Asm, rng: &mut Xoshiro256) {
    let (rd, r1, r2) = (dest(rng), src(rng), src(rng));
    match rng.below(8) {
        0 => a.mul(rd, r1, r2),
        1 => a.mulh(rd, r1, r2),
        2 => a.mulhsu(rd, r1, r2),
        3 => a.mulhu(rd, r1, r2),
        4 => a.div(rd, r1, r2),
        5 => a.divu(rd, r1, r2),
        6 => a.rem(rd, r1, r2),
        _ => a.remu(rd, r1, r2),
    }
}

fn emit_mem(a: &mut Asm, rng: &mut Xoshiro256) {
    // Always based at the data window; offsets leave room for the
    // widest (4-byte) scalar access. Unaligned accesses are allowed —
    // the hierarchy must split them identically to the flat reference.
    let off = rng.below((DATA_BYTES - 4) as u32 + 1) as i32;
    match rng.below(8) {
        0 => a.lb(dest(rng), off, S11),
        1 => a.lh(dest(rng), off, S11),
        2 => a.lw(dest(rng), off, S11),
        3 => a.lbu(dest(rng), off, S11),
        4 => a.lhu(dest(rng), off, S11),
        5 => a.sb(src(rng), off, S11),
        6 => a.sh(src(rng), off, S11),
        _ => a.sw(src(rng), off, S11),
    }
}

fn emit_vec(a: &mut Asm, rng: &mut Xoshiro256) {
    match rng.below(8) {
        0 => a.sort8(vdest(rng), vsrc(rng)),
        1 => a.merge(vdest(rng), vdest(rng), vsrc(rng), vsrc(rng)),
        2 => a.vadd(vdest(rng), vsrc(rng), vsrc(rng)),
        3 => a.vscale(vdest(rng), vsrc(rng), src(rng)),
        4 => a.vfilt(dest(rng), vdest(rng), vsrc(rng), src(rng)),
        5 => a.prefix(vdest(rng), vsrc(rng)),
        6 => a.prefix_reset(),
        _ => a.prefix_carry(dest(rng)),
    }
}

/// Emit a wild jump: a `jalr` whose target deterministically faults at
/// the next fetch — either outside DRAM ([`SimError::FetchFault`]) or
/// non-word-aligned ([`SimError::FetchMisaligned`]). Everything after
/// it is dead code unless a forward branch skipped the jump.
fn emit_wildjump(a: &mut Asm, rng: &mut Xoshiro256) {
    match rng.below(4) {
        0 => {
            // Far beyond any fuzz DRAM (aligned): a fetch fault.
            let target = 0xF000_0000u32 + 16 * rng.below(1024);
            a.li(T6, target as i64);
            a.jalr(dest(rng), T6, 0);
        }
        1 => {
            // Just past the end of DRAM (aligned).
            a.li(T6, FUZZ_DRAM_BYTES as i64);
            a.jalr(dest(rng), T6, 0);
        }
        2 => {
            // Misaligned in-text target: pc + 6 (bit 1 set).
            a.auipc(T6, 0);
            a.jalr(dest(rng), T6, 6);
        }
        _ => {
            // Odd offset: jalr clears bit 0, leaving pc + 6 — the bit-0
            // masking path followed by the misaligned-fetch fault.
            a.auipc(T6, 0);
            a.jalr(dest(rng), T6, 7);
        }
    }
}

/// Encode a benign pool-register ALU instruction to use as an SMC
/// patch word. Its architectural effect differs from the word it
/// replaces, so a backend that keeps executing the stale cached decode
/// diverges in lockstep instead of silently agreeing.
fn smc_patch_word(rng: &mut Xoshiro256) -> u32 {
    use crate::isa::Instr;
    let (rd, r1, r2) = (dest(rng), src(rng), src(rng));
    let i = match rng.below(4) {
        0 => Instr::Addi { rd, rs1: r1, imm: imm12(rng) },
        1 => Instr::Xor { rd, rs1: r1, rs2: r2 },
        2 => Instr::Add { rd, rs1: r1, rs2: r2 },
        _ => Instr::Sub { rd, rs1: r1, rs2: r2 },
    };
    crate::isa::encode(&i).expect("smc patch instruction encodes")
}

/// Emit a self-modifying-code construct (opt-in, `--weights smc=N`).
/// Both shapes store an encoded ALU instruction over the program's own
/// text and then execute the patched slot, exercising the decode-cache
/// and block-cache invalidation paths of both backends:
///
/// - **forward**: the `sw` lands on a placeholder four slots past the
///   `auipc` anchor — a word that is predecoded at load but has not yet
///   been reached by execution;
/// - **backward**: a two-iteration counted loop whose first instruction
///   sits at `t6 - 4`; iteration one executes (and caches) the original
///   word, the store overwrites it, and iteration two must re-decode.
///
/// The patch word is materialised with a fixed two-slot `lui`+`addi`
/// pair (never `li`, whose length depends on the value) so the store
/// offsets relative to the `auipc` anchor hold for every patch word.
fn emit_smc(a: &mut Asm, rng: &mut Xoshiro256) {
    let rd = dest(rng);
    let patch = smc_patch_word(rng);
    let hi = patch.wrapping_add(0x800) & 0xffff_f000;
    let lo = patch.wrapping_sub(hi) as i32;
    if rng.below(2) == 0 {
        a.auipc(T6, 0);
        a.lui(rd, hi as i32);
        a.addi(rd, rd, lo);
        a.sw(rd, 16, T6);
        // Placeholder at t6+16, overwritten by the `sw` just above
        // before the front end reaches it.
        a.addi(dest(rng), src(rng), imm12(rng));
    } else {
        a.li(S10, 2);
        let head = a.here("smc");
        // Executed as-emitted on iteration one, as the patch word on
        // iteration two.
        a.addi(dest(rng), src(rng), imm12(rng));
        a.auipc(T6, 0);
        a.lui(rd, hi as i32);
        a.addi(rd, rd, lo);
        a.sw(rd, -4, T6);
        a.addi(S10, S10, -1);
        a.bnez(S10, head);
    }
}

fn emit_vecmem(a: &mut Asm, rng: &mut Xoshiro256, vlen_bits: usize) {
    let vb = vlen_bits / 8;
    // Any offset (aligned or not) that keeps the full vector in-window.
    let off = rng.below((DATA_BYTES - vb) as u32 + 1) as i64;
    a.li(T6, off);
    if rng.below(2) == 0 {
        a.lv(vdest(rng), S11, T6);
    } else {
        a.sv(vsrc(rng), S11, T6);
    }
}

fn emit_branch(
    a: &mut Asm,
    rng: &mut Xoshiro256,
    pending: &mut Vec<(Label, usize)>,
) {
    match rng.below(8) {
        0..=3 => {
            // Forward conditional branch over the next few ops.
            let target = a.new_label("fwd");
            let (r1, r2) = (src(rng), src(rng));
            match rng.below(6) {
                0 => a.beq(r1, r2, target),
                1 => a.bne(r1, r2, target),
                2 => a.blt(r1, r2, target),
                3 => a.bge(r1, r2, target),
                4 => a.bltu(r1, r2, target),
                _ => a.bgeu(r1, r2, target),
            }
            pending.push((target, 2 + rng.below(6) as usize));
        }
        4 | 5 => {
            // Forward jal (link register drawn from the pool).
            let target = a.new_label("jfwd");
            a.jal(dest(rng), target);
            pending.push((target, 2 + rng.below(6) as usize));
        }
        6 => {
            // auipc+jalr pair targeting the very next instruction:
            // exact forward target, exercises the indirect jump.
            a.auipc(T6, 0);
            a.jalr(dest(rng), T6, 8);
        }
        _ => {
            // Self-contained counted loop on the reserved counter s10.
            // Forward-branch targets can never land inside (labels only
            // bind at op boundaries, and this whole construct is one op).
            let iters = 1 + rng.below(5) as i64;
            let body_ops = 1 + rng.below(4);
            a.li(S10, iters);
            let head = a.here("loop");
            for _ in 0..body_ops {
                let (rd, r1, r2) = (dest(rng), src(rng), src(rng));
                match rng.below(4) {
                    0 => a.add(rd, r1, r2),
                    1 => a.sub(rd, r1, r2),
                    2 => a.xor(rd, r1, r2),
                    _ => a.addi(rd, r1, imm12(rng)),
                }
            }
            a.addi(S10, S10, -1);
            a.bnez(S10, head);
        }
    }
}

/// Generate the deterministic random program for `(seed, ops, weights)`
/// at a vector width. The 4 KiB data window is part of the program
/// image (seeded random words), so loading the program fully
/// initialises both machines identically.
pub fn generate(seed: u64, ops: usize, w: &OpWeights, vlen_bits: usize) -> Program {
    assert!(w.total() > 0, "op weights must not all be zero");
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = Asm::new();
    let words = rng.vec_u32(DATA_BYTES / 4);
    let data = a.words("fuzz_data", &words);
    a.la(S11, data);
    let mut pending: Vec<(Label, usize)> = Vec::new();
    for _ in 0..ops {
        for p in pending.iter_mut() {
            p.1 -= 1;
        }
        let mut i = 0;
        while i < pending.len() {
            if pending[i].1 == 0 {
                let (l, _) = pending.remove(i);
                a.bind(l);
            } else {
                i += 1;
            }
        }
        match pick_class(&mut rng, w) {
            OpClass::Alu => emit_alu(&mut a, &mut rng),
            OpClass::Branch => emit_branch(&mut a, &mut rng, &mut pending),
            OpClass::MulDiv => emit_muldiv(&mut a, &mut rng),
            OpClass::Mem => emit_mem(&mut a, &mut rng),
            OpClass::Vec => emit_vec(&mut a, &mut rng),
            OpClass::VecMem => emit_vecmem(&mut a, &mut rng, vlen_bits),
            OpClass::WildJump => emit_wildjump(&mut a, &mut rng),
            OpClass::Smc => emit_smc(&mut a, &mut rng),
        }
    }
    for (l, _) in pending.drain(..) {
        a.bind(l);
    }
    a.halt();
    a.assemble().expect("fuzz program assembles")
}

/// Instruction budget for a case: generous versus the worst-case loop
/// expansion, so hitting it always means a termination bug.
pub fn max_instrs_for(ops: usize) -> u64 {
    ops as u64 * 64 + 4096
}

/// The stressed machine configuration the acceptance run pairs with the
/// default machine: non-blocking port (8 MSHRs), prefetch on, 2 DRAM
/// channels, dual-issue pipeline — every timing feature at once, while
/// the architectural results must stay bit-identical to the ISS.
pub fn stressed_point() -> MachinePoint {
    MachinePoint { mshrs: 8, prefetch: 4, channels: 2, issue_width: 2, ..Default::default() }
}

/// Why a fuzz case failed (structural, so campaign stats never depend
/// on report wording).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// The two backends architecturally disagreed — the bug class the
    /// campaign hunts.
    Divergence,
    /// Both sides faulted identically: a generator invariant violation.
    Fault,
    /// Neither side halted within the budget: the termination
    /// guarantee is broken.
    Watchdog,
    /// The static analyzer pre-flight ([`FuzzConfig::analyze`]) found
    /// error-severity findings in a generated program before it ran:
    /// either the generator broke a structural invariant or the
    /// analyzer has a false positive — both are bugs.
    Lint,
}

/// One failing fuzz case, with everything triage needs.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    pub seed: u64,
    pub ops: usize,
    pub weights_name: String,
    pub point: MachinePoint,
    pub kind: FailureKind,
    /// Assembly listing of the generated program.
    pub listing: String,
    /// Human-readable divergence / fault / watchdog report.
    pub report: String,
}

/// A fuzz campaign: `seeds` cases starting at `base_seed`, each run on
/// every machine point.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    pub seeds: u64,
    pub base_seed: u64,
    pub ops: usize,
    /// `None` rotates the balanced/scalar/vector presets per seed.
    pub weights: Option<OpWeights>,
    pub points: Vec<MachinePoint>,
    pub jobs: Parallelism,
    /// Static-analyzer pre-flight (`fuzz --analyze`): before running a
    /// case in lockstep, assert the generated program carries zero
    /// error-severity findings ([`crate::analysis`]). Skipped when the
    /// wild-jump class is enabled — wild jumps exist precisely to fault,
    /// and the analyzer flags every one of them.
    pub analyze: bool,
    /// Scheduler round-trip (`fuzz --sched`): before the lockstep run,
    /// schedule each generated program for the point's core
    /// configuration and prove the rewrite equivalent via
    /// [`crate::analysis::verify_schedule`] (see [`sched_case`]).
    pub sched: bool,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        Self {
            seeds: 100,
            base_seed: 1,
            ops: 300,
            weights: None,
            points: vec![MachinePoint::default(), stressed_point()],
            jobs: Parallelism::auto(),
            analyze: false,
            sched: false,
        }
    }
}

/// Campaign outcome.
#[derive(Debug)]
pub struct FuzzSummary {
    /// (seed, point) cases executed.
    pub cases: u64,
    /// Instructions retired in lockstep across all cases.
    pub instrs: u64,
    /// Cases that ended in an identical fault on both sides (a
    /// generator invariant violation — reported as failures too, but
    /// counted separately for the report).
    pub faulted: u64,
    pub failures: Vec<FuzzFailure>,
}

impl FuzzSummary {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Run one (seed, point) case in lockstep; `weights` as selected by the
/// campaign. Returns retired instructions on agreement.
pub fn run_case(
    seed: u64,
    ops: usize,
    weights_name: &str,
    w: &OpWeights,
    mp: &MachinePoint,
) -> Result<u64, Box<FuzzFailure>> {
    let prog = generate(seed, ops, w, mp.vlen);
    let fail = |listing: &Program, kind: FailureKind, report: String| {
        Box::new(FuzzFailure {
            seed,
            ops,
            weights_name: weights_name.to_string(),
            point: *mp,
            kind,
            listing: listing.disassemble(),
            report,
        })
    };
    let mut core = mp.machine().dram_bytes(FUZZ_DRAM_BYTES).build();
    let mut iss = RefIss::new(mp.vlen, core.mem.dram_size());
    core.load(&prog).expect("fuzz image fits the fuzz DRAM");
    iss.load(&prog).expect("fuzz image fits the fuzz DRAM");
    match run_lockstep(&mut core, &mut iss, max_instrs_for(ops)) {
        Ok(r) => match r.outcome {
            LockstepOutcome::Halted => Ok(r.instret),
            LockstepOutcome::Faulted(what) => {
                // With the wild-jump class enabled, an identical fetch
                // fault IS the expected outcome: both backends refused
                // the wild target the same way. Anything else (data
                // faults, or any fault without the class) remains a
                // generator invariant violation.
                if w.wildjump > 0 && crate::cosim::is_fetch_fault_key(&what) {
                    return Ok(r.instret);
                }
                Err(fail(
                    &prog,
                    FailureKind::Fault,
                    format!(
                        "program faulted identically on both sides ({what}) — the generator \
                         must never produce faulting programs (wild-jump fetch faults are \
                         only sanctioned when the wildjump class is enabled)"
                    ),
                ))
            }
            LockstepOutcome::Watchdog(n) => Err(fail(
                &prog,
                FailureKind::Watchdog,
                format!(
                    "neither side halted within {n} instructions — the generator's \
                     termination guarantee is broken"
                ),
            )),
        },
        Err(divergence) => Err(fail(&prog, FailureKind::Divergence, divergence.to_string())),
    }
}

/// Static-analyzer pre-flight for one case: generate the program and
/// assert it carries zero error-severity findings. The generator's
/// structural guarantees ("no generated program can fault") become a
/// machine-checked property instead of a construction-time comment.
/// Callers gate this on `w.wildjump == 0`: wild jumps are *meant* to
/// fault and the analyzer flags every one of them.
pub fn preflight_case(
    seed: u64,
    ops: usize,
    weights_name: &str,
    w: &OpWeights,
    mp: &MachinePoint,
) -> Result<(), Box<FuzzFailure>> {
    let prog = generate(seed, ops, w, mp.vlen);
    let cfg = crate::analysis::AnalysisConfig { vlen_bits: mp.vlen, dram_bytes: FUZZ_DRAM_BYTES };
    let report = crate::analysis::analyze_program(&prog, &cfg);
    if report.is_clean() {
        return Ok(());
    }
    Err(Box::new(FuzzFailure {
        seed,
        ops,
        weights_name: weights_name.to_string(),
        point: *mp,
        kind: FailureKind::Lint,
        listing: prog.disassemble(),
        report: format!(
            "static analyzer pre-flight found {} error(s) in a generated program:\n{}",
            report.error_count(),
            report.render(20)
        ),
    }))
}

/// Scheduler round-trip for one case (`fuzz --sched`): schedule the
/// generated program for the point's core configuration and prove the
/// rewrite semantics-preserving with
/// [`crate::analysis::verify_schedule`] — reference-ISS final-state
/// identity plus a lockstep cosim of the scheduled program on the
/// timed core. Seeds whose *original* program does not halt cleanly on
/// the ISS are skipped: the scheduler may legally reorder two faulting
/// accesses within a block, so only clean programs have a comparable
/// end state (the regular lockstep case still covers the faulting
/// ones).
pub fn sched_case(
    seed: u64,
    ops: usize,
    weights_name: &str,
    w: &OpWeights,
    mp: &MachinePoint,
) -> Result<(), Box<FuzzFailure>> {
    use crate::arch::ArchState;
    let prog = generate(seed, ops, w, mp.vlen);
    let max = max_instrs_for(ops);
    let mut iss = RefIss::new(mp.vlen, FUZZ_DRAM_BYTES);
    if iss.load(&prog).is_err() || iss.run(max).is_err() || !ArchState::halted(&iss) {
        return Ok(());
    }
    let acfg = crate::analysis::AnalysisConfig { vlen_bits: mp.vlen, dram_bytes: FUZZ_DRAM_BYTES };
    let core_cfg = *mp.machine().dram_bytes(FUZZ_DRAM_BYTES).core_config();
    let outcome = crate::analysis::schedule_program(&prog, &acfg, &core_cfg);
    if !outcome.changed() {
        return Ok(());
    }
    crate::analysis::verify_schedule(
        &prog,
        &outcome.program,
        &[],
        mp.vlen,
        FUZZ_DRAM_BYTES,
        core_cfg.issue_width,
        max,
    )
    .map_err(|report| {
        Box::new(FuzzFailure {
            seed,
            ops,
            weights_name: weights_name.to_string(),
            point: *mp,
            kind: FailureKind::Divergence,
            listing: outcome.program.disassemble(),
            report: format!(
                "scheduled program is not equivalent to the original \
                 ({} block(s) reordered, {} instr(s) moved): {report}",
                outcome.blocks_changed, outcome.instrs_moved
            ),
        })
    })
}

/// Expand a seed range into content-addressed service jobs — one
/// [`crate::service::Job`] per (machine point, seed) — so a fuzz
/// campaign can flow through the sweep service's queue and result
/// store like any other grid (`serve` fuzz submissions are built from
/// this).
pub fn seed_jobs(
    points: &[MachinePoint],
    base_seed: u64,
    seeds: u64,
    ops: usize,
    weights: &str,
) -> Vec<crate::service::Job> {
    let mut jobs = Vec::with_capacity(points.len() * seeds as usize);
    for &point in points {
        for s in 0..seeds {
            jobs.push(crate::service::Job::fuzz(point, base_seed + s, ops, weights));
        }
    }
    jobs
}

/// Run the full campaign on a bounded worker pool.
pub fn run_campaign(cfg: &FuzzConfig) -> FuzzSummary {
    let mut cases = Vec::new();
    for s in 0..cfg.seeds {
        let seed = cfg.base_seed + s;
        let (name, w) = match &cfg.weights {
            Some(w) => ("custom", *w),
            None => OpWeights::preset_for_seed(seed),
        };
        for &mp in &cfg.points {
            cases.push((seed, name, w, mp));
        }
    }
    let n_cases = cases.len() as u64;
    let analyze = cfg.analyze;
    let sched = cfg.sched;
    let results = sweep::parallel_map_bounded(cases, cfg.jobs.workers(), |(seed, name, w, mp)| {
        if analyze && w.wildjump == 0 {
            preflight_case(seed, cfg.ops, name, &w, &mp)?;
        }
        if sched {
            sched_case(seed, cfg.ops, name, &w, &mp)?;
        }
        run_case(seed, cfg.ops, name, &w, &mp)
    });
    let mut summary = FuzzSummary { cases: n_cases, instrs: 0, faulted: 0, failures: Vec::new() };
    for r in results {
        match r {
            Ok(instrs) => summary.instrs += instrs,
            Err(f) => {
                if f.kind == FailureKind::Fault {
                    summary.faulted += 1;
                }
                summary.failures.push(*f);
            }
        }
    }
    summary
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{decode, Instr};

    #[test]
    fn generation_is_deterministic() {
        let w = OpWeights::balanced();
        let a = generate(42, 200, &w, 256);
        let b = generate(42, 200, &w, 256);
        assert_eq!(a.text, b.text);
        assert_eq!(a.data, b.data);
        let c = generate(43, 200, &w, 256);
        assert_ne!(a.text, c.text, "different seeds give different programs");
    }

    #[test]
    fn every_generated_word_decodes() {
        for seed in 0..12 {
            let (_, w) = OpWeights::preset_for_seed(seed);
            let p = generate(seed, 150, &w, 256);
            for (i, &word) in p.text.iter().enumerate() {
                decode(word).unwrap_or_else(|e| {
                    panic!("seed {seed} word {i} ({word:#010x}) does not decode: {e}")
                });
            }
            assert!(matches!(decode(*p.text.last().unwrap()).unwrap(), Instr::Ecall));
        }
    }

    #[test]
    fn scalar_preset_emits_no_custom_instructions() {
        let p = generate(7, 300, &OpWeights::scalar(), 256);
        for &word in &p.text {
            let i = decode(word).unwrap();
            assert!(
                !matches!(i, Instr::CustomI { .. } | Instr::CustomS { .. }),
                "scalar preset produced {i}"
            );
        }
    }

    #[test]
    fn vector_preset_emits_custom_instructions() {
        let p = generate(8, 300, &OpWeights::vector(), 256);
        let customs = p
            .text
            .iter()
            .filter(|&&w| {
                matches!(decode(w), Ok(Instr::CustomI { .. }) | Ok(Instr::CustomS { .. }))
            })
            .count();
        assert!(customs > 30, "vector preset emitted only {customs} custom instructions");
    }

    #[test]
    fn weights_parse_roundtrip_and_errors() {
        let w = OpWeights::parse("alu=9,vec=0,vecmem=0").unwrap();
        assert_eq!(w.alu, 9);
        assert_eq!(w.vec, 0);
        assert_eq!(w.branch, OpWeights::balanced().branch, "unnamed classes keep defaults");
        assert_eq!(w.wildjump, 0, "wild jumps are opt-in");
        assert_eq!(w.smc, 0, "self-modifying stores are opt-in");
        assert_eq!(OpWeights::parse("wildjump=3").unwrap().wildjump, 3);
        assert_eq!(OpWeights::parse("smc=3").unwrap().smc, 3);
        assert!(OpWeights::parse("bogus=1").is_err());
        assert!(OpWeights::parse("alu").is_err());
        assert!(OpWeights::parse("alu=x").is_err());
        assert!(
            OpWeights::parse("alu=0,branch=0,muldiv=0,mem=0,vec=0,vecmem=0").is_err(),
            "all-zero weights rejected"
        );
    }

    #[test]
    fn presets_never_emit_wild_jumps() {
        for seed in 0..3 {
            let (_, w) = OpWeights::preset_for_seed(seed);
            assert_eq!(w.wildjump, 0);
            assert_eq!(w.smc, 0);
        }
    }

    #[test]
    fn wildjump_campaign_faults_symmetrically_without_panics() {
        // Wild jumps used to panic the timed core (misaligned fetch
        // across an IL1 block; unchecked text indexing). With the class
        // enabled, every case must end in a halt or an identical fetch
        // fault on both backends — never a divergence, data fault,
        // watchdog or panic.
        let cfg = FuzzConfig {
            seeds: 16,
            base_seed: 4000,
            ops: 150,
            weights: Some(OpWeights::wild()),
            ..Default::default()
        };
        let summary = run_campaign(&cfg);
        for f in &summary.failures {
            eprintln!("seed {} on {:?}:\n{}\n{}", f.seed, f.point, f.report, f.listing);
        }
        assert!(summary.ok(), "{} wild-jump failures", summary.failures.len());
        assert_eq!(summary.cases, 32, "16 seeds x (default + stressed)");
    }

    #[test]
    fn wildjump_weight_actually_emits_wild_targets() {
        // At weight 2 over 150 ops, the deterministic generator emits
        // at least one wild jalr — distinguishable from the benign
        // auipc+jalr branch pair by its offset (0/6/7 vs 8).
        let p = generate(4001, 150, &OpWeights::wild(), 256);
        let wilds = p
            .text
            .iter()
            .filter(|&&w| {
                matches!(
                    decode(w),
                    Ok(Instr::Jalr { rs1, offset, .. })
                        if rs1 == T6 && matches!(offset, 0 | 6 | 7)
                )
            })
            .count();
        assert!(wilds > 0, "wild preset emitted no wild jalr:\n{}", p.disassemble());
    }

    #[test]
    fn smc_weight_actually_emits_text_stores() {
        // Both construct shapes anchor the patch store on t6 via
        // `auipc`, at offset 16 (forward placeholder) or -4 (backward
        // loop head) — distinguishable from data stores, which are
        // always based on s11.
        let p = generate(5001, 150, &OpWeights::smc(), 256);
        let patches = p
            .text
            .iter()
            .filter(|&&w| {
                matches!(
                    decode(w),
                    Ok(Instr::Sw { rs1, offset, .. })
                        if rs1 == T6 && matches!(offset, 16 | -4)
                )
            })
            .count();
        assert!(patches > 0, "smc preset emitted no text patch:\n{}", p.disassemble());
    }

    #[test]
    fn smc_campaign_agrees_in_lockstep_without_divergence() {
        // Self-modifying stores hit the decode-cache and block-cache
        // invalidation paths of both backends: every case must halt
        // with bit-identical architectural state — a stale cached
        // decode on either side is an instant divergence.
        let cfg = FuzzConfig {
            seeds: 16,
            base_seed: 5000,
            ops: 150,
            weights: Some(OpWeights::smc()),
            ..Default::default()
        };
        let summary = run_campaign(&cfg);
        for f in &summary.failures {
            eprintln!("seed {} on {:?}:\n{}\n{}", f.seed, f.point, f.report, f.listing);
        }
        assert!(summary.ok(), "{} smc failures", summary.failures.len());
        assert_eq!(summary.cases, 32, "16 seeds x (default + stressed)");
    }

    #[test]
    fn smoke_campaign_has_zero_divergences() {
        let cfg = FuzzConfig { seeds: 9, base_seed: 1000, ops: 200, ..Default::default() };
        let summary = run_campaign(&cfg);
        assert_eq!(summary.cases, 18, "9 seeds x (default + stressed)");
        for f in &summary.failures {
            eprintln!("seed {} on {:?}:\n{}\n{}", f.seed, f.point, f.report, f.listing);
        }
        assert!(summary.ok(), "{} fuzz failures", summary.failures.len());
        assert!(summary.instrs > 1000, "campaign actually executed instructions");
    }

    #[test]
    fn seed_ranges_expand_into_distinct_service_jobs() {
        let points = [MachinePoint::default(), stressed_point()];
        let jobs = seed_jobs(&points, 100, 3, 250, "balanced");
        assert_eq!(jobs.len(), 6, "every (point, seed) pair becomes a job");
        let keys: std::collections::BTreeSet<u64> = jobs.iter().map(|j| j.key()).collect();
        assert_eq!(keys.len(), 6, "each job has a distinct content address");
        assert!(jobs.iter().all(|j| j.validate().is_ok()));
    }

    #[test]
    fn fuzz_terminates_at_wide_vlen() {
        let mp = MachinePoint { vlen: 1024, ..Default::default() };
        assert!(mp.validate().is_ok());
        let r = run_case(5, 150, "balanced", &OpWeights::balanced(), &mp);
        assert!(r.is_ok(), "{}", r.unwrap_err().report);
    }

    fn fuzz_analysis_config() -> crate::analysis::AnalysisConfig {
        crate::analysis::AnalysisConfig { vlen_bits: 256, dram_bytes: FUZZ_DRAM_BYTES }
    }

    #[test]
    fn branch_discipline_is_an_analyzer_checked_invariant() {
        // The module doc promises: conditional branches and `jal` only
        // target forward, backward branches exist only as the counted
        // loop's `bnez s10`, and the benign `auipc`+`jalr` pair lands on
        // the next instruction. Recover the CFG and assert all three,
        // instead of trusting the generator's construction.
        use crate::analysis::{recover_cfg, Terminator};
        for seed in 0..8 {
            let (name, w) = OpWeights::preset_for_seed(seed);
            let prog = generate(seed, 200, &w, 256);
            let (cache, graph) = recover_cfg(&prog, &fuzz_analysis_config());
            for b in graph.blocks.iter().filter(|b| b.reachable) {
                let tpc = b.term_pc(graph.base);
                match b.term {
                    Terminator::Branch { target } if target <= tpc => {
                        let i = cache
                            .word_index(tpc)
                            .and_then(|k| cache.get(k))
                            .expect("terminator decodes");
                        assert!(
                            matches!(i, Instr::Bne { rs1, rs2, .. } if rs1 == S10 && rs2 == ZERO),
                            "seed {seed} ({name}): backward branch at {tpc:#010x} is not the \
                             counted-loop `bnez s10`: {i}"
                        );
                    }
                    Terminator::Jump { target } => {
                        assert!(
                            target > tpc,
                            "seed {seed} ({name}): jal at {tpc:#010x} targets backward"
                        );
                    }
                    Terminator::Indirect { resolved } => {
                        assert_eq!(
                            resolved,
                            Some(tpc.wrapping_add(4)),
                            "seed {seed} ({name}): reachable jalr at {tpc:#010x} must resolve \
                             to the next instruction"
                        );
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn data_window_is_an_analyzer_checked_invariant() {
        // Every load/store in a preset program must constant-fold to an
        // address inside the 4 KiB data window — the other half of the
        // "no generated program can fault" guarantee.
        use crate::analysis::analyze_program;
        for seed in 0..8 {
            let (name, w) = OpWeights::preset_for_seed(seed);
            let prog = generate(seed, 200, &w, 256);
            let report = analyze_program(&prog, &fuzz_analysis_config());
            assert!(report.is_clean(), "seed {seed} ({name}):\n{}", report.render(20));
            let lo = prog.data_base;
            let hi = lo as u64 + DATA_BYTES as u64;
            assert!(!report.accesses.is_empty(), "seed {seed} ({name}) emitted no accesses");
            for acc in &report.accesses {
                let addr = acc.addr.unwrap_or_else(|| {
                    panic!(
                        "seed {seed} ({name}): access at {:#010x} did not constant-fold",
                        acc.pc
                    )
                });
                assert!(
                    addr >= lo && addr as u64 + acc.len as u64 <= hi,
                    "seed {seed} ({name}): {} at pc {:#010x} hits {addr:#010x}+{} outside the \
                     data window [{lo:#010x}, {hi:#010x})",
                    if acc.store { "store" } else { "load" },
                    acc.pc,
                    acc.len
                );
            }
        }
    }

    #[test]
    fn preflight_rejects_wild_programs() {
        // Wild jalr shapes all draw error-severity findings (wild-jump
        // or misaligned-target), which is exactly why the campaign skips
        // the pre-flight when the class is enabled.
        let mp = MachinePoint::default();
        let f = (4000..4016)
            .find_map(|seed| preflight_case(seed, 150, "wild", &OpWeights::wild(), &mp).err())
            .expect("some wild program fails the static pre-flight");
        assert!(matches!(f.kind, FailureKind::Lint), "{:?}: {}", f.kind, f.report);
    }

    #[test]
    fn smc_programs_pass_preflight_with_text_store_warnings() {
        // Self-modifying stores are warnings, not errors: the program
        // still halts cleanly, so the pre-flight must let it through
        // while flagging every text-overlapping store.
        use crate::analysis::{analyze_program, FindingKind};
        let prog = generate(5001, 150, &OpWeights::smc(), 256);
        let report = analyze_program(&prog, &fuzz_analysis_config());
        assert!(report.is_clean(), "{}", report.render(30));
        assert!(report.has_kind(FindingKind::StoreToText), "no store-to-text warning");
    }

    #[test]
    fn analyze_preflight_campaign_is_clean() {
        let cfg = FuzzConfig {
            seeds: 6,
            base_seed: 7000,
            ops: 150,
            analyze: true,
            ..Default::default()
        };
        let summary = run_campaign(&cfg);
        for f in &summary.failures {
            eprintln!("seed {} ({:?}):\n{}\n{}", f.seed, f.kind, f.report, f.listing);
        }
        assert!(summary.ok(), "{} failures with the analyze pre-flight on", summary.failures.len());
    }

    #[test]
    fn sched_campaign_roundtrip_is_equivalent() {
        // Every generated program that halts cleanly must survive the
        // scheduler round-trip: schedule for the point's core config
        // (the stressed point is dual-issue, so real reordering
        // happens), then prove ISS end-state identity + lockstep
        // agreement of the scheduled program.
        let cfg = FuzzConfig {
            seeds: 8,
            base_seed: 9000,
            ops: 150,
            sched: true,
            ..Default::default()
        };
        let summary = run_campaign(&cfg);
        for f in &summary.failures {
            eprintln!("seed {} ({:?}):\n{}\n{}", f.seed, f.kind, f.report, f.listing);
        }
        assert!(summary.ok(), "{} scheduler round-trip failures", summary.failures.len());
    }
}
