//! Comparator baselines of the paper's evaluation: the PicoRV32 drop-in
//! softcore model (Fig. 4) and the calibrated ARM Cortex-A53 reference
//! (§4.3 speedup anchors).

pub mod arm_a53;
pub mod picorv32;

pub use picorv32::{PicoConfig, PicoCore};
