//! PicoRV32 comparator model (§4.2, Fig. 4).
//!
//! The paper drops PicoRV32 [44] onto the same FPGA as "a drop-in
//! replacement that supports AXI (Lite)": no cache, one AXI-Lite
//! transaction per memory word, low IPC but a 300 MHz clock. Its STREAM
//! results are flat 4.8/3.6/4.4/4.0 MB/s across array sizes because every
//! access pays the full DRAM round trip.
//!
//! The model: a scalar RV32IM interpreter with
//! - `cpi` cycles per retired instruction (PicoRV32's documented ~4 CPI
//!   ballpark [12]),
//! - a single-beat AXI-Lite transaction of `axi_latency` core cycles per
//!   instruction fetch and per data access (no bursts, no caches),
//! - a 300 MHz clock for MB/s conversion.

use crate::asm::Program;
use crate::core::SimError;
use crate::isa::{decode, DecodeCache, Instr};
use crate::mem::{Dram, DramConfig};

#[derive(Debug, Clone, Copy)]
pub struct PicoConfig {
    pub fmax_mhz: f64,
    /// Non-memory cycles per instruction (execute + internal fetch states).
    pub cpi: u64,
    /// Core cycles for one AXI-Lite single-beat transaction at 300 MHz.
    /// Uncached single-beat reads through the Zynq PS DDR controller
    /// measure ≈ 200–250 ns (interconnect + controller + DDR), i.e.
    /// ≈ 65 cycles at 300 MHz — which also reproduces the paper's flat
    /// 4.8 MB/s Copy rate.
    pub axi_latency: u64,
    pub dram_size: usize,
}

impl Default for PicoConfig {
    fn default() -> Self {
        Self { fmax_mhz: 300.0, cpi: 4, axi_latency: 65, dram_size: 64 * 1024 * 1024 }
    }
}

pub struct PicoCore {
    pub cfg: PicoConfig,
    dram: Dram,
    regs: [u32; 32],
    pc: u32,
    cycle: u64,
    instret: u64,
    halted: bool,
    /// Predecoded text view (PicoRV32 has no I-cache, but decoding is a
    /// simulator concern, not a timing one — every fetch still pays the
    /// AXI transaction). Stores over the text range invalidate it, the
    /// same contract the timed core and the reference ISS follow.
    text: DecodeCache,
}

impl PicoCore {
    pub fn new(cfg: PicoConfig) -> Self {
        Self {
            cfg,
            dram: Dram::new(DramConfig {
                size_bytes: cfg.dram_size,
                axi_width_bits: 32,
                double_rate: false,
                burst_setup_cycles: cfg.axi_latency,
                channels: 1,
            }),
            regs: [0; 32],
            pc: 0,
            cycle: 0,
            instret: 0,
            halted: false,
            text: DecodeCache::empty(),
        }
    }

    /// Load a program image, rejecting one that does not fit DRAM with
    /// [`SimError::ImageFault`] (the same contract as `Core::load` and
    /// `RefIss::load`) instead of panicking on the host-side copy.
    pub fn load(&mut self, prog: &Program) -> Result<(), SimError> {
        let size = self.cfg.dram_size;
        for (base, len) in [(prog.text_base, prog.text.len() * 4), (prog.data_base, prog.data.len())]
        {
            if base as u64 + len as u64 > size as u64 {
                return Err(SimError::ImageFault { addr: base, len, size });
            }
        }
        let mut text_bytes = Vec::with_capacity(prog.text.len() * 4);
        for w in &prog.text {
            text_bytes.extend_from_slice(&w.to_le_bytes());
        }
        self.dram.host_write(prog.text_base, &text_bytes);
        if !prog.data.is_empty() {
            self.dram.host_write(prog.data_base, &prog.data);
        }
        self.regs = [0; 32];
        self.regs[2] = (self.cfg.dram_size as u32) & !15;
        self.pc = prog.entry;
        self.cycle = 0;
        self.instret = 0;
        self.halted = false;
        self.text.predecode(prog.text_base, &prog.text);
        Ok(())
    }

    pub fn host_write(&mut self, addr: u32, data: &[u8]) {
        self.dram.host_write(addr, data);
        if self.text.overlaps(addr, data.len()) {
            self.text.invalidate(addr, data.len());
        }
    }

    pub fn dram_slice(&self, addr: u32, len: usize) -> &[u8] {
        self.dram.host_slice(addr, len)
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    pub fn instret(&self) -> u64 {
        self.instret
    }

    pub fn reg(&self, r: crate::isa::Reg) -> u32 {
        self.regs[r.num() as usize]
    }

    pub fn halted(&self) -> bool {
        self.halted
    }

    /// Bytes/second rate for `bytes` of payload at this model's clock.
    pub fn bytes_per_second(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cycle as f64 * self.cfg.fmax_mhz * 1e6
    }

    pub fn run(&mut self, max_instrs: u64) -> Result<(), SimError> {
        let start = self.instret;
        while !self.halted {
            if self.instret - start >= max_instrs {
                return Err(SimError::Watchdog(max_instrs));
            }
            self.step()?;
        }
        Ok(())
    }

    /// Shared fault classification with Core/RefIss: end-of-range in
    /// u64, address-space wrap distinct from plain out-of-DRAM.
    fn check_mem(&self, addr: u32, len: usize) -> Result<(), SimError> {
        let end = addr as u64 + len as u64;
        if end > 1 << 32 {
            return Err(SimError::MemWrap { pc: self.pc, addr, len });
        }
        if end > self.cfg.dram_size as u64 {
            return Err(SimError::MemFault { pc: self.pc, addr, len, size: self.cfg.dram_size });
        }
        Ok(())
    }

    fn mem_read(&mut self, addr: u32, len: usize) -> Result<u32, SimError> {
        self.check_mem(addr, len)?;
        // One AXI-Lite transaction (word granularity).
        let (word, done) = self.dram.read_word_single(addr & !3, self.cfg.axi_latency, self.cycle);
        self.cycle = done;
        let shift = (addr & 3) * 8;
        Ok(word >> shift)
    }

    fn mem_write(&mut self, addr: u32, value: u32, len: usize) -> Result<(), SimError> {
        self.check_mem(addr, len)?;
        // Read-modify-write for sub-word stores (AXI-Lite with strobes
        // would avoid this; PicoRV32 uses strobes, so charge one
        // transaction only).
        let aligned = addr & !3;
        let mut cur = u32::from_le_bytes(
            self.dram.host_slice(aligned, 4).try_into().unwrap(),
        );
        let shift = (addr & 3) * 8;
        let mask = if len == 4 { u32::MAX } else { ((1u32 << (len * 8)) - 1) << shift };
        cur = (cur & !mask) | ((value << shift) & mask);
        let done = self.dram.write_word_single(aligned, cur, self.cfg.axi_latency, self.cycle);
        self.cycle = done;
        if self.text.overlaps(addr, len) {
            self.text.invalidate(addr, len);
        }
        Ok(())
    }

    fn step(&mut self) -> Result<(), SimError> {
        let pc = self.pc;
        // Instruction fetch: one AXI transaction.
        let word = self.mem_read(pc, 4)?;
        let instr = match self.text.word_index(pc) {
            Some(idx) => match self.text.get(idx) {
                Some(i) => i,
                None => {
                    let i = decode(word).map_err(|source| SimError::Illegal { pc, source })?;
                    self.text.put(idx, i);
                    i
                }
            },
            None => decode(word).map_err(|source| SimError::Illegal { pc, source })?,
        };

        let mut next_pc = pc.wrapping_add(4);
        let rd = |s: &Self, r: crate::isa::Reg| s.regs[r.num() as usize];
        let wr = |s: &mut Self, r: crate::isa::Reg, v: u32| {
            if r.num() != 0 {
                s.regs[r.num() as usize] = v;
            }
        };

        use Instr::*;
        match instr {
            Lui { rd: d, imm } => wr(self, d, imm as u32),
            Auipc { rd: d, imm } => wr(self, d, pc.wrapping_add(imm as u32)),
            Jal { rd: d, offset } => {
                wr(self, d, pc.wrapping_add(4));
                next_pc = pc.wrapping_add(offset as u32);
            }
            Jalr { rd: d, rs1, offset } => {
                let t = rd(self, rs1).wrapping_add(offset as u32) & !1;
                wr(self, d, pc.wrapping_add(4));
                next_pc = t;
            }
            Beq { rs1, rs2, offset } if rd(self, rs1) == rd(self, rs2) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Bne { rs1, rs2, offset } if rd(self, rs1) != rd(self, rs2) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Blt { rs1, rs2, offset } if (rd(self, rs1) as i32) < (rd(self, rs2) as i32) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Bge { rs1, rs2, offset } if (rd(self, rs1) as i32) >= (rd(self, rs2) as i32) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Bltu { rs1, rs2, offset } if rd(self, rs1) < rd(self, rs2) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Bgeu { rs1, rs2, offset } if rd(self, rs1) >= rd(self, rs2) => {
                next_pc = pc.wrapping_add(offset as u32)
            }
            Beq { .. } | Bne { .. } | Blt { .. } | Bge { .. } | Bltu { .. } | Bgeu { .. } => {}
            Lb { rd: d, rs1, offset } => {
                let v = self.mem_read(rd(self, rs1).wrapping_add(offset as u32), 1)?;
                wr(self, d, v as u8 as i8 as i32 as u32);
            }
            Lbu { rd: d, rs1, offset } => {
                let v = self.mem_read(rd(self, rs1).wrapping_add(offset as u32), 1)?;
                wr(self, d, v & 0xff);
            }
            Lh { rd: d, rs1, offset } => {
                let v = self.mem_read(rd(self, rs1).wrapping_add(offset as u32), 2)?;
                wr(self, d, v as u16 as i16 as i32 as u32);
            }
            Lhu { rd: d, rs1, offset } => {
                let v = self.mem_read(rd(self, rs1).wrapping_add(offset as u32), 2)?;
                wr(self, d, v & 0xffff);
            }
            Lw { rd: d, rs1, offset } => {
                let v = self.mem_read(rd(self, rs1).wrapping_add(offset as u32), 4)?;
                wr(self, d, v);
            }
            Sb { rs1, rs2, offset } => {
                self.mem_write(rd(self, rs1).wrapping_add(offset as u32), rd(self, rs2), 1)?
            }
            Sh { rs1, rs2, offset } => {
                self.mem_write(rd(self, rs1).wrapping_add(offset as u32), rd(self, rs2), 2)?
            }
            Sw { rs1, rs2, offset } => {
                self.mem_write(rd(self, rs1).wrapping_add(offset as u32), rd(self, rs2), 4)?
            }
            Addi { rd: d, rs1, imm } => wr(self, d, rd(self, rs1).wrapping_add(imm as u32)),
            Slti { rd: d, rs1, imm } => wr(self, d, ((rd(self, rs1) as i32) < imm) as u32),
            Sltiu { rd: d, rs1, imm } => wr(self, d, (rd(self, rs1) < imm as u32) as u32),
            Xori { rd: d, rs1, imm } => wr(self, d, rd(self, rs1) ^ imm as u32),
            Ori { rd: d, rs1, imm } => wr(self, d, rd(self, rs1) | imm as u32),
            Andi { rd: d, rs1, imm } => wr(self, d, rd(self, rs1) & imm as u32),
            Slli { rd: d, rs1, shamt } => wr(self, d, rd(self, rs1) << shamt),
            Srli { rd: d, rs1, shamt } => wr(self, d, rd(self, rs1) >> shamt),
            Srai { rd: d, rs1, shamt } => wr(self, d, ((rd(self, rs1) as i32) >> shamt) as u32),
            Add { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1).wrapping_add(rd(self, rs2))),
            Sub { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1).wrapping_sub(rd(self, rs2))),
            Sll { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1) << (rd(self, rs2) & 31)),
            Slt { rd: d, rs1, rs2 } => {
                wr(self, d, ((rd(self, rs1) as i32) < (rd(self, rs2) as i32)) as u32)
            }
            Sltu { rd: d, rs1, rs2 } => wr(self, d, (rd(self, rs1) < rd(self, rs2)) as u32),
            Xor { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1) ^ rd(self, rs2)),
            Srl { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1) >> (rd(self, rs2) & 31)),
            Sra { rd: d, rs1, rs2 } => {
                wr(self, d, ((rd(self, rs1) as i32) >> (rd(self, rs2) & 31)) as u32)
            }
            Or { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1) | rd(self, rs2)),
            And { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1) & rd(self, rs2)),
            Mul { rd: d, rs1, rs2 } => wr(self, d, rd(self, rs1).wrapping_mul(rd(self, rs2))),
            Mulh { rd: d, rs1, rs2 } => wr(
                self,
                d,
                (((rd(self, rs1) as i32 as i64) * (rd(self, rs2) as i32 as i64)) >> 32) as u32,
            ),
            Mulhsu { rd: d, rs1, rs2 } => wr(
                self,
                d,
                (((rd(self, rs1) as i32 as i64) * (rd(self, rs2) as u64 as i64)) >> 32) as u32,
            ),
            Mulhu { rd: d, rs1, rs2 } => {
                wr(self, d, (((rd(self, rs1) as u64) * (rd(self, rs2) as u64)) >> 32) as u32)
            }
            Div { rd: d, rs1, rs2 } => {
                let (x, y) = (rd(self, rs1) as i32, rd(self, rs2) as i32);
                let v = if y == 0 {
                    -1
                } else if x == i32::MIN && y == -1 {
                    x
                } else {
                    x.wrapping_div(y)
                };
                self.cycle += 32; // iterative divider
                wr(self, d, v as u32);
            }
            Divu { rd: d, rs1, rs2 } => {
                let (x, y) = (rd(self, rs1), rd(self, rs2));
                self.cycle += 32;
                wr(self, d, if y == 0 { u32::MAX } else { x / y });
            }
            Rem { rd: d, rs1, rs2 } => {
                let (x, y) = (rd(self, rs1) as i32, rd(self, rs2) as i32);
                let v = if y == 0 {
                    x
                } else if x == i32::MIN && y == -1 {
                    0
                } else {
                    x.wrapping_rem(y)
                };
                self.cycle += 32;
                wr(self, d, v as u32);
            }
            Remu { rd: d, rs1, rs2 } => {
                let (x, y) = (rd(self, rs1), rd(self, rs2));
                self.cycle += 32;
                wr(self, d, if y == 0 { x } else { x % y });
            }
            Fence => {}
            Ecall => self.halted = true,
            Ebreak => return Err(SimError::Break(pc)),
            Csrrs { rd: d, csr, .. } => {
                use crate::isa::instr::csr as c;
                let v = match csr {
                    c::CYCLE | c::TIME => self.cycle as u32,
                    c::CYCLEH | c::TIMEH => (self.cycle >> 32) as u32,
                    c::INSTRET => self.instret as u32,
                    c::INSTRETH => (self.instret >> 32) as u32,
                    _ => 0,
                };
                wr(self, d, v);
            }
            CustomI { .. } | CustomS { .. } => {
                return Err(SimError::Illegal {
                    pc,
                    source: crate::isa::DecodeError::UnknownOpcode { word, opcode: word & 0x7f },
                })
            }
        }

        self.pc = next_pc;
        self.cycle += self.cfg.cpi;
        self.instret += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    #[test]
    fn executes_scalar_programs() {
        let mut a = Asm::new();
        let l = a.new_label("l");
        a.li(A0, 5);
        a.li(A1, 0);
        a.bind(l);
        a.add(A1, A1, A0);
        a.addi(A0, A0, -1);
        a.bnez(A0, l);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        c.run(1000).unwrap();
        assert_eq!(c.reg(A1), 15);
    }

    #[test]
    fn memory_costs_dominate() {
        // A load-store loop must be slower than an ALU loop by roughly
        // the AXI-latency factor.
        let mut alu = Asm::new();
        let l = alu.new_label("l");
        alu.li(A0, 100);
        alu.bind(l);
        alu.addi(A0, A0, -1);
        alu.bnez(A0, l);
        alu.halt();
        let p1 = alu.assemble().unwrap();

        let mut mem = Asm::new();
        let buf = mem.buffer("buf", 64, 4);
        let l = mem.new_label("l");
        mem.li(A0, 100);
        mem.la(A1, buf);
        mem.bind(l);
        mem.lw(T0, 0, A1);
        mem.sw(T0, 4, A1);
        mem.addi(A0, A0, -1);
        mem.bnez(A0, l);
        mem.halt();
        let p2 = mem.assemble().unwrap();

        let mut c1 = PicoCore::new(PicoConfig::default());
        c1.load(&p1).unwrap();
        c1.run(10_000).unwrap();
        let mut c2 = PicoCore::new(PicoConfig::default());
        c2.load(&p2).unwrap();
        c2.run(10_000).unwrap();
        // Per iteration: ALU loop = 2 fetches; mem loop = 4 fetches + 2
        // data transactions. Cycle ratio ≈ 3.
        let ratio = c2.cycle() as f64 / c1.cycle() as f64;
        assert!(ratio > 2.0, "mem/alu cycle ratio {ratio:.1}");
    }

    #[test]
    fn store_over_text_invalidates_decoded_view() {
        // Same SMC regression as the core/ISS: a two-iteration loop
        // patches its own already-executed first instruction; the second
        // iteration must run the patched word, not the stale decode.
        let patch = crate::isa::encode(&Instr::Addi { rd: A0, rs1: A0, imm: 100 }).unwrap();
        let mut a = Asm::new();
        a.li(A0, 0);
        a.li(S10, 2);
        a.li(T1, patch as i64);
        let head = a.new_label("head");
        a.bind(head);
        a.addi(A0, A0, 1);
        a.la(T0, head);
        a.sw(T1, 0, T0);
        a.addi(S10, S10, -1);
        a.bnez(S10, head);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        c.run(1000).unwrap();
        assert_eq!(c.reg(A0), 101, "PicoRV32 executed a stale cached decode");
    }

    #[test]
    fn rejects_custom_instructions() {
        let mut a = Asm::new();
        a.sort8(V1, V1);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        assert!(matches!(c.run(10), Err(SimError::Illegal { .. })));
    }

    #[test]
    fn wrapping_access_raises_the_same_fault_as_the_other_backends() {
        // A 4-byte load at 0xFFFF_FFFE crosses the top of the 32-bit
        // address space: MemWrap, never a wrapped read of address zero.
        let mut a = Asm::new();
        a.li(A0, 0xFFFF_FFFEu32 as i32 as i64);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        let err = c.run(10).unwrap_err();
        assert!(
            matches!(err, SimError::MemWrap { addr: 0xFFFF_FFFE, len: 4, .. }),
            "{err}"
        );
        // In-range-but-past-DRAM stays an ordinary MemFault.
        let mut a = Asm::new();
        a.li(A0, 0x7000_0000);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        assert!(matches!(c.run(10), Err(SimError::MemFault { .. })));
    }

    #[test]
    fn oversized_image_is_an_image_fault_not_a_panic() {
        let mut a = Asm::new();
        a.halt();
        let mut p = a.assemble().unwrap();
        p.data_base = 0xFFFF_FF00;
        p.data = vec![0u8; 0x200];
        let mut c = PicoCore::new(PicoConfig::default());
        let err = c.load(&p).unwrap_err();
        assert!(matches!(err, SimError::ImageFault { .. }), "{err}");
    }

    #[test]
    fn stream_copy_rate_matches_paper_band() {
        // Scalar copy loop: paper reports 4.8 MB/s for PicoRV32 Copy.
        let n = 4096usize;
        let p = crate::workloads::memcpy::build_scalar(0x10000, 0x20000, n);
        let mut c = PicoCore::new(PicoConfig::default());
        c.load(&p).unwrap();
        c.host_write(0x10000, &vec![0xA5u8; n]);
        c.run(100_000_000).unwrap();
        assert_eq!(c.dram_slice(0x20000, n), &vec![0xA5u8; n][..]);
        let rate = c.bytes_per_second(n as u64) / 1e6;
        assert!((2.5..8.0).contains(&rate), "PicoRV32 Copy = {rate:.1} MB/s");
    }
}
