//! ARM Cortex-A53 reference model (§4.3 comparisons).
//!
//! The paper compares its softcore against the Ultra96's host cores: an
//! A53 at 1.2 GHz running `qsort()` (sorting baseline) and a serial
//! prefix-sum loop. We have no A53; the paper uses it purely as a ratio
//! anchor ("1.8× over qsort() on ARM", "0.4× the speed of ARM A53").
//!
//! This model is **analytic and calibrated**, not simulated: per-element
//! costs in nanoseconds are taken from public A53 measurements of the
//! same routines (glibc qsort ≈ 10–12 ns per element per log₂n level at
//! 1.2 GHz; a serial dependent-add scan sustains ≈ 1 element/2.5 ns once
//! streaming from DRAM). DESIGN.md records this as a documented
//! substitution; the paper's ratios fall out of these constants together
//! with the simulated softcore times, they are not hard-coded.

/// Clock of the Ultra96's A53 cluster.
pub const A53_CLOCK_GHZ: f64 = 1.2;

/// Calibrated per-element-per-level cost of glibc `qsort()` on A53
/// (indirect comparator call dominates), in nanoseconds. RPi3-class
/// measurements put qsort() of 16M random ints around 7–9 s, i.e.
/// ≈20 ns per element per log₂n level at 1.2 GHz.
pub const QSORT_NS_PER_ELEM_LEVEL: f64 = 20.0;

/// Calibrated serial prefix-sum throughput on A53 (DRAM-resident input),
/// nanoseconds per element: a dependent add chain with one load and one
/// store per element sustains ≈ 2 GB/s effective on the in-order A53.
pub const PREFIX_NS_PER_ELEM: f64 = 3.8;

/// Calibrated NEON memcpy bandwidth on the Ultra96's shared DDR4 (§6
/// notes NEON memcpy reaches high bandwidth on ARM), bytes/second.
pub const MEMCPY_BYTES_PER_SEC: f64 = 2.5e9;

/// Time for `qsort()` of `n` 32-bit elements, in seconds.
pub fn qsort_seconds(n: usize) -> f64 {
    let n_f = n as f64;
    n_f * n_f.log2() * QSORT_NS_PER_ELEM_LEVEL * 1e-9
}

/// Time for a serial prefix sum over `n` 32-bit elements, in seconds.
pub fn prefix_seconds(n: usize) -> f64 {
    n as f64 * PREFIX_NS_PER_ELEM * 1e-9
}

/// Time to memcpy `bytes`, in seconds.
pub fn memcpy_seconds(bytes: usize) -> f64 {
    bytes as f64 / MEMCPY_BYTES_PER_SEC
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qsort_scales_n_log_n() {
        let t1 = qsort_seconds(1 << 20);
        let t2 = qsort_seconds(1 << 21);
        let ratio = t2 / t1;
        assert!((2.0..2.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn paper_sort_anchor_is_in_range() {
        // §4.3.1: softcore mergesort achieved 1.8× over A53 qsort for
        // 64 MiB (16M elements). A53 qsort of 16M elems ≈ 4.2 s with these
        // constants; the softcore mergesort must land near 2.3 s — checked
        // end-to-end in the sec43 bench; here we sanity-check magnitude.
        let t = qsort_seconds(16 * 1024 * 1024);
        assert!((4.0..12.0).contains(&t), "A53 qsort(16M) = {t:.1}s");
    }

    #[test]
    fn prefix_anchor_magnitude() {
        // 16M elements ≈ 42 ms.
        let t = prefix_seconds(16 * 1024 * 1024);
        assert!((0.04..0.12).contains(&t), "A53 prefix(16M) = {t}s");
    }
}
