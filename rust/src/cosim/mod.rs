//! Lockstep co-simulation: step the timed [`Core`] and the reference
//! [`RefIss`] instruction by instruction and report the **first**
//! architectural divergence.
//!
//! Both machines are loaded with the same program and input image by the
//! caller; [`run_lockstep`] then retires one instruction on each side
//! per iteration and compares pc, instret, all 32 base registers and all
//! 8 vector registers. When the run completes (both sides halted, or
//! both sides faulted identically) the final memory images are compared
//! byte for byte. The only sanctioned difference is *time*: after a
//! cycle/time CSR read the timed core's value is injected into the ISS
//! (`RefIss::force_reg`) so downstream dataflow still compares exactly —
//! see the architectural contract in DESIGN.md §9.
//!
//! On divergence the driver produces a [`Divergence`] report: where it
//! happened (pc, instret), every mismatched register with both values,
//! the first mismatched memory byte if any, and a disassembly context
//! window of the instructions leading up to the divergence — everything
//! needed to triage a fuzz failure from the CI artifact alone.

use crate::arch::ArchState;
use crate::core::{Core, SimError};
use crate::isa::instr::csr;
use crate::isa::{Instr, Reg, VReg};
use crate::ref_iss::RefIss;
use std::collections::VecDeque;
use std::fmt;

/// How far back the disassembly context window reaches.
const CONTEXT_WINDOW: usize = 12;

/// How a divergence-free lockstep run ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LockstepOutcome {
    /// Both sides executed the halting `ecall`.
    Halted,
    /// Both sides faulted with the same error at the same pc (a program
    /// bug, not a simulator divergence).
    Faulted(String),
    /// Neither side halted within the instruction budget.
    Watchdog(u64),
}

/// A completed, divergence-free lockstep run.
#[derive(Debug, Clone)]
pub struct LockstepReport {
    pub outcome: LockstepOutcome,
    /// Instructions retired (per side — they are equal by construction).
    pub instret: u64,
}

/// The first architectural divergence between the two machines.
#[derive(Debug, Clone)]
pub struct Divergence {
    /// Retired-instruction index at which the state first differed.
    pub instret: u64,
    pub core_pc: u32,
    pub iss_pc: u32,
    /// One line per mismatched piece of state
    /// (`"a0: core=0x… iss=0x…"`).
    pub deltas: Vec<String>,
    /// `pc: disassembly` lines for the instructions leading up to (and
    /// including) the diverging one.
    pub context: Vec<String>,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "architectural divergence at instret {} (core pc {:#010x}, iss pc {:#010x})",
            self.instret, self.core_pc, self.iss_pc
        )?;
        for d in &self.deltas {
            writeln!(f, "  {d}")?;
        }
        writeln!(f, "  context (most recent last):")?;
        for c in &self.context {
            writeln!(f, "    {c}")?;
        }
        Ok(())
    }
}

impl std::error::Error for Divergence {}

fn error_key(e: &SimError) -> String {
    // Compare faults by kind + location; the embedded sources carry the
    // same data on both sides when the fault is genuinely identical.
    match e {
        SimError::Illegal { pc, source } => format!("illegal@{pc:#010x}:{source}"),
        SimError::MemFault { pc, addr, len, .. } => {
            format!("memfault@{pc:#010x}:{addr:#010x}+{len}")
        }
        SimError::MemWrap { pc, addr, len } => {
            format!("memwrap@{pc:#010x}:{addr:#010x}+{len}")
        }
        SimError::FetchFault { pc, .. } => format!("fetchfault@{pc:#010x}"),
        SimError::FetchMisaligned { pc } => format!("fetchmisaligned@{pc:#010x}"),
        SimError::Unit { pc, source } => format!("unit@{pc:#010x}:{source}"),
        SimError::Watchdog(n) => format!("watchdog:{n}"),
        SimError::Break(pc) => format!("ebreak@{pc:#010x}"),
        SimError::ImageFault { addr, len, .. } => {
            format!("imagefault:{addr:#010x}+{len}")
        }
    }
}

/// Whether a [`LockstepOutcome::Faulted`] key names an instruction-fetch
/// fault (wild or misaligned jump target). Kept next to [`error_key`]
/// so the two stay in sync — the fuzz campaign uses this to sanction
/// wild-jump cases instead of matching key prefixes by hand.
pub fn is_fetch_fault_key(key: &str) -> bool {
    key.starts_with("fetchfault@") || key.starts_with("fetchmisaligned@")
}

/// Compare every piece of per-step architectural state; `deltas` is left
/// empty when the machines agree.
fn compare_state(core: &Core, iss: &RefIss, deltas: &mut Vec<String>) {
    if ArchState::pc(core) != ArchState::pc(iss) {
        deltas.push(format!(
            "pc: core={:#010x} iss={:#010x}",
            ArchState::pc(core),
            ArchState::pc(iss)
        ));
    }
    if ArchState::instret(core) != ArchState::instret(iss) {
        deltas.push(format!(
            "instret: core={} iss={}",
            ArchState::instret(core),
            ArchState::instret(iss)
        ));
    }
    for n in 1..32u8 {
        let r = Reg(n);
        let (c, i) = (ArchState::reg(core, r), ArchState::reg(iss, r));
        if c != i {
            deltas.push(format!("{r}: core={c:#010x} iss={i:#010x}"));
        }
    }
    for n in 1..8u8 {
        let v = VReg(n);
        let (c, i) = (ArchState::vreg(core, v), ArchState::vreg(iss, v));
        if c != i {
            deltas.push(format!("{v}: core={c} iss={i}"));
        }
    }
}

/// Compare the full memory images (the core side must be flushed first).
fn compare_memory(core: &Core, iss: &RefIss, deltas: &mut Vec<String>) {
    let n = ArchState::mem_size(core).min(ArchState::mem_size(iss));
    if ArchState::mem_size(core) != ArchState::mem_size(iss) {
        deltas.push(format!(
            "memory size: core={} iss={}",
            ArchState::mem_size(core),
            ArchState::mem_size(iss)
        ));
    }
    let (a, b) = (ArchState::mem_slice(core, 0, n), ArchState::mem_slice(iss, 0, n));
    if a == b {
        return; // the common case: one memcmp, no byte scan
    }
    if let Some(at) = (0..n).find(|&i| a[i] != b[i]) {
        deltas.push(format!(
            "memory[{:#010x}]: core={:#04x} iss={:#04x} (first of {} differing bytes)",
            at,
            a[at],
            b[at],
            (at..n).filter(|&i| a[i] != b[i]).count()
        ));
    }
}

/// Render one line of a disassembly context window. Shared between the
/// lockstep divergence report and the static analyzer's pc-anchored
/// findings so both read identically.
pub fn context_line(pc: u32, i: &Instr) -> String {
    format!("{pc:#010x}: {i}")
}

fn divergence(
    core: &Core,
    iss: &RefIss,
    deltas: Vec<String>,
    window: &VecDeque<(u32, Instr)>,
) -> Box<Divergence> {
    Box::new(Divergence {
        instret: ArchState::instret(iss),
        core_pc: ArchState::pc(core),
        iss_pc: ArchState::pc(iss),
        deltas,
        context: window.iter().map(|(pc, i)| context_line(*pc, i)).collect(),
    })
}

/// Step both machines in lockstep until they halt, fault identically,
/// or exhaust `max_instrs`; returns the first divergence otherwise.
///
/// Caller contract: both machines are freshly loaded with the same
/// program and the same input image, and their memory sizes are equal
/// (use the core's `dram_size()` when constructing the ISS).
pub fn run_lockstep(
    core: &mut Core,
    iss: &mut RefIss,
    max_instrs: u64,
) -> Result<LockstepReport, Box<Divergence>> {
    let mut window: VecDeque<(u32, Instr)> = VecDeque::with_capacity(CONTEXT_WINDOW + 1);
    let mut deltas = Vec::new();
    compare_state(core, iss, &mut deltas);
    if !deltas.is_empty() {
        return Err(divergence(core, iss, deltas, &window));
    }
    let mut retired = 0u64;
    loop {
        match (core.halted(), ArchState::halted(iss)) {
            (true, true) => break,
            (false, false) => {}
            (c, _) => {
                let deltas = vec![format!(
                    "halt state: core={} iss={}",
                    if c { "halted" } else { "running" },
                    if c { "running" } else { "halted" }
                )];
                return Err(divergence(core, iss, deltas, &window));
            }
        }
        if retired >= max_instrs {
            return Ok(LockstepReport {
                outcome: LockstepOutcome::Watchdog(max_instrs),
                instret: retired,
            });
        }
        let iss_pc = ArchState::pc(iss);
        let core_res = core.step();
        let iss_res = iss.step();
        match (&core_res, &iss_res) {
            (Ok(()), Ok(instr)) => {
                window.push_back((iss_pc, *instr));
                if window.len() > CONTEXT_WINDOW {
                    window.pop_front();
                }
                // The one architecturally timing-dependent value: after
                // a cycle/time CSR read, adopt the timed core's value so
                // downstream dataflow stays comparable.
                if let Instr::Csrrs { rd, csr: c, .. } = *instr {
                    if matches!(c, csr::CYCLE | csr::TIME | csr::CYCLEH | csr::TIMEH) {
                        iss.force_reg(rd, core.reg(rd));
                    }
                }
                retired += 1;
            }
            (Err(ce), Err(ie)) => {
                let (ck, ik) = (error_key(ce), error_key(ie));
                if ck == ik {
                    // Both sides faulted identically: architectural
                    // agreement on a program fault.
                    core.flush_fetch_credits();
                    core.mem.flush_all();
                    let mut deltas = Vec::new();
                    compare_memory(core, iss, &mut deltas);
                    if !deltas.is_empty() {
                        return Err(divergence(core, iss, deltas, &window));
                    }
                    return Ok(LockstepReport {
                        outcome: LockstepOutcome::Faulted(ck),
                        instret: retired,
                    });
                }
                let deltas = vec![format!("fault: core={ck} iss={ik}")];
                return Err(divergence(core, iss, deltas, &window));
            }
            (Ok(()), Err(ie)) => {
                let deltas = vec![format!("fault: core=<none> iss={}", error_key(ie))];
                return Err(divergence(core, iss, deltas, &window));
            }
            (Err(ce), Ok(_)) => {
                let deltas = vec![format!("fault: core={} iss=<none>", error_key(ce))];
                return Err(divergence(core, iss, deltas, &window));
            }
        }
        let mut deltas = Vec::new();
        compare_state(core, iss, &mut deltas);
        if !deltas.is_empty() {
            return Err(divergence(core, iss, deltas, &window));
        }
    }
    // Both halted: the final memory images must be bit-identical.
    core.flush_fetch_credits();
    core.mem.flush_all();
    let mut deltas = Vec::new();
    compare_memory(core, iss, &mut deltas);
    if !deltas.is_empty() {
        return Err(divergence(core, iss, deltas, &window));
    }
    Ok(LockstepReport { outcome: LockstepOutcome::Halted, instret: retired })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    const MEM: usize = 2 * 1024 * 1024;

    fn pair(build: impl FnOnce(&mut Asm)) -> (Core, RefIss) {
        let mut a = Asm::new();
        build(&mut a);
        let p = a.assemble().unwrap();
        let mut mem = crate::mem::MemConfig::paper_default();
        mem.dram.size_bytes = MEM;
        let mut core = Core::new(crate::core::CoreConfig::paper_default(), mem);
        core.load(&p).unwrap();
        let mut iss = RefIss::paper_default(core.mem.dram_size());
        iss.load(&p).unwrap();
        (core, iss)
    }

    #[test]
    fn agreeing_run_reports_halted() {
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, 7);
            let l = a.new_label("l");
            a.li(A1, 0);
            a.bind(l);
            a.add(A1, A1, A0);
            a.addi(A0, A0, -1);
            a.bnez(A0, l);
            a.rdcycle(S0); // timing-dependent read: synced, not a divergence
            a.slli(S1, S0, 1); // ... and its dataflow must still agree
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 10_000).expect("no divergence");
        assert_eq!(r.outcome, LockstepOutcome::Halted);
        assert_eq!(r.instret, core.instret());
        assert_eq!(iss.reg(S1), core.reg(S0) << 1);
    }

    #[test]
    fn vector_run_agrees_including_memory() {
        let (mut core, mut iss) = pair(|a| {
            let d = a.words("d", &[9, 8, 7, 6, 5, 4, 3, 2].map(|x: i32| x as u32));
            a.dalign(32);
            let out = a.buffer("out", 32, 32);
            a.la(A0, d);
            a.la(A1, out);
            a.lv(V1, A0, ZERO);
            a.sort8(V2, V1);
            a.sv(V2, A1, ZERO);
            a.prefix_reset();
            a.prefix(V3, V2);
            a.sv(V3, A0, ZERO);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 10_000).expect("no divergence");
        assert_eq!(r.outcome, LockstepOutcome::Halted);
    }

    #[test]
    fn injected_register_corruption_is_reported() {
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, 5);
            a.addi(A0, A0, 1);
            a.halt();
        });
        iss.force_reg(S3, 0xDEAD);
        let d = run_lockstep(&mut core, &mut iss, 100).expect_err("must diverge");
        assert!(d.deltas.iter().any(|s| s.contains("s3")), "{d}");
        let text = d.to_string();
        assert!(text.contains("divergence at instret"), "{text}");
    }

    #[test]
    fn injected_memory_corruption_is_reported() {
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, 5);
            a.halt();
        });
        iss.host_write(0x4_0000, &[0xAB]).unwrap();
        let d = run_lockstep(&mut core, &mut iss, 100).expect_err("must diverge");
        assert!(d.deltas.iter().any(|s| s.contains("memory[0x00040000]")), "{d}");
    }

    #[test]
    fn fetch_fault_keys_are_recognised() {
        assert!(is_fetch_fault_key(&error_key(&SimError::FetchFault { pc: 0x10, size: 4 })));
        assert!(is_fetch_fault_key(&error_key(&SimError::FetchMisaligned { pc: 0x12 })));
        assert!(!is_fetch_fault_key(&error_key(&SimError::Break(0x10))));
        assert!(!is_fetch_fault_key(&error_key(&SimError::MemFault {
            pc: 0x10,
            addr: 0x20,
            len: 4,
            size: 64,
        })));
    }

    #[test]
    fn identical_faults_agree() {
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, 0x7fff_f000u32 as i64);
            a.lw(A1, 0, A0);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 100).expect("identical faults agree");
        assert!(matches!(r.outcome, LockstepOutcome::Faulted(_)), "{:?}", r.outcome);
    }

    #[test]
    fn wrapping_access_faults_identically_on_both_sides() {
        // A 4-byte load at 0xFFFF_FFFE crosses the top of the 32-bit
        // address space; both backends must classify it as a wrap fault
        // (not an out-of-DRAM fault, and never a wrapped access to
        // address zero) with the same key.
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, 0xFFFF_FFFEu32 as i32 as i64);
            a.lw(A1, 0, A0);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 100).expect("identical wrap faults agree");
        match r.outcome {
            LockstepOutcome::Faulted(key) => {
                assert!(key.starts_with("memwrap@"), "{key}");
                assert!(key.ends_with(":0xfffffffe+4"), "{key}");
            }
            other => panic!("expected a wrap fault, got {other:?}"),
        }
        // Same for a store: a half-word at 0xFFFF_FFFF wraps.
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, u32::MAX as i32 as i64);
            a.li(A1, 1);
            a.sh(A1, 0, A0);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 100).expect("identical wrap faults agree");
        match r.outcome {
            LockstepOutcome::Faulted(key) => {
                assert!(key.starts_with("memwrap@"), "{key}");
                assert!(key.ends_with(":0xffffffff+2"), "{key}");
            }
            other => panic!("expected a wrap fault, got {other:?}"),
        }
    }

    #[test]
    fn access_ending_exactly_at_the_dram_top_is_legal() {
        // The last word of DRAM is addressable (end == size is in
        // bounds); one byte further is an ordinary out-of-DRAM fault.
        let (mut core, mut iss) = pair(|a| {
            a.li(A0, (MEM - 4) as i64);
            a.li(A1, 77);
            a.sw(A1, 0, A0);
            a.lw(A2, 0, A0);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 100).expect("no divergence");
        assert_eq!(r.outcome, LockstepOutcome::Halted);
        assert_eq!(core.reg(A2), 77);

        let (mut core, mut iss) = pair(|a| {
            a.li(A0, (MEM - 3) as i64);
            a.lw(A2, 0, A0);
            a.halt();
        });
        let r = run_lockstep(&mut core, &mut iss, 100).expect("identical faults agree");
        match r.outcome {
            LockstepOutcome::Faulted(key) => {
                assert!(key.starts_with("memfault@"), "{key}");
            }
            other => panic!("expected an out-of-DRAM fault, got {other:?}"),
        }
    }

    #[test]
    fn watchdog_is_not_a_divergence() {
        let (mut core, mut iss) = pair(|a| {
            let l = a.here("forever");
            a.j(l);
        });
        let r = run_lockstep(&mut core, &mut iss, 50).expect("lockstep watchdog");
        assert_eq!(r.outcome, LockstepOutcome::Watchdog(50));
    }
}
