//! HLO-backed custom units: the same `CustomUnit` interface as the native
//! units in `simd::units`, but every invocation executes the AOT-compiled
//! Pallas datapath through PJRT — the simulator literally "runs the
//! loaded bitstream" for each instruction call.
//!
//! Latencies still come from the network structure (they are a property
//! of the *hardware shape*, not of how we compute the result), so a core
//! with an HLO pool reports identical cycle counts to a native-pool core;
//! only the datapath evaluation differs. `fabric_crosscheck.rs` asserts
//! both properties.

use super::Fabric;
use crate::simd::{
    networks, CustomUnit, MemUnit, UnitError, UnitInputs, UnitOutput, UnitPool, VecVal,
};
use std::cell::RefCell;
use std::rc::Rc;

/// Shared handle to an open fabric (single-threaded simulator; PJRT
/// objects are not Send).
pub type FabricHandle = Rc<RefCell<Fabric>>;

/// c2: sorting network evaluated through the `sort8_b1` artifact.
pub struct HloSortUnit {
    fabric: FabricHandle,
    lanes: usize,
    latency: u64,
}

impl CustomUnit for HloSortUnit {
    fn name(&self) -> &'static str {
        "sort[hlo]"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        (funct3 == 0).then_some("sort via AOT pallas artifact")
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        if inp.funct3 != 0 {
            return Err(UnitError::BadFunct3 { unit: "sort[hlo]", funct3: inp.funct3 });
        }
        if inp.vrs1.lanes() != self.lanes {
            return Err(UnitError::BadLanes {
                unit: "sort[hlo]",
                expected: self.lanes,
                got: inp.vrs1.lanes(),
            });
        }
        let rows = inp.vrs1.to_i32s();
        let sorted = self
            .fabric
            .borrow_mut()
            .sort_rows(&rows, 1)
            .unwrap_or_else(|e| panic!("fabric sort failed: {e}"));
        Ok(UnitOutput::vector(VecVal::from_i32s(&sorted), self.latency))
    }
}

/// c1: merge block through `merge_b1` (funct3 1/2 elementwise helpers are
/// delegated to the native implementation — they are not part of the
/// paper's artifact set).
pub struct HloMergeUnit {
    fabric: FabricHandle,
    native: crate::simd::MergeUnit,
    lanes: usize,
    latency: u64,
}

impl CustomUnit for HloMergeUnit {
    fn name(&self) -> &'static str {
        "merge[hlo]"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        match funct3 {
            0 => Some("odd-even merge via AOT pallas artifact"),
            _ => self.native.describe(funct3),
        }
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        if inp.funct3 != 0 {
            return self.native.execute(inp);
        }
        for v in [&inp.vrs1, &inp.vrs2] {
            if v.lanes() != self.lanes {
                return Err(UnitError::BadLanes {
                    unit: "merge[hlo]",
                    expected: self.lanes,
                    got: v.lanes(),
                });
            }
        }
        let (lo, hi) = self
            .fabric
            .borrow_mut()
            .merge_rows(&inp.vrs1.to_i32s(), &inp.vrs2.to_i32s(), 1)
            .unwrap_or_else(|e| panic!("fabric merge failed: {e}"));
        Ok(UnitOutput {
            rd: None,
            vrd1: Some(VecVal::from_i32s(&lo)),
            vrd2: Some(VecVal::from_i32s(&hi)),
            mem: None,
            latency: self.latency,
        })
    }
}

/// c3: prefix sum through `prefix_b1`; the carry register lives in the
/// unit (as in hardware) and is passed through the artifact explicitly.
pub struct HloPrefixUnit {
    fabric: FabricHandle,
    lanes: usize,
    latency: u64,
    carry: i32,
}

impl CustomUnit for HloPrefixUnit {
    fn name(&self) -> &'static str {
        "prefix[hlo]"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        match funct3 {
            0 => Some("prefix scan via AOT pallas artifact"),
            1 => Some("reset carry"),
            2 => Some("read carry"),
            _ => None,
        }
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        match inp.funct3 {
            0 => {
                if inp.vrs1.lanes() != self.lanes {
                    return Err(UnitError::BadLanes {
                        unit: "prefix[hlo]",
                        expected: self.lanes,
                        got: inp.vrs1.lanes(),
                    });
                }
                let (out, carry) = self
                    .fabric
                    .borrow_mut()
                    .prefix(&inp.vrs1.to_i32s(), 1, self.carry)
                    .unwrap_or_else(|e| panic!("fabric prefix failed: {e}"));
                self.carry = carry;
                Ok(UnitOutput::vector(VecVal::from_i32s(&out), self.latency))
            }
            1 => {
                self.carry = 0;
                Ok(UnitOutput::nothing(1))
            }
            2 => Ok(UnitOutput::scalar(self.carry as u32, 1)),
            f3 => Err(UnitError::BadFunct3 { unit: "prefix[hlo]", funct3: f3 }),
        }
    }

    fn reset(&mut self) {
        self.carry = 0;
    }

    fn is_stateful(&self) -> bool {
        true
    }
}

/// Build a unit pool whose datapaths execute through the fabric
/// artifacts (c0 stays native: load/store is DL1 wiring, not fabric
/// logic — as in the paper, where c0 is provided by the framework).
pub fn hlo_pool(fabric: FabricHandle, vlen_bits: usize) -> UnitPool {
    let lanes = vlen_bits / 32;
    let mut pool = UnitPool::empty();
    pool.load(0, Box::new(MemUnit::new(lanes)));
    pool.load(
        1,
        Box::new(HloMergeUnit {
            fabric: fabric.clone(),
            native: crate::simd::MergeUnit::new(lanes),
            lanes,
            latency: networks::merge_latency(2 * lanes),
        }),
    );
    pool.load(
        2,
        Box::new(HloSortUnit {
            fabric: fabric.clone(),
            lanes,
            latency: networks::sort_latency(lanes),
        }),
    );
    pool.load(
        3,
        Box::new(HloPrefixUnit { fabric, lanes, latency: networks::prefix_latency(lanes), carry: 0 }),
    );
    pool
}
