//! The reconfigurable-fabric runtime: loads the JAX/Pallas-authored HLO
//! artifacts (`artifacts/*.hlo.txt`, built once by `make artifacts`) and
//! executes them on the XLA PJRT CPU client.
//!
//! This is the repository's analogue of the paper's *small reconfigurable
//! region*: an instruction's datapath is a loadable artifact, not
//! hard-wired logic. The simulator can run each custom instruction either
//! natively (`simd::units`, the fast path) or through the compiled
//! artifact ([`hlo_pool`]), and `rust/tests/fabric_crosscheck.rs` asserts
//! the two backends are bit-identical — the reproduction's equivalent of
//! validating a bitstream against its RTL model.
//!
//! Python never appears on this path: artifacts are plain HLO text files;
//! loading and execution is rust + PJRT only.
//!
//! The PJRT path needs the `xla` bindings, which are unavailable in the
//! default (offline, dependency-free) build, so everything that touches
//! PJRT sits behind the **`pjrt` cargo feature**. The artifact-directory
//! probes ([`artifacts_available`], [`default_artifact_dir`]) stay
//! unconditional so feature-less builds can still report fabric status,
//! and fabric-dependent tests skip-with-a-note when artifacts are absent.

#[cfg(feature = "pjrt")]
pub mod hlo_unit;

#[cfg(feature = "pjrt")]
pub use hlo_unit::hlo_pool;

#[cfg(feature = "pjrt")]
pub use fabric::Fabric;

use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// True if `dir` holds a built artifact set.
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.txt").exists()
}

/// Locate the artifact dir from the current working directory or the
/// repo root (tests run from target subdirs).
pub fn default_artifact_dir() -> PathBuf {
    for cand in [ARTIFACT_DIR, "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if artifacts_available(&p) {
            return p;
        }
    }
    PathBuf::from(ARTIFACT_DIR)
}

#[cfg(feature = "pjrt")]
mod fabric {
    use super::{artifacts_available, default_artifact_dir};
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A loaded fabric: the PJRT client plus lazily-compiled executables.
    pub struct Fabric {
        client: xla::PjRtClient,
        dir: PathBuf,
        /// name → artifact file (from manifest.txt).
        files: HashMap<String, PathBuf>,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        pub lanes: usize,
    }

    impl Fabric {
        /// True if `dir` holds a built artifact set.
        pub fn available(dir: impl AsRef<Path>) -> bool {
            artifacts_available(dir)
        }

        /// Locate the artifact dir from the current working directory or
        /// the repo root (tests run from target subdirs).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Open the fabric: parse the manifest, create the PJRT client.
        /// Executables are compiled on first use.
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
            let mut files = HashMap::new();
            let mut lanes = 8usize;
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix('#') {
                    if let Some(l) = rest.split("lanes=").nth(1) {
                        lanes = l.trim().parse().unwrap_or(8);
                    }
                    continue;
                }
                let mut parts = line.split('\t');
                if let (Some(name), Some(rel)) = (parts.next(), parts.next()) {
                    files.insert(name.to_string(), dir.join(rel));
                }
            }
            if files.is_empty() {
                bail!("manifest {manifest:?} lists no artifacts");
            }
            let client = xla::PjRtClient::cpu()?;
            Ok(Self { client, dir, files, exes: HashMap::new(), lanes })
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        /// Artifact names listed in the manifest.
        pub fn names(&self) -> Vec<String> {
            let mut v: Vec<String> = self.files.keys().cloned().collect();
            v.sort();
            v
        }

        /// Ensure `name` is compiled ("load the bitstream into the slot").
        pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
            if self.exes.contains_key(name) {
                return Ok(());
            }
            let path = self
                .files
                .get(name)
                .ok_or_else(|| anyhow!("fabric has no artifact '{name}'"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.exes.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` over i32 inputs with explicit dims;
        /// returns each tuple element flattened.
        pub fn run_i32(
            &mut self,
            name: &str,
            inputs: &[(&[i32], &[i64])],
        ) -> Result<Vec<Vec<i32>>> {
            self.ensure_compiled(name)?;
            let exe = self.exes.get(name).expect("just compiled");
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data);
                let lit = if dims.len() == 1 && dims[0] == data.len() as i64 {
                    lit
                } else {
                    lit.reshape(dims)?
                };
                lits.push(lit);
            }
            let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<i32>().map_err(Into::into))
                .collect()
        }

        // ---- typed wrappers over the standard artifact set --------------

        fn batched(&self, base: &str, batch: usize) -> String {
            format!("{base}_b{batch}")
        }

        /// c2_sort over a batch: `rows` is `batch × lanes` i32 values.
        pub fn sort_rows(&mut self, rows: &[i32], batch: usize) -> Result<Vec<i32>> {
            let lanes = self.lanes;
            debug_assert_eq!(rows.len(), batch * lanes);
            let name = self.batched("sort8", batch);
            let out = self.run_i32(&name, &[(rows, &[batch as i64, lanes as i64])])?;
            Ok(out.into_iter().next().expect("1-tuple"))
        }

        /// c1_merge over a batch; returns (low halves, high halves).
        pub fn merge_rows(
            &mut self,
            a: &[i32],
            b: &[i32],
            batch: usize,
        ) -> Result<(Vec<i32>, Vec<i32>)> {
            let lanes = self.lanes;
            debug_assert_eq!(a.len(), batch * lanes);
            debug_assert_eq!(b.len(), batch * lanes);
            let name = self.batched("merge", batch);
            let dims = [batch as i64, lanes as i64];
            let mut out = self.run_i32(&name, &[(a, &dims), (b, &dims)])?;
            let hi = out.pop().ok_or_else(|| anyhow!("merge returned <2 results"))?;
            let lo = out.pop().ok_or_else(|| anyhow!("merge returned <2 results"))?;
            Ok((lo, hi))
        }

        /// c3_prefix over a batch with carry; returns (scanned, carry_out).
        pub fn prefix(&mut self, x: &[i32], batch: usize, carry: i32) -> Result<(Vec<i32>, i32)> {
            let lanes = self.lanes;
            debug_assert_eq!(x.len(), batch * lanes);
            let name = self.batched("prefix", batch);
            let carry_in = [carry];
            let mut out = self.run_i32(
                &name,
                &[(x, &[batch as i64, lanes as i64]), (&carry_in, &[1])],
            )?;
            let carry_out = out.pop().ok_or_else(|| anyhow!("prefix returned <2 results"))?;
            let scanned = out.pop().ok_or_else(|| anyhow!("prefix returned <2 results"))?;
            Ok((scanned, carry_out[0]))
        }

        /// The L2 whole-block sorter artifact (`sort_block_N`).
        pub fn sort_block(&mut self, x: &[i32]) -> Result<Vec<i32>> {
            let name = format!("sort_block_{}", x.len());
            let out = self.run_i32(&name, &[(x, &[x.len() as i64])])?;
            Ok(out.into_iter().next().expect("1-tuple"))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // Full end-to-end fabric tests live in
        // rust/tests/fabric_crosscheck.rs (they need built artifacts).
        // Here: error-path handling only.

        #[test]
        fn open_missing_dir_errors_helpfully() {
            let err = match Fabric::open("/nonexistent/path") {
                Err(e) => e,
                Ok(_) => panic!("open should fail"),
            };
            assert!(format!("{err:#}").contains("make artifacts"), "{err}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_is_false_for_missing_dir() {
        assert!(!artifacts_available("/nonexistent/path"));
    }

    #[test]
    fn default_dir_falls_back_to_artifact_dir_name() {
        // In a checkout without built artifacts this returns the default
        // name; with artifacts it returns an existing manifest dir.
        let d = default_artifact_dir();
        assert!(artifacts_available(&d) || d == PathBuf::from(ARTIFACT_DIR));
    }
}
