//! Minimal JSON values for the service wire protocol and the result
//! store. The default build carries no serde (Cargo.toml keeps it
//! dependency-free on purpose), and the service only needs flat
//! objects of numbers/strings plus one level of nesting for machine
//! points and sweep specs — a few hundred lines of recursive descent
//! cover that with exact, deterministic output formatting (which the
//! content-addressed store depends on).

use std::collections::BTreeMap;

pub use crate::coordinator::report::json_escape;

/// A parsed JSON value. Objects use a [`BTreeMap`], so re-rendering a
/// value always produces sorted keys — the canonical form the store
/// hashes.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse one JSON document, rejecting trailing garbage.
    pub fn parse(s: &str) -> Result<Value, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric field as an exact unsigned integer (rejects fractions,
    /// negatives, and magnitudes above 2^53 where f64 loses exactness).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.007_199_254_740_992e15 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Render back to JSON text: object keys sorted (BTreeMap order),
    /// integers without a fractional part — deterministic for the
    /// value shapes the service produces.
    pub fn render(&self) -> String {
        match self {
            Value::Null => "null".into(),
            Value::Bool(b) => b.to_string(),
            Value::Num(n) => fmt_num(*n),
            Value::Str(s) => format!("\"{}\"", json_escape(s)),
            Value::Arr(a) => {
                let items: Vec<String> = a.iter().map(|v| v.render()).collect();
                format!("[{}]", items.join(","))
            }
            Value::Obj(m) => {
                let items: Vec<String> = m
                    .iter()
                    .map(|(k, v)| format!("\"{}\":{}", json_escape(k), v.render()))
                    .collect();
                format!("{{{}}}", items.join(","))
            }
        }
    }
}

/// Integers render without a trailing `.0`; other finite numbers use
/// Rust's shortest-roundtrip `Display`. Non-finite values have no JSON
/// spelling and become null.
pub fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        "null".into()
    } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected character '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            let hex = std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by any
                            // service producer; map them to U+FFFD
                            // rather than erroring on foreign input.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("unknown escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so the
                    // byte stream is valid UTF-8).
                    let rest = std::str::from_utf8(&self.b[self.i..]).expect("input was &str");
                    let c = rest.chars().next().expect("peeked non-empty");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii slice");
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }
}

/// Incremental builder for one flat JSON object line (insertion order
/// preserved — the writers pass keys already sorted where canonical
/// output matters).
pub struct ObjWriter {
    parts: Vec<String>,
}

impl ObjWriter {
    pub fn new() -> Self {
        Self { parts: Vec::new() }
    }

    pub fn field_raw(&mut self, key: &str, raw_json: &str) -> &mut Self {
        self.parts.push(format!("\"{}\":{}", json_escape(key), raw_json));
        self
    }

    pub fn field_str(&mut self, key: &str, v: &str) -> &mut Self {
        let quoted = format!("\"{}\"", json_escape(v));
        self.field_raw(key, &quoted)
    }

    pub fn field_u64(&mut self, key: &str, v: u64) -> &mut Self {
        self.field_raw(key, &v.to_string())
    }

    pub fn field_f64(&mut self, key: &str, v: f64) -> &mut Self {
        let s = fmt_num(v);
        self.field_raw(key, &s)
    }

    pub fn field_bool(&mut self, key: &str, v: bool) -> &mut Self {
        self.field_raw(key, if v { "true" } else { "false" })
    }

    pub fn finish(&self) -> String {
        format!("{{{}}}", self.parts.join(","))
    }
}

impl Default for ObjWriter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5").unwrap(), Value::Num(-12.5));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
        let v = Value::parse("[1, 2, [3]]").unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 3);
        let v = Value::parse("{\"a\": 1, \"b\": {\"c\": [true, null]}}").unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "tru", "\"unterminated", "1 2", "{\"a\" 1}"] {
            assert!(Value::parse(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = Value::parse("\"a\\\"b\\\\c\\n\\t\\u0041\"").unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\n\tA"));
        let rendered = Value::Str("a\"b\\c\n".into()).render();
        assert_eq!(Value::parse(&rendered).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn as_u64_rejects_lossy_numbers() {
        assert_eq!(Value::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Value::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Value::parse("4096").unwrap().as_u64(), Some(4096));
    }

    #[test]
    fn render_sorts_object_keys() {
        let v = Value::parse("{\"b\":2,\"a\":1}").unwrap();
        assert_eq!(v.render(), "{\"a\":1,\"b\":2}");
        assert_eq!(Value::parse("[1,2.5]").unwrap().render(), "[1,2.5]");
    }

    #[test]
    fn obj_writer_builds_lines() {
        let mut w = ObjWriter::new();
        w.field_str("cmd", "submit").field_u64("n", 3).field_bool("ok", true);
        let line = w.finish();
        assert_eq!(line, "{\"cmd\":\"submit\",\"n\":3,\"ok\":true}");
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("cmd").unwrap().as_str(), Some("submit"));
    }

    #[test]
    fn fmt_num_is_integer_exact() {
        assert_eq!(fmt_num(150.0), "150");
        assert_eq!(fmt_num(0.25), "0.25");
        assert_eq!(fmt_num(f64::NAN), "null");
    }
}
