//! Content-addressed result store: one append-only JSONL file, one line
//! per completed job, indexed by [`Job::key`].
//!
//! The store is the service's durability and caching layer in one
//! mechanism. Records are only ever appended (a line per result, flushed
//! immediately), so a crash loses at most the line being written — and
//! [`ResultStore::open`] tolerates exactly that by skipping an
//! unparseable trailing line. Re-submitting a grid against the same
//! store turns every already-completed point into a cache hit; a
//! crashed run resumes by reopening the store and executing only the
//! missing points.
//!
//! Cache-correctness rules:
//!
//! - Lookups match on the **stored** key, which was computed by the
//!   binary that produced the record. [`super::CODE_VERSION`] is part of
//!   the hashed canonical string, so records written by an older code
//!   version simply never match a current key — stale results are never
//!   served and never deleted.
//! - Only `status == ok` records are served from cache. Failed records
//!   are persisted (they carry the error and attempt count for
//!   reporting), but a resume re-executes them — a transient failure
//!   must not become permanent by being cached.

use super::json::{self, ObjWriter, Value};
use super::{Job, JobKind, Outcome};
use crate::coordinator::sweep::MachinePoint;
use crate::workloads::Variant;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

/// Terminal state of a stored job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    /// The job executed and verified (where applicable).
    Ok,
    /// The job exhausted its retries (simulation fault, watchdog,
    /// timeout, or fuzz divergence).
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Ok => "ok",
            JobStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "ok" => Ok(JobStatus::Ok),
            "failed" => Ok(JobStatus::Failed),
            other => Err(format!("unknown status '{other}'")),
        }
    }
}

/// One line of the store: a job, its terminal status, and (for `Ok`)
/// the measured outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultRecord {
    /// [`Job::key`] as computed by the producing binary.
    pub key: u64,
    pub job: Job,
    pub status: JobStatus,
    /// Last attempt's error for `Failed` records.
    pub error: Option<String>,
    pub outcome: Option<Outcome>,
    /// Executions it took to reach the terminal status (1 = first try).
    pub attempts: u32,
    /// Wall-clock time of the *successful* (or final) attempt.
    pub wall_ms: u64,
    /// Runtime-only: `true` when this record was served from the store
    /// rather than executed. Never serialized.
    pub from_cache: bool,
}

impl ResultRecord {
    pub fn ok(job: Job, outcome: Outcome, attempts: u32, wall_ms: u64) -> Self {
        let key = job.key();
        Self {
            key,
            job,
            status: JobStatus::Ok,
            error: None,
            outcome: Some(outcome),
            attempts,
            wall_ms,
            from_cache: false,
        }
    }

    pub fn failed(job: Job, error: String, attempts: u32, wall_ms: u64) -> Self {
        let key = job.key();
        Self {
            key,
            job,
            status: JobStatus::Failed,
            error: Some(error),
            outcome: None,
            attempts,
            wall_ms,
            from_cache: false,
        }
    }

    /// Serialize as one JSONL line (no trailing newline). Top-level keys
    /// are emitted in sorted order; `key` is a 16-digit hex string (a
    /// JSON number would lose u64 exactness past 2^53), and so is the
    /// fuzz `seed`.
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("attempts", self.attempts as u64);
        if let Some(b) = self.job.budget {
            w.field_u64("budget", b);
        }
        if let Some(e) = &self.error {
            w.field_str("error", e);
        }
        w.field_str("key", &format!("{:016x}", self.key));
        match &self.job.kind {
            JobKind::Sim { .. } => w.field_str("kind", "sim"),
            JobKind::Fuzz { .. } => w.field_str("kind", "fuzz"),
        };
        if let JobKind::Fuzz { ops, .. } = &self.job.kind {
            w.field_u64("ops", *ops as u64);
        }
        if let Some(o) = &self.outcome {
            w.field_raw("outcome", &outcome_to_json(o));
        }
        w.field_raw("point", &self.job.point.canonical());
        match &self.job.kind {
            JobKind::Sim { size, .. } => {
                w.field_u64("size", *size as u64);
            }
            JobKind::Fuzz { seed, .. } => {
                w.field_str("seed", &format!("{seed:016x}"));
            }
        }
        w.field_str("status", self.status.name());
        if let JobKind::Sim { variant, .. } = &self.job.kind {
            w.field_str("variant", variant.name());
        }
        w.field_u64("wall_ms", self.wall_ms);
        if let JobKind::Fuzz { weights, .. } = &self.job.kind {
            w.field_str("weights", weights);
        }
        if let JobKind::Sim { workload, .. } = &self.job.kind {
            w.field_str("workload", workload);
        }
        w.finish()
    }

    /// Parse one store line back into a record.
    pub fn from_json(line: &str) -> Result<Self, String> {
        let v = Value::parse(line)?;
        let str_field = |name: &str| -> Result<&str, String> {
            v.get(name)
                .and_then(Value::as_str)
                .ok_or_else(|| format!("record missing string field '{name}'"))
        };
        let u64_field = |name: &str| -> Result<u64, String> {
            v.get(name)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("record missing integer field '{name}'"))
        };
        let key = u64::from_str_radix(str_field("key")?, 16)
            .map_err(|_| "bad hex in 'key'".to_string())?;
        let point = MachinePoint::from_canonical_fields(|axis| {
            v.get("point").and_then(|p| p.get(axis)).and_then(Value::as_usize)
        })?;
        let kind = match str_field("kind")? {
            "sim" => {
                let variant = Variant::parse(str_field("variant")?)
                    .ok_or_else(|| "bad 'variant'".to_string())?;
                JobKind::Sim {
                    workload: str_field("workload")?.to_string(),
                    variant,
                    size: u64_field("size")? as usize,
                }
            }
            "fuzz" => JobKind::Fuzz {
                seed: u64::from_str_radix(str_field("seed")?, 16)
                    .map_err(|_| "bad hex in 'seed'".to_string())?,
                ops: u64_field("ops")? as usize,
                weights: str_field("weights")?.to_string(),
            },
            other => return Err(format!("unknown job kind '{other}'")),
        };
        let budget = match v.get("budget") {
            None => None,
            Some(b) => Some(b.as_u64().ok_or_else(|| "bad 'budget'".to_string())?),
        };
        let status = JobStatus::parse(str_field("status")?)?;
        let outcome = match v.get("outcome") {
            None => None,
            Some(o) => Some(outcome_from_json(o)?),
        };
        let error = match v.get("error") {
            None => None,
            Some(e) => {
                Some(e.as_str().ok_or_else(|| "bad 'error'".to_string())?.to_string())
            }
        };
        Ok(Self {
            key,
            job: Job { point, kind, budget },
            status,
            error,
            outcome,
            attempts: u64_field("attempts")? as u32,
            wall_ms: u64_field("wall_ms")?,
            from_cache: false,
        })
    }

    /// Timing-independent identity of the *result*: the serialized
    /// record with wall-clock time and attempt count zeroed. Two runs
    /// of the same deterministic grid — interrupted or not, cached or
    /// executed — must produce equal fingerprints.
    pub fn fingerprint(&self) -> String {
        Self { wall_ms: 0, attempts: 0, ..self.clone() }.to_json()
    }
}

/// Outcome as a nested JSON object with sorted keys. `metrics` keys are
/// already sorted (BTreeMap); `verified` is `true`/`false`/`null`.
fn outcome_to_json(o: &Outcome) -> String {
    let mut w = ObjWriter::new();
    w.field_u64("bytes", o.bytes);
    w.field_u64("cycles", o.cycles);
    w.field_f64("fmax_mhz", o.fmax_mhz);
    w.field_u64("instret", o.instret);
    let metrics: Vec<String> = o
        .metrics
        .iter()
        .map(|(k, v)| format!("\"{}\":{}", json::json_escape(k), v))
        .collect();
    w.field_raw("metrics", &format!("{{{}}}", metrics.join(",")));
    match o.verified {
        Some(b) => w.field_bool("verified", b),
        None => w.field_raw("verified", "null"),
    };
    w.finish()
}

fn outcome_from_json(v: &Value) -> Result<Outcome, String> {
    let u64_field = |name: &str| -> Result<u64, String> {
        v.get(name)
            .and_then(Value::as_u64)
            .ok_or_else(|| format!("outcome missing integer field '{name}'"))
    };
    let mut metrics = BTreeMap::new();
    if let Some(m) = v.get("metrics").and_then(Value::as_obj) {
        for (k, val) in m {
            metrics.insert(
                k.clone(),
                val.as_u64().ok_or_else(|| format!("bad metric '{k}'"))?,
            );
        }
    }
    let verified = match v.get("verified") {
        None | Some(Value::Null) => None,
        Some(b) => Some(b.as_bool().ok_or_else(|| "bad 'verified'".to_string())?),
    };
    Ok(Outcome {
        cycles: u64_field("cycles")?,
        instret: u64_field("instret")?,
        bytes: u64_field("bytes")?,
        fmax_mhz: v
            .get("fmax_mhz")
            .and_then(Value::as_f64)
            .ok_or_else(|| "outcome missing 'fmax_mhz'".to_string())?,
        verified,
        metrics,
    })
}

/// The append-only JSONL store with an in-memory index over the `Ok`
/// records. All mutation goes through `&mut self`; concurrent surfaces
/// (the grid runner's workers, the serve loop) share it behind a
/// `Mutex`.
pub struct ResultStore {
    path: Option<PathBuf>,
    file: Option<File>,
    records: Vec<ResultRecord>,
    /// key → index into `records` of the latest `Ok` record. Failed
    /// records are never indexed (never served from cache).
    ok_index: BTreeMap<u64, usize>,
    /// Store lines that did not parse on open (a crash-truncated tail,
    /// or records from a foreign schema) — skipped, counted, kept on
    /// disk.
    skipped_lines: usize,
    hits: u64,
}

impl ResultStore {
    /// A store with no backing file (tests, ad-hoc grids).
    pub fn in_memory() -> Self {
        Self {
            path: None,
            file: None,
            records: Vec::new(),
            ok_index: BTreeMap::new(),
            skipped_lines: 0,
            hits: 0,
        }
    }

    /// Open (or create) the JSONL store at `path`, loading every
    /// parseable record. Unparseable lines — e.g. the torn final line
    /// of a crashed writer — are skipped and counted, never fatal.
    pub fn open(path: impl AsRef<Path>) -> Result<Self, String> {
        let path = path.as_ref();
        let mut store = Self::in_memory();
        store.path = Some(path.to_path_buf());
        if path.exists() {
            let f = File::open(path).map_err(|e| format!("open {}: {e}", path.display()))?;
            for line in BufReader::new(f).lines() {
                let line = line.map_err(|e| format!("read {}: {e}", path.display()))?;
                if line.trim().is_empty() {
                    continue;
                }
                match ResultRecord::from_json(&line) {
                    Ok(rec) => store.insert(rec),
                    Err(_) => store.skipped_lines += 1,
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("append-open {}: {e}", path.display()))?;
        store.file = Some(file);
        Ok(store)
    }

    fn insert(&mut self, rec: ResultRecord) {
        if rec.status == JobStatus::Ok {
            self.ok_index.insert(rec.key, self.records.len());
        }
        self.records.push(rec);
    }

    /// Serve `key` from cache if a completed (`Ok`) record exists.
    /// Counts a hit and returns a clone flagged `from_cache`.
    pub fn lookup(&mut self, key: u64) -> Option<ResultRecord> {
        let idx = *self.ok_index.get(&key)?;
        self.hits += 1;
        let mut rec = self.records[idx].clone();
        rec.from_cache = true;
        Some(rec)
    }

    /// Append a terminal record: one JSONL line, flushed before the
    /// index is updated (crash durability — an indexed record is always
    /// on disk).
    pub fn record(&mut self, rec: &ResultRecord) -> Result<(), String> {
        if let Some(f) = &mut self.file {
            let path = self.path.as_deref().unwrap_or(Path::new("<store>"));
            writeln!(f, "{}", rec.to_json())
                .and_then(|()| f.flush())
                .map_err(|e| format!("append {}: {e}", path.display()))?;
        }
        self.insert(rec.clone());
        Ok(())
    }

    /// Cache hits served so far (the crash-resume tests assert on this).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Records loaded + recorded (including `Failed` ones).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Completed (`Ok`, cache-servable) record count.
    pub fn completed(&self) -> usize {
        self.ok_index.len()
    }

    /// Lines skipped on open (torn tail / foreign schema).
    pub fn skipped_lines(&self) -> usize {
        self.skipped_lines
    }

    pub fn records(&self) -> &[ResultRecord] {
        &self.records
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Variant;
    use std::collections::BTreeMap;

    fn sample_outcome() -> Outcome {
        let mut metrics = BTreeMap::new();
        metrics.insert("llc_prefetches".to_string(), 42u64);
        metrics.insert("dram_queue_cycles".to_string(), 7u64);
        Outcome {
            cycles: 1000,
            instret: 800,
            bytes: 65536,
            fmax_mhz: 150.0,
            verified: Some(true),
            metrics,
        }
    }

    fn sim_job() -> Job {
        Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 65536)
    }

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("simdsoftcore_store_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn records_round_trip_through_jsonl() {
        let ok = ResultRecord::ok(sim_job(), sample_outcome(), 1, 123);
        let line = ok.to_json();
        let back = ResultRecord::from_json(&line).unwrap();
        assert_eq!(back, ok);
        assert!(!back.from_cache);

        let failed = ResultRecord::failed(
            sim_job().with_budget(100),
            "simulation failed: watchdog: exceeded 100 instructions".into(),
            3,
            55,
        );
        let back = ResultRecord::from_json(&failed.to_json()).unwrap();
        assert_eq!(back, failed);
        assert_eq!(back.job.budget, Some(100));

        let fuzz = ResultRecord::ok(
            Job::fuzz(MachinePoint::default(), u64::MAX - 1, 300, "balanced"),
            Outcome { instret: 299, verified: Some(true), ..Default::default() },
            1,
            9,
        );
        let back = ResultRecord::from_json(&fuzz.to_json()).unwrap();
        assert_eq!(back, fuzz, "u64-range seeds survive the hex encoding");
    }

    #[test]
    fn record_lines_have_sorted_keys_and_hex_key() {
        let line = ResultRecord::ok(sim_job(), sample_outcome(), 1, 123).to_json();
        assert!(line.starts_with("{\"attempts\":1,\"key\":\""), "{line}");
        assert!(line.contains(&format!("\"key\":\"{:016x}\"", sim_job().key())), "{line}");
        // Top-level keys come out in sorted order.
        let parsed = Value::parse(&line).unwrap();
        let stored_keys: Vec<&str> = parsed.as_obj().unwrap().keys().map(String::as_str).collect();
        let mut sorted = stored_keys.clone();
        sorted.sort_unstable();
        assert_eq!(stored_keys, sorted);
        // Re-rendering the parsed value (BTreeMap = sorted keys) gives
        // back the exact line: the writer IS canonical.
        assert_eq!(parsed.render(), line);
    }

    #[test]
    fn store_appends_reopens_and_serves_cache_hits() {
        let path = tmp_path("reopen");
        let _ = std::fs::remove_file(&path);
        let job = sim_job();
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert!(s.is_empty());
            s.record(&ResultRecord::ok(job.clone(), sample_outcome(), 1, 10)).unwrap();
            assert_eq!(s.len(), 1);
            assert_eq!(s.completed(), 1);
        }
        let mut s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.hits(), 0);
        let hit = s.lookup(job.key()).expect("reopened store must serve the record");
        assert!(hit.from_cache);
        assert_eq!(hit.outcome.as_ref().unwrap().cycles, 1000);
        assert_eq!(s.hits(), 1);
        assert!(s.lookup(0xdead_beef).is_none());
        assert_eq!(s.hits(), 1, "misses are not hits");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_records_are_stored_but_never_served() {
        let mut s = ResultStore::in_memory();
        let job = sim_job();
        s.record(&ResultRecord::failed(job.clone(), "boom".into(), 2, 5)).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.completed(), 0);
        assert!(s.lookup(job.key()).is_none(), "failures must be re-executed on resume");
        // A later success for the same key becomes servable.
        s.record(&ResultRecord::ok(job.clone(), sample_outcome(), 3, 8)).unwrap();
        let hit = s.lookup(job.key()).unwrap();
        assert_eq!(hit.status, JobStatus::Ok);
        assert_eq!(hit.attempts, 3);
    }

    #[test]
    fn torn_tail_lines_are_skipped_not_fatal() {
        let path = tmp_path("torn");
        let _ = std::fs::remove_file(&path);
        let good = ResultRecord::ok(sim_job(), sample_outcome(), 1, 10).to_json();
        // A crash mid-write leaves a truncated final line.
        std::fs::write(&path, format!("{good}\n{}", &good[..good.len() / 2])).unwrap();
        let mut s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.skipped_lines(), 1);
        assert!(s.lookup(sim_job().key()).is_some());
        // The store remains appendable after a torn tail.
        s.record(&ResultRecord::failed(
            Job::sim(MachinePoint::default(), "memcpy", Variant::Scalar, 64),
            "x".into(),
            1,
            1,
        ))
        .unwrap();
        assert_eq!(ResultStore::open(&path).unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_code_version_records_never_match_live_keys() {
        // A record whose key was computed by a different code version
        // sits in the store under the *old* digest: the live job's key
        // differs, so lookup misses and the point re-executes.
        let mut s = ResultStore::in_memory();
        let mut old = ResultRecord::ok(sim_job(), sample_outcome(), 1, 10);
        old.key ^= 0x1; // simulate a digest from another CODE_VERSION
        s.record(&old).unwrap();
        assert!(s.lookup(sim_job().key()).is_none());
    }

    #[test]
    fn fingerprint_ignores_timing_but_not_results() {
        let a = ResultRecord::ok(sim_job(), sample_outcome(), 1, 10);
        let b = ResultRecord::ok(sim_job(), sample_outcome(), 2, 99);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut different = sample_outcome();
        different.cycles += 1;
        let c = ResultRecord::ok(sim_job(), different, 1, 10);
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
