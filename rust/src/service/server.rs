//! `--serve` mode: a line-delimited JSON API over stdio or a local TCP
//! socket. One JSON object per line in, one per line out; background
//! grid runs stream `result` events interleaved with command replies
//! (every line is self-describing, so clients just parse each line and
//! dispatch on `ok`/`event`).
//!
//! ## Protocol
//!
//! Requests (`cmd` field):
//!
//! - `{"cmd":"ping"}` → `{"ok":true,"reply":"pong","version":...}`
//! - `{"cmd":"submit", ...}` — build a job grid and start running it in
//!   the background. Fields:
//!   - `"sim": {"workloads":[...], "variants":[...], "size":N}` —
//!     scenario jobs (variants defaults to each workload's supported
//!     set; size to its default size);
//!   - `"fuzz": {"base_seed":N, "seeds":N, "ops":N, "weights":"..."}` —
//!     differential-fuzz jobs, one per seed;
//!   - `"point": {"mshrs":4, ...}` — base machine-point overrides;
//!   - `"sweep": {"vlen":[128,256], ...}` — machine axes to cross
//!     (cartesian product);
//!   - `"budget"`, `"timeout_ms"`, `"retries"` — per-point policy
//!     overrides; `"shards"`/`"shard"` — deterministic partition
//!     selection ([`super::shard_of`]).
//!
//!   Replies `{"id":N,"jobs":J,"ok":true}` immediately, then emits one
//!   `{"cached":...,"event":"result","id":N,"label":...,"record":{...}}`
//!   per terminal point and a final `{"event":"done","id":N,
//!   "progress":{...}}`.
//! - `{"cmd":"progress"}` / `{"cmd":"progress","id":N}` — snapshot(s)
//!   of submission progress (completed/cached/failed/running and
//!   points/sec).
//! - `{"cmd":"shutdown"}` — drain every running submission, reply
//!   `{"ok":true,"reply":"bye"}`, close the session. EOF drains too
//!   (results already acknowledged are in the store either way).
//!
//! Malformed input never kills the session: it produces
//! `{"error":...,"ok":false}`.
//!
//! Two concurrent submissions of the *same* grid may both execute a
//! point (each missed the cache before the other recorded); the store
//! appends both records and serves the latest — duplicated work, never
//! wrong results.

use super::json::{ObjWriter, Value};
use super::progress::Progress;
use super::queue::{self, GridOptions};
use super::store::ResultStore;
use super::Job;
use crate::coordinator::sweep::{MachinePoint, Parallelism};
use crate::workloads::{self, Variant};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Server-side defaults for submissions that don't override them.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub parallelism: Parallelism,
    /// Default per-attempt wall-clock limit.
    pub timeout: Option<Duration>,
    /// Default retry bound.
    pub retries: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { parallelism: Parallelism::auto(), timeout: None, retries: 1 }
    }
}

type SharedWriter = Arc<Mutex<Box<dyn Write + Send>>>;

fn emit(w: &SharedWriter, line: &str) {
    let mut g = w.lock().expect("writer lock");
    let _ = writeln!(g, "{line}");
    let _ = g.flush();
}

fn error_line(msg: &str) -> String {
    let mut w = ObjWriter::new();
    w.field_str("error", msg).field_bool("ok", false);
    w.finish()
}

/// Serve one session over arbitrary reader/writer (the `--serve` stdio
/// mode, and every test harness). Consumes the store; returns it when
/// the session ends so a caller can inspect or reuse it.
pub fn serve(
    input: impl BufRead,
    output: impl Write + Send + 'static,
    store: ResultStore,
    cfg: &ServeConfig,
) -> ResultStore {
    let store = Arc::new(Mutex::new(store));
    let writer: SharedWriter = Arc::new(Mutex::new(Box::new(output)));
    let next_id = AtomicU64::new(1);
    serve_conn(input, &writer, &store, cfg, &next_id);
    Arc::try_unwrap(store)
        .unwrap_or_else(|_| panic!("submissions drained, no store refs remain"))
        .into_inner()
        .expect("store lock")
}

/// Serve TCP clients sequentially until one sends `shutdown`. Local
/// tooling speaks the same protocol as stdio; binding is the caller's
/// responsibility (use `127.0.0.1:0` and print the port for tests).
pub fn serve_tcp(listener: &TcpListener, store: ResultStore, cfg: &ServeConfig) -> ResultStore {
    let store = Arc::new(Mutex::new(store));
    let next_id = AtomicU64::new(1);
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        let reader = BufReader::new(match stream.try_clone() {
            Ok(s) => s,
            Err(_) => continue,
        });
        let writer: SharedWriter = Arc::new(Mutex::new(Box::new(stream)));
        if serve_conn(reader, &writer, &store, cfg, &next_id) {
            break;
        }
    }
    Arc::try_unwrap(store)
        .unwrap_or_else(|_| panic!("submissions drained, no store refs remain"))
        .into_inner()
        .expect("store lock")
}

/// One client session. Returns `true` when the client asked the server
/// to shut down (vs just disconnecting).
fn serve_conn(
    input: impl BufRead,
    writer: &SharedWriter,
    store: &Arc<Mutex<ResultStore>>,
    cfg: &ServeConfig,
    next_id: &AtomicU64,
) -> bool {
    let mut running: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut submissions: HashMap<u64, Arc<Progress>> = HashMap::new();
    let mut shutdown = false;
    for line in input.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let v = match Value::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                emit(writer, &error_line(&format!("bad request: {e}")));
                continue;
            }
        };
        match v.get("cmd").and_then(Value::as_str) {
            Some("ping") => {
                let mut w = ObjWriter::new();
                w.field_bool("ok", true)
                    .field_str("reply", "pong")
                    .field_str("version", super::CODE_VERSION);
                emit(writer, &w.finish());
            }
            Some("submit") => match parse_submit(&v) {
                Err(e) => emit(writer, &error_line(&e)),
                Ok(jobs) => {
                    let id = next_id.fetch_add(1, Ordering::Relaxed);
                    let progress = Arc::new(Progress::new(jobs.len() as u64));
                    submissions.insert(id, Arc::clone(&progress));
                    let mut w = ObjWriter::new();
                    w.field_u64("id", id)
                        .field_u64("jobs", jobs.len() as u64)
                        .field_bool("ok", true);
                    emit(writer, &w.finish());
                    let opts = submit_options(&v, cfg);
                    let store = Arc::clone(store);
                    let out = Arc::clone(writer);
                    running.push(std::thread::spawn(move || {
                        run_submission(id, jobs, &store, &progress, &opts, &out);
                    }));
                }
            },
            Some("progress") => match v.get("id").and_then(Value::as_u64) {
                Some(id) => match submissions.get(&id) {
                    None => emit(writer, &error_line(&format!("unknown submission id {id}"))),
                    Some(p) => {
                        let mut w = ObjWriter::new();
                        w.field_u64("id", id)
                            .field_bool("ok", true)
                            .field_raw("progress", &p.snapshot().to_json());
                        emit(writer, &w.finish());
                    }
                },
                None => {
                    let mut ids: Vec<&u64> = submissions.keys().collect();
                    ids.sort_unstable();
                    let subs: Vec<String> = ids
                        .into_iter()
                        .map(|id| {
                            let mut w = ObjWriter::new();
                            w.field_u64("id", *id)
                                .field_raw("progress", &submissions[id].snapshot().to_json());
                            w.finish()
                        })
                        .collect();
                    let mut w = ObjWriter::new();
                    w.field_bool("ok", true)
                        .field_raw("submissions", &format!("[{}]", subs.join(",")));
                    emit(writer, &w.finish());
                }
            },
            Some("shutdown") => {
                for h in running.drain(..) {
                    let _ = h.join();
                }
                let mut w = ObjWriter::new();
                w.field_bool("ok", true).field_str("reply", "bye");
                emit(writer, &w.finish());
                shutdown = true;
                break;
            }
            Some(other) => {
                emit(writer, &error_line(&format!("unknown cmd '{other}'")));
            }
            None => emit(writer, &error_line("request needs a string 'cmd' field")),
        }
    }
    // EOF or shutdown: drain outstanding submissions either way so the
    // store is quiescent when the session ends.
    for h in running {
        let _ = h.join();
    }
    shutdown
}

/// Run one submission's grid, streaming `result` events and the final
/// `done` event.
fn run_submission(
    id: u64,
    jobs: Vec<Job>,
    store: &Mutex<ResultStore>,
    progress: &Progress,
    opts: &GridOptions,
    out: &SharedWriter,
) {
    let exec = queue::default_exec();
    queue::run_grid(jobs, store, progress, opts, &exec, |rec| {
        let mut w = ObjWriter::new();
        w.field_bool("cached", rec.from_cache)
            .field_str("event", "result")
            .field_u64("id", id)
            .field_str("label", &rec.job.label())
            .field_raw("record", &rec.to_json());
        emit(out, &w.finish());
    });
    let mut w = ObjWriter::new();
    w.field_str("event", "done")
        .field_u64("id", id)
        .field_raw("progress", &progress.snapshot().to_json());
    emit(out, &w.finish());
}

/// Grid policy for one submission: server defaults plus per-submission
/// overrides.
fn submit_options(v: &Value, cfg: &ServeConfig) -> GridOptions {
    GridOptions {
        parallelism: cfg.parallelism,
        timeout: v
            .get("timeout_ms")
            .and_then(Value::as_u64)
            .map(Duration::from_millis)
            .or(cfg.timeout),
        retries: v.get("retries").and_then(Value::as_u64).map(|n| n as u32).unwrap_or(cfg.retries),
        stop_after: None,
    }
}

/// Expand a `submit` request into its job list (validated enough to
/// reject whole-request mistakes up front; per-point validation happens
/// again in the queue).
fn parse_submit(v: &Value) -> Result<Vec<Job>, String> {
    // Base machine point + sweep axes → point grid.
    let mut base = MachinePoint::default();
    if let Some(overrides) = v.get("point") {
        let obj = overrides.as_obj().ok_or("'point' must be an object")?;
        for (axis, val) in obj {
            let n = val.as_usize().ok_or_else(|| format!("bad value for point axis '{axis}'"))?;
            if !base.set(axis, n) {
                return Err(format!(
                    "unknown machine axis '{axis}' (axes: {})",
                    MachinePoint::AXES.join(", ")
                ));
            }
        }
    }
    let mut grid = vec![base];
    if let Some(sweep) = v.get("sweep") {
        let obj = sweep.as_obj().ok_or("'sweep' must be an object of axis:[values]")?;
        for (axis, vals) in obj {
            if !MachinePoint::is_axis(axis) {
                return Err(format!(
                    "unknown sweep axis '{axis}' (axes: {})",
                    MachinePoint::AXES.join(", ")
                ));
            }
            let vals: Vec<usize> = vals
                .as_arr()
                .ok_or_else(|| format!("sweep axis '{axis}' must map to an array"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| format!("bad value in sweep axis '{axis}'")))
                .collect::<Result<_, _>>()?;
            if vals.is_empty() {
                return Err(format!("sweep axis '{axis}' has no values"));
            }
            let mut expanded = Vec::with_capacity(grid.len() * vals.len());
            for p in &grid {
                for &val in &vals {
                    let mut p = *p;
                    p.set(axis, val);
                    expanded.push(p);
                }
            }
            grid = expanded;
        }
    }

    let budget = match v.get("budget") {
        None => None,
        Some(b) => Some(b.as_u64().ok_or("'budget' must be a non-negative integer")?),
    };

    let mut jobs = Vec::new();
    if let Some(sim) = v.get("sim") {
        let names: Vec<String> = sim
            .get("workloads")
            .and_then(Value::as_arr)
            .ok_or("'sim' needs a 'workloads' array")?
            .iter()
            .map(|w| {
                w.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "workload names must be strings".to_string())
            })
            .collect::<Result<_, _>>()?;
        let requested: Option<Vec<Variant>> = match sim.get("variants") {
            None => None,
            Some(arr) => Some(
                arr.as_arr()
                    .ok_or("'variants' must be an array")?
                    .iter()
                    .map(|s| {
                        s.as_str()
                            .and_then(Variant::parse)
                            .ok_or_else(|| "variants are \"scalar\" or \"vector\"".to_string())
                    })
                    .collect::<Result<_, _>>()?,
            ),
        };
        for name in &names {
            let probe = workloads::lookup(name)
                .ok_or_else(|| format!("unknown workload '{name}'"))?;
            let variants: Vec<Variant> = match &requested {
                // Unspecified: everything the workload implements.
                None => probe.variants().to_vec(),
                Some(req) => req.clone(),
            };
            let size = match sim.get("size") {
                None => probe.default_size(),
                Some(s) => s.as_usize().ok_or("'size' must be a positive integer")?,
            };
            for &point in &grid {
                for &variant in &variants {
                    let mut job = Job::sim(point, name.clone(), variant, size);
                    job.budget = budget;
                    jobs.push(job);
                }
            }
        }
    }
    if let Some(fz) = v.get("fuzz") {
        if fz.as_obj().is_none() {
            return Err("'fuzz' must be an object".to_string());
        }
        let u = |field: &str, default: u64| -> Result<u64, String> {
            match fz.get(field) {
                None => Ok(default),
                Some(x) => x.as_u64().ok_or_else(|| format!("bad 'fuzz.{field}'")),
            }
        };
        let base_seed = u("base_seed", 1)?;
        let seeds = u("seeds", 16)?;
        let ops = u("ops", 300)? as usize;
        let weights = match fz.get("weights") {
            None => "balanced".to_string(),
            Some(w) => w.as_str().ok_or("'fuzz.weights' must be a string")?.to_string(),
        };
        super::resolve_weights(&weights)?;
        for mut job in crate::fuzz::seed_jobs(&grid, base_seed, seeds, ops, &weights) {
            job.budget = budget;
            jobs.push(job);
        }
    }
    if jobs.is_empty() {
        return Err("submit needs a 'sim' and/or 'fuzz' section producing at least one job".into());
    }

    // Deterministic shard selection, if requested.
    if let Some(shards) = v.get("shards").and_then(Value::as_u64) {
        let shard = v.get("shard").and_then(Value::as_u64).unwrap_or(0);
        if shard >= shards.max(1) {
            return Err(format!("shard {shard} out of range for {shards} shards"));
        }
        jobs = queue::shard_filter(jobs, shard, shards);
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// A `Write` the test can read back after `serve` returns.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn run_session(script: &str, store: ResultStore) -> (Vec<Value>, ResultStore) {
        let out = SharedBuf::default();
        let store =
            serve(Cursor::new(script.to_string()), out.clone(), store, &ServeConfig::default());
        let bytes = out.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines = text
            .lines()
            .map(|l| Value::parse(l).unwrap_or_else(|e| panic!("bad output line '{l}': {e}")))
            .collect();
        (lines, store)
    }

    fn count_events(lines: &[Value], kind: &str) -> usize {
        lines
            .iter()
            .filter(|l| l.get("event").and_then(Value::as_str) == Some(kind))
            .count()
    }

    #[test]
    fn scripted_session_pings_submits_and_streams_results() {
        let script = "\
            {\"cmd\":\"ping\"}\n\
            {\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\"variants\":[\"vector\"],\
             \"size\":4096},\"sweep\":{\"vlen\":[128,256]}}\n\
            {\"cmd\":\"shutdown\"}\n";
        let (lines, store) = run_session(script, ResultStore::in_memory());
        // Command replies in order: pong, submit ack, bye.
        assert_eq!(lines[0].get("reply").and_then(Value::as_str), Some("pong"));
        assert!(lines[0].get("version").and_then(Value::as_str).is_some());
        let ack = lines
            .iter()
            .find(|l| l.get("jobs").is_some())
            .expect("submit acknowledgement");
        assert_eq!(ack.get("jobs").and_then(Value::as_u64), Some(2));
        assert_eq!(ack.get("ok").and_then(Value::as_bool), Some(true));
        // Two result events + one done event, then bye last.
        assert_eq!(count_events(&lines, "result"), 2);
        assert_eq!(count_events(&lines, "done"), 1);
        assert_eq!(lines.last().unwrap().get("reply").and_then(Value::as_str), Some("bye"));
        let done = lines
            .iter()
            .find(|l| l.get("event").and_then(Value::as_str) == Some("done"))
            .unwrap();
        let p = done.get("progress").unwrap();
        assert_eq!(p.get("completed").and_then(Value::as_u64), Some(2));
        assert_eq!(p.get("failed").and_then(Value::as_u64), Some(0));
        // Results landed in the store.
        assert_eq!(store.completed(), 2);
    }

    #[test]
    fn malformed_and_unknown_requests_get_error_replies_not_disconnects() {
        let script = "\
            this is not json\n\
            {\"cmd\":\"frobnicate\"}\n\
            {\"nocmd\":1}\n\
            {\"cmd\":\"submit\"}\n\
            {\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"nope\"]}}\n\
            {\"cmd\":\"progress\",\"id\":99}\n\
            {\"cmd\":\"ping\"}\n";
        let (lines, _) = run_session(script, ResultStore::in_memory());
        assert_eq!(lines.len(), 7, "every request gets exactly one reply");
        for l in &lines[..6] {
            assert_eq!(l.get("ok").and_then(Value::as_bool), Some(false), "{l:?}");
            assert!(l.get("error").and_then(Value::as_str).is_some());
        }
        // The session survived to answer the final ping.
        assert_eq!(lines[6].get("reply").and_then(Value::as_str), Some("pong"));
    }

    #[test]
    fn resubmitting_a_grid_against_a_persisted_store_is_all_cache_hits() {
        let mut path = std::env::temp_dir();
        path.push(format!("simdsoftcore_serve_cache_{}", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let submit = "{\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\
                      \"variants\":[\"vector\"],\"size\":4096},\
                      \"sweep\":{\"mshrs\":[1,4]}}\n{\"cmd\":\"shutdown\"}\n";
        let (first, _) = run_session(submit, ResultStore::open(&path).unwrap());
        assert_eq!(count_events(&first, "result"), 2);
        let cached_first = first
            .iter()
            .filter(|l| l.get("cached").and_then(Value::as_bool) == Some(true))
            .count();
        assert_eq!(cached_first, 0);

        // Fresh session, same store file: everything is served cached.
        let (second, store) = run_session(submit, ResultStore::open(&path).unwrap());
        assert_eq!(count_events(&second, "result"), 2);
        let cached_second = second
            .iter()
            .filter(|l| {
                l.get("event").and_then(Value::as_str) == Some("result")
                    && l.get("cached").and_then(Value::as_bool) == Some(true)
            })
            .count();
        assert_eq!(cached_second, 2, "second run must be 100% cache hits");
        assert_eq!(store.hits(), 2);
        let done = second
            .iter()
            .find(|l| l.get("event").and_then(Value::as_str) == Some("done"))
            .unwrap();
        assert_eq!(done.get("progress").unwrap().get("cached").and_then(Value::as_u64), Some(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn progress_command_reports_submissions() {
        // Progress for a finished submission (drained by shutdown) and
        // the aggregate form.
        let script = "{\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\
                      \"variants\":[\"vector\"],\"size\":4096}}\n\
                      {\"cmd\":\"progress\",\"id\":1}\n\
                      {\"cmd\":\"progress\"}\n\
                      {\"cmd\":\"shutdown\"}\n";
        let (lines, _) = run_session(script, ResultStore::in_memory());
        let by_id = lines
            .iter()
            .find(|l| l.get("id").is_some() && l.get("progress").is_some())
            .expect("progress reply");
        assert_eq!(by_id.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(
            by_id.get("progress").unwrap().get("total").and_then(Value::as_u64),
            Some(1)
        );
        let agg = lines
            .iter()
            .find(|l| l.get("submissions").is_some())
            .expect("aggregate progress reply");
        assert_eq!(agg.get("submissions").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn fuzz_submissions_run_seed_ranges_as_jobs() {
        let script = "{\"cmd\":\"submit\",\"fuzz\":{\"base_seed\":5,\"seeds\":3,\"ops\":60}}\n\
                      {\"cmd\":\"shutdown\"}\n";
        let (lines, store) = run_session(script, ResultStore::in_memory());
        let ack = lines.iter().find(|l| l.get("jobs").is_some()).unwrap();
        assert_eq!(ack.get("jobs").and_then(Value::as_u64), Some(3));
        assert_eq!(count_events(&lines, "result"), 3);
        assert_eq!(store.completed(), 3, "all fuzz seeds agreed with the reference ISS");
    }

    #[test]
    fn sharded_submissions_partition_the_grid() {
        // The same submission with shards=2, shard 0 and 1 must cover
        // the full 4-point grid exactly once between them.
        let sub = |shard: u64| {
            format!(
                "{{\"cmd\":\"submit\",\"sim\":{{\"workloads\":[\"memcpy\"],\
                 \"variants\":[\"vector\"],\"size\":4096}},\
                 \"sweep\":{{\"vlen\":[128,256],\"mshrs\":[1,4]}},\
                 \"shards\":2,\"shard\":{shard}}}\n{{\"cmd\":\"shutdown\"}}\n"
            )
        };
        let (l0, s0) = run_session(&sub(0), ResultStore::in_memory());
        let (l1, s1) = run_session(&sub(1), ResultStore::in_memory());
        let j0 = l0.iter().find_map(|l| l.get("jobs").and_then(Value::as_u64)).unwrap();
        let j1 = l1.iter().find_map(|l| l.get("jobs").and_then(Value::as_u64)).unwrap();
        assert_eq!(j0 + j1, 4, "shards partition the grid ({j0} + {j1})");
        assert_eq!(s0.completed() + s1.completed(), 4);
        // Out-of-range shard is rejected.
        let bad = "{\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\"size\":64},\
                   \"shards\":2,\"shard\":5}\n";
        let (lines, _) = run_session(bad, ResultStore::in_memory());
        assert_eq!(lines[0].get("ok").and_then(Value::as_bool), Some(false));
    }

    #[test]
    fn tcp_sessions_speak_the_same_protocol() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpStream;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            serve_tcp(&listener, ResultStore::in_memory(), &ServeConfig::default())
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            b"{\"cmd\":\"ping\"}\n{\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\
              \"variants\":[\"vector\"],\"size\":4096}}\n{\"cmd\":\"shutdown\"}\n",
        )
        .unwrap();
        let mut lines = Vec::new();
        for line in BufReader::new(conn.try_clone().unwrap()).lines() {
            let Ok(line) = line else { break };
            lines.push(Value::parse(&line).unwrap());
        }
        let store = server.join().unwrap();
        assert_eq!(lines[0].get("reply").and_then(Value::as_str), Some("pong"));
        assert!(lines.iter().any(|l| l.get("event").and_then(Value::as_str) == Some("result")));
        assert_eq!(lines.last().unwrap().get("reply").and_then(Value::as_str), Some("bye"));
        assert_eq!(store.completed(), 1);
    }
}
