//! Shared progress accounting for a grid run: lock-free counters the
//! workers bump and the `--serve` `progress` command snapshots. One
//! [`Progress`] value covers one submission; the server keeps one per
//! submission id.

use super::json::ObjWriter;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counters for one submitted grid. All methods take `&self` (atomics),
/// so the value sits in an `Arc` shared by every worker.
#[derive(Debug)]
pub struct Progress {
    total: AtomicU64,
    completed: AtomicU64,
    cached: AtomicU64,
    failed: AtomicU64,
    running: AtomicU64,
    started: Instant,
}

impl Progress {
    pub fn new(total: u64) -> Self {
        Self {
            total: AtomicU64::new(total),
            completed: AtomicU64::new(0),
            cached: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            running: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Grow the job universe (a second submission against the same
    /// progress value).
    pub fn add_total(&self, n: u64) {
        self.total.fetch_add(n, Ordering::Relaxed);
    }

    pub fn start_point(&self) {
        self.running.fetch_add(1, Ordering::Relaxed);
    }

    /// A point finished executing. `ok == false` also counts `failed`.
    pub fn finish_point(&self, ok: bool) {
        self.running.fetch_sub(1, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A point served from the result store (counts as completed too —
    /// the grid's work, not the machine's).
    pub fn cache_hit(&self) {
        self.cached.fetch_add(1, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// A point skipped without a terminal record (shutdown mid-grid).
    pub fn abandon_point(&self) {
        self.running.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            total: self.total.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cached: self.cached.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            running: self.running.load(Ordering::Relaxed),
            elapsed_ms: self.started.elapsed().as_millis() as u64,
        }
    }
}

/// One consistent-enough view of a [`Progress`] (individual counters
/// are exact; the set is racy by a point or two while workers run —
/// fine for a progress API).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgressSnapshot {
    pub total: u64,
    /// Terminal points: executed (ok or failed) + cached.
    pub completed: u64,
    pub cached: u64,
    pub failed: u64,
    pub running: u64,
    pub elapsed_ms: u64,
}

impl ProgressSnapshot {
    pub fn done(&self) -> bool {
        self.completed >= self.total
    }

    /// Terminal points per second of wall clock (cache hits included:
    /// the consumer cares about grid completion speed).
    pub fn points_per_sec(&self) -> f64 {
        if self.elapsed_ms == 0 {
            return 0.0;
        }
        self.completed as f64 * 1000.0 / self.elapsed_ms as f64
    }

    /// The progress object of the JSON API.
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("cached", self.cached);
        w.field_u64("completed", self.completed);
        w.field_u64("elapsed_ms", self.elapsed_ms);
        w.field_u64("failed", self.failed);
        // points_per_sec rounds to 3 decimals so the line stays stable
        // enough to eyeball; the raw counters carry the exact state.
        w.field_f64("points_per_sec", (self.points_per_sec() * 1000.0).round() / 1000.0);
        w.field_u64("running", self.running);
        w.field_u64("total", self.total);
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::json::Value;

    #[test]
    fn counters_track_the_point_lifecycle() {
        let p = Progress::new(4);
        p.start_point();
        let s = p.snapshot();
        assert_eq!((s.total, s.running, s.completed), (4, 1, 0));
        p.finish_point(true);
        p.cache_hit();
        p.start_point();
        p.finish_point(false);
        let s = p.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.cached, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.running, 0);
        assert!(!s.done());
        p.cache_hit();
        assert!(p.snapshot().done());
    }

    #[test]
    fn abandoned_points_leave_completion_untouched() {
        let p = Progress::new(2);
        p.start_point();
        p.abandon_point();
        let s = p.snapshot();
        assert_eq!((s.running, s.completed), (0, 0));
        p.add_total(3);
        assert_eq!(p.snapshot().total, 5);
    }

    #[test]
    fn snapshot_renders_valid_sorted_json() {
        let p = Progress::new(10);
        p.cache_hit();
        let j = p.snapshot().to_json();
        let v = Value::parse(&j).unwrap();
        assert_eq!(v.get("total").unwrap().as_u64(), Some(10));
        assert_eq!(v.get("cached").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("completed").unwrap().as_u64(), Some(1));
        assert!(v.get("points_per_sec").unwrap().as_f64().is_some());
        assert!(j.starts_with("{\"cached\":"), "sorted keys: {j}");
    }

    #[test]
    fn rate_is_zero_before_any_time_passes() {
        let s = ProgressSnapshot {
            total: 1,
            completed: 1,
            cached: 0,
            failed: 0,
            running: 0,
            elapsed_ms: 0,
        };
        assert_eq!(s.points_per_sec(), 0.0);
        let s = ProgressSnapshot { elapsed_ms: 500, ..s };
        assert_eq!(s.points_per_sec(), 2.0);
    }
}
