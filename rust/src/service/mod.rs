//! Fleet-scale sweep service: the orchestration layer that turns the
//! one-shot sweep CLI into a long-running simulation service
//! (ROADMAP "heavy traffic"; DESIGN.md §10 is the contract).
//!
//! The existing coordinator executes a grid with
//! [`crate::coordinator::sweep::parallel_map_bounded`] and throws the
//! results away with the process. This module adds everything around
//! that execution kernel:
//!
//! - **[`Job`]** — one unit of work: a [`MachinePoint`] plus either a
//!   workload scenario ([`JobKind::Sim`]) or a fuzz seed
//!   ([`JobKind::Fuzz`]). Jobs are *content-addressed*: [`Job::key`] is
//!   the FNV-1a digest of a canonical JSON serialization that includes
//!   the code version, so the same point never executes twice across
//!   runs, processes, or machines sharing a store.
//! - **[`store::ResultStore`]** — an append-only JSONL file indexed by
//!   job key. Re-submitting a grid is a cache hit for every point
//!   already present; a crashed run resumes by reopening the store
//!   (a truncated trailing line from the crash is tolerated).
//! - **[`queue`]** — deterministic shard assignment
//!   ([`queue::shard_of`]) and the worker pool ([`queue::run_grid`])
//!   with per-point wall-clock timeout, bounded retry, and progress
//!   accounting — a wedged point fails; it does not stall its shard.
//! - **[`server`]** — the `--serve` mode: a line-delimited JSON API
//!   over stdio or a local TCP socket for submitting grids, polling
//!   [`progress`], and streaming results as they land.
//!
//! The `mem-sweep`/`pipe-sweep` experiments route through this layer
//! (see [`crate::coordinator::experiments::mem_sweep_stored`]), so the
//! existing BENCH trajectories gain persistence and caching for free.

pub mod json;
pub mod progress;
pub mod queue;
pub mod server;
pub mod store;

use crate::coordinator::sweep::{fnv1a64, MachinePoint};
use crate::fuzz::{self, OpWeights};
use crate::workloads::{self, Scenario, Variant, WorkloadReport};
use std::collections::BTreeMap;

pub use progress::{Progress, ProgressSnapshot};
pub use queue::{default_exec, run_grid, shard_filter, shard_of, Exec, GridOptions};
pub use server::{serve, serve_tcp, ServeConfig};
pub use store::{JobStatus, ResultRecord, ResultStore};

/// Version tag folded into every job key. Bump the `+timingN` suffix
/// whenever a change alters simulated timing or architectural results:
/// old store entries then simply stop matching (the store is
/// append-only; stale records are never served, never deleted).
pub const CODE_VERSION: &str = concat!("simdsoftcore-", env!("CARGO_PKG_VERSION"), "+timing1");

/// What a [`Job`] executes at its machine point.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// One registered workload scenario (the sweep grids).
    Sim { workload: String, variant: Variant, size: usize },
    /// One differential-fuzz case: a seed run in lockstep against the
    /// reference ISS. `weights` is a preset name (`balanced`, `scalar`,
    /// `vector`, `wild`) or a `class=N,...` spec.
    Fuzz { seed: u64, ops: usize, weights: String },
}

/// One unit of service work: a machine configuration plus what to run
/// on it. Plain data (`Send`), cheap to clone; the worker thread builds
/// the core.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub point: MachinePoint,
    pub kind: JobKind,
    /// Retired-instruction watchdog for `Sim` jobs (`None` = the
    /// generous `workloads::common::MAX_INSTRS`). Part of the job
    /// identity: a budget-limited run is a different experiment from an
    /// unlimited one.
    pub budget: Option<u64>,
}

impl Job {
    pub fn sim(
        point: MachinePoint,
        workload: impl Into<String>,
        variant: Variant,
        size: usize,
    ) -> Self {
        let kind = JobKind::Sim { workload: workload.into(), variant, size };
        Self { point, kind, budget: None }
    }

    pub fn fuzz(point: MachinePoint, seed: u64, ops: usize, weights: impl Into<String>) -> Self {
        Self { point, kind: JobKind::Fuzz { seed, ops, weights: weights.into() }, budget: None }
    }

    pub fn with_budget(mut self, max_instrs: u64) -> Self {
        self.budget = Some(max_instrs);
        self
    }

    /// Stable canonical serialization of the full job identity —
    /// `(machine point, work, code version)` — with sorted keys and no
    /// float formatting anywhere. [`Job::key`] hashes these bytes;
    /// cache correctness across processes depends on this string being
    /// bit-stable, so its shape is pinned by unit tests.
    pub fn canonical(&self) -> String {
        let mut s = String::from("{");
        if let Some(b) = self.budget {
            s.push_str(&format!("\"budget\":{b},"));
        }
        s.push_str(&format!("\"code\":\"{}\",", json::json_escape(CODE_VERSION)));
        match &self.kind {
            JobKind::Sim { workload, variant, size } => {
                s.push_str(&format!(
                    "\"kind\":\"sim\",\"point\":{},\"size\":{},\"variant\":\"{}\",\
                     \"workload\":\"{}\"",
                    self.point.canonical(),
                    size,
                    variant.name(),
                    json::json_escape(workload)
                ));
            }
            JobKind::Fuzz { seed, ops, weights } => {
                s.push_str(&format!(
                    "\"kind\":\"fuzz\",\"ops\":{},\"point\":{},\"seed\":{},\"weights\":\"{}\"",
                    ops,
                    self.point.canonical(),
                    seed,
                    json::json_escape(weights)
                ));
            }
        }
        s.push('}');
        s
    }

    /// The content address of this job in the result store.
    pub fn key(&self) -> u64 {
        fnv1a64(self.canonical().as_bytes())
    }

    /// Short human-readable label for logs and the `result` events.
    pub fn label(&self) -> String {
        let p = &self.point;
        let mp = format!(
            "vlen={} llc={} mshrs={} pf={} ch={} iw={}",
            p.vlen, p.llc_block, p.mshrs, p.prefetch, p.channels, p.issue_width
        );
        match &self.kind {
            JobKind::Sim { workload, variant, size } => {
                format!("{workload}/{variant}/{size} [{mp}]")
            }
            JobKind::Fuzz { seed, ops, weights } => {
                format!("fuzz/seed{seed}/{ops}ops/{weights} [{mp}]")
            }
        }
    }

    /// Reject jobs the executor cannot run, before they enter a queue.
    pub fn validate(&self) -> Result<(), String> {
        self.point.validate()?;
        match &self.kind {
            JobKind::Sim { workload, variant, size } => {
                let Some(probe) = workloads::lookup(workload) else {
                    let names: Vec<&str> = workloads::registry().iter().map(|e| e.name).collect();
                    return Err(format!(
                        "unknown workload '{workload}' (known: {})",
                        names.join(", ")
                    ));
                };
                if !probe.variants().contains(variant) {
                    return Err(format!("workload '{workload}' has no {variant} variant"));
                }
                if *size == 0 {
                    return Err("size must be positive".into());
                }
            }
            JobKind::Fuzz { ops, weights, .. } => {
                if *ops == 0 || *ops > 50_000 {
                    return Err(format!("fuzz ops must be in 1..=50000, got {ops}"));
                }
                resolve_weights(weights)?;
            }
        }
        Ok(())
    }
}

/// Uniform measured result of a completed job — everything the sweep
/// tables and the JSON API report, in integer counters plus the clock.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Outcome {
    pub cycles: u64,
    pub instret: u64,
    pub bytes: u64,
    pub fmax_mhz: f64,
    /// `Some(outcome)` when verification ran (always for `Sim`; for
    /// `Fuzz`, agreement with the reference ISS).
    pub verified: Option<bool>,
    /// Named auxiliary counters (stall/prefetch/issue statistics) the
    /// experiment tables render.
    pub metrics: BTreeMap<String, u64>,
}

impl Outcome {
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes as f64 / self.cycles.max(1) as f64
    }

    pub fn bytes_per_second(&self) -> f64 {
        self.bytes_per_cycle() * self.fmax_mhz * 1e6
    }

    pub fn ipc(&self) -> f64 {
        self.instret as f64 / self.cycles.max(1) as f64
    }

    pub fn metric(&self, name: &str) -> u64 {
        self.metrics.get(name).copied().unwrap_or(0)
    }

    fn from_report(r: &WorkloadReport) -> Self {
        let mut metrics = BTreeMap::new();
        metrics.insert("dl1_misses".into(), r.mem.dl1.misses);
        metrics.insert("dram_queue_cycles".into(), r.mem.dram.queue_cycles);
        metrics.insert("dual_issue_pairs".into(), r.counters.dual_issue_pairs);
        metrics.insert("issue_slots_wasted".into(), r.counters.issue_slots_wasted);
        metrics.insert("llc_prefetches".into(), r.mem.llc.prefetches);
        metrics.insert("mem_bw_stall_cycles".into(), r.counters.mem_bw_stall_cycles);
        metrics.insert("mem_struct_stall_cycles".into(), r.counters.mem_struct_stall_cycles);
        Self {
            cycles: r.throughput.cycles,
            instret: r.throughput.instret,
            bytes: r.throughput.bytes,
            fmax_mhz: r.throughput.fmax_mhz,
            verified: r.verified,
            metrics,
        }
    }
}

/// Resolve a weights string: a preset name or a `class=N,...` spec.
pub fn resolve_weights(spec: &str) -> Result<OpWeights, String> {
    match spec {
        "balanced" => Ok(OpWeights::balanced()),
        "scalar" => Ok(OpWeights::scalar()),
        "vector" => Ok(OpWeights::vector()),
        "wild" => Ok(OpWeights::wild()),
        other => OpWeights::parse(other),
    }
}

/// Execute one job to completion in the calling thread. This is the
/// service's execution kernel: [`queue::run_grid`] calls it (via
/// [`default_exec`]) from its workers; a failed run — simulation
/// fault, watchdog, verify failure of a fuzz case — is an `Err` the
/// queue retries up to its bound.
pub fn execute(job: &Job) -> Result<Outcome, String> {
    match &job.kind {
        JobKind::Sim { workload, variant, size } => {
            let mut w = workloads::lookup(workload)
                .ok_or_else(|| format!("unknown workload '{workload}'"))?;
            let budget = job.budget.unwrap_or(crate::workloads::common::MAX_INSTRS);
            let report = job
                .point
                .machine()
                .run_budget(&mut *w, &Scenario::new(*variant, *size), budget)
                .map_err(|e| e.to_string())?;
            Ok(Outcome::from_report(&report))
        }
        JobKind::Fuzz { seed, ops, weights } => {
            let w = resolve_weights(weights)?;
            match fuzz::run_case(*seed, *ops, weights, &w, &job.point) {
                Ok(instrs) => Ok(Outcome {
                    cycles: 0,
                    instret: instrs,
                    bytes: 0,
                    fmax_mhz: 0.0,
                    verified: Some(true),
                    metrics: BTreeMap::new(),
                }),
                Err(f) => Err(format!("fuzz case diverged/failed: {}", f.report)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_canonical_form_is_pinned() {
        // The store's cache keys hash this string: its exact shape is
        // load-bearing (DESIGN.md §10 documents it).
        let j = Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 65536);
        assert_eq!(
            j.canonical(),
            format!(
                "{{\"code\":\"{CODE_VERSION}\",\"kind\":\"sim\",\"point\":{},\"size\":65536,\
                 \"variant\":\"vector\",\"workload\":\"memcpy\"}}",
                MachinePoint::default().canonical()
            )
        );
        let f = Job::fuzz(MachinePoint::default(), 7, 100, "balanced");
        assert_eq!(
            f.canonical(),
            format!(
                "{{\"code\":\"{CODE_VERSION}\",\"kind\":\"fuzz\",\"ops\":100,\"point\":{},\
                 \"seed\":7,\"weights\":\"balanced\"}}",
                MachinePoint::default().canonical()
            )
        );
        // A budget changes the identity (prefix position: sorted keys).
        let b = j.clone().with_budget(1000);
        assert!(b.canonical().starts_with("{\"budget\":1000,\"code\":"));
        assert_ne!(b.key(), j.key());
    }

    #[test]
    fn job_keys_separate_points_workloads_and_code_version() {
        let base = Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 4096);
        let other_point = Job::sim(
            MachinePoint { vlen: 512, ..Default::default() },
            "memcpy",
            Variant::Vector,
            4096,
        );
        let other_wl = Job::sim(MachinePoint::default(), "prefix", Variant::Vector, 4096);
        let other_variant = Job::sim(MachinePoint::default(), "memcpy", Variant::Scalar, 4096);
        let keys = [base.key(), other_point.key(), other_wl.key(), other_variant.key()];
        for (i, a) in keys.iter().enumerate() {
            for b in keys.iter().skip(i + 1) {
                assert_ne!(a, b, "distinct jobs must have distinct keys");
            }
        }
        // Same job → same key, every time (content addressing).
        let again = Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 4096);
        assert_eq!(base.key(), again.key());
        assert!(base.canonical().contains(CODE_VERSION), "key covers the code version");
    }

    #[test]
    fn job_validation_rejects_garbage() {
        let good = Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 4096);
        assert!(good.validate().is_ok());
        assert!(Job::sim(MachinePoint::default(), "nope", Variant::Vector, 1).validate().is_err());
        assert!(Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 0)
            .validate()
            .is_err());
        // dhrystone is scalar-only.
        assert!(Job::sim(MachinePoint::default(), "dhrystone", Variant::Vector, 10)
            .validate()
            .is_err());
        let bad_point = MachinePoint { vlen: 100, ..Default::default() };
        assert!(Job::sim(bad_point, "memcpy", Variant::Vector, 4096).validate().is_err());
        assert!(Job::fuzz(MachinePoint::default(), 1, 0, "balanced").validate().is_err());
        assert!(Job::fuzz(MachinePoint::default(), 1, 100, "bogus").validate().is_err());
        assert!(Job::fuzz(MachinePoint::default(), 1, 100, "alu=4,vec=1").validate().is_ok());
    }

    #[test]
    fn execute_runs_sim_and_fuzz_jobs() {
        let r = execute(&Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 16 * 1024))
            .unwrap();
        assert_eq!(r.verified, Some(true));
        assert!(r.cycles > 0 && r.instret > 0 && r.bytes == 16 * 1024);
        assert!(r.bytes_per_cycle() > 0.0);
        assert!(r.metrics.contains_key("dual_issue_pairs"));

        let f = execute(&Job::fuzz(MachinePoint::default(), 3, 60, "balanced")).unwrap();
        assert_eq!(f.verified, Some(true));
        assert!(f.instret > 0);
    }

    #[test]
    fn execute_reports_wedged_points_as_errors() {
        // A tiny instruction budget turns a healthy point into the
        // "wedged simulation" shape: the watchdog trips and the job
        // fails instead of running forever.
        let j = Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, 64 * 1024)
            .with_budget(100);
        let err = execute(&j).unwrap_err();
        assert!(err.to_lowercase().contains("watchdog"), "{err}");
    }
}
