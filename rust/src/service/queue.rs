//! The job queue: deterministic shard assignment plus the grid runner
//! that drains a shard with caching, per-point timeouts, bounded retry
//! and progress accounting.
//!
//! Execution itself reuses the repo-wide bounded worker pool
//! ([`crate::coordinator::sweep::parallel_map_bounded`]); what this
//! module adds is the service policy around each point:
//!
//! 1. consult the [`ResultStore`] — a hit is returned without running
//!    anything (and counted, so resume tests can assert on it);
//! 2. execute with an optional wall-clock timeout (the attempt runs on
//!    a detached thread so an abandoned simulation cannot wedge the
//!    worker) and a bounded number of retries;
//! 3. append the terminal record to the store *before* reporting it —
//!    a crash never loses an acknowledged result.
//!
//! Sharding is pure arithmetic on the content hash ([`shard_of`]), so
//! independent processes given `--shards N --shard I` partition any
//! grid deterministically with no coordination beyond sharing nothing.

use super::progress::Progress;
use super::store::{ResultRecord, ResultStore};
use super::{Job, Outcome};
use crate::coordinator::sweep::{parallel_map_bounded, Parallelism};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Deterministic shard assignment: a job belongs to shard
/// `key mod shards`. Stable across processes and runs because the key
/// is the FNV-1a content hash — every worker computes the same
/// partition independently.
pub fn shard_of(key: u64, shards: u64) -> u64 {
    key % shards.max(1)
}

/// Keep only the jobs belonging to `shard` of `shards`.
pub fn shard_filter(jobs: Vec<Job>, shard: u64, shards: u64) -> Vec<Job> {
    jobs.into_iter().filter(|j| shard_of(j.key(), shards) == shard).collect()
}

/// Policy knobs for one grid run.
#[derive(Debug, Clone)]
pub struct GridOptions {
    pub parallelism: Parallelism,
    /// Wall-clock limit per *attempt* (`None` = unbounded; the
    /// retired-instruction budget on the job still applies).
    pub timeout: Option<Duration>,
    /// Re-executions after a failed first attempt (attempts =
    /// `retries + 1`).
    pub retries: u32,
    /// Stop starting points after this many have been *executed*
    /// (cache hits excluded). Used to simulate a crash mid-grid in the
    /// resume tests; unfinished points come back as `None`.
    pub stop_after: Option<usize>,
}

impl Default for GridOptions {
    fn default() -> Self {
        Self { parallelism: Parallelism::auto(), timeout: None, retries: 1, stop_after: None }
    }
}

/// An executor: turns a job into an outcome. Shared (`Arc`) because
/// timed attempts run on detached threads that may outlive the grid
/// call. [`default_exec`] wraps [`super::execute`]; tests substitute
/// stubs.
pub type Exec = Arc<dyn Fn(&Job) -> Result<Outcome, String> + Send + Sync + 'static>;

/// The production executor: run the simulation/fuzz case in-process.
pub fn default_exec() -> Exec {
    Arc::new(|job: &Job| super::execute(job))
}

/// One attempt, optionally under a wall-clock limit. With a timeout the
/// attempt runs on a detached thread: `recv_timeout` abandons it on
/// expiry (the thread parks on a dead channel when it eventually
/// finishes and exits — detached, so nobody joins on it). A panicking
/// attempt surfaces as an error either way.
fn attempt(exec: &Exec, job: &Job, timeout: Option<Duration>) -> Result<Outcome, String> {
    match timeout {
        None => catch_unwind(AssertUnwindSafe(|| exec(job)))
            .unwrap_or_else(|p| Err(format!("executor panicked: {}", panic_text(&p)))),
        Some(limit) => {
            let (tx, rx) = mpsc::channel();
            let exec = Arc::clone(exec);
            let job = job.clone();
            std::thread::spawn(move || {
                let r = catch_unwind(AssertUnwindSafe(|| exec(&job)))
                    .unwrap_or_else(|p| Err(format!("executor panicked: {}", panic_text(&p))));
                let _ = tx.send(r); // receiver may have timed out; fine
            });
            match rx.recv_timeout(limit) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    Err(format!("timeout: attempt exceeded {} ms", limit.as_millis()))
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err("executor thread died before reporting".to_string())
                }
            }
        }
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Drain a grid of jobs: serve each point from `store` when possible,
/// execute the rest under the options' timeout/retry policy, append
/// every terminal record to the store, and call `on_result` as each
/// point lands (the serve loop streams these as `result` events).
///
/// Returns one entry per input job, in input order; `None` marks a
/// point abandoned by `stop_after` (the simulated crash). The caller
/// is responsible for setting `progress.add_total` — this function
/// only moves points through the running/completed/cached/failed
/// states.
pub fn run_grid(
    jobs: Vec<Job>,
    store: &Mutex<ResultStore>,
    progress: &Progress,
    opts: &GridOptions,
    exec: &Exec,
    on_result: impl Fn(&ResultRecord) + Sync,
) -> Vec<Option<ResultRecord>> {
    let cancelled = AtomicBool::new(false);
    let executed = AtomicUsize::new(0);
    let workers = opts.parallelism.workers();
    parallel_map_bounded(jobs, workers, |job| {
        if cancelled.load(Ordering::Relaxed) {
            return None;
        }
        // Invalid jobs become failed records up front — a bad point in
        // a thousand-point grid is a row in the report, not a panic.
        if let Err(e) = job.validate() {
            let rec = ResultRecord::failed(job, format!("invalid job: {e}"), 0, 0);
            let _ = store.lock().expect("store lock").record(&rec);
            progress.start_point();
            progress.finish_point(false);
            on_result(&rec);
            return Some(rec);
        }
        let key = job.key();
        if let Some(hit) = store.lock().expect("store lock").lookup(key) {
            progress.cache_hit();
            on_result(&hit);
            return Some(hit);
        }
        progress.start_point();
        let attempts = opts.retries + 1;
        let mut last_err = String::new();
        for n in 1..=attempts {
            if cancelled.load(Ordering::Relaxed) {
                progress.abandon_point();
                return None;
            }
            let t0 = Instant::now();
            let result = attempt(exec, &job, opts.timeout);
            let wall_ms = t0.elapsed().as_millis() as u64;
            match result {
                Ok(outcome) => {
                    let rec = ResultRecord::ok(job, outcome, n, wall_ms);
                    let _ = store.lock().expect("store lock").record(&rec);
                    progress.finish_point(true);
                    on_result(&rec);
                    bump_executed(&executed, &cancelled, opts.stop_after);
                    return Some(rec);
                }
                Err(e) => last_err = e,
            }
        }
        let rec = ResultRecord::failed(job, last_err, attempts, 0);
        let _ = store.lock().expect("store lock").record(&rec);
        progress.finish_point(false);
        on_result(&rec);
        bump_executed(&executed, &cancelled, opts.stop_after);
        Some(rec)
    })
}

fn bump_executed(executed: &AtomicUsize, cancelled: &AtomicBool, stop_after: Option<usize>) {
    let n = executed.fetch_add(1, Ordering::Relaxed) + 1;
    if let Some(limit) = stop_after {
        if n >= limit {
            cancelled.store(true, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::MachinePoint;
    use crate::workloads::Variant;

    fn grid(n: usize) -> Vec<Job> {
        // n distinct, valid jobs (sizes 1KiB, 2KiB, ...).
        (1..=n)
            .map(|i| Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, i * 1024))
            .collect()
    }

    /// Instant fake executor so queue-policy tests don't simulate.
    fn stub_exec() -> Exec {
        Arc::new(|job: &Job| {
            Ok(Outcome {
                cycles: job.key() | 1, // nonzero, job-dependent
                instret: 1,
                bytes: 1,
                fmax_mhz: 150.0,
                verified: Some(true),
                metrics: Default::default(),
            })
        })
    }

    fn opts_serial() -> GridOptions {
        GridOptions { parallelism: Parallelism::fixed(1), ..Default::default() }
    }

    #[test]
    fn sharding_is_deterministic_disjoint_and_complete() {
        let jobs = grid(40);
        assert_eq!(shard_of(10, 3), shard_of(10, 3));
        assert_eq!(shard_of(5, 0), 0, "zero shards behaves as one");
        let shards = 3u64;
        let parts: Vec<Vec<Job>> =
            (0..shards).map(|s| shard_filter(jobs.clone(), s, shards)).collect();
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, jobs.len(), "shards partition the grid");
        for (i, part) in parts.iter().enumerate() {
            for job in part {
                assert_eq!(shard_of(job.key(), shards), i as u64);
                // Disjoint: the job appears in no other shard.
                for (k, other) in parts.iter().enumerate() {
                    assert_eq!(other.contains(job), k == i);
                }
            }
        }
        // Stability across calls (pure function of content hash).
        assert_eq!(shard_filter(jobs.clone(), 1, shards), parts[1].clone());
    }

    #[test]
    fn run_grid_executes_then_serves_from_cache() {
        let store = Mutex::new(ResultStore::in_memory());
        let jobs = grid(5);
        let progress = Progress::new(jobs.len() as u64);
        let first = run_grid(jobs.clone(), &store, &progress, &opts_serial(), &stub_exec(), |_| {});
        assert_eq!(first.len(), 5);
        assert!(first.iter().all(|r| r.as_ref().is_some_and(|r| !r.from_cache)));
        assert_eq!(store.lock().unwrap().hits(), 0);
        assert!(progress.snapshot().done());

        // Same grid, same store: 100% cache hits, zero executions.
        let p2 = Progress::new(jobs.len() as u64);
        let streamed = AtomicUsize::new(0);
        let second = run_grid(jobs.clone(), &store, &p2, &opts_serial(), &stub_exec(), |r| {
            assert!(r.from_cache);
            streamed.fetch_add(1, Ordering::Relaxed);
        });
        assert!(second.iter().all(|r| r.as_ref().is_some_and(|r| r.from_cache)));
        assert_eq!(store.lock().unwrap().hits(), 5);
        assert_eq!(streamed.load(Ordering::Relaxed), 5);
        assert_eq!(p2.snapshot().cached, 5);
        // Order preserved: outcome matches each job's own key.
        for (job, rec) in jobs.iter().zip(&second) {
            assert_eq!(rec.as_ref().unwrap().outcome.as_ref().unwrap().cycles, job.key() | 1);
        }
    }

    #[test]
    fn retries_are_bounded_and_success_after_retry_sticks() {
        // Fails twice, then succeeds.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let flaky: Exec = Arc::new(move |_job: &Job| {
            if c.fetch_add(1, Ordering::Relaxed) < 2 {
                Err("transient".to_string())
            } else {
                Ok(Outcome { cycles: 1, ..Default::default() })
            }
        });
        let store = Mutex::new(ResultStore::in_memory());
        let opts = GridOptions { retries: 2, ..opts_serial() };
        let out = run_grid(grid(1), &store, &Progress::new(1), &opts, &flaky, |_| {});
        let rec = out[0].as_ref().unwrap();
        assert_eq!(rec.status, super::super::JobStatus::Ok);
        assert_eq!(rec.attempts, 3);

        // Always failing: bounded at retries + 1 attempts, marked failed.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let broken: Exec = Arc::new(move |_job: &Job| {
            c.fetch_add(1, Ordering::Relaxed);
            Err("hard failure".to_string())
        });
        let store = Mutex::new(ResultStore::in_memory());
        let progress = Progress::new(1);
        let out = run_grid(grid(1), &store, &progress, &opts, &broken, |_| {});
        let rec = out[0].as_ref().unwrap();
        assert_eq!(rec.status, super::super::JobStatus::Failed);
        assert_eq!(rec.attempts, 3);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        assert_eq!(rec.error.as_deref(), Some("hard failure"));
        assert_eq!(progress.snapshot().failed, 1);
        // Failed records are persisted but not cache-servable.
        assert_eq!(store.lock().unwrap().len(), 1);
        assert_eq!(store.lock().unwrap().completed(), 0);
    }

    #[test]
    fn wall_clock_timeout_fails_the_point_without_stalling_the_shard() {
        let sleeper: Exec = Arc::new(|_job: &Job| {
            std::thread::sleep(Duration::from_secs(30));
            Ok(Outcome::default())
        });
        let store = Mutex::new(ResultStore::in_memory());
        let opts = GridOptions {
            timeout: Some(Duration::from_millis(40)),
            retries: 0,
            ..opts_serial()
        };
        let t0 = Instant::now();
        let out = run_grid(grid(2), &store, &Progress::new(2), &opts, &sleeper, |_| {});
        assert!(t0.elapsed() < Duration::from_secs(10), "timeout must abandon the attempt");
        for rec in out.iter().map(|r| r.as_ref().unwrap()) {
            assert_eq!(rec.status, super::super::JobStatus::Failed);
            assert!(rec.error.as_deref().unwrap().contains("timeout"), "{:?}", rec.error);
            assert_eq!(rec.attempts, 1);
        }
    }

    #[test]
    fn panicking_executor_becomes_a_failed_record() {
        let bomb: Exec = Arc::new(|_job: &Job| panic!("executor bug"));
        let store = Mutex::new(ResultStore::in_memory());
        let opts = GridOptions { retries: 0, ..opts_serial() };
        let out = run_grid(grid(1), &store, &Progress::new(1), &opts, &bomb, |_| {});
        let rec = out[0].as_ref().unwrap();
        assert_eq!(rec.status, super::super::JobStatus::Failed);
        assert!(rec.error.as_deref().unwrap().contains("executor bug"), "{:?}", rec.error);
    }

    #[test]
    fn invalid_jobs_fail_fast_without_executing() {
        let exec_calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&exec_calls);
        let counting: Exec = Arc::new(move |_job: &Job| {
            c.fetch_add(1, Ordering::Relaxed);
            Ok(Outcome::default())
        });
        let store = Mutex::new(ResultStore::in_memory());
        let bad = vec![Job::sim(MachinePoint::default(), "no-such-workload", Variant::Vector, 1)];
        let out = run_grid(bad, &store, &Progress::new(1), &opts_serial(), &counting, |_| {});
        let rec = out[0].as_ref().unwrap();
        assert_eq!(rec.status, super::super::JobStatus::Failed);
        assert!(rec.error.as_deref().unwrap().contains("unknown workload"));
        assert_eq!(exec_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stop_after_simulates_a_crash_and_resume_serves_the_survivors() {
        let store = Mutex::new(ResultStore::in_memory());
        let jobs = grid(6);
        // "Crash" after 2 executed points (serial, so exactly 2).
        let crash = GridOptions { stop_after: Some(2), ..opts_serial() };
        let out = run_grid(jobs.clone(), &store, &Progress::new(6), &crash, &stub_exec(), |_| {});
        assert_eq!(out.iter().filter(|r| r.is_some()).count(), 2);
        assert_eq!(out.iter().filter(|r| r.is_none()).count(), 4);
        assert_eq!(store.lock().unwrap().len(), 2);

        // Restart against the same store: survivors come from cache,
        // the rest execute; the final result set is complete.
        let progress = Progress::new(6);
        let resumed = run_grid(jobs, &store, &progress, &opts_serial(), &stub_exec(), |_| {});
        assert!(resumed.iter().all(Option::is_some));
        assert_eq!(store.lock().unwrap().hits(), 2);
        let s = progress.snapshot();
        assert_eq!((s.cached, s.completed), (2, 6));
        assert_eq!(
            resumed.iter().filter(|r| r.as_ref().unwrap().from_cache).count(),
            2
        );
    }
}
