//! Basic-block micro-op cache for the reference ISS.
//!
//! The per-instruction interpreter pays fetch bookkeeping (alignment and
//! bounds checks, decode-cache indexing) and pc/instret updates on every
//! instruction. This module lowers each basic block once into a straight
//! run of [`Uop`]s with every pc-relative quantity **precomputed**: an
//! `auipc` becomes a constant load, a branch carries its absolute target,
//! a `jal` carries both its link value and its target. The block executor
//! in [`super::RefIss::run`] then touches no pc at all on the
//! straight-line path.
//!
//! Block formation rules (DESIGN.md §11):
//! - a block starts at any word the interpreter jumps to and extends
//!   through consecutive decodable text words;
//! - it ends at the first control-flow or halting instruction
//!   (branch/jal/jalr/ecall/ebreak), at the first undecodable word
//!   (which must fault *at its own pc*, at execution time), at the end
//!   of the text segment, or at [`MAX_BLOCK_UOPS`];
//! - blocks may overlap: a jump into the middle of an existing block
//!   simply forms a new suffix block at that word.
//!
//! Rare or stateful instructions (CSR reads, `mulh`-family, `div`/`rem`,
//! fences, `ecall`/`ebreak`, custom SIMD) are *not* re-implemented: they
//! lower to [`Uop::Sys`], which routes through the same
//! `RefIss::exec` the per-instruction engines use, so their semantics
//! cannot diverge between engines.
//!
//! Invalidation: the owning `RefIss` clears blocks whose uop span
//! overlaps any invalidated text word ([`BlockCache::invalidate_span`]).
//! The executing block is held by `Rc`, so a store that invalidates the
//! block currently running cannot free it mid-run; the executor instead
//! aborts the block at the store and re-enters through a fresh lookup.

use std::rc::Rc;

use crate::isa::Instr;

/// Upper bound on uops per block. Bounds both lowering cost on huge
/// straight-line regions and how far back
/// [`BlockCache::invalidate_span`] must look for overlapping blocks.
pub(crate) const MAX_BLOCK_UOPS: usize = 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum AluIOp {
    Add,
    Slt,
    Sltu,
    Xor,
    Or,
    And,
    Sll,
    Srl,
    Sra,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum AluROp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
    Mul,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum LoadKind {
    B,
    H,
    W,
    Bu,
    Hu,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum StoreKind {
    B,
    H,
    W,
}

impl StoreKind {
    #[inline]
    pub(crate) fn len(self) -> usize {
        match self {
            StoreKind::B => 1,
            StoreKind::H => 2,
            StoreKind::W => 4,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BrCond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// One predecoded micro-op. Register numbers are raw `u8` indices and
/// every pc-relative value is folded in at lowering time.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Uop {
    /// Constant destination value: `lui`, and `auipc` with its pc folded.
    Li { rd: u8, v: u32 },
    AluImm { op: AluIOp, rd: u8, rs1: u8, imm: u32 },
    AluReg { op: AluROp, rd: u8, rs1: u8, rs2: u8 },
    Load { kind: LoadKind, rd: u8, rs1: u8, imm: u32 },
    Store { kind: StoreKind, rs1: u8, rs2: u8, imm: u32 },
    /// Conditional branch; `target` is the absolute taken-path pc.
    Br { cond: BrCond, rs1: u8, rs2: u8, target: u32 },
    Jal { rd: u8, link: u32, target: u32 },
    Jalr { rd: u8, rs1: u8, imm: u32, link: u32 },
    /// Fallback: execute through `RefIss::exec` (see module docs).
    Sys(Instr),
}

/// Lower one decoded instruction at `pc` into a micro-op.
pub(crate) fn lower(i: Instr, pc: u32) -> Uop {
    use Instr::*;
    match i {
        Lui { rd, imm } => Uop::Li { rd: rd.num(), v: imm as u32 },
        Auipc { rd, imm } => Uop::Li { rd: rd.num(), v: pc.wrapping_add(imm as u32) },
        Jal { rd, offset } => Uop::Jal {
            rd: rd.num(),
            link: pc.wrapping_add(4),
            target: pc.wrapping_add(offset as u32),
        },
        Jalr { rd, rs1, offset } => Uop::Jalr {
            rd: rd.num(),
            rs1: rs1.num(),
            imm: offset as u32,
            link: pc.wrapping_add(4),
        },
        Beq { rs1, rs2, offset }
        | Bne { rs1, rs2, offset }
        | Blt { rs1, rs2, offset }
        | Bge { rs1, rs2, offset }
        | Bltu { rs1, rs2, offset }
        | Bgeu { rs1, rs2, offset } => {
            let cond = match i {
                Beq { .. } => BrCond::Eq,
                Bne { .. } => BrCond::Ne,
                Blt { .. } => BrCond::Lt,
                Bge { .. } => BrCond::Ge,
                Bltu { .. } => BrCond::Ltu,
                _ => BrCond::Geu,
            };
            Uop::Br {
                cond,
                rs1: rs1.num(),
                rs2: rs2.num(),
                target: pc.wrapping_add(offset as u32),
            }
        }
        Lb { rd, rs1, offset }
        | Lh { rd, rs1, offset }
        | Lw { rd, rs1, offset }
        | Lbu { rd, rs1, offset }
        | Lhu { rd, rs1, offset } => {
            let kind = match i {
                Lb { .. } => LoadKind::B,
                Lh { .. } => LoadKind::H,
                Lw { .. } => LoadKind::W,
                Lbu { .. } => LoadKind::Bu,
                _ => LoadKind::Hu,
            };
            Uop::Load { kind, rd: rd.num(), rs1: rs1.num(), imm: offset as u32 }
        }
        Sb { rs1, rs2, offset } | Sh { rs1, rs2, offset } | Sw { rs1, rs2, offset } => {
            let kind = match i {
                Sb { .. } => StoreKind::B,
                Sh { .. } => StoreKind::H,
                _ => StoreKind::W,
            };
            Uop::Store { kind, rs1: rs1.num(), rs2: rs2.num(), imm: offset as u32 }
        }
        Addi { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::Add, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Slti { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::Slt, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Sltiu { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::Sltu, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Xori { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::Xor, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Ori { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::Or, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Andi { rd, rs1, imm } => {
            Uop::AluImm { op: AluIOp::And, rd: rd.num(), rs1: rs1.num(), imm: imm as u32 }
        }
        Slli { rd, rs1, shamt } => {
            Uop::AluImm { op: AluIOp::Sll, rd: rd.num(), rs1: rs1.num(), imm: shamt as u32 }
        }
        Srli { rd, rs1, shamt } => {
            Uop::AluImm { op: AluIOp::Srl, rd: rd.num(), rs1: rs1.num(), imm: shamt as u32 }
        }
        Srai { rd, rs1, shamt } => {
            Uop::AluImm { op: AluIOp::Sra, rd: rd.num(), rs1: rs1.num(), imm: shamt as u32 }
        }
        Add { rd, rs1, rs2 }
        | Sub { rd, rs1, rs2 }
        | Sll { rd, rs1, rs2 }
        | Slt { rd, rs1, rs2 }
        | Sltu { rd, rs1, rs2 }
        | Xor { rd, rs1, rs2 }
        | Srl { rd, rs1, rs2 }
        | Sra { rd, rs1, rs2 }
        | Or { rd, rs1, rs2 }
        | And { rd, rs1, rs2 }
        | Mul { rd, rs1, rs2 } => {
            let op = match i {
                Add { .. } => AluROp::Add,
                Sub { .. } => AluROp::Sub,
                Sll { .. } => AluROp::Sll,
                Slt { .. } => AluROp::Slt,
                Sltu { .. } => AluROp::Sltu,
                Xor { .. } => AluROp::Xor,
                Srl { .. } => AluROp::Srl,
                Sra { .. } => AluROp::Sra,
                Or { .. } => AluROp::Or,
                And { .. } => AluROp::And,
                _ => AluROp::Mul,
            };
            Uop::AluReg { op, rd: rd.num(), rs1: rs1.num(), rs2: rs2.num() }
        }
        // Everything else stays on the shared `exec` path: upper
        // multiplies and div/rem (corner-case heavy), CSR reads
        // (instret-dependent), fences, ecall/ebreak, custom SIMD.
        other => Uop::Sys(other),
    }
}

/// Does `i` end the basic block it appears in?
#[inline]
pub(crate) fn ends_block(i: &Instr) -> bool {
    i.is_branch_or_jump() || matches!(i, Instr::Ecall | Instr::Ebreak)
}

/// One lowered basic block. Cheap to clone (the uops are shared), so the
/// executor can keep the block alive across an invalidation of its own
/// cache slot.
#[derive(Clone)]
pub(crate) struct Block {
    pub uops: Rc<[Uop]>,
}

/// Blocks keyed by their starting text-word index.
#[derive(Default)]
pub(crate) struct BlockCache {
    slots: Vec<Option<Block>>,
}

impl BlockCache {
    pub(crate) fn empty() -> Self {
        Self { slots: Vec::new() }
    }

    /// Drop all blocks and re-size for a freshly loaded text segment.
    pub(crate) fn reset(&mut self, words: usize) {
        self.slots.clear();
        self.slots.resize(words, None);
    }

    #[inline]
    pub(crate) fn get(&self, idx: usize) -> Option<&Block> {
        self.slots[idx].as_ref()
    }

    pub(crate) fn put(&mut self, idx: usize, b: Block) {
        self.slots[idx] = Some(b);
    }

    /// Invalidate every block whose uop range covers any word in the
    /// inclusive span `[first, last]` (as returned by
    /// [`crate::isa::DecodeCache::invalidate`]). A block starting at `s`
    /// with `n` uops covers words `[s, s + n)`; only starts within
    /// `MAX_BLOCK_UOPS - 1` words before `first` can reach it.
    pub(crate) fn invalidate_span(&mut self, first: usize, last: usize) {
        if self.slots.is_empty() {
            return;
        }
        let lo = first.saturating_sub(MAX_BLOCK_UOPS - 1);
        let hi = last.min(self.slots.len() - 1);
        for s in lo..=hi {
            if let Some(b) = &self.slots[s] {
                if s + b.uops.len() > first {
                    self.slots[s] = None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;

    #[test]
    fn lowering_precomputes_pc_relative_values() {
        let pc = 0x1000;
        match lower(Instr::Auipc { rd: A0, imm: 0x2000 }, pc) {
            Uop::Li { rd, v } => {
                assert_eq!(rd, A0.num());
                assert_eq!(v, 0x3000);
            }
            other => panic!("unexpected lowering: {other:?}"),
        }
        match lower(Instr::Jal { rd: RA, offset: -16 }, pc) {
            Uop::Jal { link, target, .. } => {
                assert_eq!(link, 0x1004);
                assert_eq!(target, 0xFF0);
            }
            other => panic!("unexpected lowering: {other:?}"),
        }
        match lower(Instr::Bne { rs1: A0, rs2: A1, offset: 8 }, pc) {
            Uop::Br { cond, target, .. } => {
                assert_eq!(cond, BrCond::Ne);
                assert_eq!(target, 0x1008);
            }
            other => panic!("unexpected lowering: {other:?}"),
        }
    }

    #[test]
    fn rare_instructions_fall_back_to_sys() {
        for i in [
            Instr::Div { rd: A0, rs1: A1, rs2: A2 },
            Instr::Mulh { rd: A0, rs1: A1, rs2: A2 },
            Instr::Csrrs { rd: A0, csr: 0xC00, rs1: ZERO },
            Instr::Fence,
            Instr::Ecall,
        ] {
            assert!(matches!(lower(i, 0), Uop::Sys(_)), "{i:?} should lower to Sys");
        }
    }

    #[test]
    fn terminators() {
        assert!(ends_block(&Instr::Jal { rd: ZERO, offset: 8 }));
        assert!(ends_block(&Instr::Ecall));
        assert!(ends_block(&Instr::Ebreak));
        assert!(!ends_block(&Instr::Csrrs { rd: A0, csr: 0xC00, rs1: ZERO }));
        assert!(!ends_block(&Instr::Addi { rd: A0, rs1: A0, imm: 1 }));
    }

    #[test]
    fn invalidate_span_clears_overlapping_blocks_only() {
        let mut c = BlockCache::empty();
        c.reset(32);
        let blk = |n: usize| Block { uops: vec![Uop::Sys(Instr::Fence); n].into() };
        c.put(0, blk(4)); // words 0..4
        c.put(4, blk(2)); // words 4..6
        c.put(10, blk(1)); // word 10
        c.invalidate_span(5, 5);
        assert!(c.get(0).is_some(), "block [0,4) does not reach word 5");
        assert!(c.get(4).is_none(), "block [4,6) covers word 5");
        assert!(c.get(10).is_some());
        c.invalidate_span(0, 0);
        assert!(c.get(0).is_none());
    }
}
