//! Reference instruction-set simulator (ISS): a second, architectural-only
//! implementation of RV32IM + the paper's I′/S′ custom SIMD types.
//!
//! Until this module existed, the only oracle for the timed
//! [`crate::core::Core`] was the flat-memory mode of the *same* core — a
//! decode or execute bug would sail through every suite because both
//! sides of the comparison shared the buggy `step()`. `RefIss` is an
//! independent execute implementation (its own instruction match, its
//! own register file and flat byte-array memory, zero timing state) that
//! shares only the pieces whose semantics are *defined* to be common:
//!
//! - [`crate::isa::decode`] / [`crate::isa::Instr`] — the instruction
//!   encoding is the specification both machines implement;
//! - [`crate::simd::UnitPool`] — a custom unit IS the architectural
//!   definition of its instruction (the paper's reconfigurable-slot
//!   model), so both backends execute the same unit object; the ISS
//!   ignores the unit's latency output entirely.
//!
//! Because there is no scoreboard, no cache model and no cycle
//! accounting, the ISS also serves as a high-throughput functional
//! backend (`Machine::backend(Backend::RefIss)`), executing the full
//! workload registry an order of magnitude faster than the timed core
//! (`cargo bench --bench iss_throughput`).
//!
//! Architectural contract vs the timed core (DESIGN.md §9): registers,
//! vector registers, pc, instret and the memory image must match
//! instruction for instruction. Cycle counts do not exist here; reads of
//! the cycle/time CSRs return `instret` (a monotonic counter), and the
//! lockstep driver ([`crate::cosim`]) injects the timed core's value so
//! downstream dataflow still compares exactly.

use crate::arch::ArchState;
use crate::asm::Program;
use crate::core::SimError;
use crate::isa::instr::csr;
use crate::isa::{decode, Instr, Reg, VReg};
use crate::simd::{standard_pool, UnitInputs, UnitPool, VecMemOp, VecVal};

/// Result of a completed ISS run (no cycle counts by construction).
#[derive(Debug, Clone, Copy)]
pub struct IssRunResult {
    pub instret: u64,
}

/// The architectural-only reference simulator.
pub struct RefIss {
    vlen_bits: usize,
    /// Cycles → seconds clock used only when the ISS backs a
    /// `WorkloadReport` (the ISS itself never counts cycles).
    pub fmax_mhz: f64,
    pub pool: UnitPool,
    regs: [u32; 32],
    vregs: [VecVal; 8],
    pc: u32,
    instret: u64,
    halted: bool,
    mem: Vec<u8>,
    text_base: u32,
    decoded: Vec<Option<Instr>>,
}

impl RefIss {
    /// ISS with the standard unit pool for `vlen_bits` and a flat memory
    /// of `mem_bytes`.
    pub fn new(vlen_bits: usize, mem_bytes: usize) -> Self {
        let lanes = vlen_bits / 32;
        Self {
            vlen_bits,
            fmax_mhz: 150.0,
            pool: standard_pool(vlen_bits),
            regs: [0; 32],
            vregs: [VecVal::zero(lanes); 8],
            pc: 0,
            instret: 0,
            halted: false,
            mem: vec![0; mem_bytes],
            text_base: 0,
            decoded: Vec::new(),
        }
    }

    /// Paper-shaped ISS (VLEN = 256) over `mem_bytes` of memory.
    pub fn paper_default(mem_bytes: usize) -> Self {
        Self::new(256, mem_bytes)
    }

    pub fn vlen_bits(&self) -> usize {
        self.vlen_bits
    }

    fn lanes(&self) -> usize {
        self.vlen_bits / 32
    }

    fn vlen_bytes(&self) -> usize {
        self.vlen_bits / 8
    }

    /// Load a program and reset architectural state, mirroring
    /// [`crate::core::Core::load`]: registers cleared, `sp` at the top
    /// of memory (16-byte aligned), pc at the entry point. Memory
    /// outside the program image is left as-is (a fresh ISS is
    /// all-zero, like fresh simulated DRAM).
    pub fn load(&mut self, prog: &Program) {
        let lanes = self.lanes();
        for (i, w) in prog.text.iter().enumerate() {
            let at = prog.text_base as usize + i * 4;
            self.mem[at..at + 4].copy_from_slice(&w.to_le_bytes());
        }
        if !prog.data.is_empty() {
            let at = prog.data_base as usize;
            self.mem[at..at + prog.data.len()].copy_from_slice(&prog.data);
        }
        self.regs = [0; 32];
        self.vregs = [VecVal::zero(lanes); 8];
        self.regs[2] = crate::arch::sp_init(self.mem.len());
        self.pc = prog.entry;
        self.instret = 0;
        self.halted = false;
        self.text_base = prog.text_base;
        self.decoded = vec![None; prog.text.len()];
        self.pool.reset_all();
    }

    /// Host-side memory write (workload input images).
    pub fn host_write(&mut self, addr: u32, data: &[u8]) {
        let at = addr as usize;
        self.mem[at..at + data.len()].copy_from_slice(data);
    }

    /// Overwrite one base register (the lockstep driver uses this to
    /// inject the timed core's value after a cycle/time CSR read, the
    /// one architecturally timing-dependent instruction).
    pub fn force_reg(&mut self, r: Reg, v: u32) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    #[inline]
    fn write_reg(&mut self, r: Reg, v: u32) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    #[inline]
    fn write_vreg(&mut self, v: VReg, val: VecVal) {
        if v.num() != 0 {
            self.vregs[v.num() as usize] = val;
        }
    }

    #[inline]
    fn check_mem(&self, addr: u32, len: usize) -> Result<(), SimError> {
        if (addr as usize).checked_add(len).is_none_or(|end| end > self.mem.len()) {
            return Err(SimError::MemFault { pc: self.pc, addr, len, size: self.mem.len() });
        }
        Ok(())
    }

    #[inline]
    fn load_u32(&self, addr: u32) -> u32 {
        let at = addr as usize;
        u32::from_le_bytes(self.mem[at..at + 4].try_into().unwrap())
    }

    /// Decode (with per-index caching over the text segment) the
    /// instruction at `pc`. Mirrors the timed core's fetch fault order
    /// exactly (DESIGN.md §9): a non-word-aligned pc (reachable through
    /// `jalr`, which clears only bit 0, or a branch offset of 4k+2) is
    /// a misaligned-fetch fault, a pc outside memory is a fetch fault —
    /// both raised before any decode-cache indexing so the truncating
    /// `/ 4` can never alias an aligned slot.
    fn fetch_decode(&mut self, pc: u32) -> Result<Instr, SimError> {
        if pc % 4 != 0 {
            return Err(SimError::FetchMisaligned { pc });
        }
        if (pc as usize).checked_add(4).is_none_or(|end| end > self.mem.len()) {
            return Err(SimError::FetchFault { pc, size: self.mem.len() });
        }
        let off = pc.wrapping_sub(self.text_base);
        if off % 4 == 0 {
            let idx = off as usize / 4;
            if let Some(slot) = self.decoded.get(idx) {
                if let Some(i) = slot {
                    return Ok(*i);
                }
                let i = decode(self.load_u32(pc))
                    .map_err(|source| SimError::Illegal { pc, source })?;
                self.decoded[idx] = Some(i);
                return Ok(i);
            }
        }
        decode(self.load_u32(pc)).map_err(|source| SimError::Illegal { pc, source })
    }

    /// Execute one instruction; returns the retired instruction (the
    /// lockstep driver inspects it to spot timing-dependent CSR reads).
    pub fn step(&mut self) -> Result<Instr, SimError> {
        debug_assert!(!self.halted, "step() after halt");
        let pc = self.pc;
        let instr = self.fetch_decode(pc)?;
        let mut next_pc = pc.wrapping_add(4);
        use Instr::*;
        match instr {
            Lui { rd, imm } => self.write_reg(rd, imm as u32),
            Auipc { rd, imm } => self.write_reg(rd, pc.wrapping_add(imm as u32)),
            Jal { rd, offset } => {
                self.write_reg(rd, pc.wrapping_add(4));
                next_pc = pc.wrapping_add(offset as u32);
            }
            Jalr { rd, rs1, offset } => {
                let base = self.regs[rs1.num() as usize];
                self.write_reg(rd, pc.wrapping_add(4));
                next_pc = base.wrapping_add(offset as u32) & !1;
            }
            Beq { rs1, rs2, offset }
            | Bne { rs1, rs2, offset }
            | Blt { rs1, rs2, offset }
            | Bge { rs1, rs2, offset }
            | Bltu { rs1, rs2, offset }
            | Bgeu { rs1, rs2, offset } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let take = match instr {
                    Beq { .. } => a == b,
                    Bne { .. } => a != b,
                    Blt { .. } => (a as i32) < (b as i32),
                    Bge { .. } => (a as i32) >= (b as i32),
                    Bltu { .. } => a < b,
                    Bgeu { .. } => a >= b,
                    _ => unreachable!(),
                };
                if take {
                    next_pc = pc.wrapping_add(offset as u32);
                }
            }
            Lb { rd, rs1, offset }
            | Lh { rd, rs1, offset }
            | Lw { rd, rs1, offset }
            | Lbu { rd, rs1, offset }
            | Lhu { rd, rs1, offset } => {
                let addr = self.regs[rs1.num() as usize].wrapping_add(offset as u32);
                let len = match instr {
                    Lb { .. } | Lbu { .. } => 1,
                    Lh { .. } | Lhu { .. } => 2,
                    _ => 4,
                };
                self.check_mem(addr, len)?;
                let at = addr as usize;
                let value = match instr {
                    Lb { .. } => self.mem[at] as i8 as i32 as u32,
                    Lbu { .. } => self.mem[at] as u32,
                    Lh { .. } => i16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as i32 as u32,
                    Lhu { .. } => u16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as u32,
                    _ => self.load_u32(addr),
                };
                self.write_reg(rd, value);
            }
            Sb { rs1, rs2, offset } | Sh { rs1, rs2, offset } | Sw { rs1, rs2, offset } => {
                let addr = self.regs[rs1.num() as usize].wrapping_add(offset as u32);
                let len = match instr {
                    Sb { .. } => 1,
                    Sh { .. } => 2,
                    _ => 4,
                };
                self.check_mem(addr, len)?;
                let bytes = self.regs[rs2.num() as usize].to_le_bytes();
                let at = addr as usize;
                self.mem[at..at + len].copy_from_slice(&bytes[..len]);
            }
            Addi { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a.wrapping_add(imm as u32));
            }
            Slti { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, ((a as i32) < imm) as u32);
            }
            Sltiu { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, (a < imm as u32) as u32);
            }
            Xori { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a ^ imm as u32);
            }
            Ori { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a | imm as u32);
            }
            Andi { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a & imm as u32);
            }
            Slli { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a << shamt);
            }
            Srli { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a >> shamt);
            }
            Srai { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, ((a as i32) >> shamt) as u32);
            }
            Add { rd, rs1, rs2 }
            | Sub { rd, rs1, rs2 }
            | Sll { rd, rs1, rs2 }
            | Slt { rd, rs1, rs2 }
            | Sltu { rd, rs1, rs2 }
            | Xor { rd, rs1, rs2 }
            | Srl { rd, rs1, rs2 }
            | Sra { rd, rs1, rs2 }
            | Or { rd, rs1, rs2 }
            | And { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Add { .. } => a.wrapping_add(b),
                    Sub { .. } => a.wrapping_sub(b),
                    Sll { .. } => a << (b & 31),
                    Slt { .. } => ((a as i32) < (b as i32)) as u32,
                    Sltu { .. } => (a < b) as u32,
                    Xor { .. } => a ^ b,
                    Srl { .. } => a >> (b & 31),
                    Sra { .. } => ((a as i32) >> (b & 31)) as u32,
                    Or { .. } => a | b,
                    And { .. } => a & b,
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Mul { rd, rs1, rs2 }
            | Mulh { rd, rs1, rs2 }
            | Mulhsu { rd, rs1, rs2 }
            | Mulhu { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Mul { .. } => a.wrapping_mul(b),
                    Mulh { .. } => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    Mulhsu { .. } => (((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32,
                    Mulhu { .. } => (((a as u64) * (b as u64)) >> 32) as u32,
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Div { rd, rs1, rs2 }
            | Divu { rd, rs1, rs2 }
            | Rem { rd, rs1, rs2 }
            | Remu { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Div { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            a
                        } else {
                            ((a as i32).wrapping_div(b as i32)) as u32
                        }
                    }
                    Divu { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else {
                            a / b
                        }
                    }
                    Rem { .. } => {
                        if b == 0 {
                            a
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            0
                        } else {
                            ((a as i32).wrapping_rem(b as i32)) as u32
                        }
                    }
                    Remu { .. } => {
                        if b == 0 {
                            a
                        } else {
                            a % b
                        }
                    }
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Fence => {}
            Ecall => {
                self.halted = true;
            }
            Ebreak => {
                return Err(SimError::Break(pc));
            }
            Csrrs { rd, csr: c, rs1: _ } => {
                // No cycles exist here; the cycle/time counters read as
                // instret (monotonic, like real time would be). The
                // lockstep driver overrides the value with the timed
                // core's — see DESIGN.md §9.
                let v = match c {
                    csr::CYCLE | csr::TIME | csr::INSTRET => self.instret as u32,
                    csr::CYCLEH | csr::TIMEH | csr::INSTRETH => (self.instret >> 32) as u32,
                    _ => 0,
                };
                self.write_reg(rd, v);
            }
            CustomI { slot, funct3, ops } => {
                self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    None,
                    0,
                    ops.vrs1,
                    ops.vrs2,
                    ops.rd,
                    ops.vrd1,
                    ops.vrd2,
                )?;
            }
            CustomS { slot, funct3, ops } => {
                self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    Some(ops.rs2),
                    ops.imm,
                    ops.vrs1,
                    crate::isa::reg::V0,
                    ops.rd,
                    ops.vrd1,
                    crate::isa::reg::V0,
                )?;
            }
        }
        self.pc = next_pc;
        self.instret += 1;
        Ok(instr)
    }

    /// Execute a custom instruction through the shared unit pool,
    /// performing any memory request on the flat image.
    #[allow(clippy::too_many_arguments)]
    fn exec_custom(
        &mut self,
        pc: u32,
        slot: usize,
        funct3: u8,
        rs1: Reg,
        rs2: Option<Reg>,
        imm: u8,
        vrs1: VReg,
        vrs2: VReg,
        rd: Reg,
        vrd1: VReg,
        vrd2: VReg,
    ) -> Result<(), SimError> {
        let inputs = UnitInputs {
            funct3,
            rs1: self.regs[rs1.num() as usize],
            rs2: rs2.map(|r| self.regs[r.num() as usize]).unwrap_or(0),
            imm,
            vrs1: self.vregs[vrs1.num() as usize],
            vrs2: self.vregs[vrs2.num() as usize],
        };
        let out = self
            .pool
            .get_mut(slot)
            .and_then(|u| u.execute(&inputs))
            .map_err(|source| SimError::Unit { pc, source })?;
        match out.mem {
            Some(VecMemOp::Load { addr }) => {
                let len = self.vlen_bytes();
                self.check_mem(addr, len)?;
                let at = addr as usize;
                let val = VecVal::from_bytes(&self.mem[at..at + len]);
                self.write_vreg(vrd1, val);
            }
            Some(VecMemOp::Store { addr, data }) => {
                let len = self.vlen_bytes();
                self.check_mem(addr, len)?;
                let mut buf = [0u8; crate::simd::MAX_VLEN_BITS / 8];
                data.write_bytes(&mut buf[..len]);
                let at = addr as usize;
                self.mem[at..at + len].copy_from_slice(&buf[..len]);
            }
            None => {
                if let Some(v) = out.vrd1 {
                    self.write_vreg(vrd1, v);
                }
                if let Some(v) = out.vrd2 {
                    self.write_vreg(vrd2, v);
                }
                if let Some(v) = out.rd {
                    self.write_reg(rd, v);
                }
            }
        }
        Ok(())
    }

    /// Run until `ecall` or the instruction budget is exhausted.
    pub fn run(&mut self, max_instrs: u64) -> Result<IssRunResult, SimError> {
        let start = self.instret;
        while !self.halted {
            if self.instret - start >= max_instrs {
                return Err(SimError::Watchdog(max_instrs));
            }
            self.step()?;
        }
        Ok(IssRunResult { instret: self.instret })
    }
}

impl ArchState for RefIss {
    fn reg(&self, r: Reg) -> u32 {
        self.regs[r.num() as usize]
    }

    fn vreg(&self, v: VReg) -> VecVal {
        self.vregs[v.num() as usize]
    }

    fn pc(&self) -> u32 {
        self.pc
    }

    fn instret(&self) -> u64 {
        self.instret
    }

    fn halted(&self) -> bool {
        self.halted
    }

    fn mem_size(&self) -> usize {
        self.mem.len()
    }

    fn mem_slice(&self, addr: u32, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    const MEM: usize = 2 * 1024 * 1024;

    fn run_asm(build: impl FnOnce(&mut Asm)) -> RefIss {
        let mut a = Asm::new();
        build(&mut a);
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p);
        iss.run(1_000_000).unwrap();
        iss
    }

    #[test]
    fn arithmetic_and_halt() {
        let iss = run_asm(|a| {
            a.li(A0, 20);
            a.li(A1, 22);
            a.add(A2, A0, A1);
            a.halt();
        });
        assert_eq!(iss.reg(A2), 42);
        assert!(iss.halted());
    }

    #[test]
    fn x0_and_v0_are_hardwired_zero() {
        let iss = run_asm(|a| {
            a.li(ZERO, 99);
            a.mv(A0, ZERO);
            a.halt();
        });
        assert_eq!(iss.reg(A0), 0);
        assert_eq!(iss.vreg(V0), VecVal::zero(8));
    }

    #[test]
    fn loops_loads_stores_and_muldiv() {
        let mut a = Asm::new();
        let buf = a.buffer("buf", 64, 8);
        a.la(A1, buf);
        a.li(A0, -2);
        a.sb(A0, 0, A1);
        a.lb(A2, 0, A1);
        a.lbu(A3, 0, A1);
        a.li(T0, -6);
        a.li(T1, 4);
        a.mul(A4, T0, T1);
        a.div(A5, T0, T1);
        a.rem(A6, T0, T1);
        let l = a.new_label("loop");
        a.li(S0, 10);
        a.li(S1, 0);
        a.bind(l);
        a.add(S1, S1, S0);
        a.addi(S0, S0, -1);
        a.bnez(S0, l);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p);
        iss.run(10_000).unwrap();
        assert_eq!(iss.reg(A2) as i32, -2);
        assert_eq!(iss.reg(A3), 0xFE);
        assert_eq!(iss.reg(A4) as i32, -24);
        assert_eq!(iss.reg(A5) as i32, -1);
        assert_eq!(iss.reg(A6) as i32, -2);
        assert_eq!(iss.reg(S1), 55);
    }

    #[test]
    fn vector_load_sort_store() {
        let mut a = Asm::new();
        let data = a.words("data", &[5, 3, 8, 1, 9, 2, 7, 4].map(|x: i32| x as u32));
        a.dalign(32);
        let out = a.buffer("out", 32, 32);
        a.la(A0, data);
        a.la(A1, out);
        a.lv(V1, A0, ZERO);
        a.sort8(V2, V1);
        a.sv(V2, A1, ZERO);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p);
        iss.run(100).unwrap();
        let got: Vec<i32> = iss
            .mem_slice(p.sym("out"), 32)
            .chunks(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5, 7, 8, 9]);
    }

    #[test]
    fn prefix_state_carries_and_resets_on_load() {
        let mut a = Asm::new();
        let d = a.words("d", &[1u32; 8]);
        a.la(A0, d);
        a.lv(V1, A0, ZERO);
        a.prefix_reset();
        a.prefix(V2, V1);
        a.prefix(V3, V1);
        a.prefix_carry(A5);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p);
        iss.run(100).unwrap();
        assert_eq!(iss.vreg(V2).to_i32s(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(iss.vreg(V3).to_i32s(), vec![9, 10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(iss.reg(A5), 16);
        // Reloading resets the carry (pool.reset_all, as Core::load does).
        iss.load(&p);
        iss.run(100).unwrap();
        assert_eq!(iss.reg(A5), 16);
    }

    #[test]
    fn watchdog_break_and_fault_mirror_the_core() {
        let mut a = Asm::new();
        let l = a.here("forever");
        a.j(l);
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p);
        assert!(matches!(iss.run(1000), Err(SimError::Watchdog(1000))));

        let mut a = Asm::new();
        a.ebreak();
        let p = a.assemble().unwrap();
        iss.load(&p);
        assert!(matches!(iss.run(10), Err(SimError::Break(_))));

        let mut a = Asm::new();
        a.li(A0, 0x7fff_f000u32 as i64);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        iss.load(&p);
        assert!(matches!(iss.run(10), Err(SimError::MemFault { .. })));
    }

    #[test]
    fn cycle_csr_reads_instret() {
        let iss = run_asm(|a| {
            a.nop();
            a.nop();
            a.rdcycle(S0);
            a.rdinstret(S1);
            a.halt();
        });
        assert_eq!(iss.reg(S0), 2, "cycle CSR reads as instret on the ISS");
        assert_eq!(iss.reg(S1), 3);
    }
}
