//! Reference instruction-set simulator (ISS): a second, architectural-only
//! implementation of RV32IM + the paper's I′/S′ custom SIMD types.
//!
//! Until this module existed, the only oracle for the timed
//! [`crate::core::Core`] was the flat-memory mode of the *same* core — a
//! decode or execute bug would sail through every suite because both
//! sides of the comparison shared the buggy `step()`. `RefIss` is an
//! independent execute implementation (its own instruction match, its
//! own register file and flat byte-array memory, zero timing state) that
//! shares only the pieces whose semantics are *defined* to be common:
//!
//! - [`crate::isa::decode`] / [`crate::isa::Instr`] — the instruction
//!   encoding is the specification both machines implement;
//! - [`crate::isa::DecodeCache`] — the predecoded text segment, with its
//!   store-invalidation contract (a store overlapping the text range
//!   drops the stale decodes, so self-modifying code re-decodes);
//! - [`crate::simd::UnitPool`] — a custom unit IS the architectural
//!   definition of its instruction (the paper's reconfigurable-slot
//!   model), so both backends execute the same unit object; the ISS
//!   ignores the unit's latency output entirely.
//!
//! Because there is no scoreboard, no cache model and no cycle
//! accounting, the ISS also serves as a high-throughput functional
//! backend (`Machine::backend(Backend::RefIss)`). It offers three
//! [`ExecEngine`]s (DESIGN.md §11):
//!
//! - **`Blocks`** (default): basic blocks are lowered once into straight
//!   runs of predecoded micro-ops ([`block`]) and executed with no
//!   per-instruction fetch bookkeeping — several times faster than
//!   per-instruction dispatch (`cargo bench --bench iss_throughput`);
//! - **`PerInstr`**: classic decode-cached one-instruction `step()`
//!   dispatch (the lockstep cosim driver steps this way);
//! - **`Uncached`**: decodes every instruction fresh from memory bytes —
//!   the cacheless oracle the invalidation property tests compare
//!   against.
//!
//! All three engines share one `exec()` and are bit-identical in
//! architectural results (`tests/exec_blocks.rs` proves it across the
//! workload registry and the fuzz corpus).
//!
//! Architectural contract vs the timed core (DESIGN.md §9): registers,
//! vector registers, pc, instret and the memory image must match
//! instruction for instruction. Cycle counts do not exist here; reads of
//! the cycle/time CSRs return `instret` (a monotonic counter), and the
//! lockstep driver ([`crate::cosim`]) injects the timed core's value so
//! downstream dataflow still compares exactly.

pub(crate) mod block;

use crate::arch::ArchState;
use crate::asm::Program;
use crate::core::SimError;
use crate::isa::instr::csr;
use crate::isa::{decode, DecodeCache, Instr, Reg, VReg};
use crate::simd::{standard_pool, UnitInputs, UnitPool, VecMemOp, VecVal};

use block::{
    ends_block, lower, AluIOp, AluROp, Block, BlockCache, BrCond, LoadKind, Uop, MAX_BLOCK_UOPS,
};

/// Result of a completed ISS run (no cycle counts by construction).
#[derive(Debug, Clone, Copy)]
pub struct IssRunResult {
    pub instret: u64,
}

/// Which execution engine [`RefIss::run_with`] uses (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecEngine {
    /// Cached basic-block micro-op execution (the default).
    Blocks,
    /// Per-instruction dispatch over the per-word decode cache.
    PerInstr,
    /// Decode every instruction fresh from memory — the slow cacheless
    /// oracle for differential tests.
    Uncached,
}

/// The architectural-only reference simulator.
pub struct RefIss {
    vlen_bits: usize,
    /// Cycles → seconds clock used only when the ISS backs a
    /// `WorkloadReport` (the ISS itself never counts cycles).
    pub fmax_mhz: f64,
    pub pool: UnitPool,
    regs: [u32; 32],
    vregs: [VecVal; 8],
    pc: u32,
    instret: u64,
    halted: bool,
    mem: Vec<u8>,
    /// Predecoded text segment (shared contract with the timed core).
    text: DecodeCache,
    /// Lowered basic blocks, keyed by starting text-word index.
    blocks: BlockCache,
    /// Bumped on every text-range invalidation; the block executor uses
    /// it to notice that a store may have rewritten its own block.
    text_epoch: u64,
}

impl RefIss {
    /// ISS with the standard unit pool for `vlen_bits` and a flat memory
    /// of `mem_bytes`.
    pub fn new(vlen_bits: usize, mem_bytes: usize) -> Self {
        let lanes = vlen_bits / 32;
        Self {
            vlen_bits,
            fmax_mhz: 150.0,
            pool: standard_pool(vlen_bits),
            regs: [0; 32],
            vregs: [VecVal::zero(lanes); 8],
            pc: 0,
            instret: 0,
            halted: false,
            mem: vec![0; mem_bytes],
            text: DecodeCache::empty(),
            blocks: BlockCache::empty(),
            text_epoch: 0,
        }
    }

    /// Paper-shaped ISS (VLEN = 256) over `mem_bytes` of memory.
    pub fn paper_default(mem_bytes: usize) -> Self {
        Self::new(256, mem_bytes)
    }

    pub fn vlen_bits(&self) -> usize {
        self.vlen_bits
    }

    fn lanes(&self) -> usize {
        self.vlen_bits / 32
    }

    fn vlen_bytes(&self) -> usize {
        self.vlen_bits / 8
    }

    /// Load a program and reset architectural state, mirroring
    /// [`crate::core::Core::load`]: registers cleared, `sp` at the top
    /// of memory (16-byte aligned), pc at the entry point. Memory
    /// outside the program image is left as-is (a fresh ISS is
    /// all-zero, like fresh simulated DRAM). The whole text segment is
    /// predecoded here; undecodable words fault lazily, at their own pc,
    /// only if fetched.
    ///
    /// An image that does not fit in memory is rejected with
    /// [`SimError::ImageFault`] (mirroring the core's `checked_add`
    /// bounds pattern) and leaves the ISS unloaded rather than
    /// panicking.
    pub fn load(&mut self, prog: &Program) -> Result<(), SimError> {
        let size = self.mem.len();
        let text_len = prog.text.len() * 4;
        if prog.text_base as u64 + text_len as u64 > size as u64 {
            return Err(SimError::ImageFault { addr: prog.text_base, len: text_len, size });
        }
        if !prog.data.is_empty() && prog.data_base as u64 + prog.data.len() as u64 > size as u64 {
            return Err(SimError::ImageFault {
                addr: prog.data_base,
                len: prog.data.len(),
                size,
            });
        }
        let lanes = self.lanes();
        for (i, w) in prog.text.iter().enumerate() {
            let at = prog.text_base as usize + i * 4;
            self.mem[at..at + 4].copy_from_slice(&w.to_le_bytes());
        }
        if !prog.data.is_empty() {
            let at = prog.data_base as usize;
            self.mem[at..at + prog.data.len()].copy_from_slice(&prog.data);
        }
        self.regs = [0; 32];
        self.vregs = [VecVal::zero(lanes); 8];
        self.regs[2] = crate::arch::sp_init(self.mem.len());
        self.pc = prog.entry;
        self.instret = 0;
        self.halted = false;
        self.text.predecode(prog.text_base, &prog.text);
        self.blocks.reset(prog.text.len());
        self.text_epoch = 0;
        self.pool.reset_all();
        Ok(())
    }

    /// Host-side memory write (workload input images). Out-of-range
    /// writes are rejected with [`SimError::ImageFault`]; writes that
    /// land on the text segment invalidate the decoded view, like a
    /// store would.
    pub fn host_write(&mut self, addr: u32, data: &[u8]) -> Result<(), SimError> {
        if addr as u64 + data.len() as u64 > self.mem.len() as u64 {
            return Err(SimError::ImageFault { addr, len: data.len(), size: self.mem.len() });
        }
        let at = addr as usize;
        self.mem[at..at + data.len()].copy_from_slice(data);
        if self.text.overlaps(addr, data.len()) {
            self.invalidate_text(addr, data.len());
        }
        Ok(())
    }

    /// Overwrite one base register (the lockstep driver uses this to
    /// inject the timed core's value after a cycle/time CSR read, the
    /// one architecturally timing-dependent instruction).
    pub fn force_reg(&mut self, r: Reg, v: u32) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    #[inline]
    fn write_reg(&mut self, r: Reg, v: u32) {
        if r.num() != 0 {
            self.regs[r.num() as usize] = v;
        }
    }

    /// Register read by raw micro-op index (always 0..=31).
    #[inline]
    fn reg8(&self, n: u8) -> u32 {
        self.regs[(n & 31) as usize]
    }

    /// Register write by raw micro-op index (x0 stays hardwired zero).
    #[inline]
    fn set_reg8(&mut self, n: u8, v: u32) {
        if n != 0 {
            self.regs[(n & 31) as usize] = v;
        }
    }

    #[inline]
    fn write_vreg(&mut self, v: VReg, val: VecVal) {
        if v.num() != 0 {
            self.vregs[v.num() as usize] = val;
        }
    }

    #[inline]
    fn mem_ok(&self, addr: u32, len: usize) -> bool {
        // End-of-range rule in u64 (not usize, whose width is
        // host-dependent) — shared with the timed core and PicoCore.
        addr as u64 + len as u64 <= self.mem.len() as u64
    }

    /// Classify a failed data access: an end address overflowing the
    /// 32-bit space is a [`SimError::MemWrap`] (no DRAM size could make
    /// it legal), anything else an out-of-DRAM [`SimError::MemFault`].
    /// All three backends raise the identical fault for the same access.
    #[inline]
    fn mem_fault(&self, pc: u32, addr: u32, len: usize) -> SimError {
        if addr as u64 + len as u64 > 1 << 32 {
            SimError::MemWrap { pc, addr, len }
        } else {
            SimError::MemFault { pc, addr, len, size: self.mem.len() }
        }
    }

    #[inline]
    fn check_mem(&self, pc: u32, addr: u32, len: usize) -> Result<(), SimError> {
        if !self.mem_ok(addr, len) {
            return Err(self.mem_fault(pc, addr, len));
        }
        Ok(())
    }

    #[inline]
    fn load_u32(&self, addr: u32) -> u32 {
        let at = addr as usize;
        u32::from_le_bytes(self.mem[at..at + 4].try_into().unwrap())
    }

    /// Drop decoded state covering `[addr, addr+len)`: the per-word
    /// decode cache, every lowered block that spans an invalidated word,
    /// and the epoch the block executor watches.
    fn invalidate_text(&mut self, addr: u32, len: usize) {
        if let Some((first, last)) = self.text.invalidate(addr, len) {
            self.blocks.invalidate_span(first, last);
            self.text_epoch = self.text_epoch.wrapping_add(1);
        }
    }

    /// Decode (through the predecoded text cache) the instruction at
    /// `pc`. Mirrors the timed core's fetch fault order exactly
    /// (DESIGN.md §9): a non-word-aligned pc (reachable through `jalr`,
    /// which clears only bit 0, or a branch offset of 4k+2) is a
    /// misaligned-fetch fault, a pc outside memory is a fetch fault —
    /// both raised before any cache indexing so a truncating word index
    /// can never alias an aligned slot.
    fn fetch_decode(&mut self, pc: u32) -> Result<Instr, SimError> {
        if pc % 4 != 0 {
            return Err(SimError::FetchMisaligned { pc });
        }
        if !self.mem_ok(pc, 4) {
            return Err(SimError::FetchFault { pc, size: self.mem.len() });
        }
        if let Some(idx) = self.text.word_index(pc) {
            if let Some(i) = self.text.get(idx) {
                return Ok(i);
            }
            let i = decode(self.load_u32(pc)).map_err(|source| SimError::Illegal { pc, source })?;
            self.text.put(idx, i);
            return Ok(i);
        }
        decode(self.load_u32(pc)).map_err(|source| SimError::Illegal { pc, source })
    }

    /// Execute one instruction; returns the retired instruction (the
    /// lockstep driver inspects it to spot timing-dependent CSR reads).
    pub fn step(&mut self) -> Result<Instr, SimError> {
        debug_assert!(!self.halted, "step() after halt");
        let pc = self.pc;
        let instr = self.fetch_decode(pc)?;
        let next = self.exec(pc, instr)?;
        self.pc = next;
        self.instret += 1;
        Ok(instr)
    }

    /// [`RefIss::step`] with no decode caching at all (the `Uncached`
    /// oracle engine).
    fn step_uncached(&mut self) -> Result<Instr, SimError> {
        debug_assert!(!self.halted, "step() after halt");
        let pc = self.pc;
        if pc % 4 != 0 {
            return Err(SimError::FetchMisaligned { pc });
        }
        if !self.mem_ok(pc, 4) {
            return Err(SimError::FetchFault { pc, size: self.mem.len() });
        }
        let instr = decode(self.load_u32(pc)).map_err(|source| SimError::Illegal { pc, source })?;
        let next = self.exec(pc, instr)?;
        self.pc = next;
        self.instret += 1;
        Ok(instr)
    }

    /// Execute one decoded instruction at `pc`, returning the next pc.
    /// Does not touch `self.pc`/`self.instret` — every engine drives
    /// this one implementation with its own bookkeeping, so instruction
    /// semantics cannot diverge between engines.
    fn exec(&mut self, pc: u32, instr: Instr) -> Result<u32, SimError> {
        let mut next_pc = pc.wrapping_add(4);
        use Instr::*;
        match instr {
            Lui { rd, imm } => self.write_reg(rd, imm as u32),
            Auipc { rd, imm } => self.write_reg(rd, pc.wrapping_add(imm as u32)),
            Jal { rd, offset } => {
                self.write_reg(rd, pc.wrapping_add(4));
                next_pc = pc.wrapping_add(offset as u32);
            }
            Jalr { rd, rs1, offset } => {
                let base = self.regs[rs1.num() as usize];
                self.write_reg(rd, pc.wrapping_add(4));
                next_pc = base.wrapping_add(offset as u32) & !1;
            }
            Beq { rs1, rs2, offset }
            | Bne { rs1, rs2, offset }
            | Blt { rs1, rs2, offset }
            | Bge { rs1, rs2, offset }
            | Bltu { rs1, rs2, offset }
            | Bgeu { rs1, rs2, offset } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let take = match instr {
                    Beq { .. } => a == b,
                    Bne { .. } => a != b,
                    Blt { .. } => (a as i32) < (b as i32),
                    Bge { .. } => (a as i32) >= (b as i32),
                    Bltu { .. } => a < b,
                    Bgeu { .. } => a >= b,
                    _ => unreachable!(),
                };
                if take {
                    next_pc = pc.wrapping_add(offset as u32);
                }
            }
            Lb { rd, rs1, offset }
            | Lh { rd, rs1, offset }
            | Lw { rd, rs1, offset }
            | Lbu { rd, rs1, offset }
            | Lhu { rd, rs1, offset } => {
                let addr = self.regs[rs1.num() as usize].wrapping_add(offset as u32);
                let len = match instr {
                    Lb { .. } | Lbu { .. } => 1,
                    Lh { .. } | Lhu { .. } => 2,
                    _ => 4,
                };
                self.check_mem(pc, addr, len)?;
                let at = addr as usize;
                let value = match instr {
                    Lb { .. } => self.mem[at] as i8 as i32 as u32,
                    Lbu { .. } => self.mem[at] as u32,
                    Lh { .. } => i16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as i32 as u32,
                    Lhu { .. } => u16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as u32,
                    _ => self.load_u32(addr),
                };
                self.write_reg(rd, value);
            }
            Sb { rs1, rs2, offset } | Sh { rs1, rs2, offset } | Sw { rs1, rs2, offset } => {
                let addr = self.regs[rs1.num() as usize].wrapping_add(offset as u32);
                let len = match instr {
                    Sb { .. } => 1,
                    Sh { .. } => 2,
                    _ => 4,
                };
                self.check_mem(pc, addr, len)?;
                let bytes = self.regs[rs2.num() as usize].to_le_bytes();
                let at = addr as usize;
                self.mem[at..at + len].copy_from_slice(&bytes[..len]);
                if self.text.overlaps(addr, len) {
                    self.invalidate_text(addr, len);
                }
            }
            Addi { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a.wrapping_add(imm as u32));
            }
            Slti { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, ((a as i32) < imm) as u32);
            }
            Sltiu { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, (a < imm as u32) as u32);
            }
            Xori { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a ^ imm as u32);
            }
            Ori { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a | imm as u32);
            }
            Andi { rd, rs1, imm } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a & imm as u32);
            }
            Slli { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a << shamt);
            }
            Srli { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, a >> shamt);
            }
            Srai { rd, rs1, shamt } => {
                let a = self.regs[rs1.num() as usize];
                self.write_reg(rd, ((a as i32) >> shamt) as u32);
            }
            Add { rd, rs1, rs2 }
            | Sub { rd, rs1, rs2 }
            | Sll { rd, rs1, rs2 }
            | Slt { rd, rs1, rs2 }
            | Sltu { rd, rs1, rs2 }
            | Xor { rd, rs1, rs2 }
            | Srl { rd, rs1, rs2 }
            | Sra { rd, rs1, rs2 }
            | Or { rd, rs1, rs2 }
            | And { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Add { .. } => a.wrapping_add(b),
                    Sub { .. } => a.wrapping_sub(b),
                    Sll { .. } => a << (b & 31),
                    Slt { .. } => ((a as i32) < (b as i32)) as u32,
                    Sltu { .. } => (a < b) as u32,
                    Xor { .. } => a ^ b,
                    Srl { .. } => a >> (b & 31),
                    Sra { .. } => ((a as i32) >> (b & 31)) as u32,
                    Or { .. } => a | b,
                    And { .. } => a & b,
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Mul { rd, rs1, rs2 }
            | Mulh { rd, rs1, rs2 }
            | Mulhsu { rd, rs1, rs2 }
            | Mulhu { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Mul { .. } => a.wrapping_mul(b),
                    Mulh { .. } => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    Mulhsu { .. } => (((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32,
                    Mulhu { .. } => (((a as u64) * (b as u64)) >> 32) as u32,
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Div { rd, rs1, rs2 }
            | Divu { rd, rs1, rs2 }
            | Rem { rd, rs1, rs2 }
            | Remu { rd, rs1, rs2 } => {
                let a = self.regs[rs1.num() as usize];
                let b = self.regs[rs2.num() as usize];
                let v = match instr {
                    Div { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            a
                        } else {
                            ((a as i32).wrapping_div(b as i32)) as u32
                        }
                    }
                    Divu { .. } => {
                        if b == 0 {
                            u32::MAX
                        } else {
                            a / b
                        }
                    }
                    Rem { .. } => {
                        if b == 0 {
                            a
                        } else if a == 0x8000_0000 && b == u32::MAX {
                            0
                        } else {
                            ((a as i32).wrapping_rem(b as i32)) as u32
                        }
                    }
                    Remu { .. } => {
                        if b == 0 {
                            a
                        } else {
                            a % b
                        }
                    }
                    _ => unreachable!(),
                };
                self.write_reg(rd, v);
            }
            Fence => {}
            Ecall => {
                self.halted = true;
            }
            Ebreak => {
                return Err(SimError::Break(pc));
            }
            Csrrs { rd, csr: c, rs1: _ } => {
                // No cycles exist here; the cycle/time counters read as
                // instret (monotonic, like real time would be). The
                // lockstep driver overrides the value with the timed
                // core's — see DESIGN.md §9.
                let v = match c {
                    csr::CYCLE | csr::TIME | csr::INSTRET => self.instret as u32,
                    csr::CYCLEH | csr::TIMEH | csr::INSTRETH => (self.instret >> 32) as u32,
                    _ => 0,
                };
                self.write_reg(rd, v);
            }
            CustomI { slot, funct3, ops } => {
                self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    None,
                    0,
                    ops.vrs1,
                    ops.vrs2,
                    ops.rd,
                    ops.vrd1,
                    ops.vrd2,
                )?;
            }
            CustomS { slot, funct3, ops } => {
                self.exec_custom(
                    pc,
                    slot.index(),
                    funct3,
                    ops.rs1,
                    Some(ops.rs2),
                    ops.imm,
                    ops.vrs1,
                    crate::isa::reg::V0,
                    ops.rd,
                    ops.vrd1,
                    crate::isa::reg::V0,
                )?;
            }
        }
        Ok(next_pc)
    }

    /// Execute a custom instruction through the shared unit pool,
    /// performing any memory request on the flat image.
    #[allow(clippy::too_many_arguments)]
    fn exec_custom(
        &mut self,
        pc: u32,
        slot: usize,
        funct3: u8,
        rs1: Reg,
        rs2: Option<Reg>,
        imm: u8,
        vrs1: VReg,
        vrs2: VReg,
        rd: Reg,
        vrd1: VReg,
        vrd2: VReg,
    ) -> Result<(), SimError> {
        let inputs = UnitInputs {
            funct3,
            rs1: self.regs[rs1.num() as usize],
            rs2: rs2.map(|r| self.regs[r.num() as usize]).unwrap_or(0),
            imm,
            vrs1: self.vregs[vrs1.num() as usize],
            vrs2: self.vregs[vrs2.num() as usize],
        };
        let out = self
            .pool
            .get_mut(slot)
            .and_then(|u| u.execute(&inputs))
            .map_err(|source| SimError::Unit { pc, source })?;
        match out.mem {
            Some(VecMemOp::Load { addr }) => {
                let len = self.vlen_bytes();
                self.check_mem(pc, addr, len)?;
                let at = addr as usize;
                let val = VecVal::from_bytes(&self.mem[at..at + len]);
                self.write_vreg(vrd1, val);
            }
            Some(VecMemOp::Store { addr, data }) => {
                let len = self.vlen_bytes();
                self.check_mem(pc, addr, len)?;
                let mut buf = [0u8; crate::simd::MAX_VLEN_BITS / 8];
                data.write_bytes(&mut buf[..len]);
                let at = addr as usize;
                self.mem[at..at + len].copy_from_slice(&buf[..len]);
                if self.text.overlaps(addr, len) {
                    self.invalidate_text(addr, len);
                }
            }
            None => {
                if let Some(v) = out.vrd1 {
                    self.write_vreg(vrd1, v);
                }
                if let Some(v) = out.vrd2 {
                    self.write_vreg(vrd2, v);
                }
                if let Some(v) = out.rd {
                    self.write_reg(rd, v);
                }
            }
        }
        Ok(())
    }

    // ---- execution engines ------------------------------------------------

    /// Run until `ecall` or the instruction budget is exhausted, with the
    /// default (block) engine.
    pub fn run(&mut self, max_instrs: u64) -> Result<IssRunResult, SimError> {
        self.run_with(max_instrs, ExecEngine::Blocks)
    }

    /// [`RefIss::run`] with an explicit engine. All engines produce
    /// bit-identical architectural results (registers, pc, instret,
    /// memory image, fault identity).
    pub fn run_with(
        &mut self,
        max_instrs: u64,
        engine: ExecEngine,
    ) -> Result<IssRunResult, SimError> {
        match engine {
            ExecEngine::Blocks => self.run_blocks(max_instrs),
            ExecEngine::PerInstr => self.run_stepwise(max_instrs),
            ExecEngine::Uncached => self.run_uncached(max_instrs),
        }
    }

    fn run_blocks(&mut self, max_instrs: u64) -> Result<IssRunResult, SimError> {
        let start = self.instret;
        while !self.halted {
            let used = self.instret - start;
            if used >= max_instrs {
                return Err(SimError::Watchdog(max_instrs));
            }
            self.run_block(max_instrs - used)?;
        }
        Ok(IssRunResult { instret: self.instret })
    }

    fn run_stepwise(&mut self, max_instrs: u64) -> Result<IssRunResult, SimError> {
        let start = self.instret;
        while !self.halted {
            if self.instret - start >= max_instrs {
                return Err(SimError::Watchdog(max_instrs));
            }
            self.step()?;
        }
        Ok(IssRunResult { instret: self.instret })
    }

    fn run_uncached(&mut self, max_instrs: u64) -> Result<IssRunResult, SimError> {
        let start = self.instret;
        while !self.halted {
            if self.instret - start >= max_instrs {
                return Err(SimError::Watchdog(max_instrs));
            }
            self.step_uncached()?;
        }
        Ok(IssRunResult { instret: self.instret })
    }

    /// Execute (at most `budget` instructions of) the basic block at the
    /// current pc. Off-text and undecodable starts fall back to a single
    /// [`RefIss::step`], which raises exactly the faults the
    /// per-instruction engine would.
    fn run_block(&mut self, budget: u64) -> Result<(), SimError> {
        let pc0 = self.pc;
        let Some(idx) = self.text.word_index(pc0) else {
            self.step()?;
            return Ok(());
        };
        let block = match self.blocks.get(idx) {
            Some(b) => b.clone(),
            None => match self.form_block(idx) {
                Some(b) => b,
                None => {
                    self.step()?;
                    return Ok(());
                }
            },
        };
        let uops = block.uops;
        let n = (uops.len() as u64).min(budget) as usize;
        let epoch = self.text_epoch;
        let mut k = 0usize;
        while k < n {
            match uops[k] {
                Uop::Li { rd, v } => self.set_reg8(rd, v),
                Uop::AluImm { op, rd, rs1, imm } => {
                    let a = self.reg8(rs1);
                    let v = match op {
                        AluIOp::Add => a.wrapping_add(imm),
                        AluIOp::Slt => (((a as i32) < (imm as i32)) as u32),
                        AluIOp::Sltu => ((a < imm) as u32),
                        AluIOp::Xor => a ^ imm,
                        AluIOp::Or => a | imm,
                        AluIOp::And => a & imm,
                        AluIOp::Sll => a << (imm & 31),
                        AluIOp::Srl => a >> (imm & 31),
                        AluIOp::Sra => ((a as i32) >> (imm & 31)) as u32,
                    };
                    self.set_reg8(rd, v);
                }
                Uop::AluReg { op, rd, rs1, rs2 } => {
                    let a = self.reg8(rs1);
                    let b = self.reg8(rs2);
                    let v = match op {
                        AluROp::Add => a.wrapping_add(b),
                        AluROp::Sub => a.wrapping_sub(b),
                        AluROp::Sll => a << (b & 31),
                        AluROp::Slt => (((a as i32) < (b as i32)) as u32),
                        AluROp::Sltu => ((a < b) as u32),
                        AluROp::Xor => a ^ b,
                        AluROp::Srl => a >> (b & 31),
                        AluROp::Sra => ((a as i32) >> (b & 31)) as u32,
                        AluROp::Or => a | b,
                        AluROp::And => a & b,
                        AluROp::Mul => a.wrapping_mul(b),
                    };
                    self.set_reg8(rd, v);
                }
                Uop::Load { kind, rd, rs1, imm } => {
                    let addr = self.reg8(rs1).wrapping_add(imm);
                    let len = match kind {
                        LoadKind::B | LoadKind::Bu => 1,
                        LoadKind::H | LoadKind::Hu => 2,
                        LoadKind::W => 4,
                    };
                    if !self.mem_ok(addr, len) {
                        let pc = pc0.wrapping_add(4 * k as u32);
                        self.pc = pc;
                        return Err(self.mem_fault(pc, addr, len));
                    }
                    let at = addr as usize;
                    let v = match kind {
                        LoadKind::B => self.mem[at] as i8 as i32 as u32,
                        LoadKind::Bu => self.mem[at] as u32,
                        LoadKind::H => {
                            i16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as i32 as u32
                        }
                        LoadKind::Hu => u16::from_le_bytes([self.mem[at], self.mem[at + 1]]) as u32,
                        LoadKind::W => self.load_u32(addr),
                    };
                    self.set_reg8(rd, v);
                }
                Uop::Store { kind, rs1, rs2, imm } => {
                    let addr = self.reg8(rs1).wrapping_add(imm);
                    let len = kind.len();
                    if !self.mem_ok(addr, len) {
                        let pc = pc0.wrapping_add(4 * k as u32);
                        self.pc = pc;
                        return Err(self.mem_fault(pc, addr, len));
                    }
                    let bytes = self.reg8(rs2).to_le_bytes();
                    let at = addr as usize;
                    self.mem[at..at + len].copy_from_slice(&bytes[..len]);
                    if self.text.overlaps(addr, len) {
                        self.invalidate_text(addr, len);
                        // The store may have rewritten a later uop of
                        // this very block: retire it, then abort the
                        // block and re-enter through a fresh lookup.
                        self.instret += 1;
                        self.pc = pc0.wrapping_add(4 * (k as u32 + 1));
                        return Ok(());
                    }
                }
                Uop::Br { cond, rs1, rs2, target } => {
                    let a = self.reg8(rs1);
                    let b = self.reg8(rs2);
                    let take = match cond {
                        BrCond::Eq => a == b,
                        BrCond::Ne => a != b,
                        BrCond::Lt => (a as i32) < (b as i32),
                        BrCond::Ge => (a as i32) >= (b as i32),
                        BrCond::Ltu => a < b,
                        BrCond::Geu => a >= b,
                    };
                    if take {
                        self.instret += 1;
                        self.pc = target;
                        return Ok(());
                    }
                }
                Uop::Jal { rd, link, target } => {
                    self.set_reg8(rd, link);
                    self.instret += 1;
                    self.pc = target;
                    return Ok(());
                }
                Uop::Jalr { rd, rs1, imm, link } => {
                    let target = self.reg8(rs1).wrapping_add(imm) & !1;
                    self.set_reg8(rd, link);
                    self.instret += 1;
                    self.pc = target;
                    return Ok(());
                }
                Uop::Sys(instr) => {
                    let pc = pc0.wrapping_add(4 * k as u32);
                    match self.exec(pc, instr) {
                        Ok(next) => {
                            // A halt, a redirect or a text invalidation
                            // (custom vector store over code) ends the
                            // block here.
                            if self.halted
                                || next != pc.wrapping_add(4)
                                || self.text_epoch != epoch
                            {
                                self.instret += 1;
                                self.pc = next;
                                return Ok(());
                            }
                        }
                        Err(e) => {
                            self.pc = pc;
                            return Err(e);
                        }
                    }
                }
            }
            self.instret += 1;
            k += 1;
        }
        self.pc = pc0.wrapping_add(4 * n as u32);
        Ok(())
    }

    /// Lower the basic block starting at text-word `idx` (see
    /// [`block`] for the formation rules) and cache it. Returns `None`
    /// when the very first word is undecodable — the caller falls back
    /// to [`RefIss::step`], which reports the illegal-instruction fault
    /// at the right pc.
    fn form_block(&mut self, idx: usize) -> Option<Block> {
        let mut uops = Vec::with_capacity(8);
        let mut k = idx;
        while k < self.text.len() && uops.len() < MAX_BLOCK_UOPS {
            let pc = self.text.base().wrapping_add(4 * k as u32);
            let i = match self.text.get(k) {
                Some(i) => i,
                None => match decode(self.load_u32(pc)) {
                    Ok(i) => {
                        self.text.put(k, i);
                        i
                    }
                    Err(_) => break,
                },
            };
            uops.push(lower(i, pc));
            if ends_block(&i) {
                break;
            }
            k += 1;
        }
        if uops.is_empty() {
            return None;
        }
        let b = Block { uops: uops.into() };
        self.blocks.put(idx, b.clone());
        Some(b)
    }
}

impl ArchState for RefIss {
    fn reg(&self, r: Reg) -> u32 {
        self.regs[r.num() as usize]
    }

    fn vreg(&self, v: VReg) -> VecVal {
        self.vregs[v.num() as usize]
    }

    fn pc(&self) -> u32 {
        self.pc
    }

    fn instret(&self) -> u64 {
        self.instret
    }

    fn halted(&self) -> bool {
        self.halted
    }

    fn mem_size(&self) -> usize {
        self.mem.len()
    }

    fn mem_slice(&self, addr: u32, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;
    use crate::isa::{encode, Instr};

    const MEM: usize = 2 * 1024 * 1024;

    fn run_asm(build: impl FnOnce(&mut Asm)) -> RefIss {
        let mut a = Asm::new();
        build(&mut a);
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        iss.run(1_000_000).unwrap();
        iss
    }

    #[test]
    fn arithmetic_and_halt() {
        let iss = run_asm(|a| {
            a.li(A0, 20);
            a.li(A1, 22);
            a.add(A2, A0, A1);
            a.halt();
        });
        assert_eq!(iss.reg(A2), 42);
        assert!(iss.halted());
    }

    #[test]
    fn x0_and_v0_are_hardwired_zero() {
        let iss = run_asm(|a| {
            a.li(ZERO, 99);
            a.mv(A0, ZERO);
            a.halt();
        });
        assert_eq!(iss.reg(A0), 0);
        assert_eq!(iss.vreg(V0), VecVal::zero(8));
    }

    #[test]
    fn loops_loads_stores_and_muldiv() {
        let mut a = Asm::new();
        let buf = a.buffer("buf", 64, 8);
        a.la(A1, buf);
        a.li(A0, -2);
        a.sb(A0, 0, A1);
        a.lb(A2, 0, A1);
        a.lbu(A3, 0, A1);
        a.li(T0, -6);
        a.li(T1, 4);
        a.mul(A4, T0, T1);
        a.div(A5, T0, T1);
        a.rem(A6, T0, T1);
        let l = a.new_label("loop");
        a.li(S0, 10);
        a.li(S1, 0);
        a.bind(l);
        a.add(S1, S1, S0);
        a.addi(S0, S0, -1);
        a.bnez(S0, l);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        iss.run(10_000).unwrap();
        assert_eq!(iss.reg(A2) as i32, -2);
        assert_eq!(iss.reg(A3), 0xFE);
        assert_eq!(iss.reg(A4) as i32, -24);
        assert_eq!(iss.reg(A5) as i32, -1);
        assert_eq!(iss.reg(A6) as i32, -2);
        assert_eq!(iss.reg(S1), 55);
    }

    #[test]
    fn vector_load_sort_store() {
        let mut a = Asm::new();
        let data = a.words("data", &[5, 3, 8, 1, 9, 2, 7, 4].map(|x: i32| x as u32));
        a.dalign(32);
        let out = a.buffer("out", 32, 32);
        a.la(A0, data);
        a.la(A1, out);
        a.lv(V1, A0, ZERO);
        a.sort8(V2, V1);
        a.sv(V2, A1, ZERO);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        iss.run(100).unwrap();
        let got: Vec<i32> = iss
            .mem_slice(p.sym("out"), 32)
            .chunks(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5, 7, 8, 9]);
    }

    #[test]
    fn prefix_state_carries_and_resets_on_load() {
        let mut a = Asm::new();
        let d = a.words("d", &[1u32; 8]);
        a.la(A0, d);
        a.lv(V1, A0, ZERO);
        a.prefix_reset();
        a.prefix(V2, V1);
        a.prefix(V3, V1);
        a.prefix_carry(A5);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        iss.run(100).unwrap();
        assert_eq!(iss.vreg(V2).to_i32s(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(iss.vreg(V3).to_i32s(), vec![9, 10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(iss.reg(A5), 16);
        // Reloading resets the carry (pool.reset_all, as Core::load does).
        iss.load(&p).unwrap();
        iss.run(100).unwrap();
        assert_eq!(iss.reg(A5), 16);
    }

    #[test]
    fn watchdog_break_and_fault_mirror_the_core() {
        let mut a = Asm::new();
        let l = a.here("forever");
        a.j(l);
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        assert!(matches!(iss.run(1000), Err(SimError::Watchdog(1000))));

        let mut a = Asm::new();
        a.ebreak();
        let p = a.assemble().unwrap();
        iss.load(&p).unwrap();
        assert!(matches!(iss.run(10), Err(SimError::Break(_))));

        let mut a = Asm::new();
        a.li(A0, 0x7fff_f000u32 as i64);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        iss.load(&p).unwrap();
        assert!(matches!(iss.run(10), Err(SimError::MemFault { .. })));
    }

    #[test]
    fn cycle_csr_reads_instret() {
        let iss = run_asm(|a| {
            a.nop();
            a.nop();
            a.rdcycle(S0);
            a.rdinstret(S1);
            a.halt();
        });
        assert_eq!(iss.reg(S0), 2, "cycle CSR reads as instret on the ISS");
        assert_eq!(iss.reg(S1), 3);
    }

    #[test]
    fn oversized_images_are_rejected_not_panics() {
        let mut a = Asm::new();
        a.halt();
        let p = a.assemble().unwrap();
        // Image fits 2 MiB but not 16 bytes of DRAM.
        let mut tiny = RefIss::paper_default(16);
        assert!(matches!(tiny.load(&p), Err(SimError::ImageFault { .. })));

        let mut a = Asm::new();
        a.words("blob", &vec![0u32; 64]);
        a.halt();
        let p = a.assemble().unwrap();
        let mut tiny = RefIss::paper_default(64);
        assert!(matches!(tiny.load(&p), Err(SimError::ImageFault { .. })));
    }

    #[test]
    fn host_write_out_of_range_is_rejected_not_a_panic() {
        let mut iss = RefIss::paper_default(1024);
        assert!(iss.host_write(0, &[1, 2, 3]).is_ok());
        assert!(matches!(
            iss.host_write(1022, &[1, 2, 3]),
            Err(SimError::ImageFault { addr: 1022, len: 3, size: 1024 })
        ));
        assert!(matches!(
            iss.host_write(u32::MAX, &[0; 8]),
            Err(SimError::ImageFault { .. })
        ));
    }

    /// The confirmed stale-decode bug: overwrite an instruction that has
    /// already executed (and is therefore cached, both as a decoded word
    /// and inside a lowered block) and assert the *new* instruction runs
    /// on the next loop iteration.
    fn smc_patch_backward(engine: ExecEngine) -> RefIss {
        let patch = encode(&Instr::Addi { rd: A0, rs1: A0, imm: 100 }).unwrap();
        let mut a = Asm::new();
        a.li(A0, 0);
        a.li(S10, 2);
        a.li(T1, patch as i64);
        let head = a.new_label("head");
        let target = a.new_label("target");
        a.bind(head);
        a.bind(target);
        a.addi(A0, A0, 1); // overwritten after the first iteration
        a.la(T0, target);
        a.sw(T1, 0, T0);
        a.addi(S10, S10, -1);
        a.bnez(S10, head);
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        iss.run_with(10_000, engine).unwrap();
        iss
    }

    #[test]
    fn smc_store_over_executed_instruction_invalidates_decode_cache() {
        for engine in [ExecEngine::Blocks, ExecEngine::PerInstr, ExecEngine::Uncached] {
            let iss = smc_patch_backward(engine);
            assert_eq!(
                iss.reg(A0),
                101,
                "{engine:?}: second iteration must run the patched addi (1 + 100)"
            );
        }
    }

    /// Forward patch: rewrite an instruction that has *not* executed yet.
    /// With load-time predecode this also requires invalidation.
    #[test]
    fn smc_store_over_not_yet_executed_instruction() {
        let patch = encode(&Instr::Addi { rd: A0, rs1: A0, imm: 100 }).unwrap();
        for engine in [ExecEngine::Blocks, ExecEngine::PerInstr, ExecEngine::Uncached] {
            let mut a = Asm::new();
            a.li(A0, 0);
            a.li(T1, patch as i64);
            let target = a.new_label("target");
            a.la(T0, target);
            a.sw(T1, 0, T0);
            a.bind(target);
            a.nop(); // patched to `addi a0, a0, 100` before first execution
            a.halt();
            let p = a.assemble().unwrap();
            let mut iss = RefIss::paper_default(MEM);
            iss.load(&p).unwrap();
            iss.run_with(10_000, engine).unwrap();
            assert_eq!(iss.reg(A0), 100, "{engine:?}: patched instruction must execute");
        }
    }

    /// host_write over text must invalidate too (it is a store from the
    /// harness's point of view).
    #[test]
    fn host_write_over_text_invalidates_decode_cache() {
        let patch = encode(&Instr::Addi { rd: A0, rs1: ZERO, imm: 77 }).unwrap();
        let mut a = Asm::new();
        a.nop();
        a.halt();
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        // Prime the block cache, then patch the nop and re-run.
        iss.run(10).unwrap();
        iss.load(&p).unwrap();
        iss.host_write(p.text_base, &patch.to_le_bytes()).unwrap();
        iss.run(10).unwrap();
        assert_eq!(iss.reg(A0), 77);
    }

    #[test]
    fn engines_agree_on_fault_pc_and_instret() {
        // A block whose 3rd instruction faults: pc/instret must match
        // the per-instruction engines exactly.
        let build = || {
            let mut a = Asm::new();
            a.li(A0, 0x7fff_f000u32 as i64);
            a.nop();
            a.lw(A1, 0, A0);
            a.halt();
            a.assemble().unwrap()
        };
        let mut results = Vec::new();
        for engine in [ExecEngine::Blocks, ExecEngine::PerInstr, ExecEngine::Uncached] {
            let mut iss = RefIss::paper_default(MEM);
            iss.load(&build()).unwrap();
            let err = iss.run_with(100, engine).unwrap_err();
            results.push((format!("{err}"), iss.pc(), iss.instret()));
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn block_budget_slices_retire_exactly_max_instrs() {
        let mut a = Asm::new();
        let l = a.here("forever");
        a.addi(A0, A0, 1);
        a.addi(A1, A1, 1);
        a.j(l);
        let p = a.assemble().unwrap();
        let mut iss = RefIss::paper_default(MEM);
        iss.load(&p).unwrap();
        assert!(matches!(iss.run(7), Err(SimError::Watchdog(7))));
        assert_eq!(iss.instret(), 7, "block engine must not overrun the budget");
    }
}
