//! Counters collected by every level of the memory system. The
//! experiment reports (Figs. 3–4) are computed from these plus the core's
//! cycle counter.

/// Per-cache counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Write hits/misses are counted in `hits`/`misses`; this counts
    /// dirty evictions (write-backs to the next level).
    pub writebacks: u64,
    /// §3.1.1: vector-store misses that allocated without fetching.
    pub alloc_no_fetch: u64,
    /// Blocks fetched speculatively by the next-N-line prefetcher (LLC
    /// only; demand fills are counted in `misses`).
    pub prefetches: u64,
    /// Cycles misses spent waiting for a free MSHR (all-outstanding).
    pub mshr_wait_cycles: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }
}

/// DRAM/interconnect counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct DramStats {
    pub read_bursts: u64,
    pub write_bursts: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Core cycles the interconnect spent busy (setup + beats), summed
    /// over channels.
    pub busy_cycles: u64,
    /// Cycles bursts waited for a free channel (bandwidth contention):
    /// the gap between a burst's arrival and the earliest channel
    /// becoming free, summed over bursts.
    pub queue_cycles: u64,
}

impl DramStats {
    pub fn bursts(&self) -> u64 {
        self.read_bursts + self.write_bursts
    }

    pub fn bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Mean burst length in bytes (0 when no bursts happened).
    pub fn mean_burst_bytes(&self) -> f64 {
        if self.bursts() == 0 {
            0.0
        } else {
            self.bytes() as f64 / self.bursts() as f64
        }
    }
}

/// Aggregated memory-system stats snapshot.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MemStats {
    pub il1: CacheStats,
    pub dl1: CacheStats,
    pub llc: CacheStats,
    pub dram: DramStats,
}

impl MemStats {
    pub fn report(&self) -> String {
        format!(
            "IL1 {:>10} acc {:>6.2}% hit | DL1 {:>10} acc {:>6.2}% hit ({} wb, {} anf) | \
             LLC {:>10} acc {:>6.2}% hit ({} wb, {} pf) | DRAM {} rd + {} wr bursts, {} B, \
             {} busy cyc, {} queued cyc",
            self.il1.accesses(),
            self.il1.hit_rate() * 100.0,
            self.dl1.accesses(),
            self.dl1.hit_rate() * 100.0,
            self.dl1.writebacks,
            self.dl1.alloc_no_fetch,
            self.llc.accesses(),
            self.llc.hit_rate() * 100.0,
            self.llc.writebacks,
            self.llc.prefetches,
            self.dram.read_bursts,
            self.dram.write_bursts,
            self.dram.bytes(),
            self.dram.busy_cycles,
            self.dram.queue_cycles,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_zero() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn dram_aggregates() {
        let d = DramStats {
            read_bursts: 2,
            write_bursts: 2,
            bytes_read: 4096,
            bytes_written: 4096,
            busy_cycles: 100,
            queue_cycles: 0,
        };
        assert_eq!(d.bursts(), 4);
        assert_eq!(d.bytes(), 8192);
        assert!((d.mean_burst_bytes() - 2048.0).abs() < 1e-12);
    }

    #[test]
    fn report_is_human_readable() {
        let s = MemStats::default();
        let r = s.report();
        assert!(r.contains("IL1"));
        assert!(r.contains("DRAM"));
    }
}
