//! Level-1 caches (§3.1, §3.1.1).
//!
//! One implementation serves both L1s:
//! - **IL1**: direct-mapped (1 way), read-only, "implemented in registers"
//!   — a hit adds no stall, the next instruction is available on the next
//!   cycle.
//! - **DL1**: set-associative, write-back + write-allocate with NRU
//!   replacement; its block size equals the vector register width so a
//!   full-block (vector) store on a miss allocates **without fetching**
//!   the block from the LLC (§3.1.1).

use super::config::{CacheGeometry, Replacement};
use super::dram::Dram;
use super::llc::Llc;
use super::mshr::MshrFile;
use super::stats::CacheStats;

/// Largest supported L1 block (VLEN 1024 → 128 bytes); lets miss paths
/// use fixed stack buffers instead of heap allocation.
pub const MAX_BLOCK_BYTES: usize = 128;

pub struct L1Cache {
    geom: CacheGeometry,
    writable: bool,
    replacement: Replacement,
    /// xorshift state for Replacement::Random (deterministic).
    rand_state: u32,
    /// log2(block bytes) — lookups use shift/mask, not division.
    block_shift: u32,
    set_mask: usize,

    tags: Vec<u32>,
    valid: Vec<bool>,
    dirty: Vec<bool>,
    ru: Vec<bool>,
    data: Vec<u8>,

    /// Outstanding-miss tracking; single-entry = the legacy blocking
    /// port (gating is then the port's job, see `mem::mshr`).
    mshrs: MshrFile,

    stats: CacheStats,
}

impl L1Cache {
    pub fn new(geom: CacheGeometry, writable: bool) -> Self {
        Self::with_policy(geom, writable, Replacement::Nru)
    }

    pub fn with_policy(geom: CacheGeometry, writable: bool, replacement: Replacement) -> Self {
        let blocks = geom.sets * geom.ways;
        assert!(geom.block_bytes().is_power_of_two() && geom.sets.is_power_of_two());
        assert!(geom.block_bytes() <= MAX_BLOCK_BYTES);
        Self {
            geom,
            writable,
            replacement,
            rand_state: 0x9E37_79B9,
            block_shift: geom.block_bytes().trailing_zeros(),
            set_mask: geom.sets - 1,
            tags: vec![0; blocks],
            valid: vec![false; blocks],
            dirty: vec![false; blocks],
            ru: vec![false; blocks],
            data: vec![0; blocks * geom.block_bytes()],
            mshrs: MshrFile::new(1),
            stats: CacheStats::default(),
        }
    }

    /// Set the MSHR count (builder-style; 1 = blocking, the default).
    pub fn with_mshrs(mut self, count: usize) -> Self {
        self.mshrs = MshrFile::new(count.max(1));
        self
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Credit `n` extra hits (used by the core's fetch line buffer,
    /// which elides architecturally-hitting IL1 reads).
    pub fn credit_hits(&mut self, n: u64) {
        self.stats.hits += n;
    }

    #[inline]
    pub fn block_bytes(&self) -> usize {
        self.geom.block_bytes()
    }

    #[inline]
    fn set_of(&self, addr: u32) -> usize {
        (addr as usize >> self.block_shift) & self.set_mask
    }

    #[inline]
    fn tag_of(&self, addr: u32) -> u32 {
        ((addr as usize >> self.block_shift) / self.geom.sets) as u32
    }

    #[inline]
    fn slot(&self, set: usize, way: usize) -> usize {
        set * self.geom.ways + way
    }

    #[inline]
    fn block_base(&self, addr: u32) -> u32 {
        addr & !(self.block_bytes() as u32 - 1)
    }

    #[inline]
    fn lookup(&self, addr: u32) -> Option<usize> {
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        for w in 0..self.geom.ways {
            let s = self.slot(set, w);
            if self.valid[s] && self.tags[s] == tag {
                return Some(s);
            }
        }
        None
    }

    fn touch(&mut self, set: usize, way_slot: usize) {
        if self.geom.ways == 1 || self.ru[way_slot] {
            return; // direct-mapped, or already marked: no state change
        }
        self.ru[way_slot] = true;
        let all_used = (0..self.geom.ways).all(|w| {
            let s = self.slot(set, w);
            !self.valid[s] || self.ru[s]
        });
        if all_used {
            for w in 0..self.geom.ways {
                let s = self.slot(set, w);
                if s != way_slot {
                    self.ru[s] = false;
                }
            }
        }
    }

    fn victim(&mut self, set: usize) -> usize {
        for w in 0..self.geom.ways {
            if !self.valid[self.slot(set, w)] {
                return w;
            }
        }
        match self.replacement {
            Replacement::Nru => {
                for w in 0..self.geom.ways {
                    if !self.ru[self.slot(set, w)] {
                        return w;
                    }
                }
                0
            }
            Replacement::Random => {
                // xorshift32 — deterministic, policy-only randomness.
                let mut x = self.rand_state;
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                self.rand_state = x;
                (x as usize) & (self.geom.ways - 1)
            }
        }
    }

    /// Evict the victim of `addr`'s set (writing back if dirty) and claim
    /// its slot for `addr`. Returns the slot; contents are stale.
    fn evict_and_claim(&mut self, addr: u32, llc: &mut Llc, dram: &mut Dram, now: u64) -> usize {
        let set = self.set_of(addr);
        let way = self.victim(set);
        let slot = self.slot(set, way);
        if self.valid[slot] && self.dirty[slot] {
            self.stats.writebacks += 1;
            let bb = self.block_bytes();
            let victim_addr = ((self.tags[slot] as usize * self.geom.sets + set) * bb) as u32;
            let base = slot * bb;
            llc.write_sub(victim_addr, &self.data[base..base + bb], dram, now);
        }
        self.tags[slot] = self.tag_of(addr);
        self.valid[slot] = true;
        self.dirty[slot] = false;
        slot
    }

    /// Read `buf.len()` bytes at `addr`; the access must not cross a block
    /// boundary (the core guarantees natural alignment). Returns the cycle
    /// the data is available.
    pub fn read(
        &mut self,
        addr: u32,
        buf: &mut [u8],
        llc: &mut Llc,
        dram: &mut Dram,
        now: u64,
    ) -> u64 {
        let bb = self.block_bytes();
        debug_assert!(
            (addr as usize % bb) + buf.len() <= bb,
            "L1 read {addr:#x}+{} crosses a block boundary",
            buf.len()
        );
        let (slot, ready) = match self.lookup(addr) {
            Some(slot) => {
                self.stats.hits += 1;
                (slot, now)
            }
            None => {
                self.stats.misses += 1;
                // A miss needs an MSHR; with a multi-entry file it may
                // start while earlier misses are still in flight.
                let (mshr, issue) = self.mshrs.acquire(now);
                self.stats.mshr_wait_cycles += issue - now;
                let slot = self.evict_and_claim(addr, llc, dram, issue);
                let base = slot * bb;
                let block_addr = self.block_base(addr);
                let ready =
                    llc.read_sub(block_addr, &mut self.data[base..base + bb], dram, issue);
                self.mshrs.complete(mshr, ready);
                (slot, ready)
            }
        };
        let set = self.set_of(addr);
        self.touch(set, slot);
        let off = slot * bb + (addr as usize % bb);
        buf.copy_from_slice(&self.data[off..off + buf.len()]);
        ready
    }

    /// Write `data` at `addr` (write-back, write-allocate). A full-block
    /// aligned write allocates without fetching (§3.1.1). Returns the
    /// cycle the store retires.
    pub fn write(
        &mut self,
        addr: u32,
        data: &[u8],
        llc: &mut Llc,
        dram: &mut Dram,
        now: u64,
    ) -> u64 {
        assert!(self.writable, "write to read-only L1 (IL1)");
        let bb = self.block_bytes();
        debug_assert!(
            (addr as usize % bb) + data.len() <= bb,
            "L1 write {addr:#x}+{} crosses a block boundary",
            data.len()
        );
        let full_block = data.len() == bb && addr as usize % bb == 0;
        let (slot, ready) = match self.lookup(addr) {
            Some(slot) => {
                self.stats.hits += 1;
                (slot, now + 1)
            }
            None => {
                self.stats.misses += 1;
                if full_block {
                    // §3.1.1: the whole block is about to be overwritten —
                    // no need to wait for a fetch (and no MSHR: nothing
                    // is outstanding).
                    let slot = self.evict_and_claim(addr, llc, dram, now);
                    self.stats.alloc_no_fetch += 1;
                    (slot, now + 1)
                } else {
                    let (mshr, issue) = self.mshrs.acquire(now);
                    self.stats.mshr_wait_cycles += issue - now;
                    let slot = self.evict_and_claim(addr, llc, dram, issue);
                    let base = slot * bb;
                    let block_addr = self.block_base(addr);
                    let ready =
                        llc.read_sub(block_addr, &mut self.data[base..base + bb], dram, issue);
                    self.mshrs.complete(mshr, ready);
                    (slot, ready + 1)
                }
            }
        };
        let set = self.set_of(addr);
        self.touch(set, slot);
        self.dirty[slot] = true;
        let off = slot * bb + (addr as usize % bb);
        self.data[off..off + data.len()].copy_from_slice(data);
        ready
    }

    /// Write back all dirty blocks (host-side, no timing).
    pub fn flush(&mut self, llc: &mut Llc, dram: &mut Dram) {
        for set in 0..self.geom.sets {
            for way in 0..self.geom.ways {
                let slot = self.slot(set, way);
                if self.valid[slot] && self.dirty[slot] {
                    let bb = self.block_bytes();
                    let addr =
                        ((self.tags[slot] as usize * self.geom.sets + set) * bb) as u32;
                    let base = slot * bb;
                    llc.write_sub(addr, &self.data[base..base + bb], dram, 0);
                    self.dirty[slot] = false;
                }
            }
        }
    }

    /// Invalidate everything without writing back (IL1 refill / tests);
    /// also forgets in-flight misses.
    pub fn invalidate_all(&mut self) {
        self.valid.iter_mut().for_each(|v| *v = false);
        self.dirty.iter_mut().for_each(|v| *v = false);
        self.ru.iter_mut().for_each(|v| *v = false);
        self.mshrs.reset();
    }

    /// Hierarchy-aware host read of one byte.
    pub fn peek(&self, addr: u32, llc: &Llc, dram: &Dram) -> u8 {
        if let Some(slot) = self.lookup(addr) {
            let off = slot * self.block_bytes() + (addr as usize % self.block_bytes());
            return self.data[off];
        }
        llc.peek(addr, dram)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::config::MemConfig;

    fn mk() -> (L1Cache, Llc, Dram) {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        (L1Cache::new(cfg.dl1, true), Llc::new(&cfg), Dram::new(cfg.dram))
    }

    #[test]
    fn read_write_roundtrip() {
        let (mut dl1, mut llc, mut dram) = mk();
        dl1.write(0x100, &42u32.to_le_bytes(), &mut llc, &mut dram, 0);
        let mut buf = [0u8; 4];
        dl1.read(0x100, &mut buf, &mut llc, &mut dram, 10);
        assert_eq!(u32::from_le_bytes(buf), 42);
    }

    #[test]
    fn hit_is_free_miss_pays_llc() {
        let (mut dl1, mut llc, mut dram) = mk();
        dram.host_write(0x2000, &[9u8; 32]);
        let mut buf = [0u8; 4];
        let r1 = dl1.read(0x2000, &mut buf, &mut llc, &mut dram, 0);
        assert!(r1 > 20, "cold miss goes to DRAM");
        let r2 = dl1.read(0x2004, &mut buf, &mut llc, &mut dram, 100);
        assert_eq!(r2, 100, "same-block hit has no memory stall");
        assert_eq!(dl1.stats().hits, 1);
        assert_eq!(dl1.stats().misses, 1);
    }

    #[test]
    fn full_block_store_skips_fetch() {
        let (mut dl1, mut llc, mut dram) = mk();
        let vec_data = [0xABu8; 32]; // VLEN=256 full block
        let ready = dl1.write(0x4000, &vec_data, &mut llc, &mut dram, 0);
        assert_eq!(ready, 1, "no fetch latency");
        assert_eq!(dl1.stats().alloc_no_fetch, 1);
        assert_eq!(dram.stats().read_bursts, 0);
        assert_eq!(llc.stats().accesses(), 0, "no LLC traffic either");
    }

    #[test]
    fn partial_store_miss_fetches_block() {
        let (mut dl1, mut llc, mut dram) = mk();
        dram.host_write(0x4000, &[0x11u8; 32]);
        let ready = dl1.write(0x4004, &7u32.to_le_bytes(), &mut llc, &mut dram, 0);
        assert!(ready > 20, "partial write must fetch the rest of the block");
        // Block now = old content with word 1 replaced.
        let mut buf = [0u8; 4];
        dl1.read(0x4000, &mut buf, &mut llc, &mut dram, 100);
        assert_eq!(buf, [0x11; 4]);
        dl1.read(0x4004, &mut buf, &mut llc, &mut dram, 100);
        assert_eq!(u32::from_le_bytes(buf), 7);
    }

    #[test]
    fn dirty_eviction_reaches_llc_and_dram() {
        let (mut dl1, mut llc, mut dram) = mk();
        // DL1 paper-default: 32 sets × 32-byte blocks → same set every
        // 1024 bytes. Write 5 dirty blocks in one set (4 ways).
        for i in 0..5u32 {
            let data = [i as u8 + 1; 32];
            dl1.write(0x1000 + i * 1024, &data, &mut llc, &mut dram, 0);
        }
        assert!(dl1.stats().writebacks >= 1);
        // The evicted block must be readable through the hierarchy.
        dl1.flush(&mut llc, &mut dram);
        llc.flush(&mut dram);
        for i in 0..5u32 {
            let mut got = [0u8; 32];
            dram.host_read(0x1000 + i * 1024, &mut got);
            assert_eq!(got, [i as u8 + 1; 32], "block {i}");
        }
    }

    #[test]
    fn direct_mapped_il1_conflicts() {
        let cfg = MemConfig::paper_default();
        let mut il1 = L1Cache::new(cfg.il1, false);
        let mut llc = Llc::new(&cfg);
        let mut dram = Dram::new(crate::mem::config::DramConfig {
            size_bytes: 1 << 20,
            ..cfg.dram
        });
        let mut buf = [0u8; 4];
        // IL1: 64 sets × 32 B = 2 KiB; addresses 2 KiB apart conflict.
        il1.read(0x0000, &mut buf, &mut llc, &mut dram, 0);
        il1.read(0x0800, &mut buf, &mut llc, &mut dram, 100);
        il1.read(0x0000, &mut buf, &mut llc, &mut dram, 200);
        assert_eq!(il1.stats().misses, 3, "direct-mapped conflict evicts");
    }

    #[test]
    #[should_panic(expected = "read-only")]
    fn il1_rejects_writes() {
        let cfg = MemConfig::paper_default();
        let mut il1 = L1Cache::new(cfg.il1, false);
        let mut llc = Llc::new(&cfg);
        let mut dram =
            Dram::new(crate::mem::config::DramConfig { size_bytes: 1 << 20, ..cfg.dram });
        il1.write(0, &[0u8; 4], &mut llc, &mut dram, 0);
    }

    #[test]
    fn dl1_mshr_file_bounds_overlap() {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        let mut dl1 = L1Cache::with_policy(cfg.dl1, true, cfg.replacement).with_mshrs(2);
        let mut llc = Llc::new(&cfg);
        let mut dram = Dram::new(cfg.dram);
        let mut buf = [0u8; 4];
        // Two misses to different LLC blocks fit in the two MSHRs…
        dl1.read(0x0000, &mut buf, &mut llc, &mut dram, 0);
        dl1.read(0x10000, &mut buf, &mut llc, &mut dram, 1);
        assert_eq!(dl1.stats().mshr_wait_cycles, 0);
        // …the third must wait for a slot to free.
        dl1.read(0x20000, &mut buf, &mut llc, &mut dram, 2);
        assert!(dl1.stats().mshr_wait_cycles > 0, "third miss waited for an MSHR");
        assert_eq!(dl1.stats().misses, 3);
    }

    #[test]
    fn peek_prefers_l1_dirty_data() {
        let (mut dl1, mut llc, mut dram) = mk();
        dl1.write(0x3000, &[0x66u8; 4], &mut llc, &mut dram, 0);
        assert_eq!(dl1.peek(0x3000, &llc, &dram), 0x66);
        assert_eq!(llc.peek(0x3000, &dram), 0, "LLC unaware of DL1 dirty line");
    }
}
