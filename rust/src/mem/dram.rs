//! DRAM + AXI-style interconnect model (§3.1.2–3.1.4).
//!
//! The model is burst-oriented: the LLC transfers whole LLC blocks as
//! single bursts ("associating entire LLC blocks with bursts was a
//! convenient and practical organisation choice", §3.1.2). A burst costs
//! `burst_setup_cycles` plus one beat of `axi_width_bits` per cycle (two
//! per cycle at double rate, §3.1.4). The interconnect has
//! `DramConfig::channels` independent channels; a burst occupies the
//! earliest-free channel end to end, so with one channel (the paper's
//! configuration) overlapping requests queue exactly as before, while
//! with several channels concurrent fills and write-backs contend for
//! aggregate bandwidth instead of serialising. The wait for a free
//! channel is accounted in `DramStats::queue_cycles`.
//!
//! AXI's 4 KiB-boundary rule is honoured structurally: the LLC never
//! issues a burst that crosses a 4 KiB boundary because LLC blocks are
//! power-of-two sized, block-aligned and at most 4 KiB (validated in
//! [`super::config::MemConfig::validate`] geometry); a debug assertion
//! checks it here.

use super::config::DramConfig;
use super::stats::DramStats;

pub struct Dram {
    cfg: DramConfig,
    data: Vec<u8>,
    /// Per-channel busy-until core cycle.
    busy_until: Vec<u64>,
    stats: DramStats,
}

/// Timing result of a burst: when the first `critical_offset` bytes are
/// available (critical-word-first, §3.1.3) and when the burst fully ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BurstTiming {
    /// Cycle at which the critical prefix has landed.
    pub critical_ready: u64,
    /// Cycle at which the whole burst is done (channel free).
    pub done: u64,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Self {
        Self {
            cfg,
            data: vec![0u8; cfg.size_bytes],
            busy_until: vec![0; cfg.channels.max(1)],
            stats: DramStats::default(),
        }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    pub fn stats(&self) -> DramStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = DramStats::default();
    }

    /// Beats needed for `bytes`.
    fn beats(&self, bytes: usize) -> u64 {
        let bpc = self.cfg.bytes_per_cycle();
        bytes.div_ceil(bpc) as u64
    }

    /// Place a transaction arriving at `now` on the earliest-free
    /// channel; returns `(channel, start)` and accounts the queue wait.
    fn claim_channel(&mut self, now: u64) -> (usize, u64) {
        let (ch, &busy) = self
            .busy_until
            .iter()
            .enumerate()
            .min_by_key(|&(_, &busy)| busy)
            .expect("at least one channel");
        let start = now.max(busy);
        self.stats.queue_cycles += start - now;
        (ch, start)
    }

    /// Drop all channel occupancy (program load / timing reset).
    pub fn reset_timing(&mut self) {
        self.busy_until.iter_mut().for_each(|b| *b = 0);
    }

    #[inline]
    fn check_range(&self, addr: u32, len: usize) {
        debug_assert!(
            (addr as usize) + len <= self.data.len(),
            "DRAM access {addr:#x}+{len} beyond size {:#x}",
            self.data.len()
        );
        // AXI 4 KiB boundary rule: a burst must not cross a 4 KiB page.
        debug_assert!(
            len <= 4096 && (addr as usize % 4096) + len <= 4096 || len > 4096,
            "burst {addr:#x}+{len} crosses a 4KiB AXI boundary"
        );
    }

    /// Read a whole burst of `buf.len()` bytes starting at `addr`.
    ///
    /// `critical_offset` is the byte offset (within the burst) of the
    /// datum the requester is stalled on; `critical_ready` reports when
    /// the beats covering `[0, critical_offset]` have arrived, because
    /// §3.1.3's sub-blocked LLC forwards the requested L1 block before the
    /// burst finishes.
    pub fn read_burst(
        &mut self,
        addr: u32,
        buf: &mut [u8],
        critical_offset: usize,
        now: u64,
    ) -> BurstTiming {
        self.check_range(addr, buf.len());
        let a = addr as usize;
        buf.copy_from_slice(&self.data[a..a + buf.len()]);

        let (ch, start) = self.claim_channel(now);
        let transfer_start = start + self.cfg.burst_setup_cycles;
        let critical_beats = self.beats(critical_offset + 1);
        let total_beats = self.beats(buf.len());
        let done = transfer_start + total_beats;
        self.stats.read_bursts += 1;
        self.stats.bytes_read += buf.len() as u64;
        self.stats.busy_cycles += done - start;
        self.busy_until[ch] = done;
        BurstTiming { critical_ready: transfer_start + critical_beats, done }
    }

    /// Write a whole burst. Returns when the channel is free again.
    pub fn write_burst(&mut self, addr: u32, buf: &[u8], now: u64) -> u64 {
        self.check_range(addr, buf.len());
        let a = addr as usize;
        self.data[a..a + buf.len()].copy_from_slice(buf);

        let (ch, start) = self.claim_channel(now);
        let done = start + self.cfg.burst_setup_cycles + self.beats(buf.len());
        self.stats.write_bursts += 1;
        self.stats.bytes_written += buf.len() as u64;
        self.stats.busy_cycles += done - start;
        self.busy_until[ch] = done;
        done
    }

    /// Single-beat (AXI-Lite style) 32-bit read — used by the PicoRV32
    /// baseline model, which has no cache and no bursts.
    pub fn read_word_single(&mut self, addr: u32, latency: u64, now: u64) -> (u32, u64) {
        self.check_range(addr, 4);
        let a = addr as usize & !3;
        let w = u32::from_le_bytes(self.data[a..a + 4].try_into().unwrap());
        let (ch, start) = self.claim_channel(now);
        let done = start + latency;
        self.stats.read_bursts += 1;
        self.stats.bytes_read += 4;
        self.stats.busy_cycles += done - start;
        self.busy_until[ch] = done;
        (w, done)
    }

    /// Single-beat 32-bit write (AXI-Lite style).
    pub fn write_word_single(&mut self, addr: u32, value: u32, latency: u64, now: u64) -> u64 {
        self.check_range(addr, 4);
        let a = addr as usize & !3;
        self.data[a..a + 4].copy_from_slice(&value.to_le_bytes());
        let (ch, start) = self.claim_channel(now);
        let done = start + latency;
        self.stats.write_bursts += 1;
        self.stats.bytes_written += 4;
        self.stats.busy_cycles += done - start;
        self.busy_until[ch] = done;
        done
    }

    // ---- host (zero-time) access for program loading & verification -----

    pub fn host_read(&self, addr: u32, buf: &mut [u8]) {
        let a = addr as usize;
        buf.copy_from_slice(&self.data[a..a + buf.len()]);
    }

    pub fn host_write(&mut self, addr: u32, buf: &[u8]) {
        let a = addr as usize;
        self.data[a..a + buf.len()].copy_from_slice(buf);
    }

    pub fn host_slice(&self, addr: u32, len: usize) -> &[u8] {
        &self.data[addr as usize..addr as usize + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DramConfig {
        DramConfig {
            size_bytes: 1 << 20,
            axi_width_bits: 128,
            double_rate: true,
            burst_setup_cycles: 20,
            channels: 1,
        }
    }

    #[test]
    fn burst_roundtrip_preserves_data() {
        let mut d = Dram::new(cfg());
        let src: Vec<u8> = (0..2048).map(|i| (i % 251) as u8).collect();
        d.write_burst(0x1000, &src, 0);
        let mut out = vec![0u8; 2048];
        d.read_burst(0x1000, &mut out, 0, 100);
        assert_eq!(src, out);
    }

    #[test]
    fn burst_timing_setup_plus_beats() {
        let mut d = Dram::new(cfg());
        let mut buf = vec![0u8; 2048];
        // 2048 bytes at 32 B/cycle = 64 beats; setup 20.
        let t = d.read_burst(0, &mut buf, 0, 0);
        assert_eq!(t.done, 20 + 64);
        // Critical word at offset 0 arrives after the first beat.
        assert_eq!(t.critical_ready, 21);
    }

    #[test]
    fn critical_word_first_scales_with_offset() {
        let mut d = Dram::new(cfg());
        let mut buf = vec![0u8; 2048];
        // Critical offset into the second half of the burst.
        let t = d.read_burst(0, &mut buf, 1024, 0);
        assert_eq!(t.critical_ready, 20 + 33); // beats covering 1025 bytes
        assert!(t.critical_ready < t.done);
    }

    #[test]
    fn channel_serialises_bursts() {
        let mut d = Dram::new(cfg());
        let mut buf = vec![0u8; 1024];
        let t1 = d.read_burst(0, &mut buf, 0, 0);
        // Second burst issued "in the past" still queues behind the first.
        let t2 = d.read_burst(4096, &mut buf, 0, 1);
        assert!(t2.critical_ready > t1.done);
        assert_eq!(t2.done, t1.done + 20 + 32);
    }

    #[test]
    fn two_channels_overlap_bursts() {
        let mut two = cfg();
        two.channels = 2;
        let mut d = Dram::new(two);
        let mut buf = vec![0u8; 1024];
        // Two bursts back to back run on separate channels: no queueing.
        let t1 = d.read_burst(0, &mut buf, 0, 0);
        let t2 = d.read_burst(4096, &mut buf, 0, 1);
        assert_eq!(t1.done, 20 + 32);
        assert_eq!(t2.done, 1 + 20 + 32, "second channel starts immediately");
        assert_eq!(d.stats().queue_cycles, 0);
        // A third burst queues behind the earliest-free channel.
        let t3 = d.read_burst(8192, &mut buf, 0, 2);
        assert_eq!(t3.done, t1.done + 20 + 32);
        assert_eq!(d.stats().queue_cycles, t1.done - 2);
    }

    #[test]
    fn single_rate_halves_throughput() {
        let mut slow = cfg();
        slow.double_rate = false;
        let mut d = Dram::new(slow);
        let mut buf = vec![0u8; 2048];
        let t = d.read_burst(0, &mut buf, 0, 0);
        assert_eq!(t.done, 20 + 128);
    }

    #[test]
    fn axi_lite_single_beats() {
        let mut d = Dram::new(cfg());
        d.host_write(0x40, &0xdead_beefu32.to_le_bytes());
        let (w, done) = d.read_word_single(0x40, 30, 5);
        assert_eq!(w, 0xdead_beef);
        assert_eq!(done, 35);
        let done2 = d.write_word_single(0x44, 7, 30, 0);
        assert_eq!(done2, 65, "queues behind the read");
    }

    #[test]
    fn stats_accumulate() {
        let mut d = Dram::new(cfg());
        let mut buf = vec![0u8; 512];
        d.read_burst(0, &mut buf, 0, 0);
        d.write_burst(0x1000, &buf, 0);
        let s = d.stats();
        assert_eq!(s.read_bursts, 1);
        assert_eq!(s.write_bursts, 1);
        assert_eq!(s.bytes(), 1024);
        assert!(s.busy_cycles > 0);
    }

    #[test]
    #[should_panic(expected = "crosses a 4KiB AXI boundary")]
    #[cfg_attr(not(debug_assertions), ignore = "debug_assert compiled out in release")]
    fn boundary_crossing_trips_debug_assert() {
        let mut d = Dram::new(cfg());
        let mut buf = vec![0u8; 2048];
        d.read_burst(3072, &mut buf, 0, 0); // 3072+2048 crosses 4096
    }
}
