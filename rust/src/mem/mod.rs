//! Memory-hierarchy substrate (§3.1 of the paper): IL1 (direct-mapped,
//! register-backed), DL1 (set-associative write-back, block = VLEN), a
//! unified wide-block sub-blocked LLC with NRU replacement, and an
//! AXI-style burst DRAM model with an optional double-rate interconnect
//! and one or more independent channels.
//!
//! The hierarchy is non-blocking when configured so: MSHR files at DL1
//! and the LLC (`MemConfig::{dl1_mshrs, llc_mshrs}`) bound how many
//! misses overlap, a next-N-line stream prefetcher
//! (`MemConfig::prefetch_depth`) rides the LLC fill path, and
//! `DramConfig::channels` models aggregate DRAM bandwidth. The defaults
//! (1 MSHR, depth 0, 1 channel) reproduce the paper's blocking model
//! cycle for cycle. A flat magic-memory oracle
//! (`MemConfig::model = MemModel::Flat`) backs the differential tests.

pub mod config;
pub mod dram;
pub mod l1;
pub mod llc;
pub mod memsys;
pub mod mshr;
pub mod stats;

pub use config::{CacheGeometry, DramConfig, MemConfig, MemConfigError, MemModel, Replacement};
pub use dram::{BurstTiming, Dram};
pub use l1::L1Cache;
pub use llc::Llc;
pub use memsys::{Access, MemSys};
pub use mshr::MshrFile;
pub use stats::{CacheStats, DramStats, MemStats};
