//! Memory-hierarchy substrate (§3.1 of the paper): IL1 (direct-mapped,
//! register-backed), DL1 (set-associative write-back, block = VLEN), a
//! unified wide-block sub-blocked LLC with NRU replacement, and an
//! AXI-style burst DRAM model with an optional double-rate interconnect.

pub mod config;
pub mod dram;
pub mod l1;
pub mod llc;
pub mod memsys;
pub mod stats;

pub use config::{CacheGeometry, DramConfig, MemConfig, MemConfigError, Replacement};
pub use dram::{BurstTiming, Dram};
pub use l1::L1Cache;
pub use llc::Llc;
pub use memsys::MemSys;
pub use stats::{CacheStats, DramStats, MemStats};
