//! Miss-status-holding registers: the bounded set of outstanding misses
//! a cache level may have in flight. This is what turns the hierarchy
//! non-blocking — a miss only has to wait when every MSHR is already
//! tracking an earlier miss, so up to `capacity` misses overlap on the
//! DRAM channels (miss-under-miss) while hits proceed immediately
//! (hit-under-miss).
//!
//! The file tracks occupancy only; callers account the wait they
//! observe (`issue - now` from [`MshrFile::acquire`]) into their own
//! `CacheStats::mshr_wait_cycles` — one counter, owned by the cache
//! level, resettable with the rest of its stats.
//!
//! A **single-entry** file is special-cased as the legacy blocking
//! model: there the port register itself is the one MSHR and the port's
//! hold-until-data-returns ordering already serialises misses, so
//! [`MshrFile::acquire`] applies no extra gating (gating on the burst
//! *end* would double-count the latency the port already exposed and
//! change the calibrated Table-1 timing).

/// Busy-until cycle per MSHR slot.
#[derive(Debug, Clone)]
pub struct MshrFile {
    slots: Vec<u64>,
}

impl MshrFile {
    /// `capacity >= 1` (validated by `MemConfig::validate`).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "an MSHR file needs at least one slot");
        Self { slots: vec![0; capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Begin tracking a miss arriving at `now`: returns `(slot, issue)`
    /// where `issue >= now` is the cycle the miss may actually start
    /// (when the earliest slot frees). The caller must follow up with
    /// [`MshrFile::complete`] once the miss's finish time is known.
    /// Single-entry files never gate (see module docs).
    pub fn acquire(&mut self, now: u64) -> (usize, u64) {
        if self.slots.len() == 1 {
            return (0, now);
        }
        let (slot, &busy) = self
            .slots
            .iter()
            .enumerate()
            .min_by_key(|&(_, &busy)| busy)
            .expect("file is non-empty");
        (slot, now.max(busy))
    }

    /// Mark `slot` busy until `done` (the miss's data has landed).
    pub fn complete(&mut self, slot: usize, done: u64) {
        if self.slots.len() > 1 {
            self.slots[slot] = self.slots[slot].max(done);
        }
    }

    /// A slot that is already free at `now`, if any — used by the
    /// prefetcher, which must never delay a demand miss to get a slot.
    pub fn try_acquire(&mut self, now: u64) -> Option<usize> {
        if self.slots.len() == 1 {
            return None;
        }
        self.slots.iter().position(|&busy| busy <= now)
    }

    /// Drop all in-flight state (program load / test reset).
    pub fn reset(&mut self) {
        self.slots.iter_mut().for_each(|s| *s = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_entry_never_gates() {
        let mut m = MshrFile::new(1);
        let (_, issue) = m.acquire(5);
        assert_eq!(issue, 5);
        m.complete(0, 100);
        let (_, issue) = m.acquire(6);
        assert_eq!(issue, 6, "blocking-port mode leaves gating to the port");
        assert_eq!(m.try_acquire(0), None, "prefetch disabled at capacity 1");
    }

    #[test]
    fn misses_overlap_up_to_capacity() {
        let mut m = MshrFile::new(2);
        let (s0, i0) = m.acquire(0);
        m.complete(s0, 50);
        let (s1, i1) = m.acquire(1);
        m.complete(s1, 60);
        assert_eq!((i0, i1), (0, 1), "two misses in flight, no wait");
        // Third miss must wait for the earliest slot (busy until 50).
        let (_, i2) = m.acquire(2);
        assert_eq!(i2, 50, "all MSHRs busy: gated to the first release");
    }

    #[test]
    fn try_acquire_only_returns_free_slots() {
        let mut m = MshrFile::new(2);
        let (s0, _) = m.acquire(0);
        m.complete(s0, 50);
        let s1 = m.try_acquire(0).expect("one slot still free");
        m.complete(s1, 80);
        assert_eq!(m.try_acquire(10), None, "both busy");
        assert!(m.try_acquire(60).is_some(), "slot 0 freed at 50");
        m.reset();
        assert!(m.try_acquire(0).is_some());
    }
}
