//! The assembled memory system: IL1 + DL1 over a unified LLC over DRAM
//! (Fig. 2 of the paper). This is the object the simulated core talks to.
//!
//! The data port is modelled here, not in the core: every access goes
//! through [`MemSys::read`] / [`MemSys::write`] and returns an
//! [`Access`] splitting *issue* (when the port accepted the operation)
//! from *ready* (when its data is available / the store retired). With
//! the default single DL1 MSHR the port is **blocking** — it holds until
//! the previous access's data returned, reproducing the paper model
//! cycle for cycle. With `dl1_mshrs >= 2` the port frees one cycle after
//! issue: hits proceed under outstanding misses and misses overlap up to
//! the MSHR counts (the non-blocking hierarchy).
//!
//! `MemConfig::model == MemModel::Flat` swaps the whole hierarchy for a
//! flat single-cycle "magic memory" with identical architectural
//! behaviour — the oracle the differential test suite runs every
//! workload against.

use super::config::{MemConfig, MemConfigError, MemModel};
use super::dram::Dram;
use super::l1::L1Cache;
use super::llc::Llc;
use super::stats::MemStats;
use crate::asm::Program;

/// Timing of one data-port access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    /// Cycle the port accepted the operation (>= the request cycle).
    pub issue: u64,
    /// Cycle the load data is available / the store retired.
    pub ready: u64,
    /// Portion of `issue - request` spent waiting for the port register
    /// itself (structural hazard: an operation issued last cycle).
    pub struct_stall: u64,
    /// Portion of `issue - request` spent waiting for in-flight data on
    /// the blocking port (bandwidth/latency exposure). Zero on a
    /// non-blocking port, where waiting moves into MSHR/queue stats.
    pub bw_stall: u64,
}

pub struct MemSys {
    pub cfg: MemConfig,
    il1: L1Cache,
    dl1: L1Cache,
    llc: Llc,
    dram: Dram,
    /// Cycle the next data-port operation may start.
    port_free: u64,
    /// The structural part of `port_free` (previous issue + 1); the
    /// remainder up to `port_free` is blocking-mode data hold.
    port_free_struct: u64,
    /// Blocking port semantics (single DL1 MSHR).
    blocking: bool,
}

impl MemSys {
    /// Build a memory system, rejecting invalid configurations (zero
    /// ways/MSHRs/channels, mismatched block sizes, …).
    pub fn new(cfg: MemConfig) -> Result<Self, MemConfigError> {
        cfg.validate()?;
        Ok(Self {
            cfg,
            il1: L1Cache::new(cfg.il1, false),
            dl1: L1Cache::with_policy(cfg.dl1, true, cfg.replacement).with_mshrs(cfg.dl1_mshrs),
            llc: Llc::new(&cfg),
            dram: Dram::new(cfg.dram),
            port_free: 0,
            port_free_struct: 0,
            blocking: cfg.dl1_mshrs <= 1,
        })
    }

    #[inline]
    fn flat(&self) -> bool {
        self.cfg.model == MemModel::Flat
    }

    /// Copy a program image into DRAM (host-side, no timing) and drop any
    /// cached state, in-flight misses and channel occupancy.
    pub fn load_program(&mut self, prog: &Program) {
        let mut text_bytes = Vec::with_capacity(prog.text.len() * 4);
        for w in &prog.text {
            text_bytes.extend_from_slice(&w.to_le_bytes());
        }
        self.dram.host_write(prog.text_base, &text_bytes);
        if !prog.data.is_empty() {
            self.dram.host_write(prog.data_base, &prog.data);
        }
        self.il1.invalidate_all();
        self.dl1.invalidate_all();
        self.llc.invalidate_all();
        self.reset_timing();
    }

    /// Forget all timing state (port, in-flight DRAM bursts) without
    /// touching cache contents.
    pub fn reset_timing(&mut self) {
        self.port_free = 0;
        self.port_free_struct = 0;
        self.dram.reset_timing();
    }

    /// Instruction fetch through IL1. Hit: instruction available this
    /// cycle (the IL1 is "implemented in registers", §3.1). Returns
    /// `(word, ready_cycle)`.
    pub fn fetch(&mut self, pc: u32, now: u64) -> (u32, u64) {
        if self.flat() {
            let mut buf = [0u8; 4];
            self.dram.host_read(pc, &mut buf);
            return (u32::from_le_bytes(buf), now);
        }
        let mut buf = [0u8; 4];
        let ready = self.il1.read(pc, &mut buf, &mut self.llc, &mut self.dram, now);
        (u32::from_le_bytes(buf), ready)
    }

    /// Accept a data-port operation requested at `now`: apply the port
    /// hold, classify the wait, and return the issue cycle.
    fn accept(&self, now: u64) -> (u64, u64, u64) {
        let issue = now.max(self.port_free);
        let struct_stall = self.port_free_struct.clamp(now, issue) - now;
        let bw_stall = (issue - now) - struct_stall;
        (issue, struct_stall, bw_stall)
    }

    /// Release the port after an operation issued at `issue` whose data
    /// is ready at `ready`.
    fn release(&mut self, issue: u64, ready: u64) {
        self.port_free_struct = issue + 1;
        self.port_free = if self.blocking { ready.max(issue + 1) } else { issue + 1 };
    }

    /// Data read through DL1; splits block-crossing accesses.
    pub fn read(&mut self, addr: u32, buf: &mut [u8], now: u64) -> Access {
        if self.flat() {
            self.dram.host_read(addr, buf);
            return Access { issue: now, ready: now, struct_stall: 0, bw_stall: 0 };
        }
        let (issue, struct_stall, bw_stall) = self.accept(now);
        let bb = self.dl1.block_bytes();
        let mut ready = issue;
        let mut done = 0usize;
        while done < buf.len() {
            let a = addr + done as u32;
            let room = bb - (a as usize % bb);
            let chunk = room.min(buf.len() - done);
            let chunk_buf = &mut buf[done..done + chunk];
            let r = self.dl1.read(a, chunk_buf, &mut self.llc, &mut self.dram, issue);
            ready = ready.max(r);
            done += chunk;
        }
        self.release(issue, ready);
        Access { issue, ready, struct_stall, bw_stall }
    }

    /// Data write through DL1; splits block-crossing accesses.
    pub fn write(&mut self, addr: u32, data: &[u8], now: u64) -> Access {
        if self.flat() {
            self.dram.host_write(addr, data);
            return Access { issue: now, ready: now, struct_stall: 0, bw_stall: 0 };
        }
        let (issue, struct_stall, bw_stall) = self.accept(now);
        let bb = self.dl1.block_bytes();
        let mut ready = issue;
        let mut done = 0usize;
        while done < data.len() {
            let a = addr + done as u32;
            let room = bb - (a as usize % bb);
            let chunk = room.min(data.len() - done);
            let r =
                self.dl1.write(a, &data[done..done + chunk], &mut self.llc, &mut self.dram, issue);
            ready = ready.max(r);
            done += chunk;
        }
        self.release(issue, ready);
        Access { issue, ready, struct_stall, bw_stall }
    }

    /// Write all dirty state down to DRAM (host-side, end of run).
    pub fn flush_all(&mut self) {
        self.dl1.flush(&mut self.llc, &mut self.dram);
        self.llc.flush(&mut self.dram);
    }

    /// Make the instruction-fetch path coherent after a store hit the
    /// text segment (self-modifying code): push dirty data down to DRAM
    /// and drop the IL1, so the next fetch of the written line sees the
    /// new bytes. Host-side — no cycles are booked; the post-SMC refetch
    /// is simply modeled as cold (there is no hardware coherence between
    /// the write path and the IL1 on this core, matching the `fence.i`
    /// cost model being "a full refetch"). A no-op on the flat memory
    /// model, where stores and fetches already share one image.
    pub fn sync_fetch(&mut self) {
        if self.flat() {
            return;
        }
        self.dl1.flush(&mut self.llc, &mut self.dram);
        self.llc.flush(&mut self.dram);
        self.il1.invalidate_all();
    }

    /// Hierarchy-aware host read (no timing, no state change).
    pub fn peek(&self, addr: u32) -> u8 {
        self.dl1.peek(addr, &self.llc, &self.dram)
    }

    /// Host read of a range (hierarchy-aware, slow; use `flush_all` +
    /// `dram_slice` for bulk verification).
    pub fn peek_range(&self, addr: u32, len: usize) -> Vec<u8> {
        (0..len).map(|i| self.peek(addr + i as u32)).collect()
    }

    /// Host write (no timing): goes straight to DRAM, so callers must
    /// either write before execution or flush+invalidate first.
    pub fn host_write(&mut self, addr: u32, data: &[u8]) {
        self.dram.host_write(addr, data);
    }

    /// Direct DRAM view (valid after `flush_all`).
    pub fn dram_slice(&self, addr: u32, len: usize) -> &[u8] {
        self.dram.host_slice(addr, len)
    }

    pub fn dram_size(&self) -> usize {
        self.dram.size()
    }

    pub fn stats(&self) -> MemStats {
        MemStats {
            il1: self.il1.stats(),
            dl1: self.dl1.stats(),
            llc: self.llc.stats(),
            dram: self.dram.stats(),
        }
    }

    /// Credit line-buffer fetches (see `core`) as IL1 hits so reported
    /// hit rates stay architecturally accurate.
    pub fn credit_il1_hits(&mut self, n: u64) {
        self.il1.credit_hits(n);
    }

    pub fn reset_stats(&mut self) {
        self.il1.reset_stats();
        self.dl1.reset_stats();
        self.llc.reset_stats();
        self.dram.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn mk() -> MemSys {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        MemSys::new(cfg).unwrap()
    }

    fn mk_with(f: impl FnOnce(&mut MemConfig)) -> MemSys {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        f(&mut cfg);
        MemSys::new(cfg).unwrap()
    }

    #[test]
    fn program_load_and_fetch() {
        let mut m = mk();
        let mut a = crate::asm::Asm::new();
        a.addi(crate::isa::reg::A0, crate::isa::reg::ZERO, 7);
        a.halt();
        let p = a.assemble().unwrap();
        m.load_program(&p);
        let (w, _) = m.fetch(p.text_base, 0);
        assert_eq!(crate::isa::decode(w).unwrap().to_string(), "addi a0, zero, 7");
    }

    #[test]
    fn rejects_invalid_configs() {
        let mut cfg = MemConfig::paper_default();
        cfg.dl1.ways = 0;
        assert!(matches!(MemSys::new(cfg), Err(MemConfigError::ZeroWays { .. })));

        let mut cfg = MemConfig::paper_default();
        cfg.llc.block_bits = cfg.dl1.block_bits / 2; // block > LLC block
        assert!(matches!(MemSys::new(cfg), Err(MemConfigError::LlcBlockTooSmall { .. })));

        let mut cfg = MemConfig::paper_default();
        cfg.dl1_mshrs = 0;
        assert!(matches!(MemSys::new(cfg), Err(MemConfigError::ZeroMshrs { .. })));
    }

    #[test]
    fn block_crossing_access_is_split_correctly() {
        let mut m = mk();
        let data: Vec<u8> = (0..64).collect();
        // Unaligned write straddling a 32-byte block boundary.
        m.write(0x1f0, &data, 0);
        let mut got = vec![0u8; 64];
        m.read(0x1f0, &mut got, 100);
        assert_eq!(got, data);
    }

    /// The repo's central functional-correctness property: an arbitrary
    /// mix of reads and writes through the full hierarchy must equal a
    /// flat shadow memory, regardless of evictions and write-backs.
    #[test]
    fn random_traffic_matches_shadow_memory() {
        crate::util::proptest::check("memsys matches shadow", 16, |rng: &mut Xoshiro256| {
            let mut m = mk();
            let mut shadow = vec![0u8; 1 << 16];
            let mut now = 0u64;
            for _ in 0..2000 {
                let len = [1usize, 2, 4, 8, 32][rng.below(5) as usize];
                let addr = (rng.below((1 << 16) - 64) as usize / len * len) as u32;
                if rng.below(2) == 0 {
                    let data = rng.vec_u8(len);
                    now = m.write(addr, &data, now).ready.max(now) + 1;
                    shadow[addr as usize..addr as usize + len].copy_from_slice(&data);
                } else {
                    let mut buf = vec![0u8; len];
                    now = m.read(addr, &mut buf, now).ready.max(now) + 1;
                    let want = shadow[addr as usize..addr as usize + len].to_vec();
                    crate::prop_assert_eq!(buf, want);
                }
            }
            // After a flush, DRAM must equal the shadow exactly.
            m.flush_all();
            let dram = m.dram_slice(0, 1 << 16);
            crate::prop_assert!(dram == &shadow[..], "post-flush DRAM differs from shadow");
            Ok(())
        });
    }

    /// Unaligned/block-crossing traffic, cross-checked against BOTH the
    /// flat shadow and the magic-memory oracle model, under blocking and
    /// non-blocking (MSHR + prefetch + 2-channel) configurations — the
    /// read/write splitting in `MemSys` and `L1Cache` must be purely a
    /// timing concern.
    #[test]
    fn unaligned_random_traffic_matches_flat_reference() {
        for nonblocking in [false, true] {
            crate::util::proptest::check("unaligned memsys vs flat", 8, |rng: &mut Xoshiro256| {
                let mut m = mk_with(|cfg| {
                    if nonblocking {
                        cfg.dl1_mshrs = 4;
                        cfg.llc_mshrs = 8;
                        cfg.prefetch_depth = 2;
                        cfg.dram.channels = 2;
                    }
                });
                let mut flat = mk_with(|cfg| cfg.model = MemModel::Flat);
                let mut shadow = vec![0u8; 1 << 16];
                let mut now = 0u64;
                for _ in 0..1500 {
                    let len = 1 + rng.below(64) as usize;
                    let addr = rng.below((1 << 16) - 64);
                    if rng.below(2) == 0 {
                        let data = rng.vec_u8(len);
                        now = m.write(addr, &data, now).ready.max(now) + 1;
                        flat.write(addr, &data, now);
                        shadow[addr as usize..addr as usize + len].copy_from_slice(&data);
                    } else {
                        let mut buf = vec![0u8; len];
                        let mut fbuf = vec![0u8; len];
                        now = m.read(addr, &mut buf, now).ready.max(now) + 1;
                        flat.read(addr, &mut fbuf, now);
                        let want = &shadow[addr as usize..addr as usize + len];
                        crate::prop_assert_eq!(&buf[..], want);
                        crate::prop_assert_eq!(&fbuf[..], want);
                    }
                }
                m.flush_all();
                flat.flush_all();
                crate::prop_assert!(
                    m.dram_slice(0, 1 << 16) == flat.dram_slice(0, 1 << 16),
                    "cached and flat DRAM images diverged"
                );
                Ok(())
            });
        }
    }

    #[test]
    fn memcpy_traffic_is_two_bursts_per_block() {
        // Vector memcpy of 8 KiB with 32-byte (VLEN) transfers: per
        // 2048-byte LLC block, one read burst (src) and one write-back
        // burst (dst) — the §3.1.1 no-fetch path must avoid dst fetches.
        let mut m = mk();
        let n = 8192u32;
        let (src, dst) = (0x0_0000u32, 0x8_0000u32);
        let mut now = 0u64;
        for off in (0..n).step_by(32) {
            let mut v = [0u8; 32];
            now = m.read(src + off, &mut v, now).ready;
            now = m.write(dst + off, &v, now).ready;
        }
        m.flush_all();
        let s = m.stats();
        let blocks = (n / 2048) as u64;
        assert_eq!(s.dram.read_bursts, blocks, "one src fetch per LLC block");
        assert_eq!(s.dram.write_bursts, blocks, "one dst write-back per LLC block");
        assert_eq!(s.dl1.alloc_no_fetch, (n / 32) as u64, "every vector store skips fetch");
    }

    #[test]
    fn blocking_port_holds_until_data_returns() {
        // Default (1 MSHR): a hit right after a miss stalls on the port
        // until the miss's data came back — the legacy model.
        let mut m = mk();
        let miss = m.read(0x4000, &mut [0u8; 4], 0);
        assert!(miss.ready > 20, "cold miss pays the burst setup");
        // Warm the second line, then miss + hit back to back.
        m.read(0x4000, &mut [0u8; 4], 1000); // hit, port free quickly
        let miss = m.read(0x10000, &mut [0u8; 4], 2000);
        let hit = m.read(0x4000, &mut [0u8; 4], 2001);
        assert!(hit.issue >= miss.ready, "blocking port holds the hit");
        assert!(hit.bw_stall > 0, "the wait is bandwidth exposure, not structural");
    }

    #[test]
    fn nonblocking_port_allows_hit_under_miss() {
        let mut m = mk_with(|cfg| {
            cfg.dl1_mshrs = 4;
            cfg.llc_mshrs = 4;
        });
        m.read(0x4000, &mut [0u8; 4], 0); // warm a line
        let miss = m.read(0x10000, &mut [0u8; 4], 2000);
        assert!(miss.ready > 2020, "cold miss still pays DRAM latency");
        let hit = m.read(0x4000, &mut [0u8; 4], 2001);
        assert_eq!(hit.issue, 2001, "hit proceeds under the outstanding miss");
        assert_eq!(hit.ready, 2001, "DL1 hit has no memory stall");
        assert_eq!(hit.bw_stall, 0);
    }

    #[test]
    fn nonblocking_misses_overlap_across_channels() {
        // Two independent misses with two DRAM channels available: the
        // blocking port still serialises them (the second may not even
        // issue before the first's data returned), while 2+ MSHRs let
        // the second burst start immediately on the free channel.
        let mut blocking = mk_with(|cfg| cfg.dram.channels = 2);
        blocking.read(0x00000, &mut [0u8; 4], 0);
        let b = blocking.read(0x10000, &mut [0u8; 4], 1);
        let mut nb = mk_with(|cfg| {
            cfg.dl1_mshrs = 4;
            cfg.llc_mshrs = 4;
            cfg.dram.channels = 2;
        });
        nb.read(0x00000, &mut [0u8; 4], 0);
        let b2 = nb.read(0x10000, &mut [0u8; 4], 1);
        assert_eq!(b2.issue, 1, "miss-under-miss issues immediately");
        assert!(b.issue > 20, "blocking port waits for the first miss");
        assert!(b2.ready < b.ready, "overlapped miss must finish earlier ({b2:?} vs {b:?})");
    }

    #[test]
    fn flat_model_is_single_cycle_and_correct() {
        let mut m = mk_with(|cfg| cfg.model = MemModel::Flat);
        let data: Vec<u8> = (0..64).collect();
        let w = m.write(0x1f3, &data, 5);
        assert_eq!((w.issue, w.ready), (5, 5));
        let mut got = vec![0u8; 64];
        let r = m.read(0x1f3, &mut got, 9);
        assert_eq!((r.issue, r.ready), (9, 9));
        assert_eq!(got, data);
        // Fetch is immediate too, and flush is a no-op (data already flat).
        m.flush_all();
        assert_eq!(m.dram_slice(0x1f3, 64), &data[..]);
    }

    #[test]
    fn stats_reset() {
        let mut m = mk();
        let mut buf = [0u8; 4];
        m.read(0, &mut buf, 0);
        assert!(m.stats().dl1.accesses() > 0);
        m.reset_stats();
        assert_eq!(m.stats().dl1.accesses(), 0);
        assert_eq!(m.stats().dram.bursts(), 0);
    }
}
