//! The assembled memory system: IL1 + DL1 over a unified LLC over DRAM
//! (Fig. 2 of the paper). This is the object the simulated core talks to.

use super::config::MemConfig;
use super::dram::Dram;
use super::l1::L1Cache;
use super::llc::Llc;
use super::stats::MemStats;
use crate::asm::Program;

pub struct MemSys {
    pub cfg: MemConfig,
    il1: L1Cache,
    dl1: L1Cache,
    llc: Llc,
    dram: Dram,
}

impl MemSys {
    pub fn new(cfg: MemConfig) -> Self {
        cfg.validate().expect("invalid memory configuration");
        Self {
            cfg,
            il1: L1Cache::new(cfg.il1, false),
            dl1: L1Cache::with_policy(cfg.dl1, true, cfg.replacement),
            llc: Llc::new(&cfg),
            dram: Dram::new(cfg.dram),
        }
    }

    /// Copy a program image into DRAM (host-side, no timing) and drop any
    /// cached state.
    pub fn load_program(&mut self, prog: &Program) {
        let mut text_bytes = Vec::with_capacity(prog.text.len() * 4);
        for w in &prog.text {
            text_bytes.extend_from_slice(&w.to_le_bytes());
        }
        self.dram.host_write(prog.text_base, &text_bytes);
        if !prog.data.is_empty() {
            self.dram.host_write(prog.data_base, &prog.data);
        }
        self.il1.invalidate_all();
        self.dl1.invalidate_all();
        self.llc.invalidate_all();
    }

    /// Instruction fetch through IL1. Hit: instruction available this
    /// cycle (the IL1 is "implemented in registers", §3.1). Returns
    /// `(word, ready_cycle)`.
    pub fn fetch(&mut self, pc: u32, now: u64) -> (u32, u64) {
        let mut buf = [0u8; 4];
        let ready = self.il1.read(pc, &mut buf, &mut self.llc, &mut self.dram, now);
        (u32::from_le_bytes(buf), ready)
    }

    /// Data read through DL1; splits block-crossing accesses.
    pub fn read(&mut self, addr: u32, buf: &mut [u8], now: u64) -> u64 {
        let bb = self.dl1.block_bytes();
        let mut ready = now;
        let mut done = 0usize;
        while done < buf.len() {
            let a = addr + done as u32;
            let room = bb - (a as usize % bb);
            let chunk = room.min(buf.len() - done);
            let r = self.dl1.read(a, &mut buf[done..done + chunk], &mut self.llc, &mut self.dram, now);
            ready = ready.max(r);
            done += chunk;
        }
        ready
    }

    /// Data write through DL1; splits block-crossing accesses.
    pub fn write(&mut self, addr: u32, data: &[u8], now: u64) -> u64 {
        let bb = self.dl1.block_bytes();
        let mut ready = now;
        let mut done = 0usize;
        while done < data.len() {
            let a = addr + done as u32;
            let room = bb - (a as usize % bb);
            let chunk = room.min(data.len() - done);
            let r = self.dl1.write(a, &data[done..done + chunk], &mut self.llc, &mut self.dram, now);
            ready = ready.max(r);
            done += chunk;
        }
        ready
    }

    /// Write all dirty state down to DRAM (host-side, end of run).
    pub fn flush_all(&mut self) {
        self.dl1.flush(&mut self.llc, &mut self.dram);
        self.llc.flush(&mut self.dram);
    }

    /// Hierarchy-aware host read (no timing, no state change).
    pub fn peek(&self, addr: u32) -> u8 {
        self.dl1.peek(addr, &self.llc, &self.dram)
    }

    /// Host read of a range (hierarchy-aware, slow; use `flush_all` +
    /// `dram_slice` for bulk verification).
    pub fn peek_range(&self, addr: u32, len: usize) -> Vec<u8> {
        (0..len).map(|i| self.peek(addr + i as u32)).collect()
    }

    /// Host write (no timing): goes straight to DRAM, so callers must
    /// either write before execution or flush+invalidate first.
    pub fn host_write(&mut self, addr: u32, data: &[u8]) {
        self.dram.host_write(addr, data);
    }

    /// Direct DRAM view (valid after `flush_all`).
    pub fn dram_slice(&self, addr: u32, len: usize) -> &[u8] {
        self.dram.host_slice(addr, len)
    }

    pub fn dram_size(&self) -> usize {
        self.dram.size()
    }

    pub fn stats(&self) -> MemStats {
        MemStats {
            il1: self.il1.stats(),
            dl1: self.dl1.stats(),
            llc: self.llc.stats(),
            dram: self.dram.stats(),
        }
    }

    /// Credit line-buffer fetches (see `core`) as IL1 hits so reported
    /// hit rates stay architecturally accurate.
    pub fn credit_il1_hits(&mut self, n: u64) {
        self.il1.credit_hits(n);
    }

    pub fn reset_stats(&mut self) {
        self.il1.reset_stats();
        self.dl1.reset_stats();
        self.llc.reset_stats();
        self.dram.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn mk() -> MemSys {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        MemSys::new(cfg)
    }

    #[test]
    fn program_load_and_fetch() {
        let mut m = mk();
        let mut a = crate::asm::Asm::new();
        a.addi(crate::isa::reg::A0, crate::isa::reg::ZERO, 7);
        a.halt();
        let p = a.assemble().unwrap();
        m.load_program(&p);
        let (w, _) = m.fetch(p.text_base, 0);
        assert_eq!(crate::isa::decode(w).unwrap().to_string(), "addi a0, zero, 7");
    }

    #[test]
    fn block_crossing_access_is_split_correctly() {
        let mut m = mk();
        let data: Vec<u8> = (0..64).collect();
        // Unaligned write straddling a 32-byte block boundary.
        m.write(0x1f0, &data, 0);
        let mut got = vec![0u8; 64];
        m.read(0x1f0, &mut got, 100);
        assert_eq!(got, data);
    }

    /// The repo's central functional-correctness property: an arbitrary
    /// mix of reads and writes through the full hierarchy must equal a
    /// flat shadow memory, regardless of evictions and write-backs.
    #[test]
    fn random_traffic_matches_shadow_memory() {
        crate::util::proptest::check("memsys matches shadow", 16, |rng: &mut Xoshiro256| {
            let mut m = mk();
            let mut shadow = vec![0u8; 1 << 16];
            let mut now = 0u64;
            for _ in 0..2000 {
                let len = [1usize, 2, 4, 8, 32][rng.below(5) as usize];
                let addr = (rng.below((1 << 16) - 64) as usize / len * len) as u32;
                if rng.below(2) == 0 {
                    let data = rng.vec_u8(len);
                    now = m.write(addr, &data, now).max(now) + 1;
                    shadow[addr as usize..addr as usize + len].copy_from_slice(&data);
                } else {
                    let mut buf = vec![0u8; len];
                    now = m.read(addr, &mut buf, now).max(now) + 1;
                    crate::prop_assert_eq!(buf, shadow[addr as usize..addr as usize + len].to_vec());
                }
            }
            // After a flush, DRAM must equal the shadow exactly.
            m.flush_all();
            let dram = m.dram_slice(0, 1 << 16);
            crate::prop_assert!(dram == &shadow[..], "post-flush DRAM differs from shadow");
            Ok(())
        });
    }

    #[test]
    fn memcpy_traffic_is_two_bursts_per_block() {
        // Vector memcpy of 8 KiB with 32-byte (VLEN) transfers: per
        // 2048-byte LLC block, one read burst (src) and one write-back
        // burst (dst) — the §3.1.1 no-fetch path must avoid dst fetches.
        let mut m = mk();
        let n = 8192u32;
        let (src, dst) = (0x0_0000u32, 0x8_0000u32);
        let mut now = 0u64;
        for off in (0..n).step_by(32) {
            let mut v = [0u8; 32];
            now = m.read(src + off, &mut v, now);
            now = m.write(dst + off, &v, now);
        }
        m.flush_all();
        let s = m.stats();
        let blocks = (n / 2048) as u64;
        assert_eq!(s.dram.read_bursts, blocks, "one src fetch per LLC block");
        assert_eq!(s.dram.write_bursts, blocks, "one dst write-back per LLC block");
        assert_eq!(s.dl1.alloc_no_fetch, (n / 32) as u64, "every vector store skips fetch");
    }

    #[test]
    fn stats_reset() {
        let mut m = mk();
        let mut buf = [0u8; 4];
        m.read(0, &mut buf, 0);
        assert!(m.stats().dl1.accesses() > 0);
        m.reset_stats();
        assert_eq!(m.stats().dl1.accesses(), 0);
        assert_eq!(m.stats().dram.bursts(), 0);
    }
}
