//! Memory-system configuration (§3.1 + Table 1 of the paper).
//!
//! The defaults reproduce Table 1: IL1 = 64-set direct-mapped × 256-bit
//! blocks (2 KiB), DL1 = 32 sets × 4 ways × 256-bit blocks (4 KiB),
//! LLC = 32 sets × 4 ways × 16384-bit blocks (256 KiB, 64 sub-blocks of
//! 256 bits), AXI-style interconnect 128 bits wide at double rate
//! (§3.1.4), softcore clocked at 150 MHz.

/// Block replacement policy for the set-associative caches (§3.1: the
/// paper chooses NRU and notes a random policy "would stagnate the
/// bandwidth for memory copying when the source and destination are
/// aligned" — the `ablations` bench demonstrates exactly that).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Replacement {
    #[default]
    Nru,
    /// Deterministic pseudo-random victim selection (xorshift).
    Random,
}

/// Which model backs a [`super::MemSys`]: the full cache hierarchy of
/// the paper, or a flat single-cycle "magic memory" with identical
/// architectural behaviour and trivial timing — the reference model the
/// differential test suite (`rust/tests/mem_differential.rs`) compares
/// the hierarchy against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemModel {
    #[default]
    Cached,
    Flat,
}

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub sets: usize,
    pub ways: usize,
    /// Block size in bits (the paper speaks in bits; we keep that unit).
    pub block_bits: usize,
}

impl CacheGeometry {
    pub const fn block_bytes(&self) -> usize {
        self.block_bits / 8
    }

    pub const fn capacity_bytes(&self) -> usize {
        self.sets * self.ways * self.block_bytes()
    }
}

/// DRAM + interconnect timing (§3.1.2–3.1.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramConfig {
    /// Backing storage size in bytes (the Ultra96 reserved 1 GiB for the
    /// FPGA; scaled runs use less).
    pub size_bytes: usize,
    /// AXI data width in bits (the port is "rather narrow", e.g. 128).
    pub axi_width_bits: usize,
    /// §3.1.4: run the interconnect at double rate, i.e. two beats per
    /// core cycle, emulating double data width.
    pub double_rate: bool,
    /// Fixed cycles to open a burst (arbitration + DRAM access time,
    /// in core clocks).
    pub burst_setup_cycles: u64,
    /// Independent DRAM channels. A burst occupies exactly one channel;
    /// the controller places each burst on the earliest-free channel, so
    /// concurrent fills/write-backs contend for aggregate bandwidth
    /// instead of serialising on a single `busy_until` (1 = the paper's
    /// single AXI port).
    pub channels: usize,
}

impl DramConfig {
    /// Bytes transferred per core cycle once a burst is streaming.
    pub fn bytes_per_cycle(&self) -> usize {
        self.axi_width_bits / 8 * if self.double_rate { 2 } else { 1 }
    }
}

/// Full memory-system configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemConfig {
    pub il1: CacheGeometry,
    pub dl1: CacheGeometry,
    pub llc: CacheGeometry,
    pub dram: DramConfig,
    /// Extra cycles for a DL1-miss round trip to LLC on a hit there
    /// (tag lookup + sub-block read; the paper keeps this at one cycle
    /// thanks to the sub-block organisation, §3.1.3).
    pub llc_hit_cycles: u64,
    /// Replacement policy for DL1 and LLC (IL1 is direct-mapped).
    pub replacement: Replacement,
    /// DL1 MSHR count. `1` models the original fully-blocking data port
    /// (the port register *is* the single MSHR: the next access may not
    /// start before the previous one's data returned). `>= 2` makes the
    /// port non-blocking: hits proceed under outstanding misses and up
    /// to this many DL1 misses overlap (hit-under-miss and
    /// miss-under-miss).
    pub dl1_mshrs: usize,
    /// LLC MSHR count: outstanding DRAM fills (demand + prefetch). As at
    /// DL1, `1` keeps the legacy blocking fill path.
    pub llc_mshrs: usize,
    /// Next-N-line stream prefetcher depth on the LLC fill path: a
    /// demand miss on block B also fetches B+1..B+N when a fill MSHR is
    /// free (0 = prefetching off, the paper's configuration).
    pub prefetch_depth: usize,
    /// Cache hierarchy vs flat magic-memory oracle.
    pub model: MemModel,
}

#[derive(Debug, PartialEq, Eq)]
pub enum MemConfigError {
    NotPowerOfTwo { what: &'static str, got: usize },
    L1BlockMismatch { il1: usize, dl1: usize },
    LlcBlockTooSmall { llc: usize, l1: usize },
    BlockNotWordMultiple(usize),
    DramNotBlockMultiple(usize),
    /// DRAM larger than the RV32 core can address: the stack pointer is
    /// initialised to the top of memory, so anything past
    /// `4 GiB - 16` (the 16-byte stack alignment) would silently wrap
    /// `sp` through the 32-bit cast.
    DramTooLarge { got: usize },
    ZeroWays { what: &'static str },
    ZeroMshrs { what: &'static str },
    ZeroChannels,
    /// §3.1.1 contract between core and memory: the DL1/IL1 block size
    /// must equal the core's vector register width (checked by
    /// `Core::try_new`, which knows both configs).
    BlockVlenMismatch { block_bits: usize, vlen_bits: usize },
}

impl std::fmt::Display for MemConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemConfigError::NotPowerOfTwo { what, got } => {
                write!(f, "{what} must be a power of two (got {got})")
            }
            MemConfigError::L1BlockMismatch { il1, dl1 } => write!(
                f,
                "IL1 and DL1 block sizes must match the LLC sub-block size; got IL1={il1}, DL1={dl1} bits"
            ),
            MemConfigError::LlcBlockTooSmall { llc, l1 } => {
                write!(f, "LLC block ({llc} bits) must be a multiple of the L1 block ({l1} bits)")
            }
            MemConfigError::BlockNotWordMultiple(bits) => {
                write!(f, "block size {bits} bits is not a multiple of 32")
            }
            MemConfigError::DramNotBlockMultiple(bytes) => {
                write!(f, "DRAM size {bytes} bytes is not a multiple of the LLC block size")
            }
            MemConfigError::DramTooLarge { got } => {
                write!(
                    f,
                    "DRAM size {got} bytes exceeds the RV32 addressable limit ({} bytes)",
                    MemConfig::MAX_DRAM_BYTES
                )
            }
            MemConfigError::ZeroWays { what } => {
                write!(f, "{what} must have at least one way")
            }
            MemConfigError::ZeroMshrs { what } => {
                write!(f, "{what} needs at least one MSHR (1 = blocking port)")
            }
            MemConfigError::ZeroChannels => {
                write!(f, "DRAM needs at least one channel")
            }
            MemConfigError::BlockVlenMismatch { block_bits, vlen_bits } => write!(
                f,
                "§3.1.1: DL1 block size ({block_bits} bits) must equal VLEN ({vlen_bits} bits)"
            ),
        }
    }
}

impl std::error::Error for MemConfigError {}

impl MemConfig {
    /// Largest representable DRAM: the 32-bit address space minus the
    /// 16-byte stack alignment, so `sp = top of memory` stays a valid
    /// `u32` (see [`crate::arch::sp_init`]).
    pub const MAX_DRAM_BYTES: u64 = (1u64 << 32) - 16;

    /// Table 1 configuration (VLEN = 256 bits).
    pub fn paper_default() -> Self {
        Self::for_vlen(256)
    }

    /// Table-1-shaped configuration for a given vector width: the paper
    /// sets the L1 block size equal to VLEN (§3.1.1) and keeps capacities
    /// constant, so the set counts scale inversely with block size.
    pub fn for_vlen(vlen_bits: usize) -> Self {
        let il1_capacity = 2 * 1024; // 2 KiB
        let dl1_capacity = 4 * 1024; // 4 KiB, 4-way
        let llc_capacity = 256 * 1024; // 256 KiB, 4-way
        let llc_block_bits = 16384;
        let block_bytes = vlen_bits / 8;
        MemConfig {
            il1: CacheGeometry {
                sets: il1_capacity / block_bytes,
                ways: 1,
                block_bits: vlen_bits,
            },
            dl1: CacheGeometry {
                sets: dl1_capacity / block_bytes / 4,
                ways: 4,
                block_bits: vlen_bits,
            },
            llc: CacheGeometry {
                sets: llc_capacity / (llc_block_bits / 8) / 4,
                ways: 4,
                block_bits: llc_block_bits,
            },
            dram: DramConfig {
                size_bytes: 64 * 1024 * 1024,
                axi_width_bits: 128,
                double_rate: true,
                burst_setup_cycles: 20,
                channels: 1,
            },
            llc_hit_cycles: 1,
            replacement: Replacement::Nru,
            dl1_mshrs: 1,
            llc_mshrs: 1,
            prefetch_depth: 0,
            model: MemModel::Cached,
        }
    }

    /// Sub-blocks per LLC block (§3.1.3).
    pub fn llc_sub_blocks(&self) -> usize {
        self.llc.block_bits / self.dl1.block_bits
    }

    pub fn validate(&self) -> Result<(), MemConfigError> {
        // Zero-resource checks first: a zero way/MSHR/channel count is
        // the clearer diagnosis when derived values (set counts) are
        // degenerate too.
        for (what, ways) in [("IL1", self.il1.ways), ("DL1", self.dl1.ways), ("LLC", self.llc.ways)]
        {
            if ways == 0 {
                return Err(MemConfigError::ZeroWays { what });
            }
        }
        for (what, mshrs) in [("DL1", self.dl1_mshrs), ("LLC", self.llc_mshrs)] {
            if mshrs == 0 {
                return Err(MemConfigError::ZeroMshrs { what });
            }
        }
        if self.dram.channels == 0 {
            return Err(MemConfigError::ZeroChannels);
        }
        for (what, got) in [
            ("IL1 sets", self.il1.sets),
            ("DL1 sets", self.dl1.sets),
            ("LLC sets", self.llc.sets),
            ("IL1 block bits", self.il1.block_bits),
            ("DL1 block bits", self.dl1.block_bits),
            ("LLC block bits", self.llc.block_bits),
            ("AXI width", self.dram.axi_width_bits),
        ] {
            if !got.is_power_of_two() {
                return Err(MemConfigError::NotPowerOfTwo { what, got });
            }
        }
        if self.il1.block_bits != self.dl1.block_bits {
            return Err(MemConfigError::L1BlockMismatch {
                il1: self.il1.block_bits,
                dl1: self.dl1.block_bits,
            });
        }
        if self.llc.block_bits < self.dl1.block_bits {
            return Err(MemConfigError::LlcBlockTooSmall {
                llc: self.llc.block_bits,
                l1: self.dl1.block_bits,
            });
        }
        for bits in [self.il1.block_bits, self.dl1.block_bits, self.llc.block_bits] {
            if bits % 32 != 0 {
                return Err(MemConfigError::BlockNotWordMultiple(bits));
            }
        }
        if self.dram.size_bytes as u64 > Self::MAX_DRAM_BYTES {
            return Err(MemConfigError::DramTooLarge { got: self.dram.size_bytes });
        }
        if self.dram.size_bytes % self.llc.block_bytes() != 0 {
            return Err(MemConfigError::DramNotBlockMultiple(self.dram.size_bytes));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_matches_table_1() {
        let c = MemConfig::paper_default();
        c.validate().unwrap();
        assert_eq!(c.il1.capacity_bytes(), 2 * 1024);
        assert_eq!(c.il1.ways, 1, "IL1 is direct-mapped");
        assert_eq!(c.dl1.capacity_bytes(), 4 * 1024);
        assert_eq!(c.dl1.sets, 32);
        assert_eq!(c.dl1.ways, 4);
        assert_eq!(c.dl1.block_bits, 256);
        assert_eq!(c.llc.capacity_bytes(), 256 * 1024);
        assert_eq!(c.llc.sets, 32);
        assert_eq!(c.llc.ways, 4);
        assert_eq!(c.llc.block_bits, 16384);
        assert_eq!(c.llc_sub_blocks(), 64);
    }

    #[test]
    fn vlen_variants_keep_capacity() {
        for vlen in [128, 256, 512, 1024] {
            let c = MemConfig::for_vlen(vlen);
            c.validate().unwrap();
            assert_eq!(c.dl1.capacity_bytes(), 4 * 1024, "vlen {vlen}");
            assert_eq!(c.dl1.block_bits, vlen);
            assert_eq!(c.il1.block_bits, vlen);
        }
    }

    #[test]
    fn double_rate_doubles_bandwidth() {
        let mut d = MemConfig::paper_default().dram;
        assert_eq!(d.bytes_per_cycle(), 32);
        d.double_rate = false;
        assert_eq!(d.bytes_per_cycle(), 16);
    }

    #[test]
    fn validation_catches_mismatches() {
        let mut c = MemConfig::paper_default();
        c.il1.block_bits = 128;
        assert!(matches!(c.validate(), Err(MemConfigError::L1BlockMismatch { .. })));

        let mut c = MemConfig::paper_default();
        c.llc.sets = 33;
        assert!(matches!(c.validate(), Err(MemConfigError::NotPowerOfTwo { .. })));

        let mut c = MemConfig::paper_default();
        c.llc.block_bits = 128;
        assert!(matches!(c.validate(), Err(MemConfigError::LlcBlockTooSmall { .. })));
    }

    #[test]
    fn validation_rejects_unaddressable_dram() {
        // A 4 GiB DRAM would wrap sp to 0 through the u32 cast; it must
        // be a rejected configuration, not a silent truncation.
        let mut c = MemConfig::paper_default();
        c.dram.size_bytes = 1 << 32;
        assert!(matches!(c.validate(), Err(MemConfigError::DramTooLarge { .. })));
        // The largest valid size is block-aligned and accepted.
        let mut c = MemConfig::paper_default();
        c.dram.size_bytes = (1 << 32) - 2 * c.llc.block_bytes();
        assert!(c.dram.size_bytes as u64 <= MemConfig::MAX_DRAM_BYTES);
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_zero_ways_mshrs_channels() {
        let mut c = MemConfig::paper_default();
        c.llc.ways = 0;
        assert!(matches!(c.validate(), Err(MemConfigError::ZeroWays { what: "LLC" })));

        let mut c = MemConfig::paper_default();
        c.dl1_mshrs = 0;
        assert!(matches!(c.validate(), Err(MemConfigError::ZeroMshrs { what: "DL1" })));

        let mut c = MemConfig::paper_default();
        c.llc_mshrs = 0;
        assert!(matches!(c.validate(), Err(MemConfigError::ZeroMshrs { what: "LLC" })));

        let mut c = MemConfig::paper_default();
        c.dram.channels = 0;
        assert!(matches!(c.validate(), Err(MemConfigError::ZeroChannels)));
    }

    #[test]
    fn paper_default_is_blocking_and_unprefetched() {
        // The Table-1 machine reproduces the paper: single-MSHR blocking
        // port, no prefetcher, one AXI channel.
        let c = MemConfig::paper_default();
        assert_eq!((c.dl1_mshrs, c.llc_mshrs, c.prefetch_depth, c.dram.channels), (1, 1, 0, 1));
        assert_eq!(c.model, MemModel::Cached);
    }
}
