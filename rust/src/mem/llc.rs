//! Unified last-level cache (§3.1.2–3.1.3): very wide blocks (e.g.
//! 16384 bits) stored as consecutive narrower *sub-blocks* the size of an
//! L1 block, so a whole L1 block is served in a single cycle while DRAM
//! transfers whole LLC blocks as long bursts.
//!
//! Key behaviours reproduced from the paper:
//! - **NRU replacement** (one meta bit per block, §3.1) — a random policy
//!   "would stagnate the bandwidth for memcpy() when source and
//!   destination are aligned".
//! - **Per-sub-block valid bits**: a full-sub-block write allocates
//!   without fetching from DRAM (the §3.1.1 no-fetch-on-full-write
//!   optimisation applied at the LLC level — DL1 write-backs always cover
//!   a whole sub-block).
//! - **Critical-sub-block-first** (§3.1.3): on a fetch, the requested L1
//!   block is forwarded as soon as its beats land, before the burst
//!   finishes; the channel stays busy until the burst completes.
//!
//! Beyond the paper, the LLC is non-blocking: DRAM fills are tracked in
//! an [`MshrFile`] (`MemConfig::llc_mshrs`), so with two or more MSHRs
//! several fills overlap on the DRAM channels, and a next-N-line stream
//! prefetcher (`MemConfig::prefetch_depth`) rides the fill path — a
//! demand miss on block B also fetches B+1..B+N when a fill MSHR is
//! free. In-flight blocks carry a per-slot `ready_at` cycle; a hit on a
//! block whose fill has not landed yet waits for it (a "late prefetch"
//! is cheaper than a miss but not free). The default single-MSHR,
//! depth-0 configuration reproduces the paper's blocking timing exactly.

use super::config::{CacheGeometry, MemConfig, Replacement};
use super::dram::Dram;
use super::mshr::MshrFile;
use super::stats::CacheStats;

pub struct Llc {
    geom: CacheGeometry,
    replacement: Replacement,
    rand_state: u32,
    sub_bytes: usize,
    subs_per_block: usize,
    hit_cycles: u64,
    /// Precomputed shifts/masks (all geometry is power-of-two).
    block_shift: u32,
    set_mask: usize,
    sub_shift: u32,
    /// Reusable whole-block staging buffer for DRAM fills (avoids a heap
    /// allocation per LLC miss).
    fill_buf: Vec<u8>,

    /// Per (set, way): tag value (block address / sets).
    tags: Vec<u32>,
    valid: Vec<bool>,
    dirty: Vec<bool>,
    /// NRU "recently used" bit per block.
    ru: Vec<bool>,
    /// Per-block sub-block valid bitmap (≤128 sub-blocks per block in any
    /// valid configuration: 16384-bit block / 128-bit sub-block).
    sub_valid: Vec<u128>,
    /// Cycle at which the block's last fill lands (0 when not in
    /// flight): hits on an in-flight block wait for it.
    ready_at: Vec<u64>,
    /// Tagged prefetching: set on prefetched blocks, cleared on their
    /// first demand hit, which re-arms the stream (fetches the next
    /// lines) so a steady stream pays one demand miss, not one per
    /// `prefetch_depth` blocks.
    prefetched: Vec<bool>,
    data: Vec<u8>,

    /// Outstanding-fill tracking; single-entry = legacy blocking fills.
    mshrs: MshrFile,
    /// Next-N-line prefetch depth on the fill path (0 = off).
    prefetch_depth: usize,
    /// DRAM capacity — the prefetcher must not run past it.
    dram_limit: usize,

    stats: CacheStats,
}

impl Llc {
    pub fn new(cfg: &MemConfig) -> Self {
        let geom = cfg.llc;
        let sub_bytes = cfg.dl1.block_bytes();
        let subs_per_block = cfg.llc_sub_blocks();
        assert!(subs_per_block <= 128, "sub-block bitmap limited to 128");
        let blocks = geom.sets * geom.ways;
        assert!(geom.block_bytes().is_power_of_two() && geom.sets.is_power_of_two());
        Self {
            geom,
            replacement: cfg.replacement,
            rand_state: 0x2545_F491,
            sub_bytes,
            subs_per_block,
            hit_cycles: cfg.llc_hit_cycles,
            block_shift: geom.block_bytes().trailing_zeros(),
            set_mask: geom.sets - 1,
            sub_shift: sub_bytes.trailing_zeros(),
            fill_buf: vec![0u8; geom.block_bytes()],
            tags: vec![0; blocks],
            valid: vec![false; blocks],
            dirty: vec![false; blocks],
            ru: vec![false; blocks],
            sub_valid: vec![0; blocks],
            ready_at: vec![0; blocks],
            prefetched: vec![false; blocks],
            data: vec![0; blocks * geom.block_bytes()],
            mshrs: MshrFile::new(cfg.llc_mshrs.max(1)),
            prefetch_depth: cfg.prefetch_depth,
            dram_limit: cfg.dram.size_bytes,
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    #[inline]
    fn block_bytes(&self) -> usize {
        self.geom.block_bytes()
    }

    #[inline]
    fn set_of(&self, addr: u32) -> usize {
        (addr as usize >> self.block_shift) & self.set_mask
    }

    #[inline]
    fn tag_of(&self, addr: u32) -> u32 {
        ((addr as usize >> self.block_shift) / self.geom.sets) as u32
    }

    #[inline]
    fn block_base(&self, addr: u32) -> u32 {
        addr & !(self.block_bytes() as u32 - 1)
    }

    #[inline]
    fn sub_index(&self, addr: u32) -> usize {
        (addr as usize & (self.block_bytes() - 1)) >> self.sub_shift
    }

    #[inline]
    fn slot(&self, set: usize, way: usize) -> usize {
        set * self.geom.ways + way
    }

    fn lookup(&self, addr: u32) -> Option<usize> {
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        (0..self.geom.ways)
            .map(|w| self.slot(set, w))
            .find(|&s| self.valid[s] && self.tags[s] == tag)
    }

    /// NRU touch: mark used; if every way in the set is now marked, clear
    /// all other marks (the one-bit approximation of LRU, §3.1).
    fn touch(&mut self, set: usize, way_slot: usize) {
        if self.ru[way_slot] {
            return; // already marked: no state change
        }
        self.ru[way_slot] = true;
        let all_used = (0..self.geom.ways).all(|w| {
            let s = self.slot(set, w);
            !self.valid[s] || self.ru[s]
        });
        if all_used {
            for w in 0..self.geom.ways {
                let s = self.slot(set, w);
                if s != way_slot {
                    self.ru[s] = false;
                }
            }
        }
    }

    /// Pick the victim way for `set`: first invalid, else first not
    /// recently used, else way 0.
    fn victim(&mut self, set: usize) -> usize {
        for w in 0..self.geom.ways {
            if !self.valid[self.slot(set, w)] {
                return w;
            }
        }
        match self.replacement {
            Replacement::Nru => {
                for w in 0..self.geom.ways {
                    if !self.ru[self.slot(set, w)] {
                        return w;
                    }
                }
                0
            }
            Replacement::Random => {
                let mut x = self.rand_state;
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                self.rand_state = x;
                (x as usize) & (self.geom.ways - 1)
            }
        }
    }

    /// Write back the victim's valid sub-blocks to DRAM (runs of valid
    /// sub-blocks become bursts; a fully-valid block is one whole-block
    /// burst, the common case).
    fn writeback(&mut self, slot: usize, set: usize, dram: &mut Dram, now: u64) {
        if !self.valid[slot] || !self.dirty[slot] {
            return;
        }
        self.stats.writebacks += 1;
        let block_addr = ((self.tags[slot] as usize * self.geom.sets + set)
            * self.block_bytes()) as u32;
        let base = slot * self.block_bytes();
        let mask = self.sub_valid[slot];
        let mut i = 0;
        while i < self.subs_per_block {
            if mask >> i & 1 == 1 {
                let run_start = i;
                while i < self.subs_per_block && mask >> i & 1 == 1 {
                    i += 1;
                }
                let lo = run_start * self.sub_bytes;
                let hi = i * self.sub_bytes;
                dram.write_burst(
                    block_addr + lo as u32,
                    &self.data[base + lo..base + hi],
                    now,
                );
            } else {
                i += 1;
            }
        }
    }

    /// Allocate a block for `addr` (evicting if needed) WITHOUT fetching
    /// its contents; returns the slot. Sub-valid bits start empty.
    fn allocate(&mut self, addr: u32, dram: &mut Dram, now: u64) -> usize {
        let set = self.set_of(addr);
        let way = self.victim(set);
        let slot = self.slot(set, way);
        self.writeback(slot, set, dram, now);
        self.tags[slot] = self.tag_of(addr);
        self.valid[slot] = true;
        self.dirty[slot] = false;
        self.sub_valid[slot] = 0;
        self.ready_at[slot] = 0;
        self.prefetched[slot] = false;
        self.ru[slot] = false;
        slot
    }

    #[inline]
    fn full_sub_mask(&self) -> u128 {
        if self.subs_per_block == 128 {
            u128::MAX
        } else {
            (1u128 << self.subs_per_block) - 1
        }
    }

    /// Burst-fetch all *invalid* sub-blocks of `slot` from DRAM (one
    /// whole-block burst; valid — possibly dirty — sub-blocks are
    /// preserved). Returns the cycle the critical sub-block is ready.
    fn fill(&mut self, slot: usize, addr: u32, dram: &mut Dram, now: u64) -> u64 {
        // A demand fill needs a fill MSHR; with a multi-entry file the
        // burst may start before earlier fills have landed.
        let (mshr, issue) = self.mshrs.acquire(now);
        self.stats.mshr_wait_cycles += issue - now;
        let block_addr = self.block_base(addr);
        let critical = addr as usize & (self.block_bytes() - 1);
        let bb = self.geom.block_bytes();
        let base = slot * bb;
        let mask = self.sub_valid[slot];
        let timing = if mask == 0 {
            // Common case (fresh allocation): burst straight into the
            // cache array — no staging copy.
            dram.read_burst(block_addr, &mut self.data[base..base + bb], critical, issue)
        } else {
            // Partially-valid block: stage, then fill only invalid subs.
            let timing = dram.read_burst(block_addr, &mut self.fill_buf, critical, issue);
            for i in 0..self.subs_per_block {
                if mask >> i & 1 == 0 {
                    let lo = i * self.sub_bytes;
                    self.data[base + lo..base + lo + self.sub_bytes]
                        .copy_from_slice(&self.fill_buf[lo..lo + self.sub_bytes]);
                }
            }
            timing
        };
        self.mshrs.complete(mshr, timing.done);
        self.sub_valid[slot] = self.full_sub_mask();
        self.ready_at[slot] = timing.critical_ready;
        timing.critical_ready
    }

    /// Next-N-line stream prefetch after a demand miss on the block
    /// containing `addr`: fetch following blocks that are absent, inside
    /// DRAM, and for which a fill MSHR is free *right now* — the
    /// prefetcher never delays demand traffic (and is therefore inert
    /// with a single, blocking MSHR). Prefetched blocks become usable at
    /// their burst's end (`ready_at`), not critical-sub-first.
    fn prefetch_next(&mut self, addr: u32, dram: &mut Dram, now: u64) {
        if self.prefetch_depth == 0 {
            return;
        }
        let bb = self.block_bytes() as u64;
        let base = self.block_base(addr) as u64;
        for i in 1..=self.prefetch_depth as u64 {
            let pa = base + i * bb;
            if pa + bb > self.dram_limit as u64 {
                break;
            }
            let pa = pa as u32;
            if self.lookup(pa).is_some() {
                continue;
            }
            let Some(mshr) = self.mshrs.try_acquire(now) else { break };
            let slot = self.allocate(pa, dram, now);
            let set = self.set_of(pa);
            self.touch(set, slot);
            let bbu = self.geom.block_bytes();
            let dbase = slot * bbu;
            let timing = dram.read_burst(pa, &mut self.data[dbase..dbase + bbu], 0, now);
            self.mshrs.complete(mshr, timing.done);
            self.sub_valid[slot] = self.full_sub_mask();
            self.ready_at[slot] = timing.done;
            self.prefetched[slot] = true;
            self.stats.prefetches += 1;
        }
    }

    /// Read one L1 block (sub-block granularity). `buf.len()` must equal
    /// the sub-block size and `addr` must be sub-block aligned.
    /// Returns the cycle the data is available to the requesting L1.
    pub fn read_sub(&mut self, addr: u32, buf: &mut [u8], dram: &mut Dram, now: u64) -> u64 {
        debug_assert_eq!(buf.len(), self.sub_bytes);
        debug_assert_eq!(addr as usize % self.sub_bytes, 0);
        let sub = self.sub_index(addr);
        let mut missed = false;
        let ready = if let Some(slot) = self.lookup(addr) {
            let set = self.set_of(addr);
            self.touch(set, slot);
            if self.sub_valid[slot] >> sub & 1 == 1 {
                self.stats.hits += 1;
                if self.prefetched[slot] {
                    // First demand hit on a prefetched block: re-arm the
                    // stream so it stays `prefetch_depth` lines ahead.
                    self.prefetched[slot] = false;
                    missed = true;
                }
                // An in-flight (prefetched) block is only usable once its
                // burst lands; a landed block costs the plain hit latency.
                now.max(self.ready_at[slot]) + self.hit_cycles
            } else {
                missed = true;
                // Block allocated by writes, requested sub not yet valid:
                // fetch the remainder of the block.
                self.stats.misses += 1;
                self.fill(slot, addr, dram, now) + self.hit_cycles
            }
        } else {
            missed = true;
            self.stats.misses += 1;
            let slot = self.allocate(addr, dram, now);
            let set = self.set_of(addr);
            self.touch(set, slot);
            self.fill(slot, addr, dram, now) + self.hit_cycles
        };
        let slot = self.lookup(addr).expect("block just ensured");
        let base = slot * self.block_bytes() + sub * self.sub_bytes;
        buf.copy_from_slice(&self.data[base..base + self.sub_bytes]);
        // Stream prefetch rides the demand-miss fill path and re-arms on
        // prefetch hits (after the copy-out: a prefetch allocation must
        // never displace the data being returned).
        if missed {
            self.prefetch_next(addr, dram, now);
        }
        ready
    }

    /// Write one full sub-block (a DL1 write-back or an uncached vector
    /// store). Never fetches from DRAM: a full-sub-block write validates
    /// the sub-block by itself (§3.1.1 applied at this level).
    pub fn write_sub(&mut self, addr: u32, data: &[u8], dram: &mut Dram, now: u64) -> u64 {
        debug_assert_eq!(data.len(), self.sub_bytes);
        debug_assert_eq!(addr as usize % self.sub_bytes, 0);
        let sub = self.sub_index(addr);
        let slot = match self.lookup(addr) {
            Some(slot) => {
                self.stats.hits += 1;
                slot
            }
            None => {
                self.stats.misses += 1;
                self.stats.alloc_no_fetch += 1;
                self.allocate(addr, dram, now)
            }
        };
        let set = self.set_of(addr);
        self.touch(set, slot);
        let base = slot * self.block_bytes() + sub * self.sub_bytes;
        self.data[base..base + self.sub_bytes].copy_from_slice(data);
        self.sub_valid[slot] |= 1 << sub;
        self.dirty[slot] = true;
        now + 1
    }

    /// Write back everything dirty (host-side; no timing).
    pub fn flush(&mut self, dram: &mut Dram) {
        for set in 0..self.geom.sets {
            for way in 0..self.geom.ways {
                let slot = self.slot(set, way);
                self.writeback(slot, set, dram, 0);
                self.dirty[slot] = false;
            }
        }
    }

    /// Hierarchy-aware host read of a single byte (no timing, no state
    /// change) — checks the cache before DRAM.
    pub fn peek(&self, addr: u32, dram: &Dram) -> u8 {
        if let Some(slot) = self.lookup(addr) {
            let sub = self.sub_index(addr);
            if self.sub_valid[slot] >> sub & 1 == 1 {
                let off = slot * self.block_bytes() + (addr as usize & (self.block_bytes() - 1));
                return self.data[off];
            }
        }
        let mut b = [0u8];
        dram.host_read(addr, &mut b);
        b[0]
    }

    /// Invalidate everything (drops dirty data — program (re)load and
    /// test helper); also forgets in-flight fills.
    pub fn invalidate_all(&mut self) {
        self.valid.iter_mut().for_each(|v| *v = false);
        self.sub_valid.iter_mut().for_each(|v| *v = 0);
        self.dirty.iter_mut().for_each(|v| *v = false);
        self.ru.iter_mut().for_each(|v| *v = false);
        self.ready_at.iter_mut().for_each(|v| *v = 0);
        self.prefetched.iter_mut().for_each(|v| *v = false);
        self.mshrs.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::config::MemConfig;

    fn mk() -> (Llc, Dram) {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        (Llc::new(&cfg), Dram::new(cfg.dram))
    }

    const SUB: usize = 32; // 256-bit sub-block

    #[test]
    fn read_after_dram_write_roundtrips() {
        let (mut llc, mut dram) = mk();
        let pattern: Vec<u8> = (0..SUB as u8).collect();
        dram.host_write(0x4000, &pattern);
        let mut buf = vec![0u8; SUB];
        let ready = llc.read_sub(0x4000, &mut buf, &mut dram, 10);
        assert_eq!(buf, pattern);
        assert!(ready > 10 + 20, "miss must pay the burst setup");
        // Second read: hit in 1 cycle.
        let ready2 = llc.read_sub(0x4000, &mut buf, &mut dram, 200);
        assert_eq!(ready2, 201);
        assert_eq!(llc.stats().hits, 1);
        assert_eq!(llc.stats().misses, 1);
    }

    #[test]
    fn write_allocates_without_fetch() {
        let (mut llc, mut dram) = mk();
        let data = vec![7u8; SUB];
        let ready = llc.write_sub(0x8000, &data, &mut dram, 0);
        assert_eq!(ready, 1, "no-fetch allocation completes immediately");
        assert_eq!(llc.stats().alloc_no_fetch, 1);
        assert_eq!(dram.stats().read_bursts, 0, "no DRAM fetch for a full-sub write");
        // Reading it back hits the cache.
        let mut buf = vec![0u8; SUB];
        let r = llc.read_sub(0x8000, &mut buf, &mut dram, 10);
        assert_eq!(r, 11);
        assert_eq!(buf, data);
    }

    #[test]
    fn partial_block_read_fetches_only_invalid_subs() {
        let (mut llc, mut dram) = mk();
        // DRAM has pattern A everywhere in the block.
        let block: Vec<u8> = vec![0xAA; 2048];
        dram.host_write(0x0000, &block);
        // Write sub 0 with pattern B (allocates, no fetch).
        let newer = vec![0xBB; SUB];
        llc.write_sub(0x0000, &newer, &mut dram, 0);
        // Read sub 1 → fetches block but must NOT clobber sub 0.
        let mut buf = vec![0u8; SUB];
        llc.read_sub(SUB as u32, &mut buf, &mut dram, 10);
        assert_eq!(buf, vec![0xAA; SUB]);
        let mut buf0 = vec![0u8; SUB];
        llc.read_sub(0, &mut buf0, &mut dram, 400);
        assert_eq!(buf0, newer, "dirty sub survived the fill");
    }

    #[test]
    fn eviction_writes_back_dirty_data() {
        let (mut llc, mut dram) = mk();
        // Fill one set beyond its ways with dirty blocks. Set index is
        // (addr / 2048) % 32 → addresses 2048*32 apart share a set.
        let stride = 2048 * 32;
        let mut patterns = Vec::new();
        for i in 0..5u32 {
            let data = vec![i as u8 + 1; SUB];
            llc.write_sub(i * stride, &data, &mut dram, 0);
            patterns.push(data);
        }
        // First block was evicted (NRU) — its data must be in DRAM.
        llc.flush(&mut dram);
        for i in 0..5u32 {
            let mut got = vec![0u8; SUB];
            dram.host_read(i * stride, &mut got);
            assert_eq!(got, patterns[i as usize], "block {i}");
        }
        assert!(llc.stats().writebacks >= 1);
    }

    #[test]
    fn critical_sub_block_first_beats_full_burst() {
        let (mut llc, mut dram) = mk();
        let mut buf = vec![0u8; SUB];
        // Miss on the first sub-block of a 2048-byte block: ready after
        // setup + 1 beat + hit_cycles, well before the 64-beat burst ends.
        let ready = llc.read_sub(0x0000, &mut buf, &mut dram, 0);
        assert_eq!(ready, 20 + 1 + 1);
        // The next read of a different block queues behind the burst.
        let ready2 = llc.read_sub(0x10000, &mut buf, &mut dram, ready);
        assert!(ready2 > 20 + 64, "channel was still busy with burst 1");
    }

    #[test]
    fn nru_keeps_streaming_alternation_alive() {
        // memcpy pattern: alternating reads (src) and writes (dst) whose
        // blocks map to the same set must not evict each other — NRU keeps
        // both resident, unlike random replacement (§3.1).
        let (mut llc, mut dram) = mk();
        let stride = 2048 * 32; // same set
        let src = 0u32;
        let dst = stride;
        let mut buf = vec![0u8; SUB];
        let mut misses_after_warmup = 0;
        for i in 0..64u32 {
            let off = (i as usize % 64) as u32 * SUB as u32;
            let before = llc.stats().misses;
            llc.read_sub(src + off, &mut buf, &mut dram, 0);
            llc.write_sub(dst + off, &buf, &mut dram, 0);
            if i >= 2 {
                misses_after_warmup += llc.stats().misses - before;
            }
        }
        assert_eq!(misses_after_warmup, 0, "src and dst blocks must coexist");
    }

    fn mk_prefetch(mshrs: usize, depth: usize) -> (Llc, Dram) {
        let mut cfg = MemConfig::paper_default();
        cfg.dram.size_bytes = 1 << 20;
        cfg.llc_mshrs = mshrs;
        cfg.prefetch_depth = depth;
        (Llc::new(&cfg), Dram::new(cfg.dram))
    }

    #[test]
    fn prefetcher_hides_the_next_blocks() {
        let (mut llc, mut dram) = mk_prefetch(4, 2);
        let mut buf = vec![0u8; SUB];
        // Demand miss on block 0 prefetches blocks 1 and 2.
        llc.read_sub(0, &mut buf, &mut dram, 0);
        assert_eq!(llc.stats().prefetches, 2);
        // A read of block 1 after its burst landed is a plain hit…
        let r = llc.read_sub(2048, &mut buf, &mut dram, 10_000);
        assert_eq!(r, 10_001);
        assert_eq!(llc.stats().misses, 1, "block 1 was prefetched, not missed");
        // …and that first hit re-armed the stream (block 3 fetched).
        assert_eq!(llc.stats().prefetches, 3);
    }

    #[test]
    fn prefetched_data_is_functionally_correct() {
        let (mut llc, mut dram) = mk_prefetch(8, 3);
        for blk in 0u8..4 {
            dram.host_write(blk as u32 * 2048, &vec![0xC0 + blk; 2048]);
        }
        let mut buf = vec![0u8; SUB];
        llc.read_sub(0, &mut buf, &mut dram, 0);
        assert_eq!(dram.stats().read_bursts, 4, "demand block + 3 prefetched blocks");
        for blk in 1u8..4 {
            llc.read_sub(blk as u32 * 2048 + 64, &mut buf, &mut dram, 10_000);
            assert_eq!(buf, vec![0xC0 + blk; SUB], "block {blk}");
        }
        assert_eq!(llc.stats().misses, 1, "blocks 1..3 were prefetched, not missed");
    }

    #[test]
    fn late_prefetch_hit_waits_for_its_burst() {
        let (mut llc, mut dram) = mk_prefetch(4, 1);
        let mut buf = vec![0u8; SUB];
        let demand_ready = llc.read_sub(0, &mut buf, &mut dram, 0);
        assert_eq!(demand_ready, 22, "setup 20 + 1 beat + 1 hit cycle");
        // The prefetch of block 1 queued behind the demand burst (done at
        // 84, prefetch burst done at 168): reading it right after the
        // demand data arrives waits for the in-flight burst.
        let r = llc.read_sub(2048, &mut buf, &mut dram, demand_ready);
        assert_eq!(r, 168 + 1, "late prefetch is cheaper than a miss but not free");
    }

    #[test]
    fn single_blocking_mshr_disables_prefetch() {
        let (mut llc, mut dram) = mk_prefetch(1, 4);
        let mut buf = vec![0u8; SUB];
        llc.read_sub(0, &mut buf, &mut dram, 0);
        assert_eq!(llc.stats().prefetches, 0, "no free MSHR to ride on");
    }

    #[test]
    fn mshrs_bound_outstanding_fills() {
        // Two MSHRs: a third concurrent fill must wait for the first
        // fill's burst to land before it may even start.
        let (mut llc, mut dram) = mk_prefetch(2, 0);
        let mut buf = vec![0u8; SUB];
        llc.read_sub(0x0000, &mut buf, &mut dram, 0);
        llc.read_sub(0x10000, &mut buf, &mut dram, 1);
        let before = llc.stats().mshr_wait_cycles;
        assert_eq!(before, 0);
        llc.read_sub(0x20000, &mut buf, &mut dram, 2);
        assert!(llc.stats().mshr_wait_cycles > 0, "third fill waited for an MSHR");
    }

    #[test]
    fn peek_sees_cached_dirty_data() {
        let (mut llc, mut dram) = mk();
        let data = vec![0x5A; SUB];
        llc.write_sub(0x6000, &data, &mut dram, 0);
        assert_eq!(llc.peek(0x6000, &dram), 0x5A);
        // DRAM itself still has zeros.
        let mut b = [0u8];
        dram.host_read(0x6000, &mut b);
        assert_eq!(b[0], 0);
    }

    #[test]
    fn flush_then_invalidate_pushes_all_state_to_dram() {
        let (mut llc, mut dram) = mk();
        for i in 0..16u32 {
            let data = vec![i as u8; SUB];
            llc.write_sub(0x4000 + i * SUB as u32, &data, &mut dram, 0);
        }
        llc.flush(&mut dram);
        llc.invalidate_all();
        for i in 0..16u32 {
            let mut got = vec![0u8; SUB];
            dram.host_read(0x4000 + i * SUB as u32, &mut got);
            assert_eq!(got, vec![i as u8; SUB]);
        }
    }
}
