//! Architectural register names: the 32 base integer registers of RV32I
//! and the 8 vector registers of the paper's SIMD extension (§2.1).
//!
//! Vector register fields in the I′/S′ encodings are 3 bits wide, which
//! fixes the architectural maximum at 8 vector registers; `v0` reads as
//! the constant 0 (like `x0`), so unused operand slots of a many-operand
//! instruction are aliased to `v0`.

use std::fmt;

/// A base (scalar, 32-bit) register `x0..x31`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub u8);

impl Reg {
    pub const fn new(n: u8) -> Self {
        assert!(n < 32);
        Reg(n)
    }

    #[inline]
    pub const fn num(self) -> u8 {
        self.0
    }

    /// ABI name (the assembler accepts and the disassembler prints these).
    pub fn abi_name(self) -> &'static str {
        ABI_NAMES[self.0 as usize]
    }

    /// Parse either the `x<N>` form or an ABI name.
    pub fn parse(s: &str) -> Option<Reg> {
        if let Some(num) = s.strip_prefix('x') {
            if let Ok(n) = num.parse::<u8>() {
                if n < 32 {
                    return Some(Reg(n));
                }
            }
        }
        ABI_NAMES
            .iter()
            .position(|&n| n == s)
            .map(|i| Reg(i as u8))
            .or(match s {
                // `fp` is an alias for `s0`/`x8`.
                "fp" => Some(Reg(8)),
                _ => None,
            })
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.abi_name())
    }
}

const ABI_NAMES: [&str; 32] = [
    "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3", "a4",
    "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11", "t3", "t4",
    "t5", "t6",
];

// Convenience constants (the ones programs actually use).
pub const ZERO: Reg = Reg(0);
pub const RA: Reg = Reg(1);
pub const SP: Reg = Reg(2);
pub const GP: Reg = Reg(3);
pub const TP: Reg = Reg(4);
pub const T0: Reg = Reg(5);
pub const T1: Reg = Reg(6);
pub const T2: Reg = Reg(7);
pub const S0: Reg = Reg(8);
pub const S1: Reg = Reg(9);
pub const A0: Reg = Reg(10);
pub const A1: Reg = Reg(11);
pub const A2: Reg = Reg(12);
pub const A3: Reg = Reg(13);
pub const A4: Reg = Reg(14);
pub const A5: Reg = Reg(15);
pub const A6: Reg = Reg(16);
pub const A7: Reg = Reg(17);
pub const S2: Reg = Reg(18);
pub const S3: Reg = Reg(19);
pub const S4: Reg = Reg(20);
pub const S5: Reg = Reg(21);
pub const S6: Reg = Reg(22);
pub const S7: Reg = Reg(23);
pub const S8: Reg = Reg(24);
pub const S9: Reg = Reg(25);
pub const S10: Reg = Reg(26);
pub const S11: Reg = Reg(27);
pub const T3: Reg = Reg(28);
pub const T4: Reg = Reg(29);
pub const T5: Reg = Reg(30);
pub const T6: Reg = Reg(31);

/// A vector register `v0..v7` (§2.1: 3-bit fields, `v0` ≡ 0).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VReg(pub u8);

impl VReg {
    pub const fn new(n: u8) -> Self {
        assert!(n < 8);
        VReg(n)
    }

    #[inline]
    pub const fn num(self) -> u8 {
        self.0
    }

    /// The constant-zero vector register used to alias unused operand slots.
    pub const ZERO: VReg = VReg(0);

    pub fn parse(s: &str) -> Option<VReg> {
        let num = s.strip_prefix('v')?;
        let n = num.parse::<u8>().ok()?;
        (n < 8).then_some(VReg(n))
    }
}

impl fmt::Display for VReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "v{}", self.0)
    }
}

pub const V0: VReg = VReg(0);
pub const V1: VReg = VReg(1);
pub const V2: VReg = VReg(2);
pub const V3: VReg = VReg(3);
pub const V4: VReg = VReg(4);
pub const V5: VReg = VReg(5);
pub const V6: VReg = VReg(6);
pub const V7: VReg = VReg(7);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abi_names_roundtrip() {
        for n in 0..32u8 {
            let r = Reg(n);
            assert_eq!(Reg::parse(r.abi_name()), Some(r), "abi {}", r.abi_name());
            assert_eq!(Reg::parse(&format!("x{n}")), Some(r));
        }
    }

    #[test]
    fn fp_alias() {
        assert_eq!(Reg::parse("fp"), Some(S0));
        assert_eq!(Reg::parse("s0"), Some(S0));
        assert_eq!(Reg::parse("x8"), Some(S0));
    }

    #[test]
    fn bad_regs_rejected() {
        assert_eq!(Reg::parse("x32"), None);
        assert_eq!(Reg::parse("q1"), None);
        assert_eq!(Reg::parse(""), None);
        assert_eq!(VReg::parse("v8"), None);
        assert_eq!(VReg::parse("x1"), None);
    }

    #[test]
    fn vreg_roundtrip() {
        for n in 0..8u8 {
            assert_eq!(VReg::parse(&format!("v{n}")), Some(VReg(n)));
        }
        assert_eq!(format!("{}", V3), "v3");
    }

    #[test]
    fn display_uses_abi() {
        assert_eq!(format!("{}", A0), "a0");
        assert_eq!(format!("{}", ZERO), "zero");
        assert_eq!(format!("{}", T6), "t6");
    }
}
