//! Instruction decoding: 32-bit machine word → `Instr`.
//!
//! The inverse of [`super::encode`]; `decode(encode(i)) == i` is a repo
//! invariant enforced by a property test in `rust/tests/isa_roundtrip.rs`.

use super::encode::{bits, sext};
use super::instr::{CustomSlot, IPrime, Instr, SPrime};
use super::reg::{Reg, VReg};

#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    UnknownOpcode { word: u32, opcode: u32 },
    BadFunct { word: u32, opcode: u32 },
    UnsupportedSystem { word: u32 },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::UnknownOpcode { word, opcode } => {
                write!(f, "illegal instruction word {word:#010x}: unknown opcode {opcode:#09b}")
            }
            DecodeError::BadFunct { word, opcode } => write!(
                f,
                "illegal instruction word {word:#010x}: bad funct3/funct7 for opcode {opcode:#09b}"
            ),
            DecodeError::UnsupportedSystem { word } => {
                write!(f, "unsupported system instruction {word:#010x}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

#[inline]
fn rd(w: u32) -> Reg {
    Reg(bits(w, 11, 7) as u8)
}
#[inline]
fn rs1(w: u32) -> Reg {
    Reg(bits(w, 19, 15) as u8)
}
#[inline]
fn rs2(w: u32) -> Reg {
    Reg(bits(w, 24, 20) as u8)
}
#[inline]
fn funct3(w: u32) -> u32 {
    bits(w, 14, 12)
}
#[inline]
fn funct7(w: u32) -> u32 {
    bits(w, 31, 25)
}

#[inline]
fn imm_i(w: u32) -> i32 {
    sext(bits(w, 31, 20), 12)
}

#[inline]
fn imm_s(w: u32) -> i32 {
    sext((bits(w, 31, 25) << 5) | bits(w, 11, 7), 12)
}

#[inline]
fn imm_b(w: u32) -> i32 {
    sext(
        (bits(w, 31, 31) << 12) | (bits(w, 7, 7) << 11) | (bits(w, 30, 25) << 5)
            | (bits(w, 11, 8) << 1),
        13,
    )
}

#[inline]
fn imm_u(w: u32) -> i32 {
    (w & 0xffff_f000) as i32
}

#[inline]
fn imm_j(w: u32) -> i32 {
    sext(
        (bits(w, 31, 31) << 20) | (bits(w, 19, 12) << 12) | (bits(w, 20, 20) << 11)
            | (bits(w, 30, 21) << 1),
        21,
    )
}

/// Decode a 32-bit instruction word.
pub fn decode(w: u32) -> Result<Instr, DecodeError> {
    use Instr::*;
    let opcode = bits(w, 6, 0);

    // Custom slots first: the paper routes all custom SIMD instructions
    // through the four custom-reserved major opcodes.
    if let Some(slot) = CustomSlot::from_opcode(opcode) {
        let f3 = funct3(w) as u8;
        return Ok(if f3 < 4 {
            CustomI {
                slot,
                funct3: f3,
                ops: IPrime {
                    vrs1: VReg(bits(w, 31, 29) as u8),
                    vrd1: VReg(bits(w, 28, 26) as u8),
                    vrs2: VReg(bits(w, 25, 23) as u8),
                    vrd2: VReg(bits(w, 22, 20) as u8),
                    rs1: rs1(w),
                    rd: rd(w),
                },
            }
        } else {
            CustomS {
                slot,
                funct3: f3,
                ops: SPrime {
                    vrs1: VReg(bits(w, 31, 29) as u8),
                    vrd1: VReg(bits(w, 28, 26) as u8),
                    imm: bits(w, 25, 25) as u8,
                    rs2: rs2(w),
                    rs1: rs1(w),
                    rd: rd(w),
                },
            }
        });
    }

    Ok(match opcode {
        0b011_0111 => Lui { rd: rd(w), imm: imm_u(w) },
        0b001_0111 => Auipc { rd: rd(w), imm: imm_u(w) },
        0b110_1111 => Jal { rd: rd(w), offset: imm_j(w) },
        0b110_0111 => match funct3(w) {
            0b000 => Jalr { rd: rd(w), rs1: rs1(w), offset: imm_i(w) },
            _ => return Err(DecodeError::BadFunct { word: w, opcode }),
        },
        0b110_0011 => {
            let (rs1, rs2, offset) = (rs1(w), rs2(w), imm_b(w));
            match funct3(w) {
                0b000 => Beq { rs1, rs2, offset },
                0b001 => Bne { rs1, rs2, offset },
                0b100 => Blt { rs1, rs2, offset },
                0b101 => Bge { rs1, rs2, offset },
                0b110 => Bltu { rs1, rs2, offset },
                0b111 => Bgeu { rs1, rs2, offset },
                _ => return Err(DecodeError::BadFunct { word: w, opcode }),
            }
        }
        0b000_0011 => {
            let (rd, rs1, offset) = (rd(w), rs1(w), imm_i(w));
            match funct3(w) {
                0b000 => Lb { rd, rs1, offset },
                0b001 => Lh { rd, rs1, offset },
                0b010 => Lw { rd, rs1, offset },
                0b100 => Lbu { rd, rs1, offset },
                0b101 => Lhu { rd, rs1, offset },
                _ => return Err(DecodeError::BadFunct { word: w, opcode }),
            }
        }
        0b010_0011 => {
            let (rs1, rs2, offset) = (rs1(w), rs2(w), imm_s(w));
            match funct3(w) {
                0b000 => Sb { rs1, rs2, offset },
                0b001 => Sh { rs1, rs2, offset },
                0b010 => Sw { rs1, rs2, offset },
                _ => return Err(DecodeError::BadFunct { word: w, opcode }),
            }
        }
        0b001_0011 => {
            let (rd, rs1) = (rd(w), rs1(w));
            match funct3(w) {
                0b000 => Addi { rd, rs1, imm: imm_i(w) },
                0b010 => Slti { rd, rs1, imm: imm_i(w) },
                0b011 => Sltiu { rd, rs1, imm: imm_i(w) },
                0b100 => Xori { rd, rs1, imm: imm_i(w) },
                0b110 => Ori { rd, rs1, imm: imm_i(w) },
                0b111 => Andi { rd, rs1, imm: imm_i(w) },
                0b001 => match funct7(w) {
                    0 => Slli { rd, rs1, shamt: bits(w, 24, 20) as u8 },
                    _ => return Err(DecodeError::BadFunct { word: w, opcode }),
                },
                0b101 => match funct7(w) {
                    0 => Srli { rd, rs1, shamt: bits(w, 24, 20) as u8 },
                    0b010_0000 => Srai { rd, rs1, shamt: bits(w, 24, 20) as u8 },
                    _ => return Err(DecodeError::BadFunct { word: w, opcode }),
                },
                _ => unreachable!(),
            }
        }
        0b011_0011 => {
            let (rd, rs1, rs2) = (rd(w), rs1(w), rs2(w));
            match (funct7(w), funct3(w)) {
                (0, 0b000) => Add { rd, rs1, rs2 },
                (0b010_0000, 0b000) => Sub { rd, rs1, rs2 },
                (0, 0b001) => Sll { rd, rs1, rs2 },
                (0, 0b010) => Slt { rd, rs1, rs2 },
                (0, 0b011) => Sltu { rd, rs1, rs2 },
                (0, 0b100) => Xor { rd, rs1, rs2 },
                (0, 0b101) => Srl { rd, rs1, rs2 },
                (0b010_0000, 0b101) => Sra { rd, rs1, rs2 },
                (0, 0b110) => Or { rd, rs1, rs2 },
                (0, 0b111) => And { rd, rs1, rs2 },
                (1, 0b000) => Mul { rd, rs1, rs2 },
                (1, 0b001) => Mulh { rd, rs1, rs2 },
                (1, 0b010) => Mulhsu { rd, rs1, rs2 },
                (1, 0b011) => Mulhu { rd, rs1, rs2 },
                (1, 0b100) => Div { rd, rs1, rs2 },
                (1, 0b101) => Divu { rd, rs1, rs2 },
                (1, 0b110) => Rem { rd, rs1, rs2 },
                (1, 0b111) => Remu { rd, rs1, rs2 },
                _ => return Err(DecodeError::BadFunct { word: w, opcode }),
            }
        }
        0b000_1111 => match funct3(w) {
            // funct3=0 is FENCE (fm/pred/succ/rs1/rd are hints, legal to
            // ignore); funct3=1 would be FENCE.I (Zifencei, not
            // implemented) and 2..=7 are reserved — all must trap, not
            // silently alias to a plain fence.
            0b000 => Fence,
            _ => return Err(DecodeError::BadFunct { word: w, opcode }),
        },
        0b111_0011 => match (funct3(w), bits(w, 31, 20)) {
            // ECALL/EBREAK require rd = rs1 = 0; other bit patterns in
            // those fields are reserved system encodings.
            (0b000, 0) if rd(w) == Reg(0) && rs1(w) == Reg(0) => Ecall,
            (0b000, 1) if rd(w) == Reg(0) && rs1(w) == Reg(0) => Ebreak,
            (0b010, csr) => Csrrs { rd: rd(w), csr: csr as u16, rs1: rs1(w) },
            _ => return Err(DecodeError::UnsupportedSystem { word: w }),
        },
        _ => return Err(DecodeError::UnknownOpcode { word: w, opcode }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode;
    use crate::isa::reg::*;

    #[test]
    fn golden_decodings() {
        assert_eq!(decode(0x0015_0513).unwrap(), Instr::Addi { rd: A0, rs1: A0, imm: 1 });
        assert_eq!(decode(0x00c5_8533).unwrap(), Instr::Add { rd: A0, rs1: A1, rs2: A2 });
        assert_eq!(decode(0x0041_2503).unwrap(), Instr::Lw { rd: A0, rs1: SP, offset: 4 });
        assert_eq!(decode(0xfeb5_0ee3).unwrap(), Instr::Beq { rs1: A0, rs2: A1, offset: -4 });
        assert_eq!(
            decode(0xc000_2573).unwrap(),
            Instr::Csrrs { rd: A0, csr: 0xC00, rs1: ZERO }
        );
    }

    #[test]
    fn negative_immediates_sign_extend() {
        // addi a0, a0, -1 => 0xfff50513
        assert_eq!(decode(0xfff5_0513).unwrap(), Instr::Addi { rd: A0, rs1: A0, imm: -1 });
        // lw a0, -8(sp)
        let w = encode(&Instr::Lw { rd: A0, rs1: SP, offset: -8 }).unwrap();
        assert_eq!(decode(w).unwrap(), Instr::Lw { rd: A0, rs1: SP, offset: -8 });
    }

    #[test]
    fn illegal_words_rejected() {
        assert!(matches!(decode(0x0000_0000), Err(DecodeError::UnknownOpcode { .. })));
        assert!(matches!(decode(0xffff_ffff), Err(DecodeError::UnknownOpcode { .. }) | Err(_)));
        // R-type with funct7 junk
        assert!(matches!(decode(0x7000_0033), Err(DecodeError::BadFunct { .. })));
    }

    #[test]
    fn reserved_fence_and_system_patterns_trap() {
        // Plain fence (funct3=0) decodes, including nonzero pred/succ
        // hint bits (a real `fence rw, rw` word).
        assert_eq!(decode(0x0000_000f).unwrap(), Instr::Fence);
        assert_eq!(decode(0x0330_000f).unwrap(), Instr::Fence);
        // FENCE.I (funct3=1) and reserved funct3 values must trap, not
        // alias to fence.
        assert!(matches!(decode(0x0000_100f), Err(DecodeError::BadFunct { .. })));
        assert!(matches!(decode(0x0000_700f), Err(DecodeError::BadFunct { .. })));
        // ECALL/EBREAK with nonzero rd or rs1 are reserved system words
        // (previously they silently aliased to ecall/ebreak).
        assert_eq!(decode(0x0000_0073).unwrap(), Instr::Ecall);
        assert_eq!(decode(0x0010_0073).unwrap(), Instr::Ebreak);
        for w in [0x0000_00f3u32, 0x0000_8073, 0x0010_00f3, 0x0018_0073] {
            assert!(
                matches!(decode(w), Err(DecodeError::UnsupportedSystem { .. })),
                "{w:#010x} must trap"
            );
        }
    }

    /// Satellite invariant: `decode` is total — it never panics, for
    /// every one of 4 billion possible words (sampled densely), and
    /// every successful decode re-encodes to a word that decodes to the
    /// same instruction (canonicalisation round-trip).
    #[test]
    fn sampled_decode_never_panics_and_reencodes() {
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::seeded(0xDEC0DE);
        let mut decoded_ok = 0u32;
        for i in 0..200_000u32 {
            // Half uniform words, half words with a valid major opcode
            // (so the funct/reserved-field paths are hit densely).
            let w = if i % 2 == 0 {
                rng.next_u32()
            } else {
                let opcodes = [
                    0b011_0111u32, 0b001_0111, 0b110_1111, 0b110_0111, 0b110_0011, 0b000_0011,
                    0b010_0011, 0b001_0011, 0b011_0011, 0b000_1111, 0b111_0011, 0b000_1011,
                    0b010_1011, 0b101_1011, 0b111_1011,
                ];
                (rng.next_u32() & !0x7f) | opcodes[(i / 2) as usize % opcodes.len()]
            };
            if let Ok(instr) = decode(w) {
                decoded_ok += 1;
                // Decoded instructions are always encodable, and the
                // canonical encoding decodes back to the same thing.
                let back = encode(&instr)
                    .unwrap_or_else(|e| panic!("decode({w:#010x}) = {instr} unencodable: {e}"));
                assert_eq!(decode(back).unwrap(), instr, "word {w:#010x} → {instr}");
            }
        }
        assert!(decoded_ok > 50_000, "sampling should hit many valid words ({decoded_ok})");
    }

    #[test]
    fn custom_words_decode_to_prime_types() {
        let ops = IPrime { vrs1: V1, vrd1: V2, vrs2: V3, vrd2: V4, rs1: A0, rd: A1 };
        let i = Instr::CustomI { slot: CustomSlot::C2, funct3: 0, ops };
        assert_eq!(decode(encode(&i).unwrap()).unwrap(), i);

        let sops = SPrime { vrs1: V7, vrd1: V0, imm: 1, rs2: T0, rs1: A0, rd: A3 };
        let s = Instr::CustomS { slot: CustomSlot::C0, funct3: 4, ops: sops };
        assert_eq!(decode(encode(&s).unwrap()).unwrap(), s);
    }

    /// Exhaustive round-trip over every RV32IM variant with varied operands.
    #[test]
    fn roundtrip_all_variants() {
        let mut cases: Vec<Instr> = Vec::new();
        use Instr::*;
        for (rd, rs1v, rs2v, imm) in [
            (A0, A1, A2, 0i32),
            (T0, S0, T6, -2048),
            (ZERO, RA, SP, 2047),
            (S11, A7, GP, 1),
        ] {
            cases.extend([
                Lui { rd, imm: 0x7ffff000u32 as i32 },
                Auipc { rd, imm: (imm << 12) & !0xfff },
                Jal { rd, offset: 2048 },
                Jalr { rd, rs1: rs1v, offset: imm },
                Beq { rs1: rs1v, rs2: rs2v, offset: -4096 },
                Bne { rs1: rs1v, rs2: rs2v, offset: 4094 },
                Blt { rs1: rs1v, rs2: rs2v, offset: 2 },
                Bge { rs1: rs1v, rs2: rs2v, offset: -2 },
                Bltu { rs1: rs1v, rs2: rs2v, offset: 8 },
                Bgeu { rs1: rs1v, rs2: rs2v, offset: 16 },
                Lb { rd, rs1: rs1v, offset: imm },
                Lh { rd, rs1: rs1v, offset: imm },
                Lw { rd, rs1: rs1v, offset: imm },
                Lbu { rd, rs1: rs1v, offset: imm },
                Lhu { rd, rs1: rs1v, offset: imm },
                Sb { rs1: rs1v, rs2: rs2v, offset: imm },
                Sh { rs1: rs1v, rs2: rs2v, offset: imm },
                Sw { rs1: rs1v, rs2: rs2v, offset: imm },
                Addi { rd, rs1: rs1v, imm },
                Slti { rd, rs1: rs1v, imm },
                Sltiu { rd, rs1: rs1v, imm },
                Xori { rd, rs1: rs1v, imm },
                Ori { rd, rs1: rs1v, imm },
                Andi { rd, rs1: rs1v, imm },
                Slli { rd, rs1: rs1v, shamt: 31 },
                Srli { rd, rs1: rs1v, shamt: 0 },
                Srai { rd, rs1: rs1v, shamt: 17 },
                Add { rd, rs1: rs1v, rs2: rs2v },
                Sub { rd, rs1: rs1v, rs2: rs2v },
                Sll { rd, rs1: rs1v, rs2: rs2v },
                Slt { rd, rs1: rs1v, rs2: rs2v },
                Sltu { rd, rs1: rs1v, rs2: rs2v },
                Xor { rd, rs1: rs1v, rs2: rs2v },
                Srl { rd, rs1: rs1v, rs2: rs2v },
                Sra { rd, rs1: rs1v, rs2: rs2v },
                Or { rd, rs1: rs1v, rs2: rs2v },
                And { rd, rs1: rs1v, rs2: rs2v },
                Mul { rd, rs1: rs1v, rs2: rs2v },
                Mulh { rd, rs1: rs1v, rs2: rs2v },
                Mulhsu { rd, rs1: rs1v, rs2: rs2v },
                Mulhu { rd, rs1: rs1v, rs2: rs2v },
                Div { rd, rs1: rs1v, rs2: rs2v },
                Divu { rd, rs1: rs1v, rs2: rs2v },
                Rem { rd, rs1: rs1v, rs2: rs2v },
                Remu { rd, rs1: rs1v, rs2: rs2v },
                Csrrs { rd, csr: 0xC82, rs1: ZERO },
            ]);
        }
        cases.extend([Fence, Ecall, Ebreak]);
        for instr in cases {
            let w = encode(&instr).unwrap_or_else(|e| panic!("encode {instr}: {e}"));
            let back = decode(w).unwrap_or_else(|e| panic!("decode {instr} ({w:#010x}): {e}"));
            assert_eq!(back, instr, "word {w:#010x}");
        }
    }
}
