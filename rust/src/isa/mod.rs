//! Instruction-set architecture layer: RV32IM plus the paper's
//! non-standard I′/S′ vector instruction types (§2.1, Fig. 1).
//!
//! - [`reg`] — base (`x0..x31`) and vector (`v0..v7`) register names.
//! - [`instr`] — the decoded [`instr::Instr`] form shared by all layers.
//! - [`encode`] / [`decode`] — machine-word codecs; `decode ∘ encode = id`
//!   is enforced by property tests.
//! - [`predecode`] — the decode-once text-segment cache (with its
//!   store-invalidation contract) shared by both execution backends.

pub mod decode;
pub mod encode;
pub mod instr;
pub mod predecode;
pub mod reg;

pub use decode::{decode, DecodeError};
pub use encode::{encode, EncodeError};
pub use instr::{csr, CustomSlot, IPrime, Instr, SPrime};
pub use predecode::DecodeCache;
pub use reg::{Reg, VReg};
