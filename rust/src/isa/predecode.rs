//! Predecoded text segment: one decode per text word, shared by both
//! backends.
//!
//! Both the timed [`crate::core::Core`] and the architectural
//! [`crate::ref_iss::RefIss`] decode each text word at most once and
//! then dispatch on the cached [`Instr`]. [`DecodeCache`] is that shared
//! map plus the piece the seed version of both backends was missing: an
//! **invalidation contract**. A store whose byte range overlaps the text
//! segment must call [`DecodeCache::invalidate`] so self-modifying code
//! re-decodes the new word instead of silently executing the stale one
//! (DESIGN.md §11).
//!
//! Words that do not decode are left empty rather than failing the whole
//! load: an illegal word only faults if it is actually fetched, and it
//! must fault *at its pc* at execution time, exactly like the
//! decode-on-demand path did.

use super::{decode, Instr};

/// Per-word decoded view of the text segment `[base, base + 4*len)`.
#[derive(Debug, Default)]
pub struct DecodeCache {
    base: u32,
    slots: Vec<Option<Instr>>,
}

impl DecodeCache {
    /// An empty cache (no program loaded).
    pub fn empty() -> Self {
        Self { base: 0, slots: Vec::new() }
    }

    /// Predecode a freshly loaded text segment. Undecodable words keep an
    /// empty slot (see module docs).
    pub fn predecode(&mut self, base: u32, words: &[u32]) {
        self.base = base;
        self.slots.clear();
        self.slots.extend(words.iter().map(|&w| decode(w).ok()));
    }

    pub fn base(&self) -> u32 {
        self.base
    }

    /// Number of text words covered.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Word index of `pc`, if `pc` lies in the text segment and is
    /// word-aligned *relative to the text base*. Callers must have
    /// already raised misaligned-fetch faults: a pc at `base + 4k + 2`
    /// returns `None` here so the truncating division can never alias an
    /// aligned slot.
    #[inline]
    pub fn word_index(&self, pc: u32) -> Option<usize> {
        let off = pc.wrapping_sub(self.base);
        if off % 4 != 0 {
            return None;
        }
        let idx = (off / 4) as usize;
        (idx < self.slots.len()).then_some(idx)
    }

    #[inline]
    pub fn get(&self, idx: usize) -> Option<Instr> {
        self.slots[idx]
    }

    /// Cache a decode performed on demand (after an invalidation, or for
    /// a word that was undecodable at load time and has been rewritten).
    #[inline]
    pub fn put(&mut self, idx: usize, i: Instr) {
        self.slots[idx] = Some(i);
    }

    /// Does the byte range `[addr, addr + len)` overlap the text
    /// segment? Widths are carried in `u64` so a range reaching the top
    /// of the 32-bit address space cannot wrap.
    #[inline]
    pub fn overlaps(&self, addr: u32, len: usize) -> bool {
        if self.slots.is_empty() || len == 0 {
            return false;
        }
        let end = addr as u64 + len as u64;
        let text_end = self.base as u64 + self.slots.len() as u64 * 4;
        (addr as u64) < text_end && end > self.base as u64
    }

    /// Drop every decoded word the byte range `[addr, addr + len)`
    /// touches. Returns the inclusive word-index span cleared, so the
    /// caller can also invalidate derived state (block caches), or
    /// `None` when the range misses the text segment entirely.
    pub fn invalidate(&mut self, addr: u32, len: usize) -> Option<(usize, usize)> {
        if !self.overlaps(addr, len) {
            return None;
        }
        let start = (addr as u64).max(self.base as u64) - self.base as u64;
        let end = (addr as u64 + len as u64).min(self.base as u64 + self.slots.len() as u64 * 4)
            - self.base as u64;
        let first = (start / 4) as usize;
        let last = ((end - 1) / 4) as usize;
        for slot in &mut self.slots[first..=last] {
            *slot = None;
        }
        Some((first, last))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode;
    use crate::isa::reg::*;

    fn cache_of(words: &[u32]) -> DecodeCache {
        let mut c = DecodeCache::empty();
        c.predecode(0x100, words);
        c
    }

    fn addi_word() -> u32 {
        encode(&Instr::Addi { rd: A0, rs1: A0, imm: 1 }).unwrap()
    }

    #[test]
    fn predecode_fills_slots_and_tolerates_illegal_words() {
        let c = cache_of(&[addi_word(), 0xffff_ffff, addi_word()]);
        assert_eq!(c.len(), 3);
        assert!(c.get(0).is_some());
        assert!(c.get(1).is_none(), "illegal word stays empty, faults only if fetched");
        assert!(c.get(2).is_some());
    }

    #[test]
    fn word_index_rejects_unaligned_and_out_of_range() {
        let c = cache_of(&[addi_word(), addi_word()]);
        assert_eq!(c.word_index(0x100), Some(0));
        assert_eq!(c.word_index(0x104), Some(1));
        assert_eq!(c.word_index(0x102), None, "base+2 must not alias slot 0");
        assert_eq!(c.word_index(0x108), None);
        assert_eq!(c.word_index(0xFC), None);
    }

    #[test]
    fn overlap_and_invalidate_spans() {
        let mut c = cache_of(&[addi_word(); 4]); // text = [0x100, 0x110)
        assert!(!c.overlaps(0xF0, 16));
        assert!(c.overlaps(0xFD, 4), "straddling the base overlaps");
        assert!(c.overlaps(0x10F, 1));
        assert!(!c.overlaps(0x110, 64));
        assert!(!c.overlaps(0x104, 0));

        // A 1-byte store into the middle word clears exactly that word.
        assert_eq!(c.invalidate(0x105, 1), Some((1, 1)));
        assert!(c.get(1).is_none());
        assert!(c.get(0).is_some() && c.get(2).is_some());

        // An unaligned 4-byte store straddles two words.
        assert_eq!(c.invalidate(0x109, 4), Some((2, 3)));
        assert!(c.get(2).is_none() && c.get(3).is_none());

        // A huge range clamps to the text bounds.
        c.predecode(0x100, &[addi_word(); 4]);
        assert_eq!(c.invalidate(0, 0x1000), Some((0, 3)));
        assert_eq!(c.invalidate(0x200, 4), None);
    }

    #[test]
    fn overlap_near_address_space_top_does_not_wrap() {
        let mut c = DecodeCache::empty();
        c.predecode(0x100, &[addi_word()]);
        assert!(!c.overlaps(0xffff_fff0, 0x20));
    }
}
