//! Instruction encoding: `Instr` → 32-bit machine word.
//!
//! RV32I/M encodings follow the unprivileged spec; I′/S′ follow Fig. 1 of
//! the paper. One decode-level convention of ours (documented in
//! DESIGN.md): within a custom slot, `funct3 < 4` encodes an I′-type
//! instruction and `funct3 >= 4` an S′-type, so the decoder needs no
//! per-slot side table — mirroring how the paper's binutils patch fixes
//! the format per mnemonic.

use super::instr::{CustomSlot, IPrime, Instr, SPrime};
use super::reg::Reg;

#[derive(Debug, PartialEq, Eq)]
pub enum EncodeError {
    ImmOutOfRange { what: &'static str, imm: i64, lo: i64, hi: i64 },
    Misaligned { what: &'static str, imm: i64, align: i64 },
    BadShamt(u8),
    BadFunct3 { what: &'static str, funct3: u8, why: &'static str },
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::ImmOutOfRange { what, imm, lo, hi } => {
                write!(f, "immediate {imm} out of range for {what} (range {lo}..={hi})")
            }
            EncodeError::Misaligned { what, imm, align } => {
                write!(f, "{what} offset {imm} must be a multiple of {align}")
            }
            EncodeError::BadShamt(shamt) => {
                write!(f, "shift amount {shamt} out of range (0..=31)")
            }
            EncodeError::BadFunct3 { what, funct3, why } => {
                write!(f, "funct3 {funct3} invalid for {what}: {why}")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

fn check_range(what: &'static str, imm: i64, lo: i64, hi: i64) -> Result<(), EncodeError> {
    if imm < lo || imm > hi {
        return Err(EncodeError::ImmOutOfRange { what, imm, lo, hi });
    }
    Ok(())
}

#[inline]
fn r(rd: Reg, f3: u32, rs1: Reg, rs2: Reg, f7: u32, opcode: u32) -> u32 {
    (f7 << 25)
        | ((rs2.num() as u32) << 20)
        | ((rs1.num() as u32) << 15)
        | (f3 << 12)
        | ((rd.num() as u32) << 7)
        | opcode
}

#[inline]
fn i(rd: Reg, f3: u32, rs1: Reg, imm12: i32, opcode: u32) -> u32 {
    (((imm12 as u32) & 0xfff) << 20)
        | ((rs1.num() as u32) << 15)
        | (f3 << 12)
        | ((rd.num() as u32) << 7)
        | opcode
}

#[inline]
fn s(f3: u32, rs1: Reg, rs2: Reg, imm12: i32, opcode: u32) -> u32 {
    let imm = imm12 as u32;
    (((imm >> 5) & 0x7f) << 25)
        | ((rs2.num() as u32) << 20)
        | ((rs1.num() as u32) << 15)
        | (f3 << 12)
        | ((imm & 0x1f) << 7)
        | opcode
}

#[inline]
fn b(f3: u32, rs1: Reg, rs2: Reg, off: i32, opcode: u32) -> u32 {
    let imm = off as u32;
    (((imm >> 12) & 1) << 31)
        | (((imm >> 5) & 0x3f) << 25)
        | ((rs2.num() as u32) << 20)
        | ((rs1.num() as u32) << 15)
        | (f3 << 12)
        | (((imm >> 1) & 0xf) << 8)
        | (((imm >> 11) & 1) << 7)
        | opcode
}

#[inline]
fn u(rd: Reg, imm: i32, opcode: u32) -> u32 {
    ((imm as u32) & 0xffff_f000) | ((rd.num() as u32) << 7) | opcode
}

#[inline]
fn j(rd: Reg, off: i32, opcode: u32) -> u32 {
    let imm = off as u32;
    (((imm >> 20) & 1) << 31)
        | (((imm >> 1) & 0x3ff) << 21)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 12) & 0xff) << 12)
        | ((rd.num() as u32) << 7)
        | opcode
}

/// Encode the paper's I′-type (Fig. 1):
/// `vrs1[31:29] vrd1[28:26] vrs2[25:23] vrd2[22:20] rs1 funct3 rd opcode`.
#[inline]
fn iprime(slot: CustomSlot, funct3: u8, ops: &IPrime) -> u32 {
    ((ops.vrs1.num() as u32) << 29)
        | ((ops.vrd1.num() as u32) << 26)
        | ((ops.vrs2.num() as u32) << 23)
        | ((ops.vrd2.num() as u32) << 20)
        | ((ops.rs1.num() as u32) << 15)
        | ((funct3 as u32) << 12)
        | ((ops.rd.num() as u32) << 7)
        | slot.opcode()
}

/// Encode the paper's S′-type (Fig. 1):
/// `vrs1[31:29] vrd1[28:26] imm[25] rs2[24:20] rs1 funct3 rd opcode`.
#[inline]
fn sprime(slot: CustomSlot, funct3: u8, ops: &SPrime) -> u32 {
    ((ops.vrs1.num() as u32) << 29)
        | ((ops.vrd1.num() as u32) << 26)
        | (((ops.imm & 1) as u32) << 25)
        | ((ops.rs2.num() as u32) << 20)
        | ((ops.rs1.num() as u32) << 15)
        | ((funct3 as u32) << 12)
        | ((ops.rd.num() as u32) << 7)
        | slot.opcode()
}

const OP_LUI: u32 = 0b011_0111;
const OP_AUIPC: u32 = 0b001_0111;
const OP_JAL: u32 = 0b110_1111;
const OP_JALR: u32 = 0b110_0111;
const OP_BRANCH: u32 = 0b110_0011;
const OP_LOAD: u32 = 0b000_0011;
const OP_STORE: u32 = 0b010_0011;
const OP_IMM: u32 = 0b001_0011;
const OP_REG: u32 = 0b011_0011;
const OP_FENCE: u32 = 0b000_1111;
const OP_SYSTEM: u32 = 0b111_0011;

/// Encode an instruction to its 32-bit machine word.
pub fn encode(instr: &Instr) -> Result<u32, EncodeError> {
    use Instr::*;
    Ok(match *instr {
        Lui { rd, imm } => {
            // `imm` carries the already-shifted 32-bit value (low 12 bits 0).
            if imm & 0xfff != 0 {
                return Err(EncodeError::Misaligned { what: "lui", imm: imm as i64, align: 4096 });
            }
            u(rd, imm, OP_LUI)
        }
        Auipc { rd, imm } => {
            if imm & 0xfff != 0 {
                return Err(EncodeError::Misaligned { what: "auipc", imm: imm as i64, align: 4096 });
            }
            u(rd, imm, OP_AUIPC)
        }
        Jal { rd, offset } => {
            check_range("jal", offset as i64, -(1 << 20), (1 << 20) - 2)?;
            if offset & 1 != 0 {
                return Err(EncodeError::Misaligned { what: "jal", imm: offset as i64, align: 2 });
            }
            j(rd, offset, OP_JAL)
        }
        Jalr { rd, rs1, offset } => {
            check_range("jalr", offset as i64, -2048, 2047)?;
            i(rd, 0b000, rs1, offset, OP_JALR)
        }
        Beq { rs1, rs2, offset } => branch(0b000, rs1, rs2, offset)?,
        Bne { rs1, rs2, offset } => branch(0b001, rs1, rs2, offset)?,
        Blt { rs1, rs2, offset } => branch(0b100, rs1, rs2, offset)?,
        Bge { rs1, rs2, offset } => branch(0b101, rs1, rs2, offset)?,
        Bltu { rs1, rs2, offset } => branch(0b110, rs1, rs2, offset)?,
        Bgeu { rs1, rs2, offset } => branch(0b111, rs1, rs2, offset)?,
        Lb { rd, rs1, offset } => load(rd, 0b000, rs1, offset)?,
        Lh { rd, rs1, offset } => load(rd, 0b001, rs1, offset)?,
        Lw { rd, rs1, offset } => load(rd, 0b010, rs1, offset)?,
        Lbu { rd, rs1, offset } => load(rd, 0b100, rs1, offset)?,
        Lhu { rd, rs1, offset } => load(rd, 0b101, rs1, offset)?,
        Sb { rs1, rs2, offset } => store(0b000, rs1, rs2, offset)?,
        Sh { rs1, rs2, offset } => store(0b001, rs1, rs2, offset)?,
        Sw { rs1, rs2, offset } => store(0b010, rs1, rs2, offset)?,
        Addi { rd, rs1, imm } => alu_imm(rd, 0b000, rs1, imm)?,
        Slti { rd, rs1, imm } => alu_imm(rd, 0b010, rs1, imm)?,
        Sltiu { rd, rs1, imm } => alu_imm(rd, 0b011, rs1, imm)?,
        Xori { rd, rs1, imm } => alu_imm(rd, 0b100, rs1, imm)?,
        Ori { rd, rs1, imm } => alu_imm(rd, 0b110, rs1, imm)?,
        Andi { rd, rs1, imm } => alu_imm(rd, 0b111, rs1, imm)?,
        Slli { rd, rs1, shamt } => shift(rd, 0b001, rs1, shamt, 0)?,
        Srli { rd, rs1, shamt } => shift(rd, 0b101, rs1, shamt, 0)?,
        Srai { rd, rs1, shamt } => shift(rd, 0b101, rs1, shamt, 0b010_0000)?,
        Add { rd, rs1, rs2 } => r(rd, 0b000, rs1, rs2, 0, OP_REG),
        Sub { rd, rs1, rs2 } => r(rd, 0b000, rs1, rs2, 0b010_0000, OP_REG),
        Sll { rd, rs1, rs2 } => r(rd, 0b001, rs1, rs2, 0, OP_REG),
        Slt { rd, rs1, rs2 } => r(rd, 0b010, rs1, rs2, 0, OP_REG),
        Sltu { rd, rs1, rs2 } => r(rd, 0b011, rs1, rs2, 0, OP_REG),
        Xor { rd, rs1, rs2 } => r(rd, 0b100, rs1, rs2, 0, OP_REG),
        Srl { rd, rs1, rs2 } => r(rd, 0b101, rs1, rs2, 0, OP_REG),
        Sra { rd, rs1, rs2 } => r(rd, 0b101, rs1, rs2, 0b010_0000, OP_REG),
        Or { rd, rs1, rs2 } => r(rd, 0b110, rs1, rs2, 0, OP_REG),
        And { rd, rs1, rs2 } => r(rd, 0b111, rs1, rs2, 0, OP_REG),
        Fence => OP_FENCE,
        Ecall => 0x0000_0073,
        Ebreak => 0x0010_0073,
        Csrrs { rd, csr, rs1 } => {
            ((csr as u32) << 20)
                | ((rs1.num() as u32) << 15)
                | (0b010 << 12)
                | ((rd.num() as u32) << 7)
                | OP_SYSTEM
        }
        Mul { rd, rs1, rs2 } => r(rd, 0b000, rs1, rs2, 1, OP_REG),
        Mulh { rd, rs1, rs2 } => r(rd, 0b001, rs1, rs2, 1, OP_REG),
        Mulhsu { rd, rs1, rs2 } => r(rd, 0b010, rs1, rs2, 1, OP_REG),
        Mulhu { rd, rs1, rs2 } => r(rd, 0b011, rs1, rs2, 1, OP_REG),
        Div { rd, rs1, rs2 } => r(rd, 0b100, rs1, rs2, 1, OP_REG),
        Divu { rd, rs1, rs2 } => r(rd, 0b101, rs1, rs2, 1, OP_REG),
        Rem { rd, rs1, rs2 } => r(rd, 0b110, rs1, rs2, 1, OP_REG),
        Remu { rd, rs1, rs2 } => r(rd, 0b111, rs1, rs2, 1, OP_REG),
        CustomI { slot, funct3, ops } => {
            if funct3 >= 4 {
                return Err(EncodeError::BadFunct3 {
                    what: "I'-type",
                    funct3,
                    why: "funct3 0..=3 encode I'-type; 4..=7 are S'-type",
                });
            }
            iprime(slot, funct3, &ops)
        }
        CustomS { slot, funct3, ops } => {
            if !(4..8).contains(&funct3) {
                return Err(EncodeError::BadFunct3 {
                    what: "S'-type",
                    funct3,
                    why: "funct3 4..=7 encode S'-type; 0..=3 are I'-type",
                });
            }
            sprime(slot, funct3, &ops)
        }
    })
}

fn branch(f3: u32, rs1: Reg, rs2: Reg, offset: i32) -> Result<u32, EncodeError> {
    check_range("branch", offset as i64, -4096, 4094)?;
    if offset & 1 != 0 {
        return Err(EncodeError::Misaligned { what: "branch", imm: offset as i64, align: 2 });
    }
    Ok(b(f3, rs1, rs2, offset, OP_BRANCH))
}

fn load(rd: Reg, f3: u32, rs1: Reg, offset: i32) -> Result<u32, EncodeError> {
    check_range("load", offset as i64, -2048, 2047)?;
    Ok(i(rd, f3, rs1, offset, OP_LOAD))
}

fn store(f3: u32, rs1: Reg, rs2: Reg, offset: i32) -> Result<u32, EncodeError> {
    check_range("store", offset as i64, -2048, 2047)?;
    Ok(s(f3, rs1, rs2, offset, OP_STORE))
}

fn alu_imm(rd: Reg, f3: u32, rs1: Reg, imm: i32) -> Result<u32, EncodeError> {
    check_range("alu-imm", imm as i64, -2048, 2047)?;
    Ok(i(rd, f3, rs1, imm, OP_IMM))
}

fn shift(rd: Reg, f3: u32, rs1: Reg, shamt: u8, f7: u32) -> Result<u32, EncodeError> {
    if shamt >= 32 {
        return Err(EncodeError::BadShamt(shamt));
    }
    Ok(r(rd, f3, rs1, Reg(shamt), f7, OP_IMM))
}

// Re-export field helpers for the decoder (kept here so layout knowledge
// lives in one file).
pub(crate) mod fields {
    /// Extract `[hi:lo]` (inclusive) from a word.
    #[inline]
    pub fn bits(word: u32, hi: u32, lo: u32) -> u32 {
        (word >> lo) & ((1u32 << (hi - lo + 1)) - 1)
    }

    /// Sign-extend the low `n` bits of `v`.
    #[inline]
    pub fn sext(v: u32, n: u32) -> i32 {
        let shift = 32 - n;
        ((v << shift) as i32) >> shift
    }
}

pub(crate) use fields::{bits, sext};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;

    /// Cross-checked against `riscv64-unknown-elf-gcc -c` objdump output
    /// (well-known encodings).
    #[test]
    fn golden_encodings() {
        // addi a0, a0, 1  => 0x00150513
        assert_eq!(encode(&Instr::Addi { rd: A0, rs1: A0, imm: 1 }).unwrap(), 0x0015_0513);
        // add a0, a1, a2 => 0x00c58533
        assert_eq!(encode(&Instr::Add { rd: A0, rs1: A1, rs2: A2 }).unwrap(), 0x00c5_8533);
        // lw a0, 4(sp) => 0x00412503
        assert_eq!(encode(&Instr::Lw { rd: A0, rs1: SP, offset: 4 }).unwrap(), 0x0041_2503);
        // sw a0, 8(sp) => 0x00a12423
        assert_eq!(encode(&Instr::Sw { rs1: SP, rs2: A0, offset: 8 }).unwrap(), 0x00a1_2423);
        // lui a0, 0x12345 => 0x12345537
        assert_eq!(encode(&Instr::Lui { rd: A0, imm: 0x1234_5000 }).unwrap(), 0x1234_5537);
        // jal ra, 16 => 0x010000ef
        assert_eq!(encode(&Instr::Jal { rd: RA, offset: 16 }).unwrap(), 0x0100_00ef);
        // beq a0, a1, -4 => 0xfeb50ee3
        assert_eq!(encode(&Instr::Beq { rs1: A0, rs2: A1, offset: -4 }).unwrap(), 0xfeb5_0ee3);
        // mul a0, a1, a2 => 0x02c58533
        assert_eq!(encode(&Instr::Mul { rd: A0, rs1: A1, rs2: A2 }).unwrap(), 0x02c5_8533);
        // srai a0, a0, 3 => 0x40355513
        assert_eq!(encode(&Instr::Srai { rd: A0, rs1: A0, shamt: 3 }).unwrap(), 0x4035_5513);
        // ecall / ebreak / fence
        assert_eq!(encode(&Instr::Ecall).unwrap(), 0x0000_0073);
        assert_eq!(encode(&Instr::Ebreak).unwrap(), 0x0010_0073);
        // csrrs a0, cycle, zero  (rdcycle a0) => 0xc0002573
        assert_eq!(
            encode(&Instr::Csrrs { rd: A0, csr: 0xC00, rs1: ZERO }).unwrap(),
            0xc000_2573
        );
    }

    #[test]
    fn range_validation() {
        assert!(matches!(
            encode(&Instr::Addi { rd: A0, rs1: A0, imm: 5000 }),
            Err(EncodeError::ImmOutOfRange { .. })
        ));
        assert!(matches!(
            encode(&Instr::Beq { rs1: A0, rs2: A1, offset: 3 }),
            Err(EncodeError::Misaligned { .. })
        ));
        assert!(matches!(
            encode(&Instr::Slli { rd: A0, rs1: A0, shamt: 32 }),
            Err(EncodeError::BadShamt(32))
        ));
        assert!(matches!(
            encode(&Instr::Jal { rd: RA, offset: 1 << 20 }),
            Err(EncodeError::ImmOutOfRange { .. })
        ));
    }

    #[test]
    fn iprime_field_placement() {
        let ops = IPrime { vrs1: V1, vrd1: V2, vrs2: V3, vrd2: V4, rs1: A0, rd: A1 };
        let w = encode(&Instr::CustomI { slot: CustomSlot::C2, funct3: 1, ops }).unwrap();
        assert_eq!(bits(w, 31, 29), 1, "vrs1");
        assert_eq!(bits(w, 28, 26), 2, "vrd1");
        assert_eq!(bits(w, 25, 23), 3, "vrs2");
        assert_eq!(bits(w, 22, 20), 4, "vrd2");
        assert_eq!(bits(w, 19, 15), 10, "rs1");
        assert_eq!(bits(w, 14, 12), 1, "funct3");
        assert_eq!(bits(w, 11, 7), 11, "rd");
        assert_eq!(bits(w, 6, 0), CustomSlot::C2.opcode(), "opcode");
    }

    #[test]
    fn sprime_field_placement() {
        let ops = SPrime { vrs1: V5, vrd1: V6, imm: 1, rs2: A2, rs1: A0, rd: ZERO };
        let w = encode(&Instr::CustomS { slot: CustomSlot::C0, funct3: 5, ops }).unwrap();
        assert_eq!(bits(w, 31, 29), 5, "vrs1");
        assert_eq!(bits(w, 28, 26), 6, "vrd1");
        assert_eq!(bits(w, 25, 25), 1, "imm");
        assert_eq!(bits(w, 24, 20), 12, "rs2");
        assert_eq!(bits(w, 19, 15), 10, "rs1");
        assert_eq!(bits(w, 14, 12), 5, "funct3");
        assert_eq!(bits(w, 11, 7), 0, "rd");
        assert_eq!(bits(w, 6, 0), CustomSlot::C0.opcode(), "opcode");
    }

    #[test]
    fn custom_funct3_convention_enforced() {
        let iops = IPrime { vrs1: V1, vrd1: V1, vrs2: V0, vrd2: V0, rs1: ZERO, rd: ZERO };
        assert!(matches!(
            encode(&Instr::CustomI { slot: CustomSlot::C1, funct3: 4, ops: iops }),
            Err(EncodeError::BadFunct3 { .. })
        ));
        let sops = SPrime { vrs1: V1, vrd1: V1, imm: 0, rs2: ZERO, rs1: ZERO, rd: ZERO };
        assert!(matches!(
            encode(&Instr::CustomS { slot: CustomSlot::C1, funct3: 2, ops: sops }),
            Err(EncodeError::BadFunct3 { .. })
        ));
    }
}
