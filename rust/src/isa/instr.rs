//! Decoded instruction forms: RV32I base, the M extension, the Zicsr
//! subset the softcore exposes (cycle/instret counters), and the paper's
//! two non-standard vector instruction types I′ and S′ (§2.1, Fig. 1).
//!
//! `Instr` is the single source of truth shared by the encoder, decoder,
//! assembler, disassembler and the simulator core.

use super::reg::{Reg, VReg};
use std::fmt;

/// Opcode slot for custom instructions. RISC-V reserves four major opcodes
/// for custom extensions; the paper's `cN_*` mnemonics name the unit
/// loaded into reconfigurable slot N, which we bind 1:1 to these opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CustomSlot {
    /// custom-0, opcode `0001011`
    C0,
    /// custom-1, opcode `0101011`
    C1,
    /// custom-2, opcode `1011011`
    C2,
    /// custom-3, opcode `1111011`
    C3,
}

impl CustomSlot {
    pub const ALL: [CustomSlot; 4] = [CustomSlot::C0, CustomSlot::C1, CustomSlot::C2, CustomSlot::C3];

    pub const fn opcode(self) -> u32 {
        match self {
            CustomSlot::C0 => 0b000_1011,
            CustomSlot::C1 => 0b010_1011,
            CustomSlot::C2 => 0b101_1011,
            CustomSlot::C3 => 0b111_1011,
        }
    }

    pub const fn from_opcode(op: u32) -> Option<CustomSlot> {
        match op {
            0b000_1011 => Some(CustomSlot::C0),
            0b010_1011 => Some(CustomSlot::C1),
            0b101_1011 => Some(CustomSlot::C2),
            0b111_1011 => Some(CustomSlot::C3),
            _ => None,
        }
    }

    pub const fn index(self) -> usize {
        match self {
            CustomSlot::C0 => 0,
            CustomSlot::C1 => 1,
            CustomSlot::C2 => 2,
            CustomSlot::C3 => 3,
        }
    }

    pub const fn from_index(i: usize) -> Option<CustomSlot> {
        match i {
            0 => Some(CustomSlot::C0),
            1 => Some(CustomSlot::C1),
            2 => Some(CustomSlot::C2),
            3 => Some(CustomSlot::C3),
            _ => None,
        }
    }
}

impl fmt::Display for CustomSlot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.index())
    }
}

/// Operand bundle of an I′-type instruction (Fig. 1).
///
/// Field layout (32-bit word, MSB→LSB):
/// `vrs1[31:29] vrd1[28:26] vrs2[25:23] vrd2[22:20] rs1[19:15] funct3[14:12] rd[11:7] opcode[6:0]`
///
/// The 12-bit immediate of the standard I-type is repurposed as four 3-bit
/// vector register names, giving up to 6 accessible registers per
/// instruction (2 base + 4 vector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IPrime {
    pub vrs1: VReg,
    pub vrd1: VReg,
    pub vrs2: VReg,
    pub vrd2: VReg,
    pub rs1: Reg,
    pub rd: Reg,
}

/// Operand bundle of an S′-type instruction (Fig. 1).
///
/// Field layout (32-bit word, MSB→LSB):
/// `vrs1[31:29] vrd1[28:26] imm[25] rs2[24:20] rs1[19:15] funct3[14:12] rd[11:7] opcode[6:0]`
///
/// S′ trades the `vrs2`/`vrd2` fields of I′ for a second base source
/// register `rs2` (useful to split loop indices for load/store-style
/// instructions, §2.1). The 6 bits freed by `vrs2+vrd2` hold the 5-bit
/// `rs2` plus a single immediate bit (the paper's figure leaves the
/// residual bit as `imm`; we expose it as a 1-bit modifier flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SPrime {
    pub vrs1: VReg,
    pub vrd1: VReg,
    /// 1-bit immediate/modifier flag (bit 25).
    pub imm: u8,
    pub rs2: Reg,
    pub rs1: Reg,
    pub rd: Reg,
}

/// A decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    // ---- RV32I: upper immediates & jumps --------------------------------
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, offset: i32 },
    Jalr { rd: Reg, rs1: Reg, offset: i32 },

    // ---- RV32I: conditional branches ------------------------------------
    Beq { rs1: Reg, rs2: Reg, offset: i32 },
    Bne { rs1: Reg, rs2: Reg, offset: i32 },
    Blt { rs1: Reg, rs2: Reg, offset: i32 },
    Bge { rs1: Reg, rs2: Reg, offset: i32 },
    Bltu { rs1: Reg, rs2: Reg, offset: i32 },
    Bgeu { rs1: Reg, rs2: Reg, offset: i32 },

    // ---- RV32I: loads / stores ------------------------------------------
    Lb { rd: Reg, rs1: Reg, offset: i32 },
    Lh { rd: Reg, rs1: Reg, offset: i32 },
    Lw { rd: Reg, rs1: Reg, offset: i32 },
    Lbu { rd: Reg, rs1: Reg, offset: i32 },
    Lhu { rd: Reg, rs1: Reg, offset: i32 },
    Sb { rs1: Reg, rs2: Reg, offset: i32 },
    Sh { rs1: Reg, rs2: Reg, offset: i32 },
    Sw { rs1: Reg, rs2: Reg, offset: i32 },

    // ---- RV32I: immediate ALU -------------------------------------------
    Addi { rd: Reg, rs1: Reg, imm: i32 },
    Slti { rd: Reg, rs1: Reg, imm: i32 },
    Sltiu { rd: Reg, rs1: Reg, imm: i32 },
    Xori { rd: Reg, rs1: Reg, imm: i32 },
    Ori { rd: Reg, rs1: Reg, imm: i32 },
    Andi { rd: Reg, rs1: Reg, imm: i32 },
    Slli { rd: Reg, rs1: Reg, shamt: u8 },
    Srli { rd: Reg, rs1: Reg, shamt: u8 },
    Srai { rd: Reg, rs1: Reg, shamt: u8 },

    // ---- RV32I: register ALU --------------------------------------------
    Add { rd: Reg, rs1: Reg, rs2: Reg },
    Sub { rd: Reg, rs1: Reg, rs2: Reg },
    Sll { rd: Reg, rs1: Reg, rs2: Reg },
    Slt { rd: Reg, rs1: Reg, rs2: Reg },
    Sltu { rd: Reg, rs1: Reg, rs2: Reg },
    Xor { rd: Reg, rs1: Reg, rs2: Reg },
    Srl { rd: Reg, rs1: Reg, rs2: Reg },
    Sra { rd: Reg, rs1: Reg, rs2: Reg },
    Or { rd: Reg, rs1: Reg, rs2: Reg },
    And { rd: Reg, rs1: Reg, rs2: Reg },

    // ---- RV32I: system ----------------------------------------------------
    Fence,
    Ecall,
    Ebreak,

    // ---- Zicsr subset (read-only performance counters) --------------------
    /// `csrrs rd, csr, rs1` — the softcore implements the read-only
    /// counter CSRs (cycle/cycleh/instret/instreth/time/timeh).
    Csrrs { rd: Reg, csr: u16, rs1: Reg },

    // ---- M extension -------------------------------------------------------
    Mul { rd: Reg, rs1: Reg, rs2: Reg },
    Mulh { rd: Reg, rs1: Reg, rs2: Reg },
    Mulhsu { rd: Reg, rs1: Reg, rs2: Reg },
    Mulhu { rd: Reg, rs1: Reg, rs2: Reg },
    Div { rd: Reg, rs1: Reg, rs2: Reg },
    Divu { rd: Reg, rs1: Reg, rs2: Reg },
    Rem { rd: Reg, rs1: Reg, rs2: Reg },
    Remu { rd: Reg, rs1: Reg, rs2: Reg },

    // ---- Paper's custom SIMD types (§2.1) ----------------------------------
    /// I′-type custom instruction: `funct3` selects the operation within
    /// the slot's loaded unit.
    CustomI { slot: CustomSlot, funct3: u8, ops: IPrime },
    /// S′-type custom instruction.
    CustomS { slot: CustomSlot, funct3: u8, ops: SPrime },
}

impl Instr {
    /// The destination base register written by this instruction, if any.
    pub fn rd(&self) -> Option<Reg> {
        use Instr::*;
        match *self {
            Lui { rd, .. }
            | Auipc { rd, .. }
            | Jal { rd, .. }
            | Jalr { rd, .. }
            | Lb { rd, .. }
            | Lh { rd, .. }
            | Lw { rd, .. }
            | Lbu { rd, .. }
            | Lhu { rd, .. }
            | Addi { rd, .. }
            | Slti { rd, .. }
            | Sltiu { rd, .. }
            | Xori { rd, .. }
            | Ori { rd, .. }
            | Andi { rd, .. }
            | Slli { rd, .. }
            | Srli { rd, .. }
            | Srai { rd, .. }
            | Add { rd, .. }
            | Sub { rd, .. }
            | Sll { rd, .. }
            | Slt { rd, .. }
            | Sltu { rd, .. }
            | Xor { rd, .. }
            | Srl { rd, .. }
            | Sra { rd, .. }
            | Or { rd, .. }
            | And { rd, .. }
            | Csrrs { rd, .. }
            | Mul { rd, .. }
            | Mulh { rd, .. }
            | Mulhsu { rd, .. }
            | Mulhu { rd, .. }
            | Div { rd, .. }
            | Divu { rd, .. }
            | Rem { rd, .. }
            | Remu { rd, .. } => Some(rd),
            CustomI { ops, .. } => Some(ops.rd),
            CustomS { ops, .. } => Some(ops.rd),
            _ => None,
        }
    }

    /// True for control-flow instructions (used by the assembler to decide
    /// which immediates are label-relative).
    pub fn is_branch_or_jump(&self) -> bool {
        use Instr::*;
        matches!(
            self,
            Jal { .. } | Jalr { .. } | Beq { .. } | Bne { .. } | Blt { .. } | Bge { .. }
                | Bltu { .. } | Bgeu { .. }
        )
    }

    /// True if the instruction accesses data memory through DL1.
    pub fn is_mem(&self) -> bool {
        use Instr::*;
        matches!(
            self,
            Lb { .. } | Lh { .. } | Lw { .. } | Lbu { .. } | Lhu { .. } | Sb { .. } | Sh { .. }
                | Sw { .. }
        )
    }

    /// True for scalar loads (writes `rd` through the load-use pipe).
    pub fn is_load(&self) -> bool {
        use Instr::*;
        matches!(self, Lb { .. } | Lh { .. } | Lw { .. } | Lbu { .. } | Lhu { .. })
    }

    /// True for scalar stores.
    pub fn is_store(&self) -> bool {
        use Instr::*;
        matches!(self, Sb { .. } | Sh { .. } | Sw { .. })
    }

    /// True for instructions whose *result value* depends on their own
    /// address (`auipc`, and the link value of `jal`/`jalr`): these may
    /// never be moved by the instruction scheduler.
    pub fn is_pc_relative(&self) -> bool {
        use Instr::*;
        matches!(self, Auipc { .. } | Jal { .. } | Jalr { .. })
    }

    /// Canonical mnemonic (what the text assembler parses and the
    /// disassembler prints).
    pub fn mnemonic(&self) -> &'static str {
        use Instr::*;
        match self {
            Lui { .. } => "lui",
            Auipc { .. } => "auipc",
            Jal { .. } => "jal",
            Jalr { .. } => "jalr",
            Beq { .. } => "beq",
            Bne { .. } => "bne",
            Blt { .. } => "blt",
            Bge { .. } => "bge",
            Bltu { .. } => "bltu",
            Bgeu { .. } => "bgeu",
            Lb { .. } => "lb",
            Lh { .. } => "lh",
            Lw { .. } => "lw",
            Lbu { .. } => "lbu",
            Lhu { .. } => "lhu",
            Sb { .. } => "sb",
            Sh { .. } => "sh",
            Sw { .. } => "sw",
            Addi { .. } => "addi",
            Slti { .. } => "slti",
            Sltiu { .. } => "sltiu",
            Xori { .. } => "xori",
            Ori { .. } => "ori",
            Andi { .. } => "andi",
            Slli { .. } => "slli",
            Srli { .. } => "srli",
            Srai { .. } => "srai",
            Add { .. } => "add",
            Sub { .. } => "sub",
            Sll { .. } => "sll",
            Slt { .. } => "slt",
            Sltu { .. } => "sltu",
            Xor { .. } => "xor",
            Srl { .. } => "srl",
            Sra { .. } => "sra",
            Or { .. } => "or",
            And { .. } => "and",
            Fence => "fence",
            Ecall => "ecall",
            Ebreak => "ebreak",
            Csrrs { .. } => "csrrs",
            Mul { .. } => "mul",
            Mulh { .. } => "mulh",
            Mulhsu { .. } => "mulhsu",
            Mulhu { .. } => "mulhu",
            Div { .. } => "div",
            Divu { .. } => "divu",
            Rem { .. } => "rem",
            Remu { .. } => "remu",
            CustomI { .. } => "custom.i",
            CustomS { .. } => "custom.s",
        }
    }
}

impl fmt::Display for Instr {
    /// Disassembly in the syntax the text assembler accepts.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Instr::*;
        match *self {
            Lui { rd, imm } => write!(f, "lui {rd}, {:#x}", (imm as u32) >> 12),
            Auipc { rd, imm } => write!(f, "auipc {rd}, {:#x}", (imm as u32) >> 12),
            Jal { rd, offset } => write!(f, "jal {rd}, {offset}"),
            Jalr { rd, rs1, offset } => write!(f, "jalr {rd}, {offset}({rs1})"),
            Beq { rs1, rs2, offset } => write!(f, "beq {rs1}, {rs2}, {offset}"),
            Bne { rs1, rs2, offset } => write!(f, "bne {rs1}, {rs2}, {offset}"),
            Blt { rs1, rs2, offset } => write!(f, "blt {rs1}, {rs2}, {offset}"),
            Bge { rs1, rs2, offset } => write!(f, "bge {rs1}, {rs2}, {offset}"),
            Bltu { rs1, rs2, offset } => write!(f, "bltu {rs1}, {rs2}, {offset}"),
            Bgeu { rs1, rs2, offset } => write!(f, "bgeu {rs1}, {rs2}, {offset}"),
            Lb { rd, rs1, offset } => write!(f, "lb {rd}, {offset}({rs1})"),
            Lh { rd, rs1, offset } => write!(f, "lh {rd}, {offset}({rs1})"),
            Lw { rd, rs1, offset } => write!(f, "lw {rd}, {offset}({rs1})"),
            Lbu { rd, rs1, offset } => write!(f, "lbu {rd}, {offset}({rs1})"),
            Lhu { rd, rs1, offset } => write!(f, "lhu {rd}, {offset}({rs1})"),
            Sb { rs1, rs2, offset } => write!(f, "sb {rs2}, {offset}({rs1})"),
            Sh { rs1, rs2, offset } => write!(f, "sh {rs2}, {offset}({rs1})"),
            Sw { rs1, rs2, offset } => write!(f, "sw {rs2}, {offset}({rs1})"),
            Addi { rd, rs1, imm } => write!(f, "addi {rd}, {rs1}, {imm}"),
            Slti { rd, rs1, imm } => write!(f, "slti {rd}, {rs1}, {imm}"),
            Sltiu { rd, rs1, imm } => write!(f, "sltiu {rd}, {rs1}, {imm}"),
            Xori { rd, rs1, imm } => write!(f, "xori {rd}, {rs1}, {imm}"),
            Ori { rd, rs1, imm } => write!(f, "ori {rd}, {rs1}, {imm}"),
            Andi { rd, rs1, imm } => write!(f, "andi {rd}, {rs1}, {imm}"),
            Slli { rd, rs1, shamt } => write!(f, "slli {rd}, {rs1}, {shamt}"),
            Srli { rd, rs1, shamt } => write!(f, "srli {rd}, {rs1}, {shamt}"),
            Srai { rd, rs1, shamt } => write!(f, "srai {rd}, {rs1}, {shamt}"),
            Add { rd, rs1, rs2 } => write!(f, "add {rd}, {rs1}, {rs2}"),
            Sub { rd, rs1, rs2 } => write!(f, "sub {rd}, {rs1}, {rs2}"),
            Sll { rd, rs1, rs2 } => write!(f, "sll {rd}, {rs1}, {rs2}"),
            Slt { rd, rs1, rs2 } => write!(f, "slt {rd}, {rs1}, {rs2}"),
            Sltu { rd, rs1, rs2 } => write!(f, "sltu {rd}, {rs1}, {rs2}"),
            Xor { rd, rs1, rs2 } => write!(f, "xor {rd}, {rs1}, {rs2}"),
            Srl { rd, rs1, rs2 } => write!(f, "srl {rd}, {rs1}, {rs2}"),
            Sra { rd, rs1, rs2 } => write!(f, "sra {rd}, {rs1}, {rs2}"),
            Or { rd, rs1, rs2 } => write!(f, "or {rd}, {rs1}, {rs2}"),
            And { rd, rs1, rs2 } => write!(f, "and {rd}, {rs1}, {rs2}"),
            Fence => write!(f, "fence"),
            Ecall => write!(f, "ecall"),
            Ebreak => write!(f, "ebreak"),
            Csrrs { rd, csr, rs1 } => write!(f, "csrrs {rd}, {csr:#x}, {rs1}"),
            Mul { rd, rs1, rs2 } => write!(f, "mul {rd}, {rs1}, {rs2}"),
            Mulh { rd, rs1, rs2 } => write!(f, "mulh {rd}, {rs1}, {rs2}"),
            Mulhsu { rd, rs1, rs2 } => write!(f, "mulhsu {rd}, {rs1}, {rs2}"),
            Mulhu { rd, rs1, rs2 } => write!(f, "mulhu {rd}, {rs1}, {rs2}"),
            Div { rd, rs1, rs2 } => write!(f, "div {rd}, {rs1}, {rs2}"),
            Divu { rd, rs1, rs2 } => write!(f, "divu {rd}, {rs1}, {rs2}"),
            Rem { rd, rs1, rs2 } => write!(f, "rem {rd}, {rs1}, {rs2}"),
            Remu { rd, rs1, rs2 } => write!(f, "remu {rd}, {rs1}, {rs2}"),
            CustomI { slot, funct3, ops } => write!(
                f,
                "{slot}.i{funct3} {}, {}, {}, {}, {}, {}",
                ops.rd, ops.vrd1, ops.vrd2, ops.rs1, ops.vrs1, ops.vrs2
            ),
            CustomS { slot, funct3, ops } => write!(
                f,
                "{slot}.s{funct3} {}, {}, {}, {}, {}, {}",
                ops.rd, ops.vrd1, ops.rs1, ops.rs2, ops.vrs1, ops.imm
            ),
        }
    }
}

/// CSR numbers implemented by the softcore (read-only counters).
pub mod csr {
    pub const CYCLE: u16 = 0xC00;
    pub const TIME: u16 = 0xC01;
    pub const INSTRET: u16 = 0xC02;
    pub const CYCLEH: u16 = 0xC80;
    pub const TIMEH: u16 = 0xC81;
    pub const INSTRETH: u16 = 0xC82;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;

    #[test]
    fn custom_slot_opcode_roundtrip() {
        for slot in CustomSlot::ALL {
            assert_eq!(CustomSlot::from_opcode(slot.opcode()), Some(slot));
            assert_eq!(CustomSlot::from_index(slot.index()), Some(slot));
        }
        assert_eq!(CustomSlot::from_opcode(0b0110011), None);
        assert_eq!(CustomSlot::from_index(4), None);
    }

    #[test]
    fn rd_extraction() {
        assert_eq!(Instr::Add { rd: A0, rs1: A1, rs2: A2 }.rd(), Some(A0));
        assert_eq!(Instr::Sw { rs1: A0, rs2: A1, offset: 0 }.rd(), None);
        assert_eq!(Instr::Beq { rs1: A0, rs2: A1, offset: 8 }.rd(), None);
        assert_eq!(Instr::Fence.rd(), None);
    }

    #[test]
    fn class_predicates() {
        assert!(Instr::Jal { rd: RA, offset: 16 }.is_branch_or_jump());
        assert!(!Instr::Add { rd: A0, rs1: A1, rs2: A2 }.is_branch_or_jump());
        assert!(Instr::Lw { rd: A0, rs1: A1, offset: 0 }.is_mem());
        assert!(!Instr::Jal { rd: RA, offset: 16 }.is_mem());
    }

    #[test]
    fn display_smoke() {
        let i = Instr::Addi { rd: A0, rs1: ZERO, imm: -5 };
        assert_eq!(i.to_string(), "addi a0, zero, -5");
        let s = Instr::Sw { rs1: SP, rs2: A0, offset: 12 };
        assert_eq!(s.to_string(), "sw a0, 12(sp)");
    }
}
