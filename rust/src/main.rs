//! `simdsoftcore` — CLI for the softcore framework: run programs on the
//! simulated core, run any registered workload across a configuration
//! sweep, regenerate every table/figure of the paper, inspect the fabric
//! artifacts.
//!
//! ```text
//! simdsoftcore <command> [options]
//!
//! workloads:
//!   run-workload <name> [--variant v] [--size N] [--vlen N]
//!                [--llc-block N] [--mshrs N] [--prefetch N]
//!                [--channels N] [--issue-width N]
//!                [--sweep axis=a,b,c]... [--json]
//!                                       run a registered workload; sweep
//!                                       axes: variant, size, vlen,
//!                                       llc-block, mshrs, prefetch,
//!                                       channels, issue-width (mshrs=1
//!                                       is the paper's blocking port,
//!                                       >=2 non-blocking; issue-width=1
//!                                       is the paper's single-issue
//!                                       pipeline, 2/4 superscalar)
//!   run-workload --elf FILE [machine flags] [--sweep axis=...]... [--json]
//!                                       run a prebuilt RV32 ELF binary
//!                                       (riscv-tests HTIF convention,
//!                                       DESIGN.md §13) instead of a
//!                                       registry workload; machine axes
//!                                       sweep as above, verified =
//!                                       "binary reported HTIF pass";
//!                                       the static analyzer pre-flights
//!                                       the binary and error-severity
//!                                       findings abort before the timed
//!                                       run (--no-analyze opts out)
//!   list-workloads                      registry contents
//!
//! verification:
//!   fuzz [--seeds N] [--base-seed S] [--ops M] [--analyze] [--sched]
//!        [--weights alu=..,branch=..,muldiv=..,mem=..,vec=..,vecmem=..,wildjump=..,smc=..]
//!        [--sweep axis=a,b,c]... [--artifact-dir DIR] [--json]
//!                                       differential fuzzing: random
//!                                       programs run in lockstep on the
//!                                       timed core and the reference ISS;
//!                                       default grid = paper machine +
//!                                       stressed memory (mshrs=8,
//!                                       prefetch, 2 channels); --sweep
//!                                       uses the machine axes above;
//!                                       --analyze pre-flights every case
//!                                       through the static analyzer;
//!                                       --sched round-trips every case
//!                                       through the intra-block list
//!                                       scheduler and proves equivalence
//!                                       by state compare + lockstep
//!                                       cosim; on failure the program
//!                                       listing and divergence report
//!                                       land in --artifact-dir (default
//!                                       fuzz-artifacts/)
//!   analyze [<workload>] [--variant v] [--size N] [--vlen N]
//!           [--listing FILE.s] [--perf] [--schedule] [--width 1|2|4]
//!           [--json]
//!                                       static guest-program analyzer
//!                                       (DESIGN.md §12): CFG recovery +
//!                                       dataflow lints over every
//!                                       registry workload (or one, or an
//!                                       assembled .s listing); also
//!                                       cross-checks recovered block
//!                                       boundaries against the reference
//!                                       ISS block lowering; exits
//!                                       non-zero on any error-severity
//!                                       finding (CI captures --json as
//!                                       BENCH_analysis.json); --perf
//!                                       adds the static per-block cycle
//!                                       cost model + stall-attribution
//!                                       findings and --schedule the
//!                                       cosim-verified intra-block list
//!                                       scheduler, both at issue width
//!                                       --width (default 2)
//!   sched-bench [<workload>] [--variant v] [--size N] [--vlen N] [--json]
//!                                       per-workload static cost-model
//!                                       estimate vs measured cycles vs
//!                                       post-schedule cycles on the
//!                                       flat-memory core at issue widths
//!                                       1/2/4; every reordered program
//!                                       must prove equivalence (CI
//!                                       captures --json as
//!                                       BENCH_sched.json)
//!   compliance [--dir DIR] [--json]     rv32ui/rv32um compliance suite:
//!                                       every checked-in ELF under
//!                                       rust/tests/compliance/ runs on
//!                                       the timed core AND the reference
//!                                       ISS plus a static-analyzer
//!                                       pre-flight; exits non-zero on
//!                                       any failure or any backend
//!                                       pass/fail mismatch (CI captures
//!                                       --json as BENCH_compliance.json)
//!
//! Every command accepts the `--jobs N` flag bounding its sweep worker
//! pool (default: available parallelism).
//!
//! sweep service (DESIGN.md §10):
//!   sweep-grid <workload>... [--variant v] [--size N]
//!              [--sweep axis=a,b,c]... [--store FILE.jsonl]
//!              [--shards N --shard I] [--timeout-ms T] [--retries R]
//!              [--budget N] [--expect-all-cached] [--json]
//!                                       run a workload grid through the
//!                                       service queue; with --store,
//!                                       completed points are served from
//!                                       the content-addressed result
//!                                       store on re-runs (crash-resume);
//!                                       --expect-all-cached fails unless
//!                                       every point was a cache hit (CI
//!                                       uses it to prove cache
//!                                       effectiveness)
//!   serve [--store FILE.jsonl] [--listen ADDR] [--timeout-ms T]
//!         [--retries R]
//!                                       long-running service: line-
//!                                       delimited JSON API over stdio
//!                                       (or a TCP socket with --listen);
//!                                       commands: ping, submit,
//!                                       progress, shutdown (protocol in
//!                                       rust/src/service/server.rs)
//!
//! experiments (all accept --json):
//!   fig3 [--side left|right] [--full]   memcpy design-space sweeps
//!   mem-sweep [--full]                  streaming bandwidth vs LLC block
//!                                       x MSHRs/prefetch/channels
//!                                       (CI captures --json as BENCH_mem.json)
//!   pipe-sweep [--full]                 cycles vs issue width (1/2/4) for
//!                                       cpubench + streaming kernels
//!                                       (CI captures --json as
//!                                       BENCH_pipeline.json)
//!   fig4 [--full] [--ratios]            adapted STREAM vs PicoRV32
//!   table1                              selected configuration
//!   table2                              DMIPS/CoreMark comparison
//!   fig5                                c1_merge semantics
//!   fig6                                pipeline trace of the chunk loop
//!   memcpy [--full]                     §4.1 headline rate
//!   sort-speedup [--full]               §4.3.1 sorting
//!   prefix-speedup [--full]             §4.3.2 prefix sum
//!   discussion                          §6 instruction/cycle reduction
//!   all [--full] [--markdown]           everything above
//!
//! tools:
//!   run <prog.s> [--trace] [--vlen N]   assemble + run a text program
//!   disasm <prog.s>                     assemble + disassemble
//!   fabric [--dir artifacts]            list + smoke-test the artifacts
//!   config                              print the Table-1 configuration
//! ```

use simdsoftcore::coordinator::sweep::{self, machine_grid, MachinePoint, Parallelism};
use simdsoftcore::coordinator::{experiments as exp, Scale, Table};
use simdsoftcore::core::{Core, Trace};
use simdsoftcore::fuzz::{self, FuzzConfig, OpWeights};
use simdsoftcore::service::{
    self, GridOptions, Job, JobKind, JobStatus, Progress, ResultStore, ServeConfig,
};
use simdsoftcore::workloads::{registry, Scenario, Variant};
use std::process::ExitCode;
use std::sync::Mutex;
use std::time::Duration;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let flags = Flags::new(&args[1..]);
    match dispatch(cmd, &flags) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(cmd: &str, flags: &Flags) -> Result<(), String> {
    // Sweep worker-pool bound: every sweep surface (run-workload grids,
    // experiment drivers, the fuzz campaign, the service queue) takes
    // this value explicitly — there is no process-global width.
    let jobs = match flags.parse_usize("--jobs")? {
        None => Parallelism::auto(),
        Some(0) => return Err("--jobs must be at least 1".into()),
        Some(n) => Parallelism::fixed(n),
    };
    let scale = Scale { full: flags.has("--full"), jobs };
    let json = flags.has("--json");
    // Render one experiment table in the selected format.
    let emit = |t: Table| {
        if json {
            println!("{}", t.render_json());
        } else {
            print!("{}", t.render());
        }
    };

    match cmd {
        "fig3" => {
            let side = flags.opt_val("--side")?.unwrap_or("both");
            if !["left", "right", "both"].contains(&side) {
                return Err(format!("--side must be left|right|both, got '{side}'"));
            }
            let mut tables = Vec::new();
            if side == "left" || side == "both" {
                tables.push(exp::fig3_left(scale));
            }
            if side == "right" || side == "both" {
                tables.push(exp::fig3_right(scale));
            }
            if json {
                // Always one parseable document: fig3 emits an array
                // (it can carry one or two tables depending on --side).
                println!("{}", Table::render_json_array(&tables));
            } else {
                for t in tables {
                    print!("{}", t.render());
                }
            }
            Ok(())
        }
        "fig4" => {
            if flags.has("--ratios") {
                emit(exp::fig4_ratios(scale));
            } else {
                emit(exp::fig4(scale));
            }
            Ok(())
        }
        "table1" | "config" => {
            emit(exp::table1());
            Ok(())
        }
        "table2" => {
            emit(exp::table2());
            Ok(())
        }
        "fig5" => {
            emit(exp::fig5());
            Ok(())
        }
        "fig6" => {
            if json {
                println!("{}", fig6_table().render_json());
            } else {
                print!("{}", exp::fig6());
            }
            Ok(())
        }
        "memcpy" => {
            emit(exp::memcpy_headline(scale));
            Ok(())
        }
        "mem-sweep" => {
            emit(exp::mem_sweep(scale));
            Ok(())
        }
        "pipe-sweep" => {
            emit(exp::pipe_sweep(scale));
            Ok(())
        }
        "sort-speedup" => {
            emit(exp::sec43_sort(scale));
            Ok(())
        }
        "prefix-speedup" => {
            emit(exp::sec43_prefix(scale));
            Ok(())
        }
        "discussion" => {
            emit(exp::discussion());
            Ok(())
        }
        "all" => {
            run_all(scale, flags.has("--markdown"), json);
            Ok(())
        }
        "run-workload" => run_workload(flags, json, jobs),
        "fuzz" => run_fuzz(flags, json, jobs),
        "analyze" => run_analyze(flags, json),
        "sched-bench" => run_sched_bench(flags, json),
        "compliance" => run_compliance(flags, json),
        "sweep-grid" => run_sweep_grid(flags, json, jobs),
        "serve" => run_serve(flags, jobs),
        "list-workloads" => {
            list_workloads();
            Ok(())
        }
        "run" => run_program(flags),
        "disasm" => disasm_program(flags),
        "fabric" => fabric_info(flags.opt_val("--dir")?),
        "--help" | "help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

fn usage() -> &'static str {
    "usage: simdsoftcore <run-workload|list-workloads|fuzz|analyze|sched-bench|compliance|\
     sweep-grid|serve|fig3|mem-sweep|pipe-sweep|fig4|table1|table2|fig5|fig6|memcpy|sort-speedup|\
     prefix-speedup|discussion|all|run|disasm|fabric|config> [options]\n\
     run-workload --elf FILE runs a prebuilt RV32 ELF binary (riscv-tests HTIF convention) with \
     a static-analyzer pre-flight (--no-analyze opts out); \
     compliance runs the checked-in rv32ui/rv32um suite on both backends\n\
     analyze --perf adds the static cycle cost model, analyze --schedule the cosim-verified \
     intra-block scheduler (both honour --width 1|2|4); sched-bench compares static estimate vs \
     measured vs post-schedule cycles; fuzz --sched round-trips every case through the scheduler\n\
     sweep axes for run-workload, fuzz and sweep-grid: variant, size, vlen, llc-block, mshrs, \
     prefetch, channels, issue-width; the --jobs N flag bounds every sweep worker pool\n\
     sweep-grid/serve run through the service queue: --store FILE.jsonl persists results and \
     serves completed points from cache on re-runs\n\
     see the header of rust/src/main.rs for details"
}

/// Command-line flags after the subcommand. `opt_val` is strict: a flag
/// that takes a value errors out when the value is missing (e.g. the
/// flag is the last argument) instead of being silently ignored.
struct Flags {
    args: Vec<String>,
}

impl Flags {
    fn new(args: &[String]) -> Self {
        Self { args: args.to_vec() }
    }

    fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    /// The value following `flag`, if the flag is present. Errors when
    /// the flag is given without a value.
    fn opt_val(&self, flag: &str) -> Result<Option<&str>, String> {
        match self.args.iter().position(|a| a == flag) {
            None => Ok(None),
            Some(i) => match self.args.get(i + 1) {
                Some(v) if !v.starts_with("--") => Ok(Some(v)),
                _ => Err(format!("flag '{flag}' requires a value\n{}", usage())),
            },
        }
    }

    /// Every value of a repeatable flag (e.g. `--sweep`), with the same
    /// missing-value check.
    fn opt_vals(&self, flag: &str) -> Result<Vec<&str>, String> {
        let mut out = Vec::new();
        for (i, a) in self.args.iter().enumerate() {
            if a == flag {
                match self.args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => out.push(v.as_str()),
                    _ => return Err(format!("flag '{flag}' requires a value\n{}", usage())),
                }
            }
        }
        Ok(out)
    }

    /// Positional arguments: everything that is not a flag or the value
    /// of one of `value_flags`.
    fn positional(&self, value_flags: &[&str]) -> Vec<&str> {
        let mut out = Vec::new();
        let mut skip = false;
        for a in &self.args {
            if skip {
                skip = false;
                continue;
            }
            if value_flags.contains(&a.as_str()) {
                skip = true;
                continue;
            }
            if !a.starts_with("--") {
                out.push(a.as_str());
            }
        }
        out
    }

    fn parse_usize(&self, flag: &str) -> Result<Option<usize>, String> {
        match self.opt_val(flag)? {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("flag '{flag}' needs an unsigned integer, got '{v}'")),
        }
    }
}

/// Fig. 6 is free-form text; for `--json` it is wrapped as a one-cell
/// table so every experiment subcommand honours the flag.
fn fig6_table() -> Table {
    let mut t = Table::new("Fig. 6: pipeline trace (free-form text)", &["trace"]);
    t.row(&[exp::fig6()]);
    t
}

fn run_all(scale: Scale, markdown: bool, json: bool) {
    let mut tables = vec![
        exp::table1(),
        exp::fig3_left(scale),
        exp::fig3_right(scale),
        exp::memcpy_headline(scale),
        exp::table2(),
        exp::fig4(scale),
        exp::fig4_ratios(scale),
        exp::fig5(),
        exp::sec43_sort(scale),
        exp::sec43_prefix(scale),
        exp::discussion(),
    ];
    if json {
        tables.push(fig6_table());
        println!("{}", Table::render_json_array(&tables));
        return;
    }
    for t in tables {
        if markdown {
            print!("{}", t.render_markdown());
        } else {
            println!("{}", t.render());
        }
    }
    if markdown {
        println!("### Fig. 6 trace\n\n```\n{}```\n", exp::fig6());
    } else {
        print!("{}", exp::fig6());
    }
}

fn list_workloads() {
    println!("registered workloads (run with: simdsoftcore run-workload <name>):");
    for entry in registry() {
        let w = entry.make();
        let variants: Vec<&str> = w.variants().iter().map(|v| v.name()).collect();
        println!(
            "  {:<14} [{}] {}  (default size {})",
            entry.name,
            variants.join(", "),
            w.description(),
            w.default_size(),
        );
    }
}

/// One point of a `run-workload` sweep grid: the machine-configuration
/// axes (from the [`simdsoftcore::coordinator::sweep::MachinePoint`]
/// axis registry) plus the workload-level variant/size axes.
#[derive(Debug, Clone, Copy)]
struct SweepPoint {
    variant: Variant,
    size: usize,
    mp: MachinePoint,
}

/// Reject configuration values the simulator cannot represent before
/// any thread is spawned (e.g. `--llc-block 0` would divide by zero in
/// the LLC geometry math; `--vlen 100` fails cache-config validation).
fn check_point(p: &SweepPoint) -> Result<(), String> {
    if p.size == 0 {
        return Err("size must be positive".into());
    }
    p.mp.validate()
}

/// Best-effort text of a caught panic payload.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "workload panicked".to_string()
    }
}

fn run_workload(flags: &Flags, json: bool, jobs: Parallelism) -> Result<(), String> {
    const VALUE_FLAGS: &[&str] = &[
        "--variant", "--size", "--vlen", "--llc-block", "--mshrs", "--prefetch", "--channels",
        "--issue-width", "--sweep", "--jobs", "--elf",
    ];
    // ELF mode: a prebuilt binary instead of a registry workload.
    if let Some(path) = flags.opt_val("--elf")? {
        return run_workload_elf(path, flags, json, jobs);
    }
    let positional = flags.positional(VALUE_FLAGS);
    let Some(&name) = positional.first() else {
        return Err(format!(
            "run-workload needs a workload name; try `simdsoftcore list-workloads`\n{}",
            usage()
        ));
    };
    let Some(probe) = simdsoftcore::workloads::lookup(name) else {
        let names: Vec<&str> = registry().iter().map(|e| e.name).collect();
        return Err(format!("unknown workload '{name}'; known: {}", names.join(", ")));
    };

    // Fixed-point defaults, overridable by --variant/--size and one flag
    // per machine axis (--vlen/--llc-block/--mshrs/--prefetch/--channels).
    let mut variants: Vec<Variant> = probe.variants().to_vec();
    if let Some(v) = flags.opt_val("--variant")? {
        let v = Variant::parse(v)
            .ok_or_else(|| format!("--variant must be scalar|vector, got '{v}'"))?;
        if !probe.variants().contains(&v) {
            return Err(format!("workload '{name}' has no {v} variant"));
        }
        variants = vec![v];
    }
    let mut base = MachinePoint::default();
    for &axis in MachinePoint::AXES {
        if let Some(v) = flags.parse_usize(&format!("--{axis}"))? {
            base.set(axis, v);
        }
    }
    let mut sizes = vec![flags.parse_usize("--size")?.unwrap_or_else(|| probe.default_size())];

    // Sweep axes replace the fixed point on their axis. Machine axes
    // come from the MachinePoint registry (expanded by `machine_grid`,
    // shared with the fuzz subcommand); variant/size are workload-level.
    let mut machine_specs: Vec<&str> = Vec::new();
    for spec in flags.opt_vals("--sweep")? {
        let (axis, vals) = spec
            .split_once('=')
            .ok_or_else(|| format!("--sweep expects axis=v1,v2,..., got '{spec}'"))?;
        match axis {
            "size" => {
                sizes = vals
                    .split(',')
                    .map(|v| {
                        v.trim()
                            .parse()
                            .map_err(|_| format!("bad size value '{v}' in --sweep {spec}"))
                    })
                    .collect::<Result<Vec<_>, _>>()?;
            }
            "variant" => {
                variants = vals
                    .split(',')
                    .map(|v| {
                        Variant::parse(v.trim())
                            .ok_or_else(|| format!("bad variant '{v}' in --sweep {spec}"))
                    })
                    .collect::<Result<Vec<_>, _>>()?;
            }
            axis if MachinePoint::is_axis(axis) => {
                machine_specs.push(spec);
            }
            other => {
                return Err(format!(
                    "unknown sweep axis '{other}' (axes: variant, size, {})",
                    MachinePoint::AXES.join(", ")
                ))
            }
        }
    }
    let grid = machine_grid(base, &machine_specs)?;

    // Cartesian grid, validated up front (bad widths/blocks are usage
    // errors, not panics inside sweep threads).
    let mut points = Vec::new();
    for &mp in &grid {
        for &size in &sizes {
            for &variant in &variants {
                let p = SweepPoint { variant, size, mp };
                check_point(&p)?;
                points.push(p);
            }
        }
    }
    // Executed on a bounded worker pool (a grid can be large; one
    // uncapped thread per point would oversubscribe the host).
    let results = sweep::parallel_map_bounded(points, jobs.workers(), |p| {
        // Workload-specific size constraints are assertions; contain
        // them to a failed row instead of a CLI abort.
        let run = std::panic::catch_unwind(|| {
            let mut w = simdsoftcore::workloads::lookup(name).expect("name checked above");
            p.mp.machine().run(&mut *w, &Scenario::new(p.variant, p.size))
        });
        let r = match run {
            Ok(r) => r.map_err(|e| e.to_string()),
            Err(panic) => Err(panic_message(&panic)),
        };
        (p, r)
    });

    let mut t = Table::new(
        format!("run-workload {name}"),
        &["variant", "VLEN", "LLC block", "MSHRs", "pf", "ch", "IW", "size", "cycles", "GB/s",
          "B/cycle", "cyc/elem", "IPC", "verified"],
    );
    let mut failed = false;
    for (p, r) in results {
        match r {
            Ok(r) => t.row(&[
                p.variant.to_string(),
                p.mp.vlen.to_string(),
                p.mp.llc_block.to_string(),
                p.mp.mshrs.to_string(),
                p.mp.prefetch.to_string(),
                p.mp.channels.to_string(),
                p.mp.issue_width.to_string(),
                p.size.to_string(),
                r.throughput.cycles.to_string(),
                format!("{:.3}", r.throughput.bytes_per_second() / 1e9),
                format!("{:.2}", r.throughput.bytes_per_cycle()),
                format!("{:.2}", r.cycles_per_elem()),
                format!("{:.3}", r.throughput.ipc()),
                r.verified_cell(),
            ]),
            Err(e) => {
                failed = true;
                t.note(format!(
                    "FAILED {} vlen={} llc-block={} mshrs={} prefetch={} channels={} \
                     issue-width={} size={}: {e}",
                    p.variant,
                    p.mp.vlen,
                    p.mp.llc_block,
                    p.mp.mshrs,
                    p.mp.prefetch,
                    p.mp.channels,
                    p.mp.issue_width,
                    p.size
                ));
            }
        }
    }
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }
    if failed {
        return Err("one or more sweep points failed (see notes above)".into());
    }
    Ok(())
}

/// `run-workload --elf FILE`: a prebuilt RV32 ELF binary (riscv-tests
/// HTIF convention, DESIGN.md §13) run over the machine-axis grid.
/// Workload-level sweep axes (variant/size) are meaningless for a fixed
/// binary and are rejected; `verified` means "the binary reported HTIF
/// pass", and any HTIF fail is a non-zero exit.
fn run_workload_elf(
    path: &str,
    flags: &Flags,
    json: bool,
    jobs: Parallelism,
) -> Result<(), String> {
    use simdsoftcore::loader::ElfWorkload;
    use simdsoftcore::workloads::Workload;
    let bytes = std::fs::read(path).map_err(|e| format!("reading {path}: {e}"))?;
    let stem = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("elf")
        .to_string();
    // Fail early on a bad image, before any sweep thread spawns — and
    // run the static analyzer as a pre-flight (same contract as
    // `compliance`): error-severity findings mean the binary faults or
    // never loads, so they abort before any timed run unless the user
    // opts out with --no-analyze.
    let preflight = ElfWorkload::from_bytes(&stem, &bytes).map_err(|e| format!("{path}: {e}"))?;
    if !flags.has("--no-analyze") {
        use simdsoftcore::analysis::{self, AnalysisConfig};
        let report = analysis::analyze_program(preflight.program(), &AnalysisConfig::default());
        if !report.is_clean() {
            eprint!("{path}: {}", report.render(0));
            return Err(format!(
                "{path}: the static analyzer found {} error-severity finding(s) before the \
                 timed run (pass --no-analyze to run anyway)",
                report.error_count()
            ));
        }
    }

    let mut base = MachinePoint::default();
    for &axis in MachinePoint::AXES {
        if let Some(v) = flags.parse_usize(&format!("--{axis}"))? {
            base.set(axis, v);
        }
    }
    let mut machine_specs: Vec<&str> = Vec::new();
    for spec in flags.opt_vals("--sweep")? {
        let (axis, _) = spec
            .split_once('=')
            .ok_or_else(|| format!("--sweep expects axis=v1,v2,..., got '{spec}'"))?;
        if !MachinePoint::is_axis(axis) {
            return Err(format!(
                "sweep axis '{axis}' does not apply to --elf (axes: {})",
                MachinePoint::AXES.join(", ")
            ));
        }
        machine_specs.push(spec);
    }
    let grid = machine_grid(base, &machine_specs)?;
    for mp in &grid {
        mp.validate()?;
    }

    let results = sweep::parallel_map_bounded(grid, jobs.workers(), |mp| {
        let run = ElfWorkload::from_bytes(&stem, &bytes)
            .map_err(|e| e.to_string())
            .and_then(|mut w| {
                let sc = Scenario::new(Variant::Scalar, w.default_size());
                mp.machine().run(&mut w, &sc).map_err(|e| e.to_string())
            });
        (mp, run)
    });

    let mut t = Table::new(
        format!("run-workload --elf {stem}"),
        &["VLEN", "LLC block", "MSHRs", "pf", "ch", "IW", "instret", "cycles", "IPC", "verified"],
    );
    let mut failed = false;
    let mut htif_failed = false;
    for (mp, r) in results {
        match r {
            Ok(r) => {
                if r.verified == Some(false) {
                    htif_failed = true;
                    t.note(format!(
                        "HTIF FAIL vlen={} llc-block={} mshrs={} prefetch={} channels={} \
                         issue-width={}: {}",
                        mp.vlen,
                        mp.llc_block,
                        mp.mshrs,
                        mp.prefetch,
                        mp.channels,
                        mp.issue_width,
                        r.verify_error.as_deref().unwrap_or("?")
                    ));
                }
                t.row(&[
                    mp.vlen.to_string(),
                    mp.llc_block.to_string(),
                    mp.mshrs.to_string(),
                    mp.prefetch.to_string(),
                    mp.channels.to_string(),
                    mp.issue_width.to_string(),
                    r.throughput.instret.to_string(),
                    r.throughput.cycles.to_string(),
                    format!("{:.3}", r.throughput.ipc()),
                    r.verified_cell(),
                ]);
            }
            Err(e) => {
                failed = true;
                t.note(format!(
                    "FAILED vlen={} llc-block={} mshrs={} prefetch={} channels={} \
                     issue-width={}: {e}",
                    mp.vlen, mp.llc_block, mp.mshrs, mp.prefetch, mp.channels, mp.issue_width
                ));
            }
        }
    }
    t.note(format!("verified = \"the binary reported HTIF pass\" ({path})"));
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }
    if failed {
        return Err("one or more machine points failed (see notes above)".into());
    }
    if htif_failed {
        return Err(format!("{path}: the binary reported HTIF fail (see notes above)"));
    }
    Ok(())
}

/// The `compliance` subcommand: every checked-in rv32ui/rv32um binary
/// (DESIGN.md §13) on the timed core AND the reference ISS, with the
/// static analyzer as a pre-flight. Exits non-zero on any failure, and
/// with a dedicated message when the two backends disagree on pass/fail
/// — the differential property the suite exists to check.
fn run_compliance(flags: &Flags, json: bool) -> Result<(), String> {
    use simdsoftcore::loader::compliance::{self, BackendOutcome};
    let dir = match flags.opt_val("--dir")? {
        Some(d) => std::path::PathBuf::from(d),
        None => compliance::default_dir(),
    };
    let report = compliance::run_suite(&dir)?;
    let mut t = Table::new(
        format!("compliance ({} binaries under {})", report.rows.len(), dir.display()),
        &["test", "core", "ref ISS", "core instret", "ISS instret", "analyzer errors", "agree"],
    );
    let cell = |o: &BackendOutcome| if o.pass { "pass".to_string() } else { "FAIL".to_string() };
    for r in &report.rows {
        t.row(&[
            r.name.clone(),
            cell(&r.core),
            cell(&r.iss),
            r.core.instret.to_string(),
            r.iss.instret.to_string(),
            r.analyzer_errors.to_string(),
            (!r.mismatch()).to_string(),
        ]);
        if !r.core.pass {
            t.note(format!("{} core: {}", r.name, r.core.detail));
        }
        if !r.iss.pass {
            t.note(format!("{} ISS: {}", r.name, r.iss.detail));
        }
    }
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }
    let mismatches = report.mismatches().count();
    if mismatches > 0 {
        return Err(format!(
            "{mismatches} binaries got a different pass/fail on the timed core vs the \
             reference ISS — the backends disagree about RV32IM architecture"
        ));
    }
    if !report.all_passed() {
        let failures: Vec<&str> = report.failures().map(|r| r.name.as_str()).collect();
        return Err(format!(
            "{} compliance failure(s): {}",
            failures.len(),
            failures.join(", ")
        ));
    }
    Ok(())
}

/// The `fuzz` subcommand: differential lockstep fuzzing of the timed
/// core against the reference ISS (DESIGN.md §9).
fn run_fuzz(flags: &Flags, json: bool, jobs: Parallelism) -> Result<(), String> {
    let seeds = flags.parse_usize("--seeds")?.unwrap_or(100) as u64;
    if seeds == 0 {
        return Err("--seeds must be at least 1".into());
    }
    let base_seed = flags.parse_usize("--base-seed")?.unwrap_or(1) as u64;
    let ops = flags.parse_usize("--ops")?.unwrap_or(300);
    if ops == 0 || ops > 50_000 {
        return Err(format!("--ops must be in 1..=50000, got {ops}"));
    }
    let weights = match flags.opt_val("--weights")? {
        Some(spec) => Some(OpWeights::parse(spec)?),
        None => None,
    };
    let sweeps = flags.opt_vals("--sweep")?;
    let points = if sweeps.is_empty() {
        // Default grid: the paper machine plus the stressed memory
        // configuration (non-blocking port, prefetch, 2 DRAM channels).
        vec![MachinePoint::default(), fuzz::stressed_point()]
    } else {
        machine_grid(MachinePoint::default(), &sweeps)?
    };
    for mp in &points {
        mp.validate()?;
    }

    let cfg = FuzzConfig {
        seeds,
        base_seed,
        ops,
        weights,
        points: points.clone(),
        jobs,
        analyze: flags.has("--analyze"),
        sched: flags.has("--sched"),
    };
    let summary = fuzz::run_campaign(&cfg);

    let mut t = Table::new("fuzz: lockstep differential campaign", &["metric", "value"]);
    t.row(&["seeds".into(), format!("{seeds} (base {base_seed})")]);
    t.row(&["ops/program".into(), ops.to_string()]);
    t.row(&[
        "op mix".into(),
        match &cfg.weights {
            Some(w) => format!("{w:?}"),
            None => "preset rotation (balanced / scalar / vector)".into(),
        },
    ]);
    t.row(&["analyzer pre-flight".into(), cfg.analyze.to_string()]);
    t.row(&["scheduler round-trip".into(), cfg.sched.to_string()]);
    for (i, mp) in points.iter().enumerate() {
        t.row(&[
            format!("machine[{i}]"),
            format!(
                "vlen={} llc-block={} mshrs={} prefetch={} channels={} issue-width={}",
                mp.vlen, mp.llc_block, mp.mshrs, mp.prefetch, mp.channels, mp.issue_width
            ),
        ]);
    }
    t.row(&["cases".into(), summary.cases.to_string()]);
    t.row(&["lockstep instructions".into(), summary.instrs.to_string()]);
    t.row(&["divergences".into(), summary.failures.len().to_string()]);
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }

    if summary.ok() {
        return Ok(());
    }
    // Persist triage artifacts (CI uploads these on failure).
    let dir = flags.opt_val("--artifact-dir")?.unwrap_or("fuzz-artifacts");
    std::fs::create_dir_all(dir).map_err(|e| format!("creating {dir}: {e}"))?;
    for f in summary.failures.iter().take(16) {
        let stem = format!(
            "{dir}/seed{}-vlen{}-llc{}-mshrs{}-pf{}-ch{}",
            f.seed,
            f.point.vlen,
            f.point.llc_block,
            f.point.mshrs,
            f.point.prefetch,
            f.point.channels
        );
        std::fs::write(format!("{stem}.s"), &f.listing)
            .map_err(|e| format!("writing {stem}.s: {e}"))?;
        let header = format!(
            "seed {} | ops {} | weights {} | vlen={} llc-block={} mshrs={} prefetch={} channels={}\n\n",
            f.seed,
            f.ops,
            f.weights_name,
            f.point.vlen,
            f.point.llc_block,
            f.point.mshrs,
            f.point.prefetch,
            f.point.channels
        );
        std::fs::write(format!("{stem}.report.txt"), format!("{header}{}", f.report))
            .map_err(|e| format!("writing {stem}.report.txt: {e}"))?;
        eprintln!("fuzz failure artifacts: {stem}.s, {stem}.report.txt");
    }
    Err(format!(
        "{} of {} fuzz cases diverged — artifacts in {dir}/ (replay one with: \
         simdsoftcore fuzz --seeds 1 --base-seed <seed> --ops {ops}, repeating your \
         --weights/--sweep flags; each .report.txt header records the op mix and \
         machine point of its case)",
        summary.failures.len(),
        summary.cases
    ))
}

/// The `analyze` subcommand: the static guest-program analyzer
/// (DESIGN.md §12). Runs CFG recovery + dataflow lints over every
/// registry workload (or one named workload, or a single assembled
/// `--listing FILE.s`), cross-checks the recovered block boundaries
/// against the reference-ISS block lowering, and exits non-zero when
/// any program draws an error-severity finding — which makes it a CI
/// gate over the whole registry.
fn run_analyze(flags: &Flags, json: bool) -> Result<(), String> {
    use simdsoftcore::analysis::{self, AnalysisConfig, PerfModel};
    let vlen = flags.parse_usize("--vlen")?.unwrap_or(256);
    MachinePoint { vlen, ..MachinePoint::default() }.validate()?;
    let dram_floor = simdsoftcore::mem::config::MemConfig::paper_default().dram.size_bytes;
    let width = flags.parse_usize("--width")?.unwrap_or(2);
    if ![1, 2, 4].contains(&width) {
        return Err(format!("--width must be 1, 2 or 4, got {width}"));
    }
    let want_perf = flags.has("--perf");
    let want_sched = flags.has("--schedule");
    // Timing parameters for the cost model / scheduler: the paper
    // machine at the requested VLEN and issue width. Flat memory is the
    // cycle-exact regime (DESIGN.md §12).
    let core_cfg = *MachinePoint { vlen, issue_width: width, ..MachinePoint::default() }
        .machine()
        .magic_memory(true)
        .core_config();
    let model = PerfModel::flat(core_cfg);

    // Single-listing mode: assemble and analyze one .s file.
    if let Some(path) = flags.opt_val("--listing")? {
        let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let prog = simdsoftcore::asm::assemble_text(&src).map_err(|e| e.to_string())?;
        let cfg = AnalysisConfig { vlen_bits: vlen, dram_bytes: dram_floor };
        let report = analysis::analyze_program(&prog, &cfg);
        if json {
            let mut t = Table::new("analyze (static lints)", &[
                "program", "VLEN", "blocks", "reachable", "instrs", "errors", "warnings",
            ]);
            t.row(&[
                path.to_string(),
                vlen.to_string(),
                report.blocks.to_string(),
                report.reachable_blocks.to_string(),
                report.instrs.to_string(),
                report.error_count().to_string(),
                report.warning_count().to_string(),
            ]);
            println!("{}", t.render_json());
        } else {
            print!("{path}: {}", report.render(50));
        }
        if want_perf {
            let perf = analysis::analyze_perf(&prog, &cfg, &model);
            let mut t = Table::new(
                format!("analyze --perf ({path}, issue width {width}, flat memory)"),
                &["block pc", "instrs", "min cyc", "max cyc", "exact", "stalls"],
            );
            for c in &perf.costs {
                t.row(&[
                    format!("{:#010x}", c.pc),
                    c.instrs.to_string(),
                    c.min_cycles.to_string(),
                    c.max_cycles.to_string(),
                    c.exact.to_string(),
                    c.events.len().to_string(),
                ]);
            }
            t.note(format!(
                "whole-program lower bound {} cycles (each reachable block once, clean entry, \
                 taken terminators)",
                perf.total_min_cycles()
            ));
            if json {
                println!("{}", t.render_json());
            } else {
                print!("{}", t.render());
                for f in &perf.findings {
                    println!("{f}");
                    for line in &f.context {
                        println!("    {line}");
                    }
                }
            }
        }
        if want_sched {
            // Listings are arbitrary programs: bound the equivalence
            // runs so a non-halting input fails fast as a watchdog
            // instead of wedging the CLI.
            const LISTING_SCHED_BUDGET: u64 = 10_000_000;
            let outcome = analysis::schedule_program(&prog, &cfg, &core_cfg);
            let total =
                |p: &simdsoftcore::asm::Program| -> u64 {
                    model.block_costs(p, &cfg).iter().map(|c| c.min_cycles).sum()
                };
            let verify = if outcome.changed() {
                analysis::verify_schedule(
                    &prog,
                    &outcome.program,
                    &[],
                    vlen,
                    dram_floor,
                    width,
                    LISTING_SCHED_BUDGET,
                )
            } else {
                Ok(())
            };
            let mut t = Table::new(
                format!("analyze --schedule ({path}, issue width {width})"),
                &["blocks changed", "instrs moved", "static min before", "after", "equivalent"],
            );
            t.row(&[
                outcome.blocks_changed.to_string(),
                outcome.instrs_moved.to_string(),
                total(&prog).to_string(),
                total(&outcome.program).to_string(),
                match &verify {
                    Ok(()) if outcome.changed() => "true".to_string(),
                    Ok(()) => "- (unchanged)".to_string(),
                    Err(_) => "FAIL".to_string(),
                },
            ]);
            if json {
                println!("{}", t.render_json());
            } else {
                print!("{}", t.render());
                if outcome.changed() && verify.is_ok() {
                    print!("{}", outcome.program.disassemble());
                }
            }
            if let Err(e) = verify {
                return Err(format!("{path}: scheduled program failed verification: {e}"));
            }
        }
        return if report.is_clean() {
            Ok(())
        } else {
            Err(format!("{path}: {} error-severity finding(s)", report.error_count()))
        };
    }

    // Registry mode: every workload x variant, or one named workload.
    const VALUE_FLAGS: &[&str] =
        &["--variant", "--size", "--vlen", "--listing", "--jobs", "--width"];
    let filter = flags.positional(VALUE_FLAGS).first().copied();
    let chosen_variant = match flags.opt_val("--variant")? {
        Some(v) => Some(
            Variant::parse(v).ok_or_else(|| format!("--variant must be scalar|vector, got '{v}'"))?,
        ),
        None => None,
    };
    if let Some(name) = filter {
        if simdsoftcore::workloads::lookup(name).is_none() {
            let names: Vec<&str> = registry().iter().map(|e| e.name).collect();
            return Err(format!("unknown workload '{name}'; known: {}", names.join(", ")));
        }
    }

    let mut t = Table::new("analyze (static lints over the workload registry)", &[
        "workload", "variant", "size", "VLEN", "blocks", "reachable", "instrs", "errors",
        "warnings", "cfg=iss", "ms",
    ]);
    let mut perf_t = Table::new(
        format!("analyze --perf (issue width {width}, flat memory)"),
        &["workload", "variant", "blocks costed", "exact", "static min cyc", "stall findings"],
    );
    let mut sched_t = Table::new(format!("analyze --schedule (issue width {width})"), &[
        "workload", "variant", "blocks changed", "instrs moved", "static min before", "after",
        "equivalent",
    ]);
    let mut total_errors = 0usize;
    let mut inconsistent = 0usize;
    let mut sched_failures: Vec<String> = Vec::new();
    let mut detail = String::new();
    for entry in registry() {
        if filter.is_some_and(|f| f != entry.name) {
            continue;
        }
        let mut w = entry.make();
        let size = flags.parse_usize("--size")?.unwrap_or_else(|| w.default_size());
        let variants: Vec<Variant> = match chosen_variant {
            Some(v) if w.variants().contains(&v) => vec![v],
            Some(_) => Vec::new(), // workload lacks the requested variant
            None => w.variants().to_vec(),
        };
        for variant in variants {
            let sc = Scenario::new(variant, size).with_vlen(vlen);
            let prog = w.build(&sc);
            let (bufs, bytes_each) = w.buffers(&sc);
            let dram = dram_floor.max(simdsoftcore::machine::dram_needed(bufs, bytes_each));
            let cfg = AnalysisConfig { vlen_bits: vlen, dram_bytes: dram };
            let t0 = std::time::Instant::now();
            let report = analysis::analyze_program(&prog, &cfg);
            let (_, graph) = analysis::recover_cfg(&prog, &cfg);
            let consistency = analysis::check_block_consistency(&prog, &graph);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            total_errors += report.error_count();
            if let Err(e) = &consistency {
                inconsistent += 1;
                t.note(format!("INCONSISTENT {}/{variant}: {e}", entry.name));
            }
            if report.error_count() > 0 || filter.is_some() {
                use std::fmt::Write;
                let _ = write!(detail, "== {}/{variant} ==\n{}", entry.name, report.render(10));
            }
            t.row(&[
                entry.name.to_string(),
                variant.to_string(),
                size.to_string(),
                vlen.to_string(),
                report.blocks.to_string(),
                report.reachable_blocks.to_string(),
                report.instrs.to_string(),
                report.error_count().to_string(),
                report.warning_count().to_string(),
                consistency.is_ok().to_string(),
                format!("{ms:.1}"),
            ]);
            if want_perf {
                let perf = analysis::analyze_perf(&prog, &cfg, &model);
                let exact = perf.costs.iter().filter(|c| c.exact).count();
                perf_t.row(&[
                    entry.name.to_string(),
                    variant.to_string(),
                    perf.costs.len().to_string(),
                    exact.to_string(),
                    perf.total_min_cycles().to_string(),
                    perf.findings.len().to_string(),
                ]);
                if filter.is_some() {
                    use std::fmt::Write;
                    for f in &perf.findings {
                        let _ = writeln!(detail, "{f}");
                        for line in &f.context {
                            let _ = writeln!(detail, "    {line}");
                        }
                    }
                }
            }
            if want_sched {
                let outcome = analysis::schedule_program(&prog, &cfg, &core_cfg);
                let verify = if outcome.changed() {
                    analysis::verify_schedule(
                        &prog,
                        &outcome.program,
                        w.init_image(),
                        vlen,
                        dram,
                        width,
                        simdsoftcore::workloads::common::MAX_INSTRS,
                    )
                } else {
                    Ok(())
                };
                let total = |p: &simdsoftcore::asm::Program| -> u64 {
                    model.block_costs(p, &cfg).iter().map(|c| c.min_cycles).sum()
                };
                sched_t.row(&[
                    entry.name.to_string(),
                    variant.to_string(),
                    outcome.blocks_changed.to_string(),
                    outcome.instrs_moved.to_string(),
                    total(&prog).to_string(),
                    total(&outcome.program).to_string(),
                    match &verify {
                        Ok(()) if outcome.changed() => "true".to_string(),
                        Ok(()) => "- (unchanged)".to_string(),
                        Err(_) => "FAIL".to_string(),
                    },
                ]);
                if let Err(e) = verify {
                    sched_failures.push(format!("{}/{variant}: {e}", entry.name));
                }
            }
        }
    }
    if json {
        println!("{}", t.render_json());
        if want_perf {
            println!("{}", perf_t.render_json());
        }
        if want_sched {
            println!("{}", sched_t.render_json());
        }
    } else {
        print!("{}", t.render());
        if want_perf {
            print!("{}", perf_t.render());
        }
        if want_sched {
            print!("{}", sched_t.render());
        }
        print!("{detail}");
    }
    if !sched_failures.is_empty() {
        return Err(format!(
            "the scheduled program failed equivalence verification for: {}",
            sched_failures.join("; ")
        ));
    }
    if total_errors > 0 || inconsistent > 0 {
        return Err(format!(
            "analysis found {total_errors} error-severity finding(s) and {inconsistent} \
             static-vs-ISS block-boundary disagreement(s)"
        ));
    }
    Ok(())
}

/// Dynamic-weighted static estimate: walk the program on the reference
/// ISS counting entries into each reachable CFG block, and charge each
/// entry the block's static flat-memory minimum cost. An estimate, not
/// a bound — per-block costs assume a clean entry state and taken
/// terminators (DESIGN.md §12).
fn static_estimate(
    prog: &simdsoftcore::asm::Program,
    init: &[(u32, Vec<u8>)],
    acfg: &simdsoftcore::analysis::AnalysisConfig,
    model: &simdsoftcore::analysis::PerfModel,
    max_instrs: u64,
) -> Result<u64, String> {
    use simdsoftcore::arch::ArchState;
    let mut min_by_pc = std::collections::HashMap::new();
    for c in model.block_costs(prog, acfg) {
        min_by_pc.insert(c.pc, c.min_cycles);
    }
    let mut iss = simdsoftcore::ref_iss::RefIss::new(acfg.vlen_bits, acfg.dram_bytes);
    iss.load(prog).map_err(|e| e.to_string())?;
    for (addr, bytes) in init {
        iss.host_write(*addr, bytes).map_err(|e| e.to_string())?;
    }
    let mut est = 0u64;
    let mut steps = 0u64;
    while !ArchState::halted(&iss) {
        if steps >= max_instrs {
            return Err(format!("static-estimate walk exceeded {max_instrs} instructions"));
        }
        if let Some(&c) = min_by_pc.get(&ArchState::pc(&iss)) {
            est += c;
        }
        iss.step().map_err(|e| e.to_string())?;
        steps += 1;
    }
    Ok(est)
}

/// Run `prog` to completion on a core built from `machine`, with `w`
/// providing the input image and the result oracle; returns the cycle
/// count.
fn measure_cycles(
    machine: &simdsoftcore::machine::Machine,
    w: &mut dyn simdsoftcore::workloads::Workload,
    prog: &simdsoftcore::asm::Program,
    max_instrs: u64,
) -> Result<u64, String> {
    let mut core = machine.build();
    core.load(prog).map_err(|e| e.to_string())?;
    w.init(&mut core);
    core.run(max_instrs).map_err(|e| e.to_string())?;
    core.mem.flush_all();
    w.verify(&core).map_err(|e| e.to_string())?;
    Ok(core.cycle())
}

/// The `sched-bench` subcommand: per-workload static cost-model
/// estimate vs measured cycles vs post-schedule measured cycles on the
/// flat-memory (magic) core at issue widths 1/2/4 — CI captures --json
/// as BENCH_sched.json. Every reordered program must prove equivalence
/// (final-state compare + lockstep cosim via `analysis::verify_schedule`);
/// any verification failure is a non-zero exit.
fn run_sched_bench(flags: &Flags, json: bool) -> Result<(), String> {
    use simdsoftcore::analysis::{self, AnalysisConfig, PerfModel};
    use simdsoftcore::workloads::common::MAX_INSTRS;
    const VALUE_FLAGS: &[&str] = &["--variant", "--size", "--vlen", "--jobs"];
    let filter = flags.positional(VALUE_FLAGS).first().copied();
    let vlen = flags.parse_usize("--vlen")?.unwrap_or(256);
    MachinePoint { vlen, ..MachinePoint::default() }.validate()?;
    let chosen_variant = match flags.opt_val("--variant")? {
        Some(v) => Some(
            Variant::parse(v).ok_or_else(|| format!("--variant must be scalar|vector, got '{v}'"))?,
        ),
        None => None,
    };
    if let Some(name) = filter {
        if simdsoftcore::workloads::lookup(name).is_none() {
            let names: Vec<&str> = registry().iter().map(|e| e.name).collect();
            return Err(format!("unknown workload '{name}'; known: {}", names.join(", ")));
        }
    }
    let dram_floor = simdsoftcore::mem::config::MemConfig::paper_default().dram.size_bytes;

    let mut t = Table::new(
        "sched-bench: static estimate vs measured vs post-schedule cycles (flat memory)",
        &[
            "workload", "variant", "size", "IW", "est cyc", "cycles", "sched cyc", "saved %",
            "moved", "verified",
        ],
    );
    let mut failures: Vec<String> = Vec::new();
    for entry in registry() {
        if filter.is_some_and(|f| f != entry.name) {
            continue;
        }
        let mut w = entry.make();
        let size = flags.parse_usize("--size")?.unwrap_or_else(|| w.default_size());
        let variants: Vec<Variant> = match chosen_variant {
            Some(v) if w.variants().contains(&v) => vec![v],
            Some(_) => Vec::new(),
            None => w.variants().to_vec(),
        };
        for variant in variants {
            let sc = Scenario::new(variant, size).with_vlen(vlen);
            let prog = w.build(&sc);
            let (bufs, bytes_each) = w.buffers(&sc);
            let dram = dram_floor.max(simdsoftcore::machine::dram_needed(bufs, bytes_each));
            let acfg = AnalysisConfig { vlen_bits: vlen, dram_bytes: dram };
            for width in [1usize, 2, 4] {
                let machine = MachinePoint { vlen, issue_width: width, ..MachinePoint::default() }
                    .machine()
                    .magic_memory(true)
                    .dram_bytes(dram);
                let core_cfg = *machine.core_config();
                let model = PerfModel::flat(core_cfg);
                let est = static_estimate(&prog, w.init_image(), &acfg, &model, MAX_INSTRS)
                    .map_err(|e| format!("{}/{variant} IW{width}: {e}", entry.name))?;
                let cycles = measure_cycles(&machine, w.as_mut(), &prog, MAX_INSTRS)
                    .map_err(|e| format!("{}/{variant} IW{width}: {e}", entry.name))?;
                let outcome = analysis::schedule_program(&prog, &acfg, &core_cfg);
                let (sched_cycles, verified) = if outcome.changed() {
                    match measure_cycles(&machine, w.as_mut(), &outcome.program, MAX_INSTRS) {
                        Ok(c) => {
                            let v = analysis::verify_schedule(
                                &prog,
                                &outcome.program,
                                w.init_image(),
                                vlen,
                                dram,
                                width,
                                MAX_INSTRS,
                            );
                            (c, v)
                        }
                        Err(e) => (0, Err(format!("scheduled run failed: {e}"))),
                    }
                } else {
                    (cycles, Ok(()))
                };
                if let Err(e) = &verified {
                    failures.push(format!("{}/{variant} IW{width}: {e}", entry.name));
                }
                let saved = if cycles > 0 {
                    100.0 * (cycles as f64 - sched_cycles as f64) / cycles as f64
                } else {
                    0.0
                };
                t.row(&[
                    entry.name.to_string(),
                    variant.to_string(),
                    size.to_string(),
                    width.to_string(),
                    est.to_string(),
                    cycles.to_string(),
                    sched_cycles.to_string(),
                    format!("{saved:.1}"),
                    outcome.instrs_moved.to_string(),
                    match &verified {
                        Ok(()) if outcome.changed() => "true".to_string(),
                        Ok(()) => "- (unchanged)".to_string(),
                        Err(_) => "FAIL".to_string(),
                    },
                ]);
            }
        }
    }
    t.note(
        "est cyc = sum over the run of (block entries x static flat-memory block minimum); \
         cycles measured on the magic-memory core; saved % = measured reduction after \
         intra-block scheduling",
    );
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(format!("scheduler verification failed for: {}", failures.join("; ")))
    }
}

/// The `sweep-grid` subcommand: run a workload grid through the sweep
/// service queue (DESIGN.md §10). With `--store` the grid is resumable:
/// completed points are served from the content-addressed result store,
/// so re-running after a crash (or a second identical invocation) only
/// simulates what is missing.
fn run_sweep_grid(flags: &Flags, json: bool, jobs: Parallelism) -> Result<(), String> {
    const VALUE_FLAGS: &[&str] = &[
        "--variant", "--size", "--sweep", "--jobs", "--store", "--shards", "--shard",
        "--timeout-ms", "--retries", "--budget",
    ];
    let names = flags.positional(VALUE_FLAGS);
    if names.is_empty() {
        return Err(format!(
            "sweep-grid needs at least one workload name; try `simdsoftcore list-workloads`\n{}",
            usage()
        ));
    }
    let variant = match flags.opt_val("--variant")? {
        Some(v) => Some(
            Variant::parse(v).ok_or_else(|| format!("--variant must be scalar|vector, got '{v}'"))?,
        ),
        None => None,
    };
    let size = flags.parse_usize("--size")?;
    let budget = flags.parse_usize("--budget")?.map(|b| b as u64);
    let sweeps = flags.opt_vals("--sweep")?;
    let grid = machine_grid(MachinePoint::default(), &sweeps)?;

    let mut grid_jobs = Vec::new();
    for &name in &names {
        let Some(probe) = simdsoftcore::workloads::lookup(name) else {
            let known: Vec<&str> = registry().iter().map(|e| e.name).collect();
            return Err(format!("unknown workload '{name}'; known: {}", known.join(", ")));
        };
        let variants: Vec<Variant> = match variant {
            Some(v) => vec![v],
            None => probe.variants().to_vec(),
        };
        let sz = size.unwrap_or_else(|| probe.default_size());
        for &mp in &grid {
            for &v in &variants {
                let mut job = Job::sim(mp, name, v, sz);
                job.budget = budget;
                job.validate()?;
                grid_jobs.push(job);
            }
        }
    }
    // Deterministic shard selection: independent processes given the
    // same grid and --shards N partition it without coordination.
    if let Some(shards) = flags.parse_usize("--shards")? {
        let shard = flags.parse_usize("--shard")?.unwrap_or(0);
        if shard >= shards.max(1) {
            return Err(format!("--shard {shard} out of range for --shards {shards}"));
        }
        grid_jobs = service::shard_filter(grid_jobs, shard as u64, shards as u64);
    }

    let store = match flags.opt_val("--store")? {
        Some(path) => ResultStore::open(path)?,
        None => ResultStore::in_memory(),
    };
    let opts = GridOptions {
        parallelism: jobs,
        timeout: flags.parse_usize("--timeout-ms")?.map(|ms| Duration::from_millis(ms as u64)),
        retries: flags.parse_usize("--retries")?.unwrap_or(1) as u32,
        stop_after: None,
    };
    let progress = Progress::new(grid_jobs.len() as u64);
    let store = Mutex::new(store);
    let recs =
        service::run_grid(grid_jobs, &store, &progress, &opts, &service::default_exec(), |_| {});
    let store = store.into_inner().expect("store lock");
    let snap = progress.snapshot();

    let mut t = Table::new(
        "sweep-grid (service queue)",
        &["workload", "variant", "size", "VLEN", "LLC block", "MSHRs", "pf", "ch", "IW",
          "cycles", "GB/s", "IPC", "verified", "status", "attempts", "cached"],
    );
    let mut failed = 0usize;
    for rec in recs.into_iter().flatten() {
        let JobKind::Sim { workload, variant, size } = &rec.job.kind else {
            continue; // sweep-grid only submits sim jobs
        };
        let (cycles, gbs, ipc, verified) = match &rec.outcome {
            Some(o) => (
                o.cycles.to_string(),
                format!("{:.3}", o.bytes_per_second() / 1e9),
                format!("{:.3}", o.ipc()),
                match o.verified {
                    Some(v) => v.to_string(),
                    None => "-".to_string(),
                },
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        if rec.status == JobStatus::Failed {
            failed += 1;
            t.note(format!("FAILED {}: {}", rec.job.label(), rec.error.as_deref().unwrap_or("?")));
        }
        let mp = &rec.job.point;
        t.row(&[
            workload.clone(),
            variant.to_string(),
            size.to_string(),
            mp.vlen.to_string(),
            mp.llc_block.to_string(),
            mp.mshrs.to_string(),
            mp.prefetch.to_string(),
            mp.channels.to_string(),
            mp.issue_width.to_string(),
            cycles,
            gbs,
            ipc,
            verified,
            rec.status.name().to_string(),
            rec.attempts.to_string(),
            rec.from_cache.to_string(),
        ]);
    }
    t.note(format!(
        "store: {} records ({} ok), {} cache hits this run, {} torn lines skipped",
        store.len(),
        store.completed(),
        snap.cached,
        store.skipped_lines()
    ));
    if json {
        println!("{}", t.render_json());
    } else {
        print!("{}", t.render());
    }
    if flags.has("--expect-all-cached") && snap.cached < snap.total {
        return Err(format!(
            "--expect-all-cached: only {}/{} points were served from the store",
            snap.cached, snap.total
        ));
    }
    if failed > 0 {
        return Err(format!("{failed} sweep points failed (see notes above)"));
    }
    Ok(())
}

/// The `serve` subcommand: the long-running sweep service. Speaks the
/// line-delimited JSON protocol (rust/src/service/server.rs) over stdio
/// by default, or over a TCP socket with `--listen ADDR`.
fn run_serve(flags: &Flags, jobs: Parallelism) -> Result<(), String> {
    let store = match flags.opt_val("--store")? {
        Some(path) => ResultStore::open(path)?,
        None => ResultStore::in_memory(),
    };
    let cfg = ServeConfig {
        parallelism: jobs,
        timeout: flags.parse_usize("--timeout-ms")?.map(|ms| Duration::from_millis(ms as u64)),
        retries: flags.parse_usize("--retries")?.unwrap_or(1) as u32,
    };
    match flags.opt_val("--listen")? {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .map_err(|e| format!("binding {addr}: {e}"))?;
            let local = listener.local_addr().map_err(|e| e.to_string())?;
            eprintln!("serving line-delimited JSON on {local} (store: {:?})", store.path());
            service::serve_tcp(&listener, store, &cfg);
        }
        None => {
            let stdin = std::io::stdin();
            service::serve(stdin.lock(), std::io::stdout(), store, &cfg);
        }
    }
    Ok(())
}

fn run_program(flags: &Flags) -> Result<(), String> {
    let path = *flags
        .positional(&["--vlen", "--jobs"])
        .first()
        .ok_or("run needs a .s file argument")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = simdsoftcore::asm::assemble_text(&src).map_err(|e| e.to_string())?;
    let vlen: usize = flags.parse_usize("--vlen")?.unwrap_or(256);
    let mut core = Core::for_vlen(vlen);
    if flags.has("--trace") {
        core.trace = Trace::full();
    }
    core.load(&prog).map_err(|e| e.to_string())?;
    let run = core.run(1_000_000_000).map_err(|e| e.to_string())?;
    println!(
        "halted: {} instructions, {} cycles (IPC {:.3})",
        run.instret,
        run.cycles,
        run.ipc()
    );
    println!("{}", core.mem.stats().report());
    // Dump argument registers (a0..a3) — program outputs by convention.
    use simdsoftcore::isa::reg::*;
    for (name, r) in [("a0", A0), ("a1", A1), ("a2", A2), ("a3", A3)] {
        println!("  {name} = {:#010x} ({})", core.reg(r), core.reg(r) as i32);
    }
    if flags.has("--trace") {
        println!("{}", core.trace.render_pipeline());
    }
    Ok(())
}

fn disasm_program(flags: &Flags) -> Result<(), String> {
    let path = *flags
        .positional(&["--jobs"])
        .first()
        .ok_or("disasm needs a .s file argument")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = simdsoftcore::asm::assemble_text(&src).map_err(|e| e.to_string())?;
    print!("{}", prog.disassemble());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn fabric_info(dir: Option<&str>) -> Result<(), String> {
    use simdsoftcore::runtime::Fabric;
    let dir = dir
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Fabric::default_dir);
    if !Fabric::available(&dir) {
        return Err(format!("no artifacts at {dir:?}; run `make artifacts`"));
    }
    let mut fabric = Fabric::open(&dir).map_err(|e| format!("{e:#}"))?;
    println!("fabric at {:?} (lanes = {}):", fabric.dir(), fabric.lanes);
    for name in fabric.names() {
        println!("  {name}");
    }
    // Smoke test: sort a vector through the fabric.
    let lanes = fabric.lanes;
    let vals: Vec<i32> = (0..lanes as i32).rev().collect();
    let sorted = fabric.sort_rows(&vals, 1).map_err(|e| format!("{e:#}"))?;
    println!("smoke: sort{lanes} {vals:?} -> {sorted:?}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn fabric_info(dir: Option<&str>) -> Result<(), String> {
    use simdsoftcore::runtime;
    let dir = dir
        .map(std::path::PathBuf::from)
        .unwrap_or_else(runtime::default_artifact_dir);
    let state = if runtime::artifacts_available(&dir) { "present" } else { "absent" };
    Err(format!(
        "this binary was built without the 'pjrt' feature (artifacts {state} at {dir:?}); \
         rebuild with `cargo build --features pjrt` to load the fabric"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> Flags {
        Flags::new(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn opt_val_returns_present_value() {
        let f = flags(&["--side", "left", "--full"]);
        assert_eq!(f.opt_val("--side").unwrap(), Some("left"));
        assert_eq!(f.opt_val("--vlen").unwrap(), None);
        assert!(f.has("--full"));
    }

    #[test]
    fn opt_val_rejects_flag_as_last_argument() {
        // Regression: `simdsoftcore fig3 --side` used to silently behave
        // like no --side at all; it must be a usage error.
        let f = flags(&["--full", "--side"]);
        let err = f.opt_val("--side").unwrap_err();
        assert!(err.contains("'--side' requires a value"), "{err}");
    }

    #[test]
    fn opt_val_rejects_flag_followed_by_flag() {
        let f = flags(&["--side", "--full"]);
        assert!(f.opt_val("--side").is_err());
    }

    #[test]
    fn opt_vals_collects_repeats_and_checks_values() {
        let f = flags(&["--sweep", "vlen=128,256", "--sweep", "size=1024"]);
        assert_eq!(f.opt_vals("--sweep").unwrap(), vec!["vlen=128,256", "size=1024"]);
        let f = flags(&["--sweep", "vlen=128", "--sweep"]);
        assert!(f.opt_vals("--sweep").is_err());
    }

    #[test]
    fn positional_skips_flag_values() {
        let f = flags(&["--vlen", "512", "prog.s", "--trace"]);
        assert_eq!(f.positional(&["--vlen"]), vec!["prog.s"]);
        // A sweep value like `vlen=128,256` must not look positional.
        let f = flags(&["memcpy", "--sweep", "vlen=128,256"]);
        assert_eq!(f.positional(&["--sweep"]), vec!["memcpy"]);
    }

    #[test]
    fn parse_usize_validates() {
        let f = flags(&["--vlen", "512"]);
        assert_eq!(f.parse_usize("--vlen").unwrap(), Some(512));
        let f = flags(&["--vlen", "lots"]);
        assert!(f.parse_usize("--vlen").is_err());
    }
}
