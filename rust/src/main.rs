//! `simdsoftcore` — CLI for the softcore framework: run programs on the
//! simulated core, regenerate every table/figure of the paper, inspect
//! the fabric artifacts.
//!
//! ```text
//! simdsoftcore <command> [options]
//!
//! experiments:
//!   fig3 [--side left|right] [--full]   memcpy design-space sweeps
//!   fig4 [--full] [--ratios]            adapted STREAM vs PicoRV32
//!   table1                              selected configuration
//!   table2                              DMIPS/CoreMark comparison
//!   fig5                                c1_merge semantics
//!   fig6                                pipeline trace of the chunk loop
//!   memcpy [--full]                     §4.1 headline rate
//!   sort-speedup [--full]               §4.3.1 sorting
//!   prefix-speedup [--full]             §4.3.2 prefix sum
//!   discussion                          §6 instruction/cycle reduction
//!   all [--full] [--markdown]           everything above
//!
//! tools:
//!   run <prog.s> [--trace] [--vlen N]   assemble + run a text program
//!   disasm <prog.s>                     assemble + disassemble
//!   fabric [--dir artifacts]            list + smoke-test the artifacts
//!   config                              print the Table-1 configuration
//! ```

use simdsoftcore::coordinator::{experiments as exp, Scale};
use simdsoftcore::core::{Core, Trace};
use simdsoftcore::runtime::Fabric;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let flags: Vec<&str> = args[1..].iter().map(|s| s.as_str()).collect();
    let has = |f: &str| flags.contains(&f);
    let opt_val = |f: &str| -> Option<&str> {
        flags.iter().position(|&a| a == f).and_then(|i| flags.get(i + 1).copied())
    };
    let scale = Scale { full: has("--full") };

    let result: Result<(), String> = match cmd.as_str() {
        "fig3" => {
            let side = opt_val("--side").unwrap_or("both");
            if side == "left" || side == "both" {
                print!("{}", exp::fig3_left(scale).render());
            }
            if side == "right" || side == "both" {
                print!("{}", exp::fig3_right(scale).render());
            }
            Ok(())
        }
        "fig4" => {
            if has("--ratios") {
                print!("{}", exp::fig4_ratios(scale).render());
            } else {
                print!("{}", exp::fig4(scale).render());
            }
            Ok(())
        }
        "table1" | "config" => {
            print!("{}", exp::table1().render());
            Ok(())
        }
        "table2" => {
            print!("{}", exp::table2().render());
            Ok(())
        }
        "fig5" => {
            print!("{}", exp::fig5().render());
            Ok(())
        }
        "fig6" => {
            print!("{}", exp::fig6());
            Ok(())
        }
        "memcpy" => {
            print!("{}", exp::memcpy_headline(scale).render());
            Ok(())
        }
        "sort-speedup" => {
            print!("{}", exp::sec43_sort(scale).render());
            Ok(())
        }
        "prefix-speedup" => {
            print!("{}", exp::sec43_prefix(scale).render());
            Ok(())
        }
        "discussion" => {
            print!("{}", exp::discussion().render());
            Ok(())
        }
        "all" => {
            run_all(scale, has("--markdown"));
            Ok(())
        }
        "run" => run_program(&flags),
        "disasm" => disasm_program(&flags),
        "fabric" => fabric_info(opt_val("--dir")),
        "--help" | "help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    };

    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> &'static str {
    "usage: simdsoftcore <fig3|fig4|table1|table2|fig5|fig6|memcpy|sort-speedup|prefix-speedup|discussion|all|run|disasm|fabric|config> [options]\n\
     see the header of rust/src/main.rs for details"
}

fn run_all(scale: Scale, markdown: bool) {
    let tables = vec![
        exp::table1(),
        exp::fig3_left(scale),
        exp::fig3_right(scale),
        exp::memcpy_headline(scale),
        exp::table2(),
        exp::fig4(scale),
        exp::fig4_ratios(scale),
        exp::fig5(),
        exp::sec43_sort(scale),
        exp::sec43_prefix(scale),
        exp::discussion(),
    ];
    for t in tables {
        if markdown {
            print!("{}", t.render_markdown());
        } else {
            println!("{}", t.render());
        }
    }
    if markdown {
        println!("### Fig. 6 trace\n\n```\n{}```\n", exp::fig6());
    } else {
        print!("{}", exp::fig6());
    }
}

fn run_program(flags: &[&str]) -> Result<(), String> {
    let path = flags
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or("run needs a .s file argument")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = simdsoftcore::asm::assemble_text(&src).map_err(|e| e.to_string())?;
    let vlen: usize = flags
        .iter()
        .position(|&a| a == "--vlen")
        .and_then(|i| flags.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let mut core = Core::for_vlen(vlen);
    if flags.contains(&"--trace") {
        core.trace = Trace::full();
    }
    core.load(&prog);
    let run = core.run(1_000_000_000).map_err(|e| e.to_string())?;
    println!(
        "halted: {} instructions, {} cycles (IPC {:.3})",
        run.instret,
        run.cycles,
        run.ipc()
    );
    println!("{}", core.mem.stats().report());
    // Dump argument registers (a0..a3) — program outputs by convention.
    use simdsoftcore::isa::reg::*;
    for (name, r) in [("a0", A0), ("a1", A1), ("a2", A2), ("a3", A3)] {
        println!("  {name} = {:#010x} ({})", core.reg(r), core.reg(r) as i32);
    }
    if flags.contains(&"--trace") {
        println!("{}", core.trace.render_pipeline());
    }
    Ok(())
}

fn disasm_program(flags: &[&str]) -> Result<(), String> {
    let path = flags
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or("disasm needs a .s file argument")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let prog = simdsoftcore::asm::assemble_text(&src).map_err(|e| e.to_string())?;
    print!("{}", prog.disassemble());
    Ok(())
}

fn fabric_info(dir: Option<&str>) -> Result<(), String> {
    let dir = dir
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Fabric::default_dir);
    if !Fabric::available(&dir) {
        return Err(format!("no artifacts at {dir:?}; run `make artifacts`"));
    }
    let mut fabric = Fabric::open(&dir).map_err(|e| format!("{e:#}"))?;
    println!("fabric at {:?} (lanes = {}):", fabric.dir(), fabric.lanes);
    for name in fabric.names() {
        println!("  {name}");
    }
    // Smoke test: sort a vector through the fabric.
    let lanes = fabric.lanes;
    let vals: Vec<i32> = (0..lanes as i32).rev().collect();
    let sorted = fabric.sort_rows(&vals, 1).map_err(|e| format!("{e:#}"))?;
    println!("smoke: sort{lanes} {vals:?} -> {sorted:?}");
    Ok(())
}
