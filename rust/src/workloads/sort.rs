//! Sorting (§4.3.1): the paper's flagship custom-SIMD use case.
//!
//! - **Baseline**: `qsort()` from C's standard library, modelled as an
//!   iterative Hoare quicksort whose every comparison goes through an
//!   indirect comparator call (`jalr` + compare + `ret`) — the defining
//!   cost of the libc interface.
//! - **Vector mergesort**: the paper's algorithm — first sort 2·L-element
//!   chunks with two `c2_sort` calls and one `c1_merge` (the Fig. 6
//!   loop), then log₂(N/2L) merge passes where each step merges two
//!   sorted vectors with `c1_merge`, retires the low half and refills
//!   from whichever run has the smaller head (the intrinsics merge
//!   algorithm of Chhugani et al. [8], in hardware).
//!
//! Input sizes must be a power of two ≥ 4 lanes (the paper's 64 MiB
//! input is 2²⁴ elements).

use super::common::{i32s_to_bytes, layout_buffers, random_i32s, read_i32s, Throughput};
use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Build the qsort() model: sort `n` i32 values at `base` in place.
///
/// Faithful to the libc interface the paper benchmarks against: the
/// comparator receives **pointers** (`int cmp(const void *a, const void
/// *b)`), so every comparison pays an indirect call, two pointer loads
/// inside the comparator and the call/return overhead — the defining
/// cost of `qsort()` vs an inlined sort. The pivot is spilled to a stack
/// slot so its address can be passed, as element comparisons in glibc's
/// quicksort compare against an element in memory.
pub fn build_qsort(base: u32, n: usize) -> Program {
    assert!(n >= 2);
    let mut a = Asm::new();
    let outer = a.new_label("outer");
    let done = a.new_label("done");
    let skip = a.new_label("skip");
    let part_loop = a.new_label("part_loop");
    let inc_i = a.new_label("inc_i");
    let dec_j = a.new_label("dec_j");
    let split = a.new_label("split");
    let cmp = a.new_label("cmp");

    // Stack discipline: s11 holds the empty-stack sentinel sp value.
    // s10 holds the address of the spilled pivot (a fixed stack slot).
    a.mv(S11, SP);
    a.addi(SP, SP, -16);
    a.mv(S10, SP); // &pivot
    // s7 = comparator function pointer (qsort's callback argument).
    let cmp_ref = cmp;
    a.la(S7, cmp_ref);
    // push (lo = base, hi = base + 4*(n-1))
    a.li(T0, base as i64);
    a.li(T1, (base as i64) + 4 * (n as i64 - 1));
    a.addi(SP, SP, -8);
    a.sw(T0, 0, SP);
    a.sw(T1, 4, SP);

    a.bind(outer);
    a.beq(SP, S11, done);
    a.lw(S0, 0, SP); // lo
    a.lw(S1, 4, SP); // hi
    a.addi(SP, SP, 8);
    a.bgeu(S0, S1, skip); // ranges of size <= 1 are sorted

    // pivot = *(lo + (((hi - lo) / 8) * 4))  — middle element, spilled
    // to the stack slot so comparisons can take its address.
    a.sub(T0, S1, S0);
    a.srli(T0, T0, 3);
    a.slli(T0, T0, 2);
    a.add(T0, T0, S0);
    a.lw(T1, 0, T0);
    a.sw(T1, 0, S10);
    a.addi(S2, S0, -4); // i = lo - 4
    a.addi(S3, S1, 4); // j = hi + 4

    a.bind(part_loop);
    a.bind(inc_i);
    a.addi(S2, S2, 4);
    a.mv(A0, S2); // &arr[i]
    a.mv(A1, S10); // &pivot
    a.jalr(RA, S7, 0); // indirect call through the comparator pointer
    a.bltz(A0, inc_i);
    a.bind(dec_j);
    a.addi(S3, S3, -4);
    a.mv(A0, S3);
    a.mv(A1, S10);
    a.jalr(RA, S7, 0);
    a.bgtz(A0, dec_j);
    a.bgeu(S2, S3, split);
    // swap *i, *j
    a.lw(T0, 0, S2);
    a.lw(T1, 0, S3);
    a.sw(T1, 0, S2);
    a.sw(T0, 0, S3);
    a.j(part_loop);

    a.bind(split);
    // push (lo, j) and (j+4, hi)
    a.addi(SP, SP, -16);
    a.sw(S0, 0, SP);
    a.sw(S3, 4, SP);
    a.addi(T0, S3, 4);
    a.sw(T0, 8, SP);
    a.sw(S1, 12, SP);
    a.bind(skip);
    a.j(outer);

    a.bind(done);
    a.halt();

    // int cmp(const void *a, const void *b) {
    //   int x = *(int*)a, y = *(int*)b; return (x > y) - (x < y);
    // }
    a.bind(cmp);
    a.lw(T2, 0, A0);
    a.lw(T3, 0, A1);
    a.slt(T0, T2, T3);
    a.slt(T1, T3, T2);
    a.sub(A0, T1, T0);
    a.ret();

    a.assemble().expect("qsort assembles")
}

/// Metadata of an assembled vector mergesort.
pub struct MergesortProgram {
    pub program: Program,
    /// Where the sorted output lands (src or scratch, by pass parity).
    pub result_base: u32,
    pub passes: u32,
}

/// Build the vector mergesort: sort `n` i32 values at `src` using
/// `scratch` as the ping-pong buffer.
pub fn build_vector_mergesort(
    src: u32,
    scratch: u32,
    n: usize,
    vlen_bits: usize,
) -> MergesortProgram {
    let lanes = vlen_bits / 32;
    let vb = (vlen_bits / 8) as i32; // vector bytes
    assert!(n.is_power_of_two() && n >= 4 * lanes, "n must be a power of two >= 4*lanes");
    let total_bytes = (n * 4) as i64;
    let chunk_bytes = 2 * vb; // sort-in-chunks granule (2 vectors)
    let passes = (n / (2 * lanes)).trailing_zeros();

    let mut a = Asm::new();

    // ---- phase 1: sort in chunks of 2 vectors (Fig. 6 loop) ------------
    a.li(S8, src as i64); // current source base
    a.li(S9, scratch as i64); // current destination base
    a.li(A2, 0); // offset
    a.li(A3, total_bytes);
    let chunk = a.here("chunk_loop");
    a.lv(V1, S8, A2);
    a.addi(T0, A2, vb);
    a.lv(V2, S8, T0);
    a.sort8(V1, V1);
    a.sort8(V2, V2);
    a.merge(V1, V2, V1, V2);
    a.sv(V1, S8, A2);
    a.sv(V2, S8, T0);
    a.addi(A2, A2, chunk_bytes);
    a.bne(A2, A3, chunk);

    // ---- phase 2: merge passes ------------------------------------------
    // s10 = run length in bytes, doubling each pass.
    a.li(S10, chunk_bytes as i64);
    let pass_loop = a.new_label("pass_loop");
    let pass_done = a.new_label("pass_done");
    a.bind(pass_loop);
    a.bge(S10, A3, pass_done); // run length == total → sorted

    // One pass: for each pair offset p, merge [p, p+R) with [p+R, p+2R).
    a.li(A2, 0); // p
    let pair_loop = a.here("pair_loop");
    {
        // idxA = p, endA = p+R, idxB = p+R, endB = p+2R, out = p
        a.mv(S0, A2);
        a.add(S1, A2, S10);
        a.mv(S2, S1);
        a.add(S3, S1, S10);
        a.mv(S4, A2);

        let mloop = a.new_label("mloop");
        let choose = a.new_label("choose");
        let load_a = a.new_label("load_a");
        let load_b = a.new_label("load_b");
        let a_empty = a.new_label("a_empty");
        let flush = a.new_label("flush");
        let pair_next = a.new_label("pair_next");

        // Pre-load the first vector of each run.
        a.lv(V1, S8, S0);
        a.addi(S0, S0, vb);
        a.lv(V2, S8, S2);
        a.addi(S2, S2, vb);

        a.bind(mloop);
        a.merge(V1, V2, V1, V2);
        a.sv(V1, S9, S4);
        a.addi(S4, S4, vb);
        a.j(choose);

        a.bind(choose);
        a.bgeu(S0, S1, a_empty);
        a.bgeu(S2, S3, load_a); // B exhausted → take A
        // Compare run heads (signed): take the smaller.
        a.add(T0, S8, S0);
        a.lw(T1, 0, T0);
        a.add(T0, S8, S2);
        a.lw(T2, 0, T0);
        a.blt(T2, T1, load_b);
        a.bind(load_a);
        a.lv(V1, S8, S0);
        a.addi(S0, S0, vb);
        a.j(mloop);
        a.bind(a_empty);
        a.bgeu(S2, S3, flush); // both exhausted
        a.bind(load_b);
        a.lv(V1, S8, S2);
        a.addi(S2, S2, vb);
        a.j(mloop);

        a.bind(flush);
        a.sv(V2, S9, S4);
        a.addi(S4, S4, vb);

        a.bind(pair_next);
        a.slli(T0, S10, 1);
        a.add(A2, A2, T0);
        a.bltu(A2, A3, pair_loop);
    }

    // Swap src/dst bases, double the run length.
    a.mv(T0, S8);
    a.mv(S8, S9);
    a.mv(S9, T0);
    a.slli(S10, S10, 1);
    a.j(pass_loop);

    a.bind(pass_done);
    a.halt();

    let result_base = if passes % 2 == 0 { src } else { scratch };
    MergesortProgram { program: a.assemble().expect("mergesort assembles"), result_base, passes }
}

#[derive(Debug, Clone, Copy)]
pub struct SortResult {
    pub throughput: Throughput,
    pub verified: bool,
    /// Cycles per element (the headline unit for speedup ratios).
    pub cycles_per_elem: f64,
}

/// Run the qsort() baseline over `n` random elements.
pub fn run_qsort(core: &mut Core, n: usize) -> Result<SortResult, SimError> {
    run_variant(core, n, Variant::Scalar)
}

/// Run the vector mergesort over `n` random elements.
pub fn run_vector_mergesort(core: &mut Core, n: usize) -> Result<SortResult, SimError> {
    run_variant(core, n, Variant::Vector)
}

fn run_variant(core: &mut Core, n: usize, variant: Variant) -> Result<SortResult, SimError> {
    let mut w = Sort::new();
    let report = run_on(&mut w, core, &Scenario::new(variant, n))?;
    Ok(SortResult {
        throughput: report.throughput,
        verified: report.verified == Some(true),
        cycles_per_elem: report.cycles_per_elem(),
    })
}

/// The §4.3.1 sorting workload behind the [`Workload`] interface:
/// scalar = the qsort() model, vector = the c2_sort/c1_merge mergesort.
/// `Scenario::size` is the element count (a power of two ≥ 4 lanes for
/// the vector variant).
pub struct Sort {
    plan: Option<Plan>,
}

struct Plan {
    result_base: u32,
    expect: Vec<i32>,
    image: Vec<(u32, Vec<u8>)>,
}

impl Sort {
    pub fn new() -> Self {
        Self { plan: None }
    }

    fn plan(&self) -> &Plan {
        self.plan.as_ref().expect("Workload::build must run first")
    }
}

impl Default for Sort {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for Sort {
    fn name(&self) -> &'static str {
        "sort"
    }

    fn description(&self) -> &'static str {
        "§4.3.1 sorting: qsort() model vs c2_sort+c1_merge mergesort; size = elements (power of two)"
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar, Variant::Vector]
    }

    fn required_units(&self, variant: Variant) -> &'static [usize] {
        match variant {
            Variant::Scalar => &[],
            Variant::Vector => &[0, 1, 2],
        }
    }

    fn default_size(&self) -> usize {
        64 * 1024
    }

    fn smoke_size(&self) -> usize {
        256
    }

    fn buffers(&self, sc: &Scenario) -> (usize, usize) {
        (2, sc.size * 4)
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let n = sc.size;
        let addrs = layout_buffers(2, n * 4);
        let (prog, result_base) = match sc.variant {
            Variant::Scalar => (build_qsort(addrs[0], n), addrs[0]),
            Variant::Vector => {
                let ms = build_vector_mergesort(addrs[0], addrs[1], n, sc.vlen_bits);
                (ms.program, ms.result_base)
            }
        };
        let input = random_i32s(n, 0xBEEF);
        let mut expect = input.clone();
        expect.sort_unstable();
        let image = vec![(addrs[0], i32s_to_bytes(&input))];
        self.plan = Some(Plan { result_base, expect, image });
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.plan().image
    }

    fn bytes_moved(&self, sc: &Scenario) -> u64 {
        (sc.size * 4) as u64
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let p = self.plan();
        let got = read_i32s(arch, p.result_base, p.expect.len());
        if got == p.expect {
            Ok(())
        } else {
            Err(VerifyError::new(format!(
                "output at {:#010x} is not the sorted input",
                p.result_base
            )))
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        let p = self.plan();
        read_i32s(arch, p.result_base, p.expect.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qsort_sorts_small() {
        let mut core = Core::paper_default();
        let r = run_qsort(&mut core, 256).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn qsort_sorts_with_duplicates() {
        // init_random_i32 over a small range would need custom init; use
        // n large enough that the 32-bit random values contain runs after
        // sorting anyway, plus check a constant array via direct build.
        let mut core = Core::paper_default();
        let addrs = layout_buffers(1, 64 * 4);
        let prog = build_qsort(addrs[0], 64);
        core.load(&prog).unwrap();
        let vals = vec![5i32; 64];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        core.mem.host_write(addrs[0], &bytes);
        core.run(10_000_000).unwrap();
        core.mem.flush_all();
        assert_eq!(read_i32s(&core, addrs[0], 64), vals);
    }

    #[test]
    fn vector_mergesort_sorts() {
        let mut core = Core::paper_default();
        for n in [32usize, 64, 256, 1024] {
            let r = run_vector_mergesort(&mut core, n).unwrap();
            assert!(r.verified, "n={n}");
        }
    }

    #[test]
    fn vector_mergesort_all_vlens() {
        for vlen in [128usize, 256, 512, 1024] {
            let mut core = Core::for_vlen(vlen);
            let r = run_vector_mergesort(&mut core, 1024).unwrap();
            assert!(r.verified, "vlen={vlen}");
        }
    }

    #[test]
    fn speedup_in_paper_band() {
        // Paper: 12.1× over softcore qsort (64 MiB). At the scaled default
        // size the band is wider but must still be near an order of
        // magnitude.
        let n = 16 * 1024;
        let mut c1 = Core::paper_default();
        let q = run_qsort(&mut c1, n).unwrap();
        let mut c2 = Core::paper_default();
        let m = run_vector_mergesort(&mut c2, n).unwrap();
        assert!(q.verified && m.verified);
        let speedup = q.cycles_per_elem / m.cycles_per_elem;
        assert!(
            (6.0..20.0).contains(&speedup),
            "sort speedup {speedup:.1}× outside acceptance band (q {:.1} c/e, m {:.1} c/e)",
            q.cycles_per_elem,
            m.cycles_per_elem
        );
    }
}
