//! String-keyed scenario registry: every workload in the repository,
//! constructible by name. This is what the `run-workload` CLI subcommand
//! and the sweep drivers enumerate — adding a workload here makes it
//! reachable from every experiment surface at once.

use super::cpubench::CpuBench;
use super::filter::Filter;
use super::memcpy::Memcpy;
use super::prefix::Prefix;
use super::sort::Sort;
use super::stream::{Kernel, Stream};
use super::workload::Workload;

/// One registered workload: a stable name plus a constructor.
pub struct RegistryEntry {
    pub name: &'static str,
    ctor: fn() -> Box<dyn Workload>,
}

impl RegistryEntry {
    /// Construct a fresh instance of the workload.
    pub fn make(&self) -> Box<dyn Workload> {
        (self.ctor)()
    }
}

/// All registered workloads, in presentation order. Names are unique
/// (asserted by `rust/tests/workload_registry.rs`).
pub fn registry() -> Vec<RegistryEntry> {
    fn entry(name: &'static str, ctor: fn() -> Box<dyn Workload>) -> RegistryEntry {
        RegistryEntry { name, ctor }
    }
    vec![
        entry("memcpy", || Box::new(Memcpy::new())),
        entry("stream-copy", || Box::new(Stream::new(Kernel::Copy))),
        entry("stream-scale", || Box::new(Stream::new(Kernel::Scale))),
        entry("stream-add", || Box::new(Stream::new(Kernel::Add))),
        entry("stream-triad", || Box::new(Stream::new(Kernel::Triad))),
        entry("sort", || Box::new(Sort::new())),
        entry("prefix", || Box::new(Prefix::new())),
        entry("filter", || Box::new(Filter::new())),
        entry("dhrystone", || Box::new(CpuBench::dhrystone())),
        entry("coremark", || Box::new(CpuBench::coremark())),
    ]
}

/// Construct the workload registered under `name`, if any.
pub fn lookup(name: &str) -> Option<Box<dyn Workload>> {
    registry().into_iter().find(|e| e.name == name).map(|e| e.make())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_match_instances() {
        let entries = registry();
        for e in &entries {
            assert_eq!(e.make().name(), e.name, "registry key must equal Workload::name");
        }
        let mut names: Vec<_> = entries.iter().map(|e| e.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), entries.len(), "registry names must be unique");
    }

    #[test]
    fn lookup_finds_known_and_rejects_unknown() {
        assert!(lookup("memcpy").is_some());
        assert!(lookup("stream-triad").is_some());
        assert!(lookup("no-such-workload").is_none());
    }
}
