//! Parallel selection (filter) — the database operation §4.3.2 motivates
//! ("prefix sum ... has numerous applications in databases, including in
//! radix hash joins and parallel filtering" [48]).
//!
//! Task: given `n` i32 values and a threshold, compact the values
//! `< threshold` densely into an output array (predicate selectivity is
//! data-dependent).
//!
//! - **scalar**: the obvious read–test–append loop.
//! - **vector**: a single pass over the data with the `c1.vfilt`
//!   compaction instruction (an exploration instruction this repo adds
//!   in the spirit of the paper — the I′ type's 6 operands carry data
//!   vector in, packed vector + count out): load a vector, compact the
//!   selected lanes, store the packed vector at the running output
//!   cursor (the next store overlaps the garbage tail), advance the
//!   cursor by the count. This is the SIMD selection kernel of Zhang &
//!   Ross [48] as *one instruction per vector*.

use super::common::{init_random_i32, layout_buffers, read_i32s, run_measuring, Throughput};
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Scalar filter: out-append loop. Leaves the count in `a6`.
pub fn build_scalar(src: u32, dst: u32, n: usize, threshold: i32) -> Program {
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (src as usize + n * 4) as i64);
    a.li(A4, threshold as i64);
    a.li(A6, 0); // count
    let l = a.here("loop");
    let skip = a.new_label("skip");
    a.lw(T0, 0, A0);
    a.addi(A0, A0, 4);
    a.bge(T0, A4, skip);
    a.sw(T0, 0, A1);
    a.addi(A1, A1, 4);
    a.addi(A6, A6, 1);
    a.bind(skip);
    a.bne(A0, A3, l);
    a.halt();
    a.assemble().expect("scalar filter assembles")
}

/// Vector filter: one `c1.vfilt` per vector, packed stores at a running
/// cursor. The destination buffer needs one vector of slack beyond the
/// selected count (each packed store writes a full VLEN vector; the
/// garbage tail is overwritten by the next store).
pub fn build_vector(src: u32, dst: u32, n: usize, threshold: i32, vlen_bits: usize) -> Program {
    let step = (vlen_bits / 8) as i32;
    let lanes = vlen_bits / 32;
    assert_eq!(n % lanes, 0);
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (n * 4) as i64);
    a.li(A4, threshold as i64);
    a.li(T4, 0); // input byte offset
    a.li(A5, 0); // output byte cursor
    a.li(A6, 0); // total selected
    let l = a.here("loop");
    a.lv(V1, A0, T4);
    a.vfilt(T0, V2, V1, A4); // pack lanes < threshold; count in t0
    a.sv(V2, A1, A5); // store packed vector (tail garbage OK)
    a.slli(T1, T0, 2);
    a.add(A5, A5, T1);
    a.add(A6, A6, T0);
    a.addi(T4, T4, step);
    a.bne(T4, A3, l);
    a.halt();
    a.assemble().expect("vector filter assembles")
}

#[derive(Debug, Clone, Copy)]
pub struct FilterResult {
    pub throughput: Throughput,
    pub verified: bool,
    pub selected: u32,
    pub cycles_per_elem: f64,
}

pub fn run(core: &mut Core, n: usize, vector: bool) -> Result<FilterResult, SimError> {
    let threshold = 0i32; // ~50% selectivity on uniform random i32
    let addrs = layout_buffers(2, n * 4 + 128);
    let (src, dst) = (addrs[0], addrs[1]);
    let prog = if vector {
        build_vector(src, dst, n, threshold, core.cfg.vlen_bits)
    } else {
        build_scalar(src, dst, n, threshold)
    };
    core.load(&prog);
    let input = init_random_i32(core, src, n, 0xF117E4);
    let throughput = run_measuring(core, (n * 4) as u64)?;
    core.mem.flush_all();
    let expect: Vec<i32> = input.iter().copied().filter(|&x| x < threshold).collect();
    let got = read_i32s(core, dst, expect.len());
    let count = core.reg(A6);
    let count_ok = !vector || count as usize == expect.len();
    Ok(FilterResult {
        throughput,
        verified: got == expect && count_ok,
        selected: count,
        cycles_per_elem: throughput.cycles as f64 / n as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_filter_is_correct() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 4096, false).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn vector_filter_is_correct_and_counts() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 4096, true).unwrap();
        assert!(r.verified);
        assert!(r.selected > 1000 && r.selected < 3000, "≈50% selectivity, got {}", r.selected);
    }

    #[test]
    fn vector_filter_other_vlens() {
        for vlen in [128usize, 512] {
            let mut core = Core::for_vlen(vlen);
            let r = run(&mut core, 4096, true).unwrap();
            assert!(r.verified, "vlen {vlen}");
        }
    }

    #[test]
    fn vfilt_beats_scalar_selection() {
        // The vector version does strictly more *work* (flags pass +
        // scatter pass) but the scan dependency chain runs on the fabric;
        // it must not be slower than ~2× scalar, and the scan itself
        // (measured via the prefix workload) is >3× faster — the
        // end-to-end win grows with selectivity-aware refinements the
        // framework enables.
        let n = 32 * 1024;
        let mut c1 = Core::paper_default();
        let s = run(&mut c1, n, false).unwrap();
        let mut c2 = Core::paper_default();
        let v = run(&mut c2, n, true).unwrap();
        assert!(s.verified && v.verified);
        let speedup = s.cycles_per_elem / v.cycles_per_elem;
        assert!(
            speedup > 1.8,
            "vfilt should win clearly: vector {:.1} c/e vs scalar {:.1} c/e ({speedup:.1}x)",
            v.cycles_per_elem,
            s.cycles_per_elem
        );
    }
}
