//! Parallel selection (filter) — the database operation §4.3.2 motivates
//! ("prefix sum ... has numerous applications in databases, including in
//! radix hash joins and parallel filtering" [48]).
//!
//! Task: given `n` i32 values and a threshold, compact the values
//! `< threshold` densely into an output array (predicate selectivity is
//! data-dependent).
//!
//! - **scalar**: the obvious read–test–append loop.
//! - **vector**: a single pass over the data with the `c1.vfilt`
//!   compaction instruction (an exploration instruction this repo adds
//!   in the spirit of the paper — the I′ type's 6 operands carry data
//!   vector in, packed vector + count out): load a vector, compact the
//!   selected lanes, store the packed vector at the running output
//!   cursor (the next store overlaps the garbage tail), advance the
//!   cursor by the count. This is the SIMD selection kernel of Zhang &
//!   Ross [48] as *one instruction per vector*.

use super::common::{i32s_to_bytes, layout_buffers, random_i32s, read_i32s, Throughput};
use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Scalar filter: out-append loop. Leaves the count in `a6`.
pub fn build_scalar(src: u32, dst: u32, n: usize, threshold: i32) -> Program {
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (src as usize + n * 4) as i64);
    a.li(A4, threshold as i64);
    a.li(A6, 0); // count
    let l = a.here("loop");
    let skip = a.new_label("skip");
    a.lw(T0, 0, A0);
    a.addi(A0, A0, 4);
    a.bge(T0, A4, skip);
    a.sw(T0, 0, A1);
    a.addi(A1, A1, 4);
    a.addi(A6, A6, 1);
    a.bind(skip);
    a.bne(A0, A3, l);
    a.halt();
    a.assemble().expect("scalar filter assembles")
}

/// Vector filter: one `c1.vfilt` per vector, packed stores at a running
/// cursor. The destination buffer needs one vector of slack beyond the
/// selected count (each packed store writes a full VLEN vector; the
/// garbage tail is overwritten by the next store).
pub fn build_vector(src: u32, dst: u32, n: usize, threshold: i32, vlen_bits: usize) -> Program {
    let step = (vlen_bits / 8) as i32;
    let lanes = vlen_bits / 32;
    assert_eq!(n % lanes, 0);
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (n * 4) as i64);
    a.li(A4, threshold as i64);
    a.li(T4, 0); // input byte offset
    a.li(A5, 0); // output byte cursor
    a.li(A6, 0); // total selected
    let l = a.here("loop");
    a.lv(V1, A0, T4);
    a.vfilt(T0, V2, V1, A4); // pack lanes < threshold; count in t0
    a.sv(V2, A1, A5); // store packed vector (tail garbage OK)
    a.slli(T1, T0, 2);
    a.add(A5, A5, T1);
    a.add(A6, A6, T0);
    a.addi(T4, T4, step);
    a.bne(T4, A3, l);
    a.halt();
    a.assemble().expect("vector filter assembles")
}

#[derive(Debug, Clone, Copy)]
pub struct FilterResult {
    pub throughput: Throughput,
    pub verified: bool,
    pub selected: u32,
    pub cycles_per_elem: f64,
}

pub fn run(core: &mut Core, n: usize, vector: bool) -> Result<FilterResult, SimError> {
    let variant = if vector { Variant::Vector } else { Variant::Scalar };
    let mut w = Filter::new();
    let report = run_on(&mut w, core, &Scenario::new(variant, n))?;
    Ok(FilterResult {
        throughput: report.throughput,
        verified: report.verified == Some(true),
        selected: core.reg(A6),
        cycles_per_elem: report.cycles_per_elem(),
    })
}

/// The parallel-selection workload behind the [`Workload`] interface.
/// `Scenario::size` is the element count (a multiple of the lane count
/// for the vector variant).
pub struct Filter {
    plan: Option<Plan>,
}

struct Plan {
    dst: u32,
    variant: Variant,
    expect: Vec<i32>,
    image: Vec<(u32, Vec<u8>)>,
}

impl Filter {
    pub fn new() -> Self {
        Self { plan: None }
    }

    fn plan(&self) -> &Plan {
        self.plan.as_ref().expect("Workload::build must run first")
    }
}

impl Default for Filter {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for Filter {
    fn name(&self) -> &'static str {
        "filter"
    }

    fn description(&self) -> &'static str {
        "parallel selection (values < 0) via c1.vfilt vs a scalar loop; size = elements"
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar, Variant::Vector]
    }

    fn required_units(&self, variant: Variant) -> &'static [usize] {
        match variant {
            Variant::Scalar => &[],
            Variant::Vector => &[0, 1],
        }
    }

    fn default_size(&self) -> usize {
        32 * 1024
    }

    fn smoke_size(&self) -> usize {
        512
    }

    fn buffers(&self, sc: &Scenario) -> (usize, usize) {
        (2, sc.size * 4 + 128)
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let threshold = 0i32; // ~50% selectivity on uniform random i32
        let n = sc.size;
        let addrs = layout_buffers(2, n * 4 + 128);
        let (src, dst) = (addrs[0], addrs[1]);
        let prog = match sc.variant {
            Variant::Vector => build_vector(src, dst, n, threshold, sc.vlen_bits),
            Variant::Scalar => build_scalar(src, dst, n, threshold),
        };
        let input = random_i32s(n, 0xF117E4);
        let expect: Vec<i32> = input.iter().copied().filter(|&x| x < threshold).collect();
        let image = vec![(src, i32s_to_bytes(&input))];
        self.plan = Some(Plan { dst, variant: sc.variant, expect, image });
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.plan().image
    }

    fn bytes_moved(&self, sc: &Scenario) -> u64 {
        (sc.size * 4) as u64
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let p = self.plan();
        let got = read_i32s(arch, p.dst, p.expect.len());
        if got != p.expect {
            return Err(VerifyError::new("packed output differs from host-side selection"));
        }
        // The vector variant also reports the selected count in a6.
        if p.variant == Variant::Vector && arch.reg(A6) as usize != p.expect.len() {
            return Err(VerifyError::new(format!(
                "selected count {} != expected {}",
                arch.reg(A6),
                p.expect.len()
            )));
        }
        Ok(())
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        let p = self.plan();
        read_i32s(arch, p.dst, p.expect.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_filter_is_correct() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 4096, false).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn vector_filter_is_correct_and_counts() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 4096, true).unwrap();
        assert!(r.verified);
        assert!(r.selected > 1000 && r.selected < 3000, "≈50% selectivity, got {}", r.selected);
    }

    #[test]
    fn vector_filter_other_vlens() {
        for vlen in [128usize, 512] {
            let mut core = Core::for_vlen(vlen);
            let r = run(&mut core, 4096, true).unwrap();
            assert!(r.verified, "vlen {vlen}");
        }
    }

    #[test]
    fn vfilt_beats_scalar_selection() {
        // The vector version does strictly more *work* (flags pass +
        // scatter pass) but the scan dependency chain runs on the fabric;
        // it must not be slower than ~2× scalar, and the scan itself
        // (measured via the prefix workload) is >3× faster — the
        // end-to-end win grows with selectivity-aware refinements the
        // framework enables.
        let n = 32 * 1024;
        let mut c1 = Core::paper_default();
        let s = run(&mut c1, n, false).unwrap();
        let mut c2 = Core::paper_default();
        let v = run(&mut c2, n, true).unwrap();
        assert!(s.verified && v.verified);
        let speedup = s.cycles_per_elem / v.cycles_per_elem;
        assert!(
            speedup > 1.8,
            "vfilt should win clearly: vector {:.1} c/e vs scalar {:.1} c/e ({speedup:.1}x)",
            v.cycles_per_elem,
            s.cycles_per_elem
        );
    }
}
