//! Adapted STREAM benchmark (§4.2, Fig. 4): Copy, Scale, Add, Triad over
//! word arrays, in the scalar RV32IM subset only ("performance as a
//! RV32IM core ... without the use of SIMD"). Loops are the plain
//! one-element-per-iteration form GCC -O2 emits (the paper's 183.4 MB/s
//! Copy rate corresponds to ≈6.5 cycles/element — a non-unrolled loop
//! with the 2-cycle load-use stall). Vector variants (using the c0/c1
//! units) are also provided for the extension experiments.

use super::common::{layout_buffers, read_i32s, Throughput};
use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    Copy,
    Scale,
    Add,
    Triad,
}

impl Kernel {
    pub const ALL: [Kernel; 4] = [Kernel::Copy, Kernel::Scale, Kernel::Add, Kernel::Triad];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Copy => "Copy",
            Kernel::Scale => "Scale",
            Kernel::Add => "Add",
            Kernel::Triad => "Triad",
        }
    }

    /// Bytes moved per element, as STREAM counts them (read + written).
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            Kernel::Copy | Kernel::Scale => 8,
            Kernel::Add | Kernel::Triad => 12,
        }
    }

    /// Arrays used: (#sources, writes c?).
    fn n_arrays(&self) -> usize {
        3 // a, b, c always laid out
    }
}

/// STREAM's integer adaptation: `q` is the scalar multiplier (STREAM uses
/// 3.0; we use 3).
const Q: i32 = 3;

/// Build one scalar STREAM kernel over `n` i32 elements.
/// Arrays: c = a (Copy); b = q*c (Scale); c = a+b (Add); a = b+q*c (Triad).
/// Pointer-walking one-element loops (GCC -O2 shape).
pub fn build_scalar(kernel: Kernel, a_base: u32, b_base: u32, c_base: u32, n: usize) -> Program {
    let mut a = Asm::new();
    a.li(A0, a_base as i64);
    a.li(A1, b_base as i64);
    a.li(A2, c_base as i64);
    a.li(A4, (a_base as usize + n * 4) as i64); // end of array a
    a.li(A5, Q as i64);
    // T4 walks the second source (if any); A0..A2 walk their arrays.
    let l = a.here("loop");
    match kernel {
        Kernel::Copy => {
            // c[i] = a[i]
            a.lw(T0, 0, A0);
            a.sw(T0, 0, A2);
            a.addi(A2, A2, 4);
        }
        Kernel::Scale => {
            // b[i] = q * c[i]  (walk c with A2, b with A1; bound on A0)
            a.lw(T0, 0, A2);
            a.mul(T0, T0, A5);
            a.sw(T0, 0, A1);
            a.addi(A1, A1, 4);
            a.addi(A2, A2, 4);
        }
        Kernel::Add => {
            // c[i] = a[i] + b[i]
            a.lw(T0, 0, A0);
            a.lw(T1, 0, A1);
            a.add(T0, T0, T1);
            a.sw(T0, 0, A2);
            a.addi(A1, A1, 4);
            a.addi(A2, A2, 4);
        }
        Kernel::Triad => {
            // a[i] = b[i] + q * c[i]  (result array a walked via T6)
            a.lw(T0, 0, A2);
            a.lw(T1, 0, A1);
            a.mul(T0, T0, A5);
            a.add(T0, T0, T1);
            a.sw(T0, 0, A0);
            a.addi(A1, A1, 4);
            a.addi(A2, A2, 4);
        }
    }
    a.addi(A0, A0, 4);
    a.bne(A0, A4, l);
    a.halt();
    a.assemble().expect("stream kernel assembles")
}

/// Build a vector STREAM kernel (uses c0.lv/sv, c1.vadd, c1.vscale).
pub fn build_vector(
    kernel: Kernel,
    a_base: u32,
    b_base: u32,
    c_base: u32,
    n: usize,
    vlen_bits: usize,
) -> Program {
    let step = (vlen_bits / 8) as i32;
    assert_eq!((n * 4) % step as usize, 0);
    let mut a = Asm::new();
    a.li(A0, a_base as i64);
    a.li(A1, b_base as i64);
    a.li(A2, c_base as i64);
    a.li(A3, 0);
    a.li(A4, (n * 4) as i64);
    a.li(A5, Q as i64);
    let l = a.here("loop");
    match kernel {
        Kernel::Copy => {
            a.lv(V1, A0, A3);
            a.sv(V1, A2, A3);
        }
        Kernel::Scale => {
            a.lv(V1, A2, A3);
            a.vscale(V2, V1, A5);
            a.sv(V2, A1, A3);
        }
        Kernel::Add => {
            a.lv(V1, A0, A3);
            a.lv(V2, A1, A3);
            a.vadd(V3, V1, V2);
            a.sv(V3, A2, A3);
        }
        Kernel::Triad => {
            a.lv(V1, A2, A3);
            a.vscale(V2, V1, A5);
            a.lv(V3, A1, A3);
            a.vadd(V4, V3, V2);
            a.sv(V4, A0, A3);
        }
    }
    a.addi(A3, A3, step);
    a.bne(A3, A4, l);
    a.halt();
    a.assemble().expect("vector stream kernel assembles")
}

#[derive(Debug, Clone, Copy)]
pub struct StreamResult {
    pub kernel: Kernel,
    pub throughput: Throughput,
    pub verified: bool,
}

/// Run one STREAM kernel over `n` elements on `core`.
pub fn run(core: &mut Core, kernel: Kernel, n: usize, vector: bool) -> Result<StreamResult, SimError> {
    let variant = if vector { Variant::Vector } else { Variant::Scalar };
    let mut w = Stream::new(kernel);
    let report = run_on(&mut w, core, &Scenario::new(variant, n))?;
    Ok(StreamResult { kernel, throughput: report.throughput, verified: report.verified == Some(true) })
}

fn verify(arch: &dyn ArchState, kernel: Kernel, ab: u32, bb: u32, cb: u32, n: usize) -> bool {
    let probe = [0usize, n / 2, n - 1];
    match kernel {
        Kernel::Copy => probe.iter().all(|&i| read_i32s(arch, cb + (i * 4) as u32, 1)[0] == 1),
        Kernel::Scale => probe.iter().all(|&i| read_i32s(arch, bb + (i * 4) as u32, 1)[0] == 0),
        Kernel::Add => probe.iter().all(|&i| read_i32s(arch, cb + (i * 4) as u32, 1)[0] == 3),
        Kernel::Triad => probe.iter().all(|&i| read_i32s(arch, ab + (i * 4) as u32, 1)[0] == 2),
    }
}

/// One adapted-STREAM kernel behind the [`Workload`] interface.
/// `Scenario::size` is the element count per array.
pub struct Stream {
    kernel: Kernel,
    plan: Option<Plan>,
}

struct Plan {
    a: u32,
    b: u32,
    c: u32,
    n: usize,
    image: Vec<(u32, Vec<u8>)>,
}

impl Stream {
    pub fn new(kernel: Kernel) -> Self {
        Self { kernel, plan: None }
    }

    fn plan(&self) -> &Plan {
        self.plan.as_ref().expect("Workload::build must run first")
    }
}

impl Workload for Stream {
    fn name(&self) -> &'static str {
        match self.kernel {
            Kernel::Copy => "stream-copy",
            Kernel::Scale => "stream-scale",
            Kernel::Add => "stream-add",
            Kernel::Triad => "stream-triad",
        }
    }

    fn description(&self) -> &'static str {
        match self.kernel {
            Kernel::Copy => "§4.2 adapted STREAM Copy (c = a); size = elements/array",
            Kernel::Scale => "§4.2 adapted STREAM Scale (b = q*c); size = elements/array",
            Kernel::Add => "§4.2 adapted STREAM Add (c = a+b); size = elements/array",
            Kernel::Triad => "§4.2 adapted STREAM Triad (a = b+q*c); size = elements/array",
        }
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar, Variant::Vector]
    }

    fn required_units(&self, variant: Variant) -> &'static [usize] {
        match (variant, self.kernel) {
            (Variant::Scalar, _) => &[],
            (Variant::Vector, Kernel::Copy) => &[0],
            (Variant::Vector, _) => &[0, 1],
        }
    }

    fn default_size(&self) -> usize {
        256 * 1024
    }

    fn smoke_size(&self) -> usize {
        1024
    }

    fn buffers(&self, sc: &Scenario) -> (usize, usize) {
        (self.kernel.n_arrays(), sc.size * 4)
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let n = sc.size;
        let addrs = layout_buffers(self.kernel.n_arrays(), n * 4);
        let (a, b, c) = (addrs[0], addrs[1], addrs[2]);
        let prog = match sc.variant {
            Variant::Vector => build_vector(self.kernel, a, b, c, n, sc.vlen_bits),
            Variant::Scalar => build_scalar(self.kernel, a, b, c, n),
        };
        // STREAM init: a=1, b=2, c=0 (integer adaptation).
        let image = vec![
            (a, 1i32.to_le_bytes().repeat(n)),
            (b, 2i32.to_le_bytes().repeat(n)),
            (c, 0i32.to_le_bytes().repeat(n)),
        ];
        self.plan = Some(Plan { a, b, c, n, image });
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.plan().image
    }

    fn bytes_moved(&self, sc: &Scenario) -> u64 {
        self.kernel.bytes_per_elem() * sc.size as u64
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let p = self.plan();
        if verify(arch, self.kernel, p.a, p.b, p.c, p.n) {
            Ok(())
        } else {
            Err(VerifyError::new(format!("{} probe values wrong", self.kernel.name())))
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        let p = self.plan();
        let out = match self.kernel {
            Kernel::Copy | Kernel::Add => p.c,
            Kernel::Scale => p.b,
            Kernel::Triad => p.a,
        };
        read_i32s(arch, out, p.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scalar_kernels_verify() {
        for k in Kernel::ALL {
            let mut core = Core::paper_default();
            let r = run(&mut core, k, 4096, false).unwrap();
            assert!(r.verified, "{} failed verification", k.name());
        }
    }

    #[test]
    fn all_vector_kernels_verify() {
        for k in Kernel::ALL {
            let mut core = Core::paper_default();
            let r = run(&mut core, k, 4096, true).unwrap();
            assert!(r.verified, "vector {} failed verification", k.name());
        }
    }

    #[test]
    fn scalar_copy_rate_in_paper_band() {
        let mut core = Core::paper_default();
        // 1 MiB arrays: past the LLC, like the paper's larger sizes.
        let r = run(&mut core, Kernel::Copy, 256 * 1024, false).unwrap();
        let mbps = r.throughput.bytes_per_second() / 1e6;
        // Paper: 183.4 MB/s. Accept 120–260.
        assert!((120.0..260.0).contains(&mbps), "Copy = {mbps:.1} MB/s");
    }

    #[test]
    fn kernel_ordering_is_sane() {
        // Copy moves fewer bytes per iteration than Add/Triad but runs
        // fewer instructions; rates should be same order of magnitude and
        // Triad ≤ Copy in B/cycle terms.
        let mut rates = Vec::new();
        for k in Kernel::ALL {
            let mut core = Core::paper_default();
            let r = run(&mut core, k, 64 * 1024, false).unwrap();
            rates.push(r.throughput.bytes_per_second());
        }
        let max = rates.iter().cloned().fold(0.0f64, f64::max);
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 3.0, "kernels should be within 3x: {rates:?}");
    }

    #[test]
    fn vector_copy_much_faster_than_scalar() {
        let mut c1 = Core::paper_default();
        let v = run(&mut c1, Kernel::Copy, 64 * 1024, true).unwrap();
        let mut c2 = Core::paper_default();
        let s = run(&mut c2, Kernel::Copy, 64 * 1024, false).unwrap();
        assert!(v.throughput.bytes_per_cycle() > 2.0 * s.throughput.bytes_per_cycle());
    }
}
