//! Prefix sum (§4.3.2): serial scalar baseline vs the `c3_prefix`
//! custom instruction (Hillis-Steele network + carry accumulator, Fig. 7).

use super::common::{init_random_i32, layout_buffers, read_i32s, run_measuring, Throughput};
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Serial prefix sum: out[i] = out[i-1] + in[i] — "trivial and easy for
/// compiling efficient code" (§4.3.2). The GCC -O2 shape: a plain
/// pointer-walking loop with the load scheduled ahead of its use (the
/// pointer bumps fill the load-use slots).
pub fn build_serial(src: u32, dst: u32, n: usize) -> Program {
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (src as usize + n * 4) as i64); // end of src
    a.li(T4, 0); // running sum
    let l = a.here("loop");
    a.lw(T0, 0, A0);
    a.addi(A0, A0, 4); // scheduled into the load-use slots
    a.addi(A1, A1, 4);
    a.add(T4, T4, T0);
    a.sw(T4, -4, A1);
    a.bne(A0, A3, l);
    a.halt();
    a.assemble().expect("serial prefix assembles")
}

/// Vector prefix sum: one `c3.prefix` per vector, the unit's carry
/// accumulator chaining batches (so the loop itself has no loop-carried
/// scalar dependency — the paper's "pipelined and non-blocking" scan).
pub fn build_vector(src: u32, dst: u32, n: usize, vlen_bits: usize) -> Program {
    let step = (vlen_bits / 8) as i32;
    assert_eq!((n * 4) % step as usize, 0);
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A2, 0);
    a.li(A3, (n * 4) as i64);
    a.prefix_reset();
    let l = a.here("loop");
    a.lv(V1, A0, A2);
    a.prefix(V2, V1);
    a.sv(V2, A1, A2);
    a.addi(A2, A2, step);
    a.bne(A2, A3, l);
    a.halt();
    a.assemble().expect("vector prefix assembles")
}

#[derive(Debug, Clone, Copy)]
pub struct PrefixResult {
    pub throughput: Throughput,
    pub verified: bool,
    pub cycles_per_elem: f64,
}

pub fn run(core: &mut Core, n: usize, vector: bool) -> Result<PrefixResult, SimError> {
    let addrs = layout_buffers(2, n * 4);
    let (src, dst) = (addrs[0], addrs[1]);
    let prog = if vector {
        build_vector(src, dst, n, core.cfg.vlen_bits)
    } else {
        build_serial(src, dst, n)
    };
    core.load(&prog);
    let input = init_random_i32(core, src, n, 0xACC);
    let throughput = run_measuring(core, (n * 4) as u64)?;
    core.mem.flush_all();
    let got = read_i32s(core, dst, n);
    let mut acc = 0i32;
    let verified = input.iter().zip(&got).all(|(&x, &y)| {
        acc = acc.wrapping_add(x);
        acc == y
    });
    Ok(PrefixResult {
        throughput,
        verified,
        cycles_per_elem: throughput.cycles as f64 / n as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_prefix_is_correct() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 1024, false).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn vector_prefix_is_correct() {
        for vlen in [128usize, 256, 512] {
            let mut core = Core::for_vlen(vlen);
            let r = run(&mut core, 4096, true).unwrap();
            assert!(r.verified, "vlen={vlen}");
        }
    }

    #[test]
    fn speedup_in_paper_band() {
        // Paper: 4.1× over the serial softcore version (64 MiB input).
        let n = 64 * 1024;
        let mut c1 = Core::paper_default();
        let s = run(&mut c1, n, false).unwrap();
        let mut c2 = Core::paper_default();
        let v = run(&mut c2, n, true).unwrap();
        assert!(s.verified && v.verified);
        let speedup = s.cycles_per_elem / v.cycles_per_elem;
        assert!(
            (2.5..7.0).contains(&speedup),
            "prefix speedup {speedup:.1}× outside band (serial {:.2} c/e, vector {:.2} c/e)",
            s.cycles_per_elem,
            v.cycles_per_elem
        );
    }
}
