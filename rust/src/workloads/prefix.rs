//! Prefix sum (§4.3.2): serial scalar baseline vs the `c3_prefix`
//! custom instruction (Hillis-Steele network + carry accumulator, Fig. 7).

use super::common::{i32s_to_bytes, layout_buffers, random_i32s, read_i32s, Throughput};
use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Serial prefix sum: out[i] = out[i-1] + in[i] — "trivial and easy for
/// compiling efficient code" (§4.3.2). The GCC -O2 shape: a plain
/// pointer-walking loop with the load scheduled ahead of its use (the
/// pointer bumps fill the load-use slots).
pub fn build_serial(src: u32, dst: u32, n: usize) -> Program {
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A3, (src as usize + n * 4) as i64); // end of src
    a.li(T4, 0); // running sum
    let l = a.here("loop");
    a.lw(T0, 0, A0);
    a.addi(A0, A0, 4); // scheduled into the load-use slots
    a.addi(A1, A1, 4);
    a.add(T4, T4, T0);
    a.sw(T4, -4, A1);
    a.bne(A0, A3, l);
    a.halt();
    a.assemble().expect("serial prefix assembles")
}

/// Vector prefix sum: one `c3.prefix` per vector, the unit's carry
/// accumulator chaining batches (so the loop itself has no loop-carried
/// scalar dependency — the paper's "pipelined and non-blocking" scan).
pub fn build_vector(src: u32, dst: u32, n: usize, vlen_bits: usize) -> Program {
    let step = (vlen_bits / 8) as i32;
    assert_eq!((n * 4) % step as usize, 0);
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A2, 0);
    a.li(A3, (n * 4) as i64);
    a.prefix_reset();
    let l = a.here("loop");
    a.lv(V1, A0, A2);
    a.prefix(V2, V1);
    a.sv(V2, A1, A2);
    a.addi(A2, A2, step);
    a.bne(A2, A3, l);
    a.halt();
    a.assemble().expect("vector prefix assembles")
}

#[derive(Debug, Clone, Copy)]
pub struct PrefixResult {
    pub throughput: Throughput,
    pub verified: bool,
    pub cycles_per_elem: f64,
}

pub fn run(core: &mut Core, n: usize, vector: bool) -> Result<PrefixResult, SimError> {
    let variant = if vector { Variant::Vector } else { Variant::Scalar };
    let mut w = Prefix::new();
    let report = run_on(&mut w, core, &Scenario::new(variant, n))?;
    Ok(PrefixResult {
        throughput: report.throughput,
        verified: report.verified == Some(true),
        cycles_per_elem: report.cycles_per_elem(),
    })
}

/// The §4.3.2 prefix-sum workload behind the [`Workload`] interface.
/// `Scenario::size` is the element count (vector bytes must divide
/// `4 * size` for the vector variant).
pub struct Prefix {
    plan: Option<Plan>,
}

struct Plan {
    dst: u32,
    expect: Vec<i32>,
    image: Vec<(u32, Vec<u8>)>,
}

impl Prefix {
    pub fn new() -> Self {
        Self { plan: None }
    }

    fn plan(&self) -> &Plan {
        self.plan.as_ref().expect("Workload::build must run first")
    }
}

impl Default for Prefix {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for Prefix {
    fn name(&self) -> &'static str {
        "prefix"
    }

    fn description(&self) -> &'static str {
        "§4.3.2 prefix sum: serial loop vs stateful c3_prefix; size = elements"
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar, Variant::Vector]
    }

    fn required_units(&self, variant: Variant) -> &'static [usize] {
        match variant {
            Variant::Scalar => &[],
            Variant::Vector => &[0, 3],
        }
    }

    fn default_size(&self) -> usize {
        1024 * 1024
    }

    fn smoke_size(&self) -> usize {
        512
    }

    fn buffers(&self, sc: &Scenario) -> (usize, usize) {
        (2, sc.size * 4)
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let n = sc.size;
        let addrs = layout_buffers(2, n * 4);
        let (src, dst) = (addrs[0], addrs[1]);
        let prog = match sc.variant {
            Variant::Vector => build_vector(src, dst, n, sc.vlen_bits),
            Variant::Scalar => build_serial(src, dst, n),
        };
        let input = random_i32s(n, 0xACC);
        let mut acc = 0i32;
        let expect: Vec<i32> = input
            .iter()
            .map(|&x| {
                acc = acc.wrapping_add(x);
                acc
            })
            .collect();
        let image = vec![(src, i32s_to_bytes(&input))];
        self.plan = Some(Plan { dst, expect, image });
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.plan().image
    }

    fn bytes_moved(&self, sc: &Scenario) -> u64 {
        (sc.size * 4) as u64
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let p = self.plan();
        let got = read_i32s(arch, p.dst, p.expect.len());
        if got == p.expect {
            Ok(())
        } else {
            Err(VerifyError::new("running sums differ from the host-side scan"))
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        let p = self.plan();
        read_i32s(arch, p.dst, p.expect.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_prefix_is_correct() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 1024, false).unwrap();
        assert!(r.verified);
    }

    #[test]
    fn vector_prefix_is_correct() {
        for vlen in [128usize, 256, 512] {
            let mut core = Core::for_vlen(vlen);
            let r = run(&mut core, 4096, true).unwrap();
            assert!(r.verified, "vlen={vlen}");
        }
    }

    #[test]
    fn speedup_in_paper_band() {
        // Paper: 4.1× over the serial softcore version (64 MiB input).
        let n = 64 * 1024;
        let mut c1 = Core::paper_default();
        let s = run(&mut c1, n, false).unwrap();
        let mut c2 = Core::paper_default();
        let v = run(&mut c2, n, true).unwrap();
        assert!(s.verified && v.verified);
        let speedup = s.cycles_per_elem / v.cycles_per_elem;
        assert!(
            (2.5..7.0).contains(&speedup),
            "prefix speedup {speedup:.1}× outside band (serial {:.2} c/e, vector {:.2} c/e)",
            s.cycles_per_elem,
            v.cycles_per_elem
        );
    }
}
