//! Workload programs of the paper's evaluation, authored through the
//! builder assembler exactly as the paper authored them through inline
//! assembly: memcpy (§4.1), STREAM (§4.2), the Table-2 CPU benchmarks,
//! sorting (§4.3.1), prefix sum (§4.3.2) and parallel selection.
//!
//! Every workload implements the [`Workload`] trait and is registered by
//! name in [`registry()`]; run one on a configured machine with
//! [`crate::machine::Machine::run`] or the `run-workload` CLI
//! subcommand. See DESIGN.md for the API walkthrough.

pub mod common;
pub mod cpubench;
pub mod filter;
pub mod memcpy;
pub mod prefix;
pub mod registry;
pub mod sort;
pub mod stream;
pub mod workload;

pub use common::Throughput;
pub use registry::{lookup, registry, RegistryEntry};
pub use workload::{
    run_on, run_on_iss, run_on_iss_engine, Scenario, Variant, VerifyError, Workload,
    WorkloadReport,
};
