//! Workload programs of the paper's evaluation, authored through the
//! builder assembler exactly as the paper authored them through inline
//! assembly: memcpy (§4.1), STREAM (§4.2), the Table-2 CPU benchmarks,
//! sorting (§4.3.1) and prefix sum (§4.3.2).

pub mod common;
pub mod cpubench;
pub mod filter;
pub mod memcpy;
pub mod prefix;
pub mod sort;
pub mod stream;

pub use common::Throughput;
