//! Table-2 CPU benchmarks: Dhrystone-like and CoreMark-like synthetic
//! kernels.
//!
//! The paper reports 1.47 DMIPS/MHz and 2.26 CoreMark/MHz, noting the
//! comparison is "indicative, not direct" (each row used a different
//! FPGA + compiler). We cannot run GCC-compiled Dhrystone/CoreMark
//! binaries (no compiler in the loop), so we do what the table needs:
//! measure the core's **IPC** on kernels with the same instruction-class
//! mix, then derive the scores with published instruction-count
//! constants:
//!
//! - Dhrystone 2.1 on RV32IM at -O2 retires ≈ 330 instructions per
//!   iteration ⇒ DMIPS/MHz = IPC × 10⁶ / (330 × 1757) ≈ IPC × 1.725.
//! - CoreMark on RV32IM at -O2 retires ≈ 385 k instructions per
//!   iteration ⇒ CoreMark/MHz ≈ IPC × 2.6.
//!
//! The kernels below are real programs with verified results, exercising
//! the class mix of the originals (integer ALU, loads/stores, branches,
//! calls; list walk + matrix multiply + state machine for CoreMark).

use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

pub const DHRYSTONE_DERIVE: f64 = 1e6 / (330.0 * 1757.0);
pub const COREMARK_DERIVE: f64 = 2.6;

/// Build the Dhrystone-like kernel: `iters` iterations of a mix of
/// record assignment (word copies), string-compare-style loops, small
/// function calls and integer arithmetic. Returns (program, expected a0).
pub fn build_dhrystone_like(iters: u32) -> (Program, u32) {
    let mut a = Asm::new();
    // Static data: two 16-word "records" and an 8-word "string".
    let rec1 = a.words("rec1", &(0..16u32).map(|i| i * 3 + 1).collect::<Vec<_>>());
    let rec2 = a.buffer("rec2", 64, 4);
    let strbuf = a.words("str", &(0..8u32).map(|i| 0x4141_4141 + i).collect::<Vec<_>>());

    let f_add3 = a.new_label("f_add3"); // a0 = a0*2 + 3
    let f_mix = a.new_label("f_mix"); // a0 ^= a1; a0 += 7

    a.li(S0, iters as i64); // loop counter
    a.li(A0, 0); // checksum
    a.la(S1, rec1);
    a.la(S2, rec2);
    a.la(S3, strbuf);

    let iter_l = a.here("iter");
    // (1) record assignment: copy 16 words rec1 -> rec2, sum them in.
    for i in 0..16 {
        a.lw(T0, i * 4, S1);
        a.sw(T0, i * 4, S2);
        a.add(A0, A0, T0);
    }
    // (2) string compare-ish loop: walk 8 words, branch on each.
    {
        let cmp_done = a.new_label("cmp_done");
        let cmp_loop = a.new_label("cmp_loop");
        a.li(T1, 0);
        a.bind(cmp_loop);
        a.slli(T2, T1, 2);
        a.add(T2, T2, S3);
        a.lw(T0, 0, T2);
        a.andi(T3, T0, 1);
        let even = a.new_label("even");
        a.beqz(T3, even);
        a.addi(A0, A0, 1);
        a.bind(even);
        a.addi(T1, T1, 1);
        a.slti(T3, T1, 8);
        a.bnez(T3, cmp_loop);
        a.bind(cmp_done);
    }
    // (3) function calls.
    a.call(f_add3);
    a.li(A1, 0x55);
    a.call(f_mix);
    // (4) arithmetic mix with a multiply and shifts.
    a.slli(T0, A0, 3);
    a.srli(T1, A0, 5);
    a.xor(A0, A0, T0);
    a.add(A0, A0, T1);
    a.li(T2, 2654435761u32 as i64);
    a.mul(T3, A0, T2);
    a.xor(A0, A0, T3);
    // loop
    a.addi(S0, S0, -1);
    a.bnez(S0, iter_l);
    a.halt();

    a.bind(f_add3);
    a.slli(A0, A0, 1);
    a.addi(A0, A0, 3);
    a.ret();
    a.bind(f_mix);
    a.xor(A0, A0, A1);
    a.addi(A0, A0, 7);
    a.ret();

    // Host-side model of the same computation for verification.
    let rec1_vals: Vec<u32> = (0..16u32).map(|i| i * 3 + 1).collect();
    let str_vals: Vec<u32> = (0..8u32).map(|i| 0x4141_4141 + i).collect();
    let mut chk: u32 = 0;
    for _ in 0..iters {
        for &v in &rec1_vals {
            chk = chk.wrapping_add(v);
        }
        for &v in &str_vals {
            if v & 1 == 1 {
                chk = chk.wrapping_add(1);
            }
        }
        chk = chk.wrapping_mul(2).wrapping_add(3);
        chk = (chk ^ 0x55).wrapping_add(7);
        let t0 = chk << 3;
        let t1 = chk >> 5;
        chk ^= t0;
        chk = chk.wrapping_add(t1);
        let t3 = chk.wrapping_mul(2654435761);
        chk ^= t3;
    }
    (a.assemble().expect("dhrystone-like assembles"), chk)
}

/// Build the CoreMark-like kernel: linked-list walk + 4×4 integer matrix
/// multiply + CRC-style state machine per iteration. Returns
/// (program, expected a0).
pub fn build_coremark_like(iters: u32) -> (Program, u32) {
    let mut a = Asm::new();
    // Linked list: 16 nodes of (value, next_offset) laid out shuffled.
    let order: [u32; 16] = [3, 7, 1, 12, 0, 9, 14, 5, 2, 11, 8, 15, 6, 13, 4, 10];
    let mut nodes = vec![0u32; 32];
    for i in 0..16 {
        let next = if i + 1 < 16 { order[i + 1] } else { u32::MAX };
        nodes[(order[i] * 2) as usize] = order[i] * 17 + 5; // value
        nodes[(order[i] * 2 + 1) as usize] = next; // next index (MAX = end)
    }
    let list = a.words("list", &nodes);
    // Matrices: 4x4 A and B.
    let ma: Vec<u32> = (0..16u32).map(|i| i + 1).collect();
    let mb: Vec<u32> = (0..16u32).map(|i| (i * 7 + 3) % 13).collect();
    let mat_a = a.words("mat_a", &ma);
    let mat_b = a.words("mat_b", &mb);
    let mat_c = a.buffer("mat_c", 64, 4);

    a.li(S0, iters as i64);
    a.li(A0, 0); // checksum
    a.la(S1, list);
    a.la(S2, mat_a);
    a.la(S3, mat_b);
    a.la(S4, mat_c);

    let iter_l = a.here("iter");
    // (1) list walk: follow next indices, sum values.
    {
        let walk = a.new_label("walk");
        let walk_done = a.new_label("walk_done");
        a.li(T0, 3); // head index (order[0])
        a.bind(walk);
        a.slli(T1, T0, 3); // node offset = idx * 8
        a.add(T1, T1, S1);
        a.lw(T2, 0, T1); // value
        a.add(A0, A0, T2);
        a.lw(T0, 4, T1); // next
        a.li(T3, -1);
        a.bne(T0, T3, walk);
        a.bind(walk_done);
    }
    // (2) 4x4 matrix multiply C = A*B, sum diagonal into checksum.
    for i in 0..4i32 {
        for j in 0..4i32 {
            a.li(T4, 0);
            for k in 0..4i32 {
                a.lw(T0, (i * 4 + k) * 4, S2);
                a.lw(T1, (k * 4 + j) * 4, S3);
                a.mul(T2, T0, T1);
                a.add(T4, T4, T2);
            }
            a.sw(T4, (i * 4 + j) * 4, S4);
            if i == j {
                a.add(A0, A0, T4);
            }
        }
    }
    // (3) state machine: 16 steps of a branchy CRC-ish update.
    {
        let sm = a.new_label("sm");
        a.li(T0, 16);
        a.bind(sm);
        a.andi(T1, A0, 3);
        let s1 = a.new_label("s1");
        let s2 = a.new_label("s2");
        let s_end = a.new_label("s_end");
        a.li(T2, 1);
        a.beq(T1, T2, s1);
        a.li(T2, 2);
        a.beq(T1, T2, s2);
        // state 0/3: shift-xor
        a.srli(T3, A0, 1);
        a.xor(A0, A0, T3);
        a.addi(A0, A0, 13);
        a.j(s_end);
        a.bind(s1);
        a.slli(T3, A0, 2);
        a.add(A0, A0, T3);
        a.j(s_end);
        a.bind(s2);
        a.xori(A0, A0, 0x2D);
        a.bind(s_end);
        a.addi(T0, T0, -1);
        a.bnez(T0, sm);
    }
    a.addi(S0, S0, -1);
    a.bnez(S0, iter_l);
    a.halt();

    // Host model.
    let mut chk: u32 = 0;
    for _ in 0..iters {
        let mut idx = 3u32;
        loop {
            chk = chk.wrapping_add(nodes[(idx * 2) as usize]);
            idx = nodes[(idx * 2 + 1) as usize];
            if idx == u32::MAX {
                break;
            }
        }
        for i in 0..4usize {
            for j in 0..4usize {
                let mut acc = 0u32;
                for k in 0..4usize {
                    acc = acc.wrapping_add(ma[i * 4 + k].wrapping_mul(mb[k * 4 + j]));
                }
                if i == j {
                    chk = chk.wrapping_add(acc);
                }
            }
        }
        for _ in 0..16 {
            match chk & 3 {
                1 => chk = chk.wrapping_add(chk << 2),
                2 => chk ^= 0x2D,
                _ => {
                    chk = (chk ^ (chk >> 1)).wrapping_add(13);
                }
            }
        }
    }
    (a.assemble().expect("coremark-like assembles"), chk)
}

#[derive(Debug, Clone, Copy)]
pub struct CpuBenchResult {
    pub ipc: f64,
    pub cycles: u64,
    pub instret: u64,
    pub verified: bool,
    /// DMIPS/MHz or CoreMark/MHz derived per module docs.
    pub derived_score: f64,
}

pub fn run_dhrystone_like(core: &mut Core, iters: u32) -> Result<CpuBenchResult, SimError> {
    run_kind(core, CpuBenchKind::Dhrystone, iters)
}

pub fn run_coremark_like(core: &mut Core, iters: u32) -> Result<CpuBenchResult, SimError> {
    run_kind(core, CpuBenchKind::Coremark, iters)
}

fn run_kind(core: &mut Core, kind: CpuBenchKind, iters: u32) -> Result<CpuBenchResult, SimError> {
    let mut w = CpuBench::new(kind);
    let report = run_on(&mut w, core, &Scenario::new(Variant::Scalar, iters as usize))?;
    let ipc = report.throughput.ipc();
    Ok(CpuBenchResult {
        ipc,
        cycles: report.throughput.cycles,
        instret: report.throughput.instret,
        verified: report.verified == Some(true),
        derived_score: ipc * kind.derive(),
    })
}

/// Which Table-2 kernel a [`CpuBench`] workload runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuBenchKind {
    Dhrystone,
    Coremark,
}

impl CpuBenchKind {
    /// IPC → score conversion constant (see module docs).
    pub fn derive(self) -> f64 {
        match self {
            CpuBenchKind::Dhrystone => DHRYSTONE_DERIVE,
            CpuBenchKind::Coremark => COREMARK_DERIVE,
        }
    }
}

/// A Table-2 CPU benchmark behind the [`Workload`] interface.
/// `Scenario::size` is the iteration count; the workload is scalar-only
/// (the paper's rows are explicitly "ignoring SIMD").
pub struct CpuBench {
    kind: CpuBenchKind,
    expect: Option<u32>,
}

impl CpuBench {
    pub fn new(kind: CpuBenchKind) -> Self {
        Self { kind, expect: None }
    }

    pub fn dhrystone() -> Self {
        Self::new(CpuBenchKind::Dhrystone)
    }

    pub fn coremark() -> Self {
        Self::new(CpuBenchKind::Coremark)
    }

    fn expect(&self) -> u32 {
        self.expect.expect("Workload::build must run first")
    }
}

impl Workload for CpuBench {
    fn name(&self) -> &'static str {
        match self.kind {
            CpuBenchKind::Dhrystone => "dhrystone",
            CpuBenchKind::Coremark => "coremark",
        }
    }

    fn description(&self) -> &'static str {
        match self.kind {
            CpuBenchKind::Dhrystone => {
                "Table-2 Dhrystone-like kernel (DMIPS/MHz from IPC); size = iterations"
            }
            CpuBenchKind::Coremark => {
                "Table-2 CoreMark-like kernel (CoreMark/MHz from IPC); size = iterations"
            }
        }
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar]
    }

    fn required_units(&self, _variant: Variant) -> &'static [usize] {
        &[]
    }

    fn default_size(&self) -> usize {
        match self.kind {
            CpuBenchKind::Dhrystone => 300,
            CpuBenchKind::Coremark => 100,
        }
    }

    fn smoke_size(&self) -> usize {
        20
    }

    fn buffers(&self, _sc: &Scenario) -> (usize, usize) {
        (0, 0) // static data only; no heap buffers
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let iters = sc.size as u32;
        let (prog, expect) = match self.kind {
            CpuBenchKind::Dhrystone => build_dhrystone_like(iters),
            CpuBenchKind::Coremark => build_coremark_like(iters),
        };
        self.expect = Some(expect);
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &[] // inputs live in the program's data segment
    }

    fn bytes_moved(&self, _sc: &Scenario) -> u64 {
        0 // IPC benchmark: no payload-byte accounting
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let expect = self.expect();
        if arch.reg(A0) == expect {
            Ok(())
        } else {
            Err(VerifyError::new(format!(
                "checksum {:#010x} != expected {:#010x}",
                arch.reg(A0),
                expect
            )))
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        vec![arch.reg(A0) as i32]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dhrystone_like_verifies_and_scores() {
        let mut core = Core::paper_default();
        let r = run_dhrystone_like(&mut core, 200).unwrap();
        assert!(r.verified, "checksum mismatch");
        // Paper: 1.47 DMIPS/MHz; band 1.1–2.0.
        assert!(
            (1.1..2.0).contains(&r.derived_score),
            "DMIPS/MHz {:.2} (IPC {:.2})",
            r.derived_score,
            r.ipc
        );
    }

    #[test]
    fn coremark_like_verifies_and_scores() {
        let mut core = Core::paper_default();
        let r = run_coremark_like(&mut core, 100).unwrap();
        assert!(r.verified, "checksum mismatch");
        // Paper: 2.26 CoreMark/MHz; band 1.7–3.0.
        assert!(
            (1.7..3.0).contains(&r.derived_score),
            "CoreMark/MHz {:.2} (IPC {:.2})",
            r.derived_score,
            r.ipc
        );
    }

    #[test]
    fn ipc_is_high_but_below_one() {
        let mut core = Core::paper_default();
        let r = run_dhrystone_like(&mut core, 100).unwrap();
        assert!(r.ipc > 0.6 && r.ipc <= 1.0, "IPC {}", r.ipc);
    }
}
