//! memcpy() — the §4.1 design-space-exploration workload.
//!
//! Two implementations:
//! - **vector**: the paper's custom-instruction version — a `c0.lv` /
//!   `c0.sv` loop moving VLEN bits per pair ("memcpy() here is manually
//!   implemented with the custom instructions for load vector and store
//!   vector");
//! - **scalar**: a `lw`/`sw` loop unrolled ×4 (what GCC -O3 emits for a
//!   word-aligned copy), the baseline that isolates the vector win.

use super::common::{i32s_to_bytes, layout_buffers, random_i32s, read_i32s, Throughput};
use super::workload::{run_on, Scenario, Variant, VerifyError, Workload};
use crate::arch::ArchState;
use crate::asm::{Asm, Program};
use crate::core::{Core, SimError};
use crate::isa::reg::*;

/// Build the vector memcpy program: copy `bytes` from `src` to `dst`.
/// The loop keeps the base in `a0`/`a1` and the running offset in `a2`
/// (the S′ type's two base registers let the index live in its own
/// register, §2.1).
pub fn build_vector(src: u32, dst: u32, bytes: usize, vlen_bits: usize) -> Program {
    let step = (vlen_bits / 8) as i32;
    assert_eq!(bytes % (step as usize), 0, "size must be a multiple of VLEN");
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A2, 0);
    a.li(A3, bytes as i64);
    let l = a.here("loop");
    a.lv(V1, A0, A2);
    a.sv(V1, A1, A2);
    a.addi(A2, A2, step);
    a.bne(A2, A3, l);
    a.halt();
    a.assemble().expect("vector memcpy assembles")
}

/// Build the scalar memcpy program (lw/sw unrolled ×4, 16 bytes/iter).
pub fn build_scalar(src: u32, dst: u32, bytes: usize) -> Program {
    assert_eq!(bytes % 16, 0, "size must be a multiple of 16");
    let mut a = Asm::new();
    a.li(A0, src as i64);
    a.li(A1, dst as i64);
    a.li(A2, 0);
    a.li(A3, bytes as i64);
    let l = a.here("loop");
    a.add(T5, A0, A2);
    a.add(T6, A1, A2);
    a.lw(T0, 0, T5);
    a.lw(T1, 4, T5);
    a.lw(T2, 8, T5);
    a.lw(T3, 12, T5);
    a.sw(T0, 0, T6);
    a.sw(T1, 4, T6);
    a.sw(T2, 8, T6);
    a.sw(T3, 12, T6);
    a.addi(A2, A2, 16);
    a.bne(A2, A3, l);
    a.halt();
    a.assemble().expect("scalar memcpy assembles")
}

/// Result of one memcpy experiment.
#[derive(Debug, Clone, Copy)]
pub struct MemcpyResult {
    pub throughput: Throughput,
    pub verified: bool,
}

/// Run memcpy on `core` and verify the copy. `bytes` counts the *copied*
/// volume (the paper's Fig. 3 rate is copied bytes per second).
pub fn run(core: &mut Core, bytes: usize, vector: bool) -> Result<MemcpyResult, SimError> {
    let variant = if vector { Variant::Vector } else { Variant::Scalar };
    let mut w = Memcpy::new();
    let report = run_on(&mut w, core, &Scenario::new(variant, bytes))?;
    Ok(MemcpyResult { throughput: report.throughput, verified: report.verified == Some(true) })
}

/// The §4.1 memcpy workload behind the [`Workload`] interface.
/// `Scenario::size` is the copied volume in **bytes** (a multiple of the
/// vector width for the vector variant, of 16 for the scalar one).
pub struct Memcpy {
    plan: Option<Plan>,
}

struct Plan {
    dst: u32,
    /// `[(src, input bytes)]` — also the expected content of `dst`.
    image: Vec<(u32, Vec<u8>)>,
}

impl Memcpy {
    pub fn new() -> Self {
        Self { plan: None }
    }

    fn plan(&self) -> &Plan {
        self.plan.as_ref().expect("Workload::build must run first")
    }
}

impl Default for Memcpy {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for Memcpy {
    fn name(&self) -> &'static str {
        "memcpy"
    }

    fn description(&self) -> &'static str {
        "§4.1 design-space memcpy; size = copied bytes"
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar, Variant::Vector]
    }

    fn required_units(&self, variant: Variant) -> &'static [usize] {
        match variant {
            Variant::Scalar => &[],
            Variant::Vector => &[0],
        }
    }

    fn default_size(&self) -> usize {
        8 * 1024 * 1024
    }

    fn smoke_size(&self) -> usize {
        16 * 1024
    }

    fn elems(&self, sc: &Scenario) -> usize {
        sc.size / 4
    }

    fn buffers(&self, sc: &Scenario) -> (usize, usize) {
        (2, sc.size)
    }

    fn build(&mut self, sc: &Scenario) -> Program {
        let addrs = layout_buffers(2, sc.size);
        let (src, dst) = (addrs[0], addrs[1]);
        let prog = match sc.variant {
            Variant::Vector => build_vector(src, dst, sc.size, sc.vlen_bits),
            Variant::Scalar => build_scalar(src, dst, sc.size),
        };
        let input = random_i32s(sc.size / 4, 0x5EED);
        let image = vec![(src, i32s_to_bytes(&input))];
        self.plan = Some(Plan { dst, image });
        prog
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.plan().image
    }

    fn bytes_moved(&self, sc: &Scenario) -> u64 {
        sc.size as u64
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        let p = self.plan();
        let expect = &p.image[0].1;
        if arch.mem_slice(p.dst, expect.len()) == expect.as_slice() {
            Ok(())
        } else {
            Err(VerifyError::new("copied data differs from source"))
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        let p = self.plan();
        read_i32s(arch, p.dst, p.image[0].1.len() / 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_memcpy_copies_and_is_fast() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 64 * 1024, true).unwrap();
        assert!(r.verified, "copy must be exact");
        // Calibration band (DESIGN.md §7): ≥ 2.5 B/cycle for the 256-bit
        // configuration (paper: 4.6 B/cycle at 0.69 GB/s / 150 MHz).
        let bpc = r.throughput.bytes_per_cycle();
        assert!(bpc > 2.5, "vector memcpy too slow: {bpc:.2} B/cycle");
        assert!(bpc < 8.0, "vector memcpy implausibly fast: {bpc:.2} B/cycle");
    }

    #[test]
    fn scalar_memcpy_copies_correctly() {
        let mut core = Core::paper_default();
        let r = run(&mut core, 16 * 1024, false).unwrap();
        assert!(r.verified);
        let bpc = r.throughput.bytes_per_cycle();
        // STREAM-copy-class rate: paper's 183.4 MB/s at 150 MHz ≈ 1.22 B/c.
        assert!(bpc > 0.6 && bpc < 2.5, "scalar memcpy rate off: {bpc:.2} B/cycle");
    }

    #[test]
    fn vector_beats_scalar_substantially() {
        let mut c1 = Core::paper_default();
        let v = run(&mut c1, 32 * 1024, true).unwrap();
        let mut c2 = Core::paper_default();
        let s = run(&mut c2, 32 * 1024, false).unwrap();
        let ratio = v.throughput.bytes_per_cycle() / s.throughput.bytes_per_cycle();
        assert!(ratio > 2.0, "vector/scalar ratio {ratio:.2}");
    }

    #[test]
    fn wider_vlen_is_faster() {
        let mut slow = Core::for_vlen(128);
        let a = run(&mut slow, 32 * 1024, true).unwrap();
        let mut fast = Core::for_vlen(1024);
        let b = run(&mut fast, 32 * 1024, true).unwrap();
        assert!(
            b.throughput.bytes_per_cycle() > 1.5 * a.throughput.bytes_per_cycle(),
            "1024-bit {:.2} B/c vs 128-bit {:.2} B/c",
            b.throughput.bytes_per_cycle(),
            a.throughput.bytes_per_cycle()
        );
    }
}
