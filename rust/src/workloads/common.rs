//! Shared helpers for the workload programs: buffer layout, host-side
//! data initialisation and throughput accounting.

use crate::arch::ArchState;
use crate::core::{Core, RunResult};
use crate::util::Xoshiro256;

/// Base address for large workload buffers (above code + static data).
pub const BUF_BASE: u32 = 0x0100_0000;

/// Align `addr` up to `align` (power of two).
pub const fn align_up(addr: u32, align: u32) -> u32 {
    (addr + align - 1) & !(align - 1)
}

/// Layout `count` buffers of `bytes` each, LLC-block aligned (2 KiB holds
/// for every explored LLC block size), starting at [`BUF_BASE`].
pub fn layout_buffers(count: usize, bytes: usize) -> Vec<u32> {
    let align = 64 * 1024; // generous: aligned for any explored LLC block
    let mut addrs = Vec::with_capacity(count);
    let mut a = BUF_BASE;
    for _ in 0..count {
        a = align_up(a, align);
        addrs.push(a);
        a += bytes as u32;
    }
    addrs
}

/// `n` deterministic random i32 values for a seed (the host side of
/// [`init_random_i32`]; workloads generate inputs at build time and
/// replay them into a core at init time).
pub fn random_i32s(n: usize, seed: u64) -> Vec<i32> {
    Xoshiro256::seeded(seed).vec_i32(n)
}

/// Little-endian byte image of a slice of i32 values.
pub fn i32s_to_bytes(vals: &[i32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

/// Fill DRAM at `addr` with `n` random i32 values; returns them.
pub fn init_random_i32(core: &mut Core, addr: u32, n: usize, seed: u64) -> Vec<i32> {
    let vals = random_i32s(n, seed);
    core.mem.host_write(addr, &i32s_to_bytes(&vals));
    vals
}

/// Read back `n` i32 values from the architectural memory image of any
/// backend (for a cached `Core`, after `flush_all`).
pub fn read_i32s(arch: &(impl ArchState + ?Sized), addr: u32, n: usize) -> Vec<i32> {
    arch.mem_slice(addr, n * 4)
        .chunks(4)
        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
        .collect()
}

/// Throughput of a run over `bytes_processed` at the core's clock.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub cycles: u64,
    pub instret: u64,
    pub bytes: u64,
    pub fmax_mhz: f64,
}

impl Throughput {
    pub fn from_run(core: &Core, run: &RunResult, bytes: u64) -> Self {
        Self { cycles: run.cycles, instret: run.instret, bytes, fmax_mhz: core.cfg.fmax_mhz }
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes as f64 / self.cycles as f64
    }

    /// Bytes/second at the modelled clock (what Figs. 3–4 plot).
    pub fn bytes_per_second(&self) -> f64 {
        self.bytes_per_cycle() * self.fmax_mhz * 1e6
    }

    pub fn ipc(&self) -> f64 {
        self.instret as f64 / self.cycles as f64
    }
}

/// A watchdog budget generous enough for every scaled workload.
pub const MAX_INSTRS: u64 = 20_000_000_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment() {
        assert_eq!(align_up(0x1001, 0x1000), 0x2000);
        assert_eq!(align_up(0x1000, 0x1000), 0x1000);
    }

    #[test]
    fn buffer_layout_disjoint_and_aligned() {
        let addrs = layout_buffers(3, 100_000);
        for w in addrs.windows(2) {
            assert!(w[1] >= w[0] + 100_000);
        }
        for a in addrs {
            assert_eq!(a % (64 * 1024), 0);
        }
    }

    #[test]
    fn throughput_math() {
        let t = Throughput { cycles: 1000, instret: 500, bytes: 4600, fmax_mhz: 150.0 };
        assert!((t.bytes_per_cycle() - 4.6).abs() < 1e-12);
        assert!((t.bytes_per_second() - 4.6 * 150e6).abs() < 1.0);
        assert!((t.ipc() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn host_init_roundtrip() {
        let mut core = crate::core::Core::paper_default();
        let vals = init_random_i32(&mut core, 0x10000, 64, 7);
        let got = read_i32s(&core, 0x10000, 64);
        assert_eq!(vals, got);
    }
}
