//! Shared helpers for the workload programs: buffer layout, host-side
//! data initialisation and throughput accounting.

use crate::arch::ArchState;
use crate::core::{Core, RunResult};
use crate::util::Xoshiro256;

/// Base address for large workload buffers (above code + static data).
pub const BUF_BASE: u32 = 0x0100_0000;

/// Align `addr` up to `align` (power of two), or `None` when the
/// aligned address no longer fits the 32-bit address space. The naive
/// `(addr + align - 1)` form wraps near 4 GiB and would silently alias
/// a buffer laid out above the boundary back over low memory.
pub const fn align_up(addr: u32, align: u32) -> Option<u32> {
    match addr.checked_add(align - 1) {
        Some(x) => Some(x & !(align - 1)),
        None => None,
    }
}

/// A workload buffer layout does not fit the 32-bit address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayoutError {
    /// Index of the buffer that overflowed.
    pub buffer: usize,
    /// Address the buffer would have started at (cursor before/after
    /// alignment, depending on which step overflowed).
    pub addr: u64,
    pub bytes: usize,
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "buffer {} at {:#x} (+{} bytes) does not fit the 32-bit address space",
            self.buffer, self.addr, self.bytes
        )
    }
}

impl std::error::Error for LayoutError {}

/// Layout `count` buffers of `bytes` each, LLC-block aligned (64 KiB
/// holds for every explored LLC block size), starting at [`BUF_BASE`].
/// Fails instead of wrapping when the layout reaches the 4 GiB boundary.
pub fn try_layout_buffers(count: usize, bytes: usize) -> Result<Vec<u32>, LayoutError> {
    const ALIGN: u32 = 64 * 1024; // generous: aligned for any explored LLC block
    let mut addrs = Vec::with_capacity(count);
    let mut next = BUF_BASE as u64;
    for i in 0..count {
        let base = u32::try_from(next)
            .ok()
            .and_then(|a| align_up(a, ALIGN))
            .ok_or(LayoutError { buffer: i, addr: next, bytes })?;
        let end = base as u64 + bytes as u64;
        if end > 1u64 << 32 {
            return Err(LayoutError { buffer: i, addr: base as u64, bytes });
        }
        addrs.push(base);
        next = end;
    }
    Ok(addrs)
}

/// Infallible form of [`try_layout_buffers`] for the in-repo workloads,
/// whose footprints [`crate::machine::Machine::run`] already bounds via
/// `dram_needed` + config validation; an overflowing layout panics with
/// the [`LayoutError`] instead of silently aliasing buffers.
pub fn layout_buffers(count: usize, bytes: usize) -> Vec<u32> {
    try_layout_buffers(count, bytes).unwrap_or_else(|e| panic!("workload buffer layout: {e}"))
}

/// `n` deterministic random i32 values for a seed (the host side of
/// [`init_random_i32`]; workloads generate inputs at build time and
/// replay them into a core at init time).
pub fn random_i32s(n: usize, seed: u64) -> Vec<i32> {
    Xoshiro256::seeded(seed).vec_i32(n)
}

/// Little-endian byte image of a slice of i32 values.
pub fn i32s_to_bytes(vals: &[i32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

/// Fill DRAM at `addr` with `n` random i32 values; returns them.
pub fn init_random_i32(core: &mut Core, addr: u32, n: usize, seed: u64) -> Vec<i32> {
    let vals = random_i32s(n, seed);
    core.mem.host_write(addr, &i32s_to_bytes(&vals));
    vals
}

/// Read back `n` i32 values from the architectural memory image of any
/// backend (for a cached `Core`, after `flush_all`).
pub fn read_i32s(arch: &(impl ArchState + ?Sized), addr: u32, n: usize) -> Vec<i32> {
    arch.mem_slice(addr, n * 4)
        .chunks(4)
        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
        .collect()
}

/// Throughput of a run over `bytes_processed` at the core's clock.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub cycles: u64,
    pub instret: u64,
    pub bytes: u64,
    pub fmax_mhz: f64,
}

impl Throughput {
    pub fn from_run(core: &Core, run: &RunResult, bytes: u64) -> Self {
        Self { cycles: run.cycles, instret: run.instret, bytes, fmax_mhz: core.cfg.fmax_mhz }
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes as f64 / self.cycles as f64
    }

    /// Bytes/second at the modelled clock (what Figs. 3–4 plot).
    pub fn bytes_per_second(&self) -> f64 {
        self.bytes_per_cycle() * self.fmax_mhz * 1e6
    }

    pub fn ipc(&self) -> f64 {
        self.instret as f64 / self.cycles as f64
    }
}

/// A watchdog budget generous enough for every scaled workload.
pub const MAX_INSTRS: u64 = 20_000_000_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment() {
        assert_eq!(align_up(0x1001, 0x1000), Some(0x2000));
        assert_eq!(align_up(0x1000, 0x1000), Some(0x1000));
    }

    #[test]
    fn align_up_checked_at_the_4gib_boundary() {
        // The last 4 KiB-aligned address is representable...
        assert_eq!(align_up(0xFFFF_F000, 0x1000), Some(0xFFFF_F000));
        // ...but one byte past it, `addr + align - 1` used to wrap to a
        // low address; the checked form refuses instead.
        assert_eq!(align_up(0xFFFF_F001, 0x1000), None);
        assert_eq!(align_up(u32::MAX, 4), None);
        assert_eq!(align_up(u32::MAX, 1), Some(u32::MAX));
    }

    #[test]
    fn layout_rejects_buffers_past_the_4gib_boundary() {
        // One buffer reaching exactly 2^32 fits (its last byte is at
        // 0xFFFF_FFFF)...
        let max_fit = (1u64 << 32) as usize - BUF_BASE as usize;
        assert_eq!(try_layout_buffers(1, max_fit), Ok(vec![BUF_BASE]));
        // ...a second one must be a layout error, not a wrapped cursor
        // aliasing buffer 0.
        let err = try_layout_buffers(2, max_fit).unwrap_err();
        assert_eq!(err.buffer, 1);
        // A single oversized buffer overflows immediately.
        assert!(try_layout_buffers(1, max_fit + 1).is_err());
        // And a mid-layout overflow names the right buffer.
        let err = try_layout_buffers(3, 0x7000_0000).unwrap_err();
        assert_eq!(err.buffer, 2);
    }

    #[test]
    fn buffer_layout_disjoint_and_aligned() {
        let addrs = layout_buffers(3, 100_000);
        for w in addrs.windows(2) {
            assert!(w[1] >= w[0] + 100_000);
        }
        for a in addrs {
            assert_eq!(a % (64 * 1024), 0);
        }
    }

    #[test]
    fn throughput_math() {
        let t = Throughput { cycles: 1000, instret: 500, bytes: 4600, fmax_mhz: 150.0 };
        assert!((t.bytes_per_cycle() - 4.6).abs() < 1e-12);
        assert!((t.bytes_per_second() - 4.6 * 150e6).abs() < 1.0);
        assert!((t.ipc() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn host_init_roundtrip() {
        let mut core = crate::core::Core::paper_default();
        let vals = init_random_i32(&mut core, 0x10000, 64, 7);
        let got = read_i32s(&core, 0x10000, 64);
        assert_eq!(vals, got);
    }
}
