//! The `Workload` abstraction: one uniform surface over every benchmark
//! program in this repository.
//!
//! Historically each workload module exported a bespoke pair of
//! `build_*`/`run_*` free functions and every experiment driver
//! hand-wired `Core::new` + buffer layout + verification. The paper's
//! whole point is *exploration* — swapping reconfigurable SIMD
//! instructions in and out and measuring many workload × configuration
//! points — so the workload surface is now a trait:
//!
//! - [`Workload::build`] assembles the program for a [`Scenario`]
//!   (variant + problem size + vector width) and records, inside the
//!   workload value, everything verification needs (buffer addresses,
//!   input data, expected results);
//! - [`Workload::init`] writes the input image into a core's DRAM
//!   (the default implementation replays [`Workload::init_image`], which
//!   also lets baseline cores like `PicoCore` reuse the same image);
//! - [`Workload::verify`] checks the architectural results after a run;
//! - [`Workload::bytes_moved`] makes throughput accounting uniform, so
//!   every driver reports GB/s the same way Figs. 3–4 do.
//!
//! Workloads are registered by name in [`super::registry`]; a configured
//! simulator is built and driven through [`crate::machine::Machine`],
//! whose `run` method performs the build → load → init → run → verify
//! sequence in one call via [`run_on`].

use super::common::{self, Throughput};
use crate::arch::ArchState;
use crate::asm::Program;
use crate::core::{Core, CoreCounters, SimError};
use crate::mem::MemStats;
use crate::ref_iss::{ExecEngine, RefIss};

/// Which implementation of a workload to run.
///
/// `Vector` is the custom-unit path: the program uses the reconfigurable
/// SIMD instructions (`c0.lv`, `c2.sort`, …) of whatever units
/// [`Workload::required_units`] names. `Scalar` is the plain RV32IM
/// baseline the paper measures against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Scalar,
    Vector,
}

impl Variant {
    pub const ALL: [Variant; 2] = [Variant::Scalar, Variant::Vector];

    pub fn name(self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Vector => "vector",
        }
    }

    /// Parse a CLI spelling ("scalar" / "vector").
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "scalar" => Some(Variant::Scalar),
            "vector" => Some(Variant::Vector),
            _ => None,
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One point of the design space: what to run and at which size.
///
/// `size` is in the workload's natural unit — bytes for `memcpy`,
/// elements for the array workloads, iterations for the Table-2 CPU
/// benches (each workload documents its unit in its `description`).
/// `vlen_bits` is filled in from the machine configuration when the
/// scenario is executed through [`crate::machine::Machine::run`] or
/// [`run_on`]; the value set here only matters when calling
/// [`Workload::build`] directly.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub variant: Variant,
    pub size: usize,
    pub vlen_bits: usize,
}

impl Scenario {
    pub fn new(variant: Variant, size: usize) -> Self {
        Self { variant, size, vlen_bits: 256 }
    }

    pub fn with_vlen(mut self, vlen_bits: usize) -> Self {
        self.vlen_bits = vlen_bits;
        self
    }
}

/// A failed [`Workload::verify`]: what differed from the expectation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError(pub String);

impl VerifyError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "verification failed: {}", self.0)
    }
}

impl std::error::Error for VerifyError {}

/// A benchmark program with scalar and/or custom-SIMD implementations.
///
/// The lifecycle is: `build(&scenario)` (assemble + precompute expected
/// results, stored in `self`) → `init(&mut core)` (write the input
/// image) → run the core → `verify(&core)`. [`run_on`] drives the whole
/// sequence; `build` must have been called before `init`/`verify`/
/// `result_data` are meaningful.
pub trait Workload {
    /// Registry key, e.g. `"memcpy"` or `"stream-triad"`.
    fn name(&self) -> &'static str;

    /// One-line summary (shown by `simdsoftcore list-workloads`),
    /// including the unit of `Scenario::size`.
    fn description(&self) -> &'static str;

    /// The implementations this workload provides.
    fn variants(&self) -> &'static [Variant];

    /// Custom-unit slots (c0..c3) a variant needs loaded. The machine
    /// refuses to run a scenario whose required slots are empty.
    fn required_units(&self, variant: Variant) -> &'static [usize];

    /// Default `Scenario::size` for CLI runs (scaled for seconds-level
    /// wall time, like `Scale::default`).
    fn default_size(&self) -> usize;

    /// A small size every variant accepts on any paper-shaped machine —
    /// used by the registry self-test and CLI smoke runs.
    fn smoke_size(&self) -> usize;

    /// Element count of a scenario (for cycles/element reporting).
    fn elems(&self, sc: &Scenario) -> usize {
        sc.size
    }

    /// Large-buffer footprint as (buffer count, bytes per buffer), used
    /// to auto-size simulated DRAM. Workloads with no heap buffers
    /// return `(0, 0)`.
    fn buffers(&self, sc: &Scenario) -> (usize, usize);

    /// Assemble the program for `sc`, recording the run plan (buffer
    /// addresses, inputs, expected outputs) inside `self`.
    fn build(&mut self, sc: &Scenario) -> Program;

    /// The input memory image produced by the last `build`, as
    /// `(address, bytes)` pairs. Borrowed (full-scale images are
    /// hundreds of MiB) and kept separate from [`Workload::init`] so
    /// non-`Core` targets (the PicoRV32 baseline harness) can replay
    /// the same image.
    fn init_image(&self) -> &[(u32, Vec<u8>)];

    /// Write the input image into the core's DRAM.
    fn init(&mut self, core: &mut Core) {
        for (addr, bytes) in self.init_image() {
            core.mem.host_write(*addr, bytes);
        }
    }

    /// Payload bytes a run of `sc` moves, as the paper counts them
    /// (copied bytes for memcpy, STREAM convention for stream, array
    /// bytes for sort/prefix/filter). Drives `Throughput`.
    fn bytes_moved(&self, sc: &Scenario) -> u64;

    /// Check the architectural results of the last run on any backend
    /// (for a cached `Core` the caller has already flushed the caches;
    /// the reference ISS is always current). Verification is written
    /// against [`ArchState`] so the timed core and the reference ISS
    /// share one oracle — the differential suites depend on this.
    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError>;

    /// Canonical result data of the last run, for cross-variant and
    /// cross-backend agreement checks (scalar and vector
    /// implementations of one workload must produce identical data).
    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32>;
}

/// Uniform result of running one scenario (what `Machine::run` returns).
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub workload: String,
    pub variant: Variant,
    /// `Scenario::size` as requested.
    pub size: usize,
    /// Element count (`Workload::elems`).
    pub elems: usize,
    pub throughput: Throughput,
    /// `Some(outcome)` when verification ran; `None` when the target
    /// cannot be verified (the PicoRV32 baseline harness).
    pub verified: Option<bool>,
    /// Human-readable mismatch description when `verified == Some(false)`.
    pub verify_error: Option<String>,
    /// Memory-system counters at the end of the run.
    pub mem: MemStats,
    /// Core-side retired-instruction and stall counters (zeroed for
    /// targets that do not expose them, like the PicoRV32 harness).
    pub counters: CoreCounters,
}

impl WorkloadReport {
    pub fn cycles_per_elem(&self) -> f64 {
        self.throughput.cycles as f64 / self.elems as f64
    }

    /// Table cell for the verification outcome: "true"/"false"/"-".
    pub fn verified_cell(&self) -> String {
        match self.verified {
            Some(v) => v.to_string(),
            None => "-".to_string(),
        }
    }
}

/// Run `w` on an already-configured core: build → load → init → run →
/// flush → verify, packaging the uniform report. The scenario's
/// `vlen_bits` is overridden by the core's configured width.
pub fn run_on(
    w: &mut dyn Workload,
    core: &mut Core,
    sc: &Scenario,
) -> Result<WorkloadReport, SimError> {
    run_on_budget(w, core, sc, common::MAX_INSTRS)
}

/// [`run_on`] with an explicit retired-instruction budget. The sweep
/// service uses this as its per-point simulation budget: a pathological
/// configuration that blows the budget surfaces as
/// [`SimError`]::Watchdog — a failed point — instead of wedging its
/// worker for hours.
pub fn run_on_budget(
    w: &mut dyn Workload,
    core: &mut Core,
    sc: &Scenario,
    max_instrs: u64,
) -> Result<WorkloadReport, SimError> {
    let sc = Scenario { vlen_bits: core.cfg.vlen_bits, ..*sc };
    let prog = w.build(&sc);
    core.load(&prog)?;
    w.init(core);
    let run = core.run(max_instrs)?;
    let throughput = Throughput::from_run(core, &run, w.bytes_moved(&sc));
    core.mem.flush_all();
    let verify = w.verify(&*core);
    Ok(WorkloadReport {
        workload: w.name().to_string(),
        variant: sc.variant,
        size: sc.size,
        elems: w.elems(&sc),
        throughput,
        verified: Some(verify.is_ok()),
        verify_error: verify.err().map(|e| e.to_string()),
        mem: core.mem.stats(),
        counters: run.counters,
    })
}

/// Run `w` on the architectural-only reference ISS: build → load →
/// init → run → verify, mirroring [`run_on`]. The ISS has no cycle
/// counter, so the report's `cycles` equals `instret` (nominal CPI 1 —
/// a *functional* backend; use the timed core for performance numbers)
/// and the memory/stall counters are zero.
pub fn run_on_iss(
    w: &mut dyn Workload,
    iss: &mut RefIss,
    sc: &Scenario,
) -> Result<WorkloadReport, SimError> {
    run_on_iss_engine(w, iss, sc, ExecEngine::Blocks)
}

/// [`run_on_iss`] with an explicit [`ExecEngine`]. The throughput bench
/// and the engine-identity tests drive this to compare block execution
/// against per-instruction dispatch on the same workload builds.
pub fn run_on_iss_engine(
    w: &mut dyn Workload,
    iss: &mut RefIss,
    sc: &Scenario,
    engine: ExecEngine,
) -> Result<WorkloadReport, SimError> {
    let sc = Scenario { vlen_bits: iss.vlen_bits(), ..*sc };
    let prog = w.build(&sc);
    iss.load(&prog)?;
    for (addr, bytes) in w.init_image() {
        iss.host_write(*addr, bytes)?;
    }
    let run = iss.run_with(common::MAX_INSTRS, engine)?;
    let throughput = Throughput {
        cycles: run.instret,
        instret: run.instret,
        bytes: w.bytes_moved(&sc),
        fmax_mhz: iss.fmax_mhz,
    };
    let verify = w.verify(&*iss);
    Ok(WorkloadReport {
        workload: w.name().to_string(),
        variant: sc.variant,
        size: sc.size,
        elems: w.elems(&sc),
        throughput,
        verified: Some(verify.is_ok()),
        verify_error: verify.err().map(|e| e.to_string()),
        mem: MemStats::default(),
        counters: CoreCounters::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("simd"), None);
    }

    #[test]
    fn scenario_defaults() {
        let sc = Scenario::new(Variant::Vector, 4096);
        assert_eq!(sc.vlen_bits, 256);
        assert_eq!(sc.with_vlen(512).vlen_bits, 512);
    }

    #[test]
    fn verify_error_displays() {
        let e = VerifyError::new("dst mismatch at 3");
        assert_eq!(e.to_string(), "verification failed: dst mismatch at 3");
    }
}
