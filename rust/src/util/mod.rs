//! Dependency-free utilities: deterministic PRNGs, statistics, and a
//! minimal property-testing harness (external crates are unavailable in
//! the offline build).

pub mod prng;
pub mod proptest;
pub mod stats;

pub use prng::{SplitMix64, Xoshiro256};
