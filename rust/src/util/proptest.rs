//! Minimal property-testing scaffolding (the `proptest` crate is not
//! available offline).
//!
//! A property is a closure from a seeded [`Xoshiro256`] to `Result<(), String>`.
//! [`check`] runs it for N independent cases; on failure it reports the
//! failing case's seed so the case can be replayed deterministically:
//!
//! ```
//! use simdsoftcore::util::proptest::check;
//! check("sorting is idempotent", 64, |rng| {
//!     let mut v = rng.vec_u32(100);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```

use super::prng::Xoshiro256;

/// Environment knob: `SIMDSOFTCORE_PROPTEST_CASES` multiplies case counts
/// (e.g. set to 10 for a deep overnight run).
fn case_multiplier() -> u32 {
    std::env::var("SIMDSOFTCORE_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Run `prop` for `cases` independently-seeded random cases.
/// Panics (test failure) with the failing seed on the first counterexample.
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Xoshiro256) -> Result<(), String>,
{
    let cases = cases * case_multiplier();
    for case in 0..cases {
        // Derive a stable per-case seed: replaying `check_one(name, seed)`
        // reproduces the failure exactly.
        let seed = derive_seed(name, case);
        let mut rng = Xoshiro256::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#018x}): {msg}\n\
                 replay with util::proptest::check_one(\"{name}\", {case}, prop)"
            );
        }
    }
}

/// Replay a single case of a property (used when debugging a failure).
pub fn check_one<F>(name: &str, case: u32, mut prop: F)
where
    F: FnMut(&mut Xoshiro256) -> Result<(), String>,
{
    let seed = derive_seed(name, case);
    let mut rng = Xoshiro256::seeded(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' case {case} (seed {seed:#018x}): {msg}");
    }
}

fn derive_seed(name: &str, case: u32) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^ ((case as u64) << 1) ^ 0x9E37_79B9_7F4A_7C15
}

/// Assert helper returning `Err` with a formatted message instead of
/// panicking, so properties compose.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Equality helper with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u32 roundtrips through u64", 32, |rng| {
            let x = rng.next_u32();
            prop_assert_eq!(x, (x as u64) as u32);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_differ_across_cases_and_names() {
        assert_ne!(derive_seed("a", 0), derive_seed("a", 1));
        assert_ne!(derive_seed("a", 0), derive_seed("b", 0));
    }
}
