//! Small statistics helpers for the bench harness and reports.

/// Arithmetic mean of a sample. Returns 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (Bessel-corrected). 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (sorts a copy). 0.0 on empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Minimum of a sample. 0.0 on empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min).min(f64::INFINITY).pipe_finite()
}

/// Maximum of a sample. 0.0 on empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max).pipe_finite()
}

trait PipeFinite {
    fn pipe_finite(self) -> f64;
}
impl PipeFinite for f64 {
    fn pipe_finite(self) -> f64 {
        if self.is_finite() {
            self
        } else {
            0.0
        }
    }
}

/// Geometric mean; ignores non-positive entries. 0.0 on empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Format a byte-per-second rate the way the paper prints it (GB/s or MB/s).
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    // GB/s from 0.5 GB/s up: the paper quotes sub-1 GB/s memcpy rates
    // (e.g. "0.69 GB/s") in GB/s.
    if bytes_per_sec >= 0.5e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.1} MB/s", bytes_per_sec / 1e6)
    } else {
        format!("{:.1} KB/s", bytes_per_sec / 1e3)
    }
}

/// Format a large count with thousands separators for report readability.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&[1.0, 2.0, 10.0]) - 2.0).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-9);
        assert_eq!(stddev(&[5.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        // non-positive ignored
        assert!((geomean(&[-1.0, 2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(0.69e9), "0.69 GB/s");
        assert_eq!(fmt_rate(183.4e6), "183.4 MB/s");
        assert_eq!(fmt_rate(4.8e6), "4.8 MB/s");
        assert_eq!(fmt_rate(900.0), "0.9 KB/s");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }

    #[test]
    fn min_max() {
        assert_eq!(min(&[3.0, 1.0, 2.0]), 1.0);
        assert_eq!(max(&[3.0, 1.0, 2.0]), 3.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }
}
