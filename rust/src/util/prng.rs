//! Deterministic PRNGs used across workload generation, tests and the
//! property-testing scaffolding.
//!
//! No external `rand` crate is available offline, so we carry our own
//! small, well-known generators: SplitMix64 (seeding / streams) and
//! xoshiro256** (bulk generation). Both are reproducible across runs and
//! platforms, which matters because EXPERIMENTS.md records exact numbers.

/// SplitMix64 — tiny, fast, good enough for seeding and for short streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method; `bound` must be non-zero).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// `len` random u32 values.
    pub fn vec_u32(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.next_u32()).collect()
    }

    /// `len` random i32 values.
    pub fn vec_i32(&mut self, len: usize) -> Vec<i32> {
        (0..len).map(|_| self.next_u32() as i32).collect()
    }

    /// `len` random bytes.
    pub fn vec_u8(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.next_u32() as u8).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference stream for seed 1234567 (computed from the canonical
        // SplitMix64 algorithm; stability of this stream is a repo invariant
        // because workloads are generated from it).
        let mut sm = SplitMix64::new(1234567);
        let first = sm.next_u64();
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(first, sm2.next_u64());
        assert_ne!(sm.next_u64(), first);
    }

    #[test]
    fn xoshiro_is_deterministic_per_seed() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_stays_in_bounds() {
        let mut r = Xoshiro256::seeded(7);
        for bound in [1u32, 2, 3, 10, 1000, u32::MAX] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Xoshiro256::seeded(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2000 {
            let v = r.range_u32(5, 8);
            assert!((5..=8).contains(&v));
            saw_lo |= v == 5;
            saw_hi |= v == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
