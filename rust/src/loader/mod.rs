//! RV32 ELF loader (DESIGN.md §13): parse little-endian ELF32
//! executables, materialise their `PT_LOAD` segments (including BSS
//! zero-fill), and lower the result to the repository's [`Program`]
//! image so every execution backend — the timed [`crate::core::Core`],
//! the reference ISS, the PicoRV32 baseline — and the static analyzer
//! accept ELF binaries exactly like builder-assembled listings.
//!
//! The loader is dependency-free by design: it parses only what the
//! simulator needs (ELF header, program headers, and the symbol table
//! for the riscv-tests `tohost`/`fromhost` HTIF convention) and rejects
//! everything it cannot represent with a typed [`LoaderError`] instead
//! of a panic. [`write::write_elf`] is the inverse — a deterministic
//! writer used by the round-trip tests and mirrored by the checked-in
//! compliance-suite generator.

pub mod compliance;
pub mod workload;
pub mod write;

pub use workload::{ElfWorkload, HtifOutcome};

use std::collections::HashMap;
use std::fmt;

use crate::asm::Program;

/// `e_machine` for RISC-V.
pub const EM_RISCV: u16 = 243;
/// `e_type` for an executable.
pub const ET_EXEC: u16 = 2;
/// `p_type` of a loadable segment.
pub const PT_LOAD: u32 = 1;
/// Segment permission bits.
pub const PF_X: u32 = 1;
pub const PF_W: u32 = 2;
pub const PF_R: u32 = 4;
/// `sh_type` of a symbol table.
const SHT_SYMTAB: u32 = 2;

/// Cap on one segment's in-memory size. The address-space check already
/// bounds `memsz` below 4 GiB; this keeps a hostile header from making
/// the loader allocate gigabytes before the simulator would reject the
/// image anyway (simulated DRAM tops out well below this).
pub const MAX_SEGMENT_BYTES: u64 = 256 * 1024 * 1024;

/// Everything the loader can reject. Each variant corresponds to one
/// malformation class in the rejection corpus (`tests/loader_elf.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LoaderError {
    /// File shorter than the 52-byte ELF32 header.
    TruncatedHeader { len: usize },
    /// First four bytes are not `\x7fELF`.
    BadMagic([u8; 4]),
    /// `EI_CLASS` is not ELFCLASS32.
    NotElf32(u8),
    /// `EI_DATA` is not little-endian.
    NotLittleEndian(u8),
    /// `e_type` is not `ET_EXEC` (relocatables/shared objects carry no
    /// load image for a flat simulator).
    NotExecutable(u16),
    /// `e_machine` is not RISC-V.
    WrongMachine(u16),
    /// `e_phentsize` disagrees with the 32-byte ELF32 program header.
    BadPhentSize(u16),
    /// The program-header table runs past the end of the file.
    TruncatedProgramHeaders { index: usize },
    /// A `PT_LOAD` with `p_memsz == 0` loads nothing; the linkers this
    /// loader supports never emit one, so it flags a corrupt image.
    ZeroSizedSegment { index: usize },
    /// `p_filesz > p_memsz` is unrepresentable (file bytes past the
    /// segment's memory image).
    FileszExceedsMemsz { index: usize, filesz: u32, memsz: u32 },
    /// Segment file bytes run past the end of the file.
    TruncatedSegment { index: usize, offset: u32, filesz: u32, len: usize },
    /// `p_vaddr + p_memsz` crosses the top of the 32-bit address space.
    SegmentOutOfAddressSpace { index: usize, vaddr: u32, memsz: u32 },
    /// Segment larger than [`MAX_SEGMENT_BYTES`].
    SegmentTooLarge { index: usize, memsz: u32 },
    /// Two `PT_LOAD` segments overlap in memory.
    OverlappingSegments { first: u32, second: u32 },
    /// No executable (`PF_X`) segment in the image.
    NoTextSegment,
    /// `e_entry` is not word-aligned.
    MisalignedEntry { entry: u32 },
    /// The executable segment does not start on a word boundary, so it
    /// cannot become the word-granular text image.
    MisalignedTextSegment { vaddr: u32 },
    /// `e_entry` does not fall inside any executable segment.
    EntryOutsideText { entry: u32 },
    /// Non-text segments span more than [`MAX_SEGMENT_BYTES`] once
    /// merged into the single data blob of a [`Program`].
    DataSpanTooLarge { span: u64 },
    /// The riscv-tests HTIF convention requires a `tohost` symbol
    /// (raised by [`ElfWorkload`], not by segment loading).
    MissingTohost,
    /// Reading the file failed.
    Io { path: String, msg: String },
}

impl fmt::Display for LoaderError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use LoaderError::*;
        match self {
            TruncatedHeader { len } => {
                write!(f, "file is {len} bytes, shorter than the 52-byte ELF32 header")
            }
            BadMagic(m) => write!(f, "bad ELF magic {m:02x?}"),
            NotElf32(c) => write!(f, "EI_CLASS {c} is not ELFCLASS32"),
            NotLittleEndian(d) => write!(f, "EI_DATA {d} is not little-endian"),
            NotExecutable(t) => write!(f, "e_type {t} is not ET_EXEC"),
            WrongMachine(m) => write!(f, "e_machine {m} is not RISC-V ({EM_RISCV})"),
            BadPhentSize(s) => write!(f, "e_phentsize {s} is not the ELF32 value 32"),
            TruncatedProgramHeaders { index } => {
                write!(f, "program header {index} runs past the end of the file")
            }
            ZeroSizedSegment { index } => write!(f, "PT_LOAD segment {index} has p_memsz == 0"),
            FileszExceedsMemsz { index, filesz, memsz } => write!(
                f,
                "segment {index} has p_filesz {filesz:#x} > p_memsz {memsz:#x}"
            ),
            TruncatedSegment { index, offset, filesz, len } => write!(
                f,
                "segment {index} claims bytes [{offset:#x}, {:#x}) but the file is {len} bytes",
                *offset as u64 + *filesz as u64
            ),
            SegmentOutOfAddressSpace { index, vaddr, memsz } => write!(
                f,
                "segment {index} at {vaddr:#010x}+{memsz:#x} crosses the 32-bit address space"
            ),
            SegmentTooLarge { index, memsz } => write!(
                f,
                "segment {index} p_memsz {memsz:#x} exceeds the {MAX_SEGMENT_BYTES:#x}-byte cap"
            ),
            OverlappingSegments { first, second } => write!(
                f,
                "PT_LOAD segments at {first:#010x} and {second:#010x} overlap in memory"
            ),
            NoTextSegment => write!(f, "no executable (PF_X) PT_LOAD segment"),
            MisalignedEntry { entry } => {
                write!(f, "entry point {entry:#010x} is not word-aligned")
            }
            MisalignedTextSegment { vaddr } => {
                write!(f, "executable segment at {vaddr:#010x} is not word-aligned")
            }
            EntryOutsideText { entry } => write!(
                f,
                "entry point {entry:#010x} falls outside every executable segment"
            ),
            DataSpanTooLarge { span } => write!(
                f,
                "data segments span {span:#x} bytes, over the {MAX_SEGMENT_BYTES:#x}-byte cap"
            ),
            MissingTohost => write!(
                f,
                "no `tohost` symbol — the riscv-tests HTIF convention needs one to report \
                 pass/fail"
            ),
            Io { path, msg } => write!(f, "reading {path}: {msg}"),
        }
    }
}

impl std::error::Error for LoaderError {}

/// One materialised `PT_LOAD` segment: `data` is `p_memsz` bytes long —
/// the file bytes followed by the BSS zero fill.
#[derive(Debug, Clone)]
pub struct Segment {
    pub vaddr: u32,
    pub flags: u32,
    pub filesz: usize,
    pub data: Vec<u8>,
}

impl Segment {
    pub fn executable(&self) -> bool {
        self.flags & PF_X != 0
    }

    /// Address one past the end of the segment (u64: a segment may end
    /// exactly at the 4 GiB boundary).
    pub fn end(&self) -> u64 {
        self.vaddr as u64 + self.data.len() as u64
    }

    fn contains(&self, addr: u32) -> bool {
        self.vaddr <= addr && (addr as u64) < self.end()
    }
}

/// A parsed ELF32 executable: entry point, loadable segments sorted by
/// address, and the symbol table (best-effort — an image without
/// sections simply has no symbols).
#[derive(Debug, Clone)]
pub struct LoadedElf {
    pub entry: u32,
    pub segments: Vec<Segment>,
    pub symbols: HashMap<String, u32>,
}

fn u16_at(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([b[off], b[off + 1]])
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an ELF32 image into its loadable segments and symbols.
pub fn parse_elf(bytes: &[u8]) -> Result<LoadedElf, LoaderError> {
    if bytes.len() < 52 {
        return Err(LoaderError::TruncatedHeader { len: bytes.len() });
    }
    let magic = [bytes[0], bytes[1], bytes[2], bytes[3]];
    if magic != [0x7f, b'E', b'L', b'F'] {
        return Err(LoaderError::BadMagic(magic));
    }
    if bytes[4] != 1 {
        return Err(LoaderError::NotElf32(bytes[4]));
    }
    if bytes[5] != 1 {
        return Err(LoaderError::NotLittleEndian(bytes[5]));
    }
    let e_type = u16_at(bytes, 16);
    if e_type != ET_EXEC {
        return Err(LoaderError::NotExecutable(e_type));
    }
    let e_machine = u16_at(bytes, 18);
    if e_machine != EM_RISCV {
        return Err(LoaderError::WrongMachine(e_machine));
    }
    let entry = u32_at(bytes, 24);
    let phoff = u32_at(bytes, 28) as u64;
    let phentsize = u16_at(bytes, 42);
    let phnum = u16_at(bytes, 44) as usize;
    if phnum > 0 && phentsize != 32 {
        return Err(LoaderError::BadPhentSize(phentsize));
    }

    let mut segments: Vec<Segment> = Vec::new();
    for i in 0..phnum {
        let off = phoff + (i as u64) * 32;
        if off + 32 > bytes.len() as u64 {
            return Err(LoaderError::TruncatedProgramHeaders { index: i });
        }
        let off = off as usize;
        let p_type = u32_at(bytes, off);
        if p_type != PT_LOAD {
            continue;
        }
        let p_offset = u32_at(bytes, off + 4);
        let p_vaddr = u32_at(bytes, off + 8);
        let p_filesz = u32_at(bytes, off + 16);
        let p_memsz = u32_at(bytes, off + 20);
        let p_flags = u32_at(bytes, off + 24);
        if p_memsz == 0 {
            return Err(LoaderError::ZeroSizedSegment { index: i });
        }
        if p_filesz > p_memsz {
            return Err(LoaderError::FileszExceedsMemsz {
                index: i,
                filesz: p_filesz,
                memsz: p_memsz,
            });
        }
        // End-of-range rules in u64: both the file range and the memory
        // range are checked against wraparound, matching the
        // simulator's MemWrap contract at the 4 GiB boundary.
        if p_vaddr as u64 + p_memsz as u64 > 1 << 32 {
            return Err(LoaderError::SegmentOutOfAddressSpace {
                index: i,
                vaddr: p_vaddr,
                memsz: p_memsz,
            });
        }
        if p_memsz as u64 > MAX_SEGMENT_BYTES {
            return Err(LoaderError::SegmentTooLarge { index: i, memsz: p_memsz });
        }
        if p_offset as u64 + p_filesz as u64 > bytes.len() as u64 {
            return Err(LoaderError::TruncatedSegment {
                index: i,
                offset: p_offset,
                filesz: p_filesz,
                len: bytes.len(),
            });
        }
        let mut data = vec![0u8; p_memsz as usize];
        let file = &bytes[p_offset as usize..(p_offset + p_filesz) as usize];
        data[..file.len()].copy_from_slice(file);
        segments.push(Segment {
            vaddr: p_vaddr,
            flags: p_flags,
            filesz: p_filesz as usize,
            data,
        });
    }
    segments.sort_by_key(|s| s.vaddr);
    for pair in segments.windows(2) {
        if pair[0].end() > pair[1].vaddr as u64 {
            return Err(LoaderError::OverlappingSegments {
                first: pair[0].vaddr,
                second: pair[1].vaddr,
            });
        }
    }

    Ok(LoadedElf { entry, segments, symbols: parse_symbols(bytes) })
}

/// Best-effort symbol-table read: `.symtab` entries resolved through
/// the string table `sh_link` names. Malformed or absent section
/// headers yield an empty map rather than a load failure — segments
/// alone are enough to *run* an image; symbols are only needed for the
/// HTIF convention, which reports their absence separately.
fn parse_symbols(bytes: &[u8]) -> HashMap<String, u32> {
    let mut symbols = HashMap::new();
    let shoff = u32_at(bytes, 32) as u64;
    let shentsize = u16_at(bytes, 46) as u64;
    let shnum = u16_at(bytes, 48) as u64;
    if shoff == 0 || shentsize != 40 {
        return symbols;
    }
    let section = |idx: u64| -> Option<(u32, u32, u32, u32, u32)> {
        let off = shoff.checked_add(idx.checked_mul(40)?)?;
        if off + 40 > bytes.len() as u64 {
            return None;
        }
        let off = off as usize;
        // (sh_type, sh_offset, sh_size, sh_link, sh_entsize)
        Some((
            u32_at(bytes, off + 4),
            u32_at(bytes, off + 16),
            u32_at(bytes, off + 20),
            u32_at(bytes, off + 24),
            u32_at(bytes, off + 36),
        ))
    };
    for idx in 0..shnum {
        let Some((sh_type, sym_off, sym_size, sh_link, entsize)) = section(idx) else {
            continue;
        };
        if sh_type != SHT_SYMTAB || entsize != 16 {
            continue;
        }
        let Some((_, str_off, str_size, _, _)) = section(sh_link as u64) else { continue };
        if str_off as u64 + str_size as u64 > bytes.len() as u64 {
            continue;
        }
        let strtab = &bytes[str_off as usize..][..str_size as usize];
        let count = (sym_size / 16) as u64;
        for k in 0..count {
            let off = sym_off as u64 + k * 16;
            if off + 16 > bytes.len() as u64 {
                break;
            }
            let off = off as usize;
            let st_name = u32_at(bytes, off) as usize;
            let st_value = u32_at(bytes, off + 4);
            let Some(tail) = strtab.get(st_name..) else { continue };
            let name_len = tail.iter().position(|&b| b == 0).unwrap_or(tail.len());
            if name_len == 0 {
                continue;
            }
            if let Ok(name) = std::str::from_utf8(&tail[..name_len]) {
                symbols.insert(name.to_string(), st_value);
            }
        }
    }
    symbols
}

/// Lower a parsed ELF to the simulator's [`Program`] image: the
/// executable segment containing the entry point becomes the
/// word-granular text; every other `PT_LOAD` is merged (zero-gapped)
/// into the single data blob.
pub fn to_program(elf: &LoadedElf) -> Result<Program, LoaderError> {
    if elf.entry % 4 != 0 {
        return Err(LoaderError::MisalignedEntry { entry: elf.entry });
    }
    if !elf.segments.iter().any(Segment::executable) {
        return Err(LoaderError::NoTextSegment);
    }
    let text_idx = elf
        .segments
        .iter()
        .position(|s| s.executable() && s.contains(elf.entry))
        .ok_or(LoaderError::EntryOutsideText { entry: elf.entry })?;
    let text_seg = &elf.segments[text_idx];
    if text_seg.vaddr % 4 != 0 {
        return Err(LoaderError::MisalignedTextSegment { vaddr: text_seg.vaddr });
    }
    let mut text = Vec::with_capacity(text_seg.data.len().div_ceil(4));
    for chunk in text_seg.data.chunks(4) {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        text.push(u32::from_le_bytes(w));
    }

    let rest: Vec<&Segment> = elf
        .segments
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != text_idx)
        .map(|(_, s)| s)
        .collect();
    let (data_base, data) = if rest.is_empty() {
        // No data segments: an empty blob placed right after the text so
        // image-size accounting stays exact (the cast only wraps for a
        // text segment ending exactly at 4 GiB, where an empty blob's
        // base is irrelevant).
        (text_seg.end() as u32, Vec::new())
    } else {
        let base = rest.iter().map(|s| s.vaddr).min().expect("non-empty");
        let end = rest.iter().map(|s| s.end()).max().expect("non-empty");
        let span = end - base as u64;
        if span > MAX_SEGMENT_BYTES {
            return Err(LoaderError::DataSpanTooLarge { span });
        }
        let mut blob = vec![0u8; span as usize];
        for s in &rest {
            let at = (s.vaddr - base) as usize;
            blob[at..at + s.data.len()].copy_from_slice(&s.data);
        }
        (base, blob)
    };

    Ok(Program {
        text_base: text_seg.vaddr,
        text,
        data_base,
        data,
        symbols: elf.symbols.clone(),
        entry: elf.entry,
    })
}

/// Parse an ELF image and lower it to a [`Program`] in one call.
pub fn load_program(bytes: &[u8]) -> Result<Program, LoaderError> {
    to_program(&parse_elf(bytes)?)
}

/// [`load_program`] from a file path.
pub fn load_file(path: &std::path::Path) -> Result<Program, LoaderError> {
    let bytes = std::fs::read(path).map_err(|e| LoaderError::Io {
        path: path.display().to_string(),
        msg: e.to_string(),
    })?;
    load_program(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal hand-rolled two-segment ELF: 8 text bytes at 0x1000
    /// (addi a0,a0,1; ecall), 4 file data bytes + 4 BSS bytes at 0x2000.
    fn tiny_elf() -> Vec<u8> {
        let text: [u32; 2] = [0x0015_0513, 0x0000_0073];
        let data: [u8; 4] = [1, 2, 3, 4];
        let mut f = vec![0u8; 52];
        f[0..4].copy_from_slice(&[0x7f, b'E', b'L', b'F']);
        f[4] = 1; // ELFCLASS32
        f[5] = 1; // little-endian
        f[6] = 1; // EV_CURRENT
        f[16..18].copy_from_slice(&ET_EXEC.to_le_bytes());
        f[18..20].copy_from_slice(&EM_RISCV.to_le_bytes());
        f[20..24].copy_from_slice(&1u32.to_le_bytes());
        f[24..28].copy_from_slice(&0x1000u32.to_le_bytes()); // e_entry
        f[28..32].copy_from_slice(&52u32.to_le_bytes()); // e_phoff
        f[40..42].copy_from_slice(&52u16.to_le_bytes()); // e_ehsize
        f[42..44].copy_from_slice(&32u16.to_le_bytes()); // e_phentsize
        f[44..46].copy_from_slice(&2u16.to_le_bytes()); // e_phnum
        let text_off = 52 + 2 * 32;
        let data_off = text_off + 8;
        let phdr = |p_off: u32, vaddr: u32, filesz: u32, memsz: u32, flags: u32| {
            let mut p = vec![0u8; 32];
            p[0..4].copy_from_slice(&PT_LOAD.to_le_bytes());
            p[4..8].copy_from_slice(&p_off.to_le_bytes());
            p[8..12].copy_from_slice(&vaddr.to_le_bytes());
            p[12..16].copy_from_slice(&vaddr.to_le_bytes());
            p[16..20].copy_from_slice(&filesz.to_le_bytes());
            p[20..24].copy_from_slice(&memsz.to_le_bytes());
            p[24..28].copy_from_slice(&flags.to_le_bytes());
            p[28..32].copy_from_slice(&4u32.to_le_bytes());
            p
        };
        f.extend(phdr(text_off as u32, 0x1000, 8, 8, PF_R | PF_X));
        f.extend(phdr(data_off as u32, 0x2000, 4, 8, PF_R | PF_W));
        for w in text {
            f.extend(w.to_le_bytes());
        }
        f.extend(data);
        f
    }

    #[test]
    fn parses_segments_with_bss_zero_fill() {
        let elf = parse_elf(&tiny_elf()).unwrap();
        assert_eq!(elf.entry, 0x1000);
        assert_eq!(elf.segments.len(), 2);
        assert!(elf.segments[0].executable());
        assert_eq!(elf.segments[1].data, vec![1, 2, 3, 4, 0, 0, 0, 0]);
        assert_eq!(elf.segments[1].filesz, 4);
    }

    #[test]
    fn lowers_to_a_program() {
        let p = load_program(&tiny_elf()).unwrap();
        assert_eq!(p.text_base, 0x1000);
        assert_eq!(p.text, vec![0x0015_0513, 0x0000_0073]);
        assert_eq!(p.data_base, 0x2000);
        assert_eq!(p.data, vec![1, 2, 3, 4, 0, 0, 0, 0]);
        assert_eq!(p.entry, 0x1000);
    }

    #[test]
    fn rejects_malformed_headers() {
        assert!(matches!(
            parse_elf(&tiny_elf()[..40]),
            Err(LoaderError::TruncatedHeader { len: 40 })
        ));
        let mut bad = tiny_elf();
        bad[0] = 0x7e;
        assert!(matches!(parse_elf(&bad), Err(LoaderError::BadMagic(_))));
        let mut bad = tiny_elf();
        bad[4] = 2; // ELFCLASS64
        assert!(matches!(parse_elf(&bad), Err(LoaderError::NotElf32(2))));
        let mut bad = tiny_elf();
        bad[18] = 0x3e; // EM_X86_64
        bad[19] = 0;
        assert!(matches!(parse_elf(&bad), Err(LoaderError::WrongMachine(0x3e))));
    }

    #[test]
    fn rejects_segment_crossing_the_address_space() {
        let mut bad = tiny_elf();
        // Second phdr's vaddr → 0xFFFF_FFFC with memsz 8: end wraps.
        let off = 52 + 32;
        bad[off + 8..off + 12].copy_from_slice(&0xFFFF_FFFCu32.to_le_bytes());
        assert!(matches!(
            parse_elf(&bad),
            Err(LoaderError::SegmentOutOfAddressSpace { vaddr: 0xFFFF_FFFC, .. })
        ));
        // ... but ending exactly at the boundary parses.
        let mut edge = tiny_elf();
        edge[off + 8..off + 12].copy_from_slice(&0xFFFF_FFF8u32.to_le_bytes());
        assert!(parse_elf(&edge).is_ok());
    }

    #[test]
    fn rejects_overlapping_segments() {
        let mut bad = tiny_elf();
        let off = 52 + 32;
        bad[off + 8..off + 12].copy_from_slice(&0x1004u32.to_le_bytes());
        assert!(matches!(
            parse_elf(&bad),
            Err(LoaderError::OverlappingSegments { first: 0x1000, second: 0x1004 })
        ));
    }

    #[test]
    fn rejects_entry_outside_text() {
        let mut bad = tiny_elf();
        bad[24..28].copy_from_slice(&0x2000u32.to_le_bytes());
        assert!(matches!(
            load_program(&bad),
            Err(LoaderError::EntryOutsideText { entry: 0x2000 })
        ));
        let mut bad = tiny_elf();
        bad[24..28].copy_from_slice(&0x1002u32.to_le_bytes());
        assert!(matches!(load_program(&bad), Err(LoaderError::MisalignedEntry { .. })));
    }
}
