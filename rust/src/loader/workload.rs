//! ELF binaries as first-class [`Workload`]s.
//!
//! An [`ElfWorkload`] wraps a loaded ELF [`Program`] and verifies runs
//! through the riscv-tests HTIF convention: the program owns a
//! word-sized `tohost` location, writes `1` on pass or
//! `(testnum << 1) | 1` on the first failing check, then executes the
//! halting `ecall` (this simulator's return-to-host). That makes a
//! prebuilt compliance binary runnable through every existing surface —
//! `Machine::run` on the timed core or the reference ISS, the
//! `run-workload --elf` CLI, and the differential suites — with
//! `verified` meaning "the binary reported HTIF pass".

use std::path::Path;

use super::LoaderError;
use crate::arch::ArchState;
use crate::asm::Program;
use crate::workloads::workload::{Scenario, Variant, VerifyError, Workload};

/// What a run reported through its `tohost` word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HtifOutcome {
    /// `tohost == 1`.
    Pass,
    /// `tohost == (testnum << 1) | 1` with `testnum != 0`.
    Fail { testnum: u32 },
    /// `tohost` still holds its initial value — the program halted (or
    /// faulted) without reporting.
    NotReported,
}

impl HtifOutcome {
    /// Classify a final `tohost` word.
    pub fn from_tohost(tohost: u32) -> Self {
        match tohost {
            0 => HtifOutcome::NotReported,
            1 => HtifOutcome::Pass,
            t => HtifOutcome::Fail { testnum: t >> 1 },
        }
    }
}

/// A prebuilt ELF binary, runnable as a registry-shaped workload.
pub struct ElfWorkload {
    name: &'static str,
    program: Program,
    tohost: u32,
    image: Vec<(u32, Vec<u8>)>,
}

impl ElfWorkload {
    /// Load an ELF image; `name` labels reports (for files, the stem).
    /// Requires the `tohost` symbol of the HTIF convention.
    pub fn from_bytes(name: &str, bytes: &[u8]) -> Result<Self, LoaderError> {
        let program = super::load_program(bytes)?;
        let tohost = *program.symbols.get("tohost").ok_or(LoaderError::MissingTohost)?;
        Ok(Self {
            // Workload::name returns &'static str; compliance binaries
            // are few and live for the whole process, so leaking the
            // name is the honest cost of joining the trait surface.
            name: Box::leak(name.to_string().into_boxed_str()),
            program,
            tohost,
            image: Vec::new(),
        })
    }

    /// Load an ELF file, labelled by its file stem.
    pub fn from_file(path: &Path) -> Result<Self, LoaderError> {
        let bytes = std::fs::read(path).map_err(|e| LoaderError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("elf");
        Self::from_bytes(name, &bytes)
    }

    /// Address of the `tohost` word.
    pub fn tohost_addr(&self) -> u32 {
        self.tohost
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Read the HTIF outcome from a halted backend's memory.
    pub fn htif(&self, arch: &dyn ArchState) -> Result<HtifOutcome, VerifyError> {
        let end = self.tohost as u64 + 4;
        if self.tohost % 4 != 0 {
            return Err(VerifyError::new(format!(
                "tohost {:#010x} is not word-aligned",
                self.tohost
            )));
        }
        if end > arch.mem_size() as u64 {
            return Err(VerifyError::new(format!(
                "tohost {:#010x} is outside the {} bytes of simulated DRAM",
                self.tohost,
                arch.mem_size()
            )));
        }
        let b = arch.mem_slice(self.tohost, 4);
        Ok(HtifOutcome::from_tohost(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
    }
}

impl Workload for ElfWorkload {
    fn name(&self) -> &'static str {
        self.name
    }

    fn description(&self) -> &'static str {
        "prebuilt RV32 ELF binary (riscv-tests HTIF convention; size = text words)"
    }

    fn variants(&self) -> &'static [Variant] {
        &[Variant::Scalar]
    }

    fn required_units(&self, _variant: Variant) -> &'static [usize] {
        &[]
    }

    fn default_size(&self) -> usize {
        self.program.text.len().max(1)
    }

    fn smoke_size(&self) -> usize {
        self.default_size()
    }

    /// Footprint hint so `Machine::run` auto-sizes DRAM over the image
    /// end and the `tohost` word, wherever the binary was linked.
    fn buffers(&self, _sc: &Scenario) -> (usize, usize) {
        let image_end = (self.program.text_end() as u64)
            .max(self.program.data_base as u64 + self.program.data.len() as u64)
            .max(self.tohost as u64 + 4);
        let covered = crate::workloads::common::BUF_BASE as u64 + 128 * 1024;
        (1, image_end.saturating_sub(covered) as usize)
    }

    fn build(&mut self, _sc: &Scenario) -> Program {
        self.program.clone()
    }

    fn init_image(&self) -> &[(u32, Vec<u8>)] {
        &self.image
    }

    fn bytes_moved(&self, _sc: &Scenario) -> u64 {
        0
    }

    fn verify(&self, arch: &dyn ArchState) -> Result<(), VerifyError> {
        match self.htif(arch)? {
            HtifOutcome::Pass => Ok(()),
            HtifOutcome::Fail { testnum } => Err(VerifyError::new(format!(
                "HTIF fail: test {testnum} (tohost = {:#x})",
                (testnum << 1) | 1
            ))),
            HtifOutcome::NotReported => {
                Err(VerifyError::new("program halted without writing tohost"))
            }
        }
    }

    fn result_data(&self, arch: &dyn ArchState) -> Vec<i32> {
        match self.htif(arch) {
            Ok(HtifOutcome::Pass) => vec![1],
            Ok(HtifOutcome::Fail { testnum }) => vec![((testnum << 1) | 1) as i32],
            _ => vec![0],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;
    use crate::loader::write::write_elf;
    use crate::machine::{Backend, Machine};

    /// Build a tiny HTIF program: write `tohost_value` to `tohost`, halt.
    fn htif_elf(tohost_value: i64) -> Vec<u8> {
        let mut a = Asm::new();
        let tohost = a.words("tohost", &[0]);
        a.words("fromhost", &[0]);
        a.la(T0, tohost);
        a.li(T1, tohost_value);
        a.sw(T1, 0, T0);
        a.halt();
        write_elf(&a.assemble().unwrap())
    }

    #[test]
    fn pass_and_fail_verify_through_htif() {
        let mut w = ElfWorkload::from_bytes("pass", &htif_elf(1)).unwrap();
        let sc = Scenario::new(Variant::Scalar, w.default_size());
        let r = Machine::paper_default().run(&mut w, &sc).unwrap();
        assert_eq!(r.verified, Some(true));

        // tohost = (3 << 1) | 1: test 3 failed.
        let mut w = ElfWorkload::from_bytes("fail", &htif_elf(7)).unwrap();
        let r = Machine::paper_default().run(&mut w, &sc).unwrap();
        assert_eq!(r.verified, Some(false));
        assert!(r.verify_error.as_deref().unwrap_or("").contains("test 3"), "{r:?}");
    }

    #[test]
    fn both_backends_agree_on_htif() {
        for backend in [Backend::Timed, Backend::RefIss] {
            let mut w = ElfWorkload::from_bytes("pass", &htif_elf(1)).unwrap();
            let sc = Scenario::new(Variant::Scalar, w.default_size());
            let r = Machine::paper_default().backend(backend).run(&mut w, &sc).unwrap();
            assert_eq!(r.verified, Some(true), "{backend:?}");
        }
    }

    #[test]
    fn silent_halt_is_a_verification_failure() {
        let mut a = Asm::new();
        a.words("tohost", &[0]);
        a.li(A0, 1);
        a.halt();
        let bytes = write_elf(&a.assemble().unwrap());
        let mut w = ElfWorkload::from_bytes("silent", &bytes).unwrap();
        let sc = Scenario::new(Variant::Scalar, w.default_size());
        let r = Machine::paper_default().run(&mut w, &sc).unwrap();
        assert_eq!(r.verified, Some(false));
        assert!(r.verify_error.as_deref().unwrap_or("").contains("without writing"), "{r:?}");
    }

    #[test]
    fn missing_tohost_is_rejected() {
        let mut a = Asm::new();
        a.li(A0, 1);
        a.halt();
        let bytes = write_elf(&a.assemble().unwrap());
        assert!(matches!(
            ElfWorkload::from_bytes("x", &bytes),
            Err(LoaderError::MissingTohost)
        ));
    }
}
