//! Compliance-suite runner: every checked-in rv32ui/rv32um riscv-tests
//! ELF, run on the timed core *and* the reference ISS, with the static
//! analyzer as a pre-flight.
//!
//! The suite's contract is differential: a binary's HTIF pass/fail must
//! be identical on both backends. A mismatch means the two execution
//! engines disagree about RV32IM architecture — exactly the class of
//! bug the lockstep fuzzer hunts, but pinned to a named, replayable
//! compliance test. The binaries live in `rust/tests/compliance/` and
//! are generated (and independently self-verified) by the checked-in
//! `gen_compliance.py`, so CI needs no cross-compilation toolchain.

use std::path::{Path, PathBuf};

use super::workload::ElfWorkload;
use crate::analysis::{self, AnalysisConfig};
use crate::machine::{Backend, Machine};
use crate::workloads::workload::{Scenario, Variant, Workload};

/// One backend's result for one binary.
#[derive(Debug, Clone)]
pub struct BackendOutcome {
    /// HTIF pass (`verified == Some(true)`); simulation errors count as
    /// a fail with the error text in `detail`.
    pub pass: bool,
    /// "pass", the HTIF failure message, or the simulation error.
    pub detail: String,
    pub instret: u64,
}

/// One compliance binary's row: both backends plus the analyzer.
#[derive(Debug, Clone)]
pub struct ComplianceRow {
    pub name: String,
    pub core: BackendOutcome,
    pub iss: BackendOutcome,
    /// Error-severity findings from the static analyzer (warnings are
    /// allowed — compliance programs legitimately read BSS, for
    /// example).
    pub analyzer_errors: usize,
}

impl ComplianceRow {
    /// Whether the two backends disagree on pass/fail — the property
    /// the suite exists to check.
    pub fn mismatch(&self) -> bool {
        self.core.pass != self.iss.pass
    }
}

/// The whole suite's results.
#[derive(Debug, Clone, Default)]
pub struct ComplianceReport {
    pub rows: Vec<ComplianceRow>,
}

impl ComplianceReport {
    pub fn mismatches(&self) -> impl Iterator<Item = &ComplianceRow> {
        self.rows.iter().filter(|r| r.mismatch())
    }

    pub fn failures(&self) -> impl Iterator<Item = &ComplianceRow> {
        self.rows.iter().filter(|r| !r.core.pass || !r.iss.pass)
    }

    pub fn all_passed(&self) -> bool {
        !self.rows.is_empty() && self.rows.iter().all(|r| r.core.pass && r.iss.pass)
    }
}

fn run_backend(path: &Path, backend: Backend) -> BackendOutcome {
    let mut w = match ElfWorkload::from_file(path) {
        Ok(w) => w,
        Err(e) => {
            return BackendOutcome { pass: false, detail: format!("load: {e}"), instret: 0 }
        }
    };
    let sc = Scenario::new(Variant::Scalar, w.default_size());
    match Machine::paper_default().backend(backend).run(&mut w, &sc) {
        Ok(r) => BackendOutcome {
            pass: r.verified == Some(true),
            detail: r.verify_error.unwrap_or_else(|| "pass".into()),
            instret: r.throughput.instret,
        },
        Err(e) => BackendOutcome { pass: false, detail: e.to_string(), instret: 0 },
    }
}

/// Run one compliance binary on both backends and the analyzer.
pub fn run_elf(path: &Path) -> ComplianceRow {
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("elf")
        .to_string();
    let analyzer_errors = match ElfWorkload::from_file(path) {
        Ok(w) => {
            let cfg = AnalysisConfig::default();
            analysis::analyze_program(w.program(), &cfg).error_count()
        }
        Err(_) => 0, // the load failure already surfaces per backend
    };
    ComplianceRow {
        name,
        core: run_backend(path, Backend::Timed),
        iss: run_backend(path, Backend::RefIss),
        analyzer_errors,
    }
}

/// Every `*.elf` under `dir`, in name order.
pub fn suite_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("reading {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "elf"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("no .elf files under {}", dir.display()));
    }
    Ok(files)
}

/// Run the full suite under `dir`.
pub fn run_suite(dir: &Path) -> Result<ComplianceReport, String> {
    let mut report = ComplianceReport::default();
    for path in suite_files(dir)? {
        report.rows.push(run_elf(&path));
    }
    Ok(report)
}

/// Default on-disk location of the checked-in suite, relative to the
/// repository layout (`rust/tests/compliance`). The CLI resolves it
/// from the working directory; tests use `CARGO_MANIFEST_DIR`.
pub fn default_dir() -> PathBuf {
    let in_rust = PathBuf::from("tests/compliance");
    if in_rust.is_dir() {
        in_rust
    } else {
        PathBuf::from("rust/tests/compliance")
    }
}
