//! Deterministic ELF32 writer: the inverse of [`super::parse_elf`].
//!
//! [`write_elf`] serialises a [`Program`] as a little-endian RV32
//! `ET_EXEC` image with one executable text segment, one read/write
//! data segment (omitted when the program has no data), and a symbol
//! table carrying every `Program` symbol. The round-trip property —
//! `load_program(write_elf(p))` reproduces `p`'s memory image bit for
//! bit — is asserted by `tests/loader_elf.rs`, and the checked-in
//! compliance-suite generator (`tests/compliance/gen_compliance.py`)
//! emits the same layout so the suite exercises exactly the shape this
//! writer defines.

use super::{EM_RISCV, ET_EXEC, PF_R, PF_W, PF_X, PT_LOAD};
use crate::asm::Program;

/// `st_shndx` for an absolute symbol.
const SHN_ABS: u16 = 0xfff1;

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend(v.to_le_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend(v.to_le_bytes());
}

fn phdr(out: &mut Vec<u8>, offset: u32, vaddr: u32, filesz: u32, memsz: u32, flags: u32) {
    push_u32(out, PT_LOAD);
    push_u32(out, offset);
    push_u32(out, vaddr);
    push_u32(out, vaddr); // p_paddr
    push_u32(out, filesz);
    push_u32(out, memsz);
    push_u32(out, flags);
    push_u32(out, 4); // p_align
}

#[allow(clippy::too_many_arguments)]
fn shdr(
    out: &mut Vec<u8>,
    name: u32,
    sh_type: u32,
    addr: u32,
    offset: u32,
    size: u32,
    link: u32,
    entsize: u32,
) {
    push_u32(out, name);
    push_u32(out, sh_type);
    push_u32(out, 0); // sh_flags (unused by the loader)
    push_u32(out, addr);
    push_u32(out, offset);
    push_u32(out, size);
    push_u32(out, link);
    push_u32(out, 0); // sh_info
    push_u32(out, 4); // sh_addralign
    push_u32(out, entsize);
}

/// Serialise `prog` as an ELF32 executable. Symbols are emitted in
/// sorted name order so the output is byte-deterministic.
pub fn write_elf(prog: &Program) -> Vec<u8> {
    let has_data = !prog.data.is_empty();
    let phnum: u16 = if has_data { 2 } else { 1 };
    let phoff: u32 = 52;
    let text_off = phoff + (phnum as u32) * 32;
    let text_size = (prog.text.len() * 4) as u32;
    let data_off = text_off + text_size;
    let data_size = prog.data.len() as u32;

    // String table: leading NUL, then each symbol name NUL-terminated.
    let mut names: Vec<&str> = prog.symbols.keys().map(String::as_str).collect();
    names.sort_unstable();
    let mut strtab = vec![0u8];
    let mut name_off = Vec::with_capacity(names.len());
    for n in &names {
        name_off.push(strtab.len() as u32);
        strtab.extend(n.as_bytes());
        strtab.push(0);
    }

    // Symbol table: the null symbol plus one global absolute symbol per
    // program symbol.
    let mut symtab = vec![0u8; 16];
    for (n, &off) in names.iter().zip(&name_off) {
        push_u32(&mut symtab, off); // st_name
        push_u32(&mut symtab, prog.symbols[*n]); // st_value
        push_u32(&mut symtab, 0); // st_size
        symtab.push(0x10); // st_info: GLOBAL | NOTYPE
        symtab.push(0); // st_other
        push_u16(&mut symtab, SHN_ABS);
    }

    let shstrtab = b"\0.text\0.symtab\0.strtab\0.shstrtab\0";
    let (n_text, n_symtab, n_strtab, n_shstrtab) = (1u32, 7, 15, 23);

    let symtab_off = data_off + data_size;
    let strtab_off = symtab_off + symtab.len() as u32;
    let shstrtab_off = strtab_off + strtab.len() as u32;
    let shoff = shstrtab_off + shstrtab.len() as u32;

    let mut out = Vec::new();
    // ELF header.
    out.extend([0x7f, b'E', b'L', b'F', 1, 1, 1]);
    out.resize(16, 0);
    push_u16(&mut out, ET_EXEC);
    push_u16(&mut out, EM_RISCV);
    push_u32(&mut out, 1); // e_version
    push_u32(&mut out, prog.entry);
    push_u32(&mut out, phoff);
    push_u32(&mut out, shoff);
    push_u32(&mut out, 0); // e_flags
    push_u16(&mut out, 52); // e_ehsize
    push_u16(&mut out, 32); // e_phentsize
    push_u16(&mut out, phnum);
    push_u16(&mut out, 40); // e_shentsize
    push_u16(&mut out, 5); // e_shnum
    push_u16(&mut out, 4); // e_shstrndx
    debug_assert_eq!(out.len(), 52);

    phdr(&mut out, text_off, prog.text_base, text_size, text_size, PF_R | PF_X);
    if has_data {
        phdr(&mut out, data_off, prog.data_base, data_size, data_size, PF_R | PF_W);
    }
    for w in &prog.text {
        push_u32(&mut out, *w);
    }
    out.extend(&prog.data);
    out.extend(&symtab);
    out.extend(&strtab);
    out.extend(shstrtab);
    debug_assert_eq!(out.len() as u32, shoff);

    // Section headers: null, .text, .symtab (link → .strtab), .strtab,
    // .shstrtab.
    shdr(&mut out, 0, 0, 0, 0, 0, 0, 0);
    shdr(&mut out, n_text, 1, prog.text_base, text_off, text_size, 0, 0);
    shdr(&mut out, n_symtab, 2, 0, symtab_off, symtab.len() as u32, 3, 16);
    shdr(&mut out, n_strtab, 3, 0, strtab_off, strtab.len() as u32, 0, 0);
    shdr(&mut out, n_shstrtab, 3, 0, shstrtab_off, shstrtab.len() as u32, 0, 0);
    out
}

#[cfg(test)]
mod tests {
    use super::super::load_program;
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    #[test]
    fn round_trips_a_builder_program() {
        let mut a = Asm::new();
        let d = a.words("table", &[10, 20, 30, 40]);
        a.la(A0, d);
        a.lw(A1, 0, A0);
        a.halt();
        let p = a.assemble().unwrap();
        let back = load_program(&write_elf(&p)).unwrap();
        assert_eq!(back.text_base, p.text_base);
        assert_eq!(back.text, p.text);
        assert_eq!(back.data_base, p.data_base);
        assert_eq!(back.data, p.data);
        assert_eq!(back.entry, p.entry);
        for (name, &addr) in &p.symbols {
            assert_eq!(back.symbols.get(name), Some(&addr), "symbol {name}");
        }
    }

    #[test]
    fn programs_without_data_get_a_single_segment() {
        let mut a = Asm::new();
        a.li(A0, 1);
        a.halt();
        let p = a.assemble().unwrap();
        let elf = super::super::parse_elf(&write_elf(&p)).unwrap();
        assert_eq!(elf.segments.len(), 1);
        assert!(elf.segments[0].executable());
        let back = load_program(&write_elf(&p)).unwrap();
        assert_eq!(back.text, p.text);
        assert!(back.data.is_empty());
    }

    #[test]
    fn output_is_deterministic() {
        let mut a = Asm::new();
        a.words("b", &[2]);
        a.words("a", &[1]);
        a.li(A0, 1);
        a.halt();
        let p = a.assemble().unwrap();
        assert_eq!(write_elf(&p), write_elf(&p));
    }
}
